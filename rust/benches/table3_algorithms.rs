//! Bench: paper Table 3 — the ten arrangements on the simulated M1.
//!
//! Regenerates the paper's central table (simulated contextual times) and
//! measures the *native* execution time of every arrangement on this host
//! as the "this testbed" column, plus the end-to-end planner latencies.

use spfft::cost::SimCost;
use spfft::fft::{Executor, SplitComplex};
use spfft::planner::{plan as run_plan, Strategy};
use spfft::report;
use spfft::util::bench::{black_box, Bench};
use spfft::util::stats::gflops;

fn main() {
    let n = 1024;
    let mut bench = Bench::from_env("table3_algorithms");

    // --- regenerate the paper table from the simulator ---
    let mut cost = SimCost::m1(n);
    println!("{}", report::table3(&mut cost));

    // --- native-host measurement of the same arrangements ---
    println!("native execution on this host (same arrangements):");
    let mut ex = Executor::new();
    let rows = report::table3_rows(&mut cost);
    let mut compiled = Vec::new();
    for row in &rows {
        compiled.push((row.label.clone(), ex.compile(&row.plan, n, true)));
    }
    for (label, cp) in compiled {
        let input = SplitComplex::random(n, 7);
        let mut buf = input.clone();
        bench.bench(format!("native/{label}"), move || {
            buf.re.copy_from_slice(&input.re);
            buf.im.copy_from_slice(&input.im);
            cp.run(&mut buf.re, &mut buf.im);
            black_box(&buf);
        });
    }

    // --- planner latency (the "completes in seconds" claim, §2.5) ---
    bench.bench("planner/dijkstra-context-free", move || {
        let mut c = SimCost::m1(1024);
        black_box(run_plan(&mut c, &Strategy::DijkstraContextFree));
    });
    bench.bench("planner/dijkstra-context-aware", move || {
        let mut c = SimCost::m1(1024);
        black_box(run_plan(&mut c, &Strategy::DijkstraContextAware { k: 1 }));
    });
    bench.bench("planner/exhaustive-640-plans", move || {
        let mut c = SimCost::m1(1024);
        black_box(run_plan(&mut c, &Strategy::Exhaustive));
    });

    let results = bench.run();
    // print a GFLOPS summary for the native rows
    println!("\nnative GFLOPS summary (5*N*log2 N convention):");
    for r in &results {
        if let Some(name) = r.name.strip_prefix("native/") {
            println!("  {:<44} {:>7.2} GFLOPS", name, gflops(n, r.summary.median));
        }
    }
}
