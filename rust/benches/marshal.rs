//! Bench: price the panel marshal (the gather → scatter round trip).
//!
//! For n ∈ {64, 256, 1024} and B ∈ {2, 4, 16, 64}: measure
//!
//! * the marshal alone — `gather` into a pooled lane panel plus the
//!   allocation-free `scatter_lane_into` back into each request's own
//!   buffer, no execution (this is the data movement the cost model's
//!   `marshal_ns` prices and `ExecMode` decisions charge to the panel);
//! * the full panel path (marshal + `run_batch`) per transform;
//! * the zero-copy scalar-sequential path (`run` in place per request);
//!
//! then report the panel-vs-sequential crossover batch per n next to
//! the m1 simulator's predicted decision, and write
//! `BENCH_marshal.json`. A small transform's marshal can exceed its
//! entire arithmetic — the measured reason the mode decision is priced
//! per (kind, n, B) instead of hard-wired at "2 or more".

use std::collections::BTreeMap;
use std::time::Instant;

use spfft::cost::{exec_mode_for, CostModel, ExecMode, SimCost};
use spfft::fft::{BatchBufferPool, Executor, SplitComplex};
use spfft::kind::TransformKind;
use spfft::planner::{plan as run_plan, Strategy};
use spfft::util::bench::{black_box, fmt_ns};
use spfft::util::json::{to_string as json_to_string, Json};
use spfft::util::stats::median;

const SIZES: [usize; 3] = [64, 256, 1024];
const BATCHES: [usize; 4] = [2, 4, 16, 64];

/// Median ns of `reps` timed executions of `f`.
fn median_ns(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    median(&samples)
}

fn main() {
    let quick =
        std::env::args().any(|a| a == "--quick") || std::env::var("SPFFT_BENCH_QUICK").is_ok();
    println!("== bench suite: marshal{} ==", if quick { " (quick)" } else { "" });

    let reps = if quick { 15 } else { 51 };
    let inner = if quick { 8 } else { 32 };
    let mut pool = BatchBufferPool::new();
    let mut jrows: Vec<Json> = Vec::new();
    let mut crossovers: Vec<(usize, Option<usize>)> = Vec::new();

    for &n in &SIZES {
        let plan = run_plan(&mut SimCost::m1(n), &Strategy::DijkstraContextAware { k: 1 }).plan;
        let mut ex = Executor::new();
        let cp = ex.compile(&plan, n, true);
        println!("n = {n}: plan {plan}");
        let mut crossover: Option<usize> = None;

        for &b in &BATCHES {
            let inputs: Vec<SplitComplex> =
                (0..b).map(|i| SplitComplex::random(n, 3 + i as u64)).collect();
            let refs: Vec<&SplitComplex> = inputs.iter().collect();
            let mut outs = inputs.clone();

            // Marshal alone: the round trip the panel pays and the
            // scalar path never does.
            let marshal_ns = median_ns(reps, || {
                for _ in 0..inner {
                    let mut buf = pool.acquire(n, b);
                    buf.gather(&refs);
                    for (lane, out) in outs.iter_mut().enumerate() {
                        buf.scatter_lane_into(lane, out);
                    }
                    black_box(&outs);
                    pool.release(buf);
                }
            }) / (inner * b) as f64;

            // Full panel path, exactly the worker hot path.
            let panel_ns = median_ns(reps, || {
                for _ in 0..inner {
                    let mut buf = pool.acquire(n, b);
                    buf.gather(&refs);
                    cp.run_batch(&mut buf);
                    for (lane, out) in outs.iter_mut().enumerate() {
                        buf.scatter_lane_into(lane, out);
                    }
                    black_box(&outs);
                    pool.release(buf);
                }
            }) / (inner * b) as f64;

            // Zero-copy scalar-sequential: in place, no staging at all.
            let mut bufs = inputs.clone();
            let scalar_ns = median_ns(reps, || {
                for _ in 0..inner {
                    for s in bufs.iter_mut() {
                        cp.run(&mut s.re, &mut s.im);
                    }
                    black_box(&bufs);
                }
            }) / (inner * b) as f64;

            let mut model = SimCost::m1(n);
            let predicted = exec_mode_for(&mut model, TransformKind::Forward, &plan, b);
            let predicted_marshal_ns = model.marshal_ns(b) / b as f64;
            let panel_wins = panel_ns < scalar_ns;
            if panel_wins && crossover.is_none() {
                crossover = Some(b);
            }
            println!(
                "  B={b:<3} marshal {:>9}/tx (m1 predicts {:>9}/tx)   panel {:>9}/tx   scalar {:>9}/tx   {} (m1 says {})",
                fmt_ns(marshal_ns),
                fmt_ns(predicted_marshal_ns),
                fmt_ns(panel_ns),
                fmt_ns(scalar_ns),
                if panel_wins { "panel wins" } else { "scalar wins" },
                predicted.label(),
            );

            let mut o = BTreeMap::new();
            o.insert("n".into(), Json::Num(n as f64));
            o.insert("b".into(), Json::Num(b as f64));
            o.insert("marshal_ns_per_transform".into(), Json::Num(marshal_ns));
            o.insert("predicted_marshal_ns_per_transform".into(), Json::Num(predicted_marshal_ns));
            o.insert("panel_ns_per_transform".into(), Json::Num(panel_ns));
            o.insert("scalar_ns_per_transform".into(), Json::Num(scalar_ns));
            o.insert("panel_wins".into(), Json::Bool(panel_wins));
            o.insert(
                "m1_decision".into(),
                Json::Str(
                    match predicted {
                        ExecMode::Panel => "panel",
                        ExecMode::ScalarSequential => "scalar",
                    }
                    .into(),
                ),
            );
            jrows.push(Json::Obj(o));
        }
        println!(
            "  crossover: {}",
            match crossover {
                Some(b) => format!("panel from B={b}"),
                None => "scalar at every measured B".to_string(),
            }
        );
        crossovers.push((n, crossover));
    }

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("marshal".into()));
    // Distinguishes a real run from the hand-authored schema example
    // committed from a toolchain-less container — tooling should gate on
    // this, not on the free-text provenance.
    root.insert("measured".to_string(), Json::Bool(true));
    root.insert("rows".to_string(), Json::Arr(jrows));
    root.insert(
        "crossover".to_string(),
        Json::Arr(
            crossovers
                .iter()
                .map(|(n, c)| {
                    let mut o = BTreeMap::new();
                    o.insert("n".into(), Json::Num(*n as f64));
                    o.insert(
                        "panel_wins_from_b".into(),
                        c.map(|b| Json::Num(b as f64)).unwrap_or(Json::Null),
                    );
                    Json::Obj(o)
                })
                .collect(),
        ),
    );
    let out = json_to_string(&Json::Obj(root));
    std::fs::write("BENCH_marshal.json", &out).expect("writing BENCH_marshal.json");
    println!("wrote BENCH_marshal.json");
}
