//! Bench: flat vs four-step blocked execution across the resident
//! boundary.
//!
//! For n from 2^12 (comfortably cache-resident) to 2^18 (well past any
//! L2), run the planner's flat arrangement and a balanced-split blocked
//! execution side by side: per-transform ns, GFLOPS, the measured
//! blocked/flat speedup, and — next to the measurements — what
//! `plan_exec` on the m1 simulator *believed* the decision should be,
//! so the modeled crossover and the measured crossover sit in one
//! table. Verifies both paths against the f64 reference (the blocked
//! contract is a pinned rel-error bound, NOT bit-identity to flat) and
//! writes `BENCH_fourstep.json`.

use std::collections::BTreeMap;
use std::time::Instant;

use spfft::cost::{CostModel, PlanningSurface, SimCost};
use spfft::fft::fourstep::radix_mix_plan;
use spfft::fft::reference::fft_ref;
use spfft::fft::{log2i, CompiledExec, Executor, SplitComplex};
use spfft::kind::TransformKind;
use spfft::plan::ExecPlan;
use spfft::planner::{plan as run_plan, plan_exec, Strategy};
use spfft::util::bench::{black_box, fmt_ns};
use spfft::util::json::{to_string as json_to_string, Json};
use spfft::util::stats::{gflops, median};

const SIZES: [usize; 4] = [1 << 12, 1 << 14, 1 << 16, 1 << 18];
const REL_BOUND: f64 = 5e-4;

/// Median ns of `reps` timed executions of `f`.
fn median_ns(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    median(&samples)
}

struct Row {
    n: usize,
    p: usize,
    q: usize,
    flat_ns: f64,
    blocked_ns: f64,
    speedup: f64,
    flat_gflops: f64,
    blocked_gflops: f64,
    modeled_blocked: bool,
    modeled_speedup: f64,
}

fn main() {
    let quick =
        std::env::args().any(|a| a == "--quick") || std::env::var("SPFFT_BENCH_QUICK").is_ok();
    println!("== bench suite: fourstep{} ==", if quick { " (quick)" } else { "" });

    let strategy = Strategy::DijkstraContextAware { k: 1 };
    let resident_limit = SimCost::m1(SIZES[0]).resident_limit_n();
    println!("m1 modeled resident limit: n <= {resident_limit}");

    let mut ex = Executor::new();
    let mut rows = Vec::new();
    let mut accuracy_ok = true;

    for &n in &SIZES {
        let l = log2i(n);
        let flat_plan = run_plan(&mut SimCost::m1(n), &strategy).plan;
        // balanced split; col/row interiors use the serviceable radix
        // mix so every size measures the same sub-plan family
        let (lp, lq) = (l / 2, l - l / 2);
        let (p, q) = (1usize << lp, 1usize << lq);
        let blocked_plan = ExecPlan::Blocked {
            p,
            q,
            col: radix_mix_plan(lp),
            row: radix_mix_plan(lq),
        };
        let mut flat =
            CompiledExec::compile(&mut ex, &ExecPlan::Flat(flat_plan.clone()), n, TransformKind::Forward);
        let mut blocked = CompiledExec::compile(&mut ex, &blocked_plan, n, TransformKind::Forward);

        // Correctness gate before any timing is trusted: both paths
        // within the pinned rel-error bound of the f64 reference.
        let input = SplitComplex::random(n, 0x45EF + n as u64);
        let want = fft_ref(&input);
        for (label, exec) in [("flat", &mut flat), ("blocked", &mut blocked)] {
            let mut out = input.clone();
            exec.run(&mut out.re, &mut out.im);
            let rel = (out.max_abs_diff(&want) / want.max_abs().max(1.0)) as f64;
            if rel >= REL_BOUND {
                accuracy_ok = false;
                eprintln!("ACCURACY FAILURE: {label} n={n} rel err {rel}");
            }
        }

        // fewer reps at the large sizes — each rep is O(n log n) work
        let reps = match (quick, n) {
            (true, _) => 5,
            (false, n) if n <= 1 << 14 => 21,
            _ => 9,
        };
        let mut buf = input.clone();
        let flat_ns = median_ns(reps, || {
            buf.re.copy_from_slice(&input.re);
            buf.im.copy_from_slice(&input.im);
            flat.run(&mut buf.re, &mut buf.im);
            black_box(&buf);
        });
        let blocked_ns = median_ns(reps, || {
            buf.re.copy_from_slice(&input.re);
            buf.im.copy_from_slice(&input.im);
            blocked.run(&mut buf.re, &mut buf.im);
            black_box(&buf);
        });

        // the modeled decision, for the crossover comparison
        let out = plan_exec(&mut |m| SimCost::m1(m), n, &strategy, PlanningSurface::forward(), None);
        let row = Row {
            n,
            p,
            q,
            flat_ns,
            blocked_ns,
            speedup: flat_ns / blocked_ns,
            flat_gflops: gflops(n, flat_ns),
            blocked_gflops: gflops(n, blocked_ns),
            modeled_blocked: out.exec.is_blocked(),
            modeled_speedup: out.flat_ns / out.believed_ns,
        };
        println!(
            "n=2^{:<2} flat {:>10} ({:>6.1} GFLOPS)   blocked[{}x{}] {:>10} ({:>6.1} GFLOPS)   speedup {:>5.2}x   model: {} ({:.2}x)",
            l,
            fmt_ns(row.flat_ns),
            row.flat_gflops,
            p,
            q,
            fmt_ns(row.blocked_ns),
            row.blocked_gflops,
            row.speedup,
            if row.modeled_blocked { "blocked" } else { "flat" },
            row.modeled_speedup,
        );
        rows.push(row);
    }

    println!("accuracy vs reference : {}", if accuracy_ok { "PASS" } else { "FAIL" });
    let crossover = rows.iter().find(|r| r.speedup > 1.0).map(|r| r.n);
    match crossover {
        Some(n) => println!("measured crossover    : blocked first wins at n = {n}"),
        None => println!("measured crossover    : flat wins everywhere on this host"),
    }

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("fourstep".into()));
    // Distinguishes a real run from the hand-authored schema example
    // committed from a toolchain-less container — tooling should gate on
    // this, not on the free-text provenance.
    root.insert("measured".to_string(), Json::Bool(true));
    root.insert("rel_bound".to_string(), Json::Num(REL_BOUND));
    root.insert("accuracy_ok".to_string(), Json::Bool(accuracy_ok));
    root.insert(
        "modeled_resident_limit_n".to_string(),
        Json::Num(resident_limit as f64),
    );
    let jrows: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut o = BTreeMap::new();
            o.insert("n".into(), Json::Num(r.n as f64));
            o.insert("p".into(), Json::Num(r.p as f64));
            o.insert("q".into(), Json::Num(r.q as f64));
            o.insert("flat_ns".into(), Json::Num(r.flat_ns));
            o.insert("blocked_ns".into(), Json::Num(r.blocked_ns));
            o.insert("speedup".into(), Json::Num(r.speedup));
            o.insert("flat_gflops".into(), Json::Num(r.flat_gflops));
            o.insert("blocked_gflops".into(), Json::Num(r.blocked_gflops));
            o.insert("modeled_blocked".into(), Json::Bool(r.modeled_blocked));
            o.insert("modeled_speedup".into(), Json::Num(r.modeled_speedup));
            Json::Obj(o)
        })
        .collect();
    root.insert("rows".to_string(), Json::Arr(jrows));
    match crossover {
        Some(n) => root.insert("measured_crossover_n".to_string(), Json::Num(n as f64)),
        None => root.insert("measured_crossover_n".to_string(), Json::Null),
    };
    let out = json_to_string(&Json::Obj(root));
    std::fs::write("BENCH_fourstep.json", &out).expect("writing BENCH_fourstep.json");
    println!("wrote BENCH_fourstep.json");

    if !accuracy_ok {
        std::process::exit(1);
    }
}
