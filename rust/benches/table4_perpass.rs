//! Bench: paper Table 4 — per-pass profile of individual radix-2 passes.
//!
//! Prints the simulated isolation profile (the U-curve whose right side
//! motivates fused blocks) and measures each native radix-2 pass on this
//! host with the paper's isolation protocol.

use spfft::cost::SimCost;
use spfft::edge::EdgeType;
use spfft::fft::{Executor, SplitComplex};
use spfft::report;
use spfft::util::bench::{black_box, Bench};

fn main() {
    let n = 1024;
    let l = 10;
    let mut cost = SimCost::m1(n);
    println!("{}", report::table4(&mut cost));

    let mut bench = Bench::from_env("table4_perpass");
    let mut ex = Executor::new();
    let k = ex.kernels();
    for stage in 0..l {
        let step = ex.compile_edge(n, EdgeType::R2, stage);
        let mut buf = SplitComplex::random(n, 11);
        bench.bench(
            format!("native/r2-pass{:02}-stride{}", stage + 1, (n >> stage) / 2),
            move || {
                spfft::fft::exec::run_step(k, &step, &mut buf.re, &mut buf.im);
                black_box(&buf);
            },
        );
    }
    for e in [EdgeType::F8, EdgeType::F16] {
        let step = ex.compile_edge(n, e, l - e.stages());
        let mut buf = SplitComplex::random(n, 12);
        bench.bench(format!("native/fused{}", e.block_size().unwrap()), move || {
            spfft::fft::exec::run_step(k, &step, &mut buf.re, &mut buf.im);
            black_box(&buf);
        });
    }
    let results = bench.run();
    println!("\nper-pass GFLOPS on this host (5N per radix-2 pass):");
    for r in &results {
        if r.name.contains("r2-pass") {
            println!("  {:<36} {:>7.2}", r.name, 5.0 * n as f64 / r.summary.median);
        }
    }
}
