//! Bench: paper Table 1 — the edge catalog, with a native timing sanity
//! pass over one representative placement of each edge type.

use spfft::edge::ALL_EDGES;
use spfft::fft::{Executor, SplitComplex};
use spfft::report;
use spfft::util::bench::{black_box, Bench};

fn main() {
    println!("{}", report::table1());
    let n = 1024;
    let l = 10;
    let mut bench = Bench::from_env("table1_edges");
    let mut ex = Executor::new();
    let k = ex.kernels();
    for e in ALL_EDGES {
        // representative placements: first valid stage and terminal stage
        for stage in [0usize, l - e.stages()] {
            let step = ex.compile_edge(n, e, stage);
            let mut buf = SplitComplex::random(n, 3);
            bench.bench(format!("edge/{}@{}", e.name(), stage), move || {
                spfft::fft::exec::run_step(k, &step, &mut buf.re, &mut buf.im);
                black_box(&buf);
            });
            if e.stages() == l {
                break;
            }
        }
    }
    bench.run();
}
