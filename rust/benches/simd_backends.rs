//! Bench: the per-ISA codelet backends against the scalar table.
//!
//! For each pinnable backend (scalar, portable `std::simd`, NEON,
//! AVX2): resolve it through `Executor::with_isa` (falling back to
//! scalar where the host lacks the feature — the fallback is part of
//! what this measures: a pinned-but-absent backend must cost exactly
//! scalar), gate on bit-identity against the scalar kernels, then time
//! the CA-optimal m1 plan unbatched and at B = 16 through the
//! lane-blocked `_b` forms. Reports per-transform ns, GFLOPS, and the
//! speedup over scalar, and writes `BENCH_simd.json`.

use std::collections::BTreeMap;
use std::time::Instant;

use spfft::cost::SimCost;
use spfft::fft::{BatchBuffer, Executor, SplitComplex};
use spfft::isa::{Isa, ALL_ISAS};
use spfft::planner::{plan as run_plan, Strategy};
use spfft::util::bench::{black_box, fmt_ns};
use spfft::util::json::{to_string as json_to_string, Json};
use spfft::util::stats::{gflops, median};

const N: usize = 1024;
const B: usize = 16;

/// Median ns of `reps` timed executions of `f`.
fn median_ns(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    median(&samples)
}

struct Row {
    requested: Isa,
    resolved: Isa,
    ns_per_tx: f64,
    batched_ns_per_tx: f64,
    gflops: f64,
}

fn main() {
    let quick =
        std::env::args().any(|a| a == "--quick") || std::env::var("SPFFT_BENCH_QUICK").is_ok();
    println!("== bench suite: simd_backends{} ==", if quick { " (quick)" } else { "" });

    let plan = run_plan(&mut SimCost::m1(N), &Strategy::DijkstraContextAware { k: 1 }).plan;
    println!("plan: {plan}  (n = {N})   host backend: {}", Isa::detect());

    let reps = if quick { 15 } else { 51 };
    let inner = if quick { 8 } else { 32 };
    let input = SplitComplex::random(N, 42);
    let inputs: Vec<SplitComplex> = (0..B).map(|i| SplitComplex::random(N, 7 + i as u64)).collect();
    let refs: Vec<&SplitComplex> = inputs.iter().collect();

    let mut scalar_ex = Executor::with_isa(Isa::Scalar);
    let scalar_cp = scalar_ex.compile(&plan, N, true);
    let want = scalar_cp.run_on(&input);

    let mut rows = Vec::new();
    let mut all_bit_identical = true;
    for &isa in ALL_ISAS.iter() {
        let mut ex = Executor::with_isa(isa);
        let resolved = ex.isa();
        let cp = ex.compile(&plan, N, true);

        // Correctness gate before any timing is trusted: unbatched and
        // every batched lane bit-identical to the scalar kernels.
        if cp.run_on(&input) != want {
            all_bit_identical = false;
            eprintln!("BIT-IDENTITY FAILURE: {isa} (resolved {resolved}) unbatched");
        }
        let mut buf = BatchBuffer::new(N, B);
        buf.gather(&refs);
        cp.run_batch(&mut buf);
        for (lane, lane_in) in inputs.iter().enumerate() {
            if buf.scatter_lane(lane) != scalar_cp.run_on(lane_in) {
                all_bit_identical = false;
                eprintln!("BIT-IDENTITY FAILURE: {isa} (resolved {resolved}) lane {lane}");
            }
        }

        let ns = median_ns(reps, || {
            for _ in 0..inner {
                black_box(cp.run_on(black_box(&input)));
            }
        }) / inner as f64;
        let batched_ns = median_ns(reps, || {
            let mut buf = BatchBuffer::new(N, B);
            buf.gather(&refs);
            cp.run_batch(&mut buf);
            black_box(&buf);
        }) / B as f64;

        let row = Row {
            requested: isa,
            resolved,
            ns_per_tx: ns,
            batched_ns_per_tx: batched_ns,
            gflops: gflops(N, ns),
        };
        println!(
            "{:<9} -> {:<8} {:>10}/tx ({:>6.1} GFLOPS)   batched B={B} {:>10}/tx",
            row.requested.name(),
            row.resolved.name(),
            fmt_ns(row.ns_per_tx),
            row.gflops,
            fmt_ns(row.batched_ns_per_tx),
        );
        rows.push(row);
    }

    let scalar_ns = rows[0].ns_per_tx;
    println!("bit-identical outputs : {}", if all_bit_identical { "PASS" } else { "FAIL" });
    for r in &rows[1..] {
        let note = if r.resolved == Isa::Scalar { " (scalar fallback on this host)" } else { "" };
        println!("{:<9} vs scalar     : {:.2}x{note}", r.requested.name(), scalar_ns / r.ns_per_tx);
    }

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("simd_backends".into()));
    // Distinguishes a real run from the hand-authored schema example
    // committed from a toolchain-less container — tooling gates on this.
    root.insert("measured".to_string(), Json::Bool(true));
    root.insert(
        "provenance".to_string(),
        Json::Str(format!(
            "measured by `cargo bench --bench simd_backends`; host backend {}; pinned \
             backends the host lacks resolve to scalar (their rows measure the fallback)",
            Isa::detect()
        )),
    );
    root.insert("n".to_string(), Json::Num(N as f64));
    root.insert("plan".to_string(), Json::Str(plan.to_string()));
    root.insert("host_backend".to_string(), Json::Str(Isa::detect().name().into()));
    root.insert("bit_identical".to_string(), Json::Bool(all_bit_identical));
    let jrows: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut o = BTreeMap::new();
            o.insert("isa".into(), Json::Str(r.requested.name().into()));
            o.insert("resolved".into(), Json::Str(r.resolved.name().into()));
            o.insert("ns_per_transform".into(), Json::Num(r.ns_per_tx));
            o.insert("batched_ns_per_transform".into(), Json::Num(r.batched_ns_per_tx));
            o.insert("gflops".into(), Json::Num(r.gflops));
            o.insert("speedup_vs_scalar".into(), Json::Num(scalar_ns / r.ns_per_tx));
            Json::Obj(o)
        })
        .collect();
    root.insert("rows".to_string(), Json::Arr(jrows));
    let out = json_to_string(&Json::Obj(root));
    std::fs::write("BENCH_simd.json", &out).expect("writing BENCH_simd.json");
    println!("wrote BENCH_simd.json");

    if !all_bit_identical {
        std::process::exit(1);
    }
}
