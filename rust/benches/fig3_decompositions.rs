//! Bench: paper Figure 3 — the three compared decompositions (pure
//! radix-2, context-free choice, context-aware choice), with per-edge
//! contextual costs and native end-to-end times for each.

use spfft::cost::SimCost;
use spfft::edge::EdgeType;
use spfft::fft::{Executor, SplitComplex};
use spfft::plan::Plan;
use spfft::planner::{plan as run_plan, Strategy};
use spfft::report;
use spfft::util::bench::{black_box, Bench};

fn main() {
    let n = 1024;
    let mut cost = SimCost::m1(n);
    println!("{}", report::figure3(&mut cost));

    let mut bench = Bench::from_env("fig3_decompositions");
    let pure = Plan::new(vec![EdgeType::R2; 10]);
    let cf = run_plan(&mut cost, &Strategy::DijkstraContextFree).plan;
    let ca = run_plan(&mut cost, &Strategy::DijkstraContextAware { k: 1 }).plan;
    let mut ex = Executor::new();
    for (name, plan) in [("pure-radix2", &pure), ("context-free", &cf), ("context-aware", &ca)] {
        let cp = ex.compile(plan, n, true);
        let input = SplitComplex::random(n, 9);
        let mut buf = input.clone();
        bench.bench(format!("native/{name} [{plan}]"), move || {
            buf.re.copy_from_slice(&input.re);
            buf.im.copy_from_slice(&input.im);
            cp.run(&mut buf.re, &mut buf.im);
            black_box(&buf);
        });
    }
    bench.run();
}
