//! Bench: native FFT hot path across sizes — the §Perf optimization
//! target for Layer 3's compute substrate (plan execution must be the
//! dominant cost, not coordination).

use spfft::fft::{Executor, SplitComplex};
use spfft::plan::Plan;
use spfft::util::bench::{black_box, Bench};
use spfft::util::stats::gflops;

fn best_native_plan(l: usize) -> Plan {
    // greedy R4 body + terminal F8 (a strong generic arrangement)
    let mut edges = Vec::new();
    let mut s = 0;
    while l - s > 3 && l - s - 3 >= 2 {
        edges.push(spfft::edge::EdgeType::R4);
        s += 2;
    }
    while l - s > 3 {
        edges.push(spfft::edge::EdgeType::R2);
        s += 1;
    }
    edges.push(spfft::edge::EdgeType::F8);
    Plan::new(edges)
}

fn main() {
    let mut bench = Bench::from_env("native_fft");
    let mut ex = Executor::new();
    let sizes = [64usize, 256, 1024, 4096, 16384];
    for n in sizes {
        let l = spfft::fft::log2i(n);
        for (name, plan) in [
            ("r2-chain", Plan::new(vec![spfft::edge::EdgeType::R2; l])),
            ("planned", best_native_plan(l)),
        ] {
            let cp = ex.compile(&plan, n, true);
            let input = SplitComplex::random(n, 1);
            let mut buf = input.clone();
            bench.bench(format!("fft{n}/{name}"), move || {
                buf.re.copy_from_slice(&input.re);
                buf.im.copy_from_slice(&input.im);
                cp.run(&mut buf.re, &mut buf.im);
                black_box(&buf);
            });
        }
    }
    let results = bench.run();
    println!("\nGFLOPS by size:");
    for r in &results {
        let n: usize = r.name[3..].split('/').next().unwrap().parse().unwrap();
        println!("  {:<24} {:>7.2} GFLOPS", r.name, gflops(n, r.summary.median));
    }
}
