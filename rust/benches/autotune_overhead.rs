//! Bench: autotune hot-path overhead + the drift→swap trajectory.
//!
//! Two claims are measured and written to `BENCH_autotune.json`:
//!
//! 1. **Sampling overhead < 2%** — the native execute loop with 1-in-64
//!    trace sampling (the production default) vs sampling disabled. The
//!    untraced path pays one relaxed atomic increment; traced requests
//!    (1/64 of them) pay per-edge `Instant` reads and one bounded
//!    `try_send`.
//! 2. **Drift trajectory** — a live service on the simulator oracle:
//!    steady-state GFLOPS before a 25x Fused-8 drift event, the degraded
//!    GFLOPS the frozen plan would serve, the recovered GFLOPS after the
//!    autotuner's hot swap, the swap latency, and how many requests
//!    convergence took.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use spfft::autotune::{trace_request, AutotuneConfig, SampleMode, TraceSampler};
use spfft::coordinator::{Backend, BatchPolicy, FftService, ServiceConfig};
use spfft::cost::{CostModel, SimCost, TableCost, Wisdom};
use spfft::edge::EdgeType;
use spfft::fft::{Executor, SplitComplex};
use spfft::plan::Plan;
use spfft::planner::{plan as run_plan, Strategy};
use spfft::util::bench::{black_box, fmt_ns};
use spfft::util::json::{to_string as json_to_string, Json};
use spfft::util::stats::gflops;

const N: usize = 1024;
const SAMPLE_PERIOD: u64 = 64;
const INFLATION: f64 = 25.0;

/// Median ns/request of `iters` executions of `f`, over `reps` samples.
fn median_ns_per_iter(reps: usize, iters: usize, mut f: impl FnMut()) -> f64 {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    spfft::util::stats::median(&samples)
}

fn overhead_section(quick: bool) -> (f64, f64, f64) {
    let plan = run_plan(&mut SimCost::m1(N), &Strategy::DijkstraContextAware { k: 1 }).plan;
    let mut ex = Executor::new();
    let cp = ex.compile(&plan, N, true);
    let input = SplitComplex::random(N, 7);
    let (reps, iters) = if quick { (9, 400) } else { (21, 2_000) };

    // Baseline: sampling disabled entirely.
    let base = median_ns_per_iter(reps, iters, || {
        black_box(cp.run_on(black_box(&input)));
    });

    // Production shape: sampler gate on every request, 1-in-64 traced;
    // a drainer thread plays the autotuner so try_send stays non-full.
    let (sampler, rx) = TraceSampler::new(SAMPLE_PERIOD, 1024);
    let sampler = Arc::new(sampler);
    let drainer = std::thread::spawn(move || while rx.recv().is_ok() {});
    let mode = SampleMode::Wallclock;
    let sampled = median_ns_per_iter(reps, iters, || {
        if sampler.should_sample() {
            let mut samples = Vec::with_capacity(cp.steps().len());
            let out = trace_request(&cp, black_box(&input), &mode, &mut samples);
            sampler.submit(samples);
            black_box(out);
        } else {
            black_box(cp.run_on(black_box(&input)));
        }
    });
    // Dropping the sampler closes the channel; the drainer then exits.
    drop(sampler);
    let _ = drainer.join();

    let pct = (sampled - base) / base * 100.0;
    (base, sampled, pct)
}

struct Trajectory {
    gflops_before: f64,
    gflops_drifted_frozen: f64,
    gflops_after_swap: f64,
    swap_latency_ns: u64,
    requests_to_converge: u64,
    swaps: u64,
    plan_before: Plan,
    plan_after: Plan,
}

fn trajectory_section(quick: bool) -> Trajectory {
    let machine = spfft::sim::Machine::m1();
    let prior = Wisdom::harvest(&mut SimCost::m1(N), "sim:m1");
    let initial = run_plan(&mut SimCost::m1(N), &Strategy::DijkstraContextAware { k: 1 }).plan;

    // True post-drift weights: every F8 cell inflated.
    let mut inflated = TableCost {
        n: N,
        edges: prior.cells.iter().map(|c| c.0).collect::<std::collections::BTreeSet<_>>().into_iter().collect(),
        cells: prior
            .cells
            .iter()
            .map(|&(e, s, ctx, ns)| ((e, s, ctx), if e == EdgeType::F8 { ns * INFLATION } else { ns }))
            .collect(),
    };

    let drifted = Arc::new(AtomicBool::new(false));
    let oracle_switch = drifted.clone();
    let oracle_machine = machine.clone();
    let mode = SampleMode::Oracle(Arc::new(move |e, s, ctx| {
        let base = oracle_machine.edge_ns(N, e, s, ctx);
        if e == EdgeType::F8 && oracle_switch.load(Ordering::Relaxed) {
            base * INFLATION
        } else {
            base
        }
    }));

    let mut at = AutotuneConfig::new(prior.clone());
    at.sample_period = 1;
    at.check_every = 8;
    at.drift_min_samples = 4;
    at.ewma_alpha = 1.0;
    at.blend_samples = 1.0;
    at.mode = mode;
    let svc = FftService::start(ServiceConfig {
        plans: vec![(N, initial.clone())],
        backend: Backend::Native,
        batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(50) },
        workers: 2,
        coalesce: Default::default(),
        queue_depth: 128,
        autotune: Some(at),
        shed_deadline: None,
        observer: None,
        exec_mode: Default::default(),
        max_resident_n: None,
    })
    .expect("service");

    let warm = if quick { 50 } else { 200 };
    for i in 0..warm {
        let _ = svc.transform(SplitComplex::random(N, i));
    }
    drifted.store(true, Ordering::Relaxed);
    let budget: u64 = if quick { 10_000 } else { 30_000 };
    let mut requests_to_converge = budget;
    let expected = run_plan(&mut inflated, &Strategy::DijkstraContextAware { k: 1 }).plan;
    for i in 0..budget {
        let _ = svc.transform(SplitComplex::random(N, 1_000_000 + i));
        let status = svc.autotune_status().expect("status");
        if status.active_plan == expected {
            requests_to_converge = i + 1;
            break;
        }
    }
    let status = svc.autotune_status().expect("status");
    let final_plan = status.active_plan.clone();
    svc.shutdown();

    Trajectory {
        gflops_before: gflops(N, machine.plan_ns(N, &initial)),
        gflops_drifted_frozen: gflops(N, inflated.plan_ns(&initial)),
        gflops_after_swap: gflops(N, inflated.plan_ns(&final_plan)),
        swap_latency_ns: status.last_swap_latency_ns,
        requests_to_converge,
        swaps: status.swaps,
        plan_before: initial,
        plan_after: final_plan,
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick") || std::env::var("SPFFT_BENCH_QUICK").is_ok();
    println!("== bench suite: autotune_overhead{} ==", if quick { " (quick)" } else { "" });

    let (base_ns, sampled_ns, pct) = overhead_section(quick);
    println!(
        "hot path, sampling off : {:>12} /request",
        fmt_ns(base_ns)
    );
    println!(
        "hot path, 1/{} sampled : {:>12} /request",
        SAMPLE_PERIOD,
        fmt_ns(sampled_ns)
    );
    println!(
        "sampling overhead      : {pct:+.2}%  (budget < 2%) {}",
        if pct < 2.0 { "PASS" } else { "WARN: over budget on this host" }
    );

    let t = trajectory_section(quick);
    println!(
        "steady-state before drift : {:>6.1} GFLOPS ({})",
        t.gflops_before, t.plan_before
    );
    println!(
        "frozen plan after drift   : {:>6.1} GFLOPS (no autotuning)",
        t.gflops_drifted_frozen
    );
    println!(
        "after hot swap            : {:>6.1} GFLOPS ({})",
        t.gflops_after_swap, t.plan_after
    );
    println!(
        "swap latency {}  convergence {} requests  swaps {}",
        fmt_ns(t.swap_latency_ns as f64),
        t.requests_to_converge,
        t.swaps
    );

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("autotune".into()));
    root.insert("n".to_string(), Json::Num(N as f64));
    root.insert("sample_period".to_string(), Json::Num(SAMPLE_PERIOD as f64));
    root.insert("hot_path_ns_sampling_off".to_string(), Json::Num(base_ns));
    root.insert("hot_path_ns_sampling_on".to_string(), Json::Num(sampled_ns));
    root.insert("sampling_overhead_pct".to_string(), Json::Num(pct));
    root.insert("sampling_overhead_budget_pct".to_string(), Json::Num(2.0));
    root.insert("gflops_steady_before_drift".to_string(), Json::Num(t.gflops_before));
    root.insert("gflops_frozen_after_drift".to_string(), Json::Num(t.gflops_drifted_frozen));
    root.insert("gflops_after_hot_swap".to_string(), Json::Num(t.gflops_after_swap));
    root.insert("swap_latency_ns".to_string(), Json::Num(t.swap_latency_ns as f64));
    root.insert(
        "requests_to_converge".to_string(),
        Json::Num(t.requests_to_converge as f64),
    );
    root.insert("swaps".to_string(), Json::Num(t.swaps as f64));
    root.insert("plan_before".to_string(), Json::Str(t.plan_before.to_string()));
    root.insert("plan_after".to_string(), Json::Str(t.plan_after.to_string()));
    let out = json_to_string(&Json::Obj(root));
    std::fs::write("BENCH_autotune.json", &out).expect("writing BENCH_autotune.json");
    println!("wrote BENCH_autotune.json");
}
