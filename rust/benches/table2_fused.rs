//! Bench: paper Table 2 — fused register blocks and the register-pressure
//! inversion (FFT-8 > FFT-16 > FFT-32 despite fusing fewer passes).
//!
//! Prints the simulated table and measures the native fused kernels at
//! their terminal positions on this host.

use spfft::cost::SimCost;
use spfft::edge::EdgeType;
use spfft::fft::{Executor, SplitComplex};
use spfft::report;
use spfft::util::bench::{black_box, Bench};
use spfft::util::stats::gflops;

fn main() {
    let n = 1024;
    let l = 10;
    let mut cost = SimCost::m1(n);
    println!("{}", report::table2(&mut cost));

    let mut bench = Bench::from_env("table2_fused");
    let mut ex = Executor::new();
    let k = ex.kernels();
    for e in [EdgeType::F8, EdgeType::F16, EdgeType::F32] {
        let stage = l - e.stages();
        let step = ex.compile_edge(n, e, stage);
        let mut buf = SplitComplex::random(n, 5);
        bench.bench(format!("native/fused{}@terminal", e.block_size().unwrap()), move || {
            spfft::fft::exec::run_step(k, &step, &mut buf.re, &mut buf.im);
            black_box(&buf);
        });
    }
    let results = bench.run();
    println!("\nnative per-block GFLOPS (5*N*stages / t):");
    for r in &results {
        let b: usize = r.name.trim_start_matches("native/fused").split('@').next().unwrap().parse().unwrap();
        let stages = b.trailing_zeros() as f64;
        let gf = 5.0 * n as f64 * stages / r.summary.median;
        println!("  FFT-{:<3} {:>7.2} GFLOPS", b, gf);
        let _ = gflops(n, r.summary.median); // convention helper exercised
    }
}
