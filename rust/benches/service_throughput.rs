//! Bench: coordinator serving throughput/latency — batching policy sweep.
//!
//! Measures end-to-end service behavior (plan cache -> batcher -> native
//! backend) under a closed-loop synthetic workload, sweeping batch sizes —
//! the L3 §Perf target: coordination overhead must stay small relative to
//! kernel time.

use std::time::{Duration, Instant};

use spfft::coordinator::{Backend, BatchPolicy, FftService, ServiceConfig};
use spfft::fft::SplitComplex;
use spfft::plan::Plan;

fn main() {
    let n = 1024;
    let plan = Plan::parse("R4,R2,R4,R4,F8").unwrap();
    let quick = std::env::args().any(|a| a == "--quick") || std::env::var("SPFFT_BENCH_QUICK").is_ok();
    let requests = if quick { 2_000 } else { 20_000 };
    println!("== bench suite: service_throughput ({requests} requests/case) ==");
    for (label, batch) in [
        ("batch1", BatchPolicy { max_batch: 1, max_wait: Duration::ZERO }),
        ("batch8", BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(100) }),
        ("batch32", BatchPolicy { max_batch: 32, max_wait: Duration::from_micros(200) }),
    ] {
        let svc = FftService::start(ServiceConfig {
            plans: vec![(n, plan.clone())],
            backend: Backend::Native,
            batch,
            workers: 1,
            coalesce: Default::default(),
            queue_depth: 512,
            autotune: None,
            shed_deadline: None,
            observer: None,
            exec_mode: Default::default(),
            max_resident_n: None,
        })
        .expect("service");
        let t0 = Instant::now();
        let mut pending = Vec::with_capacity(64);
        let mut submitted = 0usize;
        for i in 0..requests {
            match svc.submit(SplitComplex::random(n, i as u64)) {
                Ok(rx) => {
                    pending.push(rx);
                    submitted += 1;
                }
                Err(_) => {}
            }
            if pending.len() >= 64 {
                for rx in pending.drain(..) {
                    let _ = rx.recv();
                }
            }
        }
        for rx in pending {
            let _ = rx.recv();
        }
        let wall = t0.elapsed();
        let snap = svc.shutdown();
        println!(
            "{label:<8} {:>8.0} req/s  submitted {submitted}  completed {}  mean batch {:>5.2}  p50 {:?}  p95 {:?}  p99 {:?}",
            snap.throughput(wall),
            snap.completed,
            snap.mean_batch_size,
            snap.latency_p50,
            snap.latency_p95,
            snap.latency_p99,
        );
    }
}
