//! Bench: planner scalability — search latency vs problem size and
//! context order (the paper's "orders of magnitude faster than FFTW's
//! planner" claim, §2.5), plus the ablation over beam widths.

use spfft::cost::SimCost;
use spfft::planner::{plan as run_plan, Strategy};
use spfft::util::bench::{black_box, Bench};

fn main() {
    let mut bench = Bench::from_env("planner_search");
    for l in [8usize, 10, 14, 16] {
        let n = 1usize << l;
        bench.bench(format!("cf/L{l}"), move || {
            let mut c = SimCost::m1(n);
            black_box(run_plan(&mut c, &Strategy::DijkstraContextFree));
        });
        bench.bench(format!("ca-k1/L{l}"), move || {
            let mut c = SimCost::m1(n);
            black_box(run_plan(&mut c, &Strategy::DijkstraContextAware { k: 1 }));
        });
        bench.bench(format!("ca-k2/L{l}"), move || {
            let mut c = SimCost::m1(n);
            black_box(run_plan(&mut c, &Strategy::DijkstraContextAware { k: 2 }));
        });
    }
    // ablation: SPIRAL-style beam widths at L = 10
    for w in [1usize, 2, 4, 16] {
        bench.bench(format!("beam-w{w}/L10"), move || {
            let mut c = SimCost::m1(1024);
            black_box(run_plan(&mut c, &Strategy::SpiralBeam { width: w }));
        });
    }
    bench.bench("exhaustive/L10", || {
        let mut c = SimCost::m1(1024);
        black_box(run_plan(&mut c, &Strategy::Exhaustive));
    });
    bench.run();

    // quality-vs-width ablation table (DESIGN.md ablation item)
    println!("\nbeam-width quality ablation (true ns of chosen plan, L=10 M1):");
    let mut c = SimCost::m1(1024);
    let best = run_plan(&mut c, &Strategy::Exhaustive).true_ns;
    for w in [1usize, 2, 3, 4, 8, 16, 64] {
        let out = run_plan(&mut c, &Strategy::SpiralBeam { width: w });
        println!(
            "  width {:<3} -> {:<28} {:>8.1} ns  (+{:.1}% vs optimal)",
            w,
            out.plan.to_string(),
            out.true_ns,
            100.0 * (out.true_ns / best - 1.0)
        );
    }
}
