//! Bench: the batched execution engine vs per-request execution.
//!
//! For B ∈ {1, 4, 16, 64}: run B transforms of one plan sequentially
//! (`CompiledPlan::run` per transform) vs jointly (`gather` → `run_batch`
//! → `scatter` over a pooled lane-blocked buffer — the exact worker hot
//! path, transposes included). Reports per-transform ns, GFLOPS, and the
//! batched/sequential speedup, verifies bit-identical outputs, and
//! writes `BENCH_batched.json`.
//!
//! The B=1 batched row pads a single transform to a full lane group (4×
//! arithmetic) — the measured reason the service routes singleton groups
//! through the scalar path and batches only groups of two or more.

use std::collections::BTreeMap;
use std::time::Instant;

use spfft::cost::SimCost;
use spfft::fft::{BatchBufferPool, Executor, SplitComplex};
use spfft::planner::{plan as run_plan, Strategy};
use spfft::util::bench::{black_box, fmt_ns};
use spfft::util::json::{to_string as json_to_string, Json};
use spfft::util::stats::{gflops, median};

const N: usize = 1024;
const BATCHES: [usize; 4] = [1, 4, 16, 64];

/// Median ns of `reps` timed executions of `f`.
fn median_ns(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    median(&samples)
}

struct Row {
    b: usize,
    seq_ns_per_tx: f64,
    batched_ns_per_tx: f64,
    speedup: f64,
    seq_gflops: f64,
    batched_gflops: f64,
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick") || std::env::var("SPFFT_BENCH_QUICK").is_ok();
    println!("== bench suite: batched_exec{} ==", if quick { " (quick)" } else { "" });

    let plan = run_plan(&mut SimCost::m1(N), &Strategy::DijkstraContextAware { k: 1 }).plan;
    let mut ex = Executor::new();
    let cp = ex.compile(&plan, N, true);
    println!("plan: {plan}  (n = {N})");

    let reps = if quick { 15 } else { 51 };
    let inner = if quick { 4 } else { 16 };
    let mut pool = BatchBufferPool::new();
    let mut rows = Vec::new();
    let mut all_bit_identical = true;

    for &b in &BATCHES {
        let inputs: Vec<SplitComplex> =
            (0..b).map(|i| SplitComplex::random(N, 7 + i as u64)).collect();
        let refs: Vec<&SplitComplex> = inputs.iter().collect();

        // Correctness gate: every batched lane must equal the lone run
        // bit-for-bit before any timing is trusted.
        {
            let mut buf = pool.acquire(N, b);
            buf.gather(&refs);
            cp.run_batch(&mut buf);
            for (lane, input) in inputs.iter().enumerate() {
                if buf.scatter_lane(lane) != cp.run_on(input) {
                    all_bit_identical = false;
                    eprintln!("BIT-IDENTITY FAILURE at B={b} lane {lane}");
                }
            }
            pool.release(buf);
        }

        // Sequential: B independent run() calls (copy + execute each).
        let seq_ns = median_ns(reps, || {
            for input in &inputs {
                black_box(cp.run_on(black_box(input)));
            }
        }) / b as f64;

        // Batched: the worker hot path — gather, execute, and scatter of
        // EVERY lane included (scattering one lane would understate the
        // batched cost and inflate the speedup).
        let mut outs: Vec<SplitComplex> = vec![SplitComplex::zeros(N); b];
        let batched_ns = median_ns(reps, || {
            for _ in 0..inner {
                let mut buf = pool.acquire(N, b);
                buf.gather(&refs);
                cp.run_batch(&mut buf);
                buf.scatter_into(&mut outs);
                black_box(&outs);
                pool.release(buf);
            }
        }) / (inner * b) as f64;

        let row = Row {
            b,
            seq_ns_per_tx: seq_ns,
            batched_ns_per_tx: batched_ns,
            speedup: seq_ns / batched_ns,
            seq_gflops: gflops(N, seq_ns),
            batched_gflops: gflops(N, batched_ns),
        };
        println!(
            "B={:<3} sequential {:>10}/tx ({:>6.1} GFLOPS)   batched {:>10}/tx ({:>6.1} GFLOPS)   speedup {:>5.2}x",
            row.b,
            fmt_ns(row.seq_ns_per_tx),
            row.seq_gflops,
            fmt_ns(row.batched_ns_per_tx),
            row.batched_gflops,
            row.speedup
        );
        rows.push(row);
    }

    let b16 = rows.iter().find(|r| r.b == 16).expect("B=16 row");
    println!(
        "bit-identical outputs : {}",
        if all_bit_identical { "PASS" } else { "FAIL" }
    );
    println!(
        "B=16 vs sequential    : {:.2}x {}",
        b16.speedup,
        if b16.speedup > 1.0 { "PASS (batched faster per transform)" } else { "WARN: no win on this host" }
    );

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("batched_exec".into()));
    // Distinguishes a real run from the hand-authored schema example
    // committed from a toolchain-less container — tooling should gate on
    // this, not on the free-text provenance.
    root.insert("measured".to_string(), Json::Bool(true));
    root.insert("n".to_string(), Json::Num(N as f64));
    root.insert("plan".to_string(), Json::Str(plan.to_string()));
    root.insert("bit_identical".to_string(), Json::Bool(all_bit_identical));
    let jrows: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut o = BTreeMap::new();
            o.insert("b".into(), Json::Num(r.b as f64));
            o.insert("sequential_ns_per_transform".into(), Json::Num(r.seq_ns_per_tx));
            o.insert("batched_ns_per_transform".into(), Json::Num(r.batched_ns_per_tx));
            o.insert("speedup".into(), Json::Num(r.speedup));
            o.insert("sequential_gflops".into(), Json::Num(r.seq_gflops));
            o.insert("batched_gflops".into(), Json::Num(r.batched_gflops));
            Json::Obj(o)
        })
        .collect();
    root.insert("rows".to_string(), Json::Arr(jrows));
    root.insert("speedup_b16".to_string(), Json::Num(b16.speedup));
    let out = json_to_string(&Json::Obj(root));
    std::fs::write("BENCH_batched.json", &out).expect("writing BENCH_batched.json");
    println!("wrote BENCH_batched.json");

    if !all_bit_identical {
        std::process::exit(1);
    }
}
