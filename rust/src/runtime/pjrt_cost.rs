//! Edge weights measured over the AOT-compiled PJRT executables.
//!
//! Same protocol as [`crate::cost::NativeCost`] (paper §2.3: run the
//! predecessor untimed, then time the edge), but the timed operation is
//! the HLO executable produced by the Pallas/JAX build path — so the
//! planner can optimize for the actual artifact stack it will serve with.
//!
//! Note: PJRT CPU execution carries per-call dispatch overhead that the
//! native path doesn't have; weights from this provider are *self-
//! consistent* (valid for ranking plans executed via PJRT) but not
//! comparable in absolute terms to the simulated-M1 numbers.

use std::collections::HashMap;

use anyhow::Result;

use crate::cost::CostModel;
use crate::edge::{Context, EdgeType, ALL_EDGES};
use crate::fft::SplitComplex;
use crate::util::stats::{measure, MeasureSpec};

use super::artifact::Registry;

/// Live measurement provider over PJRT executables.
pub struct PjrtCost {
    registry: Registry,
    n: usize,
    spec: MeasureSpec,
    buf: SplitComplex,
    cache: HashMap<(EdgeType, usize, Context), f64>,
}

impl PjrtCost {
    pub fn new(registry: Registry, n: usize, spec: MeasureSpec) -> PjrtCost {
        crate::fft::log2i(n);
        PjrtCost {
            registry,
            n,
            spec,
            buf: SplitComplex::random(n, 0xBEEF),
            cache: HashMap::new(),
        }
    }

    pub fn registry_mut(&mut self) -> &mut Registry {
        &mut self.registry
    }

    pub fn into_registry(self) -> Registry {
        self.registry
    }

    fn edge_artifact(&self, edge: EdgeType, stage: usize) -> Result<String> {
        Ok(self
            .registry
            .manifest
            .edge(self.n, edge, stage)
            .ok_or_else(|| anyhow::anyhow!("no artifact for {edge}@{stage} n={}", self.n))?
            .name
            .clone())
    }

    fn measure_cell(&mut self, edge: EdgeType, stage: usize, ctx: Context) -> Result<f64> {
        let timed = self.edge_artifact(edge, stage)?;
        let prefix = match ctx {
            Context::Start => None,
            Context::After(prev) if stage >= prev.stages() => {
                Some(self.edge_artifact(prev, stage - prev.stages())?)
            }
            Context::After(_) => None,
        };
        // Pre-compile both executables outside the timed region.
        self.registry.executable(&timed)?;
        if let Some(p) = &prefix {
            self.registry.executable(p)?;
        }
        // PJRT execution is out-of-place: the input buffer never mutates,
        // so both closures can share the registry through a RefCell.
        let spec = self.spec;
        let buf = self.buf.clone();
        let reg_cell = std::cell::RefCell::new(&mut self.registry);
        let mut timed_fn = || {
            let _ = reg_cell.borrow_mut().execute(&timed, &buf).expect("pjrt exec");
        };
        let m = match prefix {
            None => measure(spec, None, &mut timed_fn),
            Some(pfx) => {
                let mut pre_fn = || {
                    let _ = reg_cell.borrow_mut().execute(&pfx, &buf).expect("pjrt exec");
                };
                measure(spec, Some(&mut pre_fn), &mut timed_fn)
            }
        };
        Ok(m.ns)
    }
}

impl CostModel for PjrtCost {
    fn n(&self) -> usize {
        self.n
    }

    fn available_edges(&self) -> Vec<EdgeType> {
        // Only edges with artifacts in the manifest.
        ALL_EDGES
            .iter()
            .copied()
            .filter(|e| {
                (0..=crate::fft::log2i(self.n) - e.stages())
                    .any(|s| self.registry.manifest.edge(self.n, *e, s).is_some())
            })
            .collect()
    }

    fn edge_ns(&mut self, edge: EdgeType, stage: usize, ctx: Context) -> f64 {
        if let Some(&v) = self.cache.get(&(edge, stage, ctx)) {
            return v;
        }
        let v = self
            .measure_cell(edge, stage, ctx)
            .unwrap_or_else(|e| panic!("pjrt measurement failed: {e}"));
        self.cache.insert((edge, stage, ctx), v);
        v
    }
}
