//! Artifact manifest + the PJRT executable registry.
//!
//! `make artifacts` emits `artifacts/manifest.json` (see
//! python/compile/aot.py) describing per-edge HLO files, per-arrangement
//! full-FFT files, and the bit-reversal epilogue. [`Registry`] parses the
//! manifest (with the in-tree JSON parser), compiles executables lazily on
//! its own PJRT CPU client, and executes them on split-complex buffers.
//!
//! The `xla` crate's client is not `Sync` (it wraps an `Rc`), so a
//! `Registry` is single-threaded by construction; the coordinator owns one
//! per worker thread.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context as _, Result};

use crate::edge::EdgeType;
use crate::fft::SplitComplex;
use crate::plan::Plan;
use crate::util::json::{self, Json};

/// Kind of an artifact (mirrors `kind` in the manifest).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArtifactKind {
    /// One graph edge: `edge` at `stage` (no bit-reversal).
    Edge { edge: EdgeType, stage: usize },
    /// A full named arrangement (with bit-reversal).
    Full { arrangement: String, plan: Plan },
    /// The bit-reversal permutation alone.
    Bitrev,
}

/// One artifact description from the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub n: usize,
    pub flops: u64,
    pub kind: ArtifactKind,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Parse `manifest.json` content.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let root = json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        if root.get("format").as_str() != Some("hlo-text") {
            bail!("unsupported manifest format {:?}", root.get("format"));
        }
        let arts = root
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: missing artifacts[]"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let name = a
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let file = dir.join(
                a.get("file")
                    .as_str()
                    .ok_or_else(|| anyhow!("artifact {name}: missing file"))?,
            );
            let n = a
                .get("n")
                .as_usize()
                .ok_or_else(|| anyhow!("artifact {name}: missing n"))?;
            let flops = a.get("flops").as_f64().unwrap_or(0.0) as u64;
            let kind = match a.get("kind").as_str() {
                Some("edge") => {
                    let edge = a
                        .get("edge")
                        .as_str()
                        .and_then(EdgeType::parse)
                        .ok_or_else(|| anyhow!("artifact {name}: bad edge"))?;
                    let stage = a
                        .get("stage")
                        .as_usize()
                        .ok_or_else(|| anyhow!("artifact {name}: bad stage"))?;
                    ArtifactKind::Edge { edge, stage }
                }
                Some("full") => {
                    let arrangement = a
                        .get("arrangement")
                        .as_str()
                        .unwrap_or(&name)
                        .to_string();
                    let edges = a
                        .get("plan")
                        .as_arr()
                        .ok_or_else(|| anyhow!("artifact {name}: missing plan"))?
                        .iter()
                        .map(|v| {
                            v.as_str()
                                .and_then(EdgeType::parse)
                                .ok_or_else(|| anyhow!("artifact {name}: bad plan edge {v:?}"))
                        })
                        .collect::<Result<Vec<_>>>()?;
                    ArtifactKind::Full { arrangement, plan: Plan::new(edges) }
                }
                Some("bitrev") => ArtifactKind::Bitrev,
                other => bail!("artifact {name}: unknown kind {other:?}"),
            };
            artifacts.push(ArtifactSpec { name, file, n, flops, kind });
        }
        Ok(Manifest { artifacts })
    }

    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text, dir)
    }

    /// Specs filtered to one FFT size.
    pub fn for_n(&self, n: usize) -> Vec<&ArtifactSpec> {
        self.artifacts.iter().filter(|a| a.n == n).collect()
    }

    /// Find the edge artifact for (n, edge, stage).
    pub fn edge(&self, n: usize, edge: EdgeType, stage: usize) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(
            |a| a.n == n && matches!(&a.kind, ArtifactKind::Edge { edge: e, stage: s } if *e == edge && *s == stage),
        )
    }

    /// Find a full arrangement by key (e.g. "dijkstra_ca_m1").
    pub fn full(&self, n: usize, arrangement: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(
            |a| a.n == n && matches!(&a.kind, ArtifactKind::Full { arrangement: k, .. } if k == arrangement),
        )
    }

    /// The bitrev artifact for n.
    pub fn bitrev(&self, n: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.n == n && a.kind == ArtifactKind::Bitrev)
    }
}

/// Compiled-executable registry over one PJRT CPU client.
pub struct Registry {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Registry {
    /// Load the manifest from `dir` and create the PJRT client. HLO is
    /// compiled lazily per artifact on first execution.
    pub fn load(dir: &Path) -> Result<Registry> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt client: {e:?}"))?;
        Ok(Registry { manifest, client, compiled: HashMap::new() })
    }

    /// Number of compiled executables so far.
    pub fn compiled_count(&self) -> usize {
        self.compiled.len()
    }

    /// Compile (or fetch) the executable for an artifact name.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(name) {
            let spec = self
                .manifest
                .artifacts
                .iter()
                .find(|a| a.name == name)
                .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
            let proto = xla::HloModuleProto::from_text_file(&spec.file)
                .map_err(|e| anyhow!("parsing {}: {e:?}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.compiled.insert(name.to_string(), exe);
        }
        Ok(&self.compiled[name])
    }

    /// Execute an artifact on a split-complex buffer (out of place).
    pub fn execute(&mut self, name: &str, input: &SplitComplex) -> Result<SplitComplex> {
        let exe = self.executable(name)?;
        let re = xla::Literal::vec1(&input.re);
        let im = xla::Literal::vec1(&input.im);
        let result = exe
            .execute::<xla::Literal>(&[re, im])
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: (re, im).
        let (re_out, im_out) = lit.to_tuple2().map_err(|e| anyhow!("untupling {name}: {e:?}"))?;
        Ok(SplitComplex::from_parts(
            re_out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            im_out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
        ))
    }

    /// Execute an arbitrary plan by chaining per-edge artifacts, then the
    /// bit-reversal epilogue. This is how the coordinator serves plans the
    /// planner discovered at run time without re-running Python.
    pub fn execute_plan(&mut self, n: usize, plan: &Plan, input: &SplitComplex) -> Result<SplitComplex> {
        let mut cur = input.clone();
        for (edge, stage) in plan.steps() {
            let name = self
                .manifest
                .edge(n, edge, stage)
                .ok_or_else(|| anyhow!("no artifact for {edge}@{stage} n={n}"))?
                .name
                .clone();
            cur = self.execute(&name, &cur)?;
        }
        let bitrev = self
            .manifest
            .bitrev(n)
            .ok_or_else(|| anyhow!("no bitrev artifact for n={n}"))?
            .name
            .clone();
        self.execute(&bitrev, &cur)
    }
}

/// Serialize a manifest back to JSON (used by tests and tooling).
pub fn manifest_to_json(m: &Manifest) -> Json {
    use std::collections::BTreeMap;
    let arts: Vec<Json> = m
        .artifacts
        .iter()
        .map(|a| {
            let mut o = BTreeMap::new();
            o.insert("name".into(), Json::Str(a.name.clone()));
            o.insert(
                "file".into(),
                Json::Str(a.file.file_name().unwrap().to_string_lossy().into_owned()),
            );
            o.insert("n".into(), Json::Num(a.n as f64));
            o.insert("flops".into(), Json::Num(a.flops as f64));
            match &a.kind {
                ArtifactKind::Edge { edge, stage } => {
                    o.insert("kind".into(), Json::Str("edge".into()));
                    o.insert("edge".into(), Json::Str(edge.name().into()));
                    o.insert("stage".into(), Json::Num(*stage as f64));
                }
                ArtifactKind::Full { arrangement, plan } => {
                    o.insert("kind".into(), Json::Str("full".into()));
                    o.insert("arrangement".into(), Json::Str(arrangement.clone()));
                    o.insert(
                        "plan".into(),
                        Json::Arr(plan.edges().iter().map(|e| Json::Str(e.name().into())).collect()),
                    );
                }
                ArtifactKind::Bitrev => {
                    o.insert("kind".into(), Json::Str("bitrev".into()));
                }
            }
            Json::Obj(o)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("format".into(), Json::Str("hlo-text".into()));
    root.insert("artifacts".into(), Json::Arr(arts));
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "inputs": ["re", "im"],
      "artifacts": [
        {"name": "edge_r2_s0_n32", "file": "edge_r2_s0_n32.hlo.txt", "n": 32,
         "flops": 800, "kind": "edge", "edge": "R2", "stage": 0, "bitrev": false},
        {"name": "bitrev_n32", "file": "bitrev_n32.hlo.txt", "n": 32,
         "flops": 800, "kind": "bitrev", "bitrev": true},
        {"name": "full_r2all_n32", "file": "full_r2all_n32.hlo.txt", "n": 32,
         "flops": 800, "kind": "full", "arrangement": "r2all",
         "plan": ["R2","R2","R2","R2","R2"], "bitrev": true}
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        let e = m.edge(32, EdgeType::R2, 0).unwrap();
        assert_eq!(e.name, "edge_r2_s0_n32");
        assert_eq!(e.file, PathBuf::from("/tmp/a/edge_r2_s0_n32.hlo.txt"));
        assert!(m.edge(32, EdgeType::R2, 1).is_none());
        assert!(m.edge(64, EdgeType::R2, 0).is_none());
        let f = m.full(32, "r2all").unwrap();
        match &f.kind {
            ArtifactKind::Full { plan, .. } => assert_eq!(plan.len(), 5),
            _ => panic!(),
        }
        assert!(m.bitrev(32).is_some());
        assert_eq!(m.for_n(32).len(), 3);
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(Manifest::parse("{}", Path::new(".")).is_err());
        assert!(Manifest::parse(r#"{"format":"protobuf","artifacts":[]}"#, Path::new(".")).is_err());
        let bad_edge = SAMPLE.replace("\"R2\", \"stage\": 0", "\"R99\", \"stage\": 0");
        assert!(Manifest::parse(&bad_edge, Path::new(".")).is_err());
    }

    #[test]
    fn manifest_json_roundtrip() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        let j = manifest_to_json(&m);
        let text = crate::util::json::to_string(&j);
        let m2 = Manifest::parse(&text, Path::new(".")).unwrap();
        assert_eq!(m2.artifacts.len(), m.artifacts.len());
        for (a, b) in m.artifacts.iter().zip(&m2.artifacts) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.kind, b.kind);
        }
    }
}
