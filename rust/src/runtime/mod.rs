//! PJRT runtime: load and execute the AOT artifacts from `make artifacts`.
//!
//! Python/JAX runs only at build time; this module is the request-path
//! bridge: HLO **text** artifacts (see python/compile/aot.py — text, not
//! serialized protos, because jax >= 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects) are parsed, compiled once per executable
//! on the PJRT CPU client, and executed on split-complex buffers.
//!
//! * [`artifact`] — manifest parsing + the executable registry;
//! * [`pjrt_cost`] — a [`crate::cost::CostModel`] that measures the
//!   compiled per-edge executables with the paper's context protocol.

pub mod artifact;
pub mod pjrt_cost;

pub use artifact::{ArtifactKind, ArtifactSpec, Manifest, Registry};
pub use pjrt_cost::PjrtCost;

/// Default artifacts directory: `$SPFFT_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("SPFFT_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

/// Whether a PJRT client can be created in this build/environment.
///
/// `false` under the vendored `xla` stub (offline builds) — PJRT tests,
/// benches, and backends check this and skip/fall back instead of failing.
pub fn pjrt_available() -> bool {
    xla::PjRtClient::cpu().is_ok()
}
