//! Regeneration of every table and figure in the paper's evaluation.
//!
//! Each function returns a formatted text block with the same rows/series
//! the paper reports, computed live from the requested cost model. Used by
//! the `spfft` CLI (`table --id N`, `figure --id N`) and by the benches in
//! `rust/benches/` — one regenerator per paper exhibit (see DESIGN.md §4).

use crate::cost::{CostModel, SimCost};
use crate::edge::{Context, EdgeType, ALL_EDGES};
use crate::plan::{table3_arrangements, Plan};
use crate::planner::{plan as run_plan, Strategy};
use crate::util::stats::gflops;

/// Paper Table 1: the edge-type catalog (static metadata).
pub fn table1() -> String {
    let mut s = String::from(
        "Table 1: Edge types in the computation graph\n\
         | Edge type | Stages | NEON regs | Instruction advantage |\n\
         |-----------|--------|-----------|------------------------|\n",
    );
    for e in ALL_EDGES {
        let name = match e {
            EdgeType::R2 => "Radix-2 pass",
            EdgeType::R4 => "Radix-4 pass",
            EdgeType::R8 => "Radix-8 pass",
            EdgeType::F8 => "Fused-8 block",
            EdgeType::F16 => "Fused-16 block",
            EdgeType::F32 => "Fused-32 block",
            // not in ALL_EDGES (boundary passes, not graph edges)
            EdgeType::RU => "Real split/unpack",
            EdgeType::Transpose => "Blocked transpose",
            EdgeType::BlockTwiddle => "Four-step twiddle",
        };
        s.push_str(&format!(
            "| {:<14} | {:<6} | {:<9} | {} |\n",
            name,
            e.stages(),
            e.neon_data_regs(),
            e.advantage()
        ));
    }
    s
}

/// Paper Table 2: fused register blocks (GFLOPS over the block's stages,
/// in-context after a radix-4 predecessor — the reading consistent with
/// Table 3; see EXPERIMENTS.md).
pub fn table2<C: CostModel>(cost: &mut C) -> String {
    let n = cost.n();
    let l = crate::fft::log2i(n);
    let mut s = String::from(
        "Table 2: Fused register blocks (simulated M1)\n\
         | Block  | Passes | NEON regs | On AVX2? | GFLOPS |\n\
         |--------|--------|-----------|----------|--------|\n",
    );
    for e in [EdgeType::F8, EdgeType::F16, EdgeType::F32] {
        if !cost.available_edges().contains(&e) {
            continue;
        }
        let stage = l - e.stages(); // terminal position (as in the paper)
        let t = cost.edge_ns(e, stage, Context::After(EdgeType::R4));
        let gf = 5.0 * n as f64 * e.stages() as f64 / t;
        let avx2 = if e == EdgeType::F32 { "No" } else { "Yes" };
        s.push_str(&format!(
            "| FFT-{:<3} | {:<6} | {:<9} | {:<8} | {:>5.1} |\n",
            e.block_size().unwrap(),
            e.stages(),
            e.neon_data_regs(),
            avx2,
            gf
        ));
    }
    s
}

/// One row of Table 3.
#[derive(Debug, Clone)]
pub struct Table3Row {
    pub label: String,
    pub plan: Plan,
    pub time_ns: f64,
    pub gflops: f64,
    pub pct_of_best: f64,
}

/// Paper Table 3 (the central result): the ten arrangements, with the two
/// Dijkstra rows replaced by what the searches *actually discover* on the
/// given cost model.
pub fn table3_rows<C: CostModel>(cost: &mut C) -> Vec<Table3Row> {
    let n = cost.n();
    let mut rows: Vec<(String, Plan)> = table3_arrangements()
        .into_iter()
        .filter(|r| {
            r.plan
                .edges()
                .iter()
                .all(|e| cost.available_edges().contains(e))
        })
        .map(|r| (r.label.to_string(), r.plan))
        .collect();
    let cf = run_plan(cost, &Strategy::DijkstraContextFree);
    let ca = run_plan(cost, &Strategy::DijkstraContextAware { k: 1 });
    if let Some(row) = rows.iter_mut().find(|(l, _)| l.contains("context-free")) {
        *row = (format!("Dijkstra (context-free) -> {}", cf.plan), cf.plan);
    }
    if let Some(row) = rows.iter_mut().find(|(l, _)| l.contains("context-aware")) {
        *row = (format!("Dijkstra (context-aware) -> {}", ca.plan), ca.plan);
    }
    let times: Vec<f64> = rows.iter().map(|(_, p)| cost.plan_ns(p)).collect();
    let best = times.iter().cloned().fold(f64::MAX, f64::min);
    rows.into_iter()
        .zip(times)
        .map(|((label, plan), t)| Table3Row {
            label,
            plan,
            time_ns: t,
            gflops: gflops(n, t),
            pct_of_best: 100.0 * best / t,
        })
        .collect()
}

/// Formatted Table 3.
pub fn table3<C: CostModel>(cost: &mut C) -> String {
    let mut s = String::from(
        "Table 3: algorithms on the same (simulated) core, same data\n\
         | Algorithm                                    | Time (ns) | GFLOPS | % of best |\n\
         |----------------------------------------------|-----------|--------|-----------|\n",
    );
    for row in table3_rows(cost) {
        s.push_str(&format!(
            "| {:<44} | {:>9.0} | {:>6.1} | {:>8.0}% |\n",
            row.label, row.time_ns, row.gflops, row.pct_of_best
        ));
    }
    s
}

/// Paper Table 4: per-pass profile of individual radix-2 passes plus the
/// terminal fused blocks (isolation measurements, as in the paper).
pub fn table4<C: CostModel>(cost: &mut C) -> String {
    let n = cost.n();
    let l = crate::fft::log2i(n);
    let mut s = String::from(
        "Table 4: per-pass GFLOPS for individual radix-2 passes\n\
         | Pass     | Stride | Time (ns) | GFLOPS |\n\
         |----------|--------|-----------|--------|\n",
    );
    for stage in 0..l {
        let t = cost.edge_ns(EdgeType::R2, stage, Context::Start);
        let gf = 5.0 * n as f64 / t; // per-pass FLOPs = 5N (one stage)
        s.push_str(&format!(
            "| {:<8} | {:>6} | {:>9.0} | {:>6.1} |\n",
            format!("{}", stage + 1),
            (n >> stage) / 2,
            t,
            gf
        ));
    }
    for e in [EdgeType::F8, EdgeType::F16] {
        if !cost.available_edges().contains(&e) {
            continue;
        }
        let stage = l - e.stages();
        let t = cost.edge_ns(e, stage, Context::Start);
        let gf = 5.0 * n as f64 * e.stages() as f64 / t;
        s.push_str(&format!(
            "| Fused-{:<2} | {:>6} | {:>9.0} | {:>6.1} |\n",
            e.block_size().unwrap(),
            "-",
            t,
            gf
        ));
    }
    s
}

/// Figure 1 (DOT): context-free graph.
pub fn figure1<C: CostModel>(cost: &mut C) -> String {
    let l = crate::fft::log2i(cost.n());
    crate::graph::dot::context_free_dot(cost, l)
}

/// Figure 2 (DOT): context-aware graph with the optimal path highlighted.
pub fn figure2<C: CostModel>(cost: &mut C) -> String {
    let l = crate::fft::log2i(cost.n());
    let ca = run_plan(cost, &Strategy::DijkstraContextAware { k: 1 });
    crate::graph::dot::context_aware_dot(cost, l, Some(&ca.plan))
}

/// Figure 3: the three compared decompositions (pure R2, CF, CA) with
/// per-edge contextual costs — text panel + DOT.
pub fn figure3<C: CostModel>(cost: &mut C) -> String {
    let n = cost.n();
    let l = crate::fft::log2i(n);
    let pure = Plan::new(vec![EdgeType::R2; l]);
    let cf = run_plan(cost, &Strategy::DijkstraContextFree);
    let ca = run_plan(cost, &Strategy::DijkstraContextAware { k: 1 });
    let mut s = String::from("Figure 3: three decompositions (per-edge contextual cost)\n");
    for (name, plan) in [
        ("pure radix-2", &pure),
        ("context-free Dijkstra", &cf.plan),
        ("context-aware Dijkstra", &ca.plan),
    ] {
        let total = cost.plan_ns(plan);
        s.push_str(&format!(
            "  {:<24} {}  total {:.0} ns ({:.1} GFLOPS)\n",
            name,
            plan,
            total,
            gflops(n, total)
        ));
        let mut ctx = Context::After(*plan.edges().last().unwrap());
        for (e, st) in plan.steps() {
            let w = cost.edge_ns(e, st, ctx);
            s.push_str(&format!("      {:<4} @ stage {:<2} [{}]: {:>7.1} ns\n", e.name(), st, ctx, w));
            ctx = Context::After(e);
        }
    }
    s.push('\n');
    s.push_str(&crate::graph::dot::decomposition_dot(&[
        ("pure radix-2", &pure),
        ("context-free", &cf.plan),
        ("context-aware", &ca.plan),
    ]));
    s
}

/// Convenience: the default simulated-M1 cost model at N = 1024.
pub fn default_m1() -> SimCost {
    SimCost::m1(1024)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_lists_all_edges() {
        let t = table1();
        for e in ALL_EDGES {
            assert!(t.contains(&e.stages().to_string()));
        }
        assert!(t.contains("Fused-32"));
        assert!(t.contains("swap+negate"));
    }

    #[test]
    fn table2_shows_fused_inversion() {
        // Paper Table 2: FFT-8 and FFT-16 beat FFT-32 (register pressure).
        let mut cost = default_m1();
        let t = table2(&mut cost);
        let gf: Vec<f64> = t
            .lines()
            .skip(3)
            .filter_map(|l| l.rsplit('|').nth(1))
            .filter_map(|v| v.trim().parse().ok())
            .collect();
        assert_eq!(gf.len(), 3, "{t}");
        assert!(gf[0] > gf[2], "F8 {} vs F32 {}", gf[0], gf[2]);
        assert!(gf[1] > gf[2], "F16 {} vs F32 {}", gf[1], gf[2]);
    }

    #[test]
    fn table3_has_ten_rows_and_ca_is_best() {
        let mut cost = default_m1();
        let rows = table3_rows(&mut cost);
        assert_eq!(rows.len(), 10);
        let ca = rows.iter().find(|r| r.label.contains("context-aware")).unwrap();
        assert!((ca.pct_of_best - 100.0).abs() < 1e-6, "{}", ca.pct_of_best);
        // paper's central finding: CA discovers the sandwiched-R2 plan
        assert_eq!(ca.plan, Plan::parse("R4,R2,R4,R4,F8").unwrap());
        // fused rows dominate radix rows (finding 1)
        let pure_r2 = rows.iter().find(|r| r.label.contains("pure radix-2")).unwrap();
        assert!(pure_r2.time_ns > 3.0 * ca.time_ns);
    }

    #[test]
    fn table4_shows_u_shape() {
        let mut cost = default_m1();
        let t = table4(&mut cost);
        assert!(t.contains("Fused-8"));
        // extract pass times
        let times: Vec<f64> = t
            .lines()
            .skip(3)
            .take(10)
            .filter_map(|l| l.split('|').nth(3))
            .filter_map(|v| v.trim().parse().ok())
            .collect();
        assert_eq!(times.len(), 10);
        let mid = times[4];
        assert!(times[0] > mid, "pass 1 should beat mid: {times:?}");
        assert!(times[9] > 3.0 * mid, "pass 10 collapse: {times:?}");
        assert!(times[9] > times[0], "pass 10 slowest (paper)");
    }

    #[test]
    fn figures_emit_dot() {
        let mut cost = SimCost::m1(256);
        assert!(figure1(&mut cost).starts_with("digraph"));
        assert!(figure2(&mut cost).contains("penwidth=3"));
        let f3 = figure3(&mut cost);
        assert!(f3.contains("context-aware Dijkstra"));
        assert!(f3.contains("digraph"));
    }

    #[test]
    fn haswell_table3_skips_fused_rows() {
        let mut cost = SimCost::haswell(1024);
        let rows = table3_rows(&mut cost);
        // fused-containing fixed rows are filtered out on the 2015 catalog
        assert!(rows.len() < 10);
        assert!(rows.iter().all(|r| r.plan.edges().iter().all(|e| !e.is_fused())));
    }
}
