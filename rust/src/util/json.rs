//! Minimal JSON parser/serializer for the artifact manifest.
//!
//! Full JSON value model (object/array/string/number/bool/null) with
//! UTF-8-safe string unescaping, good error positions, and a compact
//! serializer. No external crates (this environment is offline); only the
//! subset of JSON the toolchain produces is required, but the parser is a
//! complete RFC 8259 implementation minus `\u` surrogate-pair edge cases
//! beyond the BMP (which the manifest never contains).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["key"]` convenience; returns Null for missing keys/non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document (rejects trailing garbage).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        out.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

/// Serialize a value to compact JSON.
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
        let arr = v.get("a").as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), &Json::Null);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = parse(r#""A\t\\ \" π""#).unwrap();
        assert_eq!(v.as_str(), Some("A\t\\ \" π"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"artifacts":[{"file":"a.hlo.txt","n":1024,"stage":3}],"format":"hlo-text"}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }

    #[test]
    fn get_on_missing_is_null() {
        let v = parse("{}").unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
        assert_eq!(v.get("nope").get("deeper"), &Json::Null);
    }

    #[test]
    fn as_usize() {
        assert_eq!(parse("7").unwrap().as_usize(), Some(7));
        assert_eq!(parse("7.5").unwrap().as_usize(), None);
        assert_eq!(parse("-1").unwrap().as_usize(), None);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "format": "hlo-text",
          "inputs": ["re", "im"],
          "artifacts": [
            {"name": "edge_r2_s0_n1024", "file": "edge_r2_s0_n1024.hlo.txt",
             "n": 1024, "flops": 51200, "kind": "edge", "edge": "R2",
             "stage": 0, "bitrev": false},
            {"name": "full_dijkstra_ca_m1_n1024", "file": "x.hlo.txt",
             "n": 1024, "flops": 51200, "kind": "full",
             "plan": ["R4","R2","R4","R4","F8"], "bitrev": true}
          ]
        }"#;
        let v = parse(src).unwrap();
        let arts = v.get("artifacts").as_arr().unwrap();
        assert_eq!(arts[0].get("stage").as_usize(), Some(0));
        assert_eq!(arts[1].get("plan").as_arr().unwrap().len(), 5);
    }
}
