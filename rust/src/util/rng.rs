//! Deterministic PRNGs: SplitMix64 (seeding) and Xoshiro256** (streams).
//!
//! All randomness in the crate (test inputs, synthetic workloads, property
//! tests) flows through these so every run is reproducible from a seed.

/// SplitMix64 — tiny, high-quality 64-bit mixer; used to expand seeds.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the crate's general-purpose PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (any seed, including 0, is fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Rng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 top bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) (n > 0); Lemire-style rejection-free for
    /// our purposes (modulo bias negligible at the sizes we use, but use
    /// widening multiply anyway).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in [lo, hi) — convenience for ranges.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller (pairs discarded; simple and fine
    /// for test-vector generation).
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-12 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Fill a split-complex buffer with standard-normal f32 values.
    pub fn fill_normal_f32(&mut self, buf: &mut [f32]) {
        for x in buf {
            *x = self.next_normal() as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
