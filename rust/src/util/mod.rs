//! In-tree utility substrates.
//!
//! This environment is fully offline (only `xla` + `anyhow` from the
//! vendored set), so the pieces a production crate would pull from the
//! ecosystem are implemented here:
//!
//! * [`rng`] — deterministic SplitMix64 / Xoshiro256** PRNG (no `rand`);
//! * [`stats`] — the paper's measurement protocol (§4.1: median of 50
//!   trials, 5 warmup) plus robust summary statistics;
//! * [`json`] — a small JSON parser/serializer for the artifact manifest
//!   (no `serde_json`);
//! * [`cli`] — a minimal declarative argument parser (no `clap`);
//! * [`bench`] — a criterion-style benchmark harness used by
//!   `rust/benches/*` (no `criterion`);
//! * [`prop`] — a property-testing driver with shrinking-by-reseed used by
//!   `rust/tests/prop_invariants.rs` (no `proptest`).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
