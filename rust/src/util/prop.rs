//! Tiny property-testing driver (no `proptest` in the offline set).
//!
//! A property is a closure from a seeded [`Rng`](super::rng::Rng) to
//! `Result<(), String>`. The driver runs `cases` iterations with distinct
//! deterministic seeds; on failure it reports the seed so the case can be
//! replayed exactly (`SPFFT_PROP_SEED=<seed>` reruns only that seed), and
//! performs a simple "shrink by reseed" pass re-running nearby seeds to
//! find a second witness (useful to spot flaky vs systematic failures).

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, base_seed: 0x5FF7_0001 }
    }
}

/// Run a property; panics with diagnostics on the first failure.
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    if let Ok(s) = std::env::var("SPFFT_PROP_SEED") {
        let seed: u64 = s.parse().expect("SPFFT_PROP_SEED must be a u64");
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed under SPFFT_PROP_SEED={seed}: {msg}");
        }
        return;
    }
    for case in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            // shrink-by-reseed: look for additional witnesses for context
            let mut extra = Vec::new();
            for d in 1..=8u64 {
                let s2 = seed.wrapping_add(d);
                let mut r2 = Rng::new(s2);
                if prop(&mut r2).is_err() {
                    extra.push(s2);
                }
            }
            panic!(
                "property '{name}' failed at case {case} (seed {seed}): {msg}\n\
                 replay with SPFFT_PROP_SEED={seed}; nearby failing seeds: {extra:?}"
            );
        }
    }
}

/// Assert helper producing `Result<(), String>` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("xorshift-sane", Config { cases: 16, ..Default::default() }, |rng| {
            let a = rng.next_below(100);
            if a < 100 {
                Ok(())
            } else {
                Err(format!("{a} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn failing_property_reports_seed() {
        check("always-fails", Config { cases: 4, ..Default::default() }, |_| {
            Err("always-fails".to_string())
        });
    }

    #[test]
    fn seeds_are_distinct_across_cases() {
        let mut seen = std::collections::HashSet::new();
        check("distinct", Config { cases: 32, ..Default::default() }, |rng| {
            seen.insert(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen.len(), 32);
    }
}
