//! Measurement protocol and summary statistics.
//!
//! The paper's protocol (§4.1): median of 50 trials with 5 warmup
//! iterations, averaged over 3 independent runs. [`MeasureSpec`] encodes
//! exactly that and [`measure`] executes it against any closure; the
//! simulator-backed cost providers reuse the same shape so simulated and
//! live measurements are directly comparable.

use std::time::Instant;

/// Summary of a sample of measurements (nanoseconds or any unit).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub p95: f64,
    pub stddev: f64,
}

impl Summary {
    pub fn from_samples(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample");
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            min: sorted[0],
            max: sorted[n - 1],
            mean,
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            stddev: var.sqrt(),
        }
    }

    /// Relative spread (max-min)/median — the paper reports "range < 8%".
    pub fn rel_range(&self) -> f64 {
        if self.median == 0.0 {
            0.0
        } else {
            (self.max - self.min) / self.median
        }
    }
}

/// Percentile (linear interpolation) over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Median of an unsorted slice.
pub fn median(samples: &[f64]) -> f64 {
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, 50.0)
}

/// The paper's measurement protocol parameters.
#[derive(Debug, Clone, Copy)]
pub struct MeasureSpec {
    /// Timed trials per run (paper: 50).
    pub trials: usize,
    /// Untimed warmup iterations per run (paper: 5).
    pub warmup: usize,
    /// Independent runs whose medians are averaged (paper: 3).
    pub runs: usize,
}

impl MeasureSpec {
    /// Paper §4.1: median of 50 trials, 5 warmup, averaged over 3 runs.
    pub const PAPER: MeasureSpec = MeasureSpec { trials: 50, warmup: 5, runs: 3 };

    /// Cheap variant for tests / smoke runs.
    pub const QUICK: MeasureSpec = MeasureSpec { trials: 9, warmup: 2, runs: 1 };
}

/// Result of a timed measurement.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    /// Average over runs of the per-run median, in nanoseconds.
    pub ns: f64,
    /// Relative range across the run medians ((max-min)/median).
    pub run_spread: f64,
}

/// Execute `f` under the measurement protocol and return wall-clock ns.
///
/// `f` is the *timed* operation; `prefix` (if any) runs immediately before
/// each timed trial **untimed** — this is the paper's context-aware
/// measurement: "execute the predecessor (untimed), then immediately time
/// the current operation" (§2.3, Fig. 2).
pub fn measure(spec: MeasureSpec, mut prefix: Option<&mut dyn FnMut()>, f: &mut dyn FnMut()) -> Measurement {
    let mut run_medians = Vec::with_capacity(spec.runs);
    for _ in 0..spec.runs {
        for _ in 0..spec.warmup {
            if let Some(p) = prefix.as_deref_mut() {
                p();
            }
            f();
        }
        let mut samples = Vec::with_capacity(spec.trials);
        for _ in 0..spec.trials {
            if let Some(p) = prefix.as_deref_mut() {
                p();
            }
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        run_medians.push(median(&samples));
    }
    let mean = run_medians.iter().sum::<f64>() / run_medians.len() as f64;
    let max = run_medians.iter().cloned().fold(f64::MIN, f64::max);
    let min = run_medians.iter().cloned().fold(f64::MAX, f64::min);
    let med = median(&run_medians);
    Measurement {
        ns: mean,
        run_spread: if med > 0.0 { (max - min) / med } else { 0.0 },
    }
}

/// GFLOPS under the paper's FLOP convention (5·N·log2 N) for a time in ns.
pub fn gflops(n: usize, time_ns: f64) -> f64 {
    let l = (usize::BITS - 1 - n.leading_zeros()) as f64;
    5.0 * n as f64 * l / time_ns
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.mean, 3.0);
        assert!((s.stddev - 2.0_f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile_sorted(&v, 0.0), 10.0);
        assert_eq!(percentile_sorted(&v, 100.0), 40.0);
        assert_eq!(percentile_sorted(&v, 50.0), 25.0);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
    }

    #[test]
    #[should_panic]
    fn empty_summary_panics() {
        Summary::from_samples(&[]);
    }

    #[test]
    fn measure_counts_calls() {
        let spec = MeasureSpec { trials: 10, warmup: 2, runs: 2 };
        let mut timed = 0usize;
        let mut prefixed = 0usize;
        let mut pre = || prefixed += 1;
        let m = measure(spec, Some(&mut pre), &mut || timed += 1);
        // (warmup + trials) per run, prefix before every call
        assert_eq!(timed, 2 * (10 + 2));
        assert_eq!(prefixed, timed);
        assert!(m.ns >= 0.0);
    }

    #[test]
    fn gflops_convention() {
        // 51200 flops in 1722 ns -> 29.7 GFLOPS (paper Table 3 best row).
        let g = gflops(1024, 1722.0);
        assert!((g - 29.7).abs() < 0.1, "{g}");
    }

    #[test]
    fn rel_range() {
        let s = Summary::from_samples(&[95.0, 100.0, 105.0]);
        assert!((s.rel_range() - 0.1).abs() < 1e-12);
    }
}
