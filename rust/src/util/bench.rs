//! Criterion-style benchmark harness (no `criterion` in the offline set).
//!
//! Benches under `rust/benches/` are `harness = false` binaries that build a
//! [`Bench`] and register closures; the harness times each with adaptive
//! iteration counts, reports median/mean/stddev, and honors the standard
//! `cargo bench -- <filter>` argument so individual benchmarks can be run.
//! Also supports "table mode": paper-table regenerators print their rows
//! after the timing block (see `rust/benches/table3_algorithms.rs`).

use std::time::{Duration, Instant};

use super::stats::Summary;

/// One registered benchmark.
struct Case {
    name: String,
    f: Box<dyn FnMut()>,
}

/// Harness configuration.
pub struct Bench {
    cases: Vec<Case>,
    /// Target wall time per case for the measurement phase.
    pub target: Duration,
    /// Samples to collect per case.
    pub samples: usize,
    filter: Option<String>,
    quick: bool,
}

/// Result row for a completed case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters_per_sample: u64,
    pub summary: Summary,
}

impl Bench {
    /// Build from `std::env::args` (supports `-- <filter>` and `--quick`).
    pub fn from_env(suite: &str) -> Bench {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        // cargo bench passes `--bench` (and sometimes other flags); any
        // non-flag token is treated as a name filter.
        let filter = argv.iter().find(|a| !a.starts_with('-')).cloned();
        let quick = argv.iter().any(|a| a == "--quick") || std::env::var("SPFFT_BENCH_QUICK").is_ok();
        eprintln!("== bench suite: {suite}{} ==", if quick { " (quick)" } else { "" });
        Bench {
            cases: Vec::new(),
            target: if quick { Duration::from_millis(50) } else { Duration::from_millis(400) },
            samples: if quick { 11 } else { 31 },
            filter,
            quick,
        }
    }

    /// Whether `--quick` mode is on (benches may shrink their workloads).
    pub fn is_quick(&self) -> bool {
        self.quick
    }

    /// Register a benchmark closure.
    pub fn bench(&mut self, name: impl Into<String>, f: impl FnMut() + 'static) {
        let name = name.into();
        if let Some(fil) = &self.filter {
            if !name.contains(fil.as_str()) {
                return;
            }
        }
        self.cases.push(Case { name, f: Box::new(f) });
    }

    /// Run all registered cases and print a report; returns the results.
    pub fn run(mut self) -> Vec<BenchResult> {
        let mut out = Vec::new();
        for case in &mut self.cases {
            let res = run_case(case, self.target, self.samples);
            println!(
                "{:<44} median {:>12}  mean {:>12}  sd {:>6.1}%  ({} it/sample)",
                res.name,
                fmt_ns(res.summary.median),
                fmt_ns(res.summary.mean),
                100.0 * res.summary.stddev / res.summary.mean.max(1e-9),
                res.iters_per_sample,
            );
            out.push(res);
        }
        out
    }
}

fn run_case(case: &mut Case, target: Duration, samples: usize) -> BenchResult {
    // Warmup & calibration: find iters such that one sample ~ target/samples.
    let mut iters: u64 = 1;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            (case.f)();
        }
        let dt = t0.elapsed();
        if dt >= Duration::from_millis(2) || iters >= 1 << 24 {
            let per_iter = dt.as_nanos() as f64 / iters as f64;
            let per_sample_ns = (target.as_nanos() as f64 / samples as f64).max(1.0);
            iters = ((per_sample_ns / per_iter.max(0.1)).ceil() as u64).clamp(1, 1 << 24);
            break;
        }
        iters *= 4;
    }
    let mut sample_ns = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            (case.f)();
        }
        sample_ns.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    BenchResult {
        name: case.name.clone(),
        iters_per_sample: iters,
        summary: Summary::from_samples(&sample_ns),
    }
}

/// Human format for nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Prevent the optimizer from discarding a value (stable-Rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(12.0), "12.0 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.20 s");
    }

    #[test]
    fn run_case_produces_sane_numbers() {
        let mut c = Case {
            name: "spin".into(),
            f: Box::new(|| {
                let mut s = 0u64;
                for i in 0..100 {
                    s = s.wrapping_add(black_box(i));
                }
                black_box(s);
            }),
        };
        let r = run_case(&mut c, Duration::from_millis(20), 5);
        assert!(r.summary.median > 0.0);
        assert!(r.iters_per_sample >= 1);
        assert_eq!(r.summary.n, 5);
    }
}
