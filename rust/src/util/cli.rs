//! Minimal declarative CLI argument parser (no `clap` in the offline set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! subcommands (handled by the caller via [`Args::positional`]), and
//! auto-generated usage text.

use std::collections::BTreeMap;
use std::fmt;

/// Declared option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// None => boolean flag; Some(default) => value option.
    pub default: Option<&'static str>,
    /// Must be provided explicitly (empty value rejected).
    pub required: bool,
}

/// Parse error.
#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for CliError {}

/// Parsed arguments.
#[derive(Debug, Clone)]
pub struct Args {
    values: BTreeMap<&'static str, String>,
    flags: BTreeMap<&'static str, bool>,
    positional: Vec<String>,
}

impl Args {
    /// Value of `--name` (or its default).
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .map(|s| s.as_str())
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    /// Value parsed as usize.
    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError(format!("--{name} expects an integer, got '{}'", self.get(name))))
    }

    /// Value parsed as f64.
    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError(format!("--{name} expects a number, got '{}'", self.get(name))))
    }

    /// Whether boolean `--name` was passed.
    pub fn flag(&self, name: &str) -> bool {
        *self
            .flags
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not declared"))
    }

    /// Positional arguments in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// A declarative command parser.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new() }
    }

    /// Declare `--name <value>` with a default (empty default = optional,
    /// callers check for emptiness).
    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), required: false });
        self
    }

    /// Declare a required `--name <value>`.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(""), required: true });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, required: false });
        self
    }

    /// Usage text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            match o.default {
                None => s.push_str(&format!("  --{:<18} {}\n", o.name, o.help)),
                Some(_) if o.required => {
                    s.push_str(&format!("  --{:<18} {} (required)\n", format!("{} <v>", o.name), o.help))
                }
                Some(d) => s.push_str(&format!(
                    "  --{:<18} {}{}\n",
                    format!("{} <v>", o.name),
                    o.help,
                    if d.is_empty() { String::new() } else { format!(" [default: {d}]") }
                )),
            }
        }
        s
    }

    /// Parse a raw token stream (excluding the program/subcommand name).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut values: BTreeMap<&'static str, String> = BTreeMap::new();
        let mut flags: BTreeMap<&'static str, bool> = BTreeMap::new();
        for o in &self.opts {
            match o.default {
                None => {
                    flags.insert(o.name, false);
                }
                Some(d) => {
                    values.insert(o.name, d.to_string());
                }
            }
        }
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(rest) = tok.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError(format!("unknown option --{key}\n\n{}", self.usage())))?;
                match spec.default {
                    None => {
                        if inline_val.is_some() {
                            return Err(CliError(format!("--{key} is a flag, not a value option")));
                        }
                        flags.insert(spec.name, true);
                    }
                    Some(_) => {
                        let val = match inline_val {
                            Some(v) => v,
                            None => {
                                i += 1;
                                argv.get(i)
                                    .cloned()
                                    .ok_or_else(|| CliError(format!("--{key} expects a value")))?
                            }
                        };
                        values.insert(spec.name, val);
                    }
                }
            } else {
                positional.push(tok.clone());
            }
            i += 1;
        }
        for o in &self.opts {
            if o.required && values.get(o.name).is_none_or(|v| v.is_empty()) {
                return Err(CliError(format!("--{} is required\n\n{}", o.name, self.usage())));
            }
        }
        Ok(Args { values, flags, positional })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("test", "a test command")
            .opt("n", "1024", "FFT size")
            .opt("machine", "m1", "machine model")
            .req("out", "output path")
            .flag("verbose", "print more")
    }

    fn argv(toks: &[&str]) -> Vec<String> {
        toks.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&argv(&["--out", "x"])).unwrap();
        assert_eq!(a.get("n"), "1024");
        assert_eq!(a.get_usize("n").unwrap(), 1024);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn explicit_values_and_flags() {
        let a = cmd()
            .parse(&argv(&["--n=256", "--verbose", "--machine", "haswell", "--out", "y", "pos1"]))
            .unwrap();
        assert_eq!(a.get("n"), "256");
        assert_eq!(a.get("machine"), "haswell");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn required_enforced() {
        assert!(cmd().parse(&argv(&[])).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let err = cmd().parse(&argv(&["--nope", "--out", "x"])).unwrap_err();
        assert!(err.0.contains("unknown option"));
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cmd().parse(&argv(&["--verbose=1", "--out", "x"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse(&argv(&["--out"])).is_err());
    }

    #[test]
    fn bad_int() {
        let a = cmd().parse(&argv(&["--n", "abc", "--out", "x"])).unwrap();
        assert!(a.get_usize("n").is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = cmd().usage();
        assert!(u.contains("--machine"));
        assert!(u.contains("required"));
    }
}
