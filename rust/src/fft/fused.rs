//! Fused register blocks FFT-8 / FFT-16 / FFT-32 (paper §3.2, Table 2).
//!
//! A fused block of size B gathers the B-point group
//! { base + j + k·(m/B) : k ∈ [0,B) } into locals ("registers"), runs the
//! whole log2(B)-stage butterfly network on them, and scatters once — one
//! memory round trip for log2(B) stages instead of log2(B) round trips.
//!
//! Sub-stage r pairs lanes k and k + B>>(r+1); its twiddle separates into
//! W_m^{2^r j} (j-vector) × W_{B>>r}^{k'} (lane constant). Both factors
//! are **pre-combined at plan-compile time** into one (half_r × e) table
//! per sub-stage (`fused_twiddles`) — the exact analogue of the Pallas
//! kernels' trace-time tables, and of the immediates the paper's NEON
//! code bakes into registers. (§Perf log: an earlier version computed the
//! lane constants with `cos`/`sin` per butterfly at run time, making
//! fused blocks 5–10× slower than radix chains and inverting the paper's
//! premise on the native path.)
//!
//! The FFT-32 block mirrors the paper's novel NEON contribution; on real
//! NEON it spills (working set > 32 registers), which the timing
//! simulator charges (sim/compute.rs) and the graph search therefore
//! avoids, reproducing the paper's FFT-8 > FFT-32 inversion.

use std::sync::Arc;

use super::twiddle::TwiddleVec;

#[inline(always)]
fn cmul(ar: f32, ai: f32, br: f32, bi: f32) -> (f32, f32) {
    (ar * br - ai * bi, ar * bi + ai * br)
}

/// Tile width: groups processed together so the butterfly arithmetic
/// vectorizes across them (the scalar-code analogue of the paper's
/// process-4-butterflies-per-NEON-instruction structure).
pub(crate) const TILE: usize = 8;

/// Generic fused block over B complex locals. `wt[r]` must be the
/// combined sub-stage table from [`fused_twiddles`]: entry `k*e + j` is
/// W_m^{2^r j} · W_{B>>r}^{k} for k ∈ [0, (B>>r)/2), j ∈ [0, e).
///
/// §Perf: groups are processed in tiles of [`TILE`] — consecutive j
/// mid-path, consecutive blocks at the terminal position (where every
/// group shares the j = 0 twiddles) — so the inner butterflies vectorize
/// across the tile instead of running one scalar network per group.
fn fused_generic<const B: usize>(
    re: &mut [f32],
    im: &mut [f32],
    stage: usize,
    wt: &[Arc<TwiddleVec>],
) {
    let n = re.len();
    let m = n >> stage;
    let lb = B.trailing_zeros() as usize;
    debug_assert!(m >= B, "F{B} at stage {stage} invalid for n={n}");
    debug_assert_eq!(wt.len(), lb);
    let e = m / B;
    if e == 1 {
        // Terminal: every group is a contiguous B-point block with j = 0.
        // Tile across blocks; the twiddle is constant per (r, k).
        let mut base = 0;
        while base + TILE * B <= n {
            fused_tile_terminal::<B>(re, im, base, wt);
            base += TILE * B;
        }
        while base < n {
            fused_group_scalar::<B>(re, im, base, 0, 1, wt);
            base += B;
        }
        return;
    }
    let mut base = 0;
    while base < n {
        let mut j = 0;
        while j + TILE <= e {
            fused_tile_mid::<B>(re, im, base, j, e, wt);
            j += TILE;
        }
        while j < e {
            fused_group_scalar::<B>(re, im, base, j, e, wt);
            j += 1;
        }
        base += m;
    }
}

/// One group, scalar (remainder path; also the tail of the SIMD
/// codelets in [`super::simd`], so every remainder is *the* scalar code).
#[inline(always)]
pub(crate) fn fused_group_scalar<const B: usize>(
    re: &mut [f32],
    im: &mut [f32],
    base: usize,
    j: usize,
    e: usize,
    wt: &[Arc<TwiddleVec>],
) {
    let mut xr = [0f32; B];
    let mut xi = [0f32; B];
    for k in 0..B {
        xr[k] = re[base + j + k * e];
        xi[k] = im[base + j + k * e];
    }
    for (r, w) in wt.iter().enumerate() {
        let lanes = B >> r;
        let half = lanes / 2;
        for g in 0..(B / lanes) {
            let off = g * lanes;
            for k in 0..half {
                let wr = w.re[k * e + j];
                let wi = w.im[k * e + j];
                let (a, b) = (off + k, off + k + half);
                let (tr, ti) = (xr[a] + xr[b], xi[a] + xi[b]);
                let (dr, di) = (xr[a] - xr[b], xi[a] - xi[b]);
                let (pr, pi) = cmul(dr, di, wr, wi);
                xr[a] = tr;
                xi[a] = ti;
                xr[b] = pr;
                xi[b] = pi;
            }
        }
    }
    for k in 0..B {
        re[base + j + k * e] = xr[k];
        im[base + j + k * e] = xi[k];
    }
}

/// TILE consecutive-j groups of one block, vectorized across j.
#[inline(always)]
fn fused_tile_mid<const B: usize>(
    re: &mut [f32],
    im: &mut [f32],
    base: usize,
    j0: usize,
    e: usize,
    wt: &[Arc<TwiddleVec>],
) {
    let mut xr = [[0f32; TILE]; B];
    let mut xi = [[0f32; TILE]; B];
    for k in 0..B {
        let s = base + j0 + k * e;
        xr[k].copy_from_slice(&re[s..s + TILE]);
        xi[k].copy_from_slice(&im[s..s + TILE]);
    }
    for (r, w) in wt.iter().enumerate() {
        let lanes = B >> r;
        let half = lanes / 2;
        for g in 0..(B / lanes) {
            let off = g * lanes;
            for k in 0..half {
                let wrow = k * e + j0;
                let wr = &w.re[wrow..wrow + TILE];
                let wi = &w.im[wrow..wrow + TILE];
                let (a, b) = (off + k, off + k + half);
                // split_at_mut dance to hold two lanes mutably
                let (ra, rb) = lane_pair(&mut xr, a, b);
                let (ia, ib) = lane_pair(&mut xi, a, b);
                for t in 0..TILE {
                    let (tr, ti) = (ra[t] + rb[t], ia[t] + ib[t]);
                    let (dr, di) = (ra[t] - rb[t], ia[t] - ib[t]);
                    let (pr, pi) = cmul(dr, di, wr[t], wi[t]);
                    ra[t] = tr;
                    ia[t] = ti;
                    rb[t] = pr;
                    ib[t] = pi;
                }
            }
        }
    }
    for k in 0..B {
        let s = base + j0 + k * e;
        re[s..s + TILE].copy_from_slice(&xr[k]);
        im[s..s + TILE].copy_from_slice(&xi[k]);
    }
}

/// TILE consecutive terminal blocks, vectorized across blocks (the
/// "in-register transpose" trick: point k of block t sits at t*B + k).
#[inline(always)]
fn fused_tile_terminal<const B: usize>(
    re: &mut [f32],
    im: &mut [f32],
    base: usize,
    wt: &[Arc<TwiddleVec>],
) {
    let mut xr = [[0f32; TILE]; B];
    let mut xi = [[0f32; TILE]; B];
    for t in 0..TILE {
        for k in 0..B {
            xr[k][t] = re[base + t * B + k];
            xi[k][t] = im[base + t * B + k];
        }
    }
    for (r, w) in wt.iter().enumerate() {
        let lanes = B >> r;
        let half = lanes / 2;
        for g in 0..(B / lanes) {
            let off = g * lanes;
            for k in 0..half {
                let wr = w.re[k]; // e == 1: one entry per k
                let wi = w.im[k];
                let (a, b) = (off + k, off + k + half);
                let (ra, rb) = lane_pair(&mut xr, a, b);
                let (ia, ib) = lane_pair(&mut xi, a, b);
                for t in 0..TILE {
                    let (tr, ti) = (ra[t] + rb[t], ia[t] + ib[t]);
                    let (dr, di) = (ra[t] - rb[t], ia[t] - ib[t]);
                    let (pr, pi) = cmul(dr, di, wr, wi);
                    ra[t] = tr;
                    ia[t] = ti;
                    rb[t] = pr;
                    ib[t] = pi;
                }
            }
        }
    }
    for t in 0..TILE {
        for k in 0..B {
            re[base + t * B + k] = xr[k][t];
            im[base + t * B + k] = xi[k][t];
        }
    }
}

/// Batched fused block over a lane-blocked buffer (`lanes` floats per
/// element, a multiple of [`super::batch::LANE`]). Where the scalar path
/// tiles across consecutive j (or consecutive terminal blocks), the
/// batched path tiles across the **batch lanes** of one (base, j) group:
/// each sub-stage twiddle `w[k*e + j]` is loaded once per group and
/// applied to [`super::batch::LANE`] transforms at a time. Per-lane
/// arithmetic is the same butterfly network as [`fused_group_scalar`],
/// so outputs are bit-identical to the unbatched block.
fn fused_generic_b<const B: usize>(
    re: &mut [f32],
    im: &mut [f32],
    stage: usize,
    wt: &[Arc<TwiddleVec>],
    lanes: usize,
) {
    const BL: usize = super::batch::LANE;
    debug_assert!(lanes >= 1 && lanes % BL == 0 && re.len() % lanes == 0);
    let n = re.len() / lanes;
    let m = n >> stage;
    let lb = B.trailing_zeros() as usize;
    debug_assert!(m >= B, "F{B} at stage {stage} invalid for n={n}");
    debug_assert_eq!(wt.len(), lb);
    let e = m / B;
    let estride = e * lanes;
    let mut base = 0;
    while base < n {
        for j in 0..e {
            let flat = (base + j) * lanes;
            let mut c = 0;
            while c < lanes {
                fused_lane_tile::<B>(re, im, flat + c, estride, j, e, wt);
                c += BL;
            }
        }
        base += m;
    }
}

/// One [`super::batch::LANE`]-wide lane chunk of one fused group: point k
/// of the group starts at `flat0 + k * estride` in the flat buffer.
#[inline(always)]
fn fused_lane_tile<const B: usize>(
    re: &mut [f32],
    im: &mut [f32],
    flat0: usize,
    estride: usize,
    j: usize,
    e: usize,
    wt: &[Arc<TwiddleVec>],
) {
    const BL: usize = super::batch::LANE;
    let mut xr = [[0f32; BL]; B];
    let mut xi = [[0f32; BL]; B];
    for k in 0..B {
        let s = flat0 + k * estride;
        xr[k].copy_from_slice(&re[s..s + BL]);
        xi[k].copy_from_slice(&im[s..s + BL]);
    }
    for (r, w) in wt.iter().enumerate() {
        let lanes = B >> r;
        let half = lanes / 2;
        for g in 0..(B / lanes) {
            let off = g * lanes;
            for k in 0..half {
                let wr = w.re[k * e + j];
                let wi = w.im[k * e + j];
                let (a, b) = (off + k, off + k + half);
                let (ra, rb) = lane_pair_b(&mut xr, a, b);
                let (ia, ib) = lane_pair_b(&mut xi, a, b);
                for t in 0..BL {
                    let (tr, ti) = (ra[t] + rb[t], ia[t] + ib[t]);
                    let (dr, di) = (ra[t] - rb[t], ia[t] - ib[t]);
                    let (pr, pi) = cmul(dr, di, wr, wi);
                    ra[t] = tr;
                    ia[t] = ti;
                    rb[t] = pr;
                    ib[t] = pi;
                }
            }
        }
    }
    for k in 0..B {
        let s = flat0 + k * estride;
        re[s..s + BL].copy_from_slice(&xr[k]);
        im[s..s + BL].copy_from_slice(&xi[k]);
    }
}

/// Disjoint mutable refs to two batch-lane rows of the tile (a < b).
#[inline(always)]
fn lane_pair_b<const B: usize>(
    x: &mut [[f32; super::batch::LANE]; B],
    a: usize,
    b: usize,
) -> (
    &mut [f32; super::batch::LANE],
    &mut [f32; super::batch::LANE],
) {
    debug_assert!(a < b);
    let (lo, hi) = x.split_at_mut(b);
    (&mut lo[a], &mut hi[0])
}

/// Batched fused FFT-8 block over a lane-blocked buffer.
pub fn fused8_b(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>], lanes: usize) {
    fused_generic_b::<8>(re, im, stage, wt, lanes);
}

/// Batched fused FFT-16 block over a lane-blocked buffer.
pub fn fused16_b(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>], lanes: usize) {
    fused_generic_b::<16>(re, im, stage, wt, lanes);
}

/// Batched fused FFT-32 block over a lane-blocked buffer.
pub fn fused32_b(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>], lanes: usize) {
    fused_generic_b::<32>(re, im, stage, wt, lanes);
}

/// Disjoint mutable refs to two lanes of the tile array (a < b).
#[inline(always)]
fn lane_pair<const B: usize>(
    x: &mut [[f32; TILE]; B],
    a: usize,
    b: usize,
) -> (&mut [f32; TILE], &mut [f32; TILE]) {
    debug_assert!(a < b);
    let (lo, hi) = x.split_at_mut(b);
    (&mut lo[a], &mut hi[0])
}

/// Fused FFT-8 block (3 stages, 4 NEON data registers).
pub fn fused8(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>]) {
    fused_generic::<8>(re, im, stage, wt);
}

/// Fused FFT-16 block (4 stages, 8 NEON data registers).
pub fn fused16(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>]) {
    fused_generic::<16>(re, im, stage, wt);
}

/// Fused FFT-32 block (5 stages, 16 NEON data registers — novel; loses to
/// FFT-8 on real NEON from twiddle spills, paper Table 2).
pub fn fused32(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>]) {
    fused_generic::<32>(re, im, stage, wt);
}

/// Combined per-sub-stage twiddle tables for a fused-B block at (n, stage):
/// table r holds W_m^{2^r j} · W_{B>>r}^{k} at index `k*e + j`
/// (k < (B>>r)/2, j < e = m/B). Computed once, cached, shared by plans.
pub fn fused_twiddles(
    cache: &mut super::TwiddleCache,
    n: usize,
    stage: usize,
    b: usize,
) -> Vec<Arc<TwiddleVec>> {
    let m = n >> stage;
    let lb = b.trailing_zeros() as usize;
    let e = m / b;
    (0..lb)
        .map(|r| cache.fused_table(m, e, b >> r, 1 << r))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::reference::apply_radix2_stages_ref;
    use crate::fft::{SplitComplex, TwiddleCache};

    fn check(b: usize, n: usize, stage: usize, seed: u64) {
        let input = SplitComplex::random(n, seed);
        let mut got = input.clone();
        let mut cache = TwiddleCache::new();
        let wt = fused_twiddles(&mut cache, n, stage, b);
        match b {
            8 => fused8(&mut got.re, &mut got.im, stage, &wt),
            16 => fused16(&mut got.re, &mut got.im, stage, &wt),
            32 => fused32(&mut got.re, &mut got.im, stage, &wt),
            _ => unreachable!(),
        }
        let lb = b.trailing_zeros() as usize;
        let want = apply_radix2_stages_ref(&input, stage, lb);
        let scale = want.max_abs().max(1.0);
        let err = got.max_abs_diff(&want) / scale;
        assert!(err < 2e-5, "F{b} n={n} stage={stage}: rel err {err}");
    }

    #[test]
    fn fused8_matches_reference_all_stages() {
        for n in [8usize, 64, 1024] {
            for stage in 0..=(crate::fft::log2i(n).saturating_sub(3)) {
                if n >> (stage + 3) >= 1 {
                    check(8, n, stage, 31 + stage as u64);
                }
            }
        }
    }

    #[test]
    fn fused16_matches_reference_all_stages() {
        for n in [16usize, 256, 1024] {
            for stage in 0..=(crate::fft::log2i(n).saturating_sub(4)) {
                check(16, n, stage, 77 + stage as u64);
            }
        }
    }

    #[test]
    fn fused32_matches_reference_all_stages() {
        for n in [32usize, 256, 1024] {
            for stage in 0..=(crate::fft::log2i(n).saturating_sub(5)) {
                check(32, n, stage, 123 + stage as u64);
            }
        }
    }

    #[test]
    fn batched_fused_is_bit_identical_to_scalar() {
        for (b, n, stage) in [(8usize, 64usize, 0usize), (16, 256, 2), (32, 256, 0), (8, 64, 3)] {
            for batch in [1usize, 3, 4, 9] {
                let inputs: Vec<SplitComplex> =
                    (0..batch).map(|i| SplitComplex::random(n, 500 + i as u64)).collect();
                let refs: Vec<&SplitComplex> = inputs.iter().collect();
                let mut cache = TwiddleCache::new();
                let wt = fused_twiddles(&mut cache, n, stage, b);
                let mut buf = crate::fft::BatchBuffer::new(n, batch);
                buf.gather(&refs);
                let lanes = buf.lanes();
                match b {
                    8 => fused8_b(&mut buf.re, &mut buf.im, stage, &wt, lanes),
                    16 => fused16_b(&mut buf.re, &mut buf.im, stage, &wt, lanes),
                    32 => fused32_b(&mut buf.re, &mut buf.im, stage, &wt, lanes),
                    _ => unreachable!(),
                }
                for (l, input) in inputs.iter().enumerate() {
                    let mut want = input.clone();
                    match b {
                        8 => fused8(&mut want.re, &mut want.im, stage, &wt),
                        16 => fused16(&mut want.re, &mut want.im, stage, &wt),
                        32 => fused32(&mut want.re, &mut want.im, stage, &wt),
                        _ => unreachable!(),
                    }
                    assert_eq!(
                        buf.scatter_lane(l),
                        want,
                        "F{b} n={n} stage={stage} lane {l} of batch {batch}"
                    );
                }
            }
        }
    }

    #[test]
    fn fused8_equals_radix8_pass() {
        // Same transform, different instruction strategy (paper Table 1).
        let n = 512;
        let stage = 2;
        let input = SplitComplex::random(n, 8);
        let mut cache = TwiddleCache::new();

        let mut a = input.clone();
        let wt = fused_twiddles(&mut cache, n, stage, 8);
        fused8(&mut a.re, &mut a.im, stage, &wt);

        let mut b = input.clone();
        let m = n >> stage;
        let (w1, w2, w4) = (
            cache.vector(m, m / 8, 1),
            cache.vector(m, m / 8, 2),
            cache.vector(m, m / 8, 4),
        );
        crate::fft::passes::radix8(&mut b.re, &mut b.im, stage, &w1, &w2, &w4);
        assert!(a.max_abs_diff(&b) / b.max_abs().max(1.0) < 1e-5);
    }

    #[test]
    fn terminal_block_is_contiguous() {
        // At the terminal stage, e = 1 and the block covers contiguous points.
        let n = 64;
        let stage = 3; // remaining stages = 3 => F8 terminal
        check(8, n, stage, 4);
    }

    #[test]
    fn combined_tables_have_expected_shapes() {
        let mut cache = TwiddleCache::new();
        let wt = fused_twiddles(&mut cache, 1024, 2, 8); // m=256, e=32
        assert_eq!(wt.len(), 3);
        assert_eq!(wt[0].len(), 4 * 32); // half=4 lanes x e=32
        assert_eq!(wt[1].len(), 2 * 32);
        assert_eq!(wt[2].len(), 32);
        // entry (k=0, j=0) is W^0 = 1 for every sub-stage
        for w in &wt {
            assert!((w.re[0] - 1.0).abs() < 1e-7);
            assert!(w.im[0].abs() < 1e-7);
        }
    }
}
