//! Bit-reversal permutation (final reordering after DIF stages).
//!
//! Excluded from the paper's FLOP count (5·N·log2 N counts butterfly work
//! only); included in the full-arrangement executables so outputs match
//! the natural-order DFT.

use super::log2i;

/// Bit-reversed index table for length n (power of two).
pub fn bit_reverse_indices(n: usize) -> Vec<usize> {
    let l = log2i(n);
    let mut rev = vec![0usize; n];
    for (i, r) in rev.iter_mut().enumerate() {
        *r = if l == 0 { 0 } else { i.reverse_bits() >> (usize::BITS as usize - l) };
    }
    rev
}

/// In-place bit-reversal permutation of a split-complex buffer.
pub fn bit_reverse_permute(re: &mut [f32], im: &mut [f32]) {
    let n = re.len();
    assert_eq!(n, im.len());
    let l = log2i(n);
    if l == 0 {
        return;
    }
    let shift = usize::BITS as usize - l;
    for i in 0..n {
        let j = i.reverse_bits() >> shift;
        if j > i {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
}

/// In-place bit-reversal of a lane-blocked batch buffer: permute the
/// element rows (each `lanes` floats wide), leaving lane order intact.
pub fn bit_reverse_permute_b(re: &mut [f32], im: &mut [f32], lanes: usize) {
    assert_eq!(re.len(), im.len());
    assert!(lanes >= 1 && re.len() % lanes == 0);
    let n = re.len() / lanes;
    let l = log2i(n);
    if l == 0 {
        return;
    }
    let shift = usize::BITS as usize - l;
    for i in 0..n {
        let j = i.reverse_bits() >> shift;
        if j > i {
            for t in 0..lanes {
                re.swap(i * lanes + t, j * lanes + t);
                im.swap(i * lanes + t, j * lanes + t);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_are_involutive_permutation() {
        for n in [1usize, 2, 8, 64, 1024] {
            let idx = bit_reverse_indices(n);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..n).collect::<Vec<_>>());
            for i in 0..n {
                assert_eq!(idx[idx[i]], i);
            }
        }
    }

    #[test]
    fn known_small_case() {
        assert_eq!(bit_reverse_indices(8), vec![0, 4, 2, 6, 1, 5, 3, 7]);
    }

    #[test]
    fn permute_matches_indices() {
        let n = 64;
        let idx = bit_reverse_indices(n);
        let mut re: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let mut im: Vec<f32> = (0..n).map(|i| -(i as f32)).collect();
        bit_reverse_permute(&mut re, &mut im);
        for i in 0..n {
            assert_eq!(re[i], idx[i] as f32);
            assert_eq!(im[i], -(idx[i] as f32));
        }
    }

    #[test]
    fn batched_permute_matches_per_lane_permute() {
        let n = 64;
        for b in [1usize, 3, 4, 6] {
            let inputs: Vec<crate::fft::SplitComplex> =
                (0..b).map(|i| crate::fft::SplitComplex::random(n, i as u64)).collect();
            let refs: Vec<&crate::fft::SplitComplex> = inputs.iter().collect();
            let mut buf = crate::fft::BatchBuffer::new(n, b);
            buf.gather(&refs);
            let lanes = buf.lanes();
            bit_reverse_permute_b(&mut buf.re, &mut buf.im, lanes);
            for (l, input) in inputs.iter().enumerate() {
                let mut want = input.clone();
                bit_reverse_permute(&mut want.re, &mut want.im);
                assert_eq!(buf.scatter_lane(l), want, "lane {l} of batch {b}");
            }
        }
    }

    #[test]
    fn double_permute_is_identity() {
        let n = 128;
        let orig: Vec<f32> = (0..n).map(|i| (i * 3) as f32).collect();
        let mut re = orig.clone();
        let mut im = orig.clone();
        bit_reverse_permute(&mut re, &mut im);
        bit_reverse_permute(&mut re, &mut im);
        assert_eq!(re, orig);
        assert_eq!(im, orig);
    }
}
