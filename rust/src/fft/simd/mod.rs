//! Explicit SIMD codelet backends behind one [`Kernels`] vtable.
//!
//! The paper's kernels are hand-scheduled NEON; this crate's scalar
//! kernels ([`super::passes`], [`super::fused`]) reproduce the algebra
//! but leave the instruction mix to the autovectorizer. This module
//! closes that gap the way FFTW's codelet generator does (PAPERS.md,
//! *Implementing FFTs in Practice*): one algebra source
//! ([`generic`], parameterized over a [`generic::Vf32`] lane set), many
//! instruction-set instantiations —
//!
//! | ISA        | lanes | gate                                         |
//! |------------|-------|----------------------------------------------|
//! | `scalar`   | 1     | always available (this is the fallback)      |
//! | `portable` | 8     | `portable-simd` cargo feature (nightly)      |
//! | `neon`     | 4     | `target_arch = "aarch64"` (baseline)         |
//! | `avx2`     | 8     | `target_arch = "x86_64"` + runtime detection |
//!
//! A [`Kernels`] table is selected **once per compiled plan**
//! ([`super::exec::Executor`] resolves [`crate::isa::Isa::detect`] at
//! construction), so every dispatched edge — and therefore everything
//! [`crate::cost::NativeCost`] measures and every
//! [`crate::autotune::EdgeSample`] — carries the ISA that actually ran.
//! All backends are **bit-identical** to the scalar kernels (same
//! operation order, no FMA, scalar tails reuse the scalar code); parity
//! is pinned across every variant in `tests/simd_parity.rs`, which is
//! what makes `SPFFT_FORCE_SCALAR=1` a behavior-preserving switch.

use std::fmt;
use std::sync::Arc;

use crate::isa::Isa;

use super::twiddle::TwiddleVec;
use super::{fused, passes};

pub mod generic;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(feature = "portable-simd")]
mod portable;

/// Unbatched radix-2 kernel (`w1`).
pub type RadixFn = fn(&mut [f32], &mut [f32], usize, &TwiddleVec);
/// Unbatched radix-4/8 kernel (three twiddle vectors).
pub type Radix3Fn = fn(&mut [f32], &mut [f32], usize, &TwiddleVec, &TwiddleVec, &TwiddleVec);
/// Unbatched fused-block kernel (per-sub-stage combined tables).
pub type FusedFn = fn(&mut [f32], &mut [f32], usize, &[Arc<TwiddleVec>]);
/// Lane-blocked radix-2 kernel (trailing `lanes`).
pub type RadixBFn = fn(&mut [f32], &mut [f32], usize, &TwiddleVec, usize);
/// Lane-blocked radix-4/8 kernel.
pub type Radix3BFn =
    fn(&mut [f32], &mut [f32], usize, &TwiddleVec, &TwiddleVec, &TwiddleVec, usize);
/// Lane-blocked fused-block kernel.
pub type FusedBFn = fn(&mut [f32], &mut [f32], usize, &[Arc<TwiddleVec>], usize);

/// One ISA's complete kernel set: every edge type of Table 1 plus the
/// `_b` lane-blocked batched forms. Plans hold a `&'static Kernels` and
/// dispatch through it, so backend selection is one pointer indirection
/// at plan-compile time, zero on the request path.
pub struct Kernels {
    /// Which ISA these kernels execute (the tag recorded into
    /// [`crate::autotune::EdgeSample`] / wisdom).
    pub isa: Isa,
    pub radix2: RadixFn,
    pub radix4: Radix3Fn,
    pub radix8: Radix3Fn,
    pub fused8: FusedFn,
    pub fused16: FusedFn,
    pub fused32: FusedFn,
    pub radix2_b: RadixBFn,
    pub radix4_b: Radix3BFn,
    pub radix8_b: Radix3BFn,
    pub fused8_b: FusedBFn,
    pub fused16_b: FusedBFn,
    pub fused32_b: FusedBFn,
}

impl fmt::Debug for Kernels {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Kernels({})", self.isa)
    }
}

/// The always-available scalar table: the existing kernels, untouched.
/// This is the parity baseline every SIMD backend is pinned against.
pub static SCALAR: Kernels = Kernels {
    isa: Isa::Scalar,
    radix2: passes::radix2,
    radix4: passes::radix4,
    radix8: passes::radix8,
    fused8: fused::fused8,
    fused16: fused::fused16,
    fused32: fused::fused32,
    radix2_b: passes::radix2_b,
    radix4_b: passes::radix4_b,
    radix8_b: passes::radix8_b,
    fused8_b: fused::fused8_b,
    fused16_b: fused::fused16_b,
    fused32_b: fused::fused32_b,
};

#[cfg(target_arch = "aarch64")]
fn neon_kernels() -> Option<&'static Kernels> {
    Some(&neon::KERNELS)
}

#[cfg(not(target_arch = "aarch64"))]
fn neon_kernels() -> Option<&'static Kernels> {
    None
}

#[cfg(target_arch = "x86_64")]
fn avx2_kernels() -> Option<&'static Kernels> {
    // Runtime gate: the avx2 table's safe wrappers are only sound on a
    // host that actually has AVX2.
    if std::arch::is_x86_feature_detected!("avx2") {
        Some(&avx2::KERNELS)
    } else {
        None
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_kernels() -> Option<&'static Kernels> {
    None
}

#[cfg(feature = "portable-simd")]
fn portable_kernels() -> Option<&'static Kernels> {
    Some(&portable::KERNELS)
}

#[cfg(not(feature = "portable-simd"))]
fn portable_kernels() -> Option<&'static Kernels> {
    None
}

/// The kernel table for an ISA, falling back to [`SCALAR`] when the
/// backend is not compiled in (or, for AVX2, not present on this host).
/// Callers must treat the returned table's `isa` tag — not the
/// requested one — as what will execute.
pub fn for_isa(isa: Isa) -> &'static Kernels {
    match isa {
        Isa::Scalar => &SCALAR,
        Isa::Portable => portable_kernels().unwrap_or(&SCALAR),
        Isa::Neon => neon_kernels().unwrap_or(&SCALAR),
        Isa::Avx2 => avx2_kernels().unwrap_or(&SCALAR),
    }
}

/// The table [`crate::isa::Isa::detect`] resolves to on this host
/// (honors `SPFFT_FORCE_SCALAR`).
pub fn detect() -> &'static Kernels {
    for_isa(Isa::detect())
}

#[cfg(test)]
mod tests {
    use super::generic::{self, Soft};
    use super::*;
    use crate::fft::{fused::fused_twiddles, BatchBuffer, SplitComplex, TwiddleCache};
    use crate::isa::ALL_ISAS;

    /// Run every kernel of `k` and of [`SCALAR`] on identical inputs
    /// and assert exact equality — the dispatch-parity contract.
    fn assert_table_parity(k: &Kernels, n: usize, seed: u64) {
        let mut cache = TwiddleCache::new();
        let input = SplitComplex::random(n, seed);
        let pair = |name: &str, stage: usize, got: SplitComplex, want: SplitComplex| {
            assert_eq!(got, want, "{name} stage {stage} isa {} n {n}", k.isa);
        };
        // Radix passes at stage 0 and a mid stage.
        for stage in [0usize, 2] {
            let m = n >> stage;
            let w1 = cache.vector(m, m / 2, 1);
            let mut got = input.clone();
            let mut want = input.clone();
            (k.radix2)(&mut got.re, &mut got.im, stage, &w1);
            (SCALAR.radix2)(&mut want.re, &mut want.im, stage, &w1);
            pair("R2", stage, got, want);

            let (w1, w2, w3) =
                (cache.vector(m, m / 4, 1), cache.vector(m, m / 4, 2), cache.vector(m, m / 4, 3));
            let mut got = input.clone();
            let mut want = input.clone();
            (k.radix4)(&mut got.re, &mut got.im, stage, &w1, &w2, &w3);
            (SCALAR.radix4)(&mut want.re, &mut want.im, stage, &w1, &w2, &w3);
            pair("R4", stage, got, want);

            let (w1, w2, w4) =
                (cache.vector(m, m / 8, 1), cache.vector(m, m / 8, 2), cache.vector(m, m / 8, 4));
            let mut got = input.clone();
            let mut want = input.clone();
            (k.radix8)(&mut got.re, &mut got.im, stage, &w1, &w2, &w4);
            (SCALAR.radix8)(&mut want.re, &mut want.im, stage, &w1, &w2, &w4);
            pair("R8", stage, got, want);
        }
        // Fused blocks at stage 0 (mid path) and the terminal stage.
        for (b, f, sf) in [
            (8usize, k.fused8, SCALAR.fused8),
            (16, k.fused16, SCALAR.fused16),
            (32, k.fused32, SCALAR.fused32),
        ] {
            let lb = b.trailing_zeros() as usize;
            for stage in [0usize, crate::fft::log2i(n) - lb] {
                let wt = fused_twiddles(&mut cache, n, stage, b);
                let mut got = input.clone();
                let mut want = input.clone();
                f(&mut got.re, &mut got.im, stage, &wt);
                sf(&mut want.re, &mut want.im, stage, &wt);
                pair(&format!("F{b}"), stage, got, want);
            }
        }
        // Batched forms, per-lane vs the scalar batched kernels.
        let batch = 3;
        let inputs: Vec<SplitComplex> =
            (0..batch).map(|i| SplitComplex::random(n, seed + 10 + i as u64)).collect();
        let refs: Vec<&SplitComplex> = inputs.iter().collect();
        let stage = 1;
        let m = n >> stage;
        let mut fresh = || {
            let mut buf = BatchBuffer::new(n, batch);
            buf.gather(&refs);
            buf
        };
        let check = |name: &str, got: &BatchBuffer, want: &BatchBuffer| {
            for l in 0..batch {
                assert_eq!(
                    got.scatter_lane(l),
                    want.scatter_lane(l),
                    "{name} lane {l} isa {} n {n}",
                    k.isa
                );
            }
        };
        {
            let w1 = cache.vector(m, m / 2, 1);
            let (mut got, mut want) = (fresh(), fresh());
            let l = got.lanes();
            (k.radix2_b)(&mut got.re, &mut got.im, stage, &w1, l);
            (SCALAR.radix2_b)(&mut want.re, &mut want.im, stage, &w1, l);
            check("R2b", &got, &want);
        }
        {
            let (w1, w2, w3) =
                (cache.vector(m, m / 4, 1), cache.vector(m, m / 4, 2), cache.vector(m, m / 4, 3));
            let (mut got, mut want) = (fresh(), fresh());
            let l = got.lanes();
            (k.radix4_b)(&mut got.re, &mut got.im, stage, &w1, &w2, &w3, l);
            (SCALAR.radix4_b)(&mut want.re, &mut want.im, stage, &w1, &w2, &w3, l);
            check("R4b", &got, &want);
        }
        {
            let (w1, w2, w4) =
                (cache.vector(m, m / 8, 1), cache.vector(m, m / 8, 2), cache.vector(m, m / 8, 4));
            let (mut got, mut want) = (fresh(), fresh());
            let l = got.lanes();
            (k.radix8_b)(&mut got.re, &mut got.im, stage, &w1, &w2, &w4, l);
            (SCALAR.radix8_b)(&mut want.re, &mut want.im, stage, &w1, &w2, &w4, l);
            check("R8b", &got, &want);
        }
        for (b, f, sf) in [
            (8usize, k.fused8_b, SCALAR.fused8_b),
            (16, k.fused16_b, SCALAR.fused16_b),
            (32, k.fused32_b, SCALAR.fused32_b),
        ] {
            if n >> stage < b {
                continue;
            }
            let wt = fused_twiddles(&mut cache, n, stage, b);
            let (mut got, mut want) = (fresh(), fresh());
            let l = got.lanes();
            f(&mut got.re, &mut got.im, stage, &wt, l);
            sf(&mut want.re, &mut want.im, stage, &wt, l);
            check(&format!("F{b}b"), &got, &want);
        }
    }

    /// A software-vector table over the generic bodies, so the generic
    /// codelets are parity-pinned on every host (no SIMD needed).
    fn soft_table<const L: usize>() -> Kernels {
        fn k<const L: usize>() -> Kernels {
            Kernels {
                isa: Isa::Portable, // tag irrelevant for parity
                radix2: |re, im, s, w1| generic::radix2_v::<Soft<L>>(re, im, s, w1),
                radix4: |re, im, s, w1, w2, w3| generic::radix4_v::<Soft<L>>(re, im, s, w1, w2, w3),
                radix8: |re, im, s, w1, w2, w4| generic::radix8_v::<Soft<L>>(re, im, s, w1, w2, w4),
                fused8: |re, im, s, wt| generic::fused_v::<Soft<L>, 8>(re, im, s, wt),
                fused16: |re, im, s, wt| generic::fused_v::<Soft<L>, 16>(re, im, s, wt),
                fused32: |re, im, s, wt| generic::fused_v::<Soft<L>, 32>(re, im, s, wt),
                radix2_b: |re, im, s, w1, l| generic::radix2_b_v::<Soft<L>>(re, im, s, w1, l),
                radix4_b: |re, im, s, w1, w2, w3, l| {
                    generic::radix4_b_v::<Soft<L>>(re, im, s, w1, w2, w3, l)
                },
                radix8_b: |re, im, s, w1, w2, w4, l| {
                    generic::radix8_b_v::<Soft<L>>(re, im, s, w1, w2, w4, l)
                },
                fused8_b: |re, im, s, wt, l| generic::fused_b_v::<Soft<L>, 8>(re, im, s, wt, l),
                fused16_b: |re, im, s, wt, l| generic::fused_b_v::<Soft<L>, 16>(re, im, s, wt, l),
                fused32_b: |re, im, s, wt, l| generic::fused_b_v::<Soft<L>, 32>(re, im, s, wt, l),
            }
        }
        k::<L>()
    }

    #[test]
    fn generic_bodies_are_bit_identical_to_scalar_4_lane() {
        for n in [64usize, 256] {
            assert_table_parity(&soft_table::<4>(), n, 900 + n as u64);
        }
    }

    #[test]
    fn generic_bodies_are_bit_identical_to_scalar_8_lane() {
        for n in [64usize, 256] {
            assert_table_parity(&soft_table::<8>(), n, 1300 + n as u64);
        }
    }

    #[test]
    fn generic_bodies_are_bit_identical_at_odd_widths() {
        // Width 3 never divides anything evenly — the scalar tails do
        // most of the work, pinning the vector/tail seam.
        for n in [64usize, 128] {
            assert_table_parity(&soft_table::<3>(), n, 1700 + n as u64);
        }
    }

    #[test]
    fn host_backend_is_bit_identical_to_scalar() {
        // On aarch64 this exercises NEON; on x86-64 with AVX2, the
        // target_feature wrappers; elsewhere it degenerates to
        // scalar-vs-scalar (trivially true, still a dispatch check).
        for isa in ALL_ISAS {
            let k = for_isa(isa);
            assert_table_parity(k, 256, 77 + isa.index() as u64);
        }
    }

    #[test]
    fn for_isa_falls_back_to_scalar_only_when_unavailable() {
        assert_eq!(for_isa(Isa::Scalar).isa, Isa::Scalar);
        for isa in ALL_ISAS {
            let got = for_isa(isa).isa;
            assert!(got == isa || got == Isa::Scalar, "{isa} resolved to {got}");
        }
        #[cfg(target_arch = "aarch64")]
        assert_eq!(for_isa(Isa::Neon).isa, Isa::Neon);
    }

    #[test]
    fn detect_honors_force_scalar_env() {
        // Serialized within this test: set, check, restore.
        let prev = std::env::var("SPFFT_FORCE_SCALAR").ok();
        std::env::set_var("SPFFT_FORCE_SCALAR", "1");
        assert_eq!(detect().isa, Isa::Scalar);
        match prev {
            Some(v) => std::env::set_var("SPFFT_FORCE_SCALAR", v),
            None => std::env::remove_var("SPFFT_FORCE_SCALAR"),
        }
    }
}
