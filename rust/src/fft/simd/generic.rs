//! ISA-generic SIMD codelet bodies over the [`Vf32`] lane abstraction.
//!
//! Every body here mirrors its scalar counterpart in
//! [`crate::fft::passes`] / [`crate::fft::fused`] **operation for
//! operation**: the same loads, the same add/sub/mul order, complex
//! multiplies as separate mul + sub / mul + add (never FMA), and scalar
//! remainder tails that call the *actual* scalar helpers. Because every
//! lane op is a correctly-rounded IEEE-754 f32 operation, the vector
//! forms are **bit-identical** to the scalar kernels on every input —
//! the dispatch-parity property the executor tests pin
//! (`tests/simd_parity.rs`).
//!
//! Backends (`neon`, `avx2`, `portable`) only implement [`Vf32`] — a
//! load/store/splat/add/sub/mul/neg lane set — and instantiate these
//! bodies, the codelet-generator discipline of FFTW (PAPERS.md,
//! *Implementing FFTs in Practice*): one algebra source, many
//! instruction sets. `#[inline(always)]` throughout so the bodies
//! compile *inside* `#[target_feature]` wrappers and inherit the
//! feature.

use std::sync::Arc;

use super::super::batch::LANE as BL;
use super::super::fused::{fused_group_scalar, TILE};
use super::super::passes::{cmul, split8, w8_rotate, INV_SQRT2};
use super::super::twiddle::TwiddleVec;

/// A small fixed-width f32 vector: the whole surface a backend must
/// provide. `load`/`store` touch the first `LANES` elements of the
/// slice (callers guarantee length by construction; implementations
/// `debug_assert` it).
pub trait Vf32: Copy {
    /// f32 lanes per vector register.
    const LANES: usize;
    /// Load `LANES` floats from the head of `src`.
    fn load(src: &[f32]) -> Self;
    /// Store `LANES` floats to the head of `dst`.
    fn store(self, dst: &mut [f32]);
    /// Broadcast one float to all lanes.
    fn splat(x: f32) -> Self;
    fn add(self, o: Self) -> Self;
    fn sub(self, o: Self) -> Self;
    fn mul(self, o: Self) -> Self;
    fn neg(self) -> Self;
}

/// Software vector: plain f32 lane arithmetic at an arbitrary width.
/// Exists so the generic bodies are exercised (and their bit-identity
/// pinned) on *every* host, including ones with no SIMD backend
/// compiled in; also documents exactly what a hardware lane must
/// compute.
#[derive(Clone, Copy)]
pub struct Soft<const L: usize>([f32; L]);

impl<const L: usize> Vf32 for Soft<L> {
    const LANES: usize = L;

    #[inline(always)]
    fn load(src: &[f32]) -> Self {
        debug_assert!(src.len() >= L);
        let mut v = [0f32; L];
        v.copy_from_slice(&src[..L]);
        Soft(v)
    }

    #[inline(always)]
    fn store(self, dst: &mut [f32]) {
        debug_assert!(dst.len() >= L);
        dst[..L].copy_from_slice(&self.0);
    }

    #[inline(always)]
    fn splat(x: f32) -> Self {
        Soft([x; L])
    }

    #[inline(always)]
    fn add(self, o: Self) -> Self {
        let mut v = self.0;
        for (a, b) in v.iter_mut().zip(o.0) {
            *a += b;
        }
        Soft(v)
    }

    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        let mut v = self.0;
        for (a, b) in v.iter_mut().zip(o.0) {
            *a -= b;
        }
        Soft(v)
    }

    #[inline(always)]
    fn mul(self, o: Self) -> Self {
        let mut v = self.0;
        for (a, b) in v.iter_mut().zip(o.0) {
            *a *= b;
        }
        Soft(v)
    }

    #[inline(always)]
    fn neg(self) -> Self {
        let mut v = self.0;
        for a in v.iter_mut() {
            *a = -*a;
        }
        Soft(v)
    }
}

/// Vector complex multiply, same operation order as [`cmul`]:
/// `(ar·br − ai·bi, ar·bi + ai·br)` as two muls + sub, two muls + add.
#[inline(always)]
fn vcmul<V: Vf32>(ar: V, ai: V, br: V, bi: V) -> (V, V) {
    (ar.mul(br).sub(ai.mul(bi)), ar.mul(bi).add(ai.mul(br)))
}

/// Vector [`w8_rotate`]: multiply by W_8^k via 1/√2 scaling + add/sub,
/// exactly the scalar expression per lane (negation before the scale,
/// matching `-(xr + xi) * INV_SQRT2`).
#[inline(always)]
fn vw8_rotate<V: Vf32>(xr: V, xi: V, k: usize) -> (V, V) {
    let s = V::splat(INV_SQRT2);
    match k {
        0 => (xr, xi),
        1 => (xr.add(xi).mul(s), xi.sub(xr).mul(s)),
        2 => (xi, xr.neg()),
        3 => (xi.sub(xr).mul(s), xr.add(xi).neg().mul(s)),
        _ => unreachable!(),
    }
}

// ---------------------------------------------------------------------
// Radix passes (vector over the twiddle index j; scalar tail).
// ---------------------------------------------------------------------

/// [`crate::fft::passes::radix2`], vectorized across j.
#[inline(always)]
pub fn radix2_v<V: Vf32>(re: &mut [f32], im: &mut [f32], stage: usize, w1: &TwiddleVec) {
    let n = re.len();
    let m = n >> stage;
    debug_assert!(m >= 2, "R2 at stage {stage} invalid for n={n}");
    let half = m / 2;
    debug_assert_eq!(w1.len(), half);
    let (w1r, w1i) = (&w1.re[..half], &w1.im[..half]);
    let mut base = 0;
    while base < n {
        let (top, bot) = re[base..base + m].split_at_mut(half);
        let (topi, boti) = im[base..base + m].split_at_mut(half);
        let mut j = 0;
        while j + V::LANES <= half {
            let (tr, ti) = (V::load(&top[j..]), V::load(&topi[j..]));
            let (br, bi) = (V::load(&bot[j..]), V::load(&boti[j..]));
            let (sr, si) = (tr.add(br), ti.add(bi));
            let (pr, pi) = vcmul(tr.sub(br), ti.sub(bi), V::load(&w1r[j..]), V::load(&w1i[j..]));
            sr.store(&mut top[j..]);
            si.store(&mut topi[j..]);
            pr.store(&mut bot[j..]);
            pi.store(&mut boti[j..]);
            j += V::LANES;
        }
        while j < half {
            let (tr, ti) = (top[j], topi[j]);
            let (br, bi) = (bot[j], boti[j]);
            let (sr, si) = (tr + br, ti + bi);
            let (pr, pi) = cmul(tr - br, ti - bi, w1r[j], w1i[j]);
            top[j] = sr;
            topi[j] = si;
            bot[j] = pr;
            boti[j] = pi;
            j += 1;
        }
        base += m;
    }
}

/// [`crate::fft::passes::radix4`], vectorized across j.
#[inline(always)]
pub fn radix4_v<V: Vf32>(
    re: &mut [f32],
    im: &mut [f32],
    stage: usize,
    w1: &TwiddleVec,
    w2: &TwiddleVec,
    w3: &TwiddleVec,
) {
    let n = re.len();
    let m = n >> stage;
    debug_assert!(m >= 4, "R4 at stage {stage} invalid for n={n}");
    let q = m / 4;
    debug_assert_eq!(w1.len(), q);
    let (w1r, w1i) = (&w1.re[..q], &w1.im[..q]);
    let (w2r, w2i) = (&w2.re[..q], &w2.im[..q]);
    let (w3r, w3i) = (&w3.re[..q], &w3.im[..q]);
    let mut base = 0;
    while base < n {
        let (q0r, rest) = re[base..base + m].split_at_mut(q);
        let (q1r, rest) = rest.split_at_mut(q);
        let (q2r, q3r) = rest.split_at_mut(q);
        let (q0i, rest) = im[base..base + m].split_at_mut(q);
        let (q1i, rest) = rest.split_at_mut(q);
        let (q2i, q3i) = rest.split_at_mut(q);
        let mut j = 0;
        while j + V::LANES <= q {
            let (ar, ai) = (V::load(&q0r[j..]), V::load(&q0i[j..]));
            let (br, bi) = (V::load(&q1r[j..]), V::load(&q1i[j..]));
            let (cr, ci) = (V::load(&q2r[j..]), V::load(&q2i[j..]));
            let (dr, di) = (V::load(&q3r[j..]), V::load(&q3i[j..]));
            let (t0r, t0i) = (ar.add(cr), ai.add(ci));
            let (t1r, t1i) = (ar.sub(cr), ai.sub(ci));
            let (t2r, t2i) = (br.add(dr), bi.add(di));
            // t3 = -j*(b - d): swap + negate (W_4^1 trick)
            let (t3r, t3i) = (bi.sub(di), br.sub(dr).neg());
            t0r.add(t2r).store(&mut q0r[j..]);
            t0i.add(t2i).store(&mut q0i[j..]);
            let (y1r, y1i) = vcmul(
                t0r.sub(t2r),
                t0i.sub(t2i),
                V::load(&w2r[j..]),
                V::load(&w2i[j..]),
            );
            y1r.store(&mut q1r[j..]);
            y1i.store(&mut q1i[j..]);
            let (y2r, y2i) = vcmul(
                t1r.add(t3r),
                t1i.add(t3i),
                V::load(&w1r[j..]),
                V::load(&w1i[j..]),
            );
            y2r.store(&mut q2r[j..]);
            y2i.store(&mut q2i[j..]);
            let (y3r, y3i) = vcmul(
                t1r.sub(t3r),
                t1i.sub(t3i),
                V::load(&w3r[j..]),
                V::load(&w3i[j..]),
            );
            y3r.store(&mut q3r[j..]);
            y3i.store(&mut q3i[j..]);
            j += V::LANES;
        }
        while j < q {
            let (ar, ai) = (q0r[j], q0i[j]);
            let (br, bi) = (q1r[j], q1i[j]);
            let (cr, ci) = (q2r[j], q2i[j]);
            let (dr, di) = (q3r[j], q3i[j]);
            let (t0r, t0i) = (ar + cr, ai + ci);
            let (t1r, t1i) = (ar - cr, ai - ci);
            let (t2r, t2i) = (br + dr, bi + di);
            let (t3r, t3i) = (bi - di, -(br - dr));
            q0r[j] = t0r + t2r;
            q0i[j] = t0i + t2i;
            let (y1r, y1i) = cmul(t0r - t2r, t0i - t2i, w2r[j], w2i[j]);
            q1r[j] = y1r;
            q1i[j] = y1i;
            let (y2r, y2i) = cmul(t1r + t3r, t1i + t3i, w1r[j], w1i[j]);
            q2r[j] = y2r;
            q2i[j] = y2i;
            let (y3r, y3i) = cmul(t1r - t3r, t1i - t3i, w3r[j], w3i[j]);
            q3r[j] = y3r;
            q3i[j] = y3i;
            j += 1;
        }
        base += m;
    }
}

/// [`crate::fft::passes::radix8`], vectorized across j. The 8-complex
/// working set (16 data vectors plus twiddles and temporaries) is
/// exactly the register-pressure story of the paper's finding 2.
#[inline(always)]
pub fn radix8_v<V: Vf32>(
    re: &mut [f32],
    im: &mut [f32],
    stage: usize,
    w1: &TwiddleVec,
    w2: &TwiddleVec,
    w4: &TwiddleVec,
) {
    let n = re.len();
    let m = n >> stage;
    debug_assert!(m >= 8, "R8 at stage {stage} invalid for n={n}");
    let e = m / 8;
    debug_assert_eq!(w1.len(), e);
    let (w1r, w1i) = (&w1.re[..e], &w1.im[..e]);
    let (w2r, w2i) = (&w2.re[..e], &w2.im[..e]);
    let (w4r, w4i) = (&w4.re[..e], &w4.im[..e]);
    let mut base = 0;
    while base < n {
        let mut rs: [&mut [f32]; 8] = split8(&mut re[base..base + m], e);
        let mut is_: [&mut [f32]; 8] = split8(&mut im[base..base + m], e);
        let mut j = 0;
        while j + V::LANES <= e {
            let mut xr = [V::splat(0.0); 8];
            let mut xi = [V::splat(0.0); 8];
            for k in 0..8 {
                xr[k] = V::load(&rs[k][j..]);
                xi[k] = V::load(&is_[k][j..]);
            }
            let (w1rv, w1iv) = (V::load(&w1r[j..]), V::load(&w1i[j..]));
            let (w2rv, w2iv) = (V::load(&w2r[j..]), V::load(&w2i[j..]));
            let (w4rv, w4iv) = (V::load(&w4r[j..]), V::load(&w4i[j..]));
            // Stage A: pairs (k, k+4); twiddle W_m^j * W_8^k.
            let mut yr = [V::splat(0.0); 8];
            let mut yi = [V::splat(0.0); 8];
            for k in 0..4 {
                yr[k] = xr[k].add(xr[k + 4]);
                yi[k] = xi[k].add(xi[k + 4]);
                let (pr, pi) = vcmul(xr[k].sub(xr[k + 4]), xi[k].sub(xi[k + 4]), w1rv, w1iv);
                let (rr, ri) = vw8_rotate(pr, pi, k);
                yr[k + 4] = rr;
                yi[k + 4] = ri;
            }
            // Stage B: pairs (k, k+2) within halves.
            let mut zr = [V::splat(0.0); 8];
            let mut zi = [V::splat(0.0); 8];
            for half in [0usize, 4] {
                for k in 0..2 {
                    let a = half + k;
                    let b = half + k + 2;
                    zr[a] = yr[a].add(yr[b]);
                    zi[a] = yi[a].add(yi[b]);
                    let (mut pr, mut pi) =
                        vcmul(yr[a].sub(yr[b]), yi[a].sub(yi[b]), w2rv, w2iv);
                    if k == 1 {
                        // W_4^1 = -j: swap + negate
                        let t = pr;
                        pr = pi;
                        pi = t.neg();
                    }
                    zr[b] = pr;
                    zi[b] = pi;
                }
            }
            // Stage C: adjacent pairs; twiddle W_m^{4j}.
            for k in [0usize, 2, 4, 6] {
                zr[k].add(zr[k + 1]).store(&mut rs[k][j..]);
                zi[k].add(zi[k + 1]).store(&mut is_[k][j..]);
                let (pr, pi) = vcmul(zr[k].sub(zr[k + 1]), zi[k].sub(zi[k + 1]), w4rv, w4iv);
                pr.store(&mut rs[k + 1][j..]);
                pi.store(&mut is_[k + 1][j..]);
            }
            j += V::LANES;
        }
        while j < e {
            radix8_group_scalar(&mut rs, &mut is_, j, w1r[j], w1i[j], w2r[j], w2i[j], w4r[j], w4i[j]);
            j += 1;
        }
        base += m;
    }
}

/// One radix-8 group, scalar — the identical inner body of
/// [`crate::fft::passes::radix8`] (and its tail here).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn radix8_group_scalar(
    rs: &mut [&mut [f32]; 8],
    is_: &mut [&mut [f32]; 8],
    j: usize,
    w1r: f32,
    w1i: f32,
    w2r: f32,
    w2i: f32,
    w4r: f32,
    w4i: f32,
) {
    let mut xr = [0f32; 8];
    let mut xi = [0f32; 8];
    for k in 0..8 {
        xr[k] = rs[k][j];
        xi[k] = is_[k][j];
    }
    let mut yr = [0f32; 8];
    let mut yi = [0f32; 8];
    for k in 0..4 {
        yr[k] = xr[k] + xr[k + 4];
        yi[k] = xi[k] + xi[k + 4];
        let (dr, di) = (xr[k] - xr[k + 4], xi[k] - xi[k + 4]);
        let (pr, pi) = cmul(dr, di, w1r, w1i);
        let (rr, ri) = w8_rotate(pr, pi, k);
        yr[k + 4] = rr;
        yi[k + 4] = ri;
    }
    let mut zr = [0f32; 8];
    let mut zi = [0f32; 8];
    for half in [0usize, 4] {
        for k in 0..2 {
            let a = half + k;
            let b = half + k + 2;
            zr[a] = yr[a] + yr[b];
            zi[a] = yi[a] + yi[b];
            let (dr, di) = (yr[a] - yr[b], yi[a] - yi[b]);
            let (mut pr, mut pi) = cmul(dr, di, w2r, w2i);
            if k == 1 {
                let t = pr;
                pr = pi;
                pi = -t;
            }
            zr[b] = pr;
            zi[b] = pi;
        }
    }
    for k in [0usize, 2, 4, 6] {
        let (ar, ai) = (zr[k], zi[k]);
        let (br, bi) = (zr[k + 1], zi[k + 1]);
        rs[k][j] = ar + br;
        is_[k][j] = ai + bi;
        let (pr, pi) = cmul(ar - br, ai - bi, w4r, w4i);
        rs[k + 1][j] = pr;
        is_[k + 1][j] = pi;
    }
}

// ---------------------------------------------------------------------
// Batched radix passes (vector over the batch lanes of each element;
// twiddle broadcast once per j — the whole point of lane blocking).
// ---------------------------------------------------------------------

/// [`crate::fft::passes::radix2_b`], vectorized across batch lanes.
#[inline(always)]
pub fn radix2_b_v<V: Vf32>(
    re: &mut [f32],
    im: &mut [f32],
    stage: usize,
    w1: &TwiddleVec,
    lanes: usize,
) {
    debug_assert!(lanes >= 1 && re.len() % lanes == 0);
    let n = re.len() / lanes;
    let m = n >> stage;
    debug_assert!(m >= 2, "R2 at stage {stage} invalid for n={n}");
    let half = m / 2;
    debug_assert_eq!(w1.len(), half);
    let mut base = 0;
    while base < n {
        let s = base * lanes;
        let (top, bot) = re[s..s + m * lanes].split_at_mut(half * lanes);
        let (topi, boti) = im[s..s + m * lanes].split_at_mut(half * lanes);
        for j in 0..half {
            let (wr, wi) = (w1.re[j], w1.im[j]);
            let (wrv, wiv) = (V::splat(wr), V::splat(wi));
            let row = j * lanes;
            let end = row + lanes;
            let mut l = row;
            while l + V::LANES <= end {
                let (tr, ti) = (V::load(&top[l..]), V::load(&topi[l..]));
                let (br, bi) = (V::load(&bot[l..]), V::load(&boti[l..]));
                tr.add(br).store(&mut top[l..]);
                ti.add(bi).store(&mut topi[l..]);
                let (pr, pi) = vcmul(tr.sub(br), ti.sub(bi), wrv, wiv);
                pr.store(&mut bot[l..]);
                pi.store(&mut boti[l..]);
                l += V::LANES;
            }
            while l < end {
                let (tr, ti) = (top[l], topi[l]);
                let (br, bi) = (bot[l], boti[l]);
                top[l] = tr + br;
                topi[l] = ti + bi;
                let (pr, pi) = cmul(tr - br, ti - bi, wr, wi);
                bot[l] = pr;
                boti[l] = pi;
                l += 1;
            }
        }
        base += m;
    }
}

/// [`crate::fft::passes::radix4_b`], vectorized across batch lanes.
#[inline(always)]
pub fn radix4_b_v<V: Vf32>(
    re: &mut [f32],
    im: &mut [f32],
    stage: usize,
    w1: &TwiddleVec,
    w2: &TwiddleVec,
    w3: &TwiddleVec,
    lanes: usize,
) {
    debug_assert!(lanes >= 1 && re.len() % lanes == 0);
    let n = re.len() / lanes;
    let m = n >> stage;
    debug_assert!(m >= 4, "R4 at stage {stage} invalid for n={n}");
    let q = m / 4;
    debug_assert_eq!(w1.len(), q);
    let mut base = 0;
    while base < n {
        let s = base * lanes;
        let (q0r, rest) = re[s..s + m * lanes].split_at_mut(q * lanes);
        let (q1r, rest) = rest.split_at_mut(q * lanes);
        let (q2r, q3r) = rest.split_at_mut(q * lanes);
        let (q0i, rest) = im[s..s + m * lanes].split_at_mut(q * lanes);
        let (q1i, rest) = rest.split_at_mut(q * lanes);
        let (q2i, q3i) = rest.split_at_mut(q * lanes);
        for j in 0..q {
            let (w1r, w1i) = (w1.re[j], w1.im[j]);
            let (w2r, w2i) = (w2.re[j], w2.im[j]);
            let (w3r, w3i) = (w3.re[j], w3.im[j]);
            let (w1rv, w1iv) = (V::splat(w1r), V::splat(w1i));
            let (w2rv, w2iv) = (V::splat(w2r), V::splat(w2i));
            let (w3rv, w3iv) = (V::splat(w3r), V::splat(w3i));
            let row = j * lanes;
            let end = row + lanes;
            let mut l = row;
            while l + V::LANES <= end {
                let (ar, ai) = (V::load(&q0r[l..]), V::load(&q0i[l..]));
                let (br, bi) = (V::load(&q1r[l..]), V::load(&q1i[l..]));
                let (cr, ci) = (V::load(&q2r[l..]), V::load(&q2i[l..]));
                let (dr, di) = (V::load(&q3r[l..]), V::load(&q3i[l..]));
                let (t0r, t0i) = (ar.add(cr), ai.add(ci));
                let (t1r, t1i) = (ar.sub(cr), ai.sub(ci));
                let (t2r, t2i) = (br.add(dr), bi.add(di));
                let (t3r, t3i) = (bi.sub(di), br.sub(dr).neg());
                t0r.add(t2r).store(&mut q0r[l..]);
                t0i.add(t2i).store(&mut q0i[l..]);
                let (y1r, y1i) = vcmul(t0r.sub(t2r), t0i.sub(t2i), w2rv, w2iv);
                y1r.store(&mut q1r[l..]);
                y1i.store(&mut q1i[l..]);
                let (y2r, y2i) = vcmul(t1r.add(t3r), t1i.add(t3i), w1rv, w1iv);
                y2r.store(&mut q2r[l..]);
                y2i.store(&mut q2i[l..]);
                let (y3r, y3i) = vcmul(t1r.sub(t3r), t1i.sub(t3i), w3rv, w3iv);
                y3r.store(&mut q3r[l..]);
                y3i.store(&mut q3i[l..]);
                l += V::LANES;
            }
            while l < end {
                let (ar, ai) = (q0r[l], q0i[l]);
                let (br, bi) = (q1r[l], q1i[l]);
                let (cr, ci) = (q2r[l], q2i[l]);
                let (dr, di) = (q3r[l], q3i[l]);
                let (t0r, t0i) = (ar + cr, ai + ci);
                let (t1r, t1i) = (ar - cr, ai - ci);
                let (t2r, t2i) = (br + dr, bi + di);
                let (t3r, t3i) = (bi - di, -(br - dr));
                q0r[l] = t0r + t2r;
                q0i[l] = t0i + t2i;
                let (y1r, y1i) = cmul(t0r - t2r, t0i - t2i, w2r, w2i);
                q1r[l] = y1r;
                q1i[l] = y1i;
                let (y2r, y2i) = cmul(t1r + t3r, t1i + t3i, w1r, w1i);
                q2r[l] = y2r;
                q2i[l] = y2i;
                let (y3r, y3i) = cmul(t1r - t3r, t1i - t3i, w3r, w3i);
                q3r[l] = y3r;
                q3i[l] = y3i;
                l += 1;
            }
        }
        base += m;
    }
}

/// [`crate::fft::passes::radix8_b`], vectorized across batch lanes.
#[inline(always)]
pub fn radix8_b_v<V: Vf32>(
    re: &mut [f32],
    im: &mut [f32],
    stage: usize,
    w1: &TwiddleVec,
    w2: &TwiddleVec,
    w4: &TwiddleVec,
    lanes: usize,
) {
    debug_assert!(lanes >= 1 && re.len() % lanes == 0);
    let n = re.len() / lanes;
    let m = n >> stage;
    debug_assert!(m >= 8, "R8 at stage {stage} invalid for n={n}");
    let e = m / 8;
    debug_assert_eq!(w1.len(), e);
    let mut base = 0;
    while base < n {
        let s = base * lanes;
        let mut rs: [&mut [f32]; 8] = split8(&mut re[s..s + m * lanes], e * lanes);
        let mut is_: [&mut [f32]; 8] = split8(&mut im[s..s + m * lanes], e * lanes);
        for j in 0..e {
            let (w1r, w1i) = (w1.re[j], w1.im[j]);
            let (w2r, w2i) = (w2.re[j], w2.im[j]);
            let (w4r, w4i) = (w4.re[j], w4.im[j]);
            let (w1rv, w1iv) = (V::splat(w1r), V::splat(w1i));
            let (w2rv, w2iv) = (V::splat(w2r), V::splat(w2i));
            let (w4rv, w4iv) = (V::splat(w4r), V::splat(w4i));
            let row = j * lanes;
            let end = row + lanes;
            let mut l = row;
            while l + V::LANES <= end {
                let mut xr = [V::splat(0.0); 8];
                let mut xi = [V::splat(0.0); 8];
                for k in 0..8 {
                    xr[k] = V::load(&rs[k][l..]);
                    xi[k] = V::load(&is_[k][l..]);
                }
                let mut yr = [V::splat(0.0); 8];
                let mut yi = [V::splat(0.0); 8];
                for k in 0..4 {
                    yr[k] = xr[k].add(xr[k + 4]);
                    yi[k] = xi[k].add(xi[k + 4]);
                    let (pr, pi) =
                        vcmul(xr[k].sub(xr[k + 4]), xi[k].sub(xi[k + 4]), w1rv, w1iv);
                    let (rr, ri) = vw8_rotate(pr, pi, k);
                    yr[k + 4] = rr;
                    yi[k + 4] = ri;
                }
                let mut zr = [V::splat(0.0); 8];
                let mut zi = [V::splat(0.0); 8];
                for half in [0usize, 4] {
                    for k in 0..2 {
                        let a = half + k;
                        let b = half + k + 2;
                        zr[a] = yr[a].add(yr[b]);
                        zi[a] = yi[a].add(yi[b]);
                        let (mut pr, mut pi) =
                            vcmul(yr[a].sub(yr[b]), yi[a].sub(yi[b]), w2rv, w2iv);
                        if k == 1 {
                            let t = pr;
                            pr = pi;
                            pi = t.neg();
                        }
                        zr[b] = pr;
                        zi[b] = pi;
                    }
                }
                for k in [0usize, 2, 4, 6] {
                    zr[k].add(zr[k + 1]).store(&mut rs[k][l..]);
                    zi[k].add(zi[k + 1]).store(&mut is_[k][l..]);
                    let (pr, pi) =
                        vcmul(zr[k].sub(zr[k + 1]), zi[k].sub(zi[k + 1]), w4rv, w4iv);
                    pr.store(&mut rs[k + 1][l..]);
                    pi.store(&mut is_[k + 1][l..]);
                }
                l += V::LANES;
            }
            while l < end {
                radix8_group_scalar(&mut rs, &mut is_, l, w1r, w1i, w2r, w2i, w4r, w4i);
                l += 1;
            }
        }
        base += m;
    }
}

// ---------------------------------------------------------------------
// Fused register blocks (vector over the tile rows; scalar remainder
// groups call fused::fused_group_scalar — the actual scalar code).
// ---------------------------------------------------------------------

/// Disjoint mutable refs to rows a < b of a tile.
#[inline(always)]
fn row_pair<const W: usize, const B: usize>(
    x: &mut [[f32; W]; B],
    a: usize,
    b: usize,
) -> (&mut [f32; W], &mut [f32; W]) {
    debug_assert!(a < b);
    let (lo, hi) = x.split_at_mut(b);
    (&mut lo[a], &mut hi[0])
}

/// One butterfly over W-wide tile rows with a per-column twiddle slice.
#[inline(always)]
fn rows_butterfly_tw<V: Vf32, const W: usize>(
    ra: &mut [f32; W],
    ia: &mut [f32; W],
    rb: &mut [f32; W],
    ib: &mut [f32; W],
    wr: &[f32],
    wi: &[f32],
) {
    let mut t = 0;
    while t + V::LANES <= W {
        let (ar, ai) = (V::load(&ra[t..]), V::load(&ia[t..]));
        let (br, bi) = (V::load(&rb[t..]), V::load(&ib[t..]));
        let (sr, si) = (ar.add(br), ai.add(bi));
        let (pr, pi) = vcmul(ar.sub(br), ai.sub(bi), V::load(&wr[t..]), V::load(&wi[t..]));
        sr.store(&mut ra[t..]);
        si.store(&mut ia[t..]);
        pr.store(&mut rb[t..]);
        pi.store(&mut ib[t..]);
        t += V::LANES;
    }
    while t < W {
        let (tr, ti) = (ra[t] + rb[t], ia[t] + ib[t]);
        let (dr, di) = (ra[t] - rb[t], ia[t] - ib[t]);
        let (pr, pi) = cmul(dr, di, wr[t], wi[t]);
        ra[t] = tr;
        ia[t] = ti;
        rb[t] = pr;
        ib[t] = pi;
        t += 1;
    }
}

/// One butterfly over W-wide tile rows with a broadcast twiddle.
#[inline(always)]
fn rows_butterfly_tw_const<V: Vf32, const W: usize>(
    ra: &mut [f32; W],
    ia: &mut [f32; W],
    rb: &mut [f32; W],
    ib: &mut [f32; W],
    wr: f32,
    wi: f32,
) {
    let (wrv, wiv) = (V::splat(wr), V::splat(wi));
    let mut t = 0;
    while t + V::LANES <= W {
        let (ar, ai) = (V::load(&ra[t..]), V::load(&ia[t..]));
        let (br, bi) = (V::load(&rb[t..]), V::load(&ib[t..]));
        let (sr, si) = (ar.add(br), ai.add(bi));
        let (pr, pi) = vcmul(ar.sub(br), ai.sub(bi), wrv, wiv);
        sr.store(&mut ra[t..]);
        si.store(&mut ia[t..]);
        pr.store(&mut rb[t..]);
        pi.store(&mut ib[t..]);
        t += V::LANES;
    }
    while t < W {
        let (tr, ti) = (ra[t] + rb[t], ia[t] + ib[t]);
        let (dr, di) = (ra[t] - rb[t], ia[t] - ib[t]);
        let (pr, pi) = cmul(dr, di, wr, wi);
        ra[t] = tr;
        ia[t] = ti;
        rb[t] = pr;
        ib[t] = pi;
        t += 1;
    }
}

/// [`crate::fft::fused`]'s `fused_generic`, with the tile butterflies
/// vectorized ([`TILE`] = 8 columns, so NEON runs 2 vectors per row and
/// AVX2 runs 1).
#[inline(always)]
pub fn fused_v<V: Vf32, const B: usize>(
    re: &mut [f32],
    im: &mut [f32],
    stage: usize,
    wt: &[Arc<TwiddleVec>],
) {
    let n = re.len();
    let m = n >> stage;
    let lb = B.trailing_zeros() as usize;
    debug_assert!(m >= B, "F{B} at stage {stage} invalid for n={n}");
    debug_assert_eq!(wt.len(), lb);
    let e = m / B;
    if e == 1 {
        let mut base = 0;
        while base + TILE * B <= n {
            fused_tile_terminal_v::<V, B>(re, im, base, wt);
            base += TILE * B;
        }
        while base < n {
            fused_group_scalar::<B>(re, im, base, 0, 1, wt);
            base += B;
        }
        return;
    }
    let mut base = 0;
    while base < n {
        let mut j = 0;
        while j + TILE <= e {
            fused_tile_mid_v::<V, B>(re, im, base, j, e, wt);
            j += TILE;
        }
        while j < e {
            fused_group_scalar::<B>(re, im, base, j, e, wt);
            j += 1;
        }
        base += m;
    }
}

/// TILE consecutive-j groups of one block, butterflies vectorized.
#[inline(always)]
fn fused_tile_mid_v<V: Vf32, const B: usize>(
    re: &mut [f32],
    im: &mut [f32],
    base: usize,
    j0: usize,
    e: usize,
    wt: &[Arc<TwiddleVec>],
) {
    let mut xr = [[0f32; TILE]; B];
    let mut xi = [[0f32; TILE]; B];
    for k in 0..B {
        let s = base + j0 + k * e;
        xr[k].copy_from_slice(&re[s..s + TILE]);
        xi[k].copy_from_slice(&im[s..s + TILE]);
    }
    for (r, w) in wt.iter().enumerate() {
        let lanes = B >> r;
        let half = lanes / 2;
        for g in 0..(B / lanes) {
            let off = g * lanes;
            for k in 0..half {
                let wrow = k * e + j0;
                let (a, b) = (off + k, off + k + half);
                let (ra, rb) = row_pair(&mut xr, a, b);
                let (ia, ib) = row_pair(&mut xi, a, b);
                rows_butterfly_tw::<V, TILE>(
                    ra,
                    ia,
                    rb,
                    ib,
                    &w.re[wrow..wrow + TILE],
                    &w.im[wrow..wrow + TILE],
                );
            }
        }
    }
    for k in 0..B {
        let s = base + j0 + k * e;
        re[s..s + TILE].copy_from_slice(&xr[k]);
        im[s..s + TILE].copy_from_slice(&xi[k]);
    }
}

/// TILE consecutive terminal blocks (in-register transpose layout),
/// butterflies vectorized with constant twiddles.
#[inline(always)]
fn fused_tile_terminal_v<V: Vf32, const B: usize>(
    re: &mut [f32],
    im: &mut [f32],
    base: usize,
    wt: &[Arc<TwiddleVec>],
) {
    let mut xr = [[0f32; TILE]; B];
    let mut xi = [[0f32; TILE]; B];
    for t in 0..TILE {
        for k in 0..B {
            xr[k][t] = re[base + t * B + k];
            xi[k][t] = im[base + t * B + k];
        }
    }
    for (r, w) in wt.iter().enumerate() {
        let lanes = B >> r;
        let half = lanes / 2;
        for g in 0..(B / lanes) {
            let off = g * lanes;
            for k in 0..half {
                let (wr, wi) = (w.re[k], w.im[k]); // e == 1: one entry per k
                let (a, b) = (off + k, off + k + half);
                let (ra, rb) = row_pair(&mut xr, a, b);
                let (ia, ib) = row_pair(&mut xi, a, b);
                rows_butterfly_tw_const::<V, TILE>(ra, ia, rb, ib, wr, wi);
            }
        }
    }
    for t in 0..TILE {
        for k in 0..B {
            re[base + t * B + k] = xr[k][t];
            im[base + t * B + k] = xi[k][t];
        }
    }
}

/// [`crate::fft::fused`]'s `fused_generic_b`, with the per-group
/// [`BL`]-wide lane chunk butterflies vectorized. (With `BL` = 4, an
/// 8-lane ISA's vector loop never fires and the scalar tail handles the
/// whole chunk — correct, just unamortized; lane-blocked buffers are
/// sized for the 4-lane native target.)
#[inline(always)]
pub fn fused_b_v<V: Vf32, const B: usize>(
    re: &mut [f32],
    im: &mut [f32],
    stage: usize,
    wt: &[Arc<TwiddleVec>],
    lanes: usize,
) {
    debug_assert!(lanes >= 1 && lanes % BL == 0 && re.len() % lanes == 0);
    let n = re.len() / lanes;
    let m = n >> stage;
    let lb = B.trailing_zeros() as usize;
    debug_assert!(m >= B, "F{B} at stage {stage} invalid for n={n}");
    debug_assert_eq!(wt.len(), lb);
    let e = m / B;
    let estride = e * lanes;
    let mut base = 0;
    while base < n {
        for j in 0..e {
            let flat = (base + j) * lanes;
            let mut c = 0;
            while c < lanes {
                fused_lane_tile_v::<V, B>(re, im, flat + c, estride, j, e, wt);
                c += BL;
            }
        }
        base += m;
    }
}

/// One [`BL`]-wide lane chunk of one fused group, vectorized.
#[inline(always)]
fn fused_lane_tile_v<V: Vf32, const B: usize>(
    re: &mut [f32],
    im: &mut [f32],
    flat0: usize,
    estride: usize,
    j: usize,
    e: usize,
    wt: &[Arc<TwiddleVec>],
) {
    let mut xr = [[0f32; BL]; B];
    let mut xi = [[0f32; BL]; B];
    for k in 0..B {
        let s = flat0 + k * estride;
        xr[k].copy_from_slice(&re[s..s + BL]);
        xi[k].copy_from_slice(&im[s..s + BL]);
    }
    for (r, w) in wt.iter().enumerate() {
        let lanes = B >> r;
        let half = lanes / 2;
        for g in 0..(B / lanes) {
            let off = g * lanes;
            for k in 0..half {
                let (wr, wi) = (w.re[k * e + j], w.im[k * e + j]);
                let (a, b) = (off + k, off + k + half);
                let (ra, rb) = row_pair(&mut xr, a, b);
                let (ia, ib) = row_pair(&mut xi, a, b);
                rows_butterfly_tw_const::<V, BL>(ra, ia, rb, ib, wr, wi);
            }
        }
    }
    for k in 0..B {
        let s = flat0 + k * estride;
        re[s..s + BL].copy_from_slice(&xr[k]);
        im[s..s + BL].copy_from_slice(&xi[k]);
    }
}
