//! Portable `std::simd` codelet backend: 8-lane f32, compiled for
//! whatever the target baseline supports (the compiler legalizes wider
//! ops). Nightly-only (`portable_simd` language feature), so the whole
//! backend sits behind the off-by-default `portable-simd` cargo
//! feature; without it, [`crate::isa::Isa::Portable`] resolves to the
//! scalar table.

use std::simd::f32x8;
use std::sync::Arc;

use super::super::twiddle::TwiddleVec;
use super::generic::{self, Vf32};
use super::Kernels;
use crate::isa::Isa;

/// One portable 8-lane f32 vector.
#[derive(Clone, Copy)]
struct VP(f32x8);

impl Vf32 for VP {
    const LANES: usize = 8;

    #[inline(always)]
    fn load(src: &[f32]) -> Self {
        debug_assert!(src.len() >= 8);
        VP(f32x8::from_slice(&src[..8]))
    }

    #[inline(always)]
    fn store(self, dst: &mut [f32]) {
        debug_assert!(dst.len() >= 8);
        self.0.copy_to_slice(&mut dst[..8]);
    }

    #[inline(always)]
    fn splat(x: f32) -> Self {
        VP(f32x8::splat(x))
    }

    #[inline(always)]
    fn add(self, o: Self) -> Self {
        VP(self.0 + o.0)
    }

    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        VP(self.0 - o.0)
    }

    #[inline(always)]
    fn mul(self, o: Self) -> Self {
        // Plain lane multiply (std::simd never contracts to FMA), so
        // bit-parity with the scalar kernels holds.
        VP(self.0 * o.0)
    }

    #[inline(always)]
    fn neg(self) -> Self {
        VP(-self.0)
    }
}

fn radix2(re: &mut [f32], im: &mut [f32], stage: usize, w1: &TwiddleVec) {
    generic::radix2_v::<VP>(re, im, stage, w1)
}

fn radix4(re: &mut [f32], im: &mut [f32], stage: usize, w1: &TwiddleVec, w2: &TwiddleVec, w3: &TwiddleVec) {
    generic::radix4_v::<VP>(re, im, stage, w1, w2, w3)
}

fn radix8(re: &mut [f32], im: &mut [f32], stage: usize, w1: &TwiddleVec, w2: &TwiddleVec, w4: &TwiddleVec) {
    generic::radix8_v::<VP>(re, im, stage, w1, w2, w4)
}

fn fused8(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>]) {
    generic::fused_v::<VP, 8>(re, im, stage, wt)
}

fn fused16(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>]) {
    generic::fused_v::<VP, 16>(re, im, stage, wt)
}

fn fused32(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>]) {
    generic::fused_v::<VP, 32>(re, im, stage, wt)
}

fn radix2_b(re: &mut [f32], im: &mut [f32], stage: usize, w1: &TwiddleVec, lanes: usize) {
    generic::radix2_b_v::<VP>(re, im, stage, w1, lanes)
}

fn radix4_b(
    re: &mut [f32],
    im: &mut [f32],
    stage: usize,
    w1: &TwiddleVec,
    w2: &TwiddleVec,
    w3: &TwiddleVec,
    lanes: usize,
) {
    generic::radix4_b_v::<VP>(re, im, stage, w1, w2, w3, lanes)
}

fn radix8_b(
    re: &mut [f32],
    im: &mut [f32],
    stage: usize,
    w1: &TwiddleVec,
    w2: &TwiddleVec,
    w4: &TwiddleVec,
    lanes: usize,
) {
    generic::radix8_b_v::<VP>(re, im, stage, w1, w2, w4, lanes)
}

fn fused8_b(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>], lanes: usize) {
    generic::fused_b_v::<VP, 8>(re, im, stage, wt, lanes)
}

fn fused16_b(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>], lanes: usize) {
    generic::fused_b_v::<VP, 16>(re, im, stage, wt, lanes)
}

fn fused32_b(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>], lanes: usize) {
    generic::fused_b_v::<VP, 32>(re, im, stage, wt, lanes)
}

pub(super) static KERNELS: Kernels = Kernels {
    isa: Isa::Portable,
    radix2,
    radix4,
    radix8,
    fused8,
    fused16,
    fused32,
    radix2_b,
    radix4_b,
    radix8_b,
    fused8_b,
    fused16_b,
    fused32_b,
};
