//! AArch64 NEON codelet backend: 4-lane f32 over the 32×128-bit vector
//! file — the paper's native target. NEON is baseline on aarch64, so no
//! runtime feature detection or `#[target_feature]` wrappers are needed;
//! the generic bodies instantiate directly.

// Intrinsic safety varies by toolchain (pre-1.87 all of core::arch is
// `unsafe fn`, newer compilers make the value ops safe when the feature
// is statically enabled); keep the unsafe blocks and silence the lint
// where they became redundant.
#![allow(unused_unsafe)]

use std::sync::Arc;

use core::arch::aarch64::{
    float32x4_t, vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vnegq_f32, vst1q_f32, vsubq_f32,
};

use super::super::twiddle::TwiddleVec;
use super::generic::{self, Vf32};
use super::Kernels;
use crate::isa::Isa;

/// One NEON q-register of 4 f32 lanes.
#[derive(Clone, Copy)]
struct V4(float32x4_t);

impl Vf32 for V4 {
    const LANES: usize = 4;

    #[inline(always)]
    fn load(src: &[f32]) -> Self {
        debug_assert!(src.len() >= 4);
        // Safety: length checked; vld1q_f32 reads 4 f32 from the pointer.
        V4(unsafe { vld1q_f32(src.as_ptr()) })
    }

    #[inline(always)]
    fn store(self, dst: &mut [f32]) {
        debug_assert!(dst.len() >= 4);
        // Safety: length checked; vst1q_f32 writes 4 f32 to the pointer.
        unsafe { vst1q_f32(dst.as_mut_ptr(), self.0) }
    }

    #[inline(always)]
    fn splat(x: f32) -> Self {
        V4(unsafe { vdupq_n_f32(x) })
    }

    #[inline(always)]
    fn add(self, o: Self) -> Self {
        V4(unsafe { vaddq_f32(self.0, o.0) })
    }

    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        V4(unsafe { vsubq_f32(self.0, o.0) })
    }

    #[inline(always)]
    fn mul(self, o: Self) -> Self {
        // Plain multiply, never vfmaq: the scalar kernels round after
        // every op, and bit-parity with them is the contract.
        V4(unsafe { vmulq_f32(self.0, o.0) })
    }

    #[inline(always)]
    fn neg(self) -> Self {
        V4(unsafe { vnegq_f32(self.0) })
    }
}

fn radix2(re: &mut [f32], im: &mut [f32], stage: usize, w1: &TwiddleVec) {
    generic::radix2_v::<V4>(re, im, stage, w1)
}

fn radix4(re: &mut [f32], im: &mut [f32], stage: usize, w1: &TwiddleVec, w2: &TwiddleVec, w3: &TwiddleVec) {
    generic::radix4_v::<V4>(re, im, stage, w1, w2, w3)
}

fn radix8(re: &mut [f32], im: &mut [f32], stage: usize, w1: &TwiddleVec, w2: &TwiddleVec, w4: &TwiddleVec) {
    generic::radix8_v::<V4>(re, im, stage, w1, w2, w4)
}

fn fused8(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>]) {
    generic::fused_v::<V4, 8>(re, im, stage, wt)
}

fn fused16(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>]) {
    generic::fused_v::<V4, 16>(re, im, stage, wt)
}

fn fused32(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>]) {
    generic::fused_v::<V4, 32>(re, im, stage, wt)
}

fn radix2_b(re: &mut [f32], im: &mut [f32], stage: usize, w1: &TwiddleVec, lanes: usize) {
    generic::radix2_b_v::<V4>(re, im, stage, w1, lanes)
}

fn radix4_b(
    re: &mut [f32],
    im: &mut [f32],
    stage: usize,
    w1: &TwiddleVec,
    w2: &TwiddleVec,
    w3: &TwiddleVec,
    lanes: usize,
) {
    generic::radix4_b_v::<V4>(re, im, stage, w1, w2, w3, lanes)
}

fn radix8_b(
    re: &mut [f32],
    im: &mut [f32],
    stage: usize,
    w1: &TwiddleVec,
    w2: &TwiddleVec,
    w4: &TwiddleVec,
    lanes: usize,
) {
    generic::radix8_b_v::<V4>(re, im, stage, w1, w2, w4, lanes)
}

fn fused8_b(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>], lanes: usize) {
    generic::fused_b_v::<V4, 8>(re, im, stage, wt, lanes)
}

fn fused16_b(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>], lanes: usize) {
    generic::fused_b_v::<V4, 16>(re, im, stage, wt, lanes)
}

fn fused32_b(re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>], lanes: usize) {
    generic::fused_b_v::<V4, 32>(re, im, stage, wt, lanes)
}

pub(super) static KERNELS: Kernels = Kernels {
    isa: Isa::Neon,
    radix2,
    radix4,
    radix8,
    fused8,
    fused16,
    fused32,
    radix2_b,
    radix4_b,
    radix8_b,
    fused8_b,
    fused16_b,
    fused32_b,
};
