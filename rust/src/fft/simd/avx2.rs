//! x86-64 AVX2 codelet backend: 8-lane f32 over the 16×256-bit vector
//! file. Twice NEON's lane width, half its register count — which is
//! exactly why [`crate::isa::Isa::supports`] masks the F32 fused block
//! here (paper Table 1: "impossible on AVX2's 16-register file"); this
//! table still carries `fused32` entries for parity testing, but no
//! AVX2 planning surface will ever schedule them.
//!
//! AVX2 is *not* baseline x86-64, so every kernel body compiles inside
//! a `#[target_feature(enable = "avx2")]` wrapper (the generic bodies
//! and [`Vf32`] methods are `#[inline(always)]`, so they inherit the
//! feature), and the table is only handed out after
//! `is_x86_feature_detected!("avx2")` (see `for_isa` in the parent
//! module) — the safe wrappers rely on that gate.

#![allow(unused_unsafe)]

use std::sync::Arc;

use core::arch::x86_64::{
    __m256, _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
    _mm256_sub_ps, _mm256_xor_ps,
};

use super::super::twiddle::TwiddleVec;
use super::generic::{self, Vf32};
use super::Kernels;
use crate::isa::Isa;

/// One AVX2 ymm register of 8 f32 lanes.
#[derive(Clone, Copy)]
struct V8(__m256);

impl Vf32 for V8 {
    const LANES: usize = 8;

    #[inline(always)]
    fn load(src: &[f32]) -> Self {
        debug_assert!(src.len() >= 8);
        // Safety: length checked; unaligned load of 8 f32.
        V8(unsafe { _mm256_loadu_ps(src.as_ptr()) })
    }

    #[inline(always)]
    fn store(self, dst: &mut [f32]) {
        debug_assert!(dst.len() >= 8);
        // Safety: length checked; unaligned store of 8 f32.
        unsafe { _mm256_storeu_ps(dst.as_mut_ptr(), self.0) }
    }

    #[inline(always)]
    fn splat(x: f32) -> Self {
        V8(unsafe { _mm256_set1_ps(x) })
    }

    #[inline(always)]
    fn add(self, o: Self) -> Self {
        V8(unsafe { _mm256_add_ps(self.0, o.0) })
    }

    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        V8(unsafe { _mm256_sub_ps(self.0, o.0) })
    }

    #[inline(always)]
    fn mul(self, o: Self) -> Self {
        // Plain multiply, never FMA: bit-parity with the scalar kernels
        // (which round after every op) is the contract.
        V8(unsafe { _mm256_mul_ps(self.0, o.0) })
    }

    #[inline(always)]
    fn neg(self) -> Self {
        // Sign-bit flip — the exact IEEE negation the scalar `-x` does.
        V8(unsafe { _mm256_xor_ps(self.0, _mm256_set1_ps(-0.0)) })
    }
}

/// Declare a `#[target_feature(enable = "avx2")]` body plus the safe
/// vtable entry that calls it (safety: the table is gated on runtime
/// AVX2 detection in `for_isa`).
macro_rules! avx2_kernel {
    ($name:ident, $tf:ident, ($($arg:ident: $ty:ty),*), $body:expr) => {
        #[target_feature(enable = "avx2")]
        unsafe fn $tf($($arg: $ty),*) {
            $body
        }

        fn $name($($arg: $ty),*) {
            unsafe { $tf($($arg),*) }
        }
    };
}

avx2_kernel!(
    radix2,
    radix2_tf,
    (re: &mut [f32], im: &mut [f32], stage: usize, w1: &TwiddleVec),
    generic::radix2_v::<V8>(re, im, stage, w1)
);

avx2_kernel!(
    radix4,
    radix4_tf,
    (re: &mut [f32], im: &mut [f32], stage: usize, w1: &TwiddleVec, w2: &TwiddleVec, w3: &TwiddleVec),
    generic::radix4_v::<V8>(re, im, stage, w1, w2, w3)
);

avx2_kernel!(
    radix8,
    radix8_tf,
    (re: &mut [f32], im: &mut [f32], stage: usize, w1: &TwiddleVec, w2: &TwiddleVec, w4: &TwiddleVec),
    generic::radix8_v::<V8>(re, im, stage, w1, w2, w4)
);

avx2_kernel!(
    fused8,
    fused8_tf,
    (re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>]),
    generic::fused_v::<V8, 8>(re, im, stage, wt)
);

avx2_kernel!(
    fused16,
    fused16_tf,
    (re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>]),
    generic::fused_v::<V8, 16>(re, im, stage, wt)
);

avx2_kernel!(
    fused32,
    fused32_tf,
    (re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>]),
    generic::fused_v::<V8, 32>(re, im, stage, wt)
);

avx2_kernel!(
    radix2_b,
    radix2_b_tf,
    (re: &mut [f32], im: &mut [f32], stage: usize, w1: &TwiddleVec, lanes: usize),
    generic::radix2_b_v::<V8>(re, im, stage, w1, lanes)
);

avx2_kernel!(
    radix4_b,
    radix4_b_tf,
    (re: &mut [f32], im: &mut [f32], stage: usize, w1: &TwiddleVec, w2: &TwiddleVec, w3: &TwiddleVec, lanes: usize),
    generic::radix4_b_v::<V8>(re, im, stage, w1, w2, w3, lanes)
);

avx2_kernel!(
    radix8_b,
    radix8_b_tf,
    (re: &mut [f32], im: &mut [f32], stage: usize, w1: &TwiddleVec, w2: &TwiddleVec, w4: &TwiddleVec, lanes: usize),
    generic::radix8_b_v::<V8>(re, im, stage, w1, w2, w4, lanes)
);

avx2_kernel!(
    fused8_b,
    fused8_b_tf,
    (re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>], lanes: usize),
    generic::fused_b_v::<V8, 8>(re, im, stage, wt, lanes)
);

avx2_kernel!(
    fused16_b,
    fused16_b_tf,
    (re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>], lanes: usize),
    generic::fused_b_v::<V8, 16>(re, im, stage, wt, lanes)
);

avx2_kernel!(
    fused32_b,
    fused32_b_tf,
    (re: &mut [f32], im: &mut [f32], stage: usize, wt: &[Arc<TwiddleVec>], lanes: usize),
    generic::fused_b_v::<V8, 32>(re, im, stage, wt, lanes)
);

pub(super) static KERNELS: Kernels = Kernels {
    isa: Isa::Avx2,
    radix2,
    radix4,
    radix8,
    fused8,
    fused16,
    fused32,
    radix2_b,
    radix4_b,
    radix8_b,
    fused8_b,
    fused16_b,
    fused32_b,
};
