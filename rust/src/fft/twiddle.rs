//! Cached twiddle-factor tables.
//!
//! `vector(m, count, k)` returns W_m^{k·j} = exp(-2πi·k·j/m) for
//! j ∈ [0, count), computed once in f64 and cached as split f32 arrays.
//! All passes of all plans share one [`TwiddleCache`] — the paper's "same
//! twiddle table" discipline (§4.1) — so arrangement comparisons measure
//! instruction scheduling, not table-construction differences.

use std::collections::HashMap;
use std::sync::Arc;

/// One twiddle vector: split re/im, unit stride.
#[derive(Debug)]
pub struct TwiddleVec {
    pub re: Vec<f32>,
    pub im: Vec<f32>,
}

impl TwiddleVec {
    fn compute(m: usize, count: usize, k: usize) -> TwiddleVec {
        let mut re = Vec::with_capacity(count);
        let mut im = Vec::with_capacity(count);
        for j in 0..count {
            let ang = -2.0 * std::f64::consts::PI * (k as f64) * (j as f64) / (m as f64);
            re.push(ang.cos() as f32);
            im.push(ang.sin() as f32);
        }
        TwiddleVec { re, im }
    }

    pub fn len(&self) -> usize {
        self.re.len()
    }

    pub fn is_empty(&self) -> bool {
        self.re.is_empty()
    }
}

/// Process-wide twiddle cache keyed by (m, count, k), plus combined
/// fused-block sub-stage tables keyed by (m, e, lanes, step).
#[derive(Debug, Default)]
pub struct TwiddleCache {
    map: HashMap<(usize, usize, usize), Arc<TwiddleVec>>,
    fused: HashMap<(usize, usize, usize, usize), Arc<TwiddleVec>>,
}

impl TwiddleCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// W_m^{k·j} for j in [0, count). Cached.
    pub fn vector(&mut self, m: usize, count: usize, k: usize) -> Arc<TwiddleVec> {
        self.map
            .entry((m, count, k))
            .or_insert_with(|| Arc::new(TwiddleVec::compute(m, count, k)))
            .clone()
    }

    /// Combined fused-block sub-stage table: entry `k*e + j` is
    /// W_m^{step·j} · W_lanes^{k} for k ∈ [0, lanes/2), j ∈ [0, e).
    /// Cached under a disjoint key space (lanes ≥ 2 disambiguates).
    pub fn fused_table(&mut self, m: usize, e: usize, lanes: usize, step: usize) -> Arc<TwiddleVec> {
        self.fused
            .entry((m, e, lanes, step))
            .or_insert_with(|| {
                let half = lanes / 2;
                let mut re = Vec::with_capacity(half * e);
                let mut im = Vec::with_capacity(half * e);
                for k in 0..half {
                    for j in 0..e {
                        let ang = -2.0 * std::f64::consts::PI
                            * ((step * j) as f64 / m as f64 + k as f64 / lanes as f64);
                        re.push(ang.cos() as f32);
                        im.push(ang.sin() as f32);
                    }
                }
                Arc::new(TwiddleVec { re, im })
            })
            .clone()
    }

    /// Number of distinct cached vectors (for tests / memory accounting).
    pub fn entries(&self) -> usize {
        self.map.len() + self.fused.len()
    }

    /// Total cached f32 elements across both components.
    pub fn total_elems(&self) -> usize {
        self.map.values().chain(self.fused.values()).map(|v| 2 * v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_circle_and_identities() {
        let mut c = TwiddleCache::new();
        let w = c.vector(64, 32, 1);
        for j in 0..32 {
            let mag = w.re[j] * w.re[j] + w.im[j] * w.im[j];
            assert!((mag - 1.0).abs() < 1e-6);
        }
        assert_eq!(w.re[0], 1.0);
        assert_eq!(w.im[0], 0.0);
        // W_4^1 = -j
        let w4 = c.vector(4, 2, 1);
        assert!(w4.re[1].abs() < 1e-7);
        assert!((w4.im[1] + 1.0).abs() < 1e-7);
        // W_8^1 = (1-j)/sqrt(2)
        let w8 = c.vector(8, 2, 1);
        let inv = std::f32::consts::FRAC_1_SQRT_2;
        assert!((w8.re[1] - inv).abs() < 1e-7);
        assert!((w8.im[1] + inv).abs() < 1e-7);
    }

    #[test]
    fn k_scaling_matches_composition() {
        let mut c = TwiddleCache::new();
        let w1 = c.vector(128, 32, 1);
        let w2 = c.vector(128, 32, 2);
        for j in 0..32 {
            // W^2j == (W^j)^2
            let rr = w1.re[j] * w1.re[j] - w1.im[j] * w1.im[j];
            let ii = 2.0 * w1.re[j] * w1.im[j];
            assert!((rr - w2.re[j]).abs() < 1e-5);
            assert!((ii - w2.im[j]).abs() < 1e-5);
        }
    }

    #[test]
    fn cache_hits() {
        let mut c = TwiddleCache::new();
        let a = c.vector(64, 32, 1);
        let b = c.vector(64, 32, 1);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(c.entries(), 1);
        c.vector(64, 32, 3);
        assert_eq!(c.entries(), 2);
        assert_eq!(c.total_elems(), 2 * 32 * 2);
    }
}
