//! Cached twiddle-factor tables.
//!
//! `vector(m, count, k)` returns W_m^{k·j} = exp(-2πi·k·j/m) for
//! j ∈ [0, count), computed once in f64 and cached as split f32 arrays.
//! All passes of all plans share one [`TwiddleCache`] — the paper's "same
//! twiddle table" discipline (§4.1) — so arrangement comparisons measure
//! instruction scheduling, not table-construction differences.
//!
//! Behind every [`TwiddleCache`] sits one **process-global intern
//! store**: identical tables requested by different executors — the
//! service's shards, a hot-swapped replacement plan, the four-step
//! column/row sub-plans, every kind sharing the forward tables — resolve
//! to the *same* `Arc<TwiddleVec>`, not per-executor copies. A cache is
//! a thin per-executor memo over that store (lock-free on repeat
//! lookups); the store counts interning hits and misses
//! ([`global_stats`]) so the serving metrics can report how much table
//! construction the sharing avoided.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// One twiddle vector: split re/im, unit stride.
#[derive(Debug)]
pub struct TwiddleVec {
    pub re: Vec<f32>,
    pub im: Vec<f32>,
}

impl TwiddleVec {
    fn compute(m: usize, count: usize, k: usize) -> TwiddleVec {
        let mut re = Vec::with_capacity(count);
        let mut im = Vec::with_capacity(count);
        for j in 0..count {
            let ang = -2.0 * std::f64::consts::PI * (k as f64) * (j as f64) / (m as f64);
            re.push(ang.cos() as f32);
            im.push(ang.sin() as f32);
        }
        TwiddleVec { re, im }
    }

    pub fn len(&self) -> usize {
        self.re.len()
    }

    pub fn is_empty(&self) -> bool {
        self.re.is_empty()
    }
}

/// The process-global intern store: one table per distinct key,
/// whichever executor asks first. Both key spaces live behind one lock;
/// lookups only reach it on a per-executor memo miss.
#[derive(Debug, Default)]
struct InternStore {
    map: HashMap<(usize, usize, usize), Arc<TwiddleVec>>,
    fused: HashMap<(usize, usize, usize, usize), Arc<TwiddleVec>>,
}

fn intern_store() -> &'static Mutex<InternStore> {
    static STORE: OnceLock<Mutex<InternStore>> = OnceLock::new();
    STORE.get_or_init(|| Mutex::new(InternStore::default()))
}

/// Interning hits: lookups answered by an already-constructed table
/// (per-executor memo hits included — every one of these is a table the
/// sharing did not rebuild).
static INTERN_HITS: AtomicU64 = AtomicU64::new(0);
/// Interning misses: tables computed for the first time process-wide.
static INTERN_MISSES: AtomicU64 = AtomicU64::new(0);

/// Cumulative (hits, misses) of the global twiddle intern store. Hits
/// count every lookup that reused an existing table; misses count
/// first-time constructions. Monotonic over the process lifetime —
/// consumers (the serving metrics) report deltas.
pub fn global_stats() -> (u64, u64) {
    (INTERN_HITS.load(Ordering::Relaxed), INTERN_MISSES.load(Ordering::Relaxed))
}

/// Number of distinct tables interned process-wide.
pub fn global_entries() -> usize {
    let s = intern_store().lock().unwrap();
    s.map.len() + s.fused.len()
}

/// Per-executor view of the twiddle tables, keyed by (m, count, k), plus
/// combined fused-block sub-stage tables keyed by (m, e, lanes, step).
/// A local memo over the process-global intern store: repeat lookups
/// stay lock-free, and distinct caches (shards, hot-swap replacement
/// executors, four-step sub-plan compilers) share the underlying
/// `Arc<TwiddleVec>` allocations.
#[derive(Debug, Default)]
pub struct TwiddleCache {
    map: HashMap<(usize, usize, usize), Arc<TwiddleVec>>,
    fused: HashMap<(usize, usize, usize, usize), Arc<TwiddleVec>>,
}

impl TwiddleCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// W_m^{k·j} for j in [0, count). Cached; interned process-wide.
    pub fn vector(&mut self, m: usize, count: usize, k: usize) -> Arc<TwiddleVec> {
        if let Some(v) = self.map.get(&(m, count, k)) {
            INTERN_HITS.fetch_add(1, Ordering::Relaxed);
            return v.clone();
        }
        let v = {
            let mut store = intern_store().lock().unwrap();
            match store.map.get(&(m, count, k)) {
                Some(v) => {
                    INTERN_HITS.fetch_add(1, Ordering::Relaxed);
                    v.clone()
                }
                None => {
                    INTERN_MISSES.fetch_add(1, Ordering::Relaxed);
                    let v = Arc::new(TwiddleVec::compute(m, count, k));
                    store.map.insert((m, count, k), v.clone());
                    v
                }
            }
        };
        self.map.insert((m, count, k), v.clone());
        v
    }

    /// Combined fused-block sub-stage table: entry `k*e + j` is
    /// W_m^{step·j} · W_lanes^{k} for k ∈ [0, lanes/2), j ∈ [0, e).
    /// Cached under a disjoint key space (lanes ≥ 2 disambiguates);
    /// interned process-wide like [`TwiddleCache::vector`].
    pub fn fused_table(&mut self, m: usize, e: usize, lanes: usize, step: usize) -> Arc<TwiddleVec> {
        if let Some(v) = self.fused.get(&(m, e, lanes, step)) {
            INTERN_HITS.fetch_add(1, Ordering::Relaxed);
            return v.clone();
        }
        let v = {
            let mut store = intern_store().lock().unwrap();
            match store.fused.get(&(m, e, lanes, step)) {
                Some(v) => {
                    INTERN_HITS.fetch_add(1, Ordering::Relaxed);
                    v.clone()
                }
                None => {
                    INTERN_MISSES.fetch_add(1, Ordering::Relaxed);
                    let half = lanes / 2;
                    let mut re = Vec::with_capacity(half * e);
                    let mut im = Vec::with_capacity(half * e);
                    for k in 0..half {
                        for j in 0..e {
                            let ang = -2.0 * std::f64::consts::PI
                                * ((step * j) as f64 / m as f64 + k as f64 / lanes as f64);
                            re.push(ang.cos() as f32);
                            im.push(ang.sin() as f32);
                        }
                    }
                    let v = Arc::new(TwiddleVec { re, im });
                    store.fused.insert((m, e, lanes, step), v.clone());
                    v
                }
            }
        };
        self.fused.insert((m, e, lanes, step), v.clone());
        v
    }

    /// Number of distinct cached vectors (for tests / memory accounting).
    pub fn entries(&self) -> usize {
        self.map.len() + self.fused.len()
    }

    /// Total cached f32 elements across both components.
    pub fn total_elems(&self) -> usize {
        self.map.values().chain(self.fused.values()).map(|v| 2 * v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_circle_and_identities() {
        let mut c = TwiddleCache::new();
        let w = c.vector(64, 32, 1);
        for j in 0..32 {
            let mag = w.re[j] * w.re[j] + w.im[j] * w.im[j];
            assert!((mag - 1.0).abs() < 1e-6);
        }
        assert_eq!(w.re[0], 1.0);
        assert_eq!(w.im[0], 0.0);
        // W_4^1 = -j
        let w4 = c.vector(4, 2, 1);
        assert!(w4.re[1].abs() < 1e-7);
        assert!((w4.im[1] + 1.0).abs() < 1e-7);
        // W_8^1 = (1-j)/sqrt(2)
        let w8 = c.vector(8, 2, 1);
        let inv = std::f32::consts::FRAC_1_SQRT_2;
        assert!((w8.re[1] - inv).abs() < 1e-7);
        assert!((w8.im[1] + inv).abs() < 1e-7);
    }

    #[test]
    fn k_scaling_matches_composition() {
        let mut c = TwiddleCache::new();
        let w1 = c.vector(128, 32, 1);
        let w2 = c.vector(128, 32, 2);
        for j in 0..32 {
            // W^2j == (W^j)^2
            let rr = w1.re[j] * w1.re[j] - w1.im[j] * w1.im[j];
            let ii = 2.0 * w1.re[j] * w1.im[j];
            assert!((rr - w2.re[j]).abs() < 1e-5);
            assert!((ii - w2.im[j]).abs() < 1e-5);
        }
    }

    #[test]
    fn cache_hits() {
        let mut c = TwiddleCache::new();
        let a = c.vector(64, 32, 1);
        let b = c.vector(64, 32, 1);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(c.entries(), 1);
        c.vector(64, 32, 3);
        assert_eq!(c.entries(), 2);
        assert_eq!(c.total_elems(), 2 * 32 * 2);
    }

    #[test]
    fn separate_caches_intern_to_the_same_table() {
        // The global intern store: two independent caches (two shards,
        // or a hot-swap replacement executor) resolve the same key to
        // the same allocation, and the reuse is counted.
        let (h0, m0) = global_stats();
        let mut c1 = TwiddleCache::new();
        let mut c2 = TwiddleCache::new();
        // a key unlikely to collide with other tests' sizes
        let a = c1.vector(1 << 14, 3, 5);
        let b = c2.vector(1 << 14, 3, 5);
        assert!(Arc::ptr_eq(&a, &b));
        let f1 = c1.fused_table(1 << 14, 3, 4, 5);
        let f2 = c2.fused_table(1 << 14, 3, 4, 5);
        assert!(Arc::ptr_eq(&f1, &f2));
        let (h1, m1) = global_stats();
        // c2's lookups were interning hits; at most the two first-time
        // constructions were misses (other tests may add their own)
        assert!(h1 >= h0 + 2, "hits {h0} -> {h1}");
        assert!(m1 >= m0, "misses are monotonic");
        assert!(global_entries() >= 2);
        // local memo hits count too (repeat lookup, no lock); other
        // tests run concurrently, so assert the floor, not equality
        let (h2, _) = global_stats();
        c1.vector(1 << 14, 3, 5);
        let (h3, _) = global_stats();
        assert!(h3 >= h2 + 1);
    }
}
