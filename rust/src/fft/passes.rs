//! Radix-2 / radix-4 / radix-8 DIF passes over split-complex buffers.
//!
//! Same butterfly algebra as the Pallas kernels (python/compile/kernels/
//! passes.py), with the paper's instruction tricks:
//!
//! * radix-4: W_4^1 = -j as swap+negate;
//! * radix-8: W_8^{1,3} = (1∓j)/√2 as one 1/√2 scale plus add/sub.
//!
//! Each pass reads the whole array and writes it back — the memory round
//! trip per pass is the defining cost of non-fused edges (paper Table 1).

use super::twiddle::TwiddleVec;

pub const INV_SQRT2: f32 = std::f32::consts::FRAC_1_SQRT_2;

#[inline(always)]
pub(crate) fn cmul(ar: f32, ai: f32, br: f32, bi: f32) -> (f32, f32) {
    (ar * br - ai * bi, ar * bi + ai * br)
}

/// Radix-2 DIF pass at `stage`: block size m = n >> stage.
///
/// `w1` must be W_m^j for j in [0, m/2).
pub fn radix2(re: &mut [f32], im: &mut [f32], stage: usize, w1: &TwiddleVec) {
    let n = re.len();
    let m = n >> stage;
    debug_assert!(m >= 2, "R2 at stage {stage} invalid for n={n}");
    let half = m / 2;
    debug_assert_eq!(w1.len(), half);
    let mut base = 0;
    while base < n {
        let (top, rest) = re[base..base + m].split_at_mut(half);
        let bot = rest;
        let (topi, resti) = im[base..base + m].split_at_mut(half);
        let boti = resti;
        for j in 0..half {
            let (tr, ti) = (top[j], topi[j]);
            let (br, bi) = (bot[j], boti[j]);
            let (sr, si) = (tr + br, ti + bi);
            let (dr, di) = (tr - br, ti - bi);
            let (pr, pi) = cmul(dr, di, w1.re[j], w1.im[j]);
            top[j] = sr;
            topi[j] = si;
            bot[j] = pr;
            boti[j] = pi;
        }
        base += m;
    }
}

/// Radix-4 DIF pass at `stage` (advances 2 stages).
///
/// `w1`/`w2`/`w3` must be W_m^{j}, W_m^{2j}, W_m^{3j} for j in [0, m/4).
pub fn radix4(
    re: &mut [f32],
    im: &mut [f32],
    stage: usize,
    w1: &TwiddleVec,
    w2: &TwiddleVec,
    w3: &TwiddleVec,
) {
    let n = re.len();
    let m = n >> stage;
    debug_assert!(m >= 4, "R4 at stage {stage} invalid for n={n}");
    let q = m / 4;
    debug_assert_eq!(w1.len(), q);
    // §Perf: quarter-slice views give the compiler exact lengths, eliding
    // bounds checks and auto-vectorizing the j loop.
    let (w1r, w1i) = (&w1.re[..q], &w1.im[..q]);
    let (w2r, w2i) = (&w2.re[..q], &w2.im[..q]);
    let (w3r, w3i) = (&w3.re[..q], &w3.im[..q]);
    let mut base = 0;
    while base < n {
        let (q0r, rest) = re[base..base + m].split_at_mut(q);
        let (q1r, rest) = rest.split_at_mut(q);
        let (q2r, q3r) = rest.split_at_mut(q);
        let (q0i, rest) = im[base..base + m].split_at_mut(q);
        let (q1i, rest) = rest.split_at_mut(q);
        let (q2i, q3i) = rest.split_at_mut(q);
        for j in 0..q {
            let (ar, ai) = (q0r[j], q0i[j]);
            let (br, bi) = (q1r[j], q1i[j]);
            let (cr, ci) = (q2r[j], q2i[j]);
            let (dr, di) = (q3r[j], q3i[j]);
            let (t0r, t0i) = (ar + cr, ai + ci);
            let (t1r, t1i) = (ar - cr, ai - ci);
            let (t2r, t2i) = (br + dr, bi + di);
            // t3 = -j*(b - d): swap + negate (W_4^1 trick, zero multiplies)
            let (t3r, t3i) = (bi - di, -(br - dr));
            q0r[j] = t0r + t2r;
            q0i[j] = t0i + t2i;
            let (y1r, y1i) = cmul(t0r - t2r, t0i - t2i, w2r[j], w2i[j]);
            q1r[j] = y1r;
            q1i[j] = y1i;
            let (y2r, y2i) = cmul(t1r + t3r, t1i + t3i, w1r[j], w1i[j]);
            q2r[j] = y2r;
            q2i[j] = y2i;
            let (y3r, y3i) = cmul(t1r - t3r, t1i - t3i, w3r[j], w3i[j]);
            q3r[j] = y3r;
            q3i[j] = y3i;
        }
        base += m;
    }
}

/// Multiply by W_8^k using only 1/√2 scaling + add/sub (paper trick).
#[inline(always)]
pub(crate) fn w8_rotate(xr: f32, xi: f32, k: usize) -> (f32, f32) {
    match k {
        0 => (xr, xi),
        1 => ((xr + xi) * INV_SQRT2, (xi - xr) * INV_SQRT2), // (1-j)/√2
        2 => (xi, -xr),                                      // -j
        3 => ((xi - xr) * INV_SQRT2, -(xr + xi) * INV_SQRT2), // -(1+j)/√2
        _ => unreachable!(),
    }
}

/// Radix-8 DIF pass at `stage` (advances 3 stages).
///
/// `w1`/`w2`/`w4` must be W_m^{j}, W_m^{2j}, W_m^{4j} for j in [0, m/8).
pub fn radix8(
    re: &mut [f32],
    im: &mut [f32],
    stage: usize,
    w1: &TwiddleVec,
    w2: &TwiddleVec,
    w4: &TwiddleVec,
) {
    let n = re.len();
    let m = n >> stage;
    debug_assert!(m >= 8, "R8 at stage {stage} invalid for n={n}");
    let e = m / 8;
    debug_assert_eq!(w1.len(), e);
    // §Perf: eighth-slice views elide bounds checks; the j loop then
    // auto-vectorizes (same treatment as radix4).
    let (w1r, w1i) = (&w1.re[..e], &w1.im[..e]);
    let (w2r, w2i) = (&w2.re[..e], &w2.im[..e]);
    let (w4r, w4i) = (&w4.re[..e], &w4.im[..e]);
    let mut base = 0;
    while base < n {
        let mut rs: [&mut [f32]; 8] = split8(&mut re[base..base + m], e);
        let mut is_: [&mut [f32]; 8] = split8(&mut im[base..base + m], e);
        for j in 0..e {
            // Load the 8-point group — the paper's finding 2: this working
            // set (8 complex = 16 NEON vectors with temporaries) is what
            // creates register pressure on 128-bit NEON.
            let mut xr = [0f32; 8];
            let mut xi = [0f32; 8];
            for k in 0..8 {
                xr[k] = rs[k][j];
                xi[k] = is_[k][j];
            }
            // Stage A: pairs (k, k+4); twiddle W_m^j * W_8^k on low halves.
            let mut yr = [0f32; 8];
            let mut yi = [0f32; 8];
            for k in 0..4 {
                yr[k] = xr[k] + xr[k + 4];
                yi[k] = xi[k] + xi[k + 4];
                let (dr, di) = (xr[k] - xr[k + 4], xi[k] - xi[k + 4]);
                let (pr, pi) = cmul(dr, di, w1r[j], w1i[j]);
                let (rr, ri) = w8_rotate(pr, pi, k);
                yr[k + 4] = rr;
                yi[k + 4] = ri;
            }
            // Stage B: pairs (k, k+2) within halves; W_m^{2j} * W_4^{k mod 2}.
            let mut zr = [0f32; 8];
            let mut zi = [0f32; 8];
            for half in [0usize, 4] {
                for k in 0..2 {
                    let a = half + k;
                    let b = half + k + 2;
                    zr[a] = yr[a] + yr[b];
                    zi[a] = yi[a] + yi[b];
                    let (dr, di) = (yr[a] - yr[b], yi[a] - yi[b]);
                    let (mut pr, mut pi) = cmul(dr, di, w2r[j], w2i[j]);
                    if k == 1 {
                        // W_4^1 = -j: swap + negate
                        let t = pr;
                        pr = pi;
                        pi = -t;
                    }
                    zr[b] = pr;
                    zi[b] = pi;
                }
            }
            // Stage C: adjacent pairs; twiddle W_m^{4j}.
            for k in [0usize, 2, 4, 6] {
                let (ar, ai) = (zr[k], zi[k]);
                let (br, bi) = (zr[k + 1], zi[k + 1]);
                rs[k][j] = ar + br;
                is_[k][j] = ai + bi;
                let (pr, pi) = cmul(ar - br, ai - bi, w4r[j], w4i[j]);
                rs[k + 1][j] = pr;
                is_[k + 1][j] = pi;
            }
        }
        base += m;
    }
}

/// Batched radix-2 DIF pass over a lane-blocked buffer (`lanes` floats
/// per element — see [`super::batch::BatchBuffer`]). Identical butterfly
/// algebra to [`radix2`], applied to every lane of each element pair, so
/// each twiddle element is loaded once for the whole batch and per-lane
/// outputs are bit-identical to the unbatched pass.
pub fn radix2_b(re: &mut [f32], im: &mut [f32], stage: usize, w1: &TwiddleVec, lanes: usize) {
    debug_assert!(lanes >= 1 && re.len() % lanes == 0);
    let n = re.len() / lanes;
    let m = n >> stage;
    debug_assert!(m >= 2, "R2 at stage {stage} invalid for n={n}");
    let half = m / 2;
    debug_assert_eq!(w1.len(), half);
    let mut base = 0;
    while base < n {
        let s = base * lanes;
        let (top, bot) = re[s..s + m * lanes].split_at_mut(half * lanes);
        let (topi, boti) = im[s..s + m * lanes].split_at_mut(half * lanes);
        for j in 0..half {
            let (wr, wi) = (w1.re[j], w1.im[j]);
            let row = j * lanes;
            for l in row..row + lanes {
                let (tr, ti) = (top[l], topi[l]);
                let (br, bi) = (bot[l], boti[l]);
                top[l] = tr + br;
                topi[l] = ti + bi;
                let (pr, pi) = cmul(tr - br, ti - bi, wr, wi);
                bot[l] = pr;
                boti[l] = pi;
            }
        }
        base += m;
    }
}

/// Batched radix-4 DIF pass (lane-blocked analogue of [`radix4`]).
pub fn radix4_b(
    re: &mut [f32],
    im: &mut [f32],
    stage: usize,
    w1: &TwiddleVec,
    w2: &TwiddleVec,
    w3: &TwiddleVec,
    lanes: usize,
) {
    debug_assert!(lanes >= 1 && re.len() % lanes == 0);
    let n = re.len() / lanes;
    let m = n >> stage;
    debug_assert!(m >= 4, "R4 at stage {stage} invalid for n={n}");
    let q = m / 4;
    debug_assert_eq!(w1.len(), q);
    let mut base = 0;
    while base < n {
        let s = base * lanes;
        let (q0r, rest) = re[s..s + m * lanes].split_at_mut(q * lanes);
        let (q1r, rest) = rest.split_at_mut(q * lanes);
        let (q2r, q3r) = rest.split_at_mut(q * lanes);
        let (q0i, rest) = im[s..s + m * lanes].split_at_mut(q * lanes);
        let (q1i, rest) = rest.split_at_mut(q * lanes);
        let (q2i, q3i) = rest.split_at_mut(q * lanes);
        for j in 0..q {
            let (w1r, w1i) = (w1.re[j], w1.im[j]);
            let (w2r, w2i) = (w2.re[j], w2.im[j]);
            let (w3r, w3i) = (w3.re[j], w3.im[j]);
            let row = j * lanes;
            for l in row..row + lanes {
                let (ar, ai) = (q0r[l], q0i[l]);
                let (br, bi) = (q1r[l], q1i[l]);
                let (cr, ci) = (q2r[l], q2i[l]);
                let (dr, di) = (q3r[l], q3i[l]);
                let (t0r, t0i) = (ar + cr, ai + ci);
                let (t1r, t1i) = (ar - cr, ai - ci);
                let (t2r, t2i) = (br + dr, bi + di);
                // t3 = -j*(b - d): swap + negate (same trick as radix4)
                let (t3r, t3i) = (bi - di, -(br - dr));
                q0r[l] = t0r + t2r;
                q0i[l] = t0i + t2i;
                let (y1r, y1i) = cmul(t0r - t2r, t0i - t2i, w2r, w2i);
                q1r[l] = y1r;
                q1i[l] = y1i;
                let (y2r, y2i) = cmul(t1r + t3r, t1i + t3i, w1r, w1i);
                q2r[l] = y2r;
                q2i[l] = y2i;
                let (y3r, y3i) = cmul(t1r - t3r, t1i - t3i, w3r, w3i);
                q3r[l] = y3r;
                q3i[l] = y3i;
            }
        }
        base += m;
    }
}

/// Batched radix-8 DIF pass (lane-blocked analogue of [`radix8`]).
pub fn radix8_b(
    re: &mut [f32],
    im: &mut [f32],
    stage: usize,
    w1: &TwiddleVec,
    w2: &TwiddleVec,
    w4: &TwiddleVec,
    lanes: usize,
) {
    debug_assert!(lanes >= 1 && re.len() % lanes == 0);
    let n = re.len() / lanes;
    let m = n >> stage;
    debug_assert!(m >= 8, "R8 at stage {stage} invalid for n={n}");
    let e = m / 8;
    debug_assert_eq!(w1.len(), e);
    let mut base = 0;
    while base < n {
        let s = base * lanes;
        let mut rs: [&mut [f32]; 8] = split8(&mut re[s..s + m * lanes], e * lanes);
        let mut is_: [&mut [f32]; 8] = split8(&mut im[s..s + m * lanes], e * lanes);
        for j in 0..e {
            let (w1r, w1i) = (w1.re[j], w1.im[j]);
            let (w2r, w2i) = (w2.re[j], w2.im[j]);
            let (w4r, w4i) = (w4.re[j], w4.im[j]);
            let row = j * lanes;
            for l in row..row + lanes {
                let mut xr = [0f32; 8];
                let mut xi = [0f32; 8];
                for k in 0..8 {
                    xr[k] = rs[k][l];
                    xi[k] = is_[k][l];
                }
                // Stage A: pairs (k, k+4); twiddle W_m^j * W_8^k.
                let mut yr = [0f32; 8];
                let mut yi = [0f32; 8];
                for k in 0..4 {
                    yr[k] = xr[k] + xr[k + 4];
                    yi[k] = xi[k] + xi[k + 4];
                    let (dr, di) = (xr[k] - xr[k + 4], xi[k] - xi[k + 4]);
                    let (pr, pi) = cmul(dr, di, w1r, w1i);
                    let (rr, ri) = w8_rotate(pr, pi, k);
                    yr[k + 4] = rr;
                    yi[k + 4] = ri;
                }
                // Stage B: pairs (k, k+2) within halves.
                let mut zr = [0f32; 8];
                let mut zi = [0f32; 8];
                for half in [0usize, 4] {
                    for k in 0..2 {
                        let a = half + k;
                        let b = half + k + 2;
                        zr[a] = yr[a] + yr[b];
                        zi[a] = yi[a] + yi[b];
                        let (dr, di) = (yr[a] - yr[b], yi[a] - yi[b]);
                        let (mut pr, mut pi) = cmul(dr, di, w2r, w2i);
                        if k == 1 {
                            let t = pr;
                            pr = pi;
                            pi = -t;
                        }
                        zr[b] = pr;
                        zi[b] = pi;
                    }
                }
                // Stage C: adjacent pairs; twiddle W_m^{4j}.
                for k in [0usize, 2, 4, 6] {
                    let (ar, ai) = (zr[k], zi[k]);
                    let (br, bi) = (zr[k + 1], zi[k + 1]);
                    rs[k][l] = ar + br;
                    is_[k][l] = ai + bi;
                    let (pr, pi) = cmul(ar - br, ai - bi, w4r, w4i);
                    rs[k + 1][l] = pr;
                    is_[k + 1][l] = pi;
                }
            }
        }
        base += m;
    }
}

/// Split a block of length 8·e into eight e-length mutable slices.
#[inline(always)]
pub(crate) fn split8(block: &mut [f32], e: usize) -> [&mut [f32]; 8] {
    let (s0, rest) = block.split_at_mut(e);
    let (s1, rest) = rest.split_at_mut(e);
    let (s2, rest) = rest.split_at_mut(e);
    let (s3, rest) = rest.split_at_mut(e);
    let (s4, rest) = rest.split_at_mut(e);
    let (s5, rest) = rest.split_at_mut(e);
    let (s6, s7) = rest.split_at_mut(e);
    [s0, s1, s2, s3, s4, s5, s6, s7]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::reference::apply_radix2_stages_ref;
    use crate::fft::{SplitComplex, TwiddleCache};

    fn run_pass(edge: &str, v: &mut SplitComplex, stage: usize) {
        let n = v.len();
        let m = n >> stage;
        let mut c = TwiddleCache::new();
        match edge {
            "R2" => {
                let w1 = c.vector(m, m / 2, 1);
                radix2(&mut v.re, &mut v.im, stage, &w1);
            }
            "R4" => {
                let (w1, w2, w3) = (c.vector(m, m / 4, 1), c.vector(m, m / 4, 2), c.vector(m, m / 4, 3));
                radix4(&mut v.re, &mut v.im, stage, &w1, &w2, &w3);
            }
            "R8" => {
                let (w1, w2, w4) = (c.vector(m, m / 8, 1), c.vector(m, m / 8, 2), c.vector(m, m / 8, 4));
                radix8(&mut v.re, &mut v.im, stage, &w1, &w2, &w4);
            }
            _ => unreachable!(),
        }
    }

    fn check_vs_ref(edge: &str, k: usize, n: usize, stage: usize, seed: u64) {
        let input = SplitComplex::random(n, seed);
        let mut got = input.clone();
        run_pass(edge, &mut got, stage);
        let want = apply_radix2_stages_ref(&input, stage, k);
        let scale = want.max_abs().max(1.0);
        let err = got.max_abs_diff(&want) / scale;
        assert!(err < 1e-5, "{edge} n={n} stage={stage}: rel err {err}");
    }

    #[test]
    fn radix2_matches_reference_all_stages() {
        for n in [8usize, 64, 1024] {
            for stage in 0..crate::fft::log2i(n) {
                check_vs_ref("R2", 1, n, stage, 42 + stage as u64);
            }
        }
    }

    #[test]
    fn radix4_matches_reference_all_stages() {
        for n in [16usize, 64, 1024] {
            for stage in 0..=(crate::fft::log2i(n) - 2) {
                check_vs_ref("R4", 2, n, stage, 17 + stage as u64);
            }
        }
    }

    #[test]
    fn radix8_matches_reference_all_stages() {
        for n in [8usize, 64, 1024] {
            for stage in 0..=(crate::fft::log2i(n) - 3) {
                check_vs_ref("R8", 3, n, stage, 9 + stage as u64);
            }
        }
    }

    #[test]
    fn w8_rotate_matches_complex_multiply() {
        for k in 0..4usize {
            let ang = -2.0 * std::f64::consts::PI * k as f64 / 8.0;
            let (wr, wi) = (ang.cos() as f32, ang.sin() as f32);
            let (xr, xi) = (0.6f32, -1.3f32);
            let (er, ei) = cmul(xr, xi, wr, wi);
            let (gr, gi) = w8_rotate(xr, xi, k);
            assert!((er - gr).abs() < 1e-6 && (ei - gi).abs() < 1e-6, "k={k}");
        }
    }

    fn run_pass_b(edge: &str, buf: &mut crate::fft::BatchBuffer, stage: usize) {
        let n = buf.n();
        let m = n >> stage;
        let lanes = buf.lanes();
        let mut c = TwiddleCache::new();
        match edge {
            "R2" => {
                let w1 = c.vector(m, m / 2, 1);
                radix2_b(&mut buf.re, &mut buf.im, stage, &w1, lanes);
            }
            "R4" => {
                let (w1, w2, w3) = (c.vector(m, m / 4, 1), c.vector(m, m / 4, 2), c.vector(m, m / 4, 3));
                radix4_b(&mut buf.re, &mut buf.im, stage, &w1, &w2, &w3, lanes);
            }
            "R8" => {
                let (w1, w2, w4) = (c.vector(m, m / 8, 1), c.vector(m, m / 8, 2), c.vector(m, m / 8, 4));
                radix8_b(&mut buf.re, &mut buf.im, stage, &w1, &w2, &w4, lanes);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn batched_passes_are_bit_identical_to_scalar() {
        let n = 256;
        for b in [1usize, 3, 4, 7] {
            let inputs: Vec<SplitComplex> =
                (0..b).map(|i| SplitComplex::random(n, 100 + i as u64)).collect();
            for edge in ["R2", "R4", "R8"] {
                for stage in [0usize, 2] {
                    let refs: Vec<&SplitComplex> = inputs.iter().collect();
                    let mut buf = crate::fft::BatchBuffer::new(n, b);
                    buf.gather(&refs);
                    run_pass_b(edge, &mut buf, stage);
                    for (l, input) in inputs.iter().enumerate() {
                        let mut want = input.clone();
                        run_pass(edge, &mut want, stage);
                        assert_eq!(
                            buf.scatter_lane(l),
                            want,
                            "{edge} stage {stage} lane {l} of batch {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn passes_are_linear() {
        let n = 256;
        let a = SplitComplex::random(n, 5);
        for edge in ["R2", "R4", "R8"] {
            let mut x1 = a.clone();
            run_pass(edge, &mut x1, 1);
            let mut x2 = SplitComplex::from_parts(
                a.re.iter().map(|v| 2.0 * v).collect(),
                a.im.iter().map(|v| 2.0 * v).collect(),
            );
            run_pass(edge, &mut x2, 1);
            for i in 0..n {
                assert!((x2.re[i] - 2.0 * x1.re[i]).abs() < 1e-4);
                assert!((x2.im[i] - 2.0 * x1.im[i]).abs() < 1e-4);
            }
        }
    }
}
