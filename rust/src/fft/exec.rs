//! Plan executor: compile a [`Plan`] against a twiddle cache, then run it
//! repeatedly over split-complex buffers (the native-path hot loop).
//!
//! Compilation resolves every edge's twiddle vectors once; execution is
//! allocation-free. This is what the `NativeCost` provider times and what
//! the coordinator's native backend serves requests with.
//!
//! Every plan compiles for a [`TransformKind`]:
//!
//! * **Forward** — the historical path, unchanged.
//! * **Inverse** — the same forward kernels with the conjugation pushed
//!   to the buffer boundary (`IDFT = conj ∘ DFT ∘ conj / n`): one sign
//!   pass over `im` on entry, and the conjugation + 1/n scale folded
//!   into the final pass on exit ([`real::conj_scale`]).
//! * **RealForward / RealInverse** — the standard pack-into-n/2-c2c
//!   factorization. The split/unpack boundary pass is a *real*
//!   [`CompiledStep`] with edge [`EdgeType::RU`] (appended after the
//!   c2c steps for R2C, prepended before them for C2R), so it appears
//!   in traces, gets an `EdgeSample`, and its context-dependent cost is
//!   visible to the search. Real kinds always compile with bit-reversal
//!   (the unpack algebra needs the half-spectrum in natural order).

use std::sync::Arc;

use super::batch::BatchBuffer;
use super::fused::fused_twiddles;
use super::real;
use super::simd::{self, Kernels};
use super::twiddle::{TwiddleCache, TwiddleVec};
use super::{log2i, SplitComplex};
use crate::edge::EdgeType;
use crate::isa::Isa;
use crate::kind::TransformKind;
use crate::plan::Plan;

/// One compiled step: edge + stage + resolved twiddles.
#[derive(Debug, Clone)]
pub struct CompiledStep {
    pub edge: EdgeType,
    pub stage: usize,
    tw: Vec<Arc<TwiddleVec>>,
}

/// A plan compiled for a fixed n and transform kind: ready-to-run steps
/// + optional bitrev + the folded final-pass scale.
#[derive(Debug, Clone)]
pub struct CompiledPlan {
    /// Request-buffer length (for real kinds the internal c2c runs at
    /// n/2; see [`TransformKind::complex_len`]).
    pub n: usize,
    pub kind: TransformKind,
    pub plan: Plan,
    pub bitrev: bool,
    /// Scale folded into the final pass (1/n_c2c for inverse kinds).
    scale: f32,
    steps: Vec<CompiledStep>,
    /// Codelet table resolved once at compile time — every c2c step of
    /// every run dispatches through these fn pointers. Boundary passes
    /// (RU, pack/unpack, bitrev) stay scalar; they are permutation-bound.
    kernels: &'static Kernels,
}

/// Compile a single edge at (n, stage) — shared by plan compilation and
/// the per-edge measurement path. `n` is the c2c length the step runs
/// over; for [`EdgeType::RU`] it is the *half* length h (the pass walks
/// the full 2h buffer with the W_{2h} twiddles).
pub fn compile_step(
    cache: &mut TwiddleCache,
    n: usize,
    edge: EdgeType,
    stage: usize,
) -> CompiledStep {
    if edge == EdgeType::RU {
        return CompiledStep { edge, stage, tw: vec![real::real_twiddles(cache, n)] };
    }
    let m = n >> stage;
    assert!(
        m >= (1 << edge.stages()),
        "{edge} at stage {stage} invalid for n={n}"
    );
    let tw = match edge {
        EdgeType::R2 => vec![cache.vector(m, m / 2, 1)],
        EdgeType::R4 => vec![
            cache.vector(m, m / 4, 1),
            cache.vector(m, m / 4, 2),
            cache.vector(m, m / 4, 3),
        ],
        EdgeType::R8 => vec![
            cache.vector(m, m / 8, 1),
            cache.vector(m, m / 8, 2),
            cache.vector(m, m / 8, 4),
        ],
        EdgeType::F8 => fused_twiddles(cache, n, stage, 8),
        EdgeType::F16 => fused_twiddles(cache, n, stage, 16),
        EdgeType::F32 => fused_twiddles(cache, n, stage, 32),
        EdgeType::RU | EdgeType::Transpose | EdgeType::BlockTwiddle => unreachable!(),
    };
    CompiledStep { edge, stage, tw }
}

/// Run one compiled c2c step in place through `k`'s codelets. RU steps
/// are boundary passes run by the kind dispatch in [`CompiledPlan::run`],
/// never through here.
pub fn run_step(k: &Kernels, step: &CompiledStep, re: &mut [f32], im: &mut [f32]) {
    match step.edge {
        EdgeType::R2 => (k.radix2)(re, im, step.stage, &step.tw[0]),
        EdgeType::R4 => (k.radix4)(re, im, step.stage, &step.tw[0], &step.tw[1], &step.tw[2]),
        EdgeType::R8 => (k.radix8)(re, im, step.stage, &step.tw[0], &step.tw[1], &step.tw[2]),
        EdgeType::F8 => (k.fused8)(re, im, step.stage, &step.tw),
        EdgeType::F16 => (k.fused16)(re, im, step.stage, &step.tw),
        EdgeType::F32 => (k.fused32)(re, im, step.stage, &step.tw),
        _ => panic!("{} is a boundary pass; never run as a c2c step", step.edge),
    }
}

/// Run one compiled c2c step over a lane-blocked batch buffer in place
/// through `k`'s codelets.
pub fn run_step_b(k: &Kernels, step: &CompiledStep, re: &mut [f32], im: &mut [f32], lanes: usize) {
    match step.edge {
        EdgeType::R2 => (k.radix2_b)(re, im, step.stage, &step.tw[0], lanes),
        EdgeType::R4 => {
            (k.radix4_b)(re, im, step.stage, &step.tw[0], &step.tw[1], &step.tw[2], lanes)
        }
        EdgeType::R8 => {
            (k.radix8_b)(re, im, step.stage, &step.tw[0], &step.tw[1], &step.tw[2], lanes)
        }
        EdgeType::F8 => (k.fused8_b)(re, im, step.stage, &step.tw, lanes),
        EdgeType::F16 => (k.fused16_b)(re, im, step.stage, &step.tw, lanes),
        EdgeType::F32 => (k.fused32_b)(re, im, step.stage, &step.tw, lanes),
        _ => panic!("{} is a boundary pass; never run as a c2c step", step.edge),
    }
}

impl CompiledPlan {
    /// Steps in execution order (for real kinds this includes the RU
    /// boundary step: last for R2C, first for C2R).
    pub fn steps(&self) -> &[CompiledStep] {
        &self.steps
    }

    /// Length of the internal c2c transform.
    fn cn(&self) -> usize {
        self.kind.complex_len(self.n)
    }

    /// The ISA whose codelets this plan dispatches to.
    pub fn isa(&self) -> Isa {
        self.kernels.isa
    }

    /// The resolved codelet table (for per-edge measurement paths that
    /// must time exactly what this plan runs).
    pub fn kernels(&self) -> &'static Kernels {
        self.kernels
    }

    /// Execute in place (bitrev applied last if compiled with it; kind
    /// boundary passes around the c2c core as documented on [`Executor::compile_kind`]).
    pub fn run(&self, re: &mut [f32], im: &mut [f32]) {
        debug_assert_eq!(re.len(), self.n);
        debug_assert_eq!(im.len(), self.n);
        match self.kind {
            TransformKind::Forward => {
                for step in &self.steps {
                    run_step(self.kernels, step, re, im);
                }
                if self.bitrev {
                    super::bitrev::bit_reverse_permute(re, im);
                }
            }
            TransformKind::Inverse => {
                real::negate(im);
                for step in &self.steps {
                    run_step(self.kernels, step, re, im);
                }
                if self.bitrev {
                    super::bitrev::bit_reverse_permute(re, im);
                }
                real::conj_scale(re, im, self.scale);
            }
            TransformKind::RealForward => {
                let h = self.cn();
                real::pack_even_odd(re, im, h);
                let last = self.steps.len() - 1;
                for step in &self.steps[..last] {
                    run_step(self.kernels, step, &mut re[..h], &mut im[..h]);
                }
                super::bitrev::bit_reverse_permute(&mut re[..h], &mut im[..h]);
                real::unpack_r2c(re, im, &self.steps[last].tw[0]);
            }
            TransformKind::RealInverse => {
                let h = self.cn();
                real::pack_c2r(re, im, &self.steps[0].tw[0]);
                for step in &self.steps[1..] {
                    run_step(self.kernels, step, &mut re[..h], &mut im[..h]);
                }
                super::bitrev::bit_reverse_permute(&mut re[..h], &mut im[..h]);
                real::interleave_scale(re, im, self.scale);
            }
        }
    }

    /// Convenience: run on a copy.
    pub fn run_on(&self, input: &SplitComplex) -> SplitComplex {
        let mut out = input.clone();
        self.run(&mut out.re, &mut out.im);
        out
    }

    /// Execute in place, reporting each step's wall-clock nanoseconds to
    /// `on_step(edge, stage, ns)` — the autotune trace-sampling hook. The
    /// arithmetic is identical to [`CompiledPlan::run`] (same steps, same
    /// order), so traced and untraced executions are bit-identical. RU
    /// boundary steps are timed like any other step; the permutation
    /// prologue/epilogue passes (pack, bitrev, interleave, conj-scale)
    /// are untimed, exactly as bitrev always was.
    pub fn run_traced(
        &self,
        re: &mut [f32],
        im: &mut [f32],
        on_step: &mut dyn FnMut(EdgeType, usize, f64),
    ) {
        debug_assert_eq!(re.len(), self.n);
        debug_assert_eq!(im.len(), self.n);
        match self.kind {
            TransformKind::Forward | TransformKind::Inverse => {
                if self.kind == TransformKind::Inverse {
                    real::negate(im);
                }
                for step in &self.steps {
                    let t0 = std::time::Instant::now();
                    run_step(self.kernels, step, re, im);
                    on_step(step.edge, step.stage, t0.elapsed().as_nanos() as f64);
                }
                if self.bitrev {
                    super::bitrev::bit_reverse_permute(re, im);
                }
                if self.kind == TransformKind::Inverse {
                    real::conj_scale(re, im, self.scale);
                }
            }
            TransformKind::RealForward => {
                let h = self.cn();
                real::pack_even_odd(re, im, h);
                let last = self.steps.len() - 1;
                for step in &self.steps[..last] {
                    let t0 = std::time::Instant::now();
                    run_step(self.kernels, step, &mut re[..h], &mut im[..h]);
                    on_step(step.edge, step.stage, t0.elapsed().as_nanos() as f64);
                }
                super::bitrev::bit_reverse_permute(&mut re[..h], &mut im[..h]);
                let ru = &self.steps[last];
                let t0 = std::time::Instant::now();
                real::unpack_r2c(re, im, &ru.tw[0]);
                on_step(ru.edge, ru.stage, t0.elapsed().as_nanos() as f64);
            }
            TransformKind::RealInverse => {
                let h = self.cn();
                let ru = &self.steps[0];
                let t0 = std::time::Instant::now();
                real::pack_c2r(re, im, &ru.tw[0]);
                on_step(ru.edge, ru.stage, t0.elapsed().as_nanos() as f64);
                for step in &self.steps[1..] {
                    let t0 = std::time::Instant::now();
                    run_step(self.kernels, step, &mut re[..h], &mut im[..h]);
                    on_step(step.edge, step.stage, t0.elapsed().as_nanos() as f64);
                }
                super::bitrev::bit_reverse_permute(&mut re[..h], &mut im[..h]);
                real::interleave_scale(re, im, self.scale);
            }
        }
    }

    /// Execute all transforms of a gathered batch in place, one step at
    /// a time across the whole batch: each step's twiddles are loaded
    /// once and applied to every lane, amortizing the per-pass memory
    /// round trip over the batch. Per-lane outputs are bit-identical to
    /// [`CompiledPlan::run`] on that lane alone *for every kind* (the
    /// batched kernels — boundary passes included — run the same
    /// per-lane algebra; padding lanes are zeros and never feed live
    /// lanes).
    pub fn run_batch(&self, buf: &mut BatchBuffer) {
        assert_eq!(buf.n(), self.n, "batch buffer is for n={}, plan for n={}", buf.n(), self.n);
        let lanes = buf.lanes();
        match self.kind {
            TransformKind::Forward => {
                for step in &self.steps {
                    run_step_b(self.kernels, step, &mut buf.re, &mut buf.im, lanes);
                }
                if self.bitrev {
                    super::bitrev::bit_reverse_permute_b(&mut buf.re, &mut buf.im, lanes);
                }
            }
            TransformKind::Inverse => {
                real::negate(&mut buf.im);
                for step in &self.steps {
                    run_step_b(self.kernels, step, &mut buf.re, &mut buf.im, lanes);
                }
                if self.bitrev {
                    super::bitrev::bit_reverse_permute_b(&mut buf.re, &mut buf.im, lanes);
                }
                real::conj_scale(&mut buf.re, &mut buf.im, self.scale);
            }
            TransformKind::RealForward => {
                let half = self.cn() * lanes;
                real::pack_even_odd_b(&mut buf.re, &mut buf.im, self.cn(), lanes);
                let last = self.steps.len() - 1;
                for step in &self.steps[..last] {
                    run_step_b(self.kernels, step, &mut buf.re[..half], &mut buf.im[..half], lanes);
                }
                super::bitrev::bit_reverse_permute_b(&mut buf.re[..half], &mut buf.im[..half], lanes);
                real::unpack_r2c_b(&mut buf.re, &mut buf.im, &self.steps[last].tw[0], lanes);
            }
            TransformKind::RealInverse => {
                let half = self.cn() * lanes;
                real::pack_c2r_b(&mut buf.re, &mut buf.im, &self.steps[0].tw[0], lanes);
                for step in &self.steps[1..] {
                    run_step_b(self.kernels, step, &mut buf.re[..half], &mut buf.im[..half], lanes);
                }
                super::bitrev::bit_reverse_permute_b(&mut buf.re[..half], &mut buf.im[..half], lanes);
                real::interleave_scale_b(&mut buf.re, &mut buf.im, self.scale, lanes);
            }
        }
    }

    /// Batched execution reporting each step's whole-batch wall-clock
    /// nanoseconds to `on_step(edge, stage, ns)` — the autotune sampling
    /// hook for batched serving. Arithmetic is identical to
    /// [`CompiledPlan::run_batch`].
    pub fn run_batch_traced(
        &self,
        buf: &mut BatchBuffer,
        on_step: &mut dyn FnMut(EdgeType, usize, f64),
    ) {
        assert_eq!(buf.n(), self.n, "batch buffer is for n={}, plan for n={}", buf.n(), self.n);
        let lanes = buf.lanes();
        match self.kind {
            TransformKind::Forward | TransformKind::Inverse => {
                if self.kind == TransformKind::Inverse {
                    real::negate(&mut buf.im);
                }
                for step in &self.steps {
                    let t0 = std::time::Instant::now();
                    run_step_b(self.kernels, step, &mut buf.re, &mut buf.im, lanes);
                    on_step(step.edge, step.stage, t0.elapsed().as_nanos() as f64);
                }
                if self.bitrev {
                    super::bitrev::bit_reverse_permute_b(&mut buf.re, &mut buf.im, lanes);
                }
                if self.kind == TransformKind::Inverse {
                    real::conj_scale(&mut buf.re, &mut buf.im, self.scale);
                }
            }
            TransformKind::RealForward => {
                let half = self.cn() * lanes;
                real::pack_even_odd_b(&mut buf.re, &mut buf.im, self.cn(), lanes);
                let last = self.steps.len() - 1;
                for step in &self.steps[..last] {
                    let t0 = std::time::Instant::now();
                    run_step_b(self.kernels, step, &mut buf.re[..half], &mut buf.im[..half], lanes);
                    on_step(step.edge, step.stage, t0.elapsed().as_nanos() as f64);
                }
                super::bitrev::bit_reverse_permute_b(&mut buf.re[..half], &mut buf.im[..half], lanes);
                let ru = &self.steps[last];
                let t0 = std::time::Instant::now();
                real::unpack_r2c_b(&mut buf.re, &mut buf.im, &ru.tw[0], lanes);
                on_step(ru.edge, ru.stage, t0.elapsed().as_nanos() as f64);
            }
            TransformKind::RealInverse => {
                let half = self.cn() * lanes;
                let ru = &self.steps[0];
                let t0 = std::time::Instant::now();
                real::pack_c2r_b(&mut buf.re, &mut buf.im, &ru.tw[0], lanes);
                on_step(ru.edge, ru.stage, t0.elapsed().as_nanos() as f64);
                for step in &self.steps[1..] {
                    let t0 = std::time::Instant::now();
                    run_step_b(self.kernels, step, &mut buf.re[..half], &mut buf.im[..half], lanes);
                    on_step(step.edge, step.stage, t0.elapsed().as_nanos() as f64);
                }
                super::bitrev::bit_reverse_permute_b(&mut buf.re[..half], &mut buf.im[..half], lanes);
                real::interleave_scale_b(&mut buf.re, &mut buf.im, self.scale, lanes);
            }
        }
    }

    /// Convenience: traced run on a copy.
    pub fn run_on_traced(
        &self,
        input: &SplitComplex,
        on_step: &mut dyn FnMut(EdgeType, usize, f64),
    ) -> SplitComplex {
        let mut out = input.clone();
        self.run_traced(&mut out.re, &mut out.im, on_step);
        out
    }
}

/// Executor: owns the twiddle cache and the codelet table, compiles
/// plans and single edges. The table is resolved once at construction
/// ([`simd::detect`]: best backend for the host, or scalar when
/// `SPFFT_FORCE_SCALAR` is set) and stamped into every [`CompiledPlan`].
#[derive(Debug)]
pub struct Executor {
    cache: TwiddleCache,
    kernels: &'static Kernels,
}

impl Default for Executor {
    fn default() -> Self {
        Self::new()
    }
}

impl Executor {
    pub fn new() -> Self {
        Self { cache: TwiddleCache::default(), kernels: simd::detect() }
    }

    /// Executor pinned to `isa`'s codelets, falling back to scalar when
    /// that backend isn't available on this host — the parity-test and
    /// `--isa` override path ([`simd::for_isa`]).
    pub fn with_isa(isa: Isa) -> Self {
        Self { cache: TwiddleCache::default(), kernels: simd::for_isa(isa) }
    }

    /// The ISA every plan compiled by this executor dispatches to.
    pub fn isa(&self) -> Isa {
        self.kernels.isa
    }

    /// The resolved codelet table.
    pub fn kernels(&self) -> &'static Kernels {
        self.kernels
    }

    /// Compile `plan` for forward n-point transforms (the historical
    /// entry point; see [`Executor::compile_kind`]).
    pub fn compile(&mut self, plan: &Plan, n: usize, bitrev: bool) -> CompiledPlan {
        self.compile_kind(plan, n, bitrev, TransformKind::Forward)
    }

    /// Compile `plan` for n-point transforms of `kind` (panics on
    /// invalid plans — validity is the planner's contract). For c2c
    /// kinds the plan must be valid for log2(n); for real kinds `n` is
    /// the request-buffer length, the internal c2c runs at n/2, the
    /// plan must be valid for log2(n) − 1, and bit-reversal is forced
    /// on (the split/unpack algebra needs natural order).
    pub fn compile_kind(
        &mut self,
        plan: &Plan,
        n: usize,
        bitrev: bool,
        kind: TransformKind,
    ) -> CompiledPlan {
        if kind.is_real() {
            assert!(
                n >= 4 && n.is_power_of_two(),
                "real transforms need a power-of-two n >= 4, got {n}"
            );
        }
        let cn = kind.complex_len(n);
        let l = log2i(cn);
        assert!(plan.is_valid_for(l), "plan {plan} invalid for {kind} n={n} (c2c levels {l})");
        let bitrev = bitrev || kind.is_real();
        let mut steps: Vec<CompiledStep> = plan
            .steps()
            .into_iter()
            .map(|(edge, stage)| compile_step(&mut self.cache, cn, edge, stage))
            .collect();
        match kind {
            TransformKind::RealForward => {
                steps.push(compile_step(&mut self.cache, cn, EdgeType::RU, l));
            }
            TransformKind::RealInverse => {
                steps.insert(0, compile_step(&mut self.cache, cn, EdgeType::RU, 0));
            }
            _ => {}
        }
        let scale = if kind.is_inverse() { 1.0 / cn as f32 } else { 1.0 };
        CompiledPlan { n, kind, plan: plan.clone(), bitrev, scale, steps, kernels: self.kernels }
    }

    /// Compile a single edge (for per-edge measurement).
    pub fn compile_edge(&mut self, n: usize, edge: EdgeType, stage: usize) -> CompiledStep {
        compile_step(&mut self.cache, n, edge, stage)
    }

    pub fn twiddle_cache(&mut self) -> &mut TwiddleCache {
        &mut self.cache
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::reference::{dft_naive, fft_ref};
    use crate::plan::table3_arrangements;

    #[test]
    fn all_table3_plans_compute_the_same_fft() {
        let n = 1024;
        let input = SplitComplex::random(n, 2024);
        let want = fft_ref(&input);
        let scale = want.max_abs().max(1.0);
        let mut ex = Executor::new();
        for row in table3_arrangements() {
            let cp = ex.compile(&row.plan, n, true);
            let got = cp.run_on(&input);
            let err = got.max_abs_diff(&want) / scale;
            assert!(err < 5e-5, "{}: rel err {err}", row.key);
        }
    }

    #[test]
    fn compiled_plan_matches_naive_dft_small() {
        let n = 64;
        let input = SplitComplex::random(n, 7);
        let want = dft_naive(&input);
        let scale = want.max_abs().max(1.0);
        let mut ex = Executor::new();
        for plan_str in ["R2,R2,R2,R2,R2,R2", "R4,R4,R2,R2", "R8,F8", "R2,F32", "F8,F8"] {
            let plan = Plan::parse(plan_str).unwrap();
            let cp = ex.compile(&plan, n, true);
            let got = cp.run_on(&input);
            let err = got.max_abs_diff(&want) / scale;
            assert!(err < 1e-4, "{plan_str}: rel err {err}");
        }
    }

    #[test]
    fn inverse_of_forward_is_identity() {
        // inverse(forward(x)) ≈ x across plan shapes — the kind axis's
        // basic contract (both directions share the forward kernels).
        let n = 256;
        let input = SplitComplex::random(n, 31);
        let scale = input.max_abs().max(1.0);
        let mut ex = Executor::new();
        for plan_str in ["R4,R4,R2,F8", "R2,R2,R2,R2,R2,R2,R2,R2", "R8,F32", "F8,F8,R2,R2"] {
            let plan = Plan::parse(plan_str).unwrap();
            let fwd = ex.compile_kind(&plan, n, true, TransformKind::Forward);
            let inv = ex.compile_kind(&plan, n, true, TransformKind::Inverse);
            let back = inv.run_on(&fwd.run_on(&input));
            let err = back.max_abs_diff(&input) / scale;
            assert!(err < 1e-4, "{plan_str}: rel err {err}");
        }
    }

    #[test]
    fn inverse_matches_scaled_conjugate_dft() {
        // The inverse kind is the true IDFT: applying it to the naive
        // DFT of x recovers x.
        let n = 64;
        let input = SplitComplex::random(n, 77);
        let spectrum = dft_naive(&input);
        let mut ex = Executor::new();
        let inv = ex.compile_kind(&Plan::parse("R4,R4,R2,R2").unwrap(), n, true, TransformKind::Inverse);
        let back = inv.run_on(&spectrum);
        let err = back.max_abs_diff(&input) / input.max_abs().max(1.0);
        assert!(err < 1e-4, "rel err {err}");
    }

    #[test]
    fn real_forward_matches_complex_dft_of_real_signal() {
        // r2c must match the reference complex DFT of the real signal —
        // on the first n/2+1 bins by construction, and on all n bins via
        // the Hermitian mirror the unpack writes.
        let mut ex = Executor::new();
        for (n, plan_str) in [(8usize, "R2,R2"), (64, "R4,R2,R2,R2"), (512, "R4,R4,R2,F8")] {
            let mut input = SplitComplex::random(n, n as u64);
            input.im.iter_mut().for_each(|v| *v = 0.0);
            let want = dft_naive(&input);
            let cp = ex.compile_kind(&Plan::parse(plan_str).unwrap(), n, true, TransformKind::RealForward);
            let got = cp.run_on(&input);
            let scale = want.max_abs().max(1.0);
            let err = got.max_abs_diff(&want) / scale;
            assert!(err < 1e-4, "n={n} {plan_str}: rel err {err}");
        }
    }

    #[test]
    fn real_forward_ignores_imaginary_input() {
        let n = 128;
        let mut ex = Executor::new();
        let cp = ex.compile_kind(&Plan::parse("R4,R4,R2,R2").unwrap(), n, true, TransformKind::RealForward);
        let mut a = SplitComplex::random(n, 5);
        let mut b = a.clone();
        a.im.iter_mut().for_each(|v| *v = 0.0);
        b.im.iter_mut().for_each(|v| *v = 123.0);
        assert_eq!(cp.run_on(&a), cp.run_on(&b));
    }

    #[test]
    fn real_inverse_of_real_forward_is_identity() {
        let n = 256;
        let mut ex = Executor::new();
        let plan = Plan::parse("R4,R2,F16").unwrap(); // 7 levels for h = 128
        let fwd = ex.compile_kind(&plan, n, true, TransformKind::RealForward);
        let inv = ex.compile_kind(&plan, n, true, TransformKind::RealInverse);
        let mut input = SplitComplex::random(n, 404);
        input.im.iter_mut().for_each(|v| *v = 0.0);
        let back = inv.run_on(&fwd.run_on(&input));
        let err = back.max_abs_diff(&input) / input.max_abs().max(1.0);
        assert!(err < 1e-4, "rel err {err}");
        // the real-inverse output is purely real
        assert!(back.im.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn real_kinds_place_the_ru_step_at_the_boundary() {
        let n = 64;
        let mut ex = Executor::new();
        let plan = Plan::parse("R4,R2,R2,R2").unwrap();
        let r2c = ex.compile_kind(&plan, n, true, TransformKind::RealForward);
        assert_eq!(r2c.steps().last().unwrap().edge, EdgeType::RU);
        assert_eq!(r2c.steps().last().unwrap().stage, 5); // one past the c2c levels
        assert_eq!(r2c.steps().len(), plan.len() + 1);
        let c2r = ex.compile_kind(&plan, n, true, TransformKind::RealInverse);
        assert_eq!(c2r.steps().first().unwrap().edge, EdgeType::RU);
        assert_eq!(c2r.steps().first().unwrap().stage, 0);
    }

    #[test]
    #[should_panic(expected = "invalid")]
    fn invalid_plan_rejected() {
        let mut ex = Executor::new();
        ex.compile(&Plan::parse("R2,R2").unwrap(), 1024, true);
    }

    #[test]
    #[should_panic(expected = "invalid")]
    fn real_kind_rejects_full_length_plan() {
        // A real transform's c2c runs at n/2: an l-level plan is one
        // level too long.
        let mut ex = Executor::new();
        ex.compile_kind(&Plan::parse("R4,R4,R2,F8").unwrap(), 256, true, TransformKind::RealForward);
    }

    #[test]
    fn without_bitrev_output_is_bit_reversed() {
        let n = 32;
        let input = SplitComplex::random(n, 3);
        let mut ex = Executor::new();
        let plan = Plan::parse("R2,R2,R2,R2,R2").unwrap();
        let a = ex.compile(&plan, n, false).run_on(&input);
        let mut b = ex.compile(&plan, n, true).run_on(&input);
        super::super::bitrev::bit_reverse_permute(&mut b.re, &mut b.im);
        // bitrev is involutive, so un-reversing the bitrev'd output matches.
        assert!(a.max_abs_diff(&b) < 1e-6);
    }

    #[test]
    fn twiddles_shared_across_plans() {
        let mut ex = Executor::new();
        let p1 = Plan::parse("R2,R2,R2,R2,R2,R2,R2,R2,R2,R2").unwrap();
        ex.compile(&p1, 1024, true);
        let before = ex.twiddle_cache().entries();
        ex.compile(&p1, 1024, true); // recompile: all cache hits
        assert_eq!(ex.twiddle_cache().entries(), before);
        // inverse kinds share the same (forward) tables: zero new entries
        ex.compile_kind(&p1, 1024, true, TransformKind::Inverse);
        assert_eq!(ex.twiddle_cache().entries(), before);
    }

    #[test]
    fn traced_run_is_bit_identical_and_reports_every_step() {
        let n = 512;
        let input = SplitComplex::random(n, 77);
        let mut ex = Executor::new();
        let plan = Plan::parse("R4,R2,R4,R2,F8").unwrap();
        let cp = ex.compile(&plan, n, true);
        let mut seen = Vec::new();
        let traced = cp.run_on_traced(&input, &mut |edge, stage, ns| {
            seen.push((edge, stage));
            assert!(ns >= 0.0);
        });
        assert_eq!(traced, cp.run_on(&input));
        assert_eq!(seen, plan.steps());
    }

    #[test]
    fn traced_runs_are_bit_identical_for_every_kind() {
        let n = 256;
        let mut ex = Executor::new();
        let c2c = Plan::parse("R4,R4,R2,F8").unwrap();
        let half = Plan::parse("R4,R2,R2,F8").unwrap(); // 7 levels for h = 128
        for kind in crate::kind::ALL_KINDS {
            let plan = if kind.is_real() { &half } else { &c2c };
            let cp = ex.compile_kind(plan, n, true, kind);
            let input = SplitComplex::random(n, 9 + kind.index() as u64);
            let mut seen = Vec::new();
            let traced = cp.run_on_traced(&input, &mut |edge, stage, _| seen.push((edge, stage)));
            assert_eq!(traced, cp.run_on(&input), "{kind}");
            let want: Vec<(EdgeType, usize)> =
                cp.steps().iter().map(|s| (s.edge, s.stage)).collect();
            assert_eq!(seen, want, "{kind}: every step (RU included) reports");
        }
    }

    #[test]
    fn run_batch_is_bit_identical_to_sequential_runs() {
        // The batched-execution contract: every lane of a batch matches a
        // lone CompiledPlan::run bit-for-bit, including B=1 and batch
        // sizes that are not lane multiples.
        let n = 256;
        let mut ex = Executor::new();
        for plan_str in ["R4,R4,R2,F8", "R2,R2,R2,R2,R2,R2,R2,R2", "F8,F8,R2,R2", "R8,F32"] {
            let cp = ex.compile(&Plan::parse(plan_str).unwrap(), n, true);
            for b in [1usize, 2, 5, 8, 16] {
                let inputs: Vec<SplitComplex> =
                    (0..b).map(|i| SplitComplex::random(n, 900 + i as u64)).collect();
                let refs: Vec<&SplitComplex> = inputs.iter().collect();
                let mut buf = crate::fft::BatchBuffer::new(n, b);
                buf.gather(&refs);
                cp.run_batch(&mut buf);
                for (l, input) in inputs.iter().enumerate() {
                    assert_eq!(
                        buf.scatter_lane(l),
                        cp.run_on(input),
                        "{plan_str}: lane {l} of batch {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn run_batch_is_bit_identical_to_scalar_for_every_kind() {
        let n = 128;
        let mut ex = Executor::new();
        let c2c = Plan::parse("R4,R2,R2,F8").unwrap();
        let half = Plan::parse("R4,R2,F8").unwrap(); // 6 levels for h = 64
        for kind in crate::kind::ALL_KINDS {
            let plan = if kind.is_real() { &half } else { &c2c };
            let cp = ex.compile_kind(plan, n, true, kind);
            for b in [1usize, 3, 4, 6] {
                let inputs: Vec<SplitComplex> =
                    (0..b).map(|i| SplitComplex::random(n, 700 + i as u64)).collect();
                let refs: Vec<&SplitComplex> = inputs.iter().collect();
                let mut buf = crate::fft::BatchBuffer::new(n, b);
                buf.gather(&refs);
                cp.run_batch(&mut buf);
                for (l, input) in inputs.iter().enumerate() {
                    assert_eq!(
                        buf.scatter_lane(l),
                        cp.run_on(input),
                        "{kind}: lane {l} of batch {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn run_batch_without_bitrev_matches_too() {
        let n = 128;
        let mut ex = Executor::new();
        let cp = ex.compile(&Plan::parse("R4,R2,F16").unwrap(), n, false);
        let inputs: Vec<SplitComplex> = (0..3).map(|i| SplitComplex::random(n, i)).collect();
        let refs: Vec<&SplitComplex> = inputs.iter().collect();
        let mut buf = crate::fft::BatchBuffer::new(n, 3);
        buf.gather(&refs);
        cp.run_batch(&mut buf);
        for (l, input) in inputs.iter().enumerate() {
            assert_eq!(buf.scatter_lane(l), cp.run_on(input), "lane {l}");
        }
    }

    #[test]
    fn traced_batch_is_bit_identical_and_reports_every_step() {
        let n = 512;
        let mut ex = Executor::new();
        let plan = Plan::parse("R4,R2,R4,R2,F8").unwrap();
        let cp = ex.compile(&plan, n, true);
        let inputs: Vec<SplitComplex> = (0..6).map(|i| SplitComplex::random(n, 40 + i)).collect();
        let refs: Vec<&SplitComplex> = inputs.iter().collect();
        let mut traced = crate::fft::BatchBuffer::new(n, 6);
        traced.gather(&refs);
        let mut plain = traced.clone();
        let mut seen = Vec::new();
        cp.run_batch_traced(&mut traced, &mut |edge, stage, ns| {
            seen.push((edge, stage));
            assert!(ns >= 0.0);
        });
        cp.run_batch(&mut plain);
        assert_eq!(traced, plain);
        assert_eq!(seen, plan.steps());
    }

    #[test]
    fn traced_batch_matches_plain_batch_for_real_kinds() {
        let n = 64;
        let mut ex = Executor::new();
        let half = Plan::parse("R4,R2,R2,R2").unwrap(); // 5 levels for h = 32
        for kind in [TransformKind::RealForward, TransformKind::RealInverse] {
            let cp = ex.compile_kind(&half, n, true, kind);
            let inputs: Vec<SplitComplex> = (0..3).map(|i| SplitComplex::random(n, 60 + i)).collect();
            let refs: Vec<&SplitComplex> = inputs.iter().collect();
            let mut traced = crate::fft::BatchBuffer::new(n, 3);
            traced.gather(&refs);
            let mut plain = traced.clone();
            let mut seen = Vec::new();
            cp.run_batch_traced(&mut traced, &mut |edge, stage, _| seen.push((edge, stage)));
            cp.run_batch(&mut plain);
            assert_eq!(traced, plain, "{kind}");
            let want: Vec<(EdgeType, usize)> =
                cp.steps().iter().map(|s| (s.edge, s.stage)).collect();
            assert_eq!(seen, want, "{kind}");
        }
    }

    #[test]
    #[should_panic(expected = "batch buffer is for n=")]
    fn run_batch_rejects_wrong_size_buffer() {
        let mut ex = Executor::new();
        let cp = ex.compile(&Plan::parse("R4,R4,R2,F8").unwrap(), 256, true);
        let mut buf = crate::fft::BatchBuffer::new(128, 4);
        cp.run_batch(&mut buf);
    }

    #[test]
    fn executor_stamps_its_isa_into_plans() {
        let mut ex = Executor::with_isa(crate::isa::Isa::Scalar);
        assert_eq!(ex.isa(), crate::isa::Isa::Scalar);
        let cp = ex.compile(&Plan::parse("R4,R4,R2,F8").unwrap(), 256, true);
        assert_eq!(cp.isa(), crate::isa::Isa::Scalar);
        // the default executor carries whatever the host detects
        assert_eq!(Executor::new().isa(), simd::detect().isa);
    }

    #[test]
    fn detected_backend_matches_forced_scalar_bitwise() {
        // End-to-end dispatch parity on this host: whatever backend
        // detect() resolves, whole-plan outputs are bit-identical to the
        // scalar table, for every kind and for batched execution.
        let n = 256;
        let mut native = Executor::new();
        let mut scalar = Executor::with_isa(crate::isa::Isa::Scalar);
        let c2c = Plan::parse("R4,R4,R2,F8").unwrap();
        let half = Plan::parse("R4,R2,R2,F8").unwrap(); // 7 levels for h = 128
        for kind in crate::kind::ALL_KINDS {
            let plan = if kind.is_real() { &half } else { &c2c };
            let np = native.compile_kind(plan, n, true, kind);
            let sp = scalar.compile_kind(plan, n, true, kind);
            let input = SplitComplex::random(n, 1000 + kind.index() as u64);
            assert_eq!(np.run_on(&input), sp.run_on(&input), "{kind}");
            let inputs: Vec<SplitComplex> =
                (0..5).map(|i| SplitComplex::random(n, 2000 + i)).collect();
            let refs: Vec<&SplitComplex> = inputs.iter().collect();
            let mut nb = crate::fft::BatchBuffer::new(n, 5);
            nb.gather(&refs);
            let mut sb = nb.clone();
            np.run_batch(&mut nb);
            sp.run_batch(&mut sb);
            assert_eq!(nb, sb, "{kind}: batched");
        }
    }

    #[test]
    fn run_is_deterministic() {
        let n = 256;
        let input = SplitComplex::random(n, 55);
        let mut ex = Executor::new();
        let cp = ex.compile(&Plan::parse("R4,R4,R4,R2,R2").unwrap(), n, true);
        assert_eq!(cp.run_on(&input), cp.run_on(&input));
    }
}
