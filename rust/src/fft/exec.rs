//! Plan executor: compile a [`Plan`] against a twiddle cache, then run it
//! repeatedly over split-complex buffers (the native-path hot loop).
//!
//! Compilation resolves every edge's twiddle vectors once; execution is
//! allocation-free. This is what the `NativeCost` provider times and what
//! the coordinator's native backend serves requests with.

use std::sync::Arc;

use super::batch::BatchBuffer;
use super::fused::{fused16, fused16_b, fused32, fused32_b, fused8, fused8_b, fused_twiddles};
use super::passes::{radix2, radix2_b, radix4, radix4_b, radix8, radix8_b};
use super::twiddle::{TwiddleCache, TwiddleVec};
use super::{log2i, SplitComplex};
use crate::edge::EdgeType;
use crate::plan::Plan;

/// One compiled step: edge + stage + resolved twiddles.
#[derive(Debug, Clone)]
pub struct CompiledStep {
    pub edge: EdgeType,
    pub stage: usize,
    tw: Vec<Arc<TwiddleVec>>,
}

/// A plan compiled for a fixed n: ready-to-run steps + optional bitrev.
#[derive(Debug, Clone)]
pub struct CompiledPlan {
    pub n: usize,
    pub plan: Plan,
    pub bitrev: bool,
    steps: Vec<CompiledStep>,
}

/// Compile a single edge at (n, stage) — shared by plan compilation and
/// the per-edge measurement path.
pub fn compile_step(
    cache: &mut TwiddleCache,
    n: usize,
    edge: EdgeType,
    stage: usize,
) -> CompiledStep {
    let m = n >> stage;
    assert!(
        m >= (1 << edge.stages()),
        "{edge} at stage {stage} invalid for n={n}"
    );
    let tw = match edge {
        EdgeType::R2 => vec![cache.vector(m, m / 2, 1)],
        EdgeType::R4 => vec![
            cache.vector(m, m / 4, 1),
            cache.vector(m, m / 4, 2),
            cache.vector(m, m / 4, 3),
        ],
        EdgeType::R8 => vec![
            cache.vector(m, m / 8, 1),
            cache.vector(m, m / 8, 2),
            cache.vector(m, m / 8, 4),
        ],
        EdgeType::F8 => fused_twiddles(cache, n, stage, 8),
        EdgeType::F16 => fused_twiddles(cache, n, stage, 16),
        EdgeType::F32 => fused_twiddles(cache, n, stage, 32),
    };
    CompiledStep { edge, stage, tw }
}

/// Run one compiled step in place.
pub fn run_step(step: &CompiledStep, re: &mut [f32], im: &mut [f32]) {
    match step.edge {
        EdgeType::R2 => radix2(re, im, step.stage, &step.tw[0]),
        EdgeType::R4 => radix4(re, im, step.stage, &step.tw[0], &step.tw[1], &step.tw[2]),
        EdgeType::R8 => radix8(re, im, step.stage, &step.tw[0], &step.tw[1], &step.tw[2]),
        EdgeType::F8 => fused8(re, im, step.stage, &step.tw),
        EdgeType::F16 => fused16(re, im, step.stage, &step.tw),
        EdgeType::F32 => fused32(re, im, step.stage, &step.tw),
    }
}

/// Run one compiled step over a lane-blocked batch buffer in place.
pub fn run_step_b(step: &CompiledStep, re: &mut [f32], im: &mut [f32], lanes: usize) {
    match step.edge {
        EdgeType::R2 => radix2_b(re, im, step.stage, &step.tw[0], lanes),
        EdgeType::R4 => {
            radix4_b(re, im, step.stage, &step.tw[0], &step.tw[1], &step.tw[2], lanes)
        }
        EdgeType::R8 => {
            radix8_b(re, im, step.stage, &step.tw[0], &step.tw[1], &step.tw[2], lanes)
        }
        EdgeType::F8 => fused8_b(re, im, step.stage, &step.tw, lanes),
        EdgeType::F16 => fused16_b(re, im, step.stage, &step.tw, lanes),
        EdgeType::F32 => fused32_b(re, im, step.stage, &step.tw, lanes),
    }
}

impl CompiledPlan {
    /// Steps in execution order.
    pub fn steps(&self) -> &[CompiledStep] {
        &self.steps
    }

    /// Execute in place (bitrev applied last if compiled with it).
    pub fn run(&self, re: &mut [f32], im: &mut [f32]) {
        debug_assert_eq!(re.len(), self.n);
        debug_assert_eq!(im.len(), self.n);
        for step in &self.steps {
            run_step(step, re, im);
        }
        if self.bitrev {
            super::bitrev::bit_reverse_permute(re, im);
        }
    }

    /// Convenience: run on a copy.
    pub fn run_on(&self, input: &SplitComplex) -> SplitComplex {
        let mut out = input.clone();
        self.run(&mut out.re, &mut out.im);
        out
    }

    /// Execute in place, reporting each step's wall-clock nanoseconds to
    /// `on_step(edge, stage, ns)` — the autotune trace-sampling hook. The
    /// arithmetic is identical to [`CompiledPlan::run`] (same steps, same
    /// order), so traced and untraced executions are bit-identical.
    pub fn run_traced(
        &self,
        re: &mut [f32],
        im: &mut [f32],
        on_step: &mut dyn FnMut(EdgeType, usize, f64),
    ) {
        debug_assert_eq!(re.len(), self.n);
        debug_assert_eq!(im.len(), self.n);
        for step in &self.steps {
            let t0 = std::time::Instant::now();
            run_step(step, re, im);
            on_step(step.edge, step.stage, t0.elapsed().as_nanos() as f64);
        }
        if self.bitrev {
            super::bitrev::bit_reverse_permute(re, im);
        }
    }

    /// Execute all transforms of a gathered batch in place, one step at
    /// a time across the whole batch: each step's twiddles are loaded
    /// once and applied to every lane, amortizing the per-pass memory
    /// round trip over the batch. Per-lane outputs are bit-identical to
    /// [`CompiledPlan::run`] on that lane alone (the batched kernels run
    /// the same butterfly algebra per lane; padding lanes are zeros and
    /// never feed live lanes).
    pub fn run_batch(&self, buf: &mut BatchBuffer) {
        assert_eq!(buf.n(), self.n, "batch buffer is for n={}, plan for n={}", buf.n(), self.n);
        let lanes = buf.lanes();
        for step in &self.steps {
            run_step_b(step, &mut buf.re, &mut buf.im, lanes);
        }
        if self.bitrev {
            super::bitrev::bit_reverse_permute_b(&mut buf.re, &mut buf.im, lanes);
        }
    }

    /// Batched execution reporting each step's whole-batch wall-clock
    /// nanoseconds to `on_step(edge, stage, ns)` — the autotune sampling
    /// hook for batched serving. Arithmetic is identical to
    /// [`CompiledPlan::run_batch`].
    pub fn run_batch_traced(
        &self,
        buf: &mut BatchBuffer,
        on_step: &mut dyn FnMut(EdgeType, usize, f64),
    ) {
        assert_eq!(buf.n(), self.n, "batch buffer is for n={}, plan for n={}", buf.n(), self.n);
        let lanes = buf.lanes();
        for step in &self.steps {
            let t0 = std::time::Instant::now();
            run_step_b(step, &mut buf.re, &mut buf.im, lanes);
            on_step(step.edge, step.stage, t0.elapsed().as_nanos() as f64);
        }
        if self.bitrev {
            super::bitrev::bit_reverse_permute_b(&mut buf.re, &mut buf.im, lanes);
        }
    }

    /// Convenience: traced run on a copy.
    pub fn run_on_traced(
        &self,
        input: &SplitComplex,
        on_step: &mut dyn FnMut(EdgeType, usize, f64),
    ) -> SplitComplex {
        let mut out = input.clone();
        self.run_traced(&mut out.re, &mut out.im, on_step);
        out
    }
}

/// Executor: owns the twiddle cache, compiles plans and single edges.
#[derive(Debug, Default)]
pub struct Executor {
    cache: TwiddleCache,
}

impl Executor {
    pub fn new() -> Self {
        Self::default()
    }

    /// Compile `plan` for n-point transforms (panics on invalid plans —
    /// validity is the planner's contract; see `Plan::is_valid_for`).
    pub fn compile(&mut self, plan: &Plan, n: usize, bitrev: bool) -> CompiledPlan {
        let l = log2i(n);
        assert!(plan.is_valid_for(l), "plan {plan} invalid for n={n}");
        let steps = plan
            .steps()
            .into_iter()
            .map(|(edge, stage)| compile_step(&mut self.cache, n, edge, stage))
            .collect();
        CompiledPlan { n, plan: plan.clone(), bitrev, steps }
    }

    /// Compile a single edge (for per-edge measurement).
    pub fn compile_edge(&mut self, n: usize, edge: EdgeType, stage: usize) -> CompiledStep {
        compile_step(&mut self.cache, n, edge, stage)
    }

    pub fn twiddle_cache(&mut self) -> &mut TwiddleCache {
        &mut self.cache
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::reference::{dft_naive, fft_ref};
    use crate::plan::table3_arrangements;

    #[test]
    fn all_table3_plans_compute_the_same_fft() {
        let n = 1024;
        let input = SplitComplex::random(n, 2024);
        let want = fft_ref(&input);
        let scale = want.max_abs().max(1.0);
        let mut ex = Executor::new();
        for row in table3_arrangements() {
            let cp = ex.compile(&row.plan, n, true);
            let got = cp.run_on(&input);
            let err = got.max_abs_diff(&want) / scale;
            assert!(err < 5e-5, "{}: rel err {err}", row.key);
        }
    }

    #[test]
    fn compiled_plan_matches_naive_dft_small() {
        let n = 64;
        let input = SplitComplex::random(n, 7);
        let want = dft_naive(&input);
        let scale = want.max_abs().max(1.0);
        let mut ex = Executor::new();
        for plan_str in ["R2,R2,R2,R2,R2,R2", "R4,R4,R2,R2", "R8,F8", "R2,F32", "F8,F8"] {
            let plan = Plan::parse(plan_str).unwrap();
            let cp = ex.compile(&plan, n, true);
            let got = cp.run_on(&input);
            let err = got.max_abs_diff(&want) / scale;
            assert!(err < 1e-4, "{plan_str}: rel err {err}");
        }
    }

    #[test]
    #[should_panic(expected = "invalid")]
    fn invalid_plan_rejected() {
        let mut ex = Executor::new();
        ex.compile(&Plan::parse("R2,R2").unwrap(), 1024, true);
    }

    #[test]
    fn without_bitrev_output_is_bit_reversed() {
        let n = 32;
        let input = SplitComplex::random(n, 3);
        let mut ex = Executor::new();
        let plan = Plan::parse("R2,R2,R2,R2,R2").unwrap();
        let a = ex.compile(&plan, n, false).run_on(&input);
        let mut b = ex.compile(&plan, n, true).run_on(&input);
        super::super::bitrev::bit_reverse_permute(&mut b.re, &mut b.im);
        // bitrev is involutive, so un-reversing the bitrev'd output matches.
        assert!(a.max_abs_diff(&b) < 1e-6);
    }

    #[test]
    fn twiddles_shared_across_plans() {
        let mut ex = Executor::new();
        let p1 = Plan::parse("R2,R2,R2,R2,R2,R2,R2,R2,R2,R2").unwrap();
        ex.compile(&p1, 1024, true);
        let before = ex.twiddle_cache().entries();
        ex.compile(&p1, 1024, true); // recompile: all cache hits
        assert_eq!(ex.twiddle_cache().entries(), before);
    }

    #[test]
    fn traced_run_is_bit_identical_and_reports_every_step() {
        let n = 512;
        let input = SplitComplex::random(n, 77);
        let mut ex = Executor::new();
        let plan = Plan::parse("R4,R2,R4,R2,F8").unwrap();
        let cp = ex.compile(&plan, n, true);
        let mut seen = Vec::new();
        let traced = cp.run_on_traced(&input, &mut |edge, stage, ns| {
            seen.push((edge, stage));
            assert!(ns >= 0.0);
        });
        assert_eq!(traced, cp.run_on(&input));
        assert_eq!(seen, plan.steps());
    }

    #[test]
    fn run_batch_is_bit_identical_to_sequential_runs() {
        // The batched-execution contract: every lane of a batch matches a
        // lone CompiledPlan::run bit-for-bit, including B=1 and batch
        // sizes that are not lane multiples.
        let n = 256;
        let mut ex = Executor::new();
        for plan_str in ["R4,R4,R2,F8", "R2,R2,R2,R2,R2,R2,R2,R2", "F8,F8,R2,R2", "R8,F32"] {
            let cp = ex.compile(&Plan::parse(plan_str).unwrap(), n, true);
            for b in [1usize, 2, 5, 8, 16] {
                let inputs: Vec<SplitComplex> =
                    (0..b).map(|i| SplitComplex::random(n, 900 + i as u64)).collect();
                let refs: Vec<&SplitComplex> = inputs.iter().collect();
                let mut buf = crate::fft::BatchBuffer::new(n, b);
                buf.gather(&refs);
                cp.run_batch(&mut buf);
                for (l, input) in inputs.iter().enumerate() {
                    assert_eq!(
                        buf.scatter_lane(l),
                        cp.run_on(input),
                        "{plan_str}: lane {l} of batch {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn run_batch_without_bitrev_matches_too() {
        let n = 128;
        let mut ex = Executor::new();
        let cp = ex.compile(&Plan::parse("R4,R2,F16").unwrap(), n, false);
        let inputs: Vec<SplitComplex> = (0..3).map(|i| SplitComplex::random(n, i)).collect();
        let refs: Vec<&SplitComplex> = inputs.iter().collect();
        let mut buf = crate::fft::BatchBuffer::new(n, 3);
        buf.gather(&refs);
        cp.run_batch(&mut buf);
        for (l, input) in inputs.iter().enumerate() {
            assert_eq!(buf.scatter_lane(l), cp.run_on(input), "lane {l}");
        }
    }

    #[test]
    fn traced_batch_is_bit_identical_and_reports_every_step() {
        let n = 512;
        let mut ex = Executor::new();
        let plan = Plan::parse("R4,R2,R4,R2,F8").unwrap();
        let cp = ex.compile(&plan, n, true);
        let inputs: Vec<SplitComplex> = (0..6).map(|i| SplitComplex::random(n, 40 + i)).collect();
        let refs: Vec<&SplitComplex> = inputs.iter().collect();
        let mut traced = crate::fft::BatchBuffer::new(n, 6);
        traced.gather(&refs);
        let mut plain = traced.clone();
        let mut seen = Vec::new();
        cp.run_batch_traced(&mut traced, &mut |edge, stage, ns| {
            seen.push((edge, stage));
            assert!(ns >= 0.0);
        });
        cp.run_batch(&mut plain);
        assert_eq!(traced, plain);
        assert_eq!(seen, plan.steps());
    }

    #[test]
    #[should_panic(expected = "batch buffer is for n=")]
    fn run_batch_rejects_wrong_size_buffer() {
        let mut ex = Executor::new();
        let cp = ex.compile(&Plan::parse("R4,R4,R2,F8").unwrap(), 256, true);
        let mut buf = crate::fft::BatchBuffer::new(128, 4);
        cp.run_batch(&mut buf);
    }

    #[test]
    fn run_is_deterministic() {
        let n = 256;
        let input = SplitComplex::random(n, 55);
        let mut ex = Executor::new();
        let cp = ex.compile(&Plan::parse("R4,R4,R4,R2,R2").unwrap(), n, true);
        assert_eq!(cp.run_on(&input), cp.run_on(&input));
    }
}
