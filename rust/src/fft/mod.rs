//! Native split-complex FFT substrate.
//!
//! Implements every edge type of the decomposition graph (paper Table 1) as
//! real, runnable Rust code over split-complex `f32` buffers — the same
//! butterfly algebra as the Layer-1 Pallas kernels:
//!
//! * [`passes`] — radix-2/4/8 DIF passes (memory → butterflies → memory);
//! * [`fused`] — fused FFT-8/16/32 register blocks (gather once, run
//!   log2(B) stages in locals, scatter once);
//! * [`batch`] — lane-blocked batch buffers: B transforms as SIMD lanes,
//!   executed together by the `*_b` kernel variants (one twiddle load
//!   per batch instead of per transform);
//! * [`twiddle`] — cached twiddle-factor tables;
//! * [`real`] — the kind-specific boundary passes: real-input pack /
//!   split-unpack (the RU step), inverse boundary conjugation, and the
//!   folded final-pass scales — the c2c core is kind-agnostic;
//! * [`bitrev`] — bit-reversal permutation;
//! * [`simd`] — explicit SIMD codelet backends (NEON / AVX2 / portable)
//!   of every kernel above, bit-identical to the scalar forms, selected
//!   once per compiled plan through a [`simd::Kernels`] vtable;
//! * [`exec`] — the plan executor (compiled plans over a twiddle cache),
//!   parameterized by [`crate::kind::TransformKind`];
//! * [`fourstep`] — cache-blocked four-step execution for large n:
//!   n = p·q cache-resident sub-FFTs around the priced transpose and
//!   block-twiddle boundary passes;
//! * [`reference`] — O(n²) f64 DFT used as ground truth in tests.
//!
//! Three roles in the system: correctness cross-check for the PJRT
//! artifacts, the *live-measured* edge-weight source for
//! [`crate::cost::NativeCost`] (the paper's protocol on this host), and the
//! per-pass profile of Table 4.

pub mod batch;
pub mod bitrev;
pub mod exec;
pub mod fourstep;
pub mod fused;
pub mod passes;
pub mod real;
pub mod reference;
pub mod simd;
pub mod twiddle;

pub use batch::{BatchBuffer, BatchBufferPool, LANE};
pub use bitrev::{bit_reverse_indices, bit_reverse_permute};
pub use exec::{CompiledPlan, Executor};
pub use fourstep::{compile_four_step, CompiledExec, CompiledFourStep};
pub use twiddle::TwiddleCache;

/// Split-complex buffer: separate re/im arrays (paper §3.1: enables
/// unit-stride vector loads).
#[derive(Debug, Clone, PartialEq)]
pub struct SplitComplex {
    pub re: Vec<f32>,
    pub im: Vec<f32>,
}

impl SplitComplex {
    pub fn zeros(n: usize) -> Self {
        SplitComplex { re: vec![0.0; n], im: vec![0.0; n] }
    }

    pub fn from_parts(re: Vec<f32>, im: Vec<f32>) -> Self {
        assert_eq!(re.len(), im.len());
        SplitComplex { re, im }
    }

    /// Deterministic standard-normal test vector.
    pub fn random(n: usize, seed: u64) -> Self {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut v = SplitComplex::zeros(n);
        rng.fill_normal_f32(&mut v.re);
        rng.fill_normal_f32(&mut v.im);
        v
    }

    pub fn len(&self) -> usize {
        self.re.len()
    }

    pub fn is_empty(&self) -> bool {
        self.re.is_empty()
    }

    /// Max absolute difference against another buffer. NaN anywhere in
    /// either buffer yields infinity (NaN must never pass a tolerance
    /// check — a disagreeing-NaN bug once slipped through `f32::max`'s
    /// NaN-ignoring semantics).
    pub fn max_abs_diff(&self, other: &SplitComplex) -> f32 {
        assert_eq!(self.len(), other.len());
        let mut m = 0f32;
        for i in 0..self.len() {
            let dr = (self.re[i] - other.re[i]).abs();
            let di = (self.im[i] - other.im[i]).abs();
            if dr.is_nan() || di.is_nan() {
                return f32::INFINITY;
            }
            m = m.max(dr).max(di);
        }
        m
    }

    /// L-inf norm of the buffer (for relative-error scaling).
    pub fn max_abs(&self) -> f32 {
        let mut m = 0f32;
        for i in 0..self.len() {
            m = m.max(self.re[i].abs()).max(self.im[i].abs());
        }
        m
    }
}

/// Exact integer log2; panics on non-powers-of-two.
pub fn log2i(n: usize) -> usize {
    assert!(n.is_power_of_two() && n > 0, "{n} is not a positive power of two");
    n.trailing_zeros() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_complex_roundtrip() {
        let v = SplitComplex::random(64, 1);
        assert_eq!(v.len(), 64);
        assert_eq!(v.max_abs_diff(&v), 0.0);
        assert!(v.max_abs() > 0.0);
    }

    #[test]
    fn log2i_powers() {
        assert_eq!(log2i(1), 0);
        assert_eq!(log2i(1024), 10);
    }

    #[test]
    #[should_panic]
    fn log2i_rejects_non_power() {
        log2i(48);
    }

    #[test]
    fn random_is_seed_deterministic() {
        assert_eq!(SplitComplex::random(32, 7), SplitComplex::random(32, 7));
        assert_ne!(SplitComplex::random(32, 7), SplitComplex::random(32, 8));
    }
}
