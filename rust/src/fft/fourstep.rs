//! Four-step (blocked) execution for transforms too large for cache.
//!
//! A flat arrangement walks the whole 8n-byte working set once per
//! pass; past the L2 capacity every one of those walks streams from
//! DRAM and the per-pass round trip the paper prices as the dominant
//! cost (§2, Table 1) inflates by the DRAM:L1 bandwidth ratio. The
//! classic answer (Bailey's four-step / six-step; FFTW's rec-vrank
//! plans) is to factor n = p·q and do two passes of *cache-resident*
//! sub-FFTs with a twiddle multiply and a transpose between them. This
//! module is that execution path; the *decision* to use it — and the
//! choice of (p, q) — belongs to the planner, which prices the
//! boundary passes ([`crate::edge::EdgeType::Transpose`],
//! [`crate::edge::EdgeType::BlockTwiddle`]) against the spilled-tier
//! flat cost ([`crate::cost::CacheTier`]).
//!
//! ## Decomposition (decimation in time over columns)
//!
//! Write the input index j = j2 + q·j1 (j1 ∈ [0,p), j2 ∈ [0,q)) and
//! the output index k = k1 + p·k2 (k1 ∈ [0,p), k2 ∈ [0,q)). Then
//!
//! ```text
//! X[k1 + p·k2] = Σ_{j2} W_n^{j2·k1} · ( Σ_{j1} x[j2 + q·j1] W_p^{j1·k1} ) · W_q^{j2·k2}
//! ```
//!
//! which executes as four steps:
//!
//! 1. **Columns** — q FFTs of length p over the stride-q columns
//!    (inner sum). Column j2's natural-order result C_j2[k1] lands in
//!    a scratch matrix at slot `q·k1 + j2`: the gather/scatter around
//!    the sub-FFT *is* the first transpose, priced as a `TR` boundary
//!    edge. Columns run 16 at a time through the lane-blocked panel
//!    machinery ([`BatchBuffer`], the `_b` kernels): 16 consecutive
//!    columns form contiguous 16-float runs in the source rows, so the
//!    gather is unit-stride memcpy per row and the sub-FFT amortizes
//!    every twiddle load over the panel.
//! 2. **Block twiddle** — slot `q·k1 + j2` scales by W_n^{j2·k1}
//!    (`BT` boundary edge). Row k1 = 0 is the identity and is skipped.
//! 3. **Rows** — p FFTs of length q, each over a *contiguous*
//!    cache-resident row of the scratch matrix, in place. These run
//!    the scalar single-transform path: contiguity is the point, and
//!    the per-row working set (8q bytes) fits L1/L2 by construction.
//! 4. **Transpose out** — `out[k1 + p·k2] = buf[q·k1 + k2]`, tiled
//!    32×32 (the second `TR` boundary edge).
//!
//! Both sub-plans compile `bitrev = true` (the index algebra above
//! needs natural-order sub-results), so blocked output is always in
//! natural order.
//!
//! ## Kinds
//!
//! Only a *forward* c2c core exists; the other three kinds wrap it
//! with the same boundary passes [`CompiledPlan`] uses: inverse =
//! conjugate + 1/n scale, real kinds = pack/unpack around a
//! half-length core. The wrappers operate on the full request buffer;
//! the core runs at `kind.complex_len(n)`.
//!
//! ## Numerics
//!
//! Blocked and flat execution agree to within f32 rounding, **not**
//! bit-for-bit: the four-step factorization applies the same DFT
//! algebra in a different association order, so individual lanes
//! differ in the last ulps. Bit-identity to the flat path is *not*
//! part of the contract (the tests pin a relative-error bound against
//! the f64 reference instead); what is contractual is that the
//! planner's flat-vs-blocked choice never changes results beyond that
//! bound.

use std::sync::Arc;
use std::time::Instant;

use crate::edge::EdgeType;
use crate::kind::TransformKind;
use crate::plan::{ExecPlan, Plan};

use super::batch::BatchBuffer;
use super::exec::{CompiledPlan, Executor};
use super::real;
use super::twiddle::TwiddleVec;
use super::{log2i, SplitComplex};

/// Columns per panel group: 16 consecutive columns gathered into one
/// lane-blocked [`BatchBuffer`] so the column sub-FFTs run batched.
/// 16 f32 = one cache line on both modeled machines, so every gather
/// row is a full-line unit-stride copy.
pub const PANEL_COLS: usize = 16;

/// Smallest admissible factor: both p and q must hold a full panel
/// group (and a 16-wide transpose tile edge).
pub const MIN_FACTOR: usize = PANEL_COLS;

/// Transpose tile edge for the final out-of-place transpose.
const TILE: usize = 32;

/// The final-transpose walk: `dst[k1 + p·k2] = src[q·k1 + k2]`, tiled
/// [`TILE`]×[`TILE`]. Standalone so the native cost provider times
/// exactly the walk the executor runs.
pub fn tiled_transpose(
    src_re: &[f32],
    src_im: &[f32],
    dst_re: &mut [f32],
    dst_im: &mut [f32],
    p: usize,
    q: usize,
) {
    debug_assert_eq!(src_re.len(), p * q);
    debug_assert_eq!(dst_re.len(), p * q);
    for k10 in (0..p).step_by(TILE) {
        for k20 in (0..q).step_by(TILE) {
            for k1 in k10..(k10 + TILE).min(p) {
                let src = k1 * q;
                for k2 in k20..(k20 + TILE).min(q) {
                    dst_re[k1 + p * k2] = src_re[src + k2];
                    dst_im[k1 + p * k2] = src_im[src + k2];
                }
            }
        }
    }
}

/// The block-twiddle walk: slot `q·k1 + j2` of the p×q matrix scales
/// by `blocktw[k1][j2]`. Row 0 must be the identity row and is
/// skipped. Standalone for the same reason as [`tiled_transpose`].
pub fn apply_block_twiddle(re: &mut [f32], im: &mut [f32], q: usize, blocktw: &[Arc<TwiddleVec>]) {
    let p = blocktw.len();
    debug_assert_eq!(re.len(), p * q);
    for k1 in 1..p {
        let tw = &blocktw[k1];
        let row_r = &mut re[k1 * q..(k1 + 1) * q];
        let row_i = &mut im[k1 * q..(k1 + 1) * q];
        for j2 in 0..q {
            let (br, bi) = (row_r[j2], row_i[j2]);
            let (tr, ti) = (tw.re[j2], tw.im[j2]);
            row_r[j2] = br * tr - bi * ti;
            row_i[j2] = br * ti + bi * tr;
        }
    }
}

/// Wall-clock nanoseconds of the four boundary passes of one run —
/// what the traced path reports to the autotuner (the sub-FFT
/// interiors are ordinary [`CompiledPlan`] work at sub-transform
/// sizes and are *not* sampled: attribution cells have no n axis).
#[derive(Debug, Clone, Copy, Default)]
pub struct BoundaryTimings {
    /// Column gathers into the panel (first half of transpose #1).
    pub gather_ns: f64,
    /// Panel scatters into the scratch matrix (second half).
    pub scatter_ns: f64,
    /// The inter-block twiddle multiply.
    pub twiddle_ns: f64,
    /// The final tiled transpose.
    pub transpose_ns: f64,
}

/// A four-step blocked execution compiled for a fixed n and kind:
/// column/row sub-plans compiled through the per-ISA codelet tables,
/// interned block-twiddle rows, and persistent scratch (panel + p×q
/// matrix) so steady-state runs are allocation-free.
#[derive(Debug)]
pub struct CompiledFourStep {
    /// Request-buffer length (for real kinds the core runs at n/2).
    n: usize,
    kind: TransformKind,
    p: usize,
    q: usize,
    /// Column sub-FFT: forward, length p, natural order.
    col: CompiledPlan,
    /// Row sub-FFT: forward, length q, natural order.
    row: CompiledPlan,
    /// Row k1's block twiddles W_cn^{k1·j2}, j2 ∈ [0,q). Entry 0 is
    /// the identity row (kept for uniform indexing; skipped at run
    /// time). Interned process-wide like every other twiddle table.
    blocktw: Vec<Arc<TwiddleVec>>,
    /// Real-kind unpack/pack twiddles (None for c2c kinds).
    ru_tw: Option<Arc<TwiddleVec>>,
    /// Scale folded into the inverse-kind epilogue (1/cn).
    scale: f32,
    exec_plan: ExecPlan,
    /// Lane-blocked panel for one column group (p points × 16 lanes).
    panel: BatchBuffer,
    /// The p×q scratch matrix, row-major with stride q.
    buf_re: Vec<f32>,
    buf_im: Vec<f32>,
}

/// Compile the four-step execution n = p·q (factors of the *c2c*
/// length — for real kinds p·q = n/2). Both factors must be powers of
/// two ≥ [`MIN_FACTOR`]; `col` must be a valid arrangement for
/// log2(p) and `row` for log2(q).
pub fn compile_four_step(
    ex: &mut Executor,
    n: usize,
    kind: TransformKind,
    p: usize,
    q: usize,
    col: &Plan,
    row: &Plan,
) -> CompiledFourStep {
    let cn = kind.complex_len(n);
    let (lp, lq) = (log2i(p), log2i(q));
    assert_eq!(p * q, cn, "factors {p}x{q} do not cover c2c length {cn}");
    assert!(p >= MIN_FACTOR && q >= MIN_FACTOR, "factors {p}x{q} below minimum {MIN_FACTOR}");
    assert!(col.is_valid_for(lp), "column plan {col} invalid for p={p}");
    assert!(row.is_valid_for(lq), "row plan {row} invalid for q={q}");
    let compiled_col = ex.compile(col, p, true);
    let compiled_row = ex.compile(row, q, true);
    let blocktw = (0..p).map(|k1| ex.twiddle_cache().vector(cn, q, k1)).collect();
    let ru_tw = kind.is_real().then(|| real::real_twiddles(ex.twiddle_cache(), cn));
    let scale = if kind.is_inverse() { 1.0 / cn as f32 } else { 1.0 };
    CompiledFourStep {
        n,
        kind,
        p,
        q,
        col: compiled_col,
        row: compiled_row,
        blocktw,
        ru_tw,
        scale,
        exec_plan: ExecPlan::Blocked { p, q, col: col.clone(), row: row.clone() },
        panel: BatchBuffer::new(p, PANEL_COLS),
        buf_re: vec![0.0; cn],
        buf_im: vec![0.0; cn],
    }
}

impl CompiledFourStep {
    pub fn n(&self) -> usize {
        self.n
    }

    pub fn kind(&self) -> TransformKind {
        self.kind
    }

    pub fn factors(&self) -> (usize, usize) {
        (self.p, self.q)
    }

    pub fn exec_plan(&self) -> &ExecPlan {
        &self.exec_plan
    }

    /// The ISA whose codelets the sub-FFTs dispatch to.
    pub fn isa(&self) -> crate::isa::Isa {
        self.col.isa()
    }

    /// The forward c2c core over `cn = p·q` points, in place, natural
    /// order out. Returns the boundary-pass timings.
    fn core(&mut self, re: &mut [f32], im: &mut [f32]) -> BoundaryTimings {
        let (p, q) = (self.p, self.q);
        let lanes = self.panel.lanes();
        debug_assert_eq!(lanes, PANEL_COLS);
        let mut t = BoundaryTimings::default();

        // Step 1: column sub-FFTs, one 16-column panel group at a time.
        for c0 in (0..q).step_by(PANEL_COLS) {
            let t0 = Instant::now();
            for i in 0..p {
                let src = i * q + c0;
                let dst = i * lanes;
                self.panel.re[dst..dst + PANEL_COLS].copy_from_slice(&re[src..src + PANEL_COLS]);
                self.panel.im[dst..dst + PANEL_COLS].copy_from_slice(&im[src..src + PANEL_COLS]);
            }
            t.gather_ns += t0.elapsed().as_secs_f64() * 1e9;

            // lane l holds column j2 = c0 + l; forward + bitrev →
            // natural-order C_{j2}[k1] in panel row k1
            self.col.run_batch(&mut self.panel);

            let t0 = Instant::now();
            for k1 in 0..p {
                let src = k1 * lanes;
                let dst = k1 * q + c0;
                self.buf_re[dst..dst + PANEL_COLS]
                    .copy_from_slice(&self.panel.re[src..src + PANEL_COLS]);
                self.buf_im[dst..dst + PANEL_COLS]
                    .copy_from_slice(&self.panel.im[src..src + PANEL_COLS]);
            }
            t.scatter_ns += t0.elapsed().as_secs_f64() * 1e9;
        }

        // Step 2: block twiddle — slot q·k1 + j2 scales by W_cn^{k1·j2}.
        let t0 = Instant::now();
        apply_block_twiddle(&mut self.buf_re, &mut self.buf_im, q, &self.blocktw);
        t.twiddle_ns = t0.elapsed().as_secs_f64() * 1e9;

        // Step 3: row sub-FFTs over contiguous cache-resident rows, in
        // place. Scalar single-transform path by design: each row is
        // one unit-stride 8q-byte working set — the locality the
        // decomposition exists to create — and lane-blocking rows
        // would re-interleave them.
        for k1 in 0..p {
            self.row
                .run(&mut self.buf_re[k1 * q..(k1 + 1) * q], &mut self.buf_im[k1 * q..(k1 + 1) * q]);
        }

        // Step 4: out[k1 + p·k2] = buf[q·k1 + k2], tiled.
        let t0 = Instant::now();
        tiled_transpose(&self.buf_re, &self.buf_im, re, im, p, q);
        t.transpose_ns = t0.elapsed().as_secs_f64() * 1e9;
        t
    }

    /// Kind dispatch around the forward core — the same wrappers as
    /// [`CompiledPlan::run`] (negate/conj-scale for inverse,
    /// pack/unpack at half length for the real kinds).
    fn dispatch(&mut self, re: &mut [f32], im: &mut [f32]) -> BoundaryTimings {
        debug_assert_eq!(re.len(), self.n);
        debug_assert_eq!(im.len(), self.n);
        let h = self.p * self.q;
        match self.kind {
            TransformKind::Forward => self.core(re, im),
            TransformKind::Inverse => {
                real::negate(im);
                let t = self.core(re, im);
                real::conj_scale(re, im, self.scale);
                t
            }
            TransformKind::RealForward => {
                real::pack_even_odd(re, im, h);
                let t = self.core(&mut re[..h], &mut im[..h]);
                real::unpack_r2c(re, im, self.ru_tw.as_ref().unwrap());
                t
            }
            TransformKind::RealInverse => {
                real::pack_c2r(re, im, self.ru_tw.as_ref().unwrap());
                let t = self.core(&mut re[..h], &mut im[..h]);
                real::interleave_scale(re, im, self.scale);
                t
            }
        }
    }

    /// Execute in place (natural order out; kind boundary passes as on
    /// the flat path). `&mut self`: runs reuse the compiled scratch.
    pub fn run(&mut self, re: &mut [f32], im: &mut [f32]) {
        self.dispatch(re, im);
    }

    /// Execute reporting the four boundary-pass wall-clock samples to
    /// `on_step(edge, stage, ns)` in execution order: column gather
    /// (TR), panel scatter (TR), block twiddle (BT), final transpose
    /// (TR). Sub-FFT interiors are not sampled — they are ordinary
    /// compiled plans at sub-transform sizes, outside the attribution
    /// grid of the serving size. Arithmetic is identical to
    /// [`CompiledFourStep::run`].
    pub fn run_traced(
        &mut self,
        re: &mut [f32],
        im: &mut [f32],
        on_step: &mut dyn FnMut(EdgeType, usize, f64),
    ) {
        let t = self.dispatch(re, im);
        on_step(EdgeType::Transpose, 0, t.gather_ns);
        on_step(EdgeType::Transpose, 0, t.scatter_ns);
        on_step(EdgeType::BlockTwiddle, 0, t.twiddle_ns);
        on_step(EdgeType::Transpose, 0, t.transpose_ns);
    }

    /// Convenience: run on a copy.
    pub fn run_on(&mut self, input: &SplitComplex) -> SplitComplex {
        let mut out = input.clone();
        self.run(&mut out.re, &mut out.im);
        out
    }
}

/// A compiled [`ExecPlan`]: the single dispatch point callers hold so
/// flat and blocked entries flow through one type (the plan cache, the
/// service's compiled entries, the hot-swap path).
#[derive(Debug)]
pub enum CompiledExec {
    Flat(CompiledPlan),
    Blocked(Box<CompiledFourStep>),
}

impl CompiledExec {
    /// Compile an execution decision for (n, kind). Flat plans compile
    /// with bitrev so both variants produce natural order.
    pub fn compile(
        ex: &mut Executor,
        plan: &ExecPlan,
        n: usize,
        kind: TransformKind,
    ) -> CompiledExec {
        match plan {
            ExecPlan::Flat(p) => CompiledExec::Flat(ex.compile_kind(p, n, true, kind)),
            ExecPlan::Blocked { p, q, col, row } => {
                CompiledExec::Blocked(Box::new(compile_four_step(ex, n, kind, *p, *q, col, row)))
            }
        }
    }

    pub fn n(&self) -> usize {
        match self {
            CompiledExec::Flat(c) => c.n,
            CompiledExec::Blocked(c) => c.n(),
        }
    }

    pub fn kind(&self) -> TransformKind {
        match self {
            CompiledExec::Flat(c) => c.kind,
            CompiledExec::Blocked(c) => c.kind(),
        }
    }

    pub fn is_blocked(&self) -> bool {
        matches!(self, CompiledExec::Blocked(_))
    }

    /// The execution decision this was compiled from.
    pub fn exec_plan(&self) -> ExecPlan {
        match self {
            CompiledExec::Flat(c) => ExecPlan::Flat(c.plan.clone()),
            CompiledExec::Blocked(c) => c.exec_plan().clone(),
        }
    }

    pub fn isa(&self) -> crate::isa::Isa {
        match self {
            CompiledExec::Flat(c) => c.isa(),
            CompiledExec::Blocked(c) => c.isa(),
        }
    }

    /// Execute in place (natural order for both variants).
    pub fn run(&mut self, re: &mut [f32], im: &mut [f32]) {
        match self {
            CompiledExec::Flat(c) => c.run(re, im),
            CompiledExec::Blocked(c) => c.run(re, im),
        }
    }

    /// Execute with per-boundary/step sampling: flat entries report
    /// every c2c step as usual; blocked entries report the four
    /// boundary passes.
    pub fn run_traced(
        &mut self,
        re: &mut [f32],
        im: &mut [f32],
        on_step: &mut dyn FnMut(EdgeType, usize, f64),
    ) {
        match self {
            CompiledExec::Flat(c) => c.run_traced(re, im, on_step),
            CompiledExec::Blocked(c) => c.run_traced(re, im, on_step),
        }
    }
}

/// A serviceable all-R4 (plus trailing R2 when l is odd) arrangement
/// for a 2^l sub-transform — the fallback sub-plan when the caller has
/// no planned arrangement for a factor (tests, benches, cold paths).
pub fn radix_mix_plan(l: usize) -> Plan {
    let mut edges = vec![EdgeType::R4; l / 2];
    if l % 2 == 1 {
        edges.push(EdgeType::R2);
    }
    Plan::new(edges)
}

#[cfg(test)]
mod tests {
    use super::super::reference::fft_ref;
    use super::*;

    fn rel_err(got: &SplitComplex, want: &SplitComplex) -> f32 {
        got.max_abs_diff(want) / want.max_abs().max(1.0)
    }

    fn blocked(n: usize, kind: TransformKind, p: usize, q: usize) -> CompiledFourStep {
        let mut ex = Executor::new();
        let cp = radix_mix_plan(log2i(p));
        let rp = radix_mix_plan(log2i(q));
        compile_four_step(&mut ex, n, kind, p, q, &cp, &rp)
    }

    #[test]
    fn forward_matches_reference() {
        // square and both rectangular splits of n = 2^12
        for (p, q) in [(64, 64), (16, 256), (256, 16), (32, 128)] {
            let n = p * q;
            let mut fs = blocked(n, TransformKind::Forward, p, q);
            let input = SplitComplex::random(n, 0xF5 + p as u64);
            let want = fft_ref(&input);
            let got = fs.run_on(&input);
            let err = rel_err(&got, &want);
            assert!(err < 1e-4, "{p}x{q}: rel err {err}");
        }
    }

    #[test]
    fn all_kinds_agree_with_the_flat_path_within_rounding() {
        // Bit-identity to flat is NOT the contract (different
        // association order); agreement within f32 rounding is.
        let n = 1 << 12;
        let mut ex = Executor::new();
        let flat_plan = radix_mix_plan(log2i(n));
        let flat_half = radix_mix_plan(log2i(n / 2));
        for kind in [
            TransformKind::Forward,
            TransformKind::Inverse,
            TransformKind::RealForward,
            TransformKind::RealInverse,
        ] {
            let plan = if kind.is_real() { &flat_half } else { &flat_plan };
            let flat = ex.compile_kind(plan, n, true, kind);
            let (p, q) = (64, kind.complex_len(n) / 64);
            let mut fs = blocked(n, kind, p, q);
            let input = match kind {
                // c2r consumes an r2c spectrum; feed it a valid one
                TransformKind::RealInverse => {
                    let sig = SplitComplex::random(n, 0xC2);
                    let mut spec = sig.clone();
                    ex.compile_kind(&flat_half, n, true, TransformKind::RealForward)
                        .run(&mut spec.re, &mut spec.im);
                    spec
                }
                _ => SplitComplex::random(n, 0xA7 + kind as u64),
            };
            let want = flat.run_on(&input);
            let got = fs.run_on(&input);
            let err = rel_err(&got, &want);
            assert!(err < 1e-4, "{kind:?}: rel err {err}");
        }
    }

    #[test]
    fn inverse_roundtrips_through_forward() {
        let n = 1 << 12;
        let input = SplitComplex::random(n, 0x1D);
        let mut fwd = blocked(n, TransformKind::Forward, 64, 64);
        let mut inv = blocked(n, TransformKind::Inverse, 32, 128);
        let back = inv.run_on(&fwd.run_on(&input));
        let err = rel_err(&back, &input);
        assert!(err < 1e-4, "roundtrip rel err {err}");
    }

    #[test]
    fn real_kinds_roundtrip() {
        let n = 1 << 13; // h = 2^12 = 64x64
        let input = SplitComplex::random(n, 0x5E);
        let mut r2c = blocked(n, TransformKind::RealForward, 64, 64);
        let mut c2r = blocked(n, TransformKind::RealInverse, 64, 64);
        // real transform: imaginary input part is ignored by contract
        let mut real_in = input.clone();
        real_in.im.iter_mut().for_each(|x| *x = 0.0);
        let back = c2r.run_on(&r2c.run_on(&real_in));
        let err = rel_err(&back, &real_in);
        assert!(err < 1e-4, "r2c->c2r rel err {err}");
    }

    #[test]
    fn traced_run_is_bit_identical_and_emits_four_boundary_samples() {
        let n = 1 << 12;
        let input = SplitComplex::random(n, 0x77);
        let mut fs = blocked(n, TransformKind::Forward, 64, 64);
        let plain = fs.run_on(&input);
        let mut samples = Vec::new();
        let mut traced = input.clone();
        fs.run_traced(&mut traced.re, &mut traced.im, &mut |e, s, ns| {
            samples.push((e, s));
            assert!(ns >= 0.0);
        });
        assert_eq!(plain.re, traced.re, "tracing must not change arithmetic");
        assert_eq!(plain.im, traced.im);
        assert_eq!(
            samples,
            vec![
                (EdgeType::Transpose, 0),
                (EdgeType::Transpose, 0),
                (EdgeType::BlockTwiddle, 0),
                (EdgeType::Transpose, 0),
            ]
        );
    }

    #[test]
    fn compiled_exec_dispatches_both_variants() {
        let n = 1 << 12;
        let mut ex = Executor::new();
        let input = SplitComplex::random(n, 0x3C);
        let want = fft_ref(&input);

        let flat_decision = ExecPlan::Flat(radix_mix_plan(log2i(n)));
        let mut flat = CompiledExec::compile(&mut ex, &flat_decision, n, TransformKind::Forward);
        assert!(!flat.is_blocked());
        assert_eq!(flat.exec_plan(), flat_decision);
        let mut a = input.clone();
        flat.run(&mut a.re, &mut a.im);
        assert!(rel_err(&a, &want) < 1e-4);

        let blocked_decision = ExecPlan::Blocked {
            p: 64,
            q: 64,
            col: radix_mix_plan(6),
            row: radix_mix_plan(6),
        };
        let mut blk = CompiledExec::compile(&mut ex, &blocked_decision, n, TransformKind::Forward);
        assert!(blk.is_blocked());
        assert_eq!(blk.exec_plan(), blocked_decision);
        assert_eq!(blk.n(), n);
        let mut b = input.clone();
        blk.run(&mut b.re, &mut b.im);
        assert!(rel_err(&b, &want) < 1e-4);

        // both natural order → they agree with each other too
        assert!(a.max_abs_diff(&b) / want.max_abs().max(1.0) < 1e-4);
    }

    #[test]
    fn sub_plan_twiddles_intern_across_executors() {
        // Two executors compiling the same blocked decision (a shard
        // and its hot-swap replacement) share the block-twiddle rows
        // through the global intern store.
        let a = blocked(1 << 12, TransformKind::Forward, 64, 64);
        let b = blocked(1 << 12, TransformKind::Forward, 64, 64);
        for k1 in 0..64 {
            assert!(Arc::ptr_eq(&a.blocktw[k1], &b.blocktw[k1]));
        }
    }

    #[test]
    fn tiled_transpose_is_a_transpose() {
        // rectangular, tile-remainder shape on both axes
        let (p, q) = (48, 80);
        let src = SplitComplex::random(p * q, 0xEE);
        let mut dst = SplitComplex::zeros(p * q);
        tiled_transpose(&src.re, &src.im, &mut dst.re, &mut dst.im, p, q);
        for k1 in 0..p {
            for k2 in 0..q {
                assert_eq!(dst.re[k1 + p * k2], src.re[q * k1 + k2]);
                assert_eq!(dst.im[k1 + p * k2], src.im[q * k1 + k2]);
            }
        }
    }

    #[test]
    fn radix_mix_plan_is_valid_for_every_l() {
        for l in 1..=20 {
            assert!(radix_mix_plan(l).is_valid_for(l));
        }
    }
}
