//! Lane-blocked batch buffers: run B transforms as the vector lanes.
//!
//! The serving layer collects batches, but per-request execution throws
//! the batch away: every pass re-loads its twiddles and re-walks memory
//! once *per transform* — exactly the per-pass round-trip cost paper
//! Table 1 identifies as dominant. A [`BatchBuffer`] transposes a batch
//! of B same-size transforms into split-complex **[n][B] SoA panels**:
//! element `i` of every transform sits in one contiguous run of
//! `lanes()` floats (`B` rounded up to [`LANE`]), so a batched kernel
//! loads each twiddle element once and applies it to the whole batch
//! with unit-stride vector arithmetic — the batch dimension becomes the
//! SIMD lanes (the "Beating vDSP" batch-blocking structure, and FFTW's
//! howmany-loop amortization, on the native path).
//!
//! Padding lanes (between `batch()` and `lanes()`) are zero-filled by
//! [`BatchBuffer::gather`]; FFT passes are linear, so zeros stay finite
//! and never perturb the live lanes. [`BatchBufferPool`] recycles the
//! backing allocations so a worker's steady-state hot loop is
//! allocation-free.

use super::SplitComplex;

/// Lane width batches are padded to: 4 × f32 = one 128-bit NEON/SSE
/// vector, the narrowest unit the batched kernels vectorize over.
pub const LANE: usize = 4;

/// Round a batch size up to a multiple of [`LANE`].
pub fn padded_lanes(b: usize) -> usize {
    assert!(b >= 1, "batch must be non-empty");
    b.div_ceil(LANE) * LANE
}

/// A batch of `b` n-point transforms in lane-blocked split-complex
/// layout: `re[i * lanes + l]` is element `i` of transform `l`.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchBuffer {
    n: usize,
    b: usize,
    lanes: usize,
    pub re: Vec<f32>,
    pub im: Vec<f32>,
}

impl BatchBuffer {
    /// Freshly-allocated zeroed buffer for `b` n-point transforms.
    pub fn new(n: usize, b: usize) -> BatchBuffer {
        crate::fft::log2i(n); // validate power of two
        let lanes = padded_lanes(b);
        BatchBuffer { n, b, lanes, re: vec![0.0; n * lanes], im: vec![0.0; n * lanes] }
    }

    /// Transform size.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Logical batch size (live lanes).
    pub fn batch(&self) -> usize {
        self.b
    }

    /// Physical lane count (`batch()` rounded up to [`LANE`]).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Transpose per-request buffers into the lane-blocked panels.
    /// Padding lanes are zeroed; every live lane is fully overwritten.
    pub fn gather(&mut self, inputs: &[&SplitComplex]) {
        assert_eq!(inputs.len(), self.b, "gather: wrong batch size");
        for x in inputs {
            assert_eq!(x.len(), self.n, "gather: wrong transform size");
        }
        for i in 0..self.n {
            let row = i * self.lanes;
            for (l, x) in inputs.iter().enumerate() {
                self.re[row + l] = x.re[i];
                self.im[row + l] = x.im[i];
            }
            for l in inputs.len()..self.lanes {
                self.re[row + l] = 0.0;
                self.im[row + l] = 0.0;
            }
        }
    }

    /// Transpose one live lane back out into an existing buffer
    /// (allocation-free scatter for callers that recycle outputs).
    pub fn scatter_lane_into(&self, l: usize, out: &mut SplitComplex) {
        assert!(l < self.b, "lane {l} out of range (batch {})", self.b);
        assert_eq!(out.len(), self.n, "scatter into wrong-size buffer");
        for i in 0..self.n {
            out.re[i] = self.re[i * self.lanes + l];
            out.im[i] = self.im[i * self.lanes + l];
        }
    }

    /// Transpose one live lane back out as a per-request buffer.
    pub fn scatter_lane(&self, l: usize) -> SplitComplex {
        let mut out = SplitComplex::zeros(self.n);
        self.scatter_lane_into(l, &mut out);
        out
    }

    /// Transpose every live lane into existing buffers (batch order).
    pub fn scatter_into(&self, outs: &mut [SplitComplex]) {
        assert_eq!(outs.len(), self.b, "scatter into wrong batch size");
        for (l, out) in outs.iter_mut().enumerate() {
            self.scatter_lane_into(l, out);
        }
    }

    /// All live lanes, in batch order.
    pub fn scatter(&self) -> Vec<SplitComplex> {
        (0..self.b).map(|l| self.scatter_lane(l)).collect()
    }
}

/// Worker-owned pool of batch-buffer allocations. `acquire` reuses a
/// retired allocation when one exists (growing it only if the new shape
/// needs more capacity), so a steady-state worker executes batches
/// without touching the allocator.
#[derive(Debug, Default)]
pub struct BatchBufferPool {
    free: Vec<(Vec<f32>, Vec<f32>)>,
    hits: u64,
    misses: u64,
}

/// Retired allocations kept per pool; beyond this, `release` drops.
const POOL_DEPTH: usize = 4;

impl BatchBufferPool {
    pub fn new() -> BatchBufferPool {
        BatchBufferPool::default()
    }

    /// A buffer for `b` n-point transforms, recycling a retired
    /// allocation when available. Contents are unspecified — callers
    /// must `gather` before running (gather overwrites every lane).
    pub fn acquire(&mut self, n: usize, b: usize) -> BatchBuffer {
        crate::fft::log2i(n);
        let lanes = padded_lanes(b);
        let len = n * lanes;
        // Best fit: prefer a retired pair that already has the capacity.
        let pick = self
            .free
            .iter()
            .position(|(re, _)| re.capacity() >= len)
            .unwrap_or(0);
        let (mut re, mut im) = if self.free.is_empty() {
            self.misses += 1;
            (Vec::new(), Vec::new())
        } else {
            self.hits += 1;
            self.free.swap_remove(pick)
        };
        re.resize(len, 0.0);
        im.resize(len, 0.0);
        BatchBuffer { n, b, lanes, re, im }
    }

    /// Return a buffer's allocation to the pool.
    pub fn release(&mut self, buf: BatchBuffer) {
        if self.free.len() < POOL_DEPTH {
            self.free.push((buf.re, buf.im));
        }
    }

    /// Retired allocations currently pooled.
    pub fn pooled(&self) -> usize {
        self.free.len()
    }

    /// Acquires served from a retired allocation (no allocator touch).
    /// A warm worker's group loop is allocation-free exactly when this
    /// is the only counter still moving.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Acquires that had to allocate fresh backing storage (cold pool).
    pub fn misses(&self) -> u64 {
        self.misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding_rounds_up_to_lane() {
        assert_eq!(padded_lanes(1), LANE);
        assert_eq!(padded_lanes(LANE), LANE);
        assert_eq!(padded_lanes(LANE + 1), 2 * LANE);
        assert_eq!(padded_lanes(16), 16);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let n = 64;
        for b in [1usize, 2, LANE, 5, 16] {
            let inputs: Vec<SplitComplex> =
                (0..b).map(|i| SplitComplex::random(n, i as u64)).collect();
            let refs: Vec<&SplitComplex> = inputs.iter().collect();
            let mut buf = BatchBuffer::new(n, b);
            buf.gather(&refs);
            for (l, want) in inputs.iter().enumerate() {
                assert_eq!(&buf.scatter_lane(l), want, "lane {l} of batch {b}");
            }
            assert_eq!(buf.scatter(), inputs);
        }
    }

    #[test]
    fn padding_lanes_are_zero() {
        let n = 8;
        let b = 3; // pads to LANE
        let inputs: Vec<SplitComplex> = (0..b).map(|i| SplitComplex::random(n, i as u64)).collect();
        let refs: Vec<&SplitComplex> = inputs.iter().collect();
        let mut buf = BatchBuffer::new(n, b);
        // poison, then gather: pads must be re-zeroed
        buf.re.iter_mut().for_each(|v| *v = f32::NAN);
        buf.im.iter_mut().for_each(|v| *v = f32::NAN);
        buf.gather(&refs);
        for i in 0..n {
            for l in b..buf.lanes() {
                assert_eq!(buf.re[i * buf.lanes() + l], 0.0);
                assert_eq!(buf.im[i * buf.lanes() + l], 0.0);
            }
        }
    }

    #[test]
    fn layout_is_element_major() {
        let n = 8;
        let inputs: Vec<SplitComplex> = (0..2).map(|i| SplitComplex::random(n, i)).collect();
        let refs: Vec<&SplitComplex> = inputs.iter().collect();
        let mut buf = BatchBuffer::new(n, 2);
        buf.gather(&refs);
        for i in 0..n {
            assert_eq!(buf.re[i * buf.lanes()], inputs[0].re[i]);
            assert_eq!(buf.re[i * buf.lanes() + 1], inputs[1].re[i]);
        }
    }

    #[test]
    fn pool_recycles_allocations() {
        let mut pool = BatchBufferPool::new();
        let buf = pool.acquire(256, 16);
        let cap = buf.re.capacity();
        let ptr = buf.re.as_ptr();
        pool.release(buf);
        assert_eq!(pool.pooled(), 1);
        // Same shape: the exact allocation comes back, no realloc.
        let again = pool.acquire(256, 16);
        assert_eq!(again.re.as_ptr(), ptr);
        assert_eq!(again.re.capacity(), cap);
        pool.release(again);
        // Smaller shape still reuses (capacity is sufficient).
        let small = pool.acquire(64, 4);
        assert_eq!(small.re.capacity(), cap);
        assert_eq!(small.re.len(), 64 * LANE);
    }

    #[test]
    fn pool_counts_hits_and_misses() {
        let mut pool = BatchBufferPool::new();
        assert_eq!((pool.hits(), pool.misses()), (0, 0));
        let a = pool.acquire(64, 4); // cold: miss
        assert_eq!((pool.hits(), pool.misses()), (0, 1));
        pool.release(a);
        // Warm steady state: every acquire is a hit, misses stay flat —
        // the allocation-free-once-warm property as a counter invariant.
        for _ in 0..10 {
            let b = pool.acquire(64, 4);
            pool.release(b);
        }
        assert_eq!((pool.hits(), pool.misses()), (10, 1));
    }

    #[test]
    fn pool_bounds_retired_allocations() {
        let mut pool = BatchBufferPool::new();
        let bufs: Vec<BatchBuffer> = (0..8).map(|_| pool.acquire(64, 4)).collect();
        for b in bufs {
            pool.release(b);
        }
        assert!(pool.pooled() <= POOL_DEPTH);
    }
}
