//! Ground-truth reference transforms (f64, unoptimized).

use super::{log2i, SplitComplex};

/// O(n²) naive DFT in f64 — the ultimate correctness oracle.
pub fn dft_naive(input: &SplitComplex) -> SplitComplex {
    let n = input.len();
    let mut out = SplitComplex::zeros(n);
    for k in 0..n {
        let (mut sr, mut si) = (0f64, 0f64);
        for t in 0..n {
            let ang = -2.0 * std::f64::consts::PI * (k as f64) * (t as f64) / (n as f64);
            let (c, s) = (ang.cos(), ang.sin());
            let (xr, xi) = (input.re[t] as f64, input.im[t] as f64);
            sr += xr * c - xi * s;
            si += xr * s + xi * c;
        }
        out.re[k] = sr as f32;
        out.im[k] = si as f32;
    }
    out
}

/// One radix-2 DIF stage in f64 (reference semantics; matches ref.py).
pub fn radix2_stage_ref(v: &SplitComplex, stage: usize) -> SplitComplex {
    let n = v.len();
    let m = n >> stage;
    assert!(m >= 2, "stage {stage} invalid for n={n}");
    let half = m / 2;
    let mut out = SplitComplex::zeros(n);
    let mut base = 0;
    while base < n {
        for j in 0..half {
            let i0 = base + j;
            let i1 = base + j + half;
            let (tr, ti) = (v.re[i0] as f64, v.im[i0] as f64);
            let (br, bi) = (v.re[i1] as f64, v.im[i1] as f64);
            let ang = -2.0 * std::f64::consts::PI * (j as f64) / (m as f64);
            let (wr, wi) = (ang.cos(), ang.sin());
            out.re[i0] = (tr + br) as f32;
            out.im[i0] = (ti + bi) as f32;
            let (dr, di) = (tr - br, ti - bi);
            out.re[i1] = (dr * wr - di * wi) as f32;
            out.im[i1] = (dr * wi + di * wr) as f32;
        }
        base += m;
    }
    out
}

/// Apply `k` consecutive reference radix-2 stages starting at `stage`.
pub fn apply_radix2_stages_ref(v: &SplitComplex, stage: usize, k: usize) -> SplitComplex {
    let mut cur = v.clone();
    for r in 0..k {
        cur = radix2_stage_ref(&cur, stage + r);
    }
    cur
}

/// Full reference FFT: all radix-2 stages + bit-reversal.
pub fn fft_ref(v: &SplitComplex) -> SplitComplex {
    let l = log2i(v.len());
    let mut cur = apply_radix2_stages_ref(v, 0, l);
    super::bitrev::bit_reverse_permute(&mut cur.re, &mut cur.im);
    cur
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_ref_matches_naive_dft() {
        for n in [2usize, 8, 32, 128] {
            let input = SplitComplex::random(n, n as u64);
            let a = fft_ref(&input);
            let b = dft_naive(&input);
            let scale = b.max_abs().max(1.0);
            assert!(a.max_abs_diff(&b) / scale < 1e-4, "n={n}");
        }
    }

    #[test]
    fn dft_of_impulse_is_ones() {
        let n = 16;
        let mut input = SplitComplex::zeros(n);
        input.re[0] = 1.0;
        let out = dft_naive(&input);
        for k in 0..n {
            assert!((out.re[k] - 1.0).abs() < 1e-6);
            assert!(out.im[k].abs() < 1e-6);
        }
    }

    #[test]
    fn dft_of_complex_exponential_is_delta() {
        // x[t] = exp(2*pi*i*3t/16) -> X[k] = 16 * delta(k-3)
        let n = 16;
        let mut input = SplitComplex::zeros(n);
        for t in 0..n {
            let ang = 2.0 * std::f64::consts::PI * 3.0 * t as f64 / n as f64;
            input.re[t] = ang.cos() as f32;
            input.im[t] = ang.sin() as f32;
        }
        let out = dft_naive(&input);
        for k in 0..n {
            let expect = if k == 3 { n as f32 } else { 0.0 };
            assert!((out.re[k] - expect).abs() < 1e-4, "k={k}");
            assert!(out.im[k].abs() < 1e-4, "k={k}");
        }
    }

    #[test]
    fn parseval_energy_conserved() {
        let n = 64;
        let input = SplitComplex::random(n, 99);
        let out = fft_ref(&input);
        let ein: f64 = (0..n)
            .map(|i| (input.re[i] as f64).powi(2) + (input.im[i] as f64).powi(2))
            .sum();
        let eout: f64 = (0..n)
            .map(|i| (out.re[i] as f64).powi(2) + (out.im[i] as f64).powi(2))
            .sum();
        assert!((eout / (n as f64) / ein - 1.0).abs() < 1e-4);
    }
}
