//! Real-transform and inverse-transform boundary kernels.
//!
//! The c2c passes of every transform kind run the *same* forward kernels
//! ([`super::passes`] / [`super::fused`]); everything kind-specific lives
//! at the buffer boundary, in the passes of this module:
//!
//! * **Inverse (C2C-I)** uses the identity `IDFT = conj ∘ DFT ∘ conj / n`.
//!   Conjugating every twiddle table *and* every hardcoded kernel
//!   constant (the −j of radix-4, the (1−j)/√2 of radix-8, the fused
//!   blocks' internal rotations) would mean twelve hand-written kernel
//!   variants; pushing the conjugation to the buffer boundary is the
//!   same operator with two sign passes — [`negate`] on the way in, and
//!   the output conjugation **folded into the final scale pass**
//!   ([`conj_scale`]: `re *= s, im *= −s`), so the inverse pays exactly
//!   one extra sweep over `im`.
//! * **Real-input (R2C)** packs the n-point real signal into an
//!   n/2-point complex buffer ([`pack_even_odd`]), runs any forward c2c
//!   plan over the half, and then the split/unpack pass
//!   ([`unpack_r2c`]) — the RU step — reconstructs the full Hermitian
//!   spectrum via `X[k] = E[k] + W_n^k·O[k]`, `X[n−k] = conj(X[k])`.
//! * **Real-output (C2R)** inverts that factorization: the RU step
//!   ([`pack_c2r`]) merges the Hermitian spectrum into the half-size
//!   `Z` (with the inverse conjugation folded in, so the plain forward
//!   kernels follow), and [`interleave_scale`] unpacks the real signal
//!   with the 1/(n/2) scale folded into the final interleave pass.
//!
//! Every kernel has a lane-blocked `_b` variant executing the identical
//! per-lane arithmetic over a [`super::batch::BatchBuffer`] panel, so
//! batched outputs stay bit-identical to scalar runs for every kind.
//! The permutation passes (pack/interleave) are in-place-safe: reads of
//! iteration k land at indices no earlier iteration has written (the
//! loops are ordered to guarantee it; see each function's comment).

use std::sync::Arc;

use super::twiddle::{TwiddleCache, TwiddleVec};

/// The RU-pass twiddles for a c2c size of `h` (buffer size n = 2h):
/// W_n^k = exp(−2πik/n) for k in 0..=h/2, shared through the one
/// process-wide cache like every other pass's tables.
pub fn real_twiddles(cache: &mut TwiddleCache, h: usize) -> Arc<TwiddleVec> {
    cache.vector(2 * h, h / 2 + 1, 1)
}

/// Negate a buffer in place — the conjugation prologue of the inverse
/// kinds (applied to `im`). Works on scalar buffers and lane-blocked
/// panels alike (the operation is element-wise).
pub fn negate(v: &mut [f32]) {
    for x in v.iter_mut() {
        *x = -*x;
    }
}

/// Conjugate-and-scale epilogue of the inverse transform: `re *= s`,
/// `im *= −s` — the output conjugation and the 1/n scale folded into
/// one final pass. Element-wise, so panels reuse it unchanged.
pub fn conj_scale(re: &mut [f32], im: &mut [f32], s: f32) {
    for x in re.iter_mut() {
        *x *= s;
    }
    for x in im.iter_mut() {
        *x = -*x * s;
    }
}

/// R2C prologue: pack the real signal (read from `re`; `im` is input-
/// ignored) into the half-length complex buffer z[k] = x[2k] + i·x[2k+1]
/// occupying the first h slots. In-place safe ascending: iteration k
/// reads re[2k], re[2k+1] (indices ≥ 2k > any slot written so far) and
/// writes re[k], im[k].
pub fn pack_even_odd(re: &mut [f32], im: &mut [f32], h: usize) {
    debug_assert_eq!(re.len(), 2 * h);
    for k in 0..h {
        let a = re[2 * k];
        let b = re[2 * k + 1];
        re[k] = a;
        im[k] = b;
    }
}

/// Lane-blocked [`pack_even_odd`]: identical per-lane arithmetic over an
/// element-major panel (`lanes` floats per logical element).
pub fn pack_even_odd_b(re: &mut [f32], im: &mut [f32], h: usize, lanes: usize) {
    debug_assert_eq!(re.len(), 2 * h * lanes);
    for k in 0..h {
        for l in 0..lanes {
            let a = re[(2 * k) * lanes + l];
            let b = re[(2 * k + 1) * lanes + l];
            re[k * lanes + l] = a;
            im[k * lanes + l] = b;
        }
    }
}

/// The R2C split/unpack pass (the RU step): given Z = DFT_h of the
/// packed signal in the first h slots (natural order), produce the full
/// n = 2h-point spectrum in place:
///
/// ```text
/// E[k] = (Z[k] + conj(Z[h−k])) / 2      (even-sample spectrum)
/// O[k] = (Z[k] − conj(Z[h−k])) / 2i     (odd-sample spectrum)
/// X[k]     = E[k] + W_n^k · O[k]        k = 0..=h/2
/// X[h−k]   = conj(E[k] − W_n^k · O[k])
/// X[n−k]   = conj(X[k])                 (Hermitian mirror)
/// ```
///
/// Bins 0..=h are computed directly and the upper half is mirrored, so
/// the output equals the full complex DFT of the real signal. In-place
/// safe: each iteration reads Z[k], Z[h−k] into locals before writing
/// slots {k, h−k, h+k, n−k}, and later iterations never read a slot an
/// earlier one wrote.
pub fn unpack_r2c(re: &mut [f32], im: &mut [f32], tw: &TwiddleVec) {
    let n = re.len();
    let h = n / 2;
    debug_assert!(h >= 2 && tw.len() >= h / 2 + 1);
    // k = 0: X[0] and X[h] are real (Z[h] ≡ Z[0]).
    let (ar, ai) = (re[0], im[0]);
    re[0] = ar + ai;
    im[0] = 0.0;
    re[h] = ar - ai;
    im[h] = 0.0;
    for k in 1..=(h / 2) {
        let j = h - k;
        let (ar, ai) = (re[k], im[k]);
        let (br, bi) = (re[j], im[j]);
        let er = 0.5 * (ar + br);
        let ei = 0.5 * (ai - bi);
        let or_ = 0.5 * (ai + bi);
        let oi = -0.5 * (ar - br);
        let (wr, wi) = (tw.re[k], tw.im[k]);
        let pr = wr * or_ - wi * oi;
        let pi = wr * oi + wi * or_;
        re[k] = er + pr;
        im[k] = ei + pi;
        re[j] = er - pr;
        im[j] = -ei + pi;
        // Hermitian mirrors: X[n−k] = conj(X[k]), X[h+k] = conj(X[h−k]).
        re[n - k] = er + pr;
        im[n - k] = -(ei + pi);
        re[h + k] = er - pr;
        im[h + k] = -(-ei + pi);
    }
}

/// Lane-blocked [`unpack_r2c`]: identical per-lane arithmetic.
pub fn unpack_r2c_b(re: &mut [f32], im: &mut [f32], tw: &TwiddleVec, lanes: usize) {
    let n = re.len() / lanes;
    let h = n / 2;
    debug_assert!(h >= 2 && tw.len() >= h / 2 + 1);
    for l in 0..lanes {
        let (ar, ai) = (re[l], im[l]);
        re[l] = ar + ai;
        im[l] = 0.0;
        re[h * lanes + l] = ar - ai;
        im[h * lanes + l] = 0.0;
    }
    for k in 1..=(h / 2) {
        let j = h - k;
        let (wr, wi) = (tw.re[k], tw.im[k]);
        for l in 0..lanes {
            let (ar, ai) = (re[k * lanes + l], im[k * lanes + l]);
            let (br, bi) = (re[j * lanes + l], im[j * lanes + l]);
            let er = 0.5 * (ar + br);
            let ei = 0.5 * (ai - bi);
            let or_ = 0.5 * (ai + bi);
            let oi = -0.5 * (ar - br);
            let pr = wr * or_ - wi * oi;
            let pi = wr * oi + wi * or_;
            re[k * lanes + l] = er + pr;
            im[k * lanes + l] = ei + pi;
            re[j * lanes + l] = er - pr;
            im[j * lanes + l] = -ei + pi;
            re[(n - k) * lanes + l] = er + pr;
            im[(n - k) * lanes + l] = -(ei + pi);
            re[(h + k) * lanes + l] = er - pr;
            im[(h + k) * lanes + l] = -(-ei + pi);
        }
    }
}

/// The C2R spectrum-merge pass (the RU step of the real-output inverse):
/// given a Hermitian spectrum X in the full buffer (bins 0..=h read, the
/// upper half ignored), pack **conj(Z[k])** into the first h slots,
/// where Z is the half-size spectrum whose inverse DFT interleaves the
/// real output:
///
/// ```text
/// E[k] = (X[k] + conj(X[h−k])) / 2
/// O[k] = conj(W_n^k) · (X[k] − conj(X[h−k])) / 2
/// Z[k] = E[k] + i·O[k]
/// ```
///
/// The inverse conjugation (`IDFT = conj ∘ DFT ∘ conj / h`) is folded
/// into this pass — it stores conj(Z) — so the plain *forward* c2c
/// kernels follow, and [`interleave_scale`] finishes the conj + 1/h.
/// In-place safe: iteration k reads slots {k, h−k} and writes the same
/// two (k = 0 reads slot h but writes only slot 0).
pub fn pack_c2r(re: &mut [f32], im: &mut [f32], tw: &TwiddleVec) {
    let n = re.len();
    let h = n / 2;
    debug_assert!(h >= 2 && tw.len() >= h / 2 + 1);
    for k in 0..=(h / 2) {
        let j = h - k;
        let (ar, ai) = (re[k], im[k]);
        let (br, bi) = (re[j], im[j]);
        let er = 0.5 * (ar + br);
        let ei = 0.5 * (ai - bi);
        let dr = 0.5 * (ar - br);
        let di = 0.5 * (ai + bi);
        let (wr, wi) = (tw.re[k], tw.im[k]);
        // O = conj(W^k) · D
        let or_ = wr * dr + wi * di;
        let oi = wr * di - wi * dr;
        // Z[k] = (Er − Oi, Ei + Or), stored conjugated.
        re[k] = er - oi;
        im[k] = -(ei + or_);
        if k != 0 && j != k {
            // Z[h−k] = (Er + Oi, −Ei + Or), conjugated.
            re[j] = er + oi;
            im[j] = -(-ei + or_);
        }
    }
}

/// Lane-blocked [`pack_c2r`]: identical per-lane arithmetic.
pub fn pack_c2r_b(re: &mut [f32], im: &mut [f32], tw: &TwiddleVec, lanes: usize) {
    let n = re.len() / lanes;
    let h = n / 2;
    debug_assert!(h >= 2 && tw.len() >= h / 2 + 1);
    for k in 0..=(h / 2) {
        let j = h - k;
        let (wr, wi) = (tw.re[k], tw.im[k]);
        for l in 0..lanes {
            let (ar, ai) = (re[k * lanes + l], im[k * lanes + l]);
            let (br, bi) = (re[j * lanes + l], im[j * lanes + l]);
            let er = 0.5 * (ar + br);
            let ei = 0.5 * (ai - bi);
            let dr = 0.5 * (ar - br);
            let di = 0.5 * (ai + bi);
            let or_ = wr * dr + wi * di;
            let oi = wr * di - wi * dr;
            re[k * lanes + l] = er - oi;
            im[k * lanes + l] = -(ei + or_);
            if k != 0 && j != k {
                re[j * lanes + l] = er + oi;
                im[j * lanes + l] = -(-ei + or_);
            }
        }
    }
}

/// C2R epilogue: the first h slots hold conj(z[k]) (the forward kernels
/// ran over the conjugated buffer); interleave the real output
/// `x[2k] = s·re[k]`, `x[2k+1] = −s·im[k]` — the output conjugation and
/// the 1/h scale folded into the final interleave pass — and zero `im`.
/// In-place safe descending: iteration k reads slots k (indices prior
/// iterations' writes at ≥ 2k+2 never touched) and writes 2k, 2k+1.
pub fn interleave_scale(re: &mut [f32], im: &mut [f32], s: f32) {
    let h = re.len() / 2;
    for k in (0..h).rev() {
        let a = re[k] * s;
        let b = -im[k] * s;
        re[2 * k] = a;
        re[2 * k + 1] = b;
        im[2 * k] = 0.0;
        im[2 * k + 1] = 0.0;
    }
}

/// Lane-blocked [`interleave_scale`]: identical per-lane arithmetic.
pub fn interleave_scale_b(re: &mut [f32], im: &mut [f32], s: f32, lanes: usize) {
    let h = re.len() / lanes / 2;
    for k in (0..h).rev() {
        for l in 0..lanes {
            let a = re[k * lanes + l] * s;
            let b = -im[k * lanes + l] * s;
            re[(2 * k) * lanes + l] = a;
            re[(2 * k + 1) * lanes + l] = b;
            im[(2 * k) * lanes + l] = 0.0;
            im[(2 * k + 1) * lanes + l] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::reference::{dft_naive, fft_ref};
    use crate::fft::{bitrev::bit_reverse_permute, SplitComplex};

    /// Reference R2C via the c2c oracle: DFT of the real signal.
    fn dft_of_real(x: &[f32]) -> SplitComplex {
        let v = SplitComplex::from_parts(x.to_vec(), vec![0.0; x.len()]);
        dft_naive(&v)
    }

    /// Run the scalar R2C path by hand: pack → fft_ref on the half →
    /// unpack; compares against the full DFT of the real signal.
    #[test]
    fn pack_fft_unpack_matches_full_dft() {
        for n in [4usize, 8, 32, 128] {
            let h = n / 2;
            let signal: Vec<f32> = (0..n).map(|i| ((i * 7 + 3) % 11) as f32 - 5.0).collect();
            let mut re = signal.clone();
            let mut im = vec![0.0f32; n];
            pack_even_odd(&mut re, &mut im, h);
            let z = SplitComplex::from_parts(re[..h].to_vec(), im[..h].to_vec());
            let zf = fft_ref(&z);
            re[..h].copy_from_slice(&zf.re);
            im[..h].copy_from_slice(&zf.im);
            let mut cache = crate::fft::TwiddleCache::new();
            let tw = real_twiddles(&mut cache, h);
            unpack_r2c(&mut re, &mut im, &tw);
            let want = dft_of_real(&signal);
            let got = SplitComplex::from_parts(re, im);
            let scale = want.max_abs().max(1.0);
            assert!(got.max_abs_diff(&want) / scale < 1e-4, "n={n}");
        }
    }

    /// pack_c2r is the exact inverse of unpack_r2c's boundary algebra:
    /// unpack(Z) then pack recovers conj(Z) on the first h slots.
    #[test]
    fn c2r_pack_inverts_r2c_unpack() {
        let n = 64;
        let h = n / 2;
        // A spectrum that actually came from a real signal.
        let signal: Vec<f32> = (0..n).map(|i| (i as f32 * 0.7).sin()).collect();
        let mut re = signal.clone();
        let mut im = vec![0.0f32; n];
        pack_even_odd(&mut re, &mut im, h);
        let z = SplitComplex::from_parts(re[..h].to_vec(), im[..h].to_vec());
        let zf = fft_ref(&z);
        re[..h].copy_from_slice(&zf.re);
        im[..h].copy_from_slice(&zf.im);
        let mut cache = crate::fft::TwiddleCache::new();
        let tw = real_twiddles(&mut cache, h);
        unpack_r2c(&mut re, &mut im, &tw);
        pack_c2r(&mut re, &mut im, &tw);
        for k in 0..h {
            assert!((re[k] - zf.re[k]).abs() < 1e-4, "re[{k}]");
            assert!((im[k] + zf.im[k]).abs() < 1e-4, "im[{k}] (conjugated)");
        }
    }

    #[test]
    fn batched_boundary_kernels_are_bit_identical_to_scalar() {
        let n = 32;
        let h = n / 2;
        let lanes = 4;
        let mut cache = crate::fft::TwiddleCache::new();
        let tw = real_twiddles(&mut cache, h);
        let scalars: Vec<SplitComplex> =
            (0..lanes as u64).map(|i| SplitComplex::random(n, 100 + i)).collect();
        // gather into a panel by hand
        let mut pre = vec![0.0f32; n * lanes];
        let mut pim = vec![0.0f32; n * lanes];
        for (l, s) in scalars.iter().enumerate() {
            for i in 0..n {
                pre[i * lanes + l] = s.re[i];
                pim[i * lanes + l] = s.im[i];
            }
        }
        for which in 0..5 {
            let mut panel_re = pre.clone();
            let mut panel_im = pim.clone();
            let mut wants: Vec<SplitComplex> = scalars.clone();
            for w in wants.iter_mut() {
                match which {
                    0 => pack_even_odd(&mut w.re, &mut w.im, h),
                    1 => unpack_r2c(&mut w.re, &mut w.im, &tw),
                    2 => pack_c2r(&mut w.re, &mut w.im, &tw),
                    3 => interleave_scale(&mut w.re, &mut w.im, 0.125),
                    _ => conj_scale(&mut w.re, &mut w.im, 0.25),
                }
            }
            match which {
                0 => pack_even_odd_b(&mut panel_re, &mut panel_im, h, lanes),
                1 => unpack_r2c_b(&mut panel_re, &mut panel_im, &tw, lanes),
                2 => pack_c2r_b(&mut panel_re, &mut panel_im, &tw, lanes),
                3 => interleave_scale_b(&mut panel_re, &mut panel_im, 0.125, lanes),
                _ => conj_scale(&mut panel_re, &mut panel_im, 0.25),
            }
            for (l, want) in wants.iter().enumerate() {
                for i in 0..n {
                    assert_eq!(panel_re[i * lanes + l], want.re[i], "kernel {which} re[{i}] lane {l}");
                    assert_eq!(panel_im[i * lanes + l], want.im[i], "kernel {which} im[{i}] lane {l}");
                }
            }
        }
    }

    #[test]
    fn full_inverse_identity_via_boundary_conjugation() {
        // conj-in → forward reference FFT → conj-and-scale-out is the
        // exact inverse of the forward reference FFT.
        let n = 64;
        let input = SplitComplex::random(n, 9);
        let spectrum = fft_ref(&input);
        let mut re = spectrum.re.clone();
        let mut im = spectrum.im.clone();
        negate(&mut im);
        let y = fft_ref(&SplitComplex::from_parts(re.clone(), im.clone()));
        re.copy_from_slice(&y.re);
        im.copy_from_slice(&y.im);
        conj_scale(&mut re, &mut im, 1.0 / n as f32);
        let got = SplitComplex::from_parts(re, im);
        let scale = input.max_abs().max(1.0);
        assert!(got.max_abs_diff(&input) / scale < 1e-4);
    }

    #[test]
    fn unpack_handles_min_size() {
        // n = 4 (h = 2): the smallest real transform; loop degenerates
        // to the k = 0 specials plus the self-paired k = h/2 = 1.
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let mut re = x.to_vec();
        let mut im = vec![0.0f32; 4];
        pack_even_odd(&mut re, &mut im, 2);
        // DFT_2 of z = [(1,2), (3,4)]: Z = [(4,6), (-2,-2)]
        let (z0r, z0i) = (re[0] + re[1], im[0] + im[1]);
        let (z1r, z1i) = (re[0] - re[1], im[0] - im[1]);
        re[0] = z0r;
        im[0] = z0i;
        re[1] = z1r;
        im[1] = z1i;
        let mut cache = crate::fft::TwiddleCache::new();
        let tw = real_twiddles(&mut cache, 2);
        unpack_r2c(&mut re, &mut im, &tw);
        let want = dft_of_real(&x);
        let got = SplitComplex::from_parts(re, im);
        assert!(got.max_abs_diff(&want) < 1e-4);
        let _ = bit_reverse_permute; // (h = 2 bitrev is the identity)
    }
}
