//! Sharded serving: a key-affine router over per-shard worker pools.
//!
//! The single-process [`FftService`] coalesces per worker, so a held
//! singleton can never pair with same-`(kind, n)` traffic another
//! worker pulls. Sharding fixes that *by construction* instead of by
//! work stealing: the [`ShardRouter`] hashes the `(kind, n)` grouping
//! key — the exact key the coalescer groups on — so every request for
//! one key lands on one shard, where one coalesce tier sees all of that
//! key's traffic. Held singletons and under-filled groups meet their
//! partners regardless of which client or thread submitted them,
//! because "which shard accepted them" is a pure function of the key
//! (DESIGN.md §shard explains why affinity is keyed rather than
//! stolen).
//!
//! All shards share one [`Autotuner`] and one [`PlanCache`]: planning
//! knowledge is global even though execution is sharded — FFTW's wisdom
//! lesson applied to a serving topology. Admission control stays
//! per-shard (each shard has its own bounded queue), and every
//! rejection is typed ([`Rejected`]) and counted, so the per-shard
//! metrics decompose overload cleanly.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::autotune::Autotuner;
use crate::fft::{Executor, SplitComplex};
use crate::kind::TransformKind;
use crate::obs::Observer;

use super::metrics::{Metrics, MetricsSnapshot};
use super::plancache::PlanCache;
use super::service::{FftService, Rejected, ServiceConfig};

/// Routes submissions to shards by `(kind, n)` affinity.
///
/// The hash is FNV-1a over the kind index and size — stable across
/// processes and runs, so a deployment's key→shard map is reproducible
/// (the deterministic harness and the ops runbook both rely on that).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardRouter {
    shards: usize,
}

impl ShardRouter {
    pub fn new(shards: usize) -> ShardRouter {
        ShardRouter { shards: shards.max(1) }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The shard serving `(kind, n)`. Pure and total: the same key
    /// always routes to the same shard, and every key routes somewhere.
    pub fn route(&self, kind: TransformKind, n: usize) -> usize {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        for word in [kind.index() as u64, n as u64] {
            for byte in word.to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
        }
        (h % self.shards as u64) as usize
    }
}

/// A fleet of [`FftService`] shards behind one key-affine router,
/// sharing one [`PlanCache`] and (when autotuning) one [`Autotuner`].
///
/// `shards == 1` is exactly one [`FftService`] behind a router that
/// always answers 0 — behaviorally identical to the single-process
/// service.
pub struct ShardedService {
    shards: Vec<FftService>,
    router: ShardRouter,
    /// The shared tuner, stopped here — once — after every shard drains.
    tuner: Option<Arc<Autotuner>>,
    cache: Arc<PlanCache>,
}

impl ShardedService {
    /// Start `shards` identical shards from one config. Each shard gets
    /// its own worker pool and bounded queue (`config.workers` /
    /// `config.queue_depth` apply *per shard*); `config.autotune` (when
    /// set) is hoisted into a single shared tuner publishing into the
    /// shared plan cache.
    pub fn start(config: ServiceConfig, shards: usize) -> Result<ShardedService> {
        let shards = shards.max(1);
        let cache = Arc::new(PlanCache::new());
        let tuner = match &config.autotune {
            None => None,
            Some(at) => {
                if !matches!(config.backend, super::service::Backend::Native) {
                    bail!("autotune requires the native backend");
                }
                let initial = config
                    .plans
                    .iter()
                    .find(|(n, _)| *n == at.prior.n)
                    .map(|(_, p)| p.clone())
                    .ok_or_else(|| {
                        anyhow!("autotune prior is for n={}, which has no configured plan", at.prior.n)
                    })?;
                let mut at = at.clone();
                if at.observer.is_none() {
                    at.observer = config.observer.clone();
                }
                if at.cache.is_none() {
                    at.cache = Some(cache.clone());
                }
                at.exec_isa = Executor::new().isa();
                Some(Arc::new(Autotuner::start(at, initial)))
            }
        };
        let mut shard_config = config;
        // The tuner is shared; shards must not each try to own one.
        shard_config.autotune = None;
        let mut fleet = Vec::with_capacity(shards);
        for _ in 0..shards {
            fleet.push(FftService::start_with(shard_config.clone(), tuner.clone())?);
        }
        Ok(ShardedService { shards: fleet, router: ShardRouter::new(shards), tuner, cache })
    }

    pub fn router(&self) -> ShardRouter {
        self.router
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shared plan cache the tuner publishes hot swaps into.
    pub fn plan_cache(&self) -> Arc<PlanCache> {
        self.cache.clone()
    }

    /// Per-shard live metrics handles (index = shard id).
    pub fn shard_metrics(&self) -> Vec<Arc<Metrics>> {
        self.shards.iter().map(|s| s.metrics()).collect()
    }

    /// Per-shard snapshots (index = shard id).
    pub fn snapshots(&self) -> Vec<MetricsSnapshot> {
        self.shards.iter().map(|s| s.metrics().snapshot()).collect()
    }

    /// Fleet-wide aggregate of the per-shard snapshots.
    pub fn aggregate(&self) -> MetricsSnapshot {
        MetricsSnapshot::aggregate(&self.snapshots())
    }

    /// The observer of shard 0 (all shards share the config's observer).
    pub fn observer(&self) -> Option<&Arc<Observer>> {
        self.shards.first().and_then(|s| s.observer())
    }

    /// Autotuning status of the shared tuner, when configured.
    pub fn autotune_status(&self) -> Option<crate::autotune::AutotuneStatus> {
        self.tuner.as_ref().map(|t| t.status())
    }

    /// Typed-rejection submit: route by the `(kind, n)` affinity key,
    /// then admit on that shard's bounded queue.
    pub fn try_submit_kind(
        &self,
        input: SplitComplex,
        kind: TransformKind,
    ) -> std::result::Result<std::sync::mpsc::Receiver<Result<SplitComplex>>, Rejected> {
        let shard = self.router.route(kind, input.len());
        self.shards[shard].try_submit_kind(input, kind)
    }

    /// Stringly submit for parity with [`FftService::submit_kind`].
    pub fn submit_kind(
        &self,
        input: SplitComplex,
        kind: TransformKind,
    ) -> Result<std::sync::mpsc::Receiver<Result<SplitComplex>>> {
        self.try_submit_kind(input, kind).map_err(anyhow::Error::from)
    }

    /// Convenience: submit a `kind` transform and wait.
    pub fn transform_kind(&self, input: SplitComplex, kind: TransformKind) -> Result<SplitComplex> {
        self.submit_kind(input, kind)?
            .recv()
            .map_err(|_| anyhow!("worker dropped the request"))?
    }

    /// Fence every shard *before* draining any: after this returns, no
    /// shard accepts new work, so a client can never land a request on
    /// shard B while shard A is already reporting itself drained.
    pub fn begin_shutdown(&self) {
        for s in &self.shards {
            s.begin_shutdown();
        }
    }

    /// Fence all shards, drain and join each, then stop the shared
    /// tuner (after the last sample can possibly arrive). Returns the
    /// per-shard snapshots (index = shard id).
    pub fn shutdown(mut self) -> Vec<MetricsSnapshot> {
        self.begin_shutdown();
        let snaps: Vec<MetricsSnapshot> = self.shards.drain(..).map(|s| s.shutdown()).collect();
        if let Some(t) = &self.tuner {
            t.stop();
        }
        snaps
    }
}

impl Drop for ShardedService {
    fn drop(&mut self) {
        for s in &self.shards {
            s.begin_shutdown();
        }
        // Each FftService's Drop drains and joins; a shared tuner is
        // not stopped by shard drops (owns_tuner = false), so stop it
        // here after the fleet is gone.
        self.shards.drain(..).for_each(drop);
        if let Some(t) = &self.tuner {
            t.stop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::service::Backend;
    use crate::fft::reference::fft_ref;
    use crate::plan::Plan;

    fn config(n: usize, plan: &str) -> ServiceConfig {
        ServiceConfig {
            plans: vec![(n, Plan::parse(plan).unwrap())],
            backend: Backend::Native,
            batch: BatchPolicy { max_batch: 8, max_wait: std::time::Duration::from_micros(100) },
            coalesce: Default::default(),
            workers: 1,
            queue_depth: 64,
            autotune: None,
            shed_deadline: None,
            observer: None,
            exec_mode: Default::default(),
            max_resident_n: None,
        }
    }

    #[test]
    fn router_is_deterministic_total_and_key_affine() {
        let r = ShardRouter::new(4);
        for kind in crate::kind::ALL_KINDS {
            for n in [64usize, 128, 256, 512, 1024, 2048] {
                let shard = r.route(kind, n);
                assert!(shard < 4);
                // same key → same shard, always
                assert_eq!(shard, r.route(kind, n));
                assert_eq!(shard, ShardRouter::new(4).route(kind, n));
            }
        }
        // one shard: everything routes to 0 (and 0 shards clamps to 1)
        let one = ShardRouter::new(1);
        assert_eq!(one.route(TransformKind::Forward, 256), 0);
        assert_eq!(ShardRouter::new(0).shards(), 1);
        // keys actually spread: not every key on one shard
        let shards: std::collections::HashSet<usize> = crate::kind::ALL_KINDS
            .into_iter()
            .flat_map(|k| [64usize, 128, 256, 512, 1024].map(|n| r.route(k, n)))
            .collect();
        assert!(shards.len() > 1, "router collapsed every key onto one shard");
    }

    #[test]
    fn sharded_service_serves_every_kind_correctly() {
        let n = 128;
        let svc = ShardedService::start(config(n, "R4,R2,F16"), 3).unwrap();
        let input = SplitComplex::random(n, 5);
        let fwd = svc.transform_kind(input.clone(), TransformKind::Forward).unwrap();
        let want = fft_ref(&input);
        assert!(fwd.max_abs_diff(&want) / want.max_abs().max(1.0) < 1e-4);
        let back = svc.transform_kind(fwd, TransformKind::Inverse).unwrap();
        assert!(back.max_abs_diff(&input) / input.max_abs().max(1.0) < 1e-4);
        let mut real = SplitComplex::random(2 * n, 6);
        real.im.iter_mut().for_each(|v| *v = 0.0);
        let spectrum = svc.transform_kind(real.clone(), TransformKind::RealForward).unwrap();
        let want_r = fft_ref(&real);
        assert!(spectrum.max_abs_diff(&want_r) / want_r.max_abs().max(1.0) < 1e-4);
        // each key's completions landed on exactly the routed shard
        let router = svc.router();
        let snaps = svc.shutdown();
        let total = MetricsSnapshot::aggregate(&snaps);
        assert_eq!(total.completed, 3);
        assert_eq!(total.failed, 0);
        for (kind, n) in [
            (TransformKind::Forward, n),
            (TransformKind::Inverse, n),
            (TransformKind::RealForward, 2 * n),
        ] {
            let shard = router.route(kind, n);
            assert!(
                snaps[shard].completed_by_kind[kind.index()] >= 1,
                "{kind} n={n} did not complete on its routed shard {shard}"
            );
        }
    }

    #[test]
    fn begin_shutdown_fences_every_shard() {
        let svc = ShardedService::start(config(256, "R4,R4,R2,F8"), 2).unwrap();
        let rx = svc.try_submit_kind(SplitComplex::random(256, 1), TransformKind::Forward);
        assert!(rx.is_ok());
        svc.begin_shutdown();
        // both c2c kinds route (possibly) to different shards; all fenced
        for kind in [TransformKind::Forward, TransformKind::Inverse] {
            let err = svc.try_submit_kind(SplitComplex::random(256, 2), kind).unwrap_err();
            assert_eq!(err, Rejected::ShuttingDown);
        }
        let snaps = svc.shutdown();
        let total = MetricsSnapshot::aggregate(&snaps);
        assert_eq!(total.completed, 1);
        assert_eq!(total.rejected_stopped, 2);
        assert!(rx.unwrap().recv().unwrap().is_ok());
    }

    #[test]
    fn shared_tuner_serves_all_shards_and_stops_once() {
        let n = 256;
        let prior = crate::cost::Wisdom::harvest(&mut crate::cost::SimCost::m1(n), "m1");
        let mut at = crate::autotune::AutotuneConfig::new(prior);
        at.sample_period = 1;
        let mut cfg = config(n, "R4,R4,R2,F8");
        cfg.autotune = Some(at);
        let svc = ShardedService::start(cfg, 2).unwrap();
        assert!(svc.autotune_status().is_some());
        for i in 0..8u64 {
            let input = SplitComplex::random(n, i);
            let got = svc.transform_kind(input.clone(), TransformKind::Forward).unwrap();
            let want = fft_ref(&input);
            assert!(got.max_abs_diff(&want) / want.max_abs().max(1.0) < 1e-4);
        }
        let snaps = svc.shutdown();
        assert_eq!(MetricsSnapshot::aggregate(&snaps).completed, 8);
    }
}
