//! Plan cache: memoize planner results per (n, strategy, cost-source).
//!
//! Planning costs measurements (or simulator sweeps); serving must not
//! re-plan per request. Keys carry the cost-source label so plans from
//! different machines/providers don't cross-contaminate.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::plan::Plan;

/// Cache key: FFT size + strategy name + cost-source label.
pub type PlanKey = (usize, String, String);

/// Thread-safe plan cache.
#[derive(Debug, Default)]
pub struct PlanCache {
    map: Mutex<HashMap<PlanKey, Plan>>,
    hits: std::sync::atomic::AtomicU64,
    misses: std::sync::atomic::AtomicU64,
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or compute the plan for a key.
    pub fn get_or_plan(
        &self,
        n: usize,
        strategy: &str,
        source: &str,
        compute: impl FnOnce() -> Plan,
    ) -> Plan {
        let key = (n, strategy.to_string(), source.to_string());
        if let Some(p) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            return p.clone();
        }
        self.misses.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // Compute outside the lock (planning may be slow).
        let plan = compute();
        self.map.lock().unwrap().insert(key, plan.clone());
        plan
    }

    /// Insert a pre-computed plan.
    pub fn insert(&self, n: usize, strategy: &str, source: &str, plan: Plan) {
        self.map
            .lock()
            .unwrap()
            .insert((n, strategy.to_string(), source.to_string()), plan);
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(std::sync::atomic::Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::Plan;

    #[test]
    fn caches_by_key() {
        let cache = PlanCache::new();
        let mut calls = 0;
        let p1 = cache.get_or_plan(1024, "ca", "m1", || {
            calls += 1;
            Plan::parse("R4,R2,R4,R4,F8").unwrap()
        });
        let p2 = cache.get_or_plan(1024, "ca", "m1", || {
            calls += 1;
            unreachable!()
        });
        assert_eq!(p1, p2);
        assert_eq!(calls, 1);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let cache = PlanCache::new();
        cache.insert(1024, "ca", "m1", Plan::parse("R4,R2,R4,R4,F8").unwrap());
        cache.insert(1024, "ca", "haswell", Plan::parse("R4,R8,R8,R4").unwrap());
        cache.insert(256, "ca", "m1", Plan::parse("R4,R4,R2,F8").unwrap());
        assert_eq!(cache.len(), 3);
        let p = cache.get_or_plan(1024, "ca", "haswell", || unreachable!());
        assert_eq!(p, Plan::parse("R4,R8,R8,R4").unwrap());
    }

    #[test]
    fn shared_across_threads() {
        use std::sync::Arc;
        let cache = Arc::new(PlanCache::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = cache.clone();
            handles.push(std::thread::spawn(move || {
                c.get_or_plan(64, "cf", "m1", || Plan::parse("R2,R2,R2,R2,R2,R2").unwrap())
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap().total_stages(), 6);
        }
        assert_eq!(cache.len(), 1);
    }
}
