//! Plan cache: memoize planner results per (n, strategy, cost-source).
//!
//! Planning costs measurements (or simulator sweeps); serving must not
//! re-plan per request. Keys carry the cost-source label so plans from
//! different machines/providers don't cross-contaminate.
//!
//! Values are [`ExecPlan`]s, not bare stage lists: the planner's output
//! for a size is an *execution decision* — flat (one in-cache pass) or
//! blocked (four-step around the cache boundary) — and a hot swap may
//! change the mode, not just the arrangement. Callers that only deal in
//! flat plans wrap with [`ExecPlan::Flat`] on the way in and match (or
//! [`ExecPlan::as_flat`]) on the way out.
//!
//! Entries are **versioned**: the online autotuner publishes re-planned
//! arrangements through [`PlanCache::swap`], which atomically replaces
//! the entry and bumps its version. Readers holding a previously fetched
//! `ExecPlan` are unaffected (plans are owned clones); the version lets
//! observers detect publication without comparing plan contents.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::plan::ExecPlan;

/// Cache key: FFT size + strategy name + cost-source label.
pub type PlanKey = (usize, String, String);

/// Thread-safe, versioned plan cache.
#[derive(Debug, Default)]
pub struct PlanCache {
    map: Mutex<HashMap<PlanKey, (ExecPlan, u64)>>,
    hits: std::sync::atomic::AtomicU64,
    misses: std::sync::atomic::AtomicU64,
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or compute the execution decision for a key.
    pub fn get_or_plan(
        &self,
        n: usize,
        strategy: &str,
        source: &str,
        compute: impl FnOnce() -> ExecPlan,
    ) -> ExecPlan {
        let key = (n, strategy.to_string(), source.to_string());
        if let Some((p, _)) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            return p.clone();
        }
        self.misses.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // Compute outside the lock (planning may be slow). If another
        // writer (a concurrent planner, or the autotuner's swap) published
        // an entry meanwhile, keep theirs — overwriting would clobber a
        // hot-swapped plan with a stale one and bump the version for a
        // publication that never happened.
        let plan = compute();
        let mut map = self.map.lock().unwrap();
        let (cached, _) = map.entry(key).or_insert_with(|| (plan, 1));
        cached.clone()
    }

    /// Insert a pre-computed decision (bumps the version when overwriting).
    pub fn insert(&self, n: usize, strategy: &str, source: &str, plan: ExecPlan) {
        self.swap(n, strategy, source, plan);
    }

    /// Atomically publish `plan` for a key; returns the new version
    /// (1 when the key is fresh). This is the autotuner's hot-swap entry
    /// point: the replacement happens under one lock acquisition, so a
    /// concurrent reader sees either the old or the new plan, never a
    /// torn mix. A swap may flip the execution mode (flat ↔ blocked) as
    /// well as the arrangement — readers recompile from whatever variant
    /// they fetch.
    pub fn swap(&self, n: usize, strategy: &str, source: &str, plan: ExecPlan) -> u64 {
        let key = (n, strategy.to_string(), source.to_string());
        let mut map = self.map.lock().unwrap();
        let version = map.get(&key).map(|(_, v)| *v).unwrap_or(0) + 1;
        map.insert(key, (plan, version));
        version
    }

    /// Current decision for a key, if cached.
    pub fn get(&self, n: usize, strategy: &str, source: &str) -> Option<ExecPlan> {
        let key = (n, strategy.to_string(), source.to_string());
        self.map.lock().unwrap().get(&key).map(|(p, _)| p.clone())
    }

    /// Current version for a key (None when absent, 1 = first insert).
    pub fn version(&self, n: usize, strategy: &str, source: &str) -> Option<u64> {
        let key = (n, strategy.to_string(), source.to_string());
        self.map.lock().unwrap().get(&key).map(|(_, v)| *v)
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(std::sync::atomic::Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::Plan;

    fn flat(s: &str) -> ExecPlan {
        ExecPlan::Flat(Plan::parse(s).unwrap())
    }

    #[test]
    fn caches_by_key() {
        let cache = PlanCache::new();
        let mut calls = 0;
        let p1 = cache.get_or_plan(1024, "ca", "m1", || {
            calls += 1;
            flat("R4,R2,R4,R4,F8")
        });
        let p2 = cache.get_or_plan(1024, "ca", "m1", || {
            calls += 1;
            unreachable!()
        });
        assert_eq!(p1, p2);
        assert_eq!(calls, 1);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let cache = PlanCache::new();
        cache.insert(1024, "ca", "m1", flat("R4,R2,R4,R4,F8"));
        cache.insert(1024, "ca", "haswell", flat("R4,R8,R8,R4"));
        cache.insert(256, "ca", "m1", flat("R4,R4,R2,F8"));
        assert_eq!(cache.len(), 3);
        let p = cache.get_or_plan(1024, "ca", "haswell", || unreachable!());
        assert_eq!(p, flat("R4,R8,R8,R4"));
    }

    #[test]
    fn shared_across_threads() {
        use std::sync::Arc;
        let cache = Arc::new(PlanCache::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = cache.clone();
            handles.push(std::thread::spawn(move || {
                c.get_or_plan(64, "cf", "m1", || flat("R2,R2,R2,R2,R2,R2"))
            }));
        }
        for h in handles {
            let plan = h.join().unwrap();
            assert_eq!(plan.as_flat().unwrap().total_stages(), 6);
        }
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn swap_bumps_versions_and_replaces_the_plan() {
        let cache = PlanCache::new();
        assert_eq!(cache.version(1024, "autotune", "m1"), None);
        let v1 = cache.swap(1024, "autotune", "m1", flat("R4,R2,R4,R4,F8"));
        assert_eq!(v1, 1);
        let v2 = cache.swap(1024, "autotune", "m1", flat("R4,R4,R4,F16"));
        assert_eq!(v2, 2);
        assert_eq!(cache.version(1024, "autotune", "m1"), Some(2));
        assert_eq!(cache.get(1024, "autotune", "m1"), Some(flat("R4,R4,R4,F16")));
        // unrelated keys keep their own version streams
        cache.insert(256, "ca", "m1", flat("R4,R4,R2,F8"));
        assert_eq!(cache.version(256, "ca", "m1"), Some(1));
    }

    #[test]
    fn swapped_key_still_hits_through_get_or_plan() {
        let cache = PlanCache::new();
        cache.swap(1024, "ca", "m1", flat("R4,R2,R4,R4,F8"));
        let p = cache.get_or_plan(1024, "ca", "m1", || unreachable!());
        assert_eq!(p, flat("R4,R2,R4,R4,F8"));
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn blocked_decisions_cache_and_swap_like_flat_ones() {
        // A hot swap may change the execution mode, not just the stage
        // order: flat → blocked must round-trip through the same key.
        let cache = PlanCache::new();
        cache.insert(1 << 16, "ca", "m1", flat("R4,R4,R4,R4,R4,R4,R4,R4"));
        let blocked = ExecPlan::Blocked {
            p: 256,
            q: 256,
            col: Plan::parse("R4,R4,R4,R4").unwrap(),
            row: Plan::parse("R4,R4,R4,R4").unwrap(),
        };
        let v = cache.swap(1 << 16, "ca", "m1", blocked.clone());
        assert_eq!(v, 2);
        let got = cache.get_or_plan(1 << 16, "ca", "m1", || unreachable!());
        assert!(got.is_blocked());
        assert_eq!(got, blocked);
        assert_eq!(got.as_flat(), None);
    }
}
