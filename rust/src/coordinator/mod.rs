//! The serving coordinator: plan-cached, batched FFT execution.
//!
//! This is the Layer-3 "system" wrapper that turns the paper's planner
//! into a deployable service: clients submit split-complex transforms;
//! the coordinator plans (once, cached) with the configured search
//! strategy, batches compatible requests, executes on a backend (native
//! kernels or the AOT PJRT artifacts — Python never runs here), and
//! tracks latency/throughput metrics.
//!
//! Built on std threads + channels (this environment has no async
//! runtime in its offline crate set; an FFT service is CPU-bound anyway,
//! so a worker-per-core pool with bounded queues is the right shape).
//!
//! * [`metrics`] — counters + log-bucketed latency histogram;
//! * [`plancache`] — versioned (n, strategy) -> plan memoization (the
//!   autotuner hot-swaps re-planned arrangements through it);
//! * [`batcher`] — size/deadline dynamic batching plus same-key
//!   grouping: workers split each pulled batch into same-n groups and
//!   execute every group jointly through the lane-blocked batched
//!   kernels (`crate::fft::batch`), amortizing per-pass twiddle loads
//!   and memory round trips across the group — and, when a
//!   [`CoalescePolicy`] enables it, hold under-filled groups open
//!   *across* pulls and pair leftover singletons (deadline-bounded
//!   cross-batch coalescing, DESIGN.md §coalesce);
//! * [`service`] — the request loop, worker pool, and typed handles;
//!   wires in [`crate::autotune`] when `ServiceConfig::autotune` is set;
//! * [`shard`] — the scale-out tier: a key-affine [`ShardRouter`] over
//!   per-shard worker pools sharing one [`PlanCache`]/autotuner, with
//!   typed admission control ([`Rejected`]) and load shedding
//!   (DESIGN.md §shard).

pub mod batcher;
pub mod metrics;
pub mod plancache;
pub mod service;
pub mod shard;

pub use batcher::{
    collect_batch, collect_batch_until, group_by_key, BatchPolicy, Batcher, CoalescePolicy,
    CoalesceState, FlushReason, ReadyGroup,
};
pub use metrics::{Metrics, MetricsSnapshot};
pub use plancache::PlanCache;
pub use service::{Backend, ExecModePolicy, FftService, Rejected, ServiceConfig};
pub use shard::{ShardRouter, ShardedService};
