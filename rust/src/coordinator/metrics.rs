//! Service metrics: counters + a log-bucketed latency histogram.
//!
//! Bucket `i` covers latencies in `[2^i, 2^(i+1))` ns; the last bucket
//! saturates (everything at or above 2^30 ns ≈ 1.07 s lands there).
//! Percentiles report a bucket's upper edge *clamped to the true observed
//! maximum* — without the clamp, a fleet of sub-microsecond native
//! executions reads up to 2x slower than reality, and a single saturated
//! outlier reads as exactly 2^31 ns no matter how slow it really was
//! (both bugs existed here once; `sub_microsecond_percentiles_are_tight`
//! and `saturating_latencies_report_the_true_max` pin the fixes).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::cost::ExecMode;
use crate::kind::{TransformKind, KINDS};

/// Number of log2 latency buckets (1 ns .. the 2^30 ns saturation bucket).
const BUCKETS: usize = 31;

/// Buckets for the effective-batch-size histogram — one per autotune
/// batch class ([`crate::autotune::batch_class`], ceil-log2), so the
/// histogram, the learned per-class costs, and wisdom-v2 `batch`
/// records all bucket a group size identically.
pub const GROUP_BUCKETS: usize = crate::autotune::BATCH_CLASSES;

/// Thread-safe metrics sink (lock-free atomics; share via `Arc`).
#[derive(Debug, Default)]
pub struct Metrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    /// Completions per transform kind ([`TransformKind::index`] order);
    /// sums to `completed`.
    completed_by_kind: [AtomicU64; KINDS],
    failed: AtomicU64,
    /// Typed rejection splits. Every rejection also counts into `failed`
    /// (the aggregate operators alarm on); these counters say *why* —
    /// bounded queue at capacity, service stopped/stopping, size/kind
    /// validation, or load shedding (admitted too late to meet its
    /// deadline budget). Before the split, only queue-full rejections
    /// reached `failed` at all: disconnected-channel and validation
    /// bails returned errors without counting, undercounting exactly
    /// the rejections operators care about under overload.
    rejected_full: AtomicU64,
    rejected_stopped: AtomicU64,
    rejected_invalid: AtomicU64,
    rejected_shed: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    /// Jointly-executed groups (same-n runs through one batched kernel
    /// pass). A pulled batch splits into >= 1 groups.
    groups: AtomicU64,
    grouped_requests: AtomicU64,
    group_buckets: [AtomicU64; GROUP_BUCKETS],
    /// Held (coalesced) groups flushed — groups that stayed open across
    /// at least one pull window before executing.
    coalesced_flushes: AtomicU64,
    /// Held groups that gained at least one member while open (the
    /// coalescer's hit rate numerator).
    coalesce_hits: AtomicU64,
    /// Held groups formed by pairing a leftover singleton with later
    /// same-key traffic (second-level queue successes).
    singleton_pairings: AtomicU64,
    /// Summed / maximum wall age of held groups at flush (ns).
    held_age_ns_total: AtomicU64,
    held_age_ns_max: AtomicU64,
    /// Groups executed through the panel (gather → batched kernel →
    /// scatter) path vs. scalar-sequential in place. Together they sum
    /// to `groups` on the native backend; the split is the observable
    /// trace of the per-(kind, n, B) execution-mode decision.
    exec_panel_groups: AtomicU64,
    exec_scalar_groups: AtomicU64,
    /// Requests carried by each execution path.
    exec_panel_requests: AtomicU64,
    exec_scalar_requests: AtomicU64,
    /// Total wall time spent marshalling (gather + scatter around the
    /// panel kernels) — the data-movement cost the mode decision prices.
    marshal_ns_total: AtomicU64,
    busy_ns: AtomicU64,
    latency_buckets: [AtomicU64; BUCKETS],
    /// Exact maximum latency seen (ns) — the histogram alone cannot
    /// recover it (upper edges overstate; the saturation bucket is
    /// unbounded).
    max_latency_ns: AtomicU64,
    /// Global twiddle intern-store counters captured at construction
    /// ([`crate::fft::twiddle::global_stats`] is process-global and
    /// monotonic); snapshots report the deltas, i.e. interning activity
    /// over this sink's lifetime. `Metrics::default()` keeps a zero
    /// baseline and therefore reports process-lifetime totals.
    twiddle_hits_base: u64,
    twiddle_misses_base: u64,
}

/// Point-in-time snapshot with derived statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    /// Completions per transform kind ([`TransformKind::index`] order:
    /// forward, inverse, real, real-inverse); sums to `completed`.
    pub completed_by_kind: [u64; KINDS],
    pub failed: u64,
    /// Submissions rejected because the bounded queue was at capacity.
    pub rejected_full: u64,
    /// Submissions rejected because the service stopped (or was
    /// stopping) — the path that used to error without counting.
    pub rejected_stopped: u64,
    /// Submissions rejected by size/kind validation.
    pub rejected_invalid: u64,
    /// Requests shed by admission control: pulled with less remaining
    /// deadline budget than one flush window of slack, so holding them
    /// could only produce a deadline violation.
    pub rejected_shed: u64,
    pub batches: u64,
    /// Mean requests per executed batch.
    pub mean_batch_size: f64,
    /// Same-n groups executed (singletons and PJRT groups included).
    pub groups: u64,
    /// Mean requests per same-n group — the *effective* batch size the
    /// grouping step produces (groups of >= 2 on the native backend run
    /// through the batched kernels; singletons run scalar).
    pub mean_group_size: f64,
    /// Histogram of group sizes by autotune batch class
    /// ([`crate::autotune::batch_class`]: ceil-log2; bucket 0 = size 1,
    /// bucket 2 = sizes 3..=4, last bucket saturates).
    pub group_size_hist: [u64; GROUP_BUCKETS],
    /// Groups that were held open across pull windows before executing.
    pub coalesced_flushes: u64,
    /// Held groups that gained members while open.
    pub coalesce_hits: u64,
    /// `coalesce_hits / coalesced_flushes` (0 when nothing was held) —
    /// how often holding a group actually bought a bigger batch.
    pub coalesce_hit_rate: f64,
    /// Leftover singletons successfully paired by the second-level queue.
    pub singleton_pairings: u64,
    /// Mean / maximum wall age of held groups at flush.
    pub mean_held_age: Duration,
    pub max_held_age: Duration,
    /// Groups executed on the panel (gather/batched/scatter) path.
    pub exec_panel_groups: u64,
    /// Groups executed scalar-sequentially in place (no marshal).
    pub exec_scalar_groups: u64,
    /// Requests carried by the panel path.
    pub exec_panel_requests: u64,
    /// Requests carried by the scalar-sequential path.
    pub exec_scalar_requests: u64,
    /// Total wall time spent marshalling panels (gather + scatter).
    pub marshal_time: Duration,
    /// Twiddle-table intern lookups answered by an already-built table
    /// since this sink was created — the constructions the process-global
    /// sharing avoided (shards, hot-swap replacement executors, and the
    /// four-step column/row sub-plans all resolve to one store).
    pub twiddle_hits: u64,
    /// First-time twiddle-table constructions over the same window.
    pub twiddle_misses: u64,
    /// `twiddle_hits / (twiddle_hits + twiddle_misses)` (0 when the
    /// window saw no lookups).
    pub twiddle_hit_rate: f64,
    /// Total worker busy time.
    pub busy: Duration,
    pub latency_p50: Duration,
    pub latency_p95: Duration,
    pub latency_p99: Duration,
    pub latency_max: Duration,
}

impl Metrics {
    pub fn new() -> Self {
        let (twiddle_hits_base, twiddle_misses_base) = crate::fft::twiddle::global_stats();
        Metrics { twiddle_hits_base, twiddle_misses_base, ..Self::default() }
    }

    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a completion of unspecified kind (counted as forward —
    /// the pre-kind-axis behavior; the service reports through
    /// [`Metrics::on_complete_kind`]).
    pub fn on_complete(&self, latency: Duration) {
        self.on_complete_kind(TransformKind::Forward, latency);
    }

    /// Record a completion of a `kind` transform.
    pub fn on_complete_kind(&self, kind: TransformKind, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.completed_by_kind[kind.index()].fetch_add(1, Ordering::Relaxed);
        // Clamp into [1, u64::MAX]: a zero-duration latency (timer
        // granularity on sub-microsecond executions) lands in bucket 0
        // instead of underflowing the bucket index.
        let ns = latency.as_nanos().clamp(1, u64::MAX as u128) as u64;
        let bucket = (63 - ns.leading_zeros() as usize).min(BUCKETS - 1);
        self.latency_buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.max_latency_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn on_failure(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// A submission bounced off the full bounded queue (backpressure).
    /// Counts into `failed` too: the typed counters decompose the
    /// aggregate, they do not replace it.
    pub fn on_rejected_full(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
        self.rejected_full.fetch_add(1, Ordering::Relaxed);
    }

    /// A submission raced (or followed) shutdown.
    pub fn on_rejected_stopped(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
        self.rejected_stopped.fetch_add(1, Ordering::Relaxed);
    }

    /// A submission failed size/kind validation.
    pub fn on_rejected_invalid(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
        self.rejected_invalid.fetch_add(1, Ordering::Relaxed);
    }

    /// A request was shed by admission control instead of held past its
    /// deadline budget.
    pub fn on_rejected_shed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
        self.rejected_shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_batch(&self, size: usize, busy: Duration) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
        self.on_busy(busy);
    }

    /// Record worker execution time that is not attached to a pulled
    /// batch — e.g. coalesced groups flushed on an empty wake-deadline
    /// pull (counting those as batches would skew `mean_batch_size`).
    pub fn on_busy(&self, busy: Duration) {
        self.busy_ns.fetch_add(busy.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Record one same-n group of `size` requests. Every group is
    /// recorded regardless of execution path — singleton groups (scalar
    /// path) and PJRT groups included — so the histogram reads as the
    /// batching opportunity the traffic offers, not only what the
    /// batched kernels consumed.
    pub fn on_group(&self, size: usize) {
        let size = size.max(1);
        self.groups.fetch_add(1, Ordering::Relaxed);
        self.grouped_requests.fetch_add(size as u64, Ordering::Relaxed);
        let bucket = crate::autotune::batch_class(size);
        self.group_buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Record the flush of a group that was held open across pull
    /// windows: its wall age at flush, whether holding gained members,
    /// and whether it exists because a leftover singleton was paired.
    pub fn on_coalesce_flush(&self, held_age: Duration, gained: bool, paired_singleton: bool) {
        self.coalesced_flushes.fetch_add(1, Ordering::Relaxed);
        if gained {
            self.coalesce_hits.fetch_add(1, Ordering::Relaxed);
        }
        if paired_singleton {
            self.singleton_pairings.fetch_add(1, Ordering::Relaxed);
        }
        let ns = held_age.as_nanos().min(u64::MAX as u128) as u64;
        self.held_age_ns_total.fetch_add(ns, Ordering::Relaxed);
        self.held_age_ns_max.fetch_max(ns, Ordering::Relaxed);
    }

    /// Record which execution path a native group of `size` requests
    /// actually took. Every native group reports exactly once, so
    /// `exec_panel_groups + exec_scalar_groups` equals the native share
    /// of `groups` and the split is auditable against the mode table.
    pub fn on_exec_mode(&self, mode: ExecMode, size: usize) {
        let size = size.max(1) as u64;
        match mode {
            ExecMode::Panel => {
                self.exec_panel_groups.fetch_add(1, Ordering::Relaxed);
                self.exec_panel_requests.fetch_add(size, Ordering::Relaxed);
            }
            ExecMode::ScalarSequential => {
                self.exec_scalar_groups.fetch_add(1, Ordering::Relaxed);
                self.exec_scalar_requests.fetch_add(size, Ordering::Relaxed);
            }
        }
    }

    /// Record wall time spent marshalling one panel round trip (the
    /// gather into lanes plus every scatter back out).
    pub fn on_marshal(&self, spent: Duration) {
        let ns = spent.as_nanos().min(u64::MAX as u128) as u64;
        self.marshal_ns_total.fetch_add(ns, Ordering::Relaxed);
    }

    fn percentile(&self, counts: &[u64; BUCKETS], total: u64, max_ns: u64, p: f64) -> Duration {
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((total as f64) * p).ceil() as u64;
        let mut seen = 0;
        for (i, c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                // Upper edge of the bucket, clamped to the true maximum
                // (the edge overstates tight sub-microsecond populations
                // by up to 2x). The last bucket has no upper edge — its
                // only honest value is the true maximum.
                let ns = if i == BUCKETS - 1 {
                    max_ns.max(1)
                } else {
                    (1u64 << (i + 1).min(63)).min(max_ns.max(1))
                };
                return Duration::from_nanos(ns);
            }
        }
        Duration::from_nanos(max_ns.max(1))
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut counts = [0u64; BUCKETS];
        let mut total = 0;
        for (i, b) in self.latency_buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            counts[i] = c;
            total += c;
        }
        let max_ns = self.max_latency_ns.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let breq = self.batched_requests.load(Ordering::Relaxed);
        let groups = self.groups.load(Ordering::Relaxed);
        let greq = self.grouped_requests.load(Ordering::Relaxed);
        let mut group_size_hist = [0u64; GROUP_BUCKETS];
        for (slot, b) in group_size_hist.iter_mut().zip(&self.group_buckets) {
            *slot = b.load(Ordering::Relaxed);
        }
        let (twiddle_hits_now, twiddle_misses_now) = crate::fft::twiddle::global_stats();
        let twiddle_hits = twiddle_hits_now.saturating_sub(self.twiddle_hits_base);
        let twiddle_misses = twiddle_misses_now.saturating_sub(self.twiddle_misses_base);
        let coalesced_flushes = self.coalesced_flushes.load(Ordering::Relaxed);
        let coalesce_hits = self.coalesce_hits.load(Ordering::Relaxed);
        let held_total_ns = self.held_age_ns_total.load(Ordering::Relaxed);
        let mut completed_by_kind = [0u64; KINDS];
        for (slot, b) in completed_by_kind.iter_mut().zip(&self.completed_by_kind) {
            *slot = b.load(Ordering::Relaxed);
        }
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            completed_by_kind,
            failed: self.failed.load(Ordering::Relaxed),
            rejected_full: self.rejected_full.load(Ordering::Relaxed),
            rejected_stopped: self.rejected_stopped.load(Ordering::Relaxed),
            rejected_invalid: self.rejected_invalid.load(Ordering::Relaxed),
            rejected_shed: self.rejected_shed.load(Ordering::Relaxed),
            batches,
            mean_batch_size: if batches == 0 { 0.0 } else { breq as f64 / batches as f64 },
            groups,
            mean_group_size: if groups == 0 { 0.0 } else { greq as f64 / groups as f64 },
            group_size_hist,
            coalesced_flushes,
            coalesce_hits,
            coalesce_hit_rate: if coalesced_flushes == 0 {
                0.0
            } else {
                coalesce_hits as f64 / coalesced_flushes as f64
            },
            singleton_pairings: self.singleton_pairings.load(Ordering::Relaxed),
            mean_held_age: if coalesced_flushes == 0 {
                Duration::ZERO
            } else {
                Duration::from_nanos(held_total_ns / coalesced_flushes)
            },
            max_held_age: Duration::from_nanos(self.held_age_ns_max.load(Ordering::Relaxed)),
            exec_panel_groups: self.exec_panel_groups.load(Ordering::Relaxed),
            exec_scalar_groups: self.exec_scalar_groups.load(Ordering::Relaxed),
            exec_panel_requests: self.exec_panel_requests.load(Ordering::Relaxed),
            exec_scalar_requests: self.exec_scalar_requests.load(Ordering::Relaxed),
            marshal_time: Duration::from_nanos(self.marshal_ns_total.load(Ordering::Relaxed)),
            twiddle_hits,
            twiddle_misses,
            twiddle_hit_rate: if twiddle_hits + twiddle_misses == 0 {
                0.0
            } else {
                twiddle_hits as f64 / (twiddle_hits + twiddle_misses) as f64
            },
            busy: Duration::from_nanos(self.busy_ns.load(Ordering::Relaxed)),
            latency_p50: self.percentile(&counts, total, max_ns, 0.50),
            latency_p95: self.percentile(&counts, total, max_ns, 0.95),
            latency_p99: self.percentile(&counts, total, max_ns, 0.99),
            latency_max: Duration::from_nanos(max_ns),
        }
    }
}

impl MetricsSnapshot {
    /// Requests per second over a wall-clock window.
    pub fn throughput(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.completed as f64 / wall.as_secs_f64()
    }

    /// All typed rejections (the decomposed slice of `failed`).
    pub fn rejected_total(&self) -> u64 {
        self.rejected_full + self.rejected_stopped + self.rejected_invalid + self.rejected_shed
    }

    /// Fleet view across shards: counters and histograms sum, rates and
    /// means recompute from the summed numerators/denominators, and
    /// order statistics (latency percentiles, maxima) take the
    /// elementwise maximum — a conservative upper bound, since the true
    /// fleet percentile cannot exceed the worst shard's (the exact
    /// per-shard values are exported alongside the aggregate).
    pub fn aggregate(shards: &[MetricsSnapshot]) -> MetricsSnapshot {
        let mut out = MetricsSnapshot {
            submitted: 0,
            completed: 0,
            completed_by_kind: [0; KINDS],
            failed: 0,
            rejected_full: 0,
            rejected_stopped: 0,
            rejected_invalid: 0,
            rejected_shed: 0,
            batches: 0,
            mean_batch_size: 0.0,
            groups: 0,
            mean_group_size: 0.0,
            group_size_hist: [0; GROUP_BUCKETS],
            coalesced_flushes: 0,
            coalesce_hits: 0,
            coalesce_hit_rate: 0.0,
            singleton_pairings: 0,
            mean_held_age: Duration::ZERO,
            max_held_age: Duration::ZERO,
            exec_panel_groups: 0,
            exec_scalar_groups: 0,
            exec_panel_requests: 0,
            exec_scalar_requests: 0,
            marshal_time: Duration::ZERO,
            twiddle_hits: 0,
            twiddle_misses: 0,
            twiddle_hit_rate: 0.0,
            busy: Duration::ZERO,
            latency_p50: Duration::ZERO,
            latency_p95: Duration::ZERO,
            latency_p99: Duration::ZERO,
            latency_max: Duration::ZERO,
        };
        let mut batched_requests = 0f64;
        let mut grouped_requests = 0f64;
        let mut held_age_total = Duration::ZERO;
        for s in shards {
            out.submitted += s.submitted;
            out.completed += s.completed;
            for (slot, v) in out.completed_by_kind.iter_mut().zip(&s.completed_by_kind) {
                *slot += v;
            }
            out.failed += s.failed;
            out.rejected_full += s.rejected_full;
            out.rejected_stopped += s.rejected_stopped;
            out.rejected_invalid += s.rejected_invalid;
            out.rejected_shed += s.rejected_shed;
            out.batches += s.batches;
            batched_requests += s.mean_batch_size * s.batches as f64;
            out.groups += s.groups;
            grouped_requests += s.mean_group_size * s.groups as f64;
            for (slot, v) in out.group_size_hist.iter_mut().zip(&s.group_size_hist) {
                *slot += v;
            }
            out.coalesced_flushes += s.coalesced_flushes;
            out.coalesce_hits += s.coalesce_hits;
            out.singleton_pairings += s.singleton_pairings;
            held_age_total += s.mean_held_age * s.coalesced_flushes as u32;
            out.max_held_age = out.max_held_age.max(s.max_held_age);
            out.exec_panel_groups += s.exec_panel_groups;
            out.exec_scalar_groups += s.exec_scalar_groups;
            out.exec_panel_requests += s.exec_panel_requests;
            out.exec_scalar_requests += s.exec_scalar_requests;
            out.marshal_time += s.marshal_time;
            // The twiddle intern store is process-global: every shard
            // observes the same counters, so the fleet view takes the
            // maximum (summing would multiply shared work by the shard
            // count and misreport how much construction was avoided).
            out.twiddle_hits = out.twiddle_hits.max(s.twiddle_hits);
            out.twiddle_misses = out.twiddle_misses.max(s.twiddle_misses);
            out.busy += s.busy;
            out.latency_p50 = out.latency_p50.max(s.latency_p50);
            out.latency_p95 = out.latency_p95.max(s.latency_p95);
            out.latency_p99 = out.latency_p99.max(s.latency_p99);
            out.latency_max = out.latency_max.max(s.latency_max);
        }
        if out.batches > 0 {
            out.mean_batch_size = batched_requests / out.batches as f64;
        }
        if out.groups > 0 {
            out.mean_group_size = grouped_requests / out.groups as f64;
        }
        if out.coalesced_flushes > 0 {
            out.coalesce_hit_rate = out.coalesce_hits as f64 / out.coalesced_flushes as f64;
            out.mean_held_age = held_age_total / out.coalesced_flushes as u32;
        }
        let twiddle_total = out.twiddle_hits + out.twiddle_misses;
        if twiddle_total > 0 {
            out.twiddle_hit_rate = out.twiddle_hits as f64 / twiddle_total as f64;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_complete(Duration::from_micros(3));
        m.on_failure();
        m.on_batch(2, Duration::from_micros(5));
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.completed, 1);
        // kind-less completions count as forward
        assert_eq!(s.completed_by_kind, [1, 0, 0, 0]);
        assert_eq!(s.failed, 1);
        assert_eq!(s.batches, 1);
        assert_eq!(s.mean_batch_size, 2.0);
        assert_eq!(s.busy, Duration::from_micros(5));
    }

    #[test]
    fn group_histogram_buckets_match_autotune_batch_classes() {
        let m = Metrics::new();
        m.on_group(1); // class 0
        m.on_group(2); // class 1
        m.on_group(3); // class 2 (ceil-log2, same as the cost model)
        m.on_group(16); // class 4
        m.on_group(1000); // saturates in the last class
        let s = m.snapshot();
        assert_eq!(s.groups, 5);
        assert_eq!(s.group_size_hist[0], 1);
        assert_eq!(s.group_size_hist[1], 1);
        assert_eq!(s.group_size_hist[2], 1);
        assert_eq!(s.group_size_hist[4], 1);
        assert_eq!(s.group_size_hist[GROUP_BUCKETS - 1], 1);
        for (bucket, &count) in s.group_size_hist.iter().enumerate() {
            let want = [1usize, 2, 3, 16, 1000]
                .iter()
                .filter(|&&sz| crate::autotune::batch_class(sz) == bucket)
                .count() as u64;
            assert_eq!(count, want, "bucket {bucket}");
        }
        assert!((s.mean_group_size - (1.0 + 2.0 + 3.0 + 16.0 + 1000.0) / 5.0).abs() < 1e-9);
    }

    #[test]
    fn coalesce_counters_and_hit_rate() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.coalesced_flushes, 0);
        assert_eq!(s.coalesce_hit_rate, 0.0);
        assert_eq!(s.mean_held_age, Duration::ZERO);
        m.on_coalesce_flush(Duration::from_micros(400), true, false);
        m.on_coalesce_flush(Duration::from_micros(200), false, false);
        m.on_coalesce_flush(Duration::from_micros(600), true, true);
        let s = m.snapshot();
        assert_eq!(s.coalesced_flushes, 3);
        assert_eq!(s.coalesce_hits, 2);
        assert!((s.coalesce_hit_rate - 2.0 / 3.0).abs() < 1e-9);
        assert_eq!(s.singleton_pairings, 1);
        assert_eq!(s.mean_held_age, Duration::from_micros(400));
        assert_eq!(s.max_held_age, Duration::from_micros(600));
    }

    #[test]
    fn exec_mode_split_and_marshal_time_accumulate() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.exec_panel_groups, 0);
        assert_eq!(s.exec_scalar_groups, 0);
        assert_eq!(s.marshal_time, Duration::ZERO);
        m.on_exec_mode(ExecMode::Panel, 8);
        m.on_exec_mode(ExecMode::Panel, 4);
        m.on_exec_mode(ExecMode::ScalarSequential, 1);
        m.on_exec_mode(ExecMode::ScalarSequential, 3);
        m.on_marshal(Duration::from_nanos(700));
        m.on_marshal(Duration::from_nanos(300));
        let s = m.snapshot();
        assert_eq!(s.exec_panel_groups, 2);
        assert_eq!(s.exec_panel_requests, 12);
        assert_eq!(s.exec_scalar_groups, 2);
        assert_eq!(s.exec_scalar_requests, 4);
        assert_eq!(s.marshal_time, Duration::from_nanos(1000));
        // the split aggregates across shards like every other counter
        let agg = MetricsSnapshot::aggregate(&[s.clone(), s.clone()]);
        assert_eq!(agg.exec_panel_groups, 4);
        assert_eq!(agg.exec_scalar_requests, 8);
        assert_eq!(agg.marshal_time, Duration::from_nanos(2000));
    }

    #[test]
    fn typed_rejections_decompose_failed() {
        // Every typed rejection counts into `failed` too (the aggregate
        // dashboards alarm on), and the split accounts for each reason
        // exactly — including the stopped/invalid paths that once
        // errored without counting.
        let m = Metrics::new();
        m.on_rejected_full();
        m.on_rejected_full();
        m.on_rejected_stopped();
        m.on_rejected_invalid();
        m.on_rejected_shed();
        m.on_rejected_shed();
        m.on_rejected_shed();
        m.on_failure(); // an execution failure, not a rejection
        let s = m.snapshot();
        assert_eq!(s.rejected_full, 2);
        assert_eq!(s.rejected_stopped, 1);
        assert_eq!(s.rejected_invalid, 1);
        assert_eq!(s.rejected_shed, 3);
        assert_eq!(s.rejected_total(), 7);
        assert_eq!(s.failed, 8);
        assert!(s.rejected_total() <= s.failed);
    }

    #[test]
    fn aggregate_sums_counters_and_bounds_order_statistics() {
        let m1 = Metrics::new();
        m1.on_submit();
        m1.on_complete_kind(TransformKind::Forward, Duration::from_nanos(200));
        m1.on_batch(4, Duration::from_micros(2));
        m1.on_group(4);
        m1.on_coalesce_flush(Duration::from_micros(100), true, false);
        m1.on_rejected_full();
        let m2 = Metrics::new();
        m2.on_submit();
        m2.on_submit();
        m2.on_complete_kind(TransformKind::Inverse, Duration::from_nanos(800));
        m2.on_batch(2, Duration::from_micros(1));
        m2.on_group(2);
        m2.on_coalesce_flush(Duration::from_micros(300), false, false);
        m2.on_rejected_shed();
        let (s1, s2) = (m1.snapshot(), m2.snapshot());
        let agg = MetricsSnapshot::aggregate(&[s1.clone(), s2.clone()]);
        assert_eq!(agg.submitted, 3);
        assert_eq!(agg.completed, 2);
        assert_eq!(agg.completed_by_kind, [1, 1, 0, 0]);
        assert_eq!(agg.failed, 2);
        assert_eq!(agg.rejected_full, 1);
        assert_eq!(agg.rejected_shed, 1);
        assert_eq!(agg.batches, 2);
        assert!((agg.mean_batch_size - 3.0).abs() < 1e-9);
        assert_eq!(agg.groups, 2);
        assert!((agg.mean_group_size - 3.0).abs() < 1e-9);
        assert_eq!(agg.group_size_hist.iter().sum::<u64>(), 2);
        assert_eq!(agg.coalesced_flushes, 2);
        assert_eq!(agg.coalesce_hits, 1);
        assert!((agg.coalesce_hit_rate - 0.5).abs() < 1e-9);
        assert_eq!(agg.mean_held_age, Duration::from_micros(200));
        assert_eq!(agg.max_held_age, Duration::from_micros(300));
        assert_eq!(agg.busy, Duration::from_micros(3));
        // order statistics: elementwise max over shards
        assert_eq!(agg.latency_max, s1.latency_max.max(s2.latency_max));
        assert!(agg.latency_p50 >= s1.latency_p50.max(s2.latency_p50));
        // empty fleet aggregates to the zero snapshot
        assert_eq!(MetricsSnapshot::aggregate(&[]).completed, 0);
    }

    #[test]
    fn per_kind_completions_sum_to_completed() {
        let m = Metrics::new();
        m.on_complete_kind(TransformKind::Forward, Duration::from_nanos(100));
        m.on_complete_kind(TransformKind::Inverse, Duration::from_nanos(100));
        m.on_complete_kind(TransformKind::Inverse, Duration::from_nanos(100));
        m.on_complete_kind(TransformKind::RealForward, Duration::from_nanos(100));
        m.on_complete_kind(TransformKind::RealInverse, Duration::from_nanos(100));
        let s = m.snapshot();
        assert_eq!(s.completed_by_kind, [1, 2, 1, 1]);
        assert_eq!(s.completed_by_kind.iter().sum::<u64>(), s.completed);
    }

    #[test]
    fn percentiles_bracket_latencies() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.on_complete(Duration::from_nanos(1_000)); // bucket ~2^10
        }
        for _ in 0..10 {
            m.on_complete(Duration::from_micros(100)); // bucket ~2^17
        }
        let s = m.snapshot();
        assert!(s.latency_p50 >= Duration::from_nanos(1_000));
        assert!(s.latency_p50 <= Duration::from_nanos(4_096));
        assert!(s.latency_p99 >= Duration::from_micros(100));
        assert!(s.latency_max >= s.latency_p99);
    }

    #[test]
    fn sub_microsecond_percentiles_are_tight() {
        // Native n=256 executions run a few hundred ns; reporting the
        // bucket's upper edge overstated them by up to 2x. With the
        // true-max clamp a uniform population reads exactly right.
        let m = Metrics::new();
        for _ in 0..1000 {
            m.on_complete(Duration::from_nanos(300)); // bucket [256, 512)
        }
        let s = m.snapshot();
        assert_eq!(s.latency_p50, Duration::from_nanos(300));
        assert_eq!(s.latency_p99, Duration::from_nanos(300));
        assert_eq!(s.latency_max, Duration::from_nanos(300));
    }

    #[test]
    fn zero_duration_latency_lands_in_the_first_bucket() {
        // Instant granularity can hand the histogram Duration::ZERO for
        // sub-microsecond work; that must neither panic (bucket-index
        // underflow) nor vanish.
        let m = Metrics::new();
        m.on_complete(Duration::ZERO);
        let s = m.snapshot();
        assert_eq!(s.completed, 1);
        assert!(s.latency_p50 >= Duration::from_nanos(1));
        assert!(s.latency_p50 <= Duration::from_nanos(2));
    }

    #[test]
    fn saturating_latencies_report_the_true_max() {
        // Beyond the last bucket (>= 2^30 ns) the histogram saturates;
        // the reported max/percentile must not cap at the bucket edge.
        let m = Metrics::new();
        m.on_complete(Duration::from_secs(5));
        let s = m.snapshot();
        assert_eq!(s.latency_max, Duration::from_secs(5));
        assert_eq!(s.latency_p99, Duration::from_secs(5));
    }

    #[test]
    fn max_never_below_any_percentile() {
        let m = Metrics::new();
        for ns in [1u64, 77, 300, 1_000, 65_000, 2_000_000, 3_000_000_000] {
            m.on_complete(Duration::from_nanos(ns));
        }
        let s = m.snapshot();
        assert!(s.latency_p50 <= s.latency_p95);
        assert!(s.latency_p95 <= s.latency_p99);
        assert!(s.latency_p99 <= s.latency_max);
        assert_eq!(s.latency_max, Duration::from_nanos(3_000_000_000));
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.latency_p50, Duration::ZERO);
        assert_eq!(s.throughput(Duration::from_secs(1)), 0.0);
        assert_eq!(s.mean_batch_size, 0.0);
    }

    #[test]
    fn throughput() {
        let m = Metrics::new();
        for _ in 0..100 {
            m.on_complete(Duration::from_nanos(10));
        }
        let s = m.snapshot();
        assert!((s.throughput(Duration::from_secs(2)) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn twiddle_intern_counters_report_deltas_since_construction() {
        // The sink snapshots the process-global intern counters at
        // construction and reports deltas. Other tests in this process
        // intern concurrently, so assert floors and monotonicity, not
        // exact counts — and use a key no kernel test would request.
        let m = Metrics::new();
        let before = m.snapshot();
        let mut c = crate::fft::twiddle::TwiddleCache::new();
        c.vector(1 << 19, 3, 13); // first-time construction: a miss
        c.vector(1 << 19, 3, 13); // repeat lookup: a hit
        let s = m.snapshot();
        assert!(s.twiddle_misses >= 1, "construction not counted: {}", s.twiddle_misses);
        assert!(s.twiddle_hits >= 1, "reuse not counted: {}", s.twiddle_hits);
        assert!(s.twiddle_hits >= before.twiddle_hits);
        assert!(s.twiddle_misses >= before.twiddle_misses);
        assert!(s.twiddle_hit_rate > 0.0 && s.twiddle_hit_rate <= 1.0);
        // Shards share one global store: the fleet view is the max of
        // the per-shard deltas, never the sum.
        let agg = MetricsSnapshot::aggregate(&[s.clone(), s.clone()]);
        assert_eq!(agg.twiddle_hits, s.twiddle_hits);
        assert_eq!(agg.twiddle_misses, s.twiddle_misses);
        assert!((agg.twiddle_hit_rate - s.twiddle_hit_rate).abs() < 1e-9);
    }

    #[test]
    fn concurrent_updates_keep_snapshots_consistent() {
        // Snapshots taken while writers hammer the sink must stay
        // internally consistent: counters monotone across successive
        // snapshots, decompositions never overtaking their totals
        // (`snapshot` reads the per-kind splits *before* `completed`,
        // and every writer increments `completed` first), and the final
        // post-join snapshot exact.
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let writers: Vec<_> = (0..4)
            .map(|_| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    for i in 0..2000u64 {
                        m.on_submit();
                        let kind = crate::kind::ALL_KINDS[(i % 4) as usize];
                        m.on_complete_kind(kind, Duration::from_nanos(100 + i));
                        if i % 3 == 0 {
                            m.on_group((i % 7 + 1) as usize);
                        }
                        if i % 5 == 0 {
                            m.on_coalesce_flush(Duration::from_nanos(i), i % 2 == 0, false);
                        }
                    }
                })
            })
            .collect();
        let mut last = m.snapshot();
        while !writers.iter().all(|h| h.is_finished()) {
            let s = m.snapshot();
            assert!(s.submitted >= last.submitted, "submitted went backwards");
            assert!(s.completed >= last.completed, "completed went backwards");
            assert!(s.groups >= last.groups, "groups went backwards");
            assert!(s.coalesced_flushes >= last.coalesced_flushes, "flushes went backwards");
            assert!(
                s.completed_by_kind.iter().sum::<u64>() <= s.completed,
                "per-kind splits overtook the completed total"
            );
            assert!(s.latency_p50 <= s.latency_p95);
            assert!(s.latency_p95 <= s.latency_p99);
            assert!(s.latency_p99 <= s.latency_max);
            last = s;
        }
        for h in writers {
            h.join().unwrap();
        }
        let s = m.snapshot();
        assert_eq!(s.submitted, 8000);
        assert_eq!(s.completed, 8000);
        assert_eq!(s.completed_by_kind, [2000, 2000, 2000, 2000]);
        assert_eq!(s.groups, 4 * 667);
        assert_eq!(s.coalesced_flushes, 4 * 400);
        assert_eq!(s.coalesce_hits, 4 * 200);
        assert_eq!(s.group_size_hist.iter().sum::<u64>(), s.groups);
        assert_eq!(s.latency_max, Duration::from_nanos(2099));
    }
}
