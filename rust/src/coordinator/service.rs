//! The FFT service: plan once, batch, execute *as a batch*, measure —
//! and, when autotuning is on, keep re-planning from live samples.
//!
//! Request path (Python-free): client calls [`FftService::submit`] (or
//! [`FftService::submit_kind`] for inverse / real-input transforms) with
//! a split-complex buffer → the request queues to a worker → the worker
//! drains a batch ([`super::batcher::collect_batch`]) and splits it into
//! same-(kind, n) groups → each group of two or more requests gathers into a
//! pooled lane-blocked [`crate::fft::BatchBuffer`] and runs through
//! [`crate::fft::CompiledPlan::run_batch`] — every plan step loads its
//! twiddles once for the whole group instead of once per request —
//! then scatters per-request replies. Singleton groups take the scalar
//! path (lane padding would waste arithmetic). With coalescing enabled
//! (`ServiceConfig::coalesce`), under-filled groups stay open across
//! pulls and leftover singletons pair across pulls — each worker runs a
//! [`CoalesceState`] and caps its pull wait at the held work's earliest
//! deadline. Latency/throughput, effective-group-size, and coalescing
//! metrics stream to a shared [`Metrics`].
//!
//! Backends:
//! * [`Backend::Native`] — the in-crate kernels (`fft::exec`), fastest on
//!   this host, used by the serving example and benches;
//! * [`Backend::Pjrt`] — the AOT artifacts via PJRT; the registry is
//!   created inside the worker thread (the `xla` client is not `Send`).
//!
//! Autotuning (native backend): when `ServiceConfig::autotune` is set,
//! the service starts an [`Autotuner`] for the configured size. Workers
//! trace 1 in `sample_period` requests through the per-edge timing hook
//! and refresh their compiled plan from the versioned [`PlanSlot`]
//! *between* batches — a batch that started under version `v` finishes
//! under version `v`, so a hot swap can never corrupt an in-flight
//! request.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::autotune::{
    trace_batch, trace_exec_inplace, Autotuner, AutotuneConfig, AutotuneStatus, EdgeSample,
    SampleMode,
};
use crate::cost::{
    batch_class, class_batch, exec_mode_for, CostModel, ExecMode, PlanningSurface, SimCost,
    BATCH_CLASSES,
};
use crate::fft::{BatchBufferPool, CompiledExec, Executor, SplitComplex};
use crate::kind::TransformKind;
use crate::obs::{EventKind, Observer, StageTime};
use crate::plan::{ExecPlan, Plan};
use crate::planner::{plan_exec, Strategy};

use super::batcher::{collect_batch_until, BatchPolicy, CoalescePolicy, CoalesceState, ReadyGroup};
use super::metrics::Metrics;

/// Execution backend for the workers.
#[derive(Debug, Clone)]
pub enum Backend {
    /// Native in-crate kernels.
    Native,
    /// PJRT over AOT artifacts from this directory. Plans are executed by
    /// chaining per-edge executables + the bit-reversal epilogue.
    Pjrt { artifacts_dir: std::path::PathBuf },
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// FFT sizes the service accepts, with each size's startup plan.
    /// Each entry `(n, plan)` serves **four workloads**: forward and
    /// inverse c2c transforms of size n (same plan — the inverse runs
    /// the identical kernels with boundary conjugation), and
    /// real-input / real-output transforms of size 2n (whose internal
    /// c2c is exactly this n-point plan, plus the split/unpack step).
    pub plans: Vec<(usize, Plan)>,
    pub backend: Backend,
    pub batch: BatchPolicy,
    /// Cross-batch group coalescing: hold under-filled same-n groups
    /// open across pull windows (and pair leftover singletons) when the
    /// queue is deep. The default policy is disabled — identical
    /// serving behavior to per-pull grouping. Per worker: each worker
    /// coalesces the traffic it pulls.
    pub coalesce: CoalescePolicy,
    /// Worker threads (keep 1 for the PJRT backend on 1-core hosts).
    pub workers: usize,
    /// Bounded queue depth; submits beyond it fail fast (backpressure).
    pub queue_depth: usize,
    /// Online autotuning for the size matching `autotune.prior.n`
    /// (native backend only); `None` serves the startup plans forever.
    pub autotune: Option<AutotuneConfig>,
    /// Backpressure-aware deadline budget for load shedding. When set,
    /// a request a worker pulls with less remaining budget than one
    /// flush window of slack (`shed_deadline - batch.max_wait`) is shed
    /// with [`Rejected::Overloaded`] instead of held: under overload it
    /// could only have completed past its deadline, and shedding it
    /// early both tells the client the truth and stops the queue from
    /// serving work nobody is still waiting for. `None` (the default)
    /// never sheds — identical behavior to the pre-shedding service.
    pub shed_deadline: Option<Duration>,
    /// Structured observability: when set, every layer records typed
    /// events into this observer's flight recorder (submit, coalesce
    /// hold/flush, group formation, per-request latency spans) and
    /// traced groups feed the per-edge attribution table. The same
    /// observer is injected into the autotuner (unless
    /// `AutotuneConfig::observer` is already set) so the drift → replan
    /// → swap audit trail interleaves with the serving events. `None`
    /// costs nothing on the request path.
    pub observer: Option<Arc<Observer>>,
    /// Execution-mode policy for native same-(kind, n) groups: `Auto`
    /// (the default) prices the panel round trip against sequential
    /// in-place execution per batch class and takes the cheaper path;
    /// the forced modes pin one path for every group.
    pub exec_mode: ExecModePolicy,
    /// Largest FFT size served by one in-cache (flat) pass. When set,
    /// configured c2c sizes above it — and the real kinds at twice them,
    /// whose c2c core is the same spilled size — are re-planned through
    /// [`crate::planner::plan_exec`] at worker startup and may execute
    /// through the blocked four-step path (cache-resident sub-FFTs
    /// around priced transpose / block-twiddle boundary passes). `None`
    /// (the default) serves every size flat — identical behavior to the
    /// pre-blocking service.
    pub max_resident_n: Option<usize>,
}

/// How the service picks each native same-(kind, n) group's execution
/// path. The panel path (gather into a lane-blocked buffer → batched
/// kernels → scatter each lane back out) amortizes twiddle loads across
/// the group but pays a two-way transpose; the scalar path runs each
/// request sequentially in place in its own buffer and moves nothing.
/// Which one wins depends on (kind, n, B) — the cost model prices both.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecModePolicy {
    /// Price the panel round trip against sequential in-place scalar
    /// execution per (kind, n, batch class) on the cost model and take
    /// the cheaper path. With autotuning on, the tuner's live marshal
    /// and edge samples re-price the decision at runtime.
    #[default]
    Auto,
    /// Always take the panel path for groups of two or more (the
    /// pre-pricing behavior). Singletons still run scalar: lane padding
    /// would waste arithmetic with nothing to amortize it against.
    ForcePanel,
    /// Always execute scalar-sequentially in place (never marshal).
    ForceScalar,
}

impl std::str::FromStr for ExecModePolicy {
    type Err = String;

    /// CLI spelling: `auto` | `panel` | `scalar`.
    fn from_str(s: &str) -> std::result::Result<ExecModePolicy, String> {
        match s {
            "auto" => Ok(ExecModePolicy::Auto),
            "panel" => Ok(ExecModePolicy::ForcePanel),
            "scalar" => Ok(ExecModePolicy::ForceScalar),
            other => Err(format!("unknown exec mode {other:?} (expected auto|panel|scalar)")),
        }
    }
}

/// Typed submission rejection. These replace the old string bails so
/// callers — and the shard router's admission control — can branch on
/// the reason, and so every rejection path counts into exactly one of
/// the typed `rejected_*` metrics (the disconnected-channel and
/// validation paths used to error without counting at all).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Rejected {
    /// The bounded queue is at capacity (backpressure) — retry later.
    QueueFull,
    /// Admission control shed the request: its remaining deadline
    /// budget was below one flush window of slack, so it could not
    /// have been served in time.
    Overloaded,
    /// The service is shutting down (or its workers already exited).
    ShuttingDown,
    /// The request failed size/kind validation.
    Invalid(String),
}

impl Rejected {
    /// Stable reason tag used by the flight recorder and the metrics
    /// split (`queue_full`, `shed`, `shutting_down`, `invalid`).
    pub fn reason(&self) -> &'static str {
        match self {
            Rejected::QueueFull => "queue_full",
            Rejected::Overloaded => "shed",
            Rejected::ShuttingDown => "shutting_down",
            Rejected::Invalid(_) => "invalid",
        }
    }
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Rejected::QueueFull => write!(f, "queue full (backpressure)"),
            Rejected::Overloaded => {
                write!(f, "overloaded: shed (deadline budget below one flush window)")
            }
            Rejected::ShuttingDown => write!(f, "service is shutting down"),
            Rejected::Invalid(why) => f.write_str(why),
        }
    }
}

// With this impl the vendored anyhow stub's blanket
// `From<E: std::error::Error>` converts `Rejected` for the stringly
// `submit_kind` API, while `try_submit_kind` keeps the typed value.
impl std::error::Error for Rejected {}

struct Request {
    /// Submit-order id correlating `Submit` and `RequestDone` events
    /// (assigned whether or not an observer is configured).
    id: u64,
    n: usize,
    kind: TransformKind,
    input: SplitComplex,
    enqueued: Instant,
    reply: SyncSender<Result<SplitComplex>>,
}

/// Handle to a running service.
pub struct FftService {
    tx: Option<SyncSender<Request>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
    accepting: Arc<AtomicBool>,
    sizes: Vec<usize>,
    autotuner: Option<Arc<Autotuner>>,
    /// Whether shutdown stops the autotuner. False when the tuner is
    /// shared across shards ([`FftService::start_with`]): the sharing
    /// owner stops it once, after every sharer has drained.
    owns_tuner: bool,
    observer: Option<Arc<Observer>>,
    next_request: AtomicU64,
}

impl FftService {
    /// Start workers (and the autotuner, when configured) and return the
    /// handle.
    pub fn start(config: ServiceConfig) -> Result<FftService> {
        Self::start_with(config, None)
    }

    /// Like [`FftService::start`], but with an optional pre-built shared
    /// autotuner: the sharded service passes one `Arc<Autotuner>` to
    /// every shard so all shards sample into — and hot-swap from — the
    /// same online model, the serving analogue of FFTW's shared wisdom.
    /// A shared tuner is *not* stopped by this service's shutdown; its
    /// owner stops it after every sharer has drained. `config.autotune`
    /// must be `None` when a shared tuner is given.
    pub fn start_with(
        config: ServiceConfig,
        shared_tuner: Option<Arc<Autotuner>>,
    ) -> Result<FftService> {
        if config.plans.is_empty() {
            bail!("service needs at least one (n, plan)");
        }
        for (n, plan) in &config.plans {
            let l = crate::fft::log2i(*n);
            if !plan.is_valid_for(l) {
                bail!("plan {plan} invalid for n={n}");
            }
        }
        let (autotuner, owns_tuner) = match (&shared_tuner, &config.autotune) {
            (Some(_), Some(_)) => {
                bail!("pass the tuner either shared or via config.autotune, not both")
            }
            (Some(t), None) => {
                if !matches!(config.backend, Backend::Native) {
                    bail!("autotune requires the native backend");
                }
                (Some(t.clone()), false)
            }
            (None, None) => (None, true),
            (None, Some(at)) => {
                if !matches!(config.backend, Backend::Native) {
                    bail!("autotune requires the native backend");
                }
                let initial = config
                    .plans
                    .iter()
                    .find(|(n, _)| *n == at.prior.n)
                    .map(|(_, p)| p.clone())
                    .ok_or_else(|| {
                        anyhow!("autotune prior is for n={}, which has no configured plan", at.prior.n)
                    })?;
                let mut at = at.clone();
                // The service's observer doubles as the autotuner's, so
                // the drift → replan → swap audit trail lands in the
                // same flight recorder as the serving events.
                if at.observer.is_none() {
                    at.observer = config.observer.clone();
                }
                // Workers dispatch whatever backend their executors
                // detect; point the online model's ISA slot at the same
                // backend so the traced samples land where planning reads.
                at.exec_isa = Executor::new().isa();
                // Seed the tuner's marshal prior from the m1 sim model
                // when the caller gave none: the published mode table
                // then starts from the same priced flip point the static
                // per-entry tables use, and live marshal samples refine
                // it from there instead of from nothing.
                if at.marshal_priors.is_empty() {
                    let mut sim = SimCost::m1(at.prior.n);
                    for class in 1..BATCH_CLASSES {
                        let b = class_batch(class);
                        at.marshal_priors.push((class, sim.marshal_ns(b) / b as f64));
                    }
                }
                (Some(Arc::new(Autotuner::start(at, initial))), true)
            }
        };
        let metrics = Arc::new(Metrics::new());
        let accepting = Arc::new(AtomicBool::new(true));
        let (tx, rx) = sync_channel::<Request>(config.queue_depth);
        // Single shared receiver guarded by a mutex: workers steal batches.
        let rx = Arc::new(std::sync::Mutex::new(rx));
        let mut workers = Vec::new();
        for worker_id in 0..config.workers.max(1) {
            let rx = rx.clone();
            let metrics = metrics.clone();
            let config2 = config.clone();
            let tuner = autotuner.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("spfft-worker-{worker_id}"))
                    .spawn(move || worker_loop(worker_id, rx, config2, metrics, tuner))
                    .map_err(|e| anyhow!("spawn: {e}"))?,
            );
        }
        Ok(FftService {
            tx: Some(tx),
            workers,
            metrics,
            accepting,
            sizes: config.plans.iter().map(|(n, _)| *n).collect(),
            autotuner,
            observer: config.observer.clone(),
            next_request: AtomicU64::new(0),
        })
    }

    /// Submit a forward transform; returns a receiver for the result.
    /// Fails fast when the queue is full (backpressure) or shutting down.
    pub fn submit(&self, input: SplitComplex) -> Result<Receiver<Result<SplitComplex>>> {
        self.submit_kind(input, TransformKind::Forward)
    }

    /// Submit a transform of `kind`. C2c kinds accept the configured
    /// sizes; real kinds accept **twice** a configured size (the real
    /// transform's internal c2c is the configured half-size plan).
    pub fn submit_kind(
        &self,
        input: SplitComplex,
        kind: TransformKind,
    ) -> Result<Receiver<Result<SplitComplex>>> {
        self.try_submit_kind(input, kind).map_err(anyhow::Error::from)
    }

    /// Typed-rejection submit: like [`FftService::submit_kind`] but the
    /// error tells the caller *why* admission failed, so the shard
    /// router (and load-aware clients) can branch on it. Every rejection
    /// path counts into exactly one `rejected_*` metric and records a
    /// `Rejected` flight-recorder event.
    ///
    /// This is also where the shutdown race is fixed: the old path
    /// checked `accepting` and then `unwrap()`ed `tx`, so a submit
    /// concurrent with shutdown taking `tx` panicked. Both the missing
    /// sender and a disconnected channel now return
    /// [`Rejected::ShuttingDown`].
    pub fn try_submit_kind(
        &self,
        input: SplitComplex,
        kind: TransformKind,
    ) -> std::result::Result<Receiver<Result<SplitComplex>>, Rejected> {
        let n = input.len();
        if !self.accepting.load(Ordering::Relaxed) {
            return Err(self.reject(kind, n, Rejected::ShuttingDown));
        }
        let accepted = if kind.is_real() {
            n >= 4 && n % 2 == 0 && self.sizes.contains(&(n / 2))
        } else {
            self.sizes.contains(&n)
        };
        if !accepted {
            let why = format!(
                "unsupported {kind} FFT size {n} (configured c2c sizes: {:?}; \
                 real kinds serve 2x a configured size)",
                self.sizes
            );
            return Err(self.reject(kind, n, Rejected::Invalid(why)));
        }
        let (reply_tx, reply_rx) = sync_channel(1);
        let id = self.next_request.fetch_add(1, Ordering::Relaxed);
        let enqueued = Instant::now();
        let req = Request { id, n, kind, input, enqueued, reply: reply_tx };
        // Total match on the sender — no `unwrap()` left to race a
        // concurrent shutdown's `tx.take()`.
        let Some(tx) = self.tx.as_ref() else {
            return Err(self.reject(kind, n, Rejected::ShuttingDown));
        };
        match tx.try_send(req) {
            Ok(()) => {
                self.metrics.on_submit();
                if let Some(obs) = &self.observer {
                    obs.record_at(enqueued, EventKind::Submit { req: id, kind, n });
                }
                Ok(reply_rx)
            }
            Err(TrySendError::Full(_)) => Err(self.reject(kind, n, Rejected::QueueFull)),
            Err(TrySendError::Disconnected(_)) => {
                Err(self.reject(kind, n, Rejected::ShuttingDown))
            }
        }
    }

    /// Count + record one rejection, then hand the typed error back.
    fn reject(&self, kind: TransformKind, n: usize, why: Rejected) -> Rejected {
        match &why {
            Rejected::QueueFull => self.metrics.on_rejected_full(),
            Rejected::Overloaded => self.metrics.on_rejected_shed(),
            Rejected::ShuttingDown => self.metrics.on_rejected_stopped(),
            Rejected::Invalid(_) => self.metrics.on_rejected_invalid(),
        }
        if let Some(obs) = &self.observer {
            obs.record_now(EventKind::Rejected { kind, n, reason: why.reason().to_string() });
        }
        why
    }

    /// Convenience: submit a forward transform and wait.
    pub fn transform(&self, input: SplitComplex) -> Result<SplitComplex> {
        self.transform_kind(input, TransformKind::Forward)
    }

    /// Convenience: submit a `kind` transform and wait.
    pub fn transform_kind(&self, input: SplitComplex, kind: TransformKind) -> Result<SplitComplex> {
        self.submit_kind(input, kind)?
            .recv()
            .map_err(|_| anyhow!("worker dropped the request"))?
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// The configured observer, when observability is on.
    pub fn observer(&self) -> Option<&Arc<Observer>> {
        self.observer.as_ref()
    }

    /// Autotuning status, when autotuning is configured.
    pub fn autotune_status(&self) -> Option<AutotuneStatus> {
        self.autotuner.as_ref().map(|t| t.status())
    }

    /// Stop accepting new submissions without draining. Subsequent
    /// submits get [`Rejected::ShuttingDown`]; already-queued work still
    /// completes when [`FftService::shutdown`] runs. The sharded service
    /// fences every shard with this before draining any of them, so a
    /// client can never land work on shard B after shard A reported
    /// drained; it also lets tests pin the submit/shutdown interleave
    /// deterministically.
    pub fn begin_shutdown(&self) {
        self.accepting.store(false, Ordering::Relaxed);
    }

    /// Stop accepting, drain, and join workers (then the autotuner —
    /// unless it is shared, see [`FftService::start_with`] — so its
    /// learned wisdom persists after the last sample).
    pub fn shutdown(mut self) -> super::metrics::MetricsSnapshot {
        self.accepting.store(false, Ordering::Relaxed);
        drop(self.tx.take()); // close the queue; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if self.owns_tuner {
            if let Some(t) = &self.autotuner {
                t.stop();
            }
        }
        self.metrics.snapshot()
    }
}

impl Drop for FftService {
    fn drop(&mut self) {
        self.accepting.store(false, Ordering::Relaxed);
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if self.owns_tuner {
            if let Some(t) = &self.autotuner {
                t.stop();
            }
        }
    }
}

/// One compiled serving entry: request-buffer size + kind + the
/// compiled execution (flat plan or blocked four-step) + the plan
/// version it compiled under + the execution mode chosen for each batch
/// class of this (n, kind) workload.
struct CompiledEntry {
    n: usize,
    kind: TransformKind,
    exec: CompiledExec,
    version: u64,
    /// Per-batch-class execution path ([`crate::cost::batch_class`]
    /// indexing). Derived from the policy at build time and refreshed
    /// alongside plan swaps; under `Auto` with autotuning on, the
    /// tuned-size c2c entries track the tuner's live mode table.
    modes: [ExecMode; BATCH_CLASSES],
}

/// The execution-mode table an entry starts from. Forced policies pin
/// every class (class 0 — singletons — always runs scalar: a one-lane
/// panel pads three dead lanes and moves data for nothing). `Auto`
/// prices each class's panel round trip against sequential scalar
/// execution on the m1 sim model of the entry's c2c core size
/// (`model_n`); [`exec_mode_for`] doubles the marshal bytes for real
/// kinds, whose request buffers are twice the core.
fn static_mode_table(
    policy: ExecModePolicy,
    kind: TransformKind,
    plan: &Plan,
    model_n: usize,
) -> [ExecMode; BATCH_CLASSES] {
    match policy {
        ExecModePolicy::ForceScalar => [ExecMode::ScalarSequential; BATCH_CLASSES],
        ExecModePolicy::ForcePanel => std::array::from_fn(|class| {
            if class == 0 {
                ExecMode::ScalarSequential
            } else {
                ExecMode::Panel
            }
        }),
        ExecModePolicy::Auto => {
            let mut model = SimCost::m1(model_n);
            std::array::from_fn(|class| exec_mode_for(&mut model, kind, plan, class_batch(class)))
        }
    }
}

/// The execution decision for one configured `(n, plan)` entry. Within
/// the resident cap (or without one) the configured flat plan serves
/// as-is. Above the cap, [`plan_exec`] prices flat against every
/// admissible (p, q) four-step split on the m1 sim model; only a blocked
/// winner replaces the configured arrangement — when flat still wins
/// (no split fits the cap), the operator's plan stands.
fn exec_decision(n: usize, plan: &Plan, max_resident_n: Option<usize>) -> ExecPlan {
    let Some(limit) = max_resident_n else {
        return ExecPlan::Flat(plan.clone());
    };
    if n <= limit {
        return ExecPlan::Flat(plan.clone());
    }
    let mut make = SimCost::m1;
    let outcome = plan_exec(
        &mut make,
        n,
        &Strategy::DijkstraContextAware { k: 1 },
        PlanningSurface::forward(),
        Some(limit),
    );
    if outcome.exec.is_blocked() {
        outcome.exec
    } else {
        ExecPlan::Flat(plan.clone())
    }
}

enum WorkerBackend {
    Native {
        ex: Executor,
        /// One entry per (n, kind) workload each configured plan serves
        /// (forward + inverse at n, real kinds at 2n).
        compiled: Vec<CompiledEntry>,
        /// Recycled batch-buffer allocations (worker-owned; the group
        /// hot loop is allocation-free once warm).
        pool: BatchBufferPool,
        /// The configured execution-mode policy; `refresh` re-derives
        /// entry mode tables under it when plans swap or the tuner's
        /// published table moves.
        policy: ExecModePolicy,
    },
    Pjrt {
        registry: crate::runtime::Registry,
        plans: Vec<(usize, Plan)>,
    },
}

impl WorkerBackend {
    /// Recompile any entry whose published plan version moved. Called
    /// between batches only — never while a batch is executing. All
    /// four kinds derived from the tuned size's plan refresh together
    /// (c2c entries at the tuned n, real entries at 2n — they share the
    /// swapped c2c arrangement).
    fn refresh(&mut self, tuner: &Autotuner) {
        let WorkerBackend::Native { ex, compiled, policy, .. } = self else { return };
        let current = tuner.slot().current();
        // The tuner's mode table can move without a plan swap (live
        // marshal samples re-price the panel round trip at the drift
        // cadence), so under `Auto` the tuned-size c2c entries re-read
        // the published table on every refresh — a handful of relaxed
        // atomic loads, still between batches only.
        let tuned_modes =
            matches!(policy, ExecModePolicy::Auto).then(|| tuner.mode_table().snapshot());
        for entry in compiled.iter_mut() {
            let derived = if entry.kind.is_real() {
                entry.n == 2 * tuner.n()
            } else {
                entry.n == tuner.n()
            };
            if !derived {
                continue;
            }
            if entry.exec.is_blocked() {
                // Blocked entries sit outside the tuner's flat surface:
                // their sub-plans are cache-resident sub-sizes, not the
                // tuned n, so a swapped flat arrangement cannot improve
                // them. Their traced boundary samples still feed the
                // online model's shape-keyed stores; the blocked
                // decision itself is re-made by `plan_exec`, not by a
                // flat hot swap.
                continue;
            }
            if entry.version != current.version {
                entry.exec = CompiledExec::Flat(ex.compile_kind(
                    &current.plan,
                    entry.n,
                    true,
                    entry.kind,
                ));
                entry.version = current.version;
                // A swapped plan re-prices the panel: its kernel mix
                // (and therefore the batched amortization) changed.
                entry.modes = static_mode_table(*policy, entry.kind, &current.plan, tuner.n());
            }
            if let Some(modes) = &tuned_modes {
                // The tuner models the c2c surface; real-kind entries
                // keep their statically priced table (their doubled
                // buffers flip at a different point).
                if !entry.kind.is_real() {
                    entry.modes = *modes;
                }
            }
        }
    }

    /// Execute one same-(kind, n) group and reply to every request in
    /// it. Groups of >= 2 requests on the native backend run jointly
    /// through `run_batch`; singletons (and the PJRT backend) run per
    /// request. Grouping never crosses kinds — the group key is the
    /// full (kind, n) pair.
    fn execute_group(
        &mut self,
        key: (TransformKind, usize),
        group: Vec<Request>,
        held_age: Duration,
        tuner: Option<&Autotuner>,
        metrics: &Metrics,
        obs: Option<&Observer>,
    ) {
        let (kind, n) = key;
        let group_size = group.len();
        let exec_start = Instant::now();
        match self {
            WorkerBackend::Native { compiled, pool, .. } => {
                let Some(entry) = compiled.iter_mut().find(|e| e.n == n && e.kind == kind)
                else {
                    for req in group {
                        metrics.on_failure();
                        let _ = req.reply.send(Err(anyhow!("no plan for {kind} n={n}")));
                    }
                    return;
                };
                // Sample c2c groups of the tuned size only: real-kind
                // cells live on the half-size surface and would pollute
                // the tuned model's cells (inverse folds onto forward
                // unless the calibration split is on).
                let sampling = tuner
                    .filter(|t| n == t.n() && !kind.is_real() && t.sampler().should_sample());
                // The planned execution path for this group's batch
                // class. Singletons always run scalar regardless of
                // policy — a one-lane panel is pure data movement. A
                // blocked entry always runs scalar-sequential: its
                // four-step scratch (panel + p·q work buffer) is
                // per-transform, and the blocked sizes it exists for are
                // exactly the ones whose lane panels would spill.
                let mode = if group.len() < 2 || entry.exec.is_blocked() {
                    ExecMode::ScalarSequential
                } else {
                    entry.modes[batch_class(group.len())]
                };
                metrics.on_exec_mode(mode, group_size);
                if mode == ExecMode::ScalarSequential {
                    // Zero-copy path: each request transforms in place
                    // in the buffer it arrived in — no gather, no
                    // scatter, no scratch clone — and the same buffer is
                    // moved into the reply. At most the first request is
                    // traced (batch=1 samples belong on the unbatched
                    // surface).
                    let mut sampling = sampling;
                    for mut req in group {
                        let mut stages: Vec<StageTime> = Vec::new();
                        match sampling.take() {
                            Some(t) => {
                                let mut samples = Vec::new();
                                trace_exec_inplace(
                                    &mut entry.exec,
                                    &mut req.input.re,
                                    &mut req.input.im,
                                    t.mode(),
                                    &mut samples,
                                );
                                if let Some(o) = obs {
                                    o.observe_samples(&samples);
                                    stages = stage_times(&samples);
                                }
                                t.sampler().submit(samples);
                            }
                            None => entry.exec.run(&mut req.input.re, &mut req.input.im),
                        }
                        let now = Instant::now();
                        metrics.on_complete_kind(kind, now.saturating_duration_since(req.enqueued));
                        if let Some(o) = obs {
                            record_request_done(
                                o, &req, group_size, held_age, exec_start, now, stages,
                            );
                        }
                        let _ = req.reply.send(Ok(req.input));
                    }
                    return;
                }
                // Only flat entries reach the panel path (blocked
                // entries forced scalar above).
                let CompiledExec::Flat(cp) = &entry.exec else {
                    unreachable!("blocked entries are forced scalar-sequential")
                };
                // Panel path: one timed gather into the pooled
                // lane-blocked buffer, the batched kernels, then one
                // timed scatter per lane back into each request's own
                // buffer — exactly one buffer copy per request end to
                // end (the old path's per-lane `scatter_lane` allocated
                // a second). The measured round trip feeds the metrics
                // and (when sampled) the tuner, so the mode decision
                // tracks the real transpose.
                let mut buf = pool.acquire(n, group.len());
                let m0 = Instant::now();
                {
                    let inputs: Vec<&SplitComplex> = group.iter().map(|r| &r.input).collect();
                    buf.gather(&inputs);
                }
                let mut marshal = m0.elapsed();
                let mut stages: Vec<StageTime> = Vec::new();
                match sampling {
                    Some(t) => {
                        let mut samples = Vec::with_capacity(cp.steps().len());
                        trace_batch(cp, &mut buf, t.mode(), &mut samples);
                        if let Some(o) = obs {
                            o.observe_samples(&samples);
                            stages = stage_times(&samples);
                        }
                        t.sampler().submit(samples);
                    }
                    None => cp.run_batch(&mut buf),
                }
                for (lane, mut req) in group.into_iter().enumerate() {
                    let m1 = Instant::now();
                    buf.scatter_lane_into(lane, &mut req.input);
                    marshal += m1.elapsed();
                    let now = Instant::now();
                    metrics.on_complete_kind(kind, now.saturating_duration_since(req.enqueued));
                    if let Some(o) = obs {
                        record_request_done(
                            o, &req, group_size, held_age, exec_start, now, stages.clone(),
                        );
                    }
                    let _ = req.reply.send(Ok(req.input));
                }
                pool.release(buf);
                metrics.on_marshal(marshal);
                if let Some(t) = sampling {
                    // Oracle-mode runs stay deterministic: only measured
                    // wall time becomes a marshal observation.
                    if matches!(t.mode(), SampleMode::Wallclock) {
                        t.sampler().submit(vec![EdgeSample::marshal(
                            kind,
                            group_size,
                            cp.isa(),
                            marshal.as_nanos() as f64,
                        )]);
                    }
                }
            }
            WorkerBackend::Pjrt { registry, plans } => {
                // C2c kinds both run the same AOT forward executables:
                // the inverse is served via the boundary-conjugation
                // identity (IDFT = conj ∘ DFT ∘ conj / n) — one sign
                // pass over `im` going in, conjugate-and-scale coming
                // out — exactly the native path's algebra, around the
                // unchanged PJRT artifacts. Real kinds keep the typed
                // error: their RU boundary pass has no compiled artifact.
                if kind.is_real() {
                    for req in group {
                        metrics.on_failure();
                        let _ = req.reply.send(Err(anyhow!(
                            "the PJRT backend serves c2c transforms only (got {kind}; \
                             real kinds need the native backend's split/unpack pass)"
                        )));
                    }
                    return;
                }
                let plan = plans.iter().find(|(pn, _)| *pn == n).map(|(_, p)| p.clone());
                for req in group {
                    let result = match &plan {
                        Some(p) if kind == TransformKind::Inverse => {
                            let mut input = req.input.clone();
                            crate::fft::real::negate(&mut input.im);
                            registry.execute_plan(n, p, &input).map(|mut out| {
                                crate::fft::real::conj_scale(
                                    &mut out.re,
                                    &mut out.im,
                                    1.0 / n as f32,
                                );
                                out
                            })
                        }
                        Some(p) => registry.execute_plan(n, p, &req.input),
                        None => Err(anyhow!("no plan for n={n}")),
                    };
                    match &result {
                        Ok(_) => {
                            let now = Instant::now();
                            metrics
                                .on_complete_kind(kind, now.saturating_duration_since(req.enqueued));
                            if let Some(o) = obs {
                                record_request_done(
                                    o, &req, group_size, held_age, exec_start, now, Vec::new(),
                                );
                            }
                        }
                        Err(_) => metrics.on_failure(),
                    }
                    let _ = req.reply.send(result);
                }
            }
        }
    }
}

/// Per-request share of a traced group's per-stage edge timings: each
/// whole-batch sample divides evenly across its lanes.
fn stage_times(samples: &[crate::autotune::EdgeSample]) -> Vec<StageTime> {
    samples.iter().map(|s| (s.edge, s.stage, s.per_transform_ns())).collect()
}

/// Record one request's completed latency span. The decomposition is
/// computed by subtraction from two captured instants, so
/// `queue + held + exec == total` holds exactly:
/// exec = reply − execution start (capped at total), held = the group's
/// coalesce hold age (capped at total − exec), queue = the remainder.
fn record_request_done(
    obs: &Observer,
    req: &Request,
    group_size: usize,
    held_age: Duration,
    exec_start: Instant,
    now: Instant,
    stages: Vec<StageTime>,
) {
    let total_ns = now.saturating_duration_since(req.enqueued).as_nanos() as u64;
    let exec_ns = (now.saturating_duration_since(exec_start).as_nanos() as u64).min(total_ns);
    let held_ns = (held_age.as_nanos() as u64).min(total_ns - exec_ns);
    let queue_ns = total_ns - exec_ns - held_ns;
    obs.record_at(
        now,
        EventKind::RequestDone {
            req: req.id,
            kind: req.kind,
            n: req.n,
            group_size,
            queue_ns,
            held_ns,
            exec_ns,
            total_ns,
            stages,
        },
    );
}

/// Execute one ready (possibly coalesced) group and record its metrics.
fn run_group(
    backend: &mut WorkerBackend,
    group: ReadyGroup<(TransformKind, usize), Request>,
    tuner: Option<&Autotuner>,
    metrics: &Metrics,
    obs: Option<&Observer>,
) {
    metrics.on_group(group.items.len());
    if group.held_windows > 0 {
        metrics.on_coalesce_flush(group.held_age, group.gained > 0, group.paired_singletons);
    }
    if let Some(o) = obs {
        let now = Instant::now();
        let (kind, n) = group.key;
        o.record_at(
            now,
            EventKind::GroupFormed {
                kind,
                n,
                size: group.items.len(),
                held_windows: group.held_windows,
                paired_singletons: group.paired_singletons,
            },
        );
        if group.held_windows > 0 {
            o.record_at(
                now,
                EventKind::CoalesceFlush {
                    kind,
                    n,
                    size: group.items.len(),
                    held_windows: group.held_windows,
                    held_age_ns: group.held_age.as_nanos() as u64,
                    gained: group.gained,
                    paired_singletons: group.paired_singletons,
                    reason: format!("{:?}", group.reason),
                },
            );
        }
    }
    backend.execute_group(group.key, group.items, group.held_age, tuner, metrics, obs);
}

fn worker_loop(
    _id: usize,
    rx: Arc<std::sync::Mutex<Receiver<Request>>>,
    config: ServiceConfig,
    metrics: Arc<Metrics>,
    tuner: Option<Arc<Autotuner>>,
) {
    // Build the backend inside the thread (PJRT clients are not Send).
    let mut backend = match &config.backend {
        Backend::Native => {
            let mut ex = Executor::new();
            let mut compiled = Vec::new();
            for (n, p) in &config.plans {
                // Every configured (n, plan) serves four workloads: the
                // c2c pair at n and the real pair at 2n (same c2c core).
                // Each entry is priced for its own (kind, n) workload —
                // the mode table is per entry, not per plan. One
                // execution decision per configured size: the real kinds
                // at 2n share the c2c core's (p, q) split, so a size
                // that blocks, blocks for all four kinds.
                let decision = exec_decision(*n, p, config.max_resident_n);
                for kind in [TransformKind::Forward, TransformKind::Inverse] {
                    compiled.push(CompiledEntry {
                        n: *n,
                        kind,
                        exec: CompiledExec::compile(&mut ex, &decision, *n, kind),
                        version: 1,
                        modes: static_mode_table(config.exec_mode, kind, p, *n),
                    });
                }
                for kind in [TransformKind::RealForward, TransformKind::RealInverse] {
                    compiled.push(CompiledEntry {
                        n: 2 * *n,
                        kind,
                        exec: CompiledExec::compile(&mut ex, &decision, 2 * *n, kind),
                        version: 1,
                        modes: static_mode_table(config.exec_mode, kind, p, *n),
                    });
                }
            }
            WorkerBackend::Native {
                ex,
                compiled,
                pool: BatchBufferPool::new(),
                policy: config.exec_mode,
            }
        }
        Backend::Pjrt { artifacts_dir } => match crate::runtime::Registry::load(artifacts_dir) {
            Ok(registry) => WorkerBackend::Pjrt { registry, plans: config.plans.clone() },
            Err(e) => {
                eprintln!("spfft worker: failed to load artifacts: {e}");
                return;
            }
        },
    };
    // The grouping / coalescing key is the full (kind, n) pair: a
    // forward group never merges with inverse or real traffic (their
    // compiled plans differ), and FIFO holds per key.
    let mut coalesce: CoalesceState<(TransformKind, usize), Request> =
        CoalesceState::new(config.coalesce, config.batch.max_wait);
    let obs = config.observer.clone();
    loop {
        // Take the receiver lock only to pull one batch (the batching
        // deadline loop itself is shared with the owning Batcher). When
        // coalesced groups are held, cap the wait at their earliest due
        // time so no held request outlives its deadline budget — and
        // with coalescing enabled at all, never block unboundedly even
        // when *this* worker holds nothing: a sibling worker's held
        // groups need the shared receiver lock to cycle within a window,
        // or its deadline flushes would starve behind our blocking recv.
        // (Deliberate cost: an idle coalescing-enabled service wakes
        // each worker once per max_wait. A "block when no worker holds
        // anything" shared counter cannot fix that safely — a sibling
        // can start holding after we read zero and commit to an
        // unbounded recv with the lock, recreating the starvation.)
        let wake = coalesce
            .next_flush_due(|r: &Request| r.enqueued)
            .or_else(|| {
                config.coalesce.enabled().then(|| Instant::now() + config.batch.max_wait)
            });
        let batch = {
            let guard = rx.lock().unwrap();
            collect_batch_until(&*guard, config.batch, wake)
        };
        let Some(batch) = batch else {
            // Channel closed and drained: flush held work, then exit.
            for group in coalesce.flush_all(Instant::now()) {
                run_group(&mut backend, group, tuner.as_deref(), &metrics, obs.as_deref());
            }
            return;
        };
        // Pick up hot-swapped plans between batches: everything in the
        // batch we just pulled executes under one plan version.
        if let Some(t) = &tuner {
            backend.refresh(t);
        }
        let t0 = Instant::now();
        // Load shedding at pull time: a request whose remaining deadline
        // budget is below one flush window of slack could only complete
        // late — the coalescer may legitimately hold it for up to
        // `max_wait` more, so admitting it would manufacture a deadline
        // violation. Shed it with the typed rejection instead of holding.
        // (`shed_deadline: None` skips the partition entirely — identical
        // behavior to the pre-shedding service.)
        let batch = match config.shed_deadline {
            None => batch,
            Some(budget) => {
                let slack = budget.saturating_sub(config.batch.max_wait);
                let now = Instant::now();
                let (keep, shed): (Vec<Request>, Vec<Request>) = batch
                    .into_iter()
                    .partition(|r| now.saturating_duration_since(r.enqueued) <= slack);
                for req in shed {
                    metrics.on_rejected_shed();
                    if let Some(o) = &obs {
                        o.record_at(
                            now,
                            EventKind::Rejected {
                                kind: req.kind,
                                n: req.n,
                                reason: Rejected::Overloaded.reason().to_string(),
                            },
                        );
                    }
                    let _ = req.reply.send(Err(anyhow::Error::from(Rejected::Overloaded)));
                }
                keep
            }
        };
        // Admitted size only: shed requests never reach a group, so they
        // must not inflate the mean batch size.
        let size = batch.len();
        // Same-n requests execute jointly; group order preserves arrival,
        // and under-filled groups may coalesce across pulls (an empty
        // wake-deadline pull just ages and flushes the held state).
        let ready = coalesce.admit_with(
            batch,
            Instant::now(),
            |r| (r.kind, r.n),
            |r| r.enqueued,
            |&(kind, n), group_len, windows| {
                if let Some(o) = &obs {
                    o.record_now(EventKind::CoalesceHold {
                        kind,
                        n,
                        size: group_len,
                        held_windows: windows,
                    });
                }
            },
        );
        let did_work = !ready.is_empty();
        for group in ready {
            run_group(&mut backend, group, tuner.as_deref(), &metrics, obs.as_deref());
        }
        if size > 0 {
            metrics.on_batch(size, t0.elapsed());
        } else if did_work {
            // deadline/budget flushes on an empty wake pull still cost
            // execution time — busy accounting must see them
            metrics.on_busy(t0.elapsed());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::reference::fft_ref;

    fn native_service(n: usize, plan: &str, workers: usize) -> FftService {
        FftService::start(ServiceConfig {
            plans: vec![(n, Plan::parse(plan).unwrap())],
            backend: Backend::Native,
            batch: BatchPolicy { max_batch: 8, max_wait: std::time::Duration::from_micros(100) },
            coalesce: Default::default(),
            workers,
            queue_depth: 64,
            autotune: None,
            shed_deadline: None,
            observer: None,
            exec_mode: Default::default(),
            max_resident_n: None,
        })
        .unwrap()
    }

    #[test]
    fn serves_correct_ffts() {
        let svc = native_service(256, "R4,R4,R2,F8", 1);
        let input = SplitComplex::random(256, 42);
        let got = svc.transform(input.clone()).unwrap();
        let want = fft_ref(&input);
        assert!(got.max_abs_diff(&want) / want.max_abs().max(1.0) < 1e-4);
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.failed, 0);
    }

    #[test]
    fn exec_decision_respects_the_resident_cap() {
        let plan = Plan::parse("R4,R4,R2,F8").unwrap();
        // no cap → configured flat plan, regardless of n
        assert!(matches!(exec_decision(256, &plan, None), ExecPlan::Flat(ref p) if *p == plan));
        // resident n under the cap → still the configured flat plan
        assert!(
            matches!(exec_decision(256, &plan, Some(4096)), ExecPlan::Flat(ref p) if *p == plan)
        );
        // spilled n → a four-step split whose factors both fit the cap
        let big = crate::fft::fourstep::radix_mix_plan(16);
        match exec_decision(1 << 16, &big, Some(4096)) {
            ExecPlan::Blocked { p, q, .. } => {
                assert_eq!(p * q, 1 << 16);
                assert!(p <= 4096 && q <= 4096, "{p}x{q} ignores the cap");
            }
            flat => panic!("spilled size stayed flat: {flat}"),
        }
    }

    #[test]
    fn resident_cap_serves_spilled_sizes_through_the_four_step_path() {
        // n above the cap: the service must swap in a blocked entry and
        // serve it scalar-sequentially (even under ForcePanel — the
        // four-step path owns its own data movement), still matching the
        // reference transform.
        let n = 1 << 16;
        let cap = 4096;
        let plan = crate::fft::fourstep::radix_mix_plan(16);
        assert!(exec_decision(n, &plan, Some(cap)).is_blocked());
        let svc = FftService::start(ServiceConfig {
            plans: vec![(n, plan)],
            backend: Backend::Native,
            batch: BatchPolicy { max_batch: 4, max_wait: std::time::Duration::from_micros(100) },
            coalesce: Default::default(),
            workers: 1,
            queue_depth: 64,
            autotune: None,
            shed_deadline: None,
            observer: None,
            exec_mode: ExecModePolicy::ForcePanel,
            max_resident_n: Some(cap),
        })
        .unwrap();
        let inputs: Vec<SplitComplex> =
            (0..4u64).map(|i| SplitComplex::random(n, 0xB10C + i)).collect();
        let rxs: Vec<_> = inputs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
        for (rx, input) in rxs.into_iter().zip(&inputs) {
            let got = rx.recv().unwrap().unwrap();
            let want = fft_ref(input);
            assert!(got.max_abs_diff(&want) / want.max_abs().max(1.0) < 2e-4);
        }
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 4);
        assert_eq!(snap.failed, 0);
        // blocked entries never take the panel path
        assert_eq!(snap.exec_panel_groups, 0);
        assert!(snap.exec_scalar_groups >= 1);
    }

    #[test]
    fn rejects_unknown_size() {
        let svc = native_service(256, "R4,R4,R2,F8", 1);
        assert!(svc.submit(SplitComplex::random(128, 1)).is_err());
    }

    #[test]
    fn rejects_invalid_plan_at_startup() {
        let bad = FftService::start(ServiceConfig {
            plans: vec![(256, Plan::parse("R2,R2").unwrap())],
            backend: Backend::Native,
            batch: BatchPolicy::default(),
            workers: 1,
            coalesce: Default::default(),
            queue_depth: 4,
            autotune: None,
            shed_deadline: None,
            observer: None,
            exec_mode: Default::default(),
            max_resident_n: None,
        });
        assert!(bad.is_err());
    }

    #[test]
    fn rejects_autotune_without_matching_plan() {
        let prior = crate::cost::Wisdom::harvest(&mut crate::cost::SimCost::m1(1024), "m1");
        let bad = FftService::start(ServiceConfig {
            plans: vec![(256, Plan::parse("R4,R4,R2,F8").unwrap())],
            backend: Backend::Native,
            batch: BatchPolicy::default(),
            workers: 1,
            coalesce: Default::default(),
            queue_depth: 4,
            autotune: Some(AutotuneConfig::new(prior)),
            shed_deadline: None,
            observer: None,
            exec_mode: Default::default(),
            max_resident_n: None,
        });
        assert!(bad.is_err());
    }

    #[test]
    fn rejects_autotune_on_pjrt_backend() {
        let prior = crate::cost::Wisdom::harvest(&mut crate::cost::SimCost::m1(256), "m1");
        let bad = FftService::start(ServiceConfig {
            plans: vec![(256, Plan::parse("R4,R4,R2,F8").unwrap())],
            backend: Backend::Pjrt { artifacts_dir: "artifacts".into() },
            batch: BatchPolicy::default(),
            workers: 1,
            coalesce: Default::default(),
            queue_depth: 4,
            autotune: Some(AutotuneConfig::new(prior)),
            shed_deadline: None,
            observer: None,
            exec_mode: Default::default(),
            max_resident_n: None,
        });
        assert!(bad.is_err());
    }

    #[test]
    fn autotuned_service_samples_and_serves_correctly() {
        let n = 256;
        let prior = crate::cost::Wisdom::harvest(&mut crate::cost::SimCost::m1(n), "m1");
        let mut at = AutotuneConfig::new(prior);
        at.sample_period = 2;
        let svc = FftService::start(ServiceConfig {
            plans: vec![(n, Plan::parse("R4,R4,R2,F8").unwrap())],
            backend: Backend::Native,
            batch: BatchPolicy { max_batch: 8, max_wait: std::time::Duration::from_micros(50) },
            workers: 2,
            coalesce: Default::default(),
            queue_depth: 64,
            autotune: Some(at),
            shed_deadline: None,
            observer: None,
            exec_mode: Default::default(),
            max_resident_n: None,
        })
        .unwrap();
        for i in 0..40u64 {
            let input = SplitComplex::random(n, i);
            let got = svc.transform(input.clone()).unwrap();
            let want = fft_ref(&input);
            assert!(got.max_abs_diff(&want) / want.max_abs().max(1.0) < 1e-4);
        }
        // the autotuner drains asynchronously; wait for proof of sampling
        let deadline = Instant::now() + std::time::Duration::from_secs(2);
        let sampled = loop {
            let status = svc.autotune_status().expect("autotune status");
            if status.batches_ingested + status.batches_dropped >= 1 {
                break true;
            }
            if Instant::now() >= deadline {
                break false;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        };
        assert!(sampled, "sampling never reached the autotuner");
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 40);
        assert_eq!(snap.failed, 0);
    }

    #[test]
    fn many_concurrent_requests_all_complete() {
        let svc = native_service(256, "R4,R4,R4,R2,R2", 2);
        let inputs: Vec<SplitComplex> = (0..50).map(|i| SplitComplex::random(256, i)).collect();
        let want0 = fft_ref(&inputs[0]);
        let rxs: Vec<_> = inputs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
        let results: Vec<SplitComplex> = rxs.into_iter().map(|r| r.recv().unwrap().unwrap()).collect();
        assert!(results[0].max_abs_diff(&want0) / want0.max_abs().max(1.0) < 1e-4);
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 50);
        assert!(snap.batches >= 1);
        assert!(snap.mean_batch_size >= 1.0);
    }

    #[test]
    fn grouped_batched_execution_matches_reference() {
        // Burst-submit a mixed-n stream so workers pull multi-request
        // batches, split them into same-n groups, and run the groups
        // through the batched kernels; every reply must still be the
        // right transform of the right input.
        let sizes = [64usize, 256];
        let svc = FftService::start(ServiceConfig {
            plans: vec![
                // log2(64) = 6 stages: R4(2) + R2(1) + F8(3)
                (64, Plan::parse("R4,R2,F8").unwrap()),
                (256, Plan::parse("R4,R4,R2,F8").unwrap()),
            ],
            backend: Backend::Native,
            batch: BatchPolicy { max_batch: 16, max_wait: std::time::Duration::from_millis(2) },
            workers: 1,
            coalesce: Default::default(),
            queue_depth: 128,
            autotune: None,
            shed_deadline: None,
            observer: None,
            exec_mode: Default::default(),
            max_resident_n: None,
        })
        .unwrap();
        let mut pending = Vec::new();
        for i in 0..48u64 {
            let n = sizes[(i % 2) as usize];
            let input = SplitComplex::random(n, i);
            pending.push((input.clone(), svc.submit(input).unwrap()));
        }
        for (input, rx) in pending {
            let got = rx.recv().unwrap().unwrap();
            let want = fft_ref(&input);
            let rel = got.max_abs_diff(&want) / want.max_abs().max(1.0);
            assert!(rel < 1e-4, "rel err {rel}");
        }
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 48);
        assert_eq!(snap.failed, 0);
        assert!(snap.groups >= 2, "no groups recorded");
        assert_eq!(snap.group_size_hist.iter().sum::<u64>(), snap.groups);
        // Every completed request went through exactly one group.
        let grouped = (snap.mean_group_size * snap.groups as f64).round() as u64;
        assert_eq!(grouped, snap.completed);
    }

    #[test]
    fn forced_exec_modes_agree_bitwise_and_split_the_metrics() {
        // The mode decision is a pure execution-strategy choice: the
        // same burst served ForcePanel and ForceScalar must produce
        // bit-identical replies (the run_batch contract, restated at the
        // mode-decision layer), and each service's metrics must show
        // only its forced path — marshal time strictly where panels ran.
        let n = 256;
        let mk = |policy| {
            FftService::start(ServiceConfig {
                plans: vec![(n, Plan::parse("R4,R4,R2,F8").unwrap())],
                backend: Backend::Native,
                batch: BatchPolicy {
                    max_batch: 16,
                    max_wait: std::time::Duration::from_millis(2),
                },
                coalesce: Default::default(),
                workers: 1,
                queue_depth: 64,
                autotune: None,
                shed_deadline: None,
                observer: None,
                exec_mode: policy,
                max_resident_n: None,
            })
            .unwrap()
        };
        let inputs: Vec<SplitComplex> = (0..24).map(|i| SplitComplex::random(n, i)).collect();
        let run = |svc: FftService| {
            let rxs: Vec<_> = inputs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
            let outs: Vec<SplitComplex> =
                rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
            (outs, svc.shutdown())
        };
        let (panel_outs, panel_snap) = run(mk(ExecModePolicy::ForcePanel));
        let (scalar_outs, scalar_snap) = run(mk(ExecModePolicy::ForceScalar));
        for (i, (p, s)) in panel_outs.iter().zip(&scalar_outs).enumerate() {
            assert_eq!(p.re, s.re, "request {i}: panel and scalar replies diverged");
            assert_eq!(p.im, s.im, "request {i}: panel and scalar replies diverged");
        }
        // correctness against the reference, not just mutual agreement
        let want0 = fft_ref(&inputs[0]);
        assert!(panel_outs[0].max_abs_diff(&want0) / want0.max_abs().max(1.0) < 1e-4);
        assert_eq!(scalar_snap.exec_panel_groups, 0);
        assert_eq!(scalar_snap.exec_panel_requests, 0);
        assert_eq!(scalar_snap.marshal_time, std::time::Duration::ZERO);
        assert_eq!(scalar_snap.exec_scalar_groups, scalar_snap.groups);
        assert_eq!(scalar_snap.exec_scalar_requests, 24);
        // the burst leaves a deep queue, so at least one pull groups >= 2
        assert!(panel_snap.exec_panel_groups >= 1, "burst never formed a panel group");
        assert!(panel_snap.marshal_time > std::time::Duration::ZERO);
        assert_eq!(panel_snap.exec_panel_groups + panel_snap.exec_scalar_groups, panel_snap.groups);
        assert_eq!(panel_snap.exec_panel_requests + panel_snap.exec_scalar_requests, 24);
    }

    #[test]
    fn static_mode_tables_pin_the_m1_flip() {
        // The priced decision on the m1 model: a small unfused plan runs
        // scalar-sequential (per-transform cost is flat, so the panel
        // only adds the transpose), while the large radix-4 ladder's
        // batched amortization beats its marshal bill. Forced policies
        // override both; class 0 is always scalar.
        let small = Plan::parse("R4,R2,F8").unwrap(); // n=64
        let large = Plan::parse("R4,R4,R4,R4,R2,R2").unwrap(); // n=1024
        let auto_small =
            static_mode_table(ExecModePolicy::Auto, TransformKind::Forward, &small, 64);
        let auto_large =
            static_mode_table(ExecModePolicy::Auto, TransformKind::Forward, &large, 1024);
        assert_eq!(auto_small[batch_class(16)], ExecMode::ScalarSequential);
        assert_eq!(auto_large[batch_class(16)], ExecMode::Panel);
        assert_eq!(auto_large[0], ExecMode::ScalarSequential, "class 0 is always scalar");
        let forced_p =
            static_mode_table(ExecModePolicy::ForcePanel, TransformKind::Forward, &small, 64);
        assert_eq!(forced_p[0], ExecMode::ScalarSequential);
        assert!(forced_p[1..].iter().all(|m| *m == ExecMode::Panel));
        let forced_s =
            static_mode_table(ExecModePolicy::ForceScalar, TransformKind::Forward, &large, 1024);
        assert!(forced_s.iter().all(|m| *m == ExecMode::ScalarSequential));
    }

    #[test]
    fn serves_every_kind_correctly() {
        // One configured (n, plan) entry serves forward/inverse at n and
        // the real pair at 2n.
        let n = 128;
        let svc = native_service(n, "R4,R2,F16", 1);
        let input = SplitComplex::random(n, 5);
        let fwd = svc.transform_kind(input.clone(), TransformKind::Forward).unwrap();
        let want = fft_ref(&input);
        assert!(fwd.max_abs_diff(&want) / want.max_abs().max(1.0) < 1e-4);
        let back = svc.transform_kind(fwd, TransformKind::Inverse).unwrap();
        assert!(back.max_abs_diff(&input) / input.max_abs().max(1.0) < 1e-4);
        let mut real = SplitComplex::random(2 * n, 6);
        real.im.iter_mut().for_each(|v| *v = 0.0);
        let spectrum = svc.transform_kind(real.clone(), TransformKind::RealForward).unwrap();
        let want_r = fft_ref(&real);
        assert!(spectrum.max_abs_diff(&want_r) / want_r.max_abs().max(1.0) < 1e-4);
        let signal = svc.transform_kind(spectrum, TransformKind::RealInverse).unwrap();
        assert!(signal.max_abs_diff(&real) / real.max_abs().max(1.0) < 1e-4);
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 4);
        assert_eq!(snap.completed_by_kind, [1, 1, 1, 1]);
        assert_eq!(snap.failed, 0);
    }

    #[test]
    fn rejects_real_kind_at_unserved_size() {
        let svc = native_service(256, "R4,R4,R2,F8", 1);
        // real kinds serve 2x a configured size: 512 works, 256 does not
        assert!(svc
            .submit_kind(SplitComplex::random(256, 1), TransformKind::RealForward)
            .is_err());
        assert!(svc
            .submit_kind(SplitComplex::random(512, 1), TransformKind::RealForward)
            .is_ok());
    }

    #[test]
    fn coalescing_service_merges_underfilled_groups_and_stays_correct() {
        // One worker, pulls capped at 2, coalescing toward groups of 4
        // with a generous deadline: under-filled pulls must be held and
        // merged rather than executed alone, and every reply must still
        // be the right transform. (Exact hold/flush timing is covered by
        // the deterministic harness; this exercises the live wiring.)
        let n = 256;
        let svc = FftService::start(ServiceConfig {
            plans: vec![(n, Plan::parse("R4,R4,R2,F8").unwrap())],
            backend: Backend::Native,
            batch: BatchPolicy { max_batch: 2, max_wait: std::time::Duration::from_millis(5) },
            coalesce: CoalescePolicy::hold(8, 4, std::time::Duration::from_millis(100)),
            workers: 1,
            queue_depth: 64,
            autotune: None,
            shed_deadline: None,
            observer: None,
            exec_mode: Default::default(),
            max_resident_n: None,
        })
        .unwrap();
        let inputs: Vec<SplitComplex> = (0..8).map(|i| SplitComplex::random(n, i)).collect();
        let rxs: Vec<_> = inputs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
        for (input, rx) in inputs.iter().zip(rxs) {
            let got = rx.recv().unwrap().unwrap();
            let want = fft_ref(input);
            assert!(got.max_abs_diff(&want) / want.max_abs().max(1.0) < 1e-4);
        }
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 8);
        assert_eq!(snap.failed, 0);
        assert!(snap.coalesced_flushes >= 1, "nothing was ever held: {snap:?}");
        assert!(snap.max_held_age > std::time::Duration::ZERO);
    }

    #[test]
    fn backpressure_fails_fast() {
        // queue_depth 1 and a worker stalled behind a batch window: the
        // third-plus submits must see "queue full" rather than blocking.
        let svc = FftService::start(ServiceConfig {
            plans: vec![(1024, Plan::parse("R2,R2,R2,R2,R2,R2,R2,R2,R2,R2").unwrap())],
            backend: Backend::Native,
            batch: BatchPolicy { max_batch: 1, max_wait: std::time::Duration::ZERO },
            workers: 1,
            coalesce: Default::default(),
            queue_depth: 1,
            autotune: None,
            shed_deadline: None,
            observer: None,
            exec_mode: Default::default(),
            max_resident_n: None,
        })
        .unwrap();
        let mut rejected = 0;
        let mut receivers = Vec::new();
        for i in 0..200 {
            match svc.submit(SplitComplex::random(1024, i)) {
                Ok(rx) => receivers.push(rx),
                Err(_) => rejected += 1,
            }
        }
        for rx in receivers {
            let _ = rx.recv();
        }
        let snap = svc.shutdown();
        assert_eq!(snap.completed + snap.failed, 200);
        assert_eq!(snap.failed as usize, rejected);
    }

    #[test]
    fn shutdown_drains_pending_work() {
        let svc = native_service(256, "F8,F8,R2,R2", 1);
        let rxs: Vec<_> = (0..10)
            .map(|i| svc.submit(SplitComplex::random(256, i)).unwrap())
            .collect();
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 10);
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
    }

    #[test]
    fn submit_after_begin_shutdown_is_typed_not_panic() {
        // Deterministic submit/stop interleave: accept one request, fence
        // with begin_shutdown, then submit again — the second submit must
        // return the typed shutdown rejection (the old path panicked on
        // `tx.as_ref().unwrap()` when it lost the race to `tx.take()`).
        let svc = native_service(256, "R4,R4,R2,F8", 1);
        let rx = svc.try_submit_kind(SplitComplex::random(256, 1), TransformKind::Forward);
        assert!(rx.is_ok());
        svc.begin_shutdown();
        let err = svc
            .try_submit_kind(SplitComplex::random(256, 2), TransformKind::Forward)
            .unwrap_err();
        assert_eq!(err, Rejected::ShuttingDown);
        // stringly API keeps the same message for existing callers
        let err2 = svc.submit(SplitComplex::random(256, 3)).unwrap_err();
        assert_eq!(err2.to_string(), "service is shutting down");
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.rejected_stopped, 2);
        assert_eq!(snap.failed, 2);
        assert!(rx.unwrap().recv().unwrap().is_ok());
    }

    #[test]
    fn concurrent_submits_race_shutdown_without_panicking() {
        // Hammer submits from two threads while the main thread shuts the
        // service down mid-stream: every submit must resolve to Ok or a
        // typed rejection — never a panic — and the counters must account
        // for every attempt exactly.
        let svc = Arc::new(native_service(256, "R4,R4,R2,F8", 2));
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for t in 0..2u64 {
            let svc = svc.clone();
            let stop = stop.clone();
            handles.push(std::thread::spawn(move || {
                let mut ok = 0u64;
                let mut rejected = 0u64;
                let mut replies = Vec::new();
                for i in 0..300u64 {
                    if stop.load(Ordering::Relaxed) && i > 50 {
                        break;
                    }
                    match svc
                        .try_submit_kind(SplitComplex::random(256, t * 1000 + i), TransformKind::Forward)
                    {
                        Ok(rx) => {
                            ok += 1;
                            replies.push(rx);
                        }
                        Err(Rejected::ShuttingDown) | Err(Rejected::QueueFull) => rejected += 1,
                        Err(other) => panic!("unexpected rejection: {other:?}"),
                    }
                }
                for rx in replies {
                    let _ = rx.recv();
                }
                (ok, rejected)
            }));
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
        svc.begin_shutdown();
        stop.store(true, Ordering::Relaxed);
        let mut ok = 0u64;
        let mut rejected = 0u64;
        for h in handles {
            let (o, r) = h.join().expect("submitter thread panicked");
            ok += o;
            rejected += r;
        }
        let svc = Arc::try_unwrap(svc).ok().expect("submitters still hold the service");
        let snap = svc.shutdown();
        assert_eq!(snap.submitted, ok);
        assert_eq!(snap.completed, ok);
        assert_eq!(snap.rejected_full + snap.rejected_stopped, rejected);
        assert_eq!(snap.failed, rejected);
    }

    #[test]
    fn typed_rejections_count_into_split_metrics() {
        // Validation and backpressure rejections each land in their own
        // counter — and in `failed` — so operators can tell overload from
        // client error (the old path only counted queue-full).
        let svc = native_service(256, "R4,R4,R2,F8", 1);
        let err = svc
            .try_submit_kind(SplitComplex::random(128, 1), TransformKind::Forward)
            .unwrap_err();
        assert!(matches!(err, Rejected::Invalid(_)));
        assert!(err.to_string().contains("unsupported"));
        let snap = svc.shutdown();
        assert_eq!(snap.rejected_invalid, 1);
        assert_eq!(snap.rejected_full, 0);
        assert_eq!(snap.failed, 1);
    }

    #[test]
    fn shed_deadline_sheds_stale_requests_with_typed_error() {
        // One worker pinned behind a long first batch window; the shed
        // budget is tiny, so requests that sat in the queue past it must
        // come back Overloaded while fresh ones still complete. Exact
        // shed timing is pinned on the virtual-clock harness; this
        // exercises the live partition path end to end.
        let svc = FftService::start(ServiceConfig {
            plans: vec![(256, Plan::parse("R4,R4,R2,F8").unwrap())],
            backend: Backend::Native,
            batch: BatchPolicy { max_batch: 4, max_wait: std::time::Duration::from_micros(100) },
            coalesce: Default::default(),
            workers: 1,
            queue_depth: 64,
            autotune: None,
            shed_deadline: Some(std::time::Duration::from_micros(100)),
            observer: None,
            exec_mode: Default::default(),
            max_resident_n: None,
        })
        .unwrap();
        // slack = shed_deadline - max_wait = 0: anything that waits at
        // all is shed, so burst enough to leave stragglers in the queue.
        let rxs: Vec<_> = (0..32)
            .map(|i| svc.submit(SplitComplex::random(256, i)).unwrap())
            .collect();
        let mut completed = 0u64;
        let mut shed = 0u64;
        for rx in rxs {
            match rx.recv().unwrap() {
                Ok(_) => completed += 1,
                Err(e) => {
                    assert!(e.to_string().contains("overloaded"), "unexpected error: {e}");
                    shed += 1;
                }
            }
        }
        let snap = svc.shutdown();
        assert_eq!(completed + shed, 32);
        assert_eq!(snap.completed, completed);
        assert_eq!(snap.rejected_shed, shed);
        assert_eq!(snap.failed, shed);
    }
}
