//! Dynamic batching: collect requests until a size or deadline trigger.
//!
//! The classic serving tradeoff (small batches = low latency, large
//! batches = high throughput) applied to FFT requests: the first request
//! of a batch starts a deadline window; the batch closes when either
//! `max_batch` requests have arrived or the window expires.
//!
//! [`collect_batch`] is the one implementation of that deadline loop; the
//! owning [`Batcher`] and the service workers (which share one receiver
//! behind a mutex) both call it. [`group_by_key`] then splits a pulled
//! batch into jointly-executable groups — the service groups by FFT size
//! so each group can run through one batched `CompiledPlan::run_batch`.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Close a batch at this many requests.
    pub max_batch: usize,
    /// ... or this long after the first request arrived.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(200) }
    }
}

/// Pulls batches off an mpsc receiver according to a policy.
pub struct Batcher<T> {
    rx: Receiver<T>,
    pub policy: BatchPolicy,
}

impl<T> Batcher<T> {
    pub fn new(rx: Receiver<T>, policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1);
        Batcher { rx, policy }
    }

    /// Block for the next batch. Returns `None` when the channel is
    /// closed and drained (service shutdown).
    pub fn next_batch(&self) -> Option<Vec<T>> {
        collect_batch(&self.rx, self.policy)
    }
}

/// Pull one batch off `rx` under `policy`: block for the first item,
/// then collect until `max_batch` items or `max_wait` after the first.
/// Returns `None` when the channel is closed and drained. This is the
/// single batching deadline loop, shared by [`Batcher`] and the service
/// workers (which hold the receiver behind a mutex).
pub fn collect_batch<T>(rx: &Receiver<T>, policy: BatchPolicy) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout | RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

/// Split a batch into groups sharing a key, preserving arrival order
/// both across groups (first-seen order) and within each group.
pub fn group_by_key<T, K: Eq + Hash + Copy>(
    items: Vec<T>,
    key: impl Fn(&T) -> K,
) -> Vec<(K, Vec<T>)> {
    let mut order: Vec<K> = Vec::new();
    let mut map: HashMap<K, Vec<T>> = HashMap::new();
    for item in items {
        let k = key(&item);
        match map.entry(k) {
            std::collections::hash_map::Entry::Vacant(e) => {
                order.push(k);
                e.insert(vec![item]);
            }
            std::collections::hash_map::Entry::Occupied(mut e) => e.get_mut().push(item),
        }
    }
    order.into_iter().map(|k| (k, map.remove(&k).unwrap())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) });
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().unwrap(), vec![4, 5, 6, 7]);
        assert_eq!(b.next_batch().unwrap(), vec![8, 9]);
    }

    #[test]
    fn deadline_closes_partial_batch() {
        let (tx, rx) = channel();
        tx.send(1u32).unwrap();
        let b = Batcher::new(rx, BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(5) });
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![1]);
        assert!(t0.elapsed() >= Duration::from_millis(4));
        drop(tx);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn closed_empty_channel_returns_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy::default());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn collect_batch_matches_batcher_semantics() {
        // Both entry points share one implementation; exercise the free
        // function directly off a raw receiver.
        let (tx, rx) = channel();
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(20) };
        assert_eq!(collect_batch(&rx, policy).unwrap(), vec![0, 1, 2]);
        assert_eq!(collect_batch(&rx, policy).unwrap(), vec![3, 4]);
        drop(tx);
        assert!(collect_batch(&rx, policy).is_none());
    }

    #[test]
    fn group_by_key_preserves_order() {
        let items = vec![(256, 'a'), (1024, 'b'), (256, 'c'), (64, 'd'), (1024, 'e')];
        let groups = group_by_key(items, |&(n, _)| n);
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].0, 256);
        assert_eq!(groups[0].1, vec![(256, 'a'), (256, 'c')]);
        assert_eq!(groups[1].0, 1024);
        assert_eq!(groups[1].1, vec![(1024, 'b'), (1024, 'e')]);
        assert_eq!(groups[2].0, 64);
        assert_eq!(groups[2].1, vec![(64, 'd')]);
    }

    #[test]
    fn group_by_key_on_uniform_batch_is_one_group() {
        let groups = group_by_key(vec![1, 2, 3], |_| 256usize);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].1, vec![1, 2, 3]);
    }

    #[test]
    fn items_arriving_during_window_join_batch() {
        let (tx, rx) = channel();
        let b = Batcher::new(rx, BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(100) });
        let sender = std::thread::spawn(move || {
            tx.send(1).unwrap();
            std::thread::sleep(Duration::from_millis(5));
            tx.send(2).unwrap();
            tx.send(3).unwrap();
            // drop tx: batch should close on disconnect, not hang
        });
        let batch = b.next_batch().unwrap();
        sender.join().unwrap();
        assert_eq!(batch, vec![1, 2, 3]);
    }
}
