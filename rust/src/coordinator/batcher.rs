//! Dynamic batching: collect requests until a size or deadline trigger.
//!
//! The classic serving tradeoff (small batches = low latency, large
//! batches = high throughput) applied to FFT requests: the first request
//! of a batch starts a deadline window; the batch closes when either
//! `max_batch` requests have arrived or the window expires.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Close a batch at this many requests.
    pub max_batch: usize,
    /// ... or this long after the first request arrived.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(200) }
    }
}

/// Pulls batches off an mpsc receiver according to a policy.
pub struct Batcher<T> {
    rx: Receiver<T>,
    pub policy: BatchPolicy,
}

impl<T> Batcher<T> {
    pub fn new(rx: Receiver<T>, policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1);
        Batcher { rx, policy }
    }

    /// Block for the next batch. Returns `None` when the channel is
    /// closed and drained (service shutdown).
    pub fn next_batch(&self) -> Option<Vec<T>> {
        // Block for the first item.
        let first = self.rx.recv().ok()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.policy.max_wait;
        while batch.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(item) => batch.push(item),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) });
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().unwrap(), vec![4, 5, 6, 7]);
        assert_eq!(b.next_batch().unwrap(), vec![8, 9]);
    }

    #[test]
    fn deadline_closes_partial_batch() {
        let (tx, rx) = channel();
        tx.send(1u32).unwrap();
        let b = Batcher::new(rx, BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(5) });
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![1]);
        assert!(t0.elapsed() >= Duration::from_millis(4));
        drop(tx);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn closed_empty_channel_returns_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy::default());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn items_arriving_during_window_join_batch() {
        let (tx, rx) = channel();
        let b = Batcher::new(rx, BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(100) });
        let sender = std::thread::spawn(move || {
            tx.send(1).unwrap();
            std::thread::sleep(Duration::from_millis(5));
            tx.send(2).unwrap();
            tx.send(3).unwrap();
            // drop tx: batch should close on disconnect, not hang
        });
        let batch = b.next_batch().unwrap();
        sender.join().unwrap();
        assert_eq!(batch, vec![1, 2, 3]);
    }
}
