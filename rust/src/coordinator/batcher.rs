//! Dynamic batching: collect requests until a size or deadline trigger.
//!
//! The classic serving tradeoff (small batches = low latency, large
//! batches = high throughput) applied to FFT requests: the first request
//! of a batch starts a deadline window; the batch closes when either
//! `max_batch` requests have arrived or the window expires.
//!
//! [`collect_batch`] is the one implementation of that deadline loop; the
//! owning [`Batcher`] and the service workers (which share one receiver
//! behind a mutex) both call it. [`group_by_key`] then splits a pulled
//! batch into jointly-executable groups — the service groups by FFT size
//! so each group can run through one batched `CompiledPlan::run_batch`.
//!
//! [`CoalesceState`] adds the cross-batch layer on top: an under-filled
//! same-key group can stay *open across pull windows* when the queue is
//! deep, merging with later arrivals of the same key until it fills, its
//! hold budget runs out, or a member approaches its latency deadline —
//! and leftover singletons enter a second-level queue that pairs them
//! with future same-key traffic instead of letting them bypass batching
//! entirely. All timing decisions take the caller's `Instant`, so the
//! whole state machine is drivable from an injected virtual clock (the
//! deterministic coordinator harness in `tests/harness/`).

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Close a batch at this many requests.
    pub max_batch: usize,
    /// ... or this long after the first request arrived.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(200) }
    }
}

/// Pulls batches off an mpsc receiver according to a policy.
pub struct Batcher<T> {
    rx: Receiver<T>,
    pub policy: BatchPolicy,
}

impl<T> Batcher<T> {
    pub fn new(rx: Receiver<T>, policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1);
        Batcher { rx, policy }
    }

    /// Block for the next batch. Returns `None` when the channel is
    /// closed and drained (service shutdown).
    pub fn next_batch(&self) -> Option<Vec<T>> {
        collect_batch(&self.rx, self.policy)
    }
}

/// Pull one batch off `rx` under `policy`: block for the first item,
/// then collect until `max_batch` items or `max_wait` after the first.
/// Returns `None` when the channel is closed and drained. This is the
/// single batching deadline loop, shared by [`Batcher`] and the service
/// workers (which hold the receiver behind a mutex).
pub fn collect_batch<T>(rx: &Receiver<T>, policy: BatchPolicy) -> Option<Vec<T>> {
    collect_batch_until(rx, policy, None)
}

/// [`collect_batch`] with an optional wake deadline for the *first* item:
/// a worker holding coalesced groups must not block indefinitely waiting
/// for fresh traffic while a held request's latency budget burns. When
/// `wake` passes before anything arrives, the call returns an **empty**
/// batch so the caller can age and flush its held state; `None` still
/// means the channel is closed and drained.
pub fn collect_batch_until<T>(
    rx: &Receiver<T>,
    policy: BatchPolicy,
    wake: Option<Instant>,
) -> Option<Vec<T>> {
    let first = match wake {
        None => rx.recv().ok()?,
        Some(w) => {
            let now = Instant::now();
            if now >= w {
                match rx.try_recv() {
                    Ok(item) => item,
                    Err(TryRecvError::Empty) => return Some(Vec::new()),
                    Err(TryRecvError::Disconnected) => return None,
                }
            } else {
                match rx.recv_timeout(w - now) {
                    Ok(item) => item,
                    Err(RecvTimeoutError::Timeout) => return Some(Vec::new()),
                    Err(RecvTimeoutError::Disconnected) => return None,
                }
            }
        }
    };
    let mut batch = vec![first];
    let mut deadline = Instant::now() + policy.max_wait;
    if let Some(w) = wake {
        // The collection window must not eat the held work's reserved
        // flush slack: a first item arriving just before the wake would
        // otherwise extend the pull a full extra window past it.
        deadline = deadline.min(w);
    }
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout | RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

/// Split a batch into groups sharing a key, preserving arrival order
/// both across groups (first-seen order) and within each group.
pub fn group_by_key<T, K: Eq + Hash + Copy>(
    items: Vec<T>,
    key: impl Fn(&T) -> K,
) -> Vec<(K, Vec<T>)> {
    let mut order: Vec<K> = Vec::new();
    let mut map: HashMap<K, Vec<T>> = HashMap::new();
    for item in items {
        let k = key(&item);
        match map.entry(k) {
            std::collections::hash_map::Entry::Vacant(e) => {
                order.push(k);
                e.insert(vec![item]);
            }
            std::collections::hash_map::Entry::Occupied(mut e) => e.get_mut().push(item),
        }
    }
    order.into_iter().map(|k| (k, map.remove(&k).unwrap())).collect()
}

/// Cross-batch coalescing policy.
///
/// Holding trades latency for effective group size: an under-filled
/// same-key group costs one more pull window of latency per hold but
/// amortizes twiddle loads and memory round trips over more transforms
/// when it finally runs. Three bounds keep latency SLOs intact: the
/// per-group hold budget (`max_hold_windows`), the per-request deadline
/// (`deadline`, checked against each member's enqueue time with one
/// pull window of slack reserved for the flush itself), and the
/// backlog gate (`min_backlog` — groups only *start* holding when the
/// pull that produced them saw a deep queue; traffic that trickles in
/// runs straight through).
#[derive(Debug, Clone, Copy)]
pub struct CoalescePolicy {
    /// Pull windows an under-filled group may stay open (0 = coalescing
    /// disabled; every group executes in its own pull).
    pub max_hold_windows: u32,
    /// Stop holding once a group reaches this many requests.
    pub target_group: usize,
    /// Only start holding when the pull carried at least this many
    /// requests (the queue-is-deep gate). Singletons are exempt: the
    /// second-level queue pairs them within the deadline budget
    /// regardless of backlog.
    pub min_backlog: usize,
    /// Per-request end-to-end latency budget; a held request flushes
    /// early enough to leave one pull window for execution. The bound
    /// is exact for a single worker admitting at its wake deadlines
    /// (the property test pins it); with a worker pool, handoff of the
    /// shared receiver lock can delay a wake by up to ~two further pull
    /// windows plus the sibling's execution time — size `deadline`
    /// with that slop in mind.
    pub deadline: Duration,
}

impl Default for CoalescePolicy {
    /// Disabled: identical serving behavior to the pre-coalescing loop.
    fn default() -> Self {
        CoalescePolicy {
            max_hold_windows: 0,
            target_group: 4,
            min_backlog: 4,
            deadline: Duration::from_millis(5),
        }
    }
}

impl CoalescePolicy {
    /// Enabled policy: hold up to `windows` pulls, aiming for groups of
    /// `target`, within a per-request `deadline`.
    pub fn hold(windows: u32, target: usize, deadline: Duration) -> CoalescePolicy {
        CoalescePolicy {
            max_hold_windows: windows,
            target_group: target.max(2),
            min_backlog: 2,
            deadline,
        }
    }

    pub fn enabled(&self) -> bool {
        self.max_hold_windows > 0
    }
}

/// Why a group left the coalescer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushReason {
    /// Coalescing disabled or not applicable — executed in its own pull.
    Direct,
    /// Reached `target_group`.
    Filled,
    /// A member's latency budget forced the flush.
    Deadline,
    /// The hold budget (`max_hold_windows`) ran out.
    HoldExpired,
    /// The pull saw a shallow queue; holding wasn't worth it.
    ShallowQueue,
    /// Service shutdown drained the state.
    Shutdown,
}

/// A group ready to execute now, with its coalescing provenance.
#[derive(Debug)]
pub struct ReadyGroup<K, T> {
    pub key: K,
    /// Members in arrival order (held members precede later arrivals).
    pub items: Vec<T>,
    /// Pull windows the group stayed held (0 = ran straight through).
    pub held_windows: u32,
    /// Wall age of the hold at flush time (zero when not held).
    pub held_age: Duration,
    /// Members that joined while the group was held open.
    pub gained: usize,
    /// Whether this group exists because a leftover singleton was paired
    /// with later same-key traffic by the second-level queue.
    pub paired_singletons: bool,
    pub reason: FlushReason,
}

struct Held<K, T> {
    key: K,
    items: Vec<T>,
    /// Pull windows survived so far.
    windows: u32,
    /// When the group was first held.
    since: Instant,
    /// Members merged in after the first hold decision.
    gained: usize,
    /// Started life as a leftover singleton.
    was_singleton: bool,
}

impl<K: Copy, T> Held<K, T> {
    fn into_ready(self, now: Instant, reason: FlushReason) -> ReadyGroup<K, T> {
        ReadyGroup {
            key: self.key,
            paired_singletons: self.was_singleton && self.items.len() >= 2,
            held_windows: self.windows,
            held_age: if self.windows > 0 {
                now.saturating_duration_since(self.since)
            } else {
                Duration::ZERO
            },
            gained: self.gained,
            items: self.items,
            reason,
        }
    }
}

/// The cross-batch coalescing state machine (see module doc and
/// DESIGN.md §coalesce). One per worker; **every** timing decision takes
/// the caller's `now`, so tests drive it with a virtual clock and the
/// service drives it with `Instant::now()`.
pub struct CoalesceState<K: Eq + Hash + Copy, T> {
    policy: CoalescePolicy,
    /// Hold budget per member: `deadline` minus one pull window (the
    /// batcher's `max_wait`), reserved as flush slack. Computed once so
    /// every flush path shares the same due-time formula.
    slack: Duration,
    /// Under-filled groups of >= 2 held open across pulls.
    held: Vec<Held<K, T>>,
    /// Second-level queue: leftover singletons awaiting a same-key
    /// partner. At most one entry per key (same-key singletons merge).
    singles: Vec<Held<K, T>>,
}

impl<K: Eq + Hash + Copy, T> CoalesceState<K, T> {
    pub fn new(policy: CoalescePolicy, window: Duration) -> CoalesceState<K, T> {
        CoalesceState {
            policy,
            slack: policy.deadline.saturating_sub(window),
            held: Vec::new(),
            singles: Vec::new(),
        }
    }

    pub fn policy(&self) -> &CoalescePolicy {
        &self.policy
    }

    /// Held under-filled groups (size >= 2).
    pub fn held_groups(&self) -> usize {
        self.held.len()
    }

    /// Singletons waiting in the second-level queue.
    pub fn held_singletons(&self) -> usize {
        self.singles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.held.is_empty() && self.singles.is_empty()
    }

    /// Latest instant a member enqueued at `enq` may still be held:
    /// one pull window before its deadline expires.
    fn due(&self, enq: Instant) -> Instant {
        enq + self.slack
    }

    /// Earliest instant by which some held member must flush — the
    /// worker's wake deadline for its next pull. `None` when nothing is
    /// held.
    pub fn next_flush_due(&self, enqueued: impl Fn(&T) -> Instant) -> Option<Instant> {
        self.held
            .iter()
            .chain(self.singles.iter())
            .flat_map(|h| h.items.iter().map(&enqueued))
            .min()
            .map(|enq| self.due(enq))
    }

    /// Feed one pulled batch (possibly empty — a wake-deadline pull) and
    /// get back every group that must execute now. Held groups merge
    /// with same-key arrivals (held members first: FIFO per key is
    /// preserved), under-filled groups are held or flushed per policy,
    /// and everything else ages one window.
    pub fn admit(
        &mut self,
        batch: Vec<T>,
        now: Instant,
        key: impl Fn(&T) -> K,
        enqueued: impl Fn(&T) -> Instant,
    ) -> Vec<ReadyGroup<K, T>> {
        self.admit_with(batch, now, key, enqueued, |_, _, _| {})
    }

    /// [`admit`](Self::admit) with a hold observer: `on_hold(key, size,
    /// windows)` fires each time a group (or singleton) is decided *held*
    /// for another pull window — `windows` is the hold count including
    /// this one. Groups that merely age through an empty pull do not
    /// re-fire; the flight recorder gets one event per hold decision on
    /// admitted traffic.
    pub fn admit_with(
        &mut self,
        batch: Vec<T>,
        now: Instant,
        key: impl Fn(&T) -> K,
        enqueued: impl Fn(&T) -> Instant,
        mut on_hold: impl FnMut(&K, usize, u32),
    ) -> Vec<ReadyGroup<K, T>> {
        let backlog = batch.len();
        let groups = group_by_key(batch, &key);
        if !self.policy.enabled() {
            return groups
                .into_iter()
                .map(|(k, items)| ReadyGroup {
                    key: k,
                    items,
                    held_windows: 0,
                    held_age: Duration::ZERO,
                    gained: 0,
                    paired_singletons: false,
                    reason: FlushReason::Direct,
                })
                .collect();
        }
        let mut ready = Vec::new();
        let touched: Vec<K> = groups.iter().map(|(k, _)| *k).collect();
        // Age (and flush) overdue held work *before* executing this
        // pull's groups: a deadline-driven flush must not queue behind
        // fresh traffic's execution time.
        self.age_untouched(now, &touched, &enqueued, &mut ready);
        for (k, mut items) in groups {
            let entry = if let Some(pos) = self.held.iter().position(|h| h.key == k) {
                let mut h = self.held.swap_remove(pos);
                h.gained += items.len();
                h.items.append(&mut items);
                h
            } else if let Some(pos) = self.singles.iter().position(|h| h.key == k) {
                let mut h = self.singles.swap_remove(pos);
                h.gained += items.len();
                h.items.append(&mut items);
                h
            } else {
                Held { key: k, items, windows: 0, since: now, gained: 0, was_singleton: false }
            };
            self.decide(entry, now, backlog, &enqueued, &mut on_hold, &mut ready);
        }
        ready
    }

    /// Route one (possibly merged) entry: execute now or keep holding.
    fn decide(
        &mut self,
        mut entry: Held<K, T>,
        now: Instant,
        backlog: usize,
        enqueued: &impl Fn(&T) -> Instant,
        on_hold: &mut impl FnMut(&K, usize, u32),
        ready: &mut Vec<ReadyGroup<K, T>>,
    ) {
        let size = entry.items.len();
        let deadline_hit = entry.items.iter().any(|t| now >= self.due(enqueued(t)));
        if size >= self.policy.target_group {
            ready.push(entry.into_ready(now, FlushReason::Filled));
        } else if deadline_hit {
            ready.push(entry.into_ready(now, FlushReason::Deadline));
        } else if entry.windows >= self.policy.max_hold_windows {
            ready.push(entry.into_ready(now, FlushReason::HoldExpired));
        } else if size >= 2 && backlog < self.policy.min_backlog && entry.windows == 0 {
            // Queue too shallow to justify opening a hold. (Singletons
            // are exempt: pairing them is the second-level queue's job.)
            ready.push(entry.into_ready(now, FlushReason::ShallowQueue));
        } else {
            entry.windows += 1;
            on_hold(&entry.key, size, entry.windows);
            if size == 1 {
                entry.was_singleton = true;
                self.singles.push(entry);
            } else {
                self.held.push(entry);
            }
        }
    }

    fn age_untouched(
        &mut self,
        now: Instant,
        touched: &[K],
        enqueued: &impl Fn(&T) -> Instant,
        ready: &mut Vec<ReadyGroup<K, T>>,
    ) {
        // `due()` inlined via the shared `slack` (calling the method in
        // the closure would borrow all of self against the live list).
        let slack = self.slack;
        let max_hold = self.policy.max_hold_windows;
        for list in [&mut self.held, &mut self.singles] {
            let mut i = 0;
            while i < list.len() {
                if touched.contains(&list[i].key) {
                    i += 1;
                    continue;
                }
                list[i].windows += 1;
                let deadline_hit =
                    list[i].items.iter().any(|t| now >= enqueued(t) + slack);
                if deadline_hit {
                    let h = list.swap_remove(i);
                    ready.push(h.into_ready(now, FlushReason::Deadline));
                } else if list[i].windows > max_hold {
                    let h = list.swap_remove(i);
                    ready.push(h.into_ready(now, FlushReason::HoldExpired));
                } else {
                    i += 1;
                }
            }
        }
    }

    /// Flush everything (service shutdown / channel drained).
    pub fn flush_all(&mut self, now: Instant) -> Vec<ReadyGroup<K, T>> {
        self.held
            .drain(..)
            .chain(self.singles.drain(..))
            .map(|h| h.into_ready(now, FlushReason::Shutdown))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) });
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().unwrap(), vec![4, 5, 6, 7]);
        assert_eq!(b.next_batch().unwrap(), vec![8, 9]);
    }

    #[test]
    fn deadline_closes_partial_batch() {
        let (tx, rx) = channel();
        tx.send(1u32).unwrap();
        let b = Batcher::new(rx, BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(5) });
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![1]);
        assert!(t0.elapsed() >= Duration::from_millis(4));
        drop(tx);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn closed_empty_channel_returns_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy::default());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn collect_batch_matches_batcher_semantics() {
        // Both entry points share one implementation; exercise the free
        // function directly off a raw receiver.
        let (tx, rx) = channel();
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(20) };
        assert_eq!(collect_batch(&rx, policy).unwrap(), vec![0, 1, 2]);
        assert_eq!(collect_batch(&rx, policy).unwrap(), vec![3, 4]);
        drop(tx);
        assert!(collect_batch(&rx, policy).is_none());
    }

    #[test]
    fn group_by_key_preserves_order() {
        let items = vec![(256, 'a'), (1024, 'b'), (256, 'c'), (64, 'd'), (1024, 'e')];
        let groups = group_by_key(items, |&(n, _)| n);
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].0, 256);
        assert_eq!(groups[0].1, vec![(256, 'a'), (256, 'c')]);
        assert_eq!(groups[1].0, 1024);
        assert_eq!(groups[1].1, vec![(1024, 'b'), (1024, 'e')]);
        assert_eq!(groups[2].0, 64);
        assert_eq!(groups[2].1, vec![(64, 'd')]);
    }

    #[test]
    fn group_by_key_on_uniform_batch_is_one_group() {
        let groups = group_by_key(vec![1, 2, 3], |_| 256usize);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].1, vec![1, 2, 3]);
    }

    #[test]
    fn collect_batch_until_wakes_empty_on_deadline() {
        let (tx, rx) = channel::<u32>();
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(10) };
        // wake already passed, nothing queued: empty batch, not a hang
        let past = Instant::now();
        assert_eq!(collect_batch_until(&rx, policy, Some(past)).unwrap(), Vec::<u32>::new());
        // an item beats the wake deadline
        tx.send(7).unwrap();
        let soon = Instant::now() + Duration::from_millis(50);
        assert_eq!(collect_batch_until(&rx, policy, Some(soon)).unwrap(), vec![7]);
        // disconnect still reads as end-of-service
        drop(tx);
        assert!(collect_batch_until(&rx, policy, Some(Instant::now())).is_none());
        let (tx2, rx2) = channel::<u32>();
        drop(tx2);
        assert!(collect_batch_until(&rx2, policy, Some(Instant::now() + Duration::from_millis(5))).is_none());
    }

    #[test]
    fn collect_batch_until_caps_the_window_at_wake() {
        // An item arriving before the wake must not extend the
        // collection window past it — that window is the held work's
        // reserved flush slack.
        let (tx, rx) = channel();
        tx.send(1u32).unwrap();
        let policy = BatchPolicy { max_batch: 100, max_wait: Duration::from_secs(5) };
        let wake = Instant::now() + Duration::from_millis(5);
        let t0 = Instant::now();
        let batch = collect_batch_until(&rx, policy, Some(wake)).unwrap();
        assert_eq!(batch, vec![1]);
        assert!(t0.elapsed() < Duration::from_secs(1), "window not capped at wake");
        drop(tx);
    }

    // --- CoalesceState: driven entirely by fabricated instants (a base
    // Instant plus virtual offsets) — no sleeps, no wall-clock flakes.

    /// (key, seq, enqueued) test item.
    type Item = (usize, usize, Instant);

    fn coalescer(
        windows: u32,
        target: usize,
        deadline_ms: u64,
    ) -> CoalesceState<usize, Item> {
        CoalesceState::new(
            CoalescePolicy { min_backlog: 2, ..CoalescePolicy::hold(windows, target, Duration::from_millis(deadline_ms)) },
            Duration::from_micros(200),
        )
    }

    fn admit(
        c: &mut CoalesceState<usize, Item>,
        batch: Vec<Item>,
        now: Instant,
    ) -> Vec<ReadyGroup<usize, Item>> {
        c.admit(batch, now, |i| i.0, |i| i.2)
    }

    #[test]
    fn disabled_policy_passes_groups_straight_through() {
        let base = Instant::now();
        let mut c: CoalesceState<usize, Item> =
            CoalesceState::new(CoalescePolicy::default(), Duration::from_micros(200));
        let batch = vec![(64, 0, base), (256, 1, base), (64, 2, base)];
        let ready = admit(&mut c, batch, base);
        assert_eq!(ready.len(), 2);
        assert!(ready.iter().all(|g| g.reason == FlushReason::Direct && g.held_windows == 0));
        assert!(c.is_empty());
    }

    #[test]
    fn underfilled_group_is_held_then_filled_by_later_arrivals() {
        let base = Instant::now();
        let mut c = coalescer(3, 4, 50);
        // deep pull (backlog 2) with an under-filled pair: held open
        let ready = admit(&mut c, vec![(64, 0, base), (64, 1, base)], base);
        assert!(ready.is_empty());
        assert_eq!(c.held_groups(), 1);
        // next pull brings two more of the same key: group fills
        let t1 = base + Duration::from_micros(300);
        let ready = admit(&mut c, vec![(64, 2, t1), (64, 3, t1)], t1);
        assert_eq!(ready.len(), 1);
        let g = &ready[0];
        assert_eq!(g.reason, FlushReason::Filled);
        assert_eq!(g.held_windows, 1);
        assert_eq!(g.gained, 2);
        assert!(g.held_age >= Duration::from_micros(300));
        // FIFO: held members precede the new arrivals
        let seqs: Vec<usize> = g.items.iter().map(|i| i.1).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3]);
        assert!(c.is_empty());
    }

    #[test]
    fn hold_budget_bounds_the_wait() {
        let base = Instant::now();
        let mut c = coalescer(2, 8, 50);
        assert!(admit(&mut c, vec![(64, 0, base), (64, 1, base)], base).is_empty());
        // two empty pulls age the group past its budget
        let t1 = base + Duration::from_micros(300);
        assert!(admit(&mut c, vec![], t1).is_empty());
        let t2 = base + Duration::from_micros(600);
        let ready = admit(&mut c, vec![], t2);
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].reason, FlushReason::HoldExpired);
        assert_eq!(ready[0].items.len(), 2);
        assert!(c.is_empty());
    }

    #[test]
    fn deadline_flushes_before_budget_exhaustion() {
        let base = Instant::now();
        let mut c = coalescer(100, 8, 1); // 1 ms deadline, huge hold budget
        assert!(admit(&mut c, vec![(64, 0, base), (64, 1, base)], base).is_empty());
        let due = c.next_flush_due(|i| i.2).expect("held work has a due time");
        // due = enqueue + deadline - window
        assert_eq!(due, base + Duration::from_millis(1) - Duration::from_micros(200));
        let ready = admit(&mut c, vec![], due);
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].reason, FlushReason::Deadline);
    }

    #[test]
    fn shallow_queue_does_not_open_a_hold() {
        let base = Instant::now();
        let c = coalescer(3, 4, 50);
        // with min_backlog raised to 3, a 2-deep pull is too shallow to
        // open a hold for its under-filled pair
        let mut c3: CoalesceState<usize, Item> = CoalesceState::new(
            CoalescePolicy { min_backlog: 3, ..*c.policy() },
            Duration::from_micros(200),
        );
        let ready = admit(&mut c3, vec![(64, 0, base), (64, 1, base)], base);
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].reason, FlushReason::ShallowQueue);
        assert!(c3.is_empty());
    }

    #[test]
    fn singletons_pair_across_pulls() {
        let base = Instant::now();
        let mut c = coalescer(3, 4, 50);
        // a lone request waits in the second-level queue even though the
        // pull was shallow
        assert!(admit(&mut c, vec![(64, 0, base)], base).is_empty());
        assert_eq!(c.held_singletons(), 1);
        // a later lone request of the same key pairs with it; still
        // under target, so the pair keeps its remaining hold budget
        let t1 = base + Duration::from_micros(300);
        assert!(admit(&mut c, vec![(64, 1, t1)], t1).is_empty());
        assert_eq!(c.held_singletons(), 0);
        assert_eq!(c.held_groups(), 1);
        // budget exhaustion flushes the pair as one batched group
        let t2 = base + Duration::from_micros(600);
        let t3 = base + Duration::from_micros(900);
        let mut ready = admit(&mut c, vec![], t2);
        ready.extend(admit(&mut c, vec![], t3));
        assert_eq!(ready.len(), 1);
        let g = &ready[0];
        assert!(g.paired_singletons);
        assert_eq!(g.items.iter().map(|i| i.1).collect::<Vec<_>>(), vec![0, 1]);
        assert!(c.is_empty());
    }

    #[test]
    fn admit_with_reports_each_hold_decision() {
        let base = Instant::now();
        let mut c = coalescer(3, 4, 50);
        let mut holds: Vec<(usize, usize, u32)> = Vec::new();
        // deep pull: an under-filled pair is held (hook fires, window 1)
        let ready = c.admit_with(
            vec![(64, 0, base), (64, 1, base)],
            base,
            |i| i.0,
            |i| i.2,
            |k, size, w| holds.push((*k, size, w)),
        );
        assert!(ready.is_empty());
        assert_eq!(holds, vec![(64, 2, 1)]);
        // an empty pull only ages it: no new hold decision
        let t1 = base + Duration::from_micros(300);
        assert!(c
            .admit_with(vec![], t1, |i| i.0, |i| i.2, |k, size, w| holds.push((*k, size, w)))
            .is_empty());
        assert_eq!(holds.len(), 1);
        // a same-key arrival merges and is re-held: second decision,
        // merged size, window count including this one
        let t2 = base + Duration::from_micros(600);
        let ready = c.admit_with(
            vec![(64, 2, t2)],
            t2,
            |i| i.0,
            |i| i.2,
            |k, size, w| holds.push((*k, size, w)),
        );
        assert!(ready.is_empty());
        assert_eq!(holds, vec![(64, 2, 1), (64, 3, 3)]);
        // plain admit still behaves identically (delegates with a no-op)
        let t3 = base + Duration::from_micros(900);
        let ready = admit(&mut c, vec![(64, 3, t3)], t3);
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].reason, FlushReason::Filled);
        assert!(c.is_empty());
    }

    #[test]
    fn flush_all_drains_everything() {
        let base = Instant::now();
        let mut c = coalescer(5, 8, 50);
        admit(&mut c, vec![(64, 0, base), (64, 1, base), (256, 2, base)], base);
        assert_eq!(c.held_groups() + c.held_singletons(), 2);
        let ready = c.flush_all(base + Duration::from_micros(100));
        assert_eq!(ready.len(), 2);
        assert!(ready.iter().all(|g| g.reason == FlushReason::Shutdown));
        assert!(c.is_empty());
    }

    #[test]
    fn items_arriving_during_window_join_batch() {
        let (tx, rx) = channel();
        let b = Batcher::new(rx, BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(100) });
        let sender = std::thread::spawn(move || {
            tx.send(1).unwrap();
            std::thread::sleep(Duration::from_millis(5));
            tx.send(2).unwrap();
            tx.send(3).unwrap();
            // drop tx: batch should close on disconnect, not hang
        });
        let batch = b.next_batch().unwrap();
        sender.join().unwrap();
        assert_eq!(batch, vec![1, 2, 3]);
    }
}
