//! The combined machine model: per-edge conditional costs and plan timing.
//!
//! `edge_ns(n, edge, stage, ctx)` is the simulated equivalent of one cell
//! of the paper's measurement database: "time of `edge` at `stage`
//! immediately after `ctx`" (Eq. 2). The three components:
//!
//! ```text
//! cost = base_compute + pressure x pmult(ctx) + mem x bank x ctx_factor
//! ```
//!
//! * isolation (`Context::Start`) *hides* register-pressure cost
//!   (`pressure_start_mult` < 1): a benchmark loop running one edge keeps
//!   its spill slots and twiddles L1-hot. This is how context-free search
//!   gets fooled into the FFT-32 plan (paper finding 3);
//! * warm contexts apply the cache-residual affinity of
//!   [`super::memory::context_factor`] — the sandwiched-R2 mechanism.

use crate::edge::{Context, EdgeType, ALL_EDGES};
use crate::plan::Plan;

use super::compute::{base_compute_ns, base_compute_ns_batched, pressure_ns, pressure_ns_batched};
use super::memory::{mem_ns, mem_ns_batched};
use super::params::MachineParams;

/// A simulated machine: parameters + cost queries.
#[derive(Debug, Clone)]
pub struct Machine {
    pub params: MachineParams,
}

impl Machine {
    pub fn new(params: MachineParams) -> Machine {
        Machine { params }
    }

    pub fn m1() -> Machine {
        Machine::new(MachineParams::m1())
    }

    pub fn haswell() -> Machine {
        Machine::new(MachineParams::haswell())
    }

    pub fn by_name(name: &str) -> Option<Machine> {
        MachineParams::by_name(name).map(Machine::new)
    }

    pub fn name(&self) -> &'static str {
        self.params.name
    }

    /// Whether `edge` exists on this machine (F32 needs 32 vregs).
    pub fn edge_available(&self, edge: EdgeType) -> bool {
        self.params.edge_available(edge)
    }

    /// Edge types available on this machine.
    pub fn available_edges(&self) -> Vec<EdgeType> {
        ALL_EDGES.iter().copied().filter(|e| self.edge_available(*e)).collect()
    }

    /// Relative price of running `edge`'s kernel through `isa`'s codelet
    /// backend instead of this machine's native vector unit (1.0 for the
    /// native ISA). Fused edges compose the extra `isa_fused_mult`
    /// degradation — in-register blocks lose their advantage away from
    /// the ISA they were scheduled for. The RU boundary pass is scalar
    /// in every backend and never routes here.
    pub fn isa_mult(&self, edge: EdgeType, isa: crate::isa::Isa) -> f64 {
        let i = isa.index();
        let base = self.params.isa_mult[i];
        if edge.is_fused() {
            base * self.params.isa_fused_mult[i]
        } else {
            base
        }
    }

    /// Simulated time of `edge` at `stage` for an n-point FFT, conditioned
    /// on the predecessor context — one cell of the measurement database.
    pub fn edge_ns(&self, n: usize, edge: EdgeType, stage: usize, ctx: Context) -> f64 {
        assert!(self.edge_available(edge), "{edge} unavailable on {}", self.name());
        let p = &self.params;
        let pmult = match ctx {
            Context::Start => p.pressure_start_mult,
            Context::After(_) => 1.0,
        };
        base_compute_ns(p, n, edge, stage)
            + pressure_ns(p, n, edge, stage) * pmult
            + mem_ns(p, n, edge, stage, ctx)
    }

    /// Simulated time of `edge` at `stage` executed over a lane-blocked
    /// batch of `b` transforms together (whole-batch nanoseconds). The
    /// batched kernels vectorize across the batch lanes: twiddle loads
    /// amortize as 1/B, SIMD collapse disappears, panel-scaled strides
    /// keep residual affinity alive at late stages, and a thrash term
    /// bounds it all once the panel outgrows the cache — the native
    /// model of what `CompiledPlan::run_batch` actually runs, rather
    /// than `b` independent executions. `b = 1` is exactly [`Machine::edge_ns`]
    /// (the service runs singleton groups through the scalar kernels).
    pub fn edge_ns_batched(
        &self,
        n: usize,
        edge: EdgeType,
        stage: usize,
        ctx: Context,
        b: usize,
    ) -> f64 {
        let b = b.max(1);
        if b == 1 {
            return self.edge_ns(n, edge, stage, ctx);
        }
        assert!(self.edge_available(edge), "{edge} unavailable on {}", self.name());
        let p = &self.params;
        let pmult = match ctx {
            Context::Start => p.pressure_start_mult,
            Context::After(_) => 1.0,
        };
        let per_tx = base_compute_ns_batched(p, n, edge, stage, b)
            + pressure_ns_batched(p, n, edge, stage, b) * pmult
            + mem_ns_batched(p, n, edge, stage, ctx, b);
        b as f64 * per_tx
    }

    /// Simulated time of the real-transform split/unpack pass (the RU
    /// boundary step of R2C/C2R) for an n-point *c2c half* — the pass
    /// walks the full 2n-point split-complex buffer once, symmetrically
    /// (slots k and n−k per iteration), with one twiddle multiply per
    /// conjugate pair. Memory-bound; the predecessor decides whether
    /// the walk streams from cache residuals:
    ///
    /// * after a fused register block, the half-spectrum was just
    ///   scattered register-resident in natural order — the unpack
    ///   rides it nearly free (`unpack_after_fused` < 1);
    /// * after a strided radix pass, the residuals are strided lines
    ///   the symmetric walk cannot ride — most of a fresh round trip;
    /// * from `Context::Start` (isolation), the full `start_mem`
    ///   penalty applies.
    ///
    /// This is the context-dependence the real-transform plan search
    /// consumes via `CostModel::unpack_ns` — a context-free model would
    /// price the pass identically after every predecessor and miss the
    /// fused-tail advantage entirely. Since the boundary expanded graph
    /// landed (`graph::PlanningGraph`), the context-aware search prices
    /// this asymmetry *inside* the argmin: the RU edge out of every
    /// terminal (L, t_prev) node carries this function's value for that
    /// context, so a plan may trade a faster tail for a cheaper unpack.
    pub fn unpack_ns(&self, n: usize, ctx: Context) -> f64 {
        let p = &self.params;
        // one round trip over the full 2n-point buffer
        let mem_cyc = super::memory::round_trip_bytes(2 * n) / p.l1_bw_bytes_cyc;
        // one complex multiply + adds per conjugate pair, lanes-wide
        // issue groups: comparable to radix-2 butterfly groups
        let compute_cyc = (n as f64 / p.lanes as f64) * p.bf.r2;
        let ctx_mult = match ctx {
            Context::Start => p.start_mem,
            Context::After(prev) if prev.is_fused() => p.unpack_after_fused,
            Context::After(_) => 1.0 + (p.start_mem - 1.0) * 0.5,
        };
        (mem_cyc * ctx_mult + compute_cyc) * p.ns_per_cyc()
    }

    /// Simulated *whole-batch* time of the RU split/unpack pass executed
    /// over a lane-blocked panel of `b` transforms (`unpack_r2c_b` /
    /// `pack_c2r_b`): the batched model of [`Machine::unpack_ns`]. Per
    /// transform the panel walk moves the same bytes (plus padding
    /// waste below a full lane group), but the symmetric two-pointer
    /// walk becomes a pair of `B_padded`-float contiguous runs per
    /// logical slot — hardware prefetch streams them, so the *context
    /// penalty's excess over unity fades as 1/B_padded* (the after-fused
    /// *bonus* is a natural-order residual the panel walk still rides —
    /// it is kept, not faded). A thrash term bounds the amortization
    /// once the full 2n-point panel outgrows the streaming capacity,
    /// exactly as for the batched c2c passes. `b = 1` is exactly
    /// [`Machine::unpack_ns`].
    pub fn unpack_ns_batched(&self, n: usize, ctx: Context, b: usize) -> f64 {
        let b = b.max(1);
        if b == 1 {
            return self.unpack_ns(n, ctx);
        }
        let p = &self.params;
        let bp = p.padded_batch(b);
        let waste = bp as f64 / b as f64;
        let mem_cyc = super::memory::round_trip_bytes(2 * n) * waste / p.l1_bw_bytes_cyc;
        let compute_cyc = (n as f64 / p.lanes as f64) * p.bf.r2;
        let ctx_mult = match ctx {
            Context::Start => p.start_mem,
            Context::After(prev) if prev.is_fused() => p.unpack_after_fused,
            Context::After(_) => 1.0 + (p.start_mem - 1.0) * 0.5,
        };
        let ctx_mult_b =
            if ctx_mult > 1.0 { 1.0 + (ctx_mult - 1.0) / bp as f64 } else { ctx_mult };
        let thrash = super::memory::thrash_factor(p, 2 * n, bp);
        b as f64 * (mem_cyc * ctx_mult_b * thrash + compute_cyc) * p.ns_per_cyc()
    }

    /// Simulated whole-batch time of *one direction* of the serving
    /// path's panel marshal — the gather transpose of `b` request
    /// buffers into an [n][B_padded] lane-blocked panel, or the
    /// scatter back out (see [`super::memory::marshal_ns`]). A panel
    /// round trip costs two of these; `cost::exec_mode_for` adds both
    /// endpoints when comparing panel against scalar-sequential
    /// execution.
    pub fn marshal_ns(&self, n: usize, b: usize) -> f64 {
        super::memory::marshal_ns(&self.params, n, b)
    }

    /// Whether an n-point transform's working set exceeds this machine's
    /// residency boundary (see [`super::memory::spilled`]). The largest
    /// resident n is the flat-execution ceiling the planner's blocked
    /// candidates must respect per sub-transform.
    pub fn spilled(&self, n: usize) -> bool {
        super::memory::spilled(&self.params, n)
    }

    /// Largest power-of-two transform size still within the residency
    /// boundary — the default flat-execution ceiling.
    pub fn resident_limit_n(&self) -> usize {
        let mut n = 1usize;
        while !self.spilled(n * 2) {
            n *= 2;
        }
        n
    }

    /// Simulated time of one four-step tile walk over a `rows x cols`
    /// split-complex matrix (column gather, scatter-back, or the final
    /// transpose to natural order) — see [`super::memory::transpose_ns`].
    pub fn transpose_ns(&self, rows: usize, cols: usize) -> f64 {
        super::memory::transpose_ns(&self.params, rows, cols)
    }

    /// Simulated time of the four-step inter-block twiddle multiply over
    /// the whole n-point buffer — see [`super::memory::block_twiddle_ns`].
    pub fn block_twiddle_ns(&self, n: usize) -> f64 {
        super::memory::block_twiddle_ns(&self.params, n)
    }

    /// Multiplicative penalty on `edge_ns(n, edge, stage, ctx)` when the
    /// n-point buffer has spilled the residency boundary: only the
    /// memory component moves to DRAM speed (compute and register
    /// pressure are bandwidth-independent), so the factor is
    /// `(compute + pressure + mem·K) / (compute + pressure + mem)` with
    /// `K = 1/dram_bw_frac`. Unity while resident — the resident tier
    /// prices bit-identically to the pre-tier model.
    pub fn edge_spill_factor(&self, n: usize, edge: EdgeType, stage: usize, ctx: Context) -> f64 {
        if !self.spilled(n) {
            return 1.0;
        }
        let p = &self.params;
        let pmult = match ctx {
            Context::Start => p.pressure_start_mult,
            Context::After(_) => 1.0,
        };
        let compute = base_compute_ns(p, n, edge, stage) + pressure_ns(p, n, edge, stage) * pmult;
        let mem = mem_ns(p, n, edge, stage, ctx);
        (compute + mem * super::memory::spill_mult(p)) / (compute + mem)
    }

    /// Steady-state time of a full plan: every edge is costed in its true
    /// context; the first edge's context is the *last* edge of the plan
    /// (benchmark loops run the arrangement back-to-back, so in steady
    /// state the first pass sees the final pass's cache residual).
    pub fn plan_ns(&self, n: usize, plan: &Plan) -> f64 {
        assert!(!plan.is_empty(), "empty plan");
        let steps = plan.steps();
        let mut ctx = Context::After(*plan.edges().last().unwrap());
        let mut total = 0.0;
        for &(edge, stage) in &steps {
            total += self.edge_ns(n, edge, stage, ctx);
            ctx = Context::After(edge);
        }
        total
    }

    /// One-shot (cold-ish) plan time: first edge from `Context::Start`.
    pub fn plan_ns_from_start(&self, n: usize, plan: &Plan) -> f64 {
        assert!(!plan.is_empty(), "empty plan");
        let mut ctx = Context::Start;
        let mut total = 0.0;
        for (edge, stage) in plan.steps() {
            total += self.edge_ns(n, edge, stage, ctx);
            ctx = Context::After(edge);
        }
        total
    }

    /// GFLOPS of a plan under the paper's 5·N·log2(N) convention.
    pub fn plan_gflops(&self, n: usize, plan: &Plan) -> f64 {
        crate::util::stats::gflops(n, self.plan_ns(n, plan))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::edge::Context::{After, Start};
    use crate::plan::table3_arrangements;

    #[test]
    fn edge_costs_positive_and_finite() {
        let m = Machine::m1();
        for e in ALL_EDGES {
            for s in 0..=(10 - e.stages()) {
                for ctx in Context::all() {
                    let c = m.edge_ns(1024, e, s, ctx);
                    assert!(c.is_finite() && c > 0.0, "{e}@{s} {ctx}: {c}");
                }
            }
        }
    }

    #[test]
    fn plan_time_is_sum_of_contextual_edges() {
        let m = Machine::m1();
        let plan = Plan::parse("R4,R2,R4,R4,F8").unwrap();
        let manual = m.edge_ns(1024, EdgeType::R4, 0, After(EdgeType::F8))
            + m.edge_ns(1024, EdgeType::R2, 2, After(EdgeType::R4))
            + m.edge_ns(1024, EdgeType::R4, 3, After(EdgeType::R2))
            + m.edge_ns(1024, EdgeType::R4, 5, After(EdgeType::R4))
            + m.edge_ns(1024, EdgeType::F8, 7, After(EdgeType::R4));
        assert!((m.plan_ns(1024, &plan) - manual).abs() < 1e-9);
    }

    #[test]
    fn start_context_differs_from_warm() {
        let m = Machine::m1();
        let warm = m.edge_ns(1024, EdgeType::R2, 2, After(EdgeType::R4));
        let cold = m.edge_ns(1024, EdgeType::R2, 2, Start);
        assert!(cold > warm);
    }

    #[test]
    #[should_panic(expected = "unavailable")]
    fn f32_panics_on_haswell() {
        Machine::haswell().edge_ns(1024, EdgeType::F32, 5, Start);
    }

    #[test]
    fn all_table3_plans_have_finite_times() {
        let m = Machine::m1();
        for row in table3_arrangements() {
            let t = m.plan_ns(1024, &row.plan);
            assert!(t.is_finite() && t > 0.0, "{}", row.key);
        }
    }

    #[test]
    fn batched_edge_at_b1_is_exactly_the_scalar_edge() {
        let m = Machine::m1();
        for e in ALL_EDGES {
            for s in 0..=(10 - e.stages()) {
                for ctx in Context::all() {
                    assert_eq!(m.edge_ns_batched(1024, e, s, ctx, 1), m.edge_ns(1024, e, s, ctx));
                }
            }
        }
    }

    #[test]
    fn batched_edges_are_sublinear_within_the_amortization_bound() {
        // Whole-batch time at a lane-multiple B within capacity never
        // exceeds B independent executions (no collapse, amortized
        // twiddles, panel-scaled affinity — all gains, padding-free).
        let m = Machine::m1();
        for e in ALL_EDGES {
            for s in 0..=(10 - e.stages()) {
                for ctx in Context::all() {
                    let one = m.edge_ns(1024, e, s, ctx);
                    let whole = m.edge_ns_batched(1024, e, s, ctx, 16);
                    assert!(whole <= 16.0 * one * (1.0 + 1e-12), "{e}@{s} {ctx}: {whole} vs {}", 16.0 * one);
                }
            }
        }
    }

    #[test]
    fn unpack_pass_is_cheap_after_fused_expensive_after_radix() {
        // The real-transform split/unpack pass: nearly free riding a
        // fused block's natural-order residual, most of a round trip
        // after a strided radix pass, worst from isolation.
        let m = Machine::m1();
        let fused = m.unpack_ns(512, After(EdgeType::F8));
        let radix = m.unpack_ns(512, After(EdgeType::R4));
        let iso = m.unpack_ns(512, Start);
        assert!(fused > 0.0 && fused.is_finite());
        assert!(fused < radix, "fused {fused} vs radix {radix}");
        assert!(radix < iso, "radix {radix} vs iso {iso}");
    }

    #[test]
    fn boundary_context_cells_are_measurable_and_warm() {
        // After(RU) is a first-class cell: finite for every catalog
        // edge at every placement, and cheaper than the cold start for
        // spill-free radix passes (isolation hides pressure, so
        // spill-heavy edges are excluded from the ordering claim).
        let m = Machine::m1();
        for e in ALL_EDGES {
            for s in 0..=(10 - e.stages()) {
                let warm = m.edge_ns(1024, e, s, After(EdgeType::RU));
                assert!(warm.is_finite() && warm > 0.0, "{e}@{s}");
            }
        }
        for e in [EdgeType::R2, EdgeType::R4] {
            for s in 0..=(10 - e.stages()) {
                let warm = m.edge_ns(1024, e, s, After(EdgeType::RU));
                let cold = m.edge_ns(1024, e, s, Start);
                assert!(warm < cold, "{e}@{s}: {warm} vs cold {cold}");
            }
        }
    }

    #[test]
    fn batched_unpack_at_b1_is_exactly_the_scalar_unpack() {
        let m = Machine::m1();
        for ctx in Context::all() {
            assert_eq!(m.unpack_ns_batched(512, ctx, 1), m.unpack_ns(512, ctx));
        }
    }

    #[test]
    fn batched_unpack_amortizes_penalty_contexts_within_capacity() {
        // A 2n-point panel at n=512, bp=8: 64 KiB — within the M1 cap.
        let m = Machine::m1();
        for ctx in [Start, After(EdgeType::R2), After(EdgeType::R4)] {
            let one = m.unpack_ns(512, ctx);
            let whole = m.unpack_ns_batched(512, ctx, 8);
            assert!(whole < 8.0 * one, "{ctx}: {whole} vs {}", 8.0 * one);
        }
        // the after-fused bonus is a natural-order residual the panel
        // walk keeps — per-transform cost never *rises* under batching
        // at a lane multiple within capacity
        let fused = m.unpack_ns(512, After(EdgeType::F8));
        let fused_b = m.unpack_ns_batched(512, After(EdgeType::F8), 8);
        assert!(fused_b <= 8.0 * fused * (1.0 + 1e-12), "{fused_b} vs {}", 8.0 * fused);
    }

    #[test]
    fn batched_unpack_thrashes_past_capacity() {
        // n=1024 real transform: 2n-point panels, 16 KiB per lane; 32
        // lanes = 512 KiB — far past the 128 KiB M1 cap.
        let m = Machine::m1();
        let per_tx_32 = m.unpack_ns_batched(1024, Start, 32) / 32.0;
        let per_tx_8 = m.unpack_ns_batched(1024, Start, 8) / 8.0;
        assert!(per_tx_32 > per_tx_8, "{per_tx_32} vs {per_tx_8}");
    }

    #[test]
    fn resident_limit_matches_the_spill_predicate() {
        // 256 KiB boundary, 8·n resident bytes: 2^15 is the largest
        // resident power of two on both machines.
        for m in [Machine::m1(), Machine::haswell()] {
            let lim = m.resident_limit_n();
            assert_eq!(lim, 1 << 15, "{}", m.name());
            assert!(!m.spilled(lim));
            assert!(m.spilled(lim * 2));
        }
    }

    #[test]
    fn spill_factor_is_unity_while_resident() {
        // The resident tier must price bit-identically to the pre-tier
        // model: the factor is exactly 1.0, not approximately.
        let m = Machine::m1();
        for e in [EdgeType::R2, EdgeType::R4, EdgeType::F8] {
            for ctx in [Start, After(EdgeType::R4)] {
                assert_eq!(m.edge_spill_factor(1024, e, 0, ctx), 1.0, "{e} {ctx}");
            }
        }
    }

    #[test]
    fn spill_factor_scales_only_the_memory_component() {
        let m = Machine::m1();
        let n = 1 << 18;
        let f = m.edge_spill_factor(n, EdgeType::R2, 0, After(EdgeType::R4));
        // strictly above 1 but strictly below the raw DRAM multiplier:
        // compute does not slow down.
        assert!(f > 1.0, "{f}");
        assert!(f < 1.0 / m.params.dram_bw_frac, "{f}");
        // exact: edge_ns with the mem term re-priced at DRAM speed
        let p = &m.params;
        let compute = crate::sim::compute::base_compute_ns(p, n, EdgeType::R2, 0)
            + crate::sim::compute::pressure_ns(p, n, EdgeType::R2, 0);
        let mem = crate::sim::memory::mem_ns(p, n, EdgeType::R2, 0, After(EdgeType::R4));
        let want = (compute + mem / p.dram_bw_frac) / (compute + mem);
        assert!((f - want).abs() < 1e-12);
    }

    #[test]
    fn fused_plans_beat_pure_radix() {
        // Paper finding 1: fused blocks dominate radix choice (4x gap).
        let m = Machine::m1();
        let pure = m.plan_ns(1024, &Plan::parse("R4,R4,R4,R4,R4").unwrap());
        let fused = m.plan_ns(1024, &Plan::parse("R4,R4,R4,F16").unwrap());
        assert!(pure > 1.5 * fused, "pure={pure} fused={fused}");
    }
}
