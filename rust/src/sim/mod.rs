//! Micro-architecture timing simulator — the testbed substitute.
//!
//! The paper measures edge weights on an Apple M1 P-core (NEON) and, for
//! the architecture-portability claim, cites Intel Haswell (AVX2). Neither
//! is available in this environment (see DESIGN.md §2), so this module
//! provides the closest synthetic equivalent: a parametric timing model
//! that produces **edge costs conditioned on the predecessor edge type** —
//! exactly the interface the paper's measurement harness exposes to the
//! graph search.
//!
//! Structure (all parameters named and documented in [`params`]):
//!
//! * [`compute`] — instruction-schedule estimate per edge: vector-group
//!   counts, lane efficiency (SIMD collapse at small strides, paper
//!   Table 4), register working sets and spill penalties (paper §5.2:
//!   FFT-32's twiddle spills), per-block loop overhead.
//! * [`memory`] — memory round-trip cost per edge: every non-fused pass
//!   moves the whole split-complex array through the LSU once; fused
//!   blocks move it once per log2(B) stages. Context multiplies the
//!   memory component: the predecessor's write-stride residual determines
//!   how efficiently the current pass's loads hit (store-forwarding /
//!   line-residual affinity, paper §4.3 finding 4).
//! * [`machine`] — [`Machine`]: combines both into
//!   `edge_ns(n, edge, stage, ctx)` and steady-state plan timing, plus
//!   the batch axis `edge_ns_batched(n, edge, stage, ctx, B)`: a native
//!   model of the lane-blocked batched kernels (twiddle loads amortized
//!   1/B, no SIMD collapse, panel-scaled residual affinity, cache-bound
//!   thrash) instead of linear extrapolation — so offline planning sees
//!   the same cost surface the batched engine runs on.
//!
//! Calibration: the M1 parameter values are fitted so the *shape* of the
//! paper's results holds (Table 2 inversion, Table 3 ranking and ratios,
//! Table 4 U-curve, both searches' discovered plans). Absolute nanoseconds
//! are model outputs, not hardware measurements; EXPERIMENTS.md reports
//! paper-vs-simulated side by side.

pub mod compute;
pub mod machine;
pub mod memory;
pub mod params;

pub use machine::Machine;
pub use params::MachineParams;
