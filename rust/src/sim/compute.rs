//! Compute-side cost: instruction-schedule estimate per edge.
//!
//! Two components, kept separate because they respond to context
//! differently (see [`super::machine`]):
//!
//! * [`base_compute_ns`] — context-independent issue cost: butterfly
//!   vector groups through the FMA pipes, SIMD collapse when the
//!   vectorized j-range falls below the vector width (the stride-1/2
//!   decay of paper Table 4), per-block loop overhead, fused-block
//!   transpose/gather layout work.
//! * [`pressure_ns`] — register-pressure cost: spill/reload traffic for
//!   working sets beyond the register file plus mid-path twiddle reloads.
//!   In an *isolation* benchmark loop the spill slots and twiddles stay
//!   L1-hot and mostly forwarded, so this cost is largely hidden; inside a
//!   real arrangement the neighbouring passes keep the LSU busy and evict
//!   the spill lines, exposing it. This is precisely the effect that makes
//!   context-free (isolation-measured) weights over-value FFT-32 (paper
//!   §5.2 + finding 3) — the model charges it at a context-dependent
//!   multiplier.

use crate::edge::EdgeType;

use super::params::MachineParams;

/// Vectorized butterfly groups the edge issues for an n-point FFT.
/// (Number of `lanes`-wide issue groups across the whole array.)
pub fn vector_groups(p: &MachineParams, n: usize, edge: EdgeType, stage: usize) -> f64 {
    let m = n >> stage;
    if edge.is_fused() {
        let b = edge.block_size().unwrap();
        ((n / b) as f64 / p.lanes as f64).ceil()
    } else {
        let r = 1usize << edge.stages();
        let j_range = m / r;
        let blocks = (n / m) as f64;
        blocks * j_range.div_ceil(p.lanes) as f64
    }
}

/// Context-independent issue cost, in ns.
pub fn base_compute_ns(p: &MachineParams, n: usize, edge: EdgeType, stage: usize) -> f64 {
    let m = n >> stage;
    assert!(
        m >= (1 << edge.stages()),
        "{edge} at stage {stage} invalid for n={n}"
    );
    let groups = vector_groups(p, n, edge, stage);
    let blocks = (n / m) as f64;
    let cycles = if edge.is_fused() {
        let b = edge.block_size().unwrap();
        let lb = edge.stages();
        let e = m / b;
        // Work per vector group: B points x log2(B) stages, lanes points
        // per instruction; deeper in-register networks schedule less
        // cleanly (longer dependence chains), hence the depth factor.
        let depth = 1.0 + p.fused_depth_gamma * ((b / 8) as f64 - 1.0);
        let work = (b * lb * p.lanes) as f64 * p.bf.fused_per_point_stage * depth;
        // Layout work scales with the number of vectors shuffled per group.
        let vecs_per_group = (b as f64) / (p.lanes as f64) * 2.0;
        let layout = if e < p.lanes {
            // Terminal/contiguous: in-register transposes, and the
            // j-twiddles degenerate to lane constants (j = 0) — the
            // register counts of paper Table 1 are these terminal counts.
            p.fused_transpose_cyc * vecs_per_group
        } else {
            // Mid-path: strided gather/scatter of the B-point groups.
            p.fused_gather_cyc * vecs_per_group
        };
        // Mid-path blocks additionally stream a j-twiddle vector pair per
        // sub-stage per group (terminal blocks need none: j = 0).
        let twiddle = if e >= p.lanes {
            lb as f64 * p.fused_twiddle_stream_cyc
        } else {
            0.0
        };
        // Fused blocks iterate groups in a flat unrolled loop — overhead
        // amortizes per vector group, not per FFT block.
        groups * (work + layout + twiddle + p.blk_overhead_cyc)
    } else {
        let r = 1usize << edge.stages();
        let j_range = m / r;
        let per_group = match edge {
            EdgeType::R2 => p.bf.r2,
            EdgeType::R4 => p.bf.r4,
            EdgeType::R8 => p.bf.r8,
            _ => unreachable!(),
        };
        // SIMD collapse: with j_range < lanes, butterflies mix within a
        // register; charge the unused-lane fraction at the scalar penalty.
        // Higher radices amortize the scalar fallback over more work per
        // butterfly, so the penalty scales with 1/stages.
        let eff = (j_range.min(p.lanes) as f64) / (p.lanes as f64);
        let collapse = if j_range < p.lanes {
            let amortize = if p.collapse_amortized { edge.stages() as f64 } else { 1.0 };
            1.0 + (1.0 - eff) * p.scalar_penalty / amortize
        } else {
            1.0
        };
        blocks * ((j_range.div_ceil(p.lanes) as f64) * per_group * collapse)
            + blocks * p.blk_overhead_cyc
    };
    cycles * p.ns_per_cyc()
}

/// *Per-transform* issue cost of `edge` executed over a lane-blocked
/// batch of `b` transforms (`b >= 2`; `b = 1` is the scalar path of
/// [`base_compute_ns`]). The batched kernels vectorize across the batch
/// lanes, which changes the schedule in three ways:
///
/// * **No SIMD collapse.** The vector dimension is the batch, so the
///   j-range never falls below the lane width — the stride-1/2 decay of
///   paper Table 4 does not exist in batched mode. (Sub-lane batches pay
///   instead through the padding waste `B_padded / B`.)
/// * **Twiddle amortization.** One twiddle load + broadcast per
///   butterfly position serves the whole batch, so the
///   `twiddle_issue_frac` share of the issue cost (and the j-twiddle
///   streams of mid-path fused blocks) scales as 1/B.
/// * **Lane-major layout.** Terminal fused blocks need no in-register
///   transposes (the batch lanes are already the vector lanes), and loop
///   overhead is shared across the batch.
pub fn base_compute_ns_batched(
    p: &MachineParams,
    n: usize,
    edge: EdgeType,
    stage: usize,
    b: usize,
) -> f64 {
    let m = n >> stage;
    assert!(
        m >= (1 << edge.stages()),
        "{edge} at stage {stage} invalid for n={n}"
    );
    let bp = p.padded_batch(b);
    let waste = bp as f64 / b as f64;
    let bf = b as f64;
    let cycles = if edge.is_fused() {
        let bsize = edge.block_size().unwrap();
        let lb = edge.stages();
        let e = m / bsize;
        let depth = 1.0 + p.fused_depth_gamma * ((bsize / 8) as f64 - 1.0);
        // Arithmetic: the same per-point network, batch lanes always full.
        let work = (n * lb) as f64 * p.bf.fused_per_point_stage * depth * waste;
        let vecs_per_group = (bsize as f64) / (p.lanes as f64) * 2.0;
        let groups_tx = (n / bsize) as f64 * waste / p.lanes as f64;
        // Mid-path gathers stride over panel runs as in the scalar
        // kernel; terminal blocks need no transposes at all (lane-major).
        let layout = if e < p.lanes { 0.0 } else { groups_tx * p.fused_gather_cyc * vecs_per_group };
        // One j-twiddle stream per group of B instead of per transform.
        let twiddle = if e >= p.lanes {
            (n / bsize) as f64 / p.lanes as f64 * lb as f64 * p.fused_twiddle_stream_cyc / bf
        } else {
            0.0
        };
        let overhead = groups_tx * p.blk_overhead_cyc;
        work + layout + twiddle + overhead
    } else {
        let r = 1usize << edge.stages();
        let j_range = m / r;
        let blocks = (n / m) as f64;
        let per_group = match edge {
            EdgeType::R2 => p.bf.r2,
            EdgeType::R4 => p.bf.r4,
            EdgeType::R8 => p.bf.r8,
            _ => unreachable!(),
        };
        let positions = blocks * j_range as f64;
        let arith = positions * waste / p.lanes as f64 * per_group * (1.0 - p.twiddle_issue_frac);
        let twiddle = positions * per_group * p.twiddle_issue_frac / bf;
        let overhead = blocks * p.blk_overhead_cyc / bf;
        arith + twiddle + overhead
    };
    cycles * p.ns_per_cyc()
}

/// Register working set of `edge` at (n, stage), in vector registers.
/// Terminal fused blocks need no j-twiddles (j = 0 ⇒ W^0 = 1), so their
/// working set shrinks to data + lane constants + temps.
pub fn working_set(p: &MachineParams, n: usize, edge: EdgeType, stage: usize) -> usize {
    let m = n >> stage;
    if edge.is_fused() {
        let b = edge.block_size().unwrap();
        let e = m / b;
        let data = 2 * b / p.lanes.max(1); // split-complex points in vregs
        let lane_consts = b / 4; // W_B roots kept as vector immediates
        let temps = b / 4 + 4; // double-buffered halves of the network
        if e < p.lanes {
            // terminal: lane constants only
            data + lane_consts + temps
        } else {
            // mid-path: + log2(B) j-twiddle vector pairs
            data + lane_consts + temps + 2 * edge.stages()
        }
    } else {
        p.working_set_vregs(edge)
    }
}

/// Register-pressure cost, in ns, at its *full* (in-arrangement) price.
/// The machine model scales this by a context multiplier.
pub fn pressure_ns(p: &MachineParams, n: usize, edge: EdgeType, stage: usize) -> f64 {
    let ws = working_set(p, n, edge, stage);
    let cap = p.usable_vregs();
    let spilled = ws.saturating_sub(cap) as f64;
    let groups = vector_groups(p, n, edge, stage);
    // (the paper's "twiddle-factor spills", §5.2)
    // Spilled registers are re-touched on every internal sub-stage.
    let touches = edge.stages() as f64;
    let cyc = spilled * p.spill_cyc_per_vreg * touches * groups;
    cyc * p.ns_per_cyc()
}

/// *Per-transform* register-pressure cost of a batched pass: the same
/// spill traffic per vector group as the scalar kernel (a vector
/// register still holds `lanes` floats — now batch lanes — so the live
/// working set is unchanged), scaled by the padding waste.
pub fn pressure_ns_batched(p: &MachineParams, n: usize, edge: EdgeType, stage: usize, b: usize) -> f64 {
    let bp = p.padded_batch(b);
    pressure_ns(p, n, edge, stage) * (bp as f64 / b as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::edge::ALL_EDGES;

    fn m1() -> MachineParams {
        MachineParams::m1()
    }

    #[test]
    fn all_edges_positive_cost() {
        let p = m1();
        for e in ALL_EDGES {
            for s in 0..=(10 - e.stages()) {
                assert!(base_compute_ns(&p, 1024, e, s) > 0.0, "{e} at {s}");
                assert!(pressure_ns(&p, 1024, e, s) >= 0.0);
            }
        }
    }

    #[test]
    fn simd_collapse_raises_late_r2_cost() {
        // Paper Table 4: stride-1 radix-2 decays toward scalar.
        let p = m1();
        let mid = base_compute_ns(&p, 1024, EdgeType::R2, 5);
        let last = base_compute_ns(&p, 1024, EdgeType::R2, 9);
        assert!(last > 2.0 * mid, "mid={mid} last={last}");
    }

    #[test]
    fn terminal_fused_working_set_matches_paper_table1_scale() {
        let p = m1();
        // F8 terminal: small; F32 terminal: exceeds even NEON's file once
        // lane constants and temps are counted (the paper's spill story).
        let f8 = working_set(&p, 1024, EdgeType::F8, 7);
        let f16 = working_set(&p, 1024, EdgeType::F16, 6);
        let f32t = working_set(&p, 1024, EdgeType::F32, 5);
        assert!(f8 < f16 && f16 < f32t);
        assert!(f8 <= p.usable_vregs());
        assert!(f32t > p.usable_vregs(), "f32 terminal ws {f32t}");
    }

    #[test]
    fn fft32_pressure_dominates_fft8() {
        let p = m1();
        let f8 = pressure_ns(&p, 1024, EdgeType::F8, 7);
        let f32p = pressure_ns(&p, 1024, EdgeType::F32, 5);
        assert!(f32p > f8, "f8={f8} f32={f32p}");
    }

    #[test]
    fn radix8_pressure_on_m1_not_haswell() {
        // Finding 2 (M1/NEON): R8 spills on the load-store ISA. On AVX2,
        // memory-operand folding lets R8 fit 16 registers (finding 5).
        let m1p = MachineParams::m1();
        let hw = MachineParams::haswell();
        assert!(pressure_ns(&m1p, 1024, EdgeType::R8, 3) > 0.0);
        assert_eq!(pressure_ns(&hw, 1024, EdgeType::R8, 3), 0.0);
    }

    #[test]
    fn compute_scales_roughly_linearly_in_n() {
        let p = m1();
        let c256 = base_compute_ns(&p, 256, EdgeType::R4, 0);
        let c1024 = base_compute_ns(&p, 1024, EdgeType::R4, 0);
        let ratio = c1024 / c256;
        assert!(ratio > 3.0 && ratio < 5.0, "ratio {ratio}");
    }

    #[test]
    #[should_panic(expected = "invalid")]
    fn invalid_stage_panics() {
        base_compute_ns(&m1(), 1024, EdgeType::F32, 6);
    }

    #[test]
    fn batched_compute_never_collapses() {
        // The scalar late-stage R2 pays the SIMD-collapse penalty; the
        // batched kernel vectorizes across the batch and does not.
        let p = m1();
        let scalar = base_compute_ns(&p, 1024, EdgeType::R2, 9);
        let batched = base_compute_ns_batched(&p, 1024, EdgeType::R2, 9, 16);
        assert!(batched < scalar / 4.0, "scalar {scalar} batched {batched}");
    }

    #[test]
    fn batched_twiddle_share_amortizes_with_b() {
        // At lane multiples the arithmetic share is constant per
        // transform; only the 1/B terms shrink — strictly decreasing.
        let p = m1();
        for e in ALL_EDGES {
            let s = if e.is_fused() { 1 } else { 0 };
            let c4 = base_compute_ns_batched(&p, 1024, e, s, 4);
            let c16 = base_compute_ns_batched(&p, 1024, e, s, 16);
            let c64 = base_compute_ns_batched(&p, 1024, e, s, 64);
            assert!(c16 < c4 && c64 < c16, "{e}: {c4} {c16} {c64}");
        }
    }

    #[test]
    fn batched_terminal_fused_blocks_skip_the_transpose() {
        // Terminal F8 at n=1024 stage 7: the scalar kernel pays the 4x4
        // transpose trick; the lane-major batched panel needs none.
        let p = m1();
        let scalar = base_compute_ns(&p, 1024, EdgeType::F8, 7);
        let batched = base_compute_ns_batched(&p, 1024, EdgeType::F8, 7, 4);
        assert!(batched < scalar, "scalar {scalar} batched {batched}");
    }

    #[test]
    fn batched_pressure_scales_with_padding_waste() {
        let p = m1();
        let base = pressure_ns(&p, 1024, EdgeType::R8, 3);
        assert!(base > 0.0);
        assert_eq!(pressure_ns_batched(&p, 1024, EdgeType::R8, 3, 4), base);
        assert_eq!(pressure_ns_batched(&p, 1024, EdgeType::R8, 3, 2), 2.0 * base);
    }
}
