//! Memory-side cost and the context (cache-residual) model.
//!
//! Every edge moves the whole split-complex array through the LSU exactly
//! once (radix passes per pass; fused blocks once per log2 B stages —
//! their defining advantage, paper Table 1). The round-trip cost is:
//!
//! ```text
//! mem_ns = bytes / L1_bandwidth x bank(edge, stage) x ctx(prev -> edge)
//! ```
//!
//! * `bank` — widely-strided butterfly streams conflict in the L1 banks /
//!   TLB: a mild linear penalty in the read stride (drives the slow
//!   large-stride passes on the left of paper Table 4).
//! * `ctx`  — the paper's context effect (Eq. 2). The predecessor's final
//!   write stride determines which line residuals are hot:
//!   - a radix pass ending at stage s writes its outputs at stride
//!     (n >> s) / r · r-grouped, i.e. leaves *stride n>>s residuals*;
//!     a following radix-2 pass reads pairs at distance (n>>s)/2 — exactly
//!     half the residual stride, so its two load streams split one hot
//!     residual stream (store-forward friendly): `affinity_half_stride`.
//!     This is the paper's sandwiched-R2 mechanism (finding 4: "the
//!     preceding R4 leaves stride-64 lines hot, and a single R2 at
//!     stride-128 reuses them"). Only effective while the stride exceeds
//!     a cache line — at small strides everything is line-local anyway.
//!   - repeating the same pass type re-reads its own write pattern:
//!     `affinity_same_stride` (better than a random predecessor, worse
//!     than the half-stride split).
//!   - a *fused* predecessor scatters B-strided groups across the whole
//!     array, leaving a residual that next pass's streams cannot ride:
//!     `after_fused_mem` (> 1).
//!   - `Context::Start` (isolation measurement): `start_mem` (> 1), no
//!     residual help at all.
//!
//! The batch axis ([`mem_ns_batched`]): a lane-blocked panel of B
//! transforms widens every logical element to a `B_padded`-float run.
//! Per transform the round trip costs the same (plus padding waste), the
//! context affinity applies at panel-scaled strides (late passes regain
//! residual effects the scalar layout loses to line-locality), and a
//! thrash term ([`thrash_factor`]) bounds the amortization once the
//! resident panel outgrows `batch_cap_bytes`.

use crate::edge::{Context, EdgeType};

use super::params::MachineParams;

/// Bytes moved by one edge round trip (read + write of both f32 arrays).
pub fn round_trip_bytes(n: usize) -> f64 {
    (16 * n) as f64
}

/// Read stride of `edge` at `stage`, in elements: the distance between the
/// points a butterfly (or fused gather) combines.
pub fn read_stride_elems(n: usize, edge: EdgeType, stage: usize) -> usize {
    let m = n >> stage;
    if edge.is_fused() {
        m / edge.block_size().unwrap()
    } else {
        m / (1 << edge.stages())
    }
}

/// Final write stride an edge leaves behind, in elements. Every edge
/// (radix or fused) covering stages [s, s+k) leaves its last sub-stage's
/// outputs at stride n >> (s+k).
pub fn write_residual_elems(n: usize, edge: EdgeType, start_stage: usize) -> usize {
    n >> (start_stage + edge.stages())
}

/// Bank/TLB inefficiency of the access pattern (applies in all contexts).
///
/// Every edge at stage s spreads its butterfly streams across the current
/// block span m = n >> s: a radix-r pass runs r streams at stride m/r, a
/// fused-B block B streams at stride m/B — stream count x stride = span
/// either way, and it is the span that determines how many L1 banks / TLB
/// entries the pass touches concurrently. Hence one factor per stage,
/// identical across edge types (verified: this is what makes the early
/// passes of Table 4 slow regardless of radix).
pub fn bank_factor(p: &MachineParams, n: usize, edge: EdgeType, stage: usize) -> f64 {
    let _ = edge;
    let span_bytes = ((n >> stage) * 4) as f64;
    1.0 + p.k_bank * (span_bytes / 256.0) / 2.0
}

/// Context multiplier for `edge` at `stage` given predecessor `ctx`,
/// with every stride scaled by `scale` f32 elements. The scalar layout
/// is `scale == 1`; a lane-blocked batch panel widens each logical
/// element to a `B_padded`-float run, scaling read and residual strides
/// alike — the affinity *ratios* are preserved, but the line-local
/// cutoff moves: strides that were within one cache line unbatched
/// spread across lines in a panel, so late-stage passes regain the
/// residual-affinity effects the scalar layout loses.
fn context_factor_scaled(
    p: &MachineParams,
    n: usize,
    edge: EdgeType,
    stage: usize,
    ctx: Context,
    scale: usize,
) -> f64 {
    match ctx {
        Context::Start => {
            if edge.is_fused() {
                p.iso_fused_mem
            } else {
                p.start_mem
            }
        }
        Context::After(EdgeType::RU) => {
            // The boundary split/unpack pass just walked the full buffer
            // symmetrically: every line of the c2c half is freshly
            // resident (natural order), but no stride residual exists
            // for any stream to ride — a flat residency bonus,
            // independent of this pass's read stride or the panel scale.
            p.after_boundary_mem
        }
        Context::After(prev) => {
            if prev.is_fused() {
                return p.after_fused_mem;
            }
            // Predecessor ended at `stage`, so it started `prev.stages()`
            // earlier; its residual stride is n >> stage.
            let residual = (n >> stage) * scale;
            let read = read_stride_elems(n, edge, stage) * scale;
            let line_elems = 16; // 64-byte line of f32
            if read < line_elems {
                return 1.0; // line-local: residual stride irrelevant
            }
            if 2 * read == residual {
                p.affinity_half_stride
            } else if read == residual {
                p.affinity_same_stride
            } else {
                1.0
            }
        }
    }
}

/// Context multiplier for `edge` at `stage` given predecessor `ctx`.
/// `lanes`-agnostic; purely a cache-residual story.
pub fn context_factor(p: &MachineParams, n: usize, edge: EdgeType, stage: usize, ctx: Context) -> f64 {
    context_factor_scaled(p, n, edge, stage, ctx, 1)
}

/// Context multiplier for a lane-blocked batched pass whose panels hold
/// `bp` (padded) lanes per logical element.
pub fn context_factor_batched(
    p: &MachineParams,
    n: usize,
    edge: EdgeType,
    stage: usize,
    ctx: Context,
    bp: usize,
) -> f64 {
    context_factor_scaled(p, n, edge, stage, ctx, bp.max(1))
}

/// Cache-thrash factor of streaming a lane-blocked panel of `bp` lanes:
/// unity while the resident panel (`8 · n · bp` bytes, split-complex
/// f32) fits `batch_cap_bytes`, then growing linearly in the overflow.
/// This is what bounds batched amortization: past
/// [`MachineParams::batch_amort_bound`] the panel no longer streams.
pub fn thrash_factor(p: &MachineParams, n: usize, bp: usize) -> f64 {
    let panel_bytes = (8 * n * bp) as f64;
    if panel_bytes <= p.batch_cap_bytes {
        1.0
    } else {
        1.0 + p.batch_thrash * (panel_bytes / p.batch_cap_bytes - 1.0)
    }
}

/// Memory component of the edge cost, in ns.
pub fn mem_ns(p: &MachineParams, n: usize, edge: EdgeType, stage: usize, ctx: Context) -> f64 {
    let base_cyc = round_trip_bytes(n) / p.l1_bw_bytes_cyc;
    base_cyc * p.ns_per_cyc() * bank_factor(p, n, edge, stage) * context_factor(p, n, edge, stage, ctx)
}

/// *Per-transform* memory cost of one lane-blocked batched pass over `b`
/// transforms (`b >= 2`; `b = 1` is the scalar path). The whole padded
/// panel moves once per pass, so per transform the round trip picks up
/// the padding waste `B_padded / B`; the bank factor is unchanged (the
/// panel runs the same *logical* streams, each now a contiguous
/// `B_padded`-float run — no extra bank/TLB pressure per byte); the
/// context factor sees the panel-scaled strides; and the thrash factor
/// bounds the amortization once the panel outgrows the cache.
pub fn mem_ns_batched(
    p: &MachineParams,
    n: usize,
    edge: EdgeType,
    stage: usize,
    ctx: Context,
    b: usize,
) -> f64 {
    let bp = p.padded_batch(b);
    let waste = bp as f64 / b as f64;
    let base_cyc = round_trip_bytes(n) * waste / p.l1_bw_bytes_cyc;
    base_cyc
        * p.ns_per_cyc()
        * bank_factor(p, n, edge, stage)
        * context_factor_batched(p, n, edge, stage, ctx, bp)
        * thrash_factor(p, n, bp)
}

/// Whole-batch cost (ns) of *one direction* of the panel marshal: the
/// gather transpose of `b` request buffers into an [n][B_padded]
/// lane-blocked panel, or the scatter back out. This is the serving
/// path's data-movement tax that no edge cost sees — the paper's thesis
/// applied to the marshalling boundary: price it like every other step
/// and let the planner decide whether the panel round trip pays for
/// itself (`cost::exec_mode_for`).
///
/// The model: each live buffer moves read+write (16·n bytes per
/// transform); the padding lanes are zero-filled write-only
/// (8·n·(B_padded−B) bytes); the whole walk runs at
/// `marshal_bw_frac` of the streaming bandwidth (one side of the
/// transpose is always lane-strided — it cannot stream); each request
/// pays a fixed loop overhead; and the resident panel pays the same
/// cache-thrash bound as the batched passes it feeds.
pub fn marshal_ns(p: &MachineParams, n: usize, b: usize) -> f64 {
    if b == 0 {
        return 0.0;
    }
    let bp = p.padded_batch(b);
    let live_bytes = round_trip_bytes(n) * b as f64;
    let pad_bytes = (8 * n * (bp - b)) as f64;
    let cyc = (live_bytes + pad_bytes) / (p.l1_bw_bytes_cyc * p.marshal_bw_frac)
        + b as f64 * p.marshal_overhead_cyc;
    cyc * p.ns_per_cyc() * thrash_factor(p, n, bp)
}

/// Whether an n-point split-complex transform's streaming working set
/// exceeds the residency boundary: `8 · n` resident bytes (two f32
/// arrays) against [`MachineParams::l2_bytes`], strict — a buffer that
/// exactly fills the cache still streams from it. Everything the
/// cache-tier boundary state prices follows from this one predicate.
pub fn spilled(p: &MachineParams, n: usize) -> bool {
    (8 * n) as f64 > p.l2_bytes
}

/// Multiplier on streaming-memory time once a working set spills: the
/// same bytes move at `dram_bw_frac` of the L1 round-trip bandwidth,
/// so time divides by that fraction.
pub fn spill_mult(p: &MachineParams) -> f64 {
    1.0 / p.dram_bw_frac
}

/// Cost (ns) of one four-step tile walk over a `rows x cols`
/// split-complex matrix: the gather of strided columns into a resident
/// panel, the scatter back, or the final transpose to natural order —
/// all three walks move the same `16 · rows · cols` bytes with one side
/// strided by a full row length, sustaining `transpose_bw_frac` of the
/// streaming bandwidth. When the matrix itself spills the residency
/// boundary (it always does on the sizes four-step exists for — that is
/// *why* the transform went blocked), the strided side streams from
/// DRAM: the walk additionally divides by `dram_bw_frac`.
pub fn transpose_ns(p: &MachineParams, rows: usize, cols: usize) -> f64 {
    let n = rows * cols;
    let cyc = round_trip_bytes(n) / (p.l1_bw_bytes_cyc * p.transpose_bw_frac);
    let spill = if spilled(p, n) { spill_mult(p) } else { 1.0 };
    cyc * p.ns_per_cyc() * spill
}

/// Cost (ns) of the four-step inter-block twiddle multiply over the
/// whole n-point buffer: one streaming round trip (`16 · n` bytes, unit
/// stride — this pass *does* stream, unlike the tile walks) plus one
/// complex multiply per point issued through the FMA pipes at the
/// radix-2 group rate. The memory side pays the spill multiplier when
/// the buffer exceeds the residency boundary; the compute side is
/// bandwidth-independent.
pub fn block_twiddle_ns(p: &MachineParams, n: usize) -> f64 {
    let mem_cyc = round_trip_bytes(n) / p.l1_bw_bytes_cyc;
    let spill = if spilled(p, n) { spill_mult(p) } else { 1.0 };
    let compute_cyc = (n as f64 / p.lanes as f64) * p.bf.r2;
    (mem_cyc * spill + compute_cyc) * p.ns_per_cyc()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::edge::Context::{After, Start};

    fn m1() -> MachineParams {
        MachineParams::m1()
    }

    #[test]
    fn strides() {
        assert_eq!(read_stride_elems(1024, EdgeType::R2, 0), 512);
        assert_eq!(read_stride_elems(1024, EdgeType::R4, 2), 64);
        assert_eq!(read_stride_elems(1024, EdgeType::F8, 2), 32);
        assert_eq!(read_stride_elems(1024, EdgeType::F32, 5), 1);
        assert_eq!(write_residual_elems(1024, EdgeType::R4, 0), 256);
        assert_eq!(write_residual_elems(1024, EdgeType::F8, 7), 1);
    }

    #[test]
    fn r2_after_radix_gets_half_stride_bonus_at_large_strides() {
        // The paper's sandwiched-R2 effect: R2 at stage 2 after R4 (which
        // ended at stage 2, residual stride 256) reads at 128 = 256/2.
        let p = m1();
        let bonus = context_factor(&p, 1024, EdgeType::R2, 2, After(EdgeType::R4));
        assert_eq!(bonus, p.affinity_half_stride);
        // R4 at the same point reads 64 != 128: no bonus.
        let none = context_factor(&p, 1024, EdgeType::R4, 2, After(EdgeType::R4));
        assert_eq!(none, 1.0);
    }

    #[test]
    fn no_bonus_at_line_local_strides() {
        // R2 at stage 9 reads stride 1 — residuals are line-local anyway.
        let p = m1();
        assert_eq!(context_factor(&p, 1024, EdgeType::R2, 9, After(EdgeType::R4)), 1.0);
    }

    #[test]
    fn boundary_context_is_a_flat_residency_bonus() {
        // After the RU walk every line is resident: the factor is the
        // calibrated after_boundary_mem at every stage and edge type,
        // never a stride-matched affinity and never the start penalty.
        let p = m1();
        for s in [0, 2, 5, 9] {
            for e in [EdgeType::R2, EdgeType::R4, EdgeType::F8] {
                let f = context_factor(&p, 1024, e, s, After(EdgeType::RU));
                assert_eq!(f, p.after_boundary_mem, "{e}@{s}");
                let fb = context_factor_batched(&p, 1024, e, s, After(EdgeType::RU), 16);
                assert_eq!(fb, p.after_boundary_mem, "batched {e}@{s}");
            }
        }
        assert!(p.after_boundary_mem < p.start_mem);
    }

    #[test]
    fn start_and_after_fused_are_penalties_for_radix() {
        let p = m1();
        assert!(context_factor(&p, 1024, EdgeType::R4, 0, Start) > 1.0);
        // after-fused is a (calibrated) non-bonus: never below 1.
        assert!(context_factor(&p, 1024, EdgeType::R4, 5, After(EdgeType::F8)) >= 1.0);
    }

    #[test]
    fn isolation_flatters_fused_blocks() {
        // The context-free trap: an isolated fused-block loop re-gathers
        // its own scatter pattern (self-aligned residual).
        let p = m1();
        assert!(context_factor(&p, 1024, EdgeType::F32, 5, Start) < 1.0);
        assert!(context_factor(&p, 1024, EdgeType::F32, 5, After(EdgeType::R4)) >= 1.0);
    }

    #[test]
    fn bank_factor_grows_with_span() {
        let p = m1();
        let early = bank_factor(&p, 1024, EdgeType::R2, 0); // span 4 KiB
        let late = bank_factor(&p, 1024, EdgeType::R2, 8); // span 16 B
        assert!(early > 2.0, "{early}");
        assert!(late < 1.1, "{late}");
    }

    #[test]
    fn bank_factor_is_edge_type_invariant_per_stage() {
        // stream count x stride = span: all edges at a stage pay alike.
        let p = m1();
        for s in 0..5 {
            let r2 = bank_factor(&p, 1024, EdgeType::R2, s);
            let f32f = bank_factor(&p, 1024, EdgeType::F32, s);
            assert_eq!(r2, f32f);
        }
    }

    #[test]
    fn batched_panels_recover_affinity_at_line_local_strides() {
        // R2 at stage 9 reads stride 1: line-local unbatched (no bonus),
        // but a 16-lane panel widens that to a 16-float run — the
        // half-stride residual affinity applies again.
        let p = m1();
        assert_eq!(context_factor(&p, 1024, EdgeType::R2, 9, After(EdgeType::R4)), 1.0);
        let b = context_factor_batched(&p, 1024, EdgeType::R2, 9, After(EdgeType::R4), 16);
        assert_eq!(b, p.affinity_half_stride);
        // scaling preserves ratios where the scalar bonus already applied
        let scalar = context_factor(&p, 1024, EdgeType::R2, 2, After(EdgeType::R4));
        let batched = context_factor_batched(&p, 1024, EdgeType::R2, 2, After(EdgeType::R4), 16);
        assert_eq!(scalar, batched);
    }

    #[test]
    fn thrash_kicks_in_past_the_panel_capacity() {
        let p = m1();
        // n=1024: 8 KiB per lane; 16 lanes = 128 KiB = exactly capacity.
        assert_eq!(thrash_factor(&p, 1024, 16), 1.0);
        assert!(thrash_factor(&p, 1024, 32) > 1.0);
        let hw = MachineParams::haswell();
        assert!(thrash_factor(&hw, 1024, 8) > 1.0, "32 KiB L1d holds no 64 KiB panel");
    }

    #[test]
    fn batched_mem_per_transform_is_never_worse_within_capacity() {
        // At a lane-multiple batch within capacity the padded round trip
        // equals the scalar one; only the panel-scaled context factor can
        // move per-transform memory cost, and only downward.
        let p = m1();
        for s in 0..9 {
            for ctx in Context::all() {
                let scalar = mem_ns(&p, 1024, EdgeType::R4, s, ctx);
                let batched = mem_ns_batched(&p, 1024, EdgeType::R4, s, ctx, 16);
                assert!(batched <= scalar * (1.0 + 1e-12), "stage {s} {ctx}: {batched} > {scalar}");
            }
        }
    }

    #[test]
    fn padding_waste_shows_up_below_a_full_lane_group() {
        // B=2 pads to 4 lanes: the panel moves twice the live data.
        let p = m1();
        let b2 = mem_ns_batched(&p, 1024, EdgeType::R4, 0, Start, 2);
        let b4 = mem_ns_batched(&p, 1024, EdgeType::R4, 0, Start, 4);
        assert!((b2 - 2.0 * b4).abs() < 1e-9, "b2={b2} b4={b4}");
    }

    #[test]
    fn marshal_prices_live_bytes_pad_lanes_and_overhead() {
        let p = m1();
        // Full lane group, within capacity: pure formula, thrash = 1.
        let b = 4;
        let n = 256;
        let want = ((16 * n * b) as f64 / (p.l1_bw_bytes_cyc * p.marshal_bw_frac)
            + b as f64 * p.marshal_overhead_cyc)
            * p.ns_per_cyc();
        assert_eq!(marshal_ns(&p, n, b), want);
        // Padding lanes add write-only (half-rate) bytes: B=2 pads to 4,
        // costing 2 live round trips + 2 pad writes — strictly between
        // 2 and 4 live round trips' worth of traffic.
        let b2 = marshal_ns(&p, n, 2);
        let per_live = (16 * n) as f64 / (p.l1_bw_bytes_cyc * p.marshal_bw_frac) * p.ns_per_cyc();
        let ovh2 = 2.0 * p.marshal_overhead_cyc * p.ns_per_cyc();
        assert!((b2 - (2.0 * per_live + 2.0 * per_live / 2.0 + ovh2)).abs() < 1e-9);
        assert_eq!(marshal_ns(&p, n, 0), 0.0);
    }

    #[test]
    fn marshal_is_much_slower_than_the_streaming_round_trip() {
        // The transpose cannot stream: per byte it runs at
        // marshal_bw_frac of the bandwidth every edge's round trip gets.
        let p = m1();
        let stream_ns = round_trip_bytes(1024) / p.l1_bw_bytes_cyc * p.ns_per_cyc();
        let marshal_per_tx = marshal_ns(&p, 1024, 16) / 16.0;
        assert!(marshal_per_tx > 2.0 * stream_ns, "{marshal_per_tx} vs {stream_ns}");
    }

    #[test]
    fn marshal_pays_the_same_thrash_bound_as_the_panel_it_feeds() {
        let p = m1();
        // n=1024, 16 lanes: exactly at capacity — no thrash.
        let per_at_cap = marshal_ns(&p, 1024, 16) / 16.0;
        // 32 lanes: the panel overflows; per-request marshal cost grows.
        let per_over = marshal_ns(&p, 1024, 32) / 32.0;
        assert!(per_over > per_at_cap, "{per_over} vs {per_at_cap}");
        let ratio = marshal_ns(&p, 1024, 32) / (2.0 * marshal_ns(&p, 1024, 16));
        assert!((ratio - thrash_factor(&p, 1024, 32)).abs() < 1e-9);
    }

    #[test]
    fn spill_boundary_is_strict_at_l2_capacity() {
        // 8·n bytes resident: n = 2^15 exactly fills the 256 KiB
        // boundary (still resident); n = 2^16 spills.
        let p = m1();
        assert!(!spilled(&p, 1 << 15));
        assert!(spilled(&p, 1 << 16));
        assert!(!spilled(&p, 1024));
        assert!(spill_mult(&p) > 1.0);
    }

    #[test]
    fn transpose_walk_is_slower_than_the_marshal_walk() {
        // Per byte the row-strided tile walk sustains less bandwidth
        // than the lane-strided marshal walk — on a resident matrix the
        // only difference is the bandwidth fraction (marshal also pays
        // per-request overhead, widening the gap).
        let p = m1();
        let tr = transpose_ns(&p, 64, 16); // 1024 points, resident
        let per_byte_marshal = marshal_ns(&p, 1024, 4) / 4.0 / round_trip_bytes(1024);
        let per_byte_tr = tr / round_trip_bytes(1024);
        assert!(per_byte_tr > per_byte_marshal, "{per_byte_tr} vs {per_byte_marshal}");
        // exact resident formula
        let want = round_trip_bytes(1024) / (p.l1_bw_bytes_cyc * p.transpose_bw_frac) * p.ns_per_cyc();
        assert_eq!(tr, want);
    }

    #[test]
    fn spilled_transpose_pays_the_dram_multiplier() {
        let p = m1();
        // 2^18 points spill; same-shape resident matrix for the ratio.
        let spilled_ns = transpose_ns(&p, 512, 512); // 2^18
        let resident_ns = transpose_ns(&p, 128, 128); // 2^14, resident
        let scale = (512.0 * 512.0) / (128.0 * 128.0);
        let ratio = spilled_ns / (resident_ns * scale);
        assert!((ratio - spill_mult(&p)).abs() < 1e-9, "{ratio}");
    }

    #[test]
    fn block_twiddle_streams_plus_computes() {
        let p = m1();
        let n = 1024; // resident
        let want = (round_trip_bytes(n) / p.l1_bw_bytes_cyc
            + (n as f64 / p.lanes as f64) * p.bf.r2)
            * p.ns_per_cyc();
        assert_eq!(block_twiddle_ns(&p, n), want);
        // spilled: only the memory term scales by the DRAM multiplier
        let n_big = 1 << 18;
        let want_big = (round_trip_bytes(n_big) / p.l1_bw_bytes_cyc * spill_mult(&p)
            + (n_big as f64 / p.lanes as f64) * p.bf.r2)
            * p.ns_per_cyc();
        assert_eq!(block_twiddle_ns(&p, n_big), want_big);
    }

    #[test]
    fn mem_scales_linearly_in_n() {
        let p = m1();
        let a = mem_ns(&p, 256, EdgeType::R4, 2, Start);
        let b = mem_ns(&p, 1024, EdgeType::R4, 4, Start); // same m = 64
        assert!((b / a - 4.0).abs() < 0.2, "{}", b / a);
    }
}
