//! Machine parameter sets (the simulator's "hardware manuals").
//!
//! Every field is a physically-meaningful quantity; the M1 values are
//! calibrated against the paper's published aggregates (Tables 2–4) since
//! the actual silicon is unavailable in this environment. The Haswell set
//! reproduces the 2015-thesis finding the paper cites: optimum
//! R4,R8,R8,R4 with no fused blocks (finding 5).

use crate::edge::EdgeType;
use crate::isa::{Isa, NUM_ISAS};

/// Per-radix butterfly issue costs, in cycles per vector group (one group =
/// `lanes` butterflies issued through the FMA pipes).
#[derive(Debug, Clone, Copy)]
pub struct ButterflyCosts {
    /// Radix-2 group: ld/ld/cmul/add/sub/st/st ≈ limited by 2 FMA pipes.
    pub r2: f64,
    /// Radix-4 group: 4-point network, W_4^1 free (swap+negate).
    pub r4: f64,
    /// Radix-8 group: 8-point network, W_8^{1,3} as 1/sqrt(2) scale.
    pub r8: f64,
    /// Fused blocks: cycles per *point* per *stage* while data stays in
    /// registers (no loads/stores between sub-stages).
    pub fused_per_point_stage: f64,
}

/// One simulated machine.
#[derive(Debug, Clone)]
pub struct MachineParams {
    pub name: &'static str,
    /// Core clock in GHz (M1 Firestorm: 3.2).
    pub freq_ghz: f64,
    /// f32 lanes per vector register (NEON 128-bit: 4; AVX2 256-bit: 8).
    pub lanes: usize,
    /// Architectural vector registers (NEON: 32; AVX2: 16).
    pub vregs: usize,
    /// Sustained L1 load+store bandwidth, bytes per cycle (both LSU pipes).
    pub l1_bw_bytes_cyc: f64,
    /// Fixed per-block loop overhead, cycles (address setup, branch).
    pub blk_overhead_cyc: f64,
    /// Butterfly issue costs.
    pub bf: ButterflyCosts,
    /// Multiplier on compute when the vectorized j-range collapses below
    /// `lanes` (SIMD across butterflies breaks; paper Table 4 passes 9-10).
    pub scalar_penalty: f64,
    /// Whether the collapse penalty amortizes over a radix pass's internal
    /// stages (penalty / stages). True on NEON (the wider butterfly keeps
    /// more scalar work in registers); false on AVX2, where the scalar
    /// fallback costs the same per stage regardless of radix.
    pub collapse_amortized: bool,
    /// Extra cycles per vector group for in-register transposes when a
    /// fused block runs at its terminal (contiguous) position (NEON 4x4
    /// transpose trick, paper Table 1).
    pub fused_transpose_cyc: f64,
    /// Extra cycles per vector group when a fused block gathers mid-path
    /// with a non-unit stride (strided vld1 splitting).
    pub fused_gather_cyc: f64,
    /// Spill cost: cycles per spilled vector register per vector group
    /// (paper §5.2: FFT-32's twiddle spills negate its saved traffic).
    pub spill_cyc_per_vreg: f64,
    /// Cycles per sub-stage per vector group for streaming j-twiddle
    /// vectors in *mid-path* fused blocks (terminal blocks need none:
    /// j = 0 degenerates all j-twiddles to 1).
    pub fused_twiddle_stream_cyc: f64,
    /// Scheduling inefficiency growth per doubling of fused-block size
    /// (deeper in-register networks have longer dependence chains):
    /// work multiplier = 1 + gamma * (B/8 - 1).
    pub fused_depth_gamma: f64,
    /// Context multiplier on the pressure component in `Context::Start`
    /// (isolation loops keep spill slots / twiddles L1-hot, hiding most of
    /// the cost — the effect that fools context-free search, finding 3).
    pub pressure_start_mult: f64,
    /// Memory inefficiency per 256 B of read stride (bank/TLB pressure of
    /// widely-strided butterfly streams; drives Table 4's slow pass 1).
    pub k_bank: f64,
    /// Registers reserved by the ABI/compiler (stack ptr shadowing, etc.);
    /// usable vregs = vregs - reserved.
    pub reserved_vregs: usize,
    /// Whether fused register blocks exist in this machine's catalog.
    /// The paper's fused blocks (§3.2) are its NEON contribution; the
    /// Haswell numbers it cites come from the 2015 framework, which
    /// predates them — so the Haswell model searches the 2015 radix-only
    /// catalog (and F32 would not fit 16 registers regardless, Table 2).
    pub fused_available: bool,
    /// Radix-pass working sets in vector registers, [R2, R4, R8].
    /// ISA-dependent: NEON is load-store (every operand needs a register);
    /// AVX2 folds memory operands into FMAs, roughly halving pressure —
    /// which is why the 2015 thesis' Haswell optimum leans on radix-8
    /// while the M1 search avoids it (paper finding 2 / finding 5).
    pub ws_radix: [usize; 3],
    /// Context affinity: multiplier on the memory component when the
    /// current pass reads at exactly half the predecessor's write stride
    /// (the predecessor's line residuals align with this pass's load
    /// pairs — the effect behind the paper's sandwiched R2, finding 4).
    /// Applies only while strides exceed a cache line.
    pub affinity_half_stride: f64,
    /// Affinity when strides match exactly (same-type repetition).
    pub affinity_same_stride: f64,
    /// Memory multiplier for a pass immediately after a *fused* block
    /// (fused blocks scatter across the whole array, leaving a less
    /// load-friendly residual than a plain pass).
    pub after_fused_mem: f64,
    /// Memory multiplier for *radix* passes in `Context::Start` (isolation
    /// measurement: no helpful residual from a matching predecessor).
    pub start_mem: f64,
    /// Memory multiplier for *fused* blocks in `Context::Start`: an
    /// isolated fused-block loop re-gathers exactly the groups it just
    /// scattered — a self-aligned residual that flatters the block. This
    /// is the second half of the context-free trap (finding 3): isolation
    /// makes fused blocks look better than any real arrangement delivers.
    pub iso_fused_mem: f64,
    /// Fraction of a radix pass's per-group issue cost spent loading and
    /// broadcasting twiddle vectors. The scalar kernels pay it once per
    /// vector group *per transform*; the lane-blocked batched kernels
    /// load each twiddle element once per group of B and broadcast it
    /// across the batch lanes, so this fraction amortizes as 1/B — the
    /// term that makes `edge_ns_batched` sublinear for twiddle-bound
    /// edges (FFTW's howmany-loop amortization).
    pub twiddle_issue_frac: f64,
    /// Streaming-panel capacity in bytes (≈ L1d). A lane-blocked batch
    /// panel holds `8 · n · B_padded` resident bytes; while it fits, the
    /// per-transform memory cost of a batched pass matches the scalar
    /// round trip, and the amortization terms win. Beyond it the panel
    /// thrashes (see `memory::thrash_factor`) — this is the model's
    /// batched-amortization bound.
    pub batch_cap_bytes: f64,
    /// Memory-cost growth per multiple of `batch_cap_bytes` the resident
    /// panel overflows by (cache thrash of oversized batch panels).
    pub batch_thrash: f64,
    /// Memory multiplier on the real-transform split/unpack pass (the
    /// RU boundary step) when it immediately follows a fused register
    /// block: the block just scattered the half-spectrum register-
    /// resident in natural order, exactly the layout the unpack walks —
    /// the pass streams nearly free. After a strided radix pass (or
    /// from isolation) the unpack pays the round trip instead; see
    /// `Machine::unpack_ns`.
    pub unpack_after_fused: f64,
    /// Memory multiplier for a c2c pass immediately after the RU
    /// boundary pass (`Context::After(RU)` — the start context of every
    /// real-kind steady-state loop). The symmetric full-buffer walk
    /// leaves *every* line of the half-size c2c buffer freshly resident
    /// in natural order: no stream-aligned stride residual (no
    /// half-stride bonus), but no cold-start penalty either — a mild
    /// across-the-board residency bonus, between the affinity bonuses
    /// and neutral.
    pub after_boundary_mem: f64,
    /// Effective fraction of `l1_bw_bytes_cyc` the gather/scatter panel
    /// transpose sustains. The marshal walk is the pathological L1
    /// pattern: each request buffer streams sequentially but writes
    /// (gather) or reads (scatter) lane-strided panel columns —
    /// store-port bound, no line-filling on the strided side, so it
    /// runs well below the streaming round-trip bandwidth every edge
    /// pays. See `memory::marshal_ns`.
    pub marshal_bw_frac: f64,
    /// Fixed per-request overhead of the marshal loop, in cycles (lane
    /// indexing, bounds checks, loop setup per gathered/scattered
    /// buffer).
    pub marshal_overhead_cyc: f64,
    /// Last-level-cache capacity the model treats as the residency
    /// boundary, in bytes. A transform whose split-complex working set
    /// (`16 · n` bytes round trip over an `8 · n`-byte buffer ×2 for
    /// src+dst streams) exceeds this spills: every pass streams from
    /// DRAM instead of cache, and the four-step blocked decomposition
    /// becomes the cheaper execution shape. The boundary is deliberately
    /// the *private* L2 slice, not the shared SLC — the planner should
    /// go blocked before the transform starts competing for shared
    /// capacity.
    pub l2_bytes: f64,
    /// Effective fraction of `l1_bw_bytes_cyc` the four-step tiled
    /// transpose sustains. One side of every tile walk is strided by a
    /// full row length — worse than the marshal walk's lane stride, so
    /// this sits below `marshal_bw_frac`.
    pub transpose_bw_frac: f64,
    /// Sustained DRAM streaming bandwidth as a fraction of
    /// `l1_bw_bytes_cyc`. The spilled-tier multiplier divides memory
    /// components by this fraction: a pass whose working set exceeds
    /// `l2_bytes` pays its streaming traffic at DRAM speed.
    pub dram_bw_frac: f64,
    /// The machine's native vector unit: the ISA the calibrated tables
    /// above describe (M1 = NEON, Haswell = AVX2). Surfaces pinned to
    /// other backends reprice through `isa_mult` / `isa_fused_mult`.
    pub isa: Isa,
    /// Relative throughput of each codelet backend on this machine,
    /// indexed by [`Isa::index`] — the multiplier on a c2c edge's native
    /// price when a surface pins that ISA. The native entry is 1.0;
    /// scalar pays the full vector collapse (≈ lane count, softened by
    /// superscalar issue); non-native vector backends pay a modest
    /// legalization tax.
    pub isa_mult: [f64; NUM_ISAS],
    /// Extra multiplier on *fused* edges per backend (composed with
    /// `isa_mult`). Fused register blocks live or die by in-register
    /// residency, so they degrade hardest away from the native ISA —
    /// on the scalar backend an F-block is just its unfused passes with
    /// worse scheduling, which prices fused edges out of scalar plans.
    pub isa_fused_mult: [f64; NUM_ISAS],
}

impl MachineParams {
    /// Apple M1 Firestorm P-core, 128-bit NEON (calibrated; see module doc).
    pub fn m1() -> MachineParams {
        MachineParams {
            name: "m1",
            freq_ghz: 3.2,
            lanes: 4,
            vregs: 32,
            // Firestorm sustains ~3 loads + 2 stores of 16B per cycle; use
            // an effective blended 48 B/cyc for the streaming round trip.
            l1_bw_bytes_cyc: 84.89,
            blk_overhead_cyc: 3.5228,
            bf: ButterflyCosts { r2: 2.9689, r4: 8.0664, r8: 24.3582, fused_per_point_stage: 0.4752 },
            scalar_penalty: 8.0,
            collapse_amortized: true,
            fused_transpose_cyc: 0.9311,
            fused_gather_cyc: 1.0,
            spill_cyc_per_vreg: 3.7634,
            fused_twiddle_stream_cyc: 6.7615,
            fused_depth_gamma: 0.0,
            pressure_start_mult: 0.209,
            k_bank: 0.5279,
            reserved_vregs: 2,
            fused_available: true,
            // NEON load-store working sets: data + twiddles + temps.
            ws_radix: [8, 18, 36],
            affinity_half_stride: 0.15,
            affinity_same_stride: 0.50,
            after_fused_mem: 1.0,
            start_mem: 2.2,
            iso_fused_mem: 0.9268,
            twiddle_issue_frac: 0.25,
            // Firestorm L1d: 128 KiB of streaming panel before thrash.
            batch_cap_bytes: 131072.0,
            batch_thrash: 0.5,
            // A terminal fused block leaves the half-spectrum hot in
            // natural order; the unpack rides it.
            unpack_after_fused: 0.35,
            // The RU walk re-touches the whole buffer: everything is
            // L1-resident for the next pass, with no stride alignment.
            after_boundary_mem: 0.90,
            // Firestorm's store pipes keep the lane-strided transpose
            // at ~1/3 of the streaming round-trip bandwidth.
            marshal_bw_frac: 0.35,
            marshal_overhead_cyc: 12.0,
            // Firestorm p-core: 256 KiB of effectively-private capacity
            // before a streaming transform spills to the fabric.
            l2_bytes: 262144.0,
            // Row-length strides defeat the line-fill buffers harder
            // than the marshal walk's lane strides.
            transpose_bw_frac: 0.25,
            // Unified-memory DRAM streams at roughly a fifth of the
            // L1 round-trip bandwidth.
            dram_bw_frac: 0.22,
            // Calibrated for 128-bit NEON; indexed [scalar, portable,
            // neon, avx2]. Scalar collapses the 4-lane groups (softened
            // by Firestorm's 8-wide scalar issue); portable std::simd
            // legalizes to NEON with a small codegen tax; AVX2 codelets
            // would run emulated/translated here — priced, not free.
            isa: Isa::Neon,
            isa_mult: [3.0, 1.15, 1.0, 1.25],
            isa_fused_mult: [2.0, 1.1, 1.0, 1.3],
        }
    }

    /// Intel Haswell, 256-bit AVX2 (16 vregs). Tuned to reproduce the
    /// 2015-thesis optimum R4,R8,R8,R4 (no fused blocks, no F32 at all).
    pub fn haswell() -> MachineParams {
        MachineParams {
            name: "haswell",
            freq_ghz: 3.4,
            lanes: 8,
            vregs: 16,
            l1_bw_bytes_cyc: 64.0,
            blk_overhead_cyc: 16.0,
            // AVX2 has 2 FMA ports but higher-latency shuffles; the wider
            // lanes make radix-8 groups relatively cheaper per stage.
            bf: ButterflyCosts { r2: 6.0, r4: 4.0, r8: 13.3, fused_per_point_stage: 1.0 },
            scalar_penalty: 5.5,
            // x86 scalar fallback pays per stage (no NEON-style wide
            // in-register amortization) — this is what prices radix-8 out
            // of the last stages and R2 out of stage 10.
            collapse_amortized: false,
            // Cross-lane (8x8) transposes on AVX2 are port-5-bound shuffle
            // chains — terminal fused blocks lose to plain radix tails,
            // matching the fused-free 2015 Haswell optimum.
            fused_transpose_cyc: 250.0,
            fused_gather_cyc: 50.0,
            spill_cyc_per_vreg: 4.0,
            fused_twiddle_stream_cyc: 10.0,
            fused_depth_gamma: 0.30,
            pressure_start_mult: 0.20,
            k_bank: 0.02,
            reserved_vregs: 1,
            fused_available: false,
            // AVX2 memory-operand folding halves the live-register needs:
            // radix-8 fits the 16-register file (unlike on NEON), which is
            // why the thesis' Haswell optimum leans on it (finding 5).
            ws_radix: [6, 10, 15],
            affinity_half_stride: 0.95,
            affinity_same_stride: 0.98,
            after_fused_mem: 1.05,
            start_mem: 1.10,
            iso_fused_mem: 0.95,
            // AVX2 twiddles fold into memory operands less often than the
            // arithmetic does, so a larger slice of issue is twiddle work.
            twiddle_issue_frac: 0.30,
            // Haswell L1d: 32 KiB — batched panels outgrow it quickly,
            // which is why its amortization bound sits far below the M1's.
            batch_cap_bytes: 32768.0,
            batch_thrash: 0.8,
            // Weak context effects on the 2015-era Haswell model.
            unpack_after_fused: 0.9,
            after_boundary_mem: 0.98,
            // Haswell's single store port makes the strided transpose
            // side even slower relative to its streaming bandwidth.
            marshal_bw_frac: 0.25,
            marshal_overhead_cyc: 20.0,
            // Haswell private L2: 256 KiB per core.
            l2_bytes: 262144.0,
            // The single store port drags the row-strided transpose
            // side further below streaming bandwidth than on the M1.
            transpose_bw_frac: 0.18,
            // DDR3-era DRAM relative to Haswell's 64 B/cyc L1.
            dram_bw_frac: 0.15,
            // Calibrated for 256-bit AVX2; indexed [scalar, portable,
            // neon, avx2]. Scalar collapses the 8-lane groups (Haswell's
            // 4-wide issue softens less than Firestorm's); portable
            // legalizes to AVX2 cheaply; NEON codelets would run through
            // 128-bit SSE-width translation — a small tax.
            isa: Isa::Avx2,
            isa_mult: [3.2, 1.2, 1.1, 1.0],
            isa_fused_mult: [2.0, 1.15, 1.05, 1.0],
        }
    }

    /// Parse a machine name ("m1" | "haswell").
    pub fn by_name(name: &str) -> Option<MachineParams> {
        match name {
            "m1" => Some(Self::m1()),
            "haswell" => Some(Self::haswell()),
            _ => None,
        }
    }

    /// Usable vector registers.
    pub fn usable_vregs(&self) -> usize {
        self.vregs - self.reserved_vregs
    }

    /// ns per cycle.
    pub fn ns_per_cyc(&self) -> f64 {
        1.0 / self.freq_ghz
    }

    /// Round a batch size up to a whole number of vector lanes (the
    /// lane-blocked panel padding of `fft::batch`).
    pub fn padded_batch(&self, b: usize) -> usize {
        b.max(1).div_ceil(self.lanes) * self.lanes
    }

    /// The modeled batched-amortization bound for n-point transforms:
    /// the largest lane-multiple batch whose resident panel
    /// (`8 · n · B` bytes) still fits `batch_cap_bytes`. Per-transform
    /// batched cost is monotonically non-increasing in B (over lane
    /// multiples) up to this bound; past it the thrash term takes over.
    /// Zero means even one lane group of panels overflows the capacity —
    /// no amortization range exists at this size.
    pub fn batch_amort_bound(&self, n: usize) -> usize {
        let per_tx_bytes = 8 * n;
        let max_b = (self.batch_cap_bytes / per_tx_bytes as f64).floor() as usize;
        max_b / self.lanes * self.lanes
    }

    /// Whether `edge` is implementable on this machine at all.
    /// F32 requires a 32-register file (paper Table 2: "On AVX2? No").
    pub fn edge_available(&self, edge: EdgeType) -> bool {
        match edge {
            EdgeType::F32 => self.fused_available && self.vregs >= 32,
            e if e.is_fused() => self.fused_available,
            _ => true,
        }
    }

    /// Vector-register working set of one *radix-pass* butterfly group
    /// (split-complex data + twiddles + temporaries), used by the spill
    /// model. Paper §4.3 finding 2: radix-8's 16-data-vector working set
    /// creates pressure on 128-bit NEON. Fused-block working sets are
    /// position-dependent and computed in `compute::working_set`.
    pub fn working_set_vregs(&self, edge: EdgeType) -> usize {
        match edge {
            EdgeType::R2 => self.ws_radix[0],
            EdgeType::R4 => self.ws_radix[1],
            EdgeType::R8 => self.ws_radix[2],
            _ => panic!("fused working sets are position-dependent; use compute::working_set"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(MachineParams::by_name("m1").unwrap().name, "m1");
        assert_eq!(MachineParams::by_name("haswell").unwrap().name, "haswell");
        assert!(MachineParams::by_name("zen4").is_none());
    }

    #[test]
    fn edge_catalogs_by_machine() {
        // M1: the full six-edge catalog. Haswell: the 2015 radix-only
        // catalog (fused blocks are this paper's NEON contribution; F32
        // additionally would not fit 16 registers, Table 2).
        let m1 = MachineParams::m1();
        let hw = MachineParams::haswell();
        for e in crate::edge::ALL_EDGES {
            assert!(m1.edge_available(e), "{e} on m1");
            assert_eq!(hw.edge_available(e), !e.is_fused(), "{e} on haswell");
        }
    }

    #[test]
    fn radix8_register_pressure_is_m1_specific() {
        // Paper finding 2 is about NEON: "the radix-8 butterfly's
        // 16-vector working set creates register pressure on 128-bit
        // NEON" — a load-store ISA needs every operand in a register.
        // AVX2 folds memory operands into FMAs, so radix-8 *fits* its
        // 16-register file — which is why the 2015 Haswell optimum leans
        // on radix-8 (finding 5) while the M1 search avoids it.
        let m1 = MachineParams::m1();
        assert!(m1.working_set_vregs(EdgeType::R8) > m1.usable_vregs());
        let hw = MachineParams::haswell();
        assert!(hw.working_set_vregs(EdgeType::R8) <= hw.usable_vregs());
    }

    #[test]
    fn sane_physical_values() {
        for m in [MachineParams::m1(), MachineParams::haswell()] {
            assert!(m.freq_ghz > 1.0 && m.freq_ghz < 6.0);
            assert!(m.lanes == 4 || m.lanes == 8);
            assert!(m.ns_per_cyc() > 0.0);
            assert!(m.affinity_half_stride < 1.0);
            assert!(m.start_mem >= 1.0);
            assert!(m.twiddle_issue_frac > 0.0 && m.twiddle_issue_frac < 1.0);
            assert!(m.batch_cap_bytes > 0.0);
            assert!(m.batch_thrash > 0.0);
            assert!(m.unpack_after_fused > 0.0 && m.unpack_after_fused < 1.0);
            assert!(m.after_boundary_mem > 0.0 && m.after_boundary_mem <= 1.0);
            assert!(m.marshal_bw_frac > 0.0 && m.marshal_bw_frac <= 1.0);
            assert!(m.marshal_overhead_cyc >= 0.0);
            assert!(m.l2_bytes >= m.batch_cap_bytes);
            // the transpose walk is strictly worse than the marshal walk
            assert!(m.transpose_bw_frac > 0.0 && m.transpose_bw_frac < m.marshal_bw_frac);
            assert!(m.dram_bw_frac > 0.0 && m.dram_bw_frac < 1.0);
        }
    }

    #[test]
    fn isa_calibration_is_sane() {
        // Native ISA multiplies by exactly 1.0 (pinning it must be a
        // passthrough); every other backend costs more; scalar costs the
        // most and additionally loses the fused-block advantage.
        for m in [MachineParams::m1(), MachineParams::haswell()] {
            let native = m.isa.index();
            assert_eq!(m.isa_mult[native], 1.0, "{}", m.name);
            assert_eq!(m.isa_fused_mult[native], 1.0, "{}", m.name);
            for isa in crate::isa::ALL_ISAS {
                let i = isa.index();
                if i != native {
                    assert!(m.isa_mult[i] > 1.0, "{} on {}", isa, m.name);
                    assert!(m.isa_fused_mult[i] >= 1.0, "{} on {}", isa, m.name);
                }
                let scalar = Isa::Scalar.index();
                assert!(m.isa_mult[scalar] >= m.isa_mult[i], "scalar slowest on {}", m.name);
            }
        }
        assert_eq!(MachineParams::m1().isa, Isa::Neon);
        assert_eq!(MachineParams::haswell().isa, Isa::Avx2);
    }

    #[test]
    fn padded_batch_rounds_to_lanes() {
        let m = MachineParams::m1();
        assert_eq!(m.padded_batch(1), 4);
        assert_eq!(m.padded_batch(4), 4);
        assert_eq!(m.padded_batch(5), 8);
        assert_eq!(MachineParams::haswell().padded_batch(9), 16);
    }

    #[test]
    fn amortization_bounds_follow_panel_capacity() {
        // M1 (128 KiB): 16 KiB panels per transform at n=1024 → 16;
        // 2 KiB at n=256 → 64. Haswell (32 KiB): no lane-multiple of
        // n=1024 panels fits at all — no amortization range.
        let m1 = MachineParams::m1();
        assert_eq!(m1.batch_amort_bound(1024), 16);
        assert_eq!(m1.batch_amort_bound(256), 64);
        let hw = MachineParams::haswell();
        assert_eq!(hw.batch_amort_bound(1024), 0);
        assert_eq!(hw.batch_amort_bound(256), 16);
    }
}
