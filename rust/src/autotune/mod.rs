//! Online autotuning: close the measure → search → swap loop at run time.
//!
//! The paper's central claim is that edge costs are *contextual* — the
//! cost of operation B depends on which operation A ran immediately
//! before. The offline pipeline (`bin/calibrate`, `cost::Wisdom`) measures
//! those conditional weights once and freezes a plan at startup. But
//! contextual weights drift in production: co-tenant cache pressure,
//! frequency scaling, and batch-size mix all move exactly the
//! memory-affinity terms the context-aware search exploits. This
//! subsystem re-learns the weights from the live request path and
//! re-plans without downtime:
//!
//! ```text
//!            every 1/P requests                  EWMA merge over prior
//!  workers ───────────────────────▶ [sampler] ─────▶ [online cost model]
//!     ▲      per-edge, per-context timings                 │
//!     │                                                    ▼
//!  [plan slot] ◀── hot swap (versioned; in-flight   [drift detector]
//!     ▲            batches finish on old plan)             │ observed vs
//!     │                                                    │ searched-under
//!  [re-planner] ◀──── drift + hysteresis gate ─────────────┘ weights
//!     (background shortest_path_context_aware)
//! ```
//!
//! * [`sampler`] — low-overhead trace sampling on the serving hot path
//!   (single requests *and* whole batched groups, which report their
//!   batch size with each sample);
//! * [`model`] — [`OnlineCost`]: a [`crate::cost::CostModel`] blending
//!   exponentially-weighted live estimates over the offline wisdom
//!   prior, per **batch class** — batched execution amortizes the
//!   per-pass round trip, so per-transform edge costs (and therefore
//!   the optimal plan) legitimately differ with the batch size;
//! * [`drift`] — flags divergence between observed contextual weights and
//!   the weights the active plan was searched under;
//! * [`replanner`] — the background thread running the drift → search →
//!   swap state machine (see DESIGN.md §autotune);
//! * [`swap`] — [`PlanSlot`]: versioned, atomic plan publication;
//! * [`wisdom2`] — persistence of learned contextual weights across
//!   restarts (wisdom v2 file format).
//!
//! Wire-up lives in [`crate::coordinator::service`]: pass
//! [`AutotuneConfig`] in `ServiceConfig::autotune` and the service spawns
//! the re-planner and instruments its workers.

pub mod drift;
pub mod model;
pub mod replanner;
pub mod sampler;
pub mod swap;
pub mod wisdom2;

pub use drift::{DriftDetector, DriftReport};
pub use model::{batch_class, class_batch, CellEstimate, OnlineCost, BATCH_CLASSES};
pub use replanner::{Autotuner, AutotuneStatus, ModeTable};
pub use sampler::{
    trace_batch, trace_exec_inplace, trace_request, trace_request_inplace, EdgeSample, SampleMode,
    SampleSpan, TraceSampler,
};
pub use swap::{PlanSlot, VersionedPlan};
pub use wisdom2::WisdomV2;

use std::fmt;
use std::path::PathBuf;
use std::sync::Arc;

use crate::coordinator::plancache::PlanCache;
use crate::cost::Wisdom;
use crate::kind::TransformKind;

/// Configuration of the online autotuning loop.
///
/// Defaults (via [`AutotuneConfig::new`]) are tuned for a serving process:
/// sample 1 in 64 requests, require sustained 25% deviation on measured
/// cells, and only swap for a predicted ≥5% improvement.
#[derive(Clone)]
pub struct AutotuneConfig {
    /// Offline measurement prior (the weights the initial plan was
    /// searched under). Autotuning applies to FFTs of size `prior.n`.
    pub prior: Wisdom,
    /// Transform kind of the tuned c2c workload (`Forward` or
    /// `Inverse`; real kinds are rejected at [`Autotuner::start`] —
    /// real serving reuses the tuned half-size c2c surface, and real
    /// groups are not sampled). Inverse samples fold onto the forward
    /// tables unless `split_kinds` is set.
    pub kind: TransformKind,
    /// Calibration split: keep per-kind observation cells instead of
    /// folding inverse kinds onto the forward tables (see
    /// [`model::OnlineCost::set_split_kinds`]).
    pub split_kinds: bool,
    /// Codelet ISA the serving executor dispatches — the slot live
    /// samples land in and the backend un-pinned planning surfaces
    /// resolve to (see [`model::OnlineCost::set_exec_isa`]). The
    /// service layer stamps its executor's detected ISA here; the
    /// default is scalar, the always-available backend.
    pub exec_isa: crate::isa::Isa,
    /// Offline *batched* priors: per-transform databases harvested over
    /// batches of each listed width (`Wisdom::harvest_batched` over a
    /// provider with a native batched path, or `bin/calibrate
    /// --prior-out`). Installed as per-class priors in the online model,
    /// so a re-plan at a batched regime starts from the amortized cost
    /// surface instead of the unbatched prior. Each must share `prior.n`.
    pub batched_priors: Vec<(usize, Wisdom)>,
    /// Offline marshal (panel transpose) priors: `(batch class,
    /// per-transform ns)` pairs, one direction of the gather/scatter
    /// round trip — typically `SimCost::marshal_ns(class_batch(c)) /
    /// class_batch(c)` from the same simulator the prior was harvested
    /// on. Seeds the online model's per-class marshal store so the
    /// published [`ModeTable`] starts on the calibrated flip point;
    /// live `SampleSpan::Marshal` samples then move it at runtime.
    pub marshal_priors: Vec<(usize, f64)>,
    /// Sample one request in `sample_period` (1 = every request).
    pub sample_period: u64,
    /// Relative deviation |observed − reference| / reference that marks a
    /// cell as drifted.
    pub drift_threshold: f64,
    /// Samples a cell needs before it participates in drift detection.
    pub drift_min_samples: u64,
    /// Drifted cells required to declare model drift.
    pub drift_min_cells: usize,
    /// Sampled requests between drift checks.
    pub check_every: u64,
    /// Residual-streak trigger: relative deviation a cell must *sustain*
    /// across consecutive drift checks to count toward a streak. Lower
    /// than `drift_threshold` by design — the streak catches persistent
    /// few-percent residuals the per-window check reads as noise.
    pub streak_threshold: f64,
    /// Consecutive drift checks past `streak_threshold` that fire a
    /// drift event on their own (0 disables the streak trigger).
    pub streak_windows: u32,
    /// Required predicted improvement before a hot swap ((old − new)/old).
    pub hysteresis: f64,
    /// EWMA smoothing factor for live cell estimates (0 < α ≤ 1).
    pub ewma_alpha: f64,
    /// Confidence scale: a cell with `s` samples is trusted with weight
    /// `s / (s + blend_samples)` against the prior.
    pub blend_samples: f64,
    /// Where per-edge sample values come from (wall clock or an oracle —
    /// the latter drives simulator-backed tests and demos).
    pub mode: SampleMode,
    /// Persist learned weights here on shutdown (wisdom v2); seeded from
    /// this file at startup when it exists.
    pub wisdom_path: Option<PathBuf>,
    /// When set, hot swaps are also published into this plan cache under
    /// the `"autotune"` strategy key (versioned).
    pub cache: Option<Arc<PlanCache>>,
    /// Bound on in-flight sample batches (hot path drops beyond it).
    pub sample_queue_depth: usize,
    /// When set, the re-planner records its decision trail (drift →
    /// replan → swap/declined, with before/after plans and believed
    /// costs) into this observer's flight recorder. The service layer
    /// injects its own observer here when `ServiceConfig::observer` is
    /// set and this is `None`.
    pub observer: Option<Arc<crate::obs::Observer>>,
}

impl AutotuneConfig {
    /// Production-leaning defaults over an offline prior.
    pub fn new(prior: Wisdom) -> AutotuneConfig {
        AutotuneConfig {
            prior,
            kind: TransformKind::Forward,
            split_kinds: false,
            exec_isa: crate::isa::Isa::Scalar,
            batched_priors: Vec::new(),
            marshal_priors: Vec::new(),
            sample_period: 64,
            drift_threshold: 0.25,
            drift_min_samples: 8,
            drift_min_cells: 1,
            check_every: 16,
            streak_threshold: 0.1,
            streak_windows: 4,
            hysteresis: 0.05,
            ewma_alpha: 0.2,
            blend_samples: 8.0,
            mode: SampleMode::Wallclock,
            wisdom_path: None,
            cache: None,
            sample_queue_depth: 256,
            observer: None,
        }
    }
}

impl fmt::Debug for AutotuneConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("AutotuneConfig")
            .field("n", &self.prior.n)
            .field("source", &self.prior.source)
            .field("kind", &self.kind)
            .field("split_kinds", &self.split_kinds)
            .field("exec_isa", &self.exec_isa)
            .field(
                "batched_priors",
                &self.batched_priors.iter().map(|(b, _)| *b).collect::<Vec<_>>(),
            )
            .field(
                "marshal_priors",
                &self.marshal_priors.iter().map(|(c, _)| *c).collect::<Vec<_>>(),
            )
            .field("sample_period", &self.sample_period)
            .field("drift_threshold", &self.drift_threshold)
            .field("drift_min_samples", &self.drift_min_samples)
            .field("drift_min_cells", &self.drift_min_cells)
            .field("check_every", &self.check_every)
            .field("streak_threshold", &self.streak_threshold)
            .field("streak_windows", &self.streak_windows)
            .field("hysteresis", &self.hysteresis)
            .field("ewma_alpha", &self.ewma_alpha)
            .field("blend_samples", &self.blend_samples)
            .field("mode", &self.mode)
            .field("wisdom_path", &self.wisdom_path)
            .field("sample_queue_depth", &self.sample_queue_depth)
            .field("observer", &self.observer.is_some())
            .finish()
    }
}
