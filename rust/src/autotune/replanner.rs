//! The background re-planner: drain samples → update the model → detect
//! drift → re-search → hot-swap (with hysteresis).
//!
//! One thread per autotuned FFT size, entirely off the request path.
//! State machine per sample batch (see DESIGN.md §autotune):
//!
//! ```text
//! SAMPLE  — fold the batch into the online model (EWMA per cell)
//! DRIFT   — every `check_every` batches, compare observed means against
//!           the weights the active plan was searched under
//! SEARCH  — on drift: run the PlanningGraph context-aware walk over the
//!           blended model at the (tuned kind, modal batch class)
//!           PlanningSurface (milliseconds; the paper's point is that
//!           this search is cheap enough to re-run whenever weights
//!           change)
//! SWAP    — if predicted improvement clears `hysteresis`: publish the
//!           new plan into the PlanSlot (and the PlanCache, versioned);
//!           in-flight batches finish on their old snapshot
//! REBASE  — reference ← current blended weights, so the next check
//!           measures movement since *this* decision
//! ```
//!
//! On shutdown the learned weights persist as wisdom v2 when
//! `wisdom_path` is configured.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cost::{exec_mode_for, ExecMode, PlanningSurface};
use crate::graph::PlanningGraph;
use crate::plan::Plan;

use super::drift::DriftDetector;
use super::model::{batch_class, class_batch, OnlineCost, BATCH_CLASSES};
use super::sampler::{EdgeSample, SampleMode, SampleSpan, TraceSampler};
use super::swap::PlanSlot;
use super::wisdom2::WisdomV2;
use super::AutotuneConfig;

/// Point-in-time view of the autotuning loop.
#[derive(Debug, Clone)]
pub struct AutotuneStatus {
    /// Sample batches folded into the model.
    pub batches_ingested: u64,
    /// Individual edge samples folded in.
    pub samples_ingested: u64,
    /// Sample batches dropped on the hot path (queue full).
    pub batches_dropped: u64,
    pub drift_checks: u64,
    /// Checks that flagged drift.
    pub drift_events: u64,
    /// Background searches run.
    pub replans: u64,
    /// Plans actually published.
    pub swaps: u64,
    /// Active plan version (1 = startup plan).
    pub plan_version: u64,
    /// Drift-decision → publication latency of the last swap (ns).
    pub last_swap_latency_ns: u64,
    pub active_plan: Plan,
    /// Predicted from-start cost of the active plan (ns).
    pub predicted_ns: f64,
    /// Representative batch size re-planning currently optimizes for
    /// (the modal batch class of recent samples; 1 = unbatched).
    pub plan_batch: usize,
    /// Transform kind the loop tunes (from `AutotuneConfig::kind`).
    pub kind: crate::kind::TransformKind,
}

/// Lock-free published execution-mode table: one [`ExecMode`] per batch
/// class, recomputed by the autotune loop at every drift-check point
/// from the blended online model — so live marshal (and edge) samples
/// can move the panel flip point at runtime without a plan swap.
/// Workers read it when they refresh their plan snapshot, the same
/// cadence plan swaps propagate at.
pub struct ModeTable {
    /// 0 = scalar-sequential, 1 = panel.
    modes: [AtomicU8; BATCH_CLASSES],
}

impl ModeTable {
    /// All-scalar table (the safe startup default: scalar is never
    /// wrong, only sometimes slower).
    fn new() -> ModeTable {
        ModeTable { modes: std::array::from_fn(|_| AtomicU8::new(0)) }
    }

    fn set(&self, class: usize, mode: ExecMode) {
        let v = match mode {
            ExecMode::ScalarSequential => 0,
            ExecMode::Panel => 1,
        };
        self.modes[class.min(BATCH_CLASSES - 1)].store(v, Ordering::Relaxed);
    }

    /// Published mode for a batch class.
    pub fn get(&self, class: usize) -> ExecMode {
        match self.modes[class.min(BATCH_CLASSES - 1)].load(Ordering::Relaxed) {
            1 => ExecMode::Panel,
            _ => ExecMode::ScalarSequential,
        }
    }

    /// The whole table as plain values (metrics / status surfaces).
    pub fn snapshot(&self) -> [ExecMode; BATCH_CLASSES] {
        std::array::from_fn(|c| self.get(c))
    }
}

/// Re-price the panel-vs-scalar decision for every batch class under
/// the model's current blended estimates and publish the result.
fn publish_modes(
    table: &ModeTable,
    model: &mut OnlineCost,
    kind: crate::kind::TransformKind,
    plan: &Plan,
) {
    for class in 0..BATCH_CLASSES {
        table.set(class, exec_mode_for(model, kind, plan, class_batch(class)));
    }
}

#[derive(Default)]
struct Counters {
    stop: AtomicBool,
    batches: AtomicU64,
    samples: AtomicU64,
    drift_checks: AtomicU64,
    drift_events: AtomicU64,
    replans: AtomicU64,
    swaps: AtomicU64,
    last_swap_latency_ns: AtomicU64,
    /// Batch class the last drift check planned under.
    focus_class: AtomicU64,
}

/// Handle to a running autotuning loop.
pub struct Autotuner {
    n: usize,
    kind: crate::kind::TransformKind,
    slot: Arc<PlanSlot>,
    sampler: Arc<TraceSampler>,
    mode: SampleMode,
    modes: Arc<ModeTable>,
    counters: Arc<Counters>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Autotuner {
    /// Start the loop for `config.prior.n`-point FFTs with the given
    /// startup plan (version 1). Panics if the plan is invalid for that
    /// size.
    pub fn start(config: AutotuneConfig, initial_plan: Plan) -> Autotuner {
        let n = config.prior.n;
        let l = crate::fft::log2i(n);
        assert!(initial_plan.is_valid_for(l), "plan {initial_plan} invalid for n={n}");
        assert!(
            !config.kind.is_real(),
            "autotune tunes c2c workloads (forward/inverse); real-input serving \
             reuses the tuned half-size c2c surface"
        );

        let mut model =
            OnlineCost::from_wisdom(&config.prior, config.ewma_alpha, config.blend_samples);
        model.set_split_kinds(config.split_kinds);
        model.set_focus_kind(config.kind);
        // Live samples land in the dispatching backend's slot; point the
        // model's unpinned reads (and drift's view) at the same slot.
        model.set_exec_isa(config.exec_isa);
        // Install offline batched priors first: planning at a batched
        // class starts from the amortized surface the batched kernels
        // actually run ("the same cost surface", DESIGN.md §batch).
        // Learned estimates seeded from wisdom_path below still win
        // their blend against these priors.
        for (b, w) in &config.batched_priors {
            if *b < 2 {
                // batch_class(b < 2) is class 0 — the unbatched prior's
                // own regime — so this would vanish without a trace
                eprintln!("autotune: ignoring batched prior with batch {b} (must be >= 2)");
            } else if w.n == n {
                model.set_batched_prior(*b, w);
            } else {
                eprintln!("autotune: ignoring batched prior (n={} vs {n})", w.n);
            }
        }
        // Marshal priors seed the per-class transpose store, so the
        // first published mode table already sits on the calibrated
        // panel flip point instead of the cold strided-R2 proxy.
        for &(class, ns) in &config.marshal_priors {
            model.set_marshal_prior(class, ns);
        }
        if let Some(path) = &config.wisdom_path {
            if path.exists() {
                match WisdomV2::load(path) {
                    // Estimates are only meaningful against the prior they
                    // were learned over: same size AND same cost source
                    // (simulator-ns seeded into a native-ns model would mix
                    // units through every blend and drift comparison).
                    Ok(w2) if w2.n == n && w2.source == config.prior.source => {
                        w2.seed_model(&mut model)
                    }
                    Ok(w2) => eprintln!(
                        "autotune: ignoring {} (n={} source={:?} vs prior n={n} source={:?})",
                        path.display(),
                        w2.n,
                        w2.source,
                        config.prior.source
                    ),
                    Err(e) => eprintln!("autotune: ignoring {}: {e}", path.display()),
                }
            }
        }
        let detector = DriftDetector::from_wisdom(
            &config.prior,
            config.drift_threshold,
            config.drift_min_samples,
            config.drift_min_cells,
        )
        .with_streak(config.streak_threshold, config.streak_windows);
        let predicted = PlanningSurface::for_kind(config.kind)
            .plan_objective_ns(&mut model, &initial_plan);
        let slot = Arc::new(PlanSlot::new(initial_plan.clone(), predicted));
        let (sampler, rx) = TraceSampler::new(config.sample_period, config.sample_queue_depth);
        let sampler = Arc::new(sampler);
        let counters = Arc::new(Counters::default());
        let modes = Arc::new(ModeTable::new());
        publish_modes(&modes, &mut model, config.kind, &initial_plan);

        let mode = config.mode.clone();
        let kind = config.kind;
        let handle = {
            let slot = slot.clone();
            let counters = counters.clone();
            let modes = modes.clone();
            std::thread::Builder::new()
                .name(format!("spfft-autotune-{n}"))
                .spawn(move || run_loop(config, l, model, detector, rx, slot, modes, counters))
                .expect("spawning autotune thread")
        };

        Autotuner { n, kind, slot, sampler, mode, modes, counters, handle: Mutex::new(Some(handle)) }
    }

    /// FFT size this autotuner drives.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Transform kind of the tuned workload.
    pub fn kind(&self) -> crate::kind::TransformKind {
        self.kind
    }

    /// The versioned plan slot workers read.
    pub fn slot(&self) -> &Arc<PlanSlot> {
        &self.slot
    }

    /// The hot-path sampler workers consult.
    pub fn sampler(&self) -> &TraceSampler {
        &self.sampler
    }

    /// How sampled values are produced.
    pub fn mode(&self) -> &SampleMode {
        &self.mode
    }

    /// The published per-batch-class execution-mode table workers
    /// consult when refreshing their plan snapshot.
    pub fn mode_table(&self) -> &Arc<ModeTable> {
        &self.modes
    }

    /// Current status snapshot.
    pub fn status(&self) -> AutotuneStatus {
        let cur = self.slot.current();
        AutotuneStatus {
            batches_ingested: self.counters.batches.load(Ordering::Relaxed),
            samples_ingested: self.counters.samples.load(Ordering::Relaxed),
            batches_dropped: self.sampler.dropped(),
            drift_checks: self.counters.drift_checks.load(Ordering::Relaxed),
            drift_events: self.counters.drift_events.load(Ordering::Relaxed),
            replans: self.counters.replans.load(Ordering::Relaxed),
            swaps: self.counters.swaps.load(Ordering::Relaxed),
            plan_version: cur.version,
            last_swap_latency_ns: self.counters.last_swap_latency_ns.load(Ordering::Relaxed),
            active_plan: cur.plan.clone(),
            predicted_ns: cur.predicted_ns,
            plan_batch: class_batch(self.counters.focus_class.load(Ordering::Relaxed) as usize),
            kind: self.kind,
        }
    }

    /// Stop the loop and join the thread (idempotent). Learned weights
    /// persist to `wisdom_path` here when configured.
    pub fn stop(&self) {
        self.counters.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Autotuner {
    fn drop(&mut self) {
        self.stop();
    }
}

fn run_loop(
    config: AutotuneConfig,
    l: usize,
    mut model: OnlineCost,
    mut detector: DriftDetector,
    rx: Receiver<Vec<EdgeSample>>,
    slot: Arc<PlanSlot>,
    modes: Arc<ModeTable>,
    counters: Arc<Counters>,
) {
    let n = config.prior.n;
    let mut since_check = 0u64;
    // Samples per batch class since the last drift check (reset each
    // check, so the modal class reflects the *current* traffic mix, not
    // process history): re-planning targets the modal class, so a
    // service that mostly executes 16-wide groups searches under the
    // amortized 16-wide weights, not the unbatched prior.
    let mut class_counts = [0u64; BATCH_CLASSES];
    loop {
        if counters.stop.load(Ordering::Relaxed) {
            break;
        }
        let batch = match rx.recv_timeout(Duration::from_millis(20)) {
            Ok(batch) => batch,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        };
        counters.batches.fetch_add(1, Ordering::Relaxed);
        counters.samples.fetch_add(batch.len() as u64, Ordering::Relaxed);
        for sample in &batch {
            // Boundary samples from traced blocked executions carry the
            // active (p, q) shape in their span; route them to the
            // shape-keyed stores — the generic observe path discards
            // shapeless TR/BT samples by design. They don't vote on the
            // batch regime: blocked runs are unbatched.
            if let SampleSpan::Boundary { rows, cols } = sample.span {
                match sample.edge {
                    crate::edge::EdgeType::Transpose => {
                        model.observe_transpose(rows, cols, sample.ns)
                    }
                    crate::edge::EdgeType::BlockTwiddle => {
                        model.observe_block_twiddle(rows * cols, sample.ns)
                    }
                    _ => {}
                }
                continue;
            }
            // Weight by transforms, not sampled executions: 30 groups of
            // 16 outvote 60 singletons, matching how the traffic is
            // actually served.
            class_counts[batch_class(sample.batch.max(1))] += sample.batch.max(1) as u64;
            model.observe(sample);
        }
        since_check += 1;
        if since_check < config.check_every {
            continue;
        }
        since_check = 0;
        counters.drift_checks.fetch_add(1, Ordering::Relaxed);
        // First max wins: ties (and an observation-free window) resolve
        // to the smallest class, i.e. toward the unbatched prior.
        let mut modal = 0;
        for (i, &c) in class_counts.iter().enumerate() {
            if c > class_counts[modal] {
                modal = i;
            }
        }
        class_counts = [0u64; BATCH_CLASSES];
        // Re-publish the execution-mode table at every check point,
        // before the drift gate: marshal observations can move the
        // panel flip without any edge-weight drift or regime shift.
        publish_modes(&modes, &mut model, config.kind, &slot.current().plan);
        let report = detector.check(&model);
        // Re-plan on weight drift OR on a batch-regime shift: when the
        // traffic's modal class moves away from the class the active
        // plan was searched under, per-class weights can all be stable
        // (no drift) while the active plan is optimized for the wrong B
        // — e.g. batched traffic turning into singletons. The swap
        // hysteresis still gates whether the re-search publishes.
        let regime_shift = modal != model.focus_class();
        if !report.drifted && !regime_shift {
            continue;
        }
        if report.drifted {
            counters.drift_events.fetch_add(1, Ordering::Relaxed);
            if let Some(obs) = &config.observer {
                obs.record_now(crate::obs::EventKind::Drift {
                    checks: counters.drift_checks.load(Ordering::Relaxed),
                    cells_checked: report.cells_checked,
                    cells_over: report.cells_over,
                    max_rel_dev: report.max_rel_dev,
                    worst: report.worst,
                });
            }
        }
        model.set_focus_class(modal);
        counters.focus_class.store(modal as u64, Ordering::Relaxed);
        let t0 = Instant::now();
        // The search names its regime explicitly: the tuned kind and the
        // traffic's modal batch class, as one PlanningSurface — the
        // online model answers from the matching (kind, cell, class)
        // estimates directly.
        let surface = PlanningSurface::for_kind(config.kind).with_batch_class(modal);
        let graph = PlanningGraph::new(l, surface, model.available_edges());
        let result = graph.shortest_path(&mut model);
        counters.replans.fetch_add(1, Ordering::Relaxed);
        if let Some(obs) = &config.observer {
            obs.record_now(crate::obs::EventKind::Replan {
                kind: config.kind,
                class: modal,
                plan: result.plan.clone(),
                cost_ns: result.cost_ns,
            });
        }
        let current = slot.current();
        let current_cost = graph.plan_objective_ns(&mut model, &current.plan);
        if result.plan != current.plan
            && result.cost_ns < current_cost * (1.0 - config.hysteresis)
        {
            let version = slot.swap(result.plan.clone(), result.cost_ns);
            counters
                .last_swap_latency_ns
                .store(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            counters.swaps.fetch_add(1, Ordering::Relaxed);
            if let Some(cache) = &config.cache {
                // The tuner re-searches the flat surface (its samples come
                // from the in-cache serving path); a blocked decision for a
                // spilled size is re-made by `plan_exec` on top of whatever
                // flat arrangement this publishes.
                cache.swap(
                    n,
                    "autotune",
                    &config.prior.source,
                    crate::plan::ExecPlan::Flat(result.plan.clone()),
                );
            }
            // The mode decision is plan-shape-sensitive (fused-terminal
            // vs radix-tail): re-price it for the plan we just published.
            publish_modes(&modes, &mut model, config.kind, &result.plan);
            if let Some(obs) = &config.observer {
                obs.record_now(crate::obs::EventKind::Swap {
                    version,
                    old_plan: current.plan.clone(),
                    // believed cost of the *outgoing* plan under the same
                    // model/surface the incoming plan was searched with
                    old_cost_ns: current_cost,
                    new_plan: result.plan.clone(),
                    new_cost_ns: result.cost_ns,
                });
            }
        } else if let Some(obs) = &config.observer {
            obs.record_now(crate::obs::EventKind::SwapDeclined {
                plan: result.plan.clone(),
                cost_ns: result.cost_ns,
                current_cost_ns: current_cost,
            });
        }
        // Either we swapped (reference = weights the new plan was searched
        // under) or we declined (accept the new weights as the operating
        // point); both rebase so the next check measures fresh movement.
        detector.rebase(&model);
    }
    if let Some(path) = &config.wisdom_path {
        let w2 = WisdomV2::from_model(&model, &config.prior.source);
        if let Err(e) = w2.save(path) {
            eprintln!("autotune: persisting wisdom failed: {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{SimCost, Wisdom};
    use crate::edge::Context;
    use crate::planner::{plan as run_plan, Strategy};

    fn tight_config(n: usize) -> AutotuneConfig {
        let prior = Wisdom::harvest(&mut SimCost::m1(n), "m1");
        let mut cfg = AutotuneConfig::new(prior);
        cfg.sample_period = 1;
        cfg.check_every = 2;
        cfg.drift_min_samples = 2;
        cfg.drift_threshold = 0.5;
        cfg.hysteresis = 0.02;
        cfg.ewma_alpha = 1.0;
        cfg.blend_samples = 0.5;
        cfg
    }

    fn initial_plan(n: usize) -> Plan {
        run_plan(&mut SimCost::m1(n), &Strategy::DijkstraContextAware { k: 1 }).plan
    }

    /// Samples for one simulated execution of `plan`, with every cell's
    /// value scaled by `factor`.
    fn plan_samples(prior: &Wisdom, plan: &Plan, factor: f64) -> Vec<EdgeSample> {
        let lookup = |e, s, ctx| {
            prior
                .cells
                .iter()
                .find(|&&(pe, ps, pc, _)| pe == e && ps == s && pc == ctx)
                .map(|&(_, _, _, ns)| ns)
                .expect("cell in prior")
        };
        let mut ctx = Context::Start;
        plan.steps()
            .into_iter()
            .map(|(e, s)| {
                let ns = lookup(e, s, ctx) * factor;
                let sample = EdgeSample {
                    edge: e,
                    stage: s,
                    ctx,
                    kind: crate::kind::TransformKind::Forward,
                    batch: 1,
                    isa: crate::isa::Isa::Scalar,
                    span: SampleSpan::Edge,
                    ns,
                };
                ctx = Context::After(e);
                sample
            })
            .collect()
    }

    /// Batched variant: one simulated batched execution of `plan` with
    /// every per-transform cell value scaled by `factor` (whole-batch ns).
    fn plan_samples_b(prior: &Wisdom, plan: &Plan, batch: usize, factor: f64) -> Vec<EdgeSample> {
        plan_samples(prior, plan, factor)
            .into_iter()
            .map(|s| EdgeSample { batch, ns: s.ns * batch as f64, ..s })
            .collect()
    }

    fn wait_for(mut done: impl FnMut() -> bool) -> bool {
        let deadline = Instant::now() + Duration::from_secs(5);
        while Instant::now() < deadline {
            if done() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        false
    }

    #[test]
    fn stable_weights_never_swap() {
        let n = 256;
        let cfg = tight_config(n);
        let prior = cfg.prior.clone();
        let tuner = Autotuner::start(cfg, initial_plan(n));
        let plan = tuner.slot().current().plan.clone();
        for _ in 0..20 {
            tuner.sampler().submit(plan_samples(&prior, &plan, 1.0));
        }
        assert!(wait_for(|| tuner.status().drift_checks >= 3));
        let status = tuner.status();
        assert_eq!(status.swaps, 0);
        assert_eq!(status.drift_events, 0);
        assert_eq!(status.plan_version, 1);
        tuner.stop();
    }

    #[test]
    fn inflated_active_plan_triggers_replan_and_swap() {
        let n = 256;
        let cfg = tight_config(n);
        let prior = cfg.prior.clone();
        let tuner = Autotuner::start(cfg, initial_plan(n));
        let old = tuner.slot().current().plan.clone();
        for _ in 0..50 {
            tuner.sampler().submit(plan_samples(&prior, &old, 10.0));
            std::thread::sleep(Duration::from_millis(1));
            if tuner.status().swaps >= 1 {
                break;
            }
        }
        assert!(wait_for(|| tuner.status().swaps >= 1), "no swap happened");
        let status = tuner.status();
        assert!(status.plan_version >= 2);
        assert_ne!(status.active_plan, old);
        assert!(status.active_plan.is_valid_for(8));
        assert!(status.replans >= 1);
        tuner.stop();
    }

    #[test]
    fn batched_drift_replans_at_the_modal_batch_class() {
        // Feed only 16-wide batched samples with inflated costs: the
        // re-planner must flag drift, plan under the batch-16 class, and
        // report that class in its status.
        let n = 256;
        let cfg = tight_config(n);
        let prior = cfg.prior.clone();
        let tuner = Autotuner::start(cfg, initial_plan(n));
        let plan = tuner.slot().current().plan.clone();
        for _ in 0..50 {
            tuner.sampler().submit(plan_samples_b(&prior, &plan, 16, 10.0));
            std::thread::sleep(Duration::from_millis(1));
            if tuner.status().swaps >= 1 {
                break;
            }
        }
        assert!(wait_for(|| tuner.status().swaps >= 1), "no swap happened");
        let status = tuner.status();
        assert_eq!(status.plan_batch, 16, "re-plan did not target the modal batch class");
        assert!(status.plan_version >= 2);
        tuner.stop();
    }

    #[test]
    fn regime_shift_replans_without_weight_drift() {
        // Per-class weights stay exactly on the prior (no drift), but
        // the traffic's modal batch class moves: the re-planner must
        // re-search at the new class (and report it) without swapping,
        // since the stable weights produce the same optimal plan.
        let n = 256;
        let cfg = tight_config(n);
        let prior = cfg.prior.clone();
        let tuner = Autotuner::start(cfg, initial_plan(n));
        let plan = tuner.slot().current().plan.clone();
        for _ in 0..6 {
            tuner.sampler().submit(plan_samples_b(&prior, &plan, 16, 1.0));
        }
        assert!(wait_for(|| tuner.status().replans >= 1), "no regime-shift re-plan");
        let status = tuner.status();
        assert_eq!(status.drift_events, 0);
        assert_eq!(status.swaps, 0, "stable weights must not swap");
        assert_eq!(status.plan_batch, 16);
        // ... and back out of batching: singleton traffic shifts the
        // modal class to 0 again.
        for _ in 0..6 {
            tuner.sampler().submit(plan_samples(&prior, &plan, 1.0));
        }
        assert!(wait_for(|| tuner.status().replans >= 2), "no re-plan on shift back");
        let status = tuner.status();
        assert_eq!(status.plan_batch, 1);
        assert_eq!(status.swaps, 0);
        tuner.stop();
    }

    #[test]
    fn sub_threshold_residual_streak_fires_a_drift_event() {
        // Every sampled cell runs a steady 15% hot: under the 50% main
        // threshold (no check ever flags a drifted cell), over the 5%
        // streak threshold. Two consecutive quiet-but-residual checks
        // must fire a drift event through the streak trigger.
        let n = 256;
        let mut cfg = tight_config(n);
        cfg.streak_threshold = 0.05;
        cfg.streak_windows = 2;
        let prior = cfg.prior.clone();
        let tuner = Autotuner::start(cfg, initial_plan(n));
        let plan = tuner.slot().current().plan.clone();
        for _ in 0..50 {
            tuner.sampler().submit(plan_samples(&prior, &plan, 1.15));
            std::thread::sleep(Duration::from_millis(1));
            if tuner.status().drift_events >= 1 {
                break;
            }
        }
        assert!(
            wait_for(|| tuner.status().drift_events >= 1),
            "persistent 15% residual never fired the streak trigger"
        );
        tuner.stop();
    }

    #[test]
    fn mode_table_starts_calibrated_and_marshal_samples_move_it() {
        let n = 256;
        let mut cfg = tight_config(n);
        // Amortized batched prior at B=16 plus a near-free transpose:
        // the first published table already says Panel at class 4.
        let w16 = Wisdom::harvest_batched(&mut SimCost::m1(n), "m1", 16);
        cfg.batched_priors = vec![(16, w16)];
        cfg.marshal_priors = vec![(batch_class(16), 0.001)];
        let tuner = Autotuner::start(cfg, initial_plan(n));
        assert_eq!(tuner.mode_table().get(0), ExecMode::ScalarSequential, "b=1 is never a panel");
        assert_eq!(tuner.mode_table().get(batch_class(16)), ExecMode::Panel);
        // Live marshal samples price the transpose as ruinous: the next
        // check point must flip the published mode back to scalar —
        // with zero edge-weight drift and zero plan swaps involved.
        let expensive =
            EdgeSample::marshal(crate::kind::TransformKind::Forward, 16, crate::isa::Isa::Scalar, 1e9);
        for _ in 0..6 {
            tuner.sampler().submit(vec![expensive]);
        }
        assert!(
            wait_for(|| tuner.mode_table().get(batch_class(16)) == ExecMode::ScalarSequential),
            "marshal samples never moved the published mode"
        );
        assert_eq!(tuner.status().swaps, 0);
        tuner.stop();
    }

    #[test]
    fn learned_weights_persist_as_wisdom_v2() {
        let n = 256;
        let dir = std::env::temp_dir().join(format!("spfft-autotune-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("learned.wisdom2.json");
        let mut cfg = tight_config(n);
        cfg.wisdom_path = Some(path.clone());
        let prior = cfg.prior.clone();
        let tuner = Autotuner::start(cfg, initial_plan(n));
        let plan = tuner.slot().current().plan.clone();
        for _ in 0..5 {
            tuner.sampler().submit(plan_samples(&prior, &plan, 1.0));
        }
        assert!(wait_for(|| tuner.status().batches_ingested >= 5));
        tuner.stop();
        let w2 = WisdomV2::load(&path).expect("persisted wisdom");
        assert_eq!(w2.n, n);
        assert!(w2.cells.iter().any(|c| c.count > 0));
        std::fs::remove_dir_all(&dir).ok();
    }
}
