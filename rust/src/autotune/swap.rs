//! Versioned, atomic plan publication — the hot-swap primitive.
//!
//! A [`PlanSlot`] holds the currently-active plan behind an `RwLock` of an
//! `Arc`. Readers (workers) take a cheap read-lock, clone the `Arc`, and
//! execute against that snapshot — so a batch that started on version `v`
//! finishes on version `v` even if the re-planner publishes `v+1`
//! mid-batch. Writers replace the `Arc` wholesale; versions are strictly
//! monotonic. Nothing in the request path ever waits on planning.

use std::sync::{Arc, RwLock};

use crate::plan::Plan;

/// An immutable published plan.
#[derive(Debug, Clone, PartialEq)]
pub struct VersionedPlan {
    /// Monotonic version, starting at 1 for the startup plan.
    pub version: u64,
    pub plan: Plan,
    /// From-start contextual cost the publishing search predicted (ns).
    pub predicted_ns: f64,
}

/// Shared slot the re-planner publishes into and workers read from.
#[derive(Debug)]
pub struct PlanSlot {
    current: RwLock<Arc<VersionedPlan>>,
}

impl PlanSlot {
    /// Create with the startup plan at version 1.
    pub fn new(plan: Plan, predicted_ns: f64) -> PlanSlot {
        PlanSlot {
            current: RwLock::new(Arc::new(VersionedPlan { version: 1, plan, predicted_ns })),
        }
    }

    /// Snapshot of the active plan; holds no lock after returning.
    pub fn current(&self) -> Arc<VersionedPlan> {
        self.current.read().unwrap().clone()
    }

    /// Active version without cloning the plan.
    pub fn version(&self) -> u64 {
        self.current.read().unwrap().version
    }

    /// Publish a new plan; returns the new version.
    pub fn swap(&self, plan: Plan, predicted_ns: f64) -> u64 {
        let mut guard = self.current.write().unwrap();
        let version = guard.version + 1;
        *guard = Arc::new(VersionedPlan { version, plan, predicted_ns });
        version
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_version_one() {
        let slot = PlanSlot::new(Plan::parse("R4,R4,R2,F8").unwrap(), 100.0);
        let cur = slot.current();
        assert_eq!(cur.version, 1);
        assert_eq!(slot.version(), 1);
        assert_eq!(cur.plan, Plan::parse("R4,R4,R2,F8").unwrap());
    }

    #[test]
    fn swap_bumps_version_and_old_snapshots_survive() {
        let slot = PlanSlot::new(Plan::parse("R4,R4,R2,F8").unwrap(), 100.0);
        let old = slot.current();
        let v2 = slot.swap(Plan::parse("R8,F8,R2,R2").unwrap(), 90.0);
        assert_eq!(v2, 2);
        // the in-flight snapshot still points at the old plan
        assert_eq!(old.version, 1);
        assert_eq!(old.plan, Plan::parse("R4,R4,R2,F8").unwrap());
        let new = slot.current();
        assert_eq!(new.version, 2);
        assert_eq!(new.plan, Plan::parse("R8,F8,R2,R2").unwrap());
    }

    #[test]
    fn concurrent_readers_see_monotonic_versions() {
        let slot = Arc::new(PlanSlot::new(Plan::parse("R2,R2,R2").unwrap(), 1.0));
        let mut readers = Vec::new();
        for _ in 0..4 {
            let s = slot.clone();
            readers.push(std::thread::spawn(move || {
                let mut last = 0;
                for _ in 0..500 {
                    let v = s.current().version;
                    assert!(v >= last, "version went backwards: {v} < {last}");
                    last = v;
                }
            }));
        }
        for i in 0..20 {
            slot.swap(Plan::parse("R2,R2,R2").unwrap(), i as f64);
        }
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(slot.version(), 21);
    }
}
