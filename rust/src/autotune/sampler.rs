//! Trace sampling: per-edge, per-context timings off the serving hot path.
//!
//! Workers decide per request (one atomic increment) whether to trace it;
//! traced requests run through [`crate::fft::CompiledPlan::run_on_traced`]
//! and the resulting per-edge samples are handed to the re-planner over a
//! bounded channel with `try_send` — the hot path never blocks on the
//! autotuner, it drops samples when the queue is full. Untraced requests
//! pay exactly one relaxed atomic increment (the `<2%` overhead budget is
//! checked by `benches/autotune_overhead.rs`).

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;

use crate::edge::{Context, EdgeType};
use crate::fft::{CompiledPlan, SplitComplex};
use crate::isa::Isa;
use crate::kind::TransformKind;

/// Which pipeline span a sample measures.
///
/// The flight recorder and online model consume one sample stream, but
/// not everything on the serving hot path is a plan step: grouped
/// (panel) execution transposes request buffers in and out of the lane
/// panels, and that marshal time must be *observed* (so `OnlineCost`
/// can move the [`crate::cost::ExecMode`] flip at runtime) without
/// polluting the per-edge catalog cells.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SampleSpan {
    /// One plan step (c2c pass or RU boundary pass) — a catalog cell.
    Edge,
    /// The panel marshal round trip (gather + scatter) of one grouped
    /// execution: `batch` is the group's live size, `ns` covers the
    /// whole round trip (both directions). The edge/stage/ctx fields
    /// carry fixed placeholders ([`EdgeSample::marshal`]); consumers
    /// key marshal samples by batch class alone and must exclude them
    /// from edge attribution.
    Marshal,
    /// One boundary pass of a blocked (four-step) execution: a transpose
    /// walk or the inter-block twiddle multiply over the `rows × cols`
    /// sub-FFT grid. These carry their shape because the online model
    /// keys boundary observations by it (`observe_transpose` /
    /// `observe_block_twiddle`) — a shapeless TR/BT sample through the
    /// generic `observe` path is discarded. They *do* land in edge
    /// attribution (stage 0 of the boundary edge), so operators see
    /// where a blocked execution's time actually goes.
    Boundary { rows: usize, cols: usize },
}

/// One observed edge execution in its live context.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EdgeSample {
    pub edge: EdgeType,
    pub stage: usize,
    pub ctx: Context,
    /// Transform kind of the traced execution — the online model keys
    /// observations by (kind, cell, batch class). Inverse kinds fold
    /// onto the forward tables unless the calibration split is on
    /// ([`TransformKind::measured_alias`]).
    pub kind: TransformKind,
    /// Transforms executed together in this step (1 = unbatched). `ns`
    /// covers the whole batch; consumers normalize per transform.
    pub batch: usize,
    /// Codelet backend the traced plan dispatched to
    /// ([`CompiledPlan::isa`]) — the online model keys observations by
    /// it, so estimates learned on one backend never price another's
    /// surface (a scalar-forced canary and the native fleet coexist in
    /// one store).
    pub isa: Isa,
    /// Observed time in nanoseconds (for the whole batch).
    pub ns: f64,
    /// Which pipeline span this sample measures (plan step vs panel
    /// marshal). Everything before the marshal span existed is
    /// [`SampleSpan::Edge`].
    pub span: SampleSpan,
}

impl EdgeSample {
    /// Per-transform nanoseconds (`ns` normalized by the batch width).
    pub fn per_transform_ns(&self) -> f64 {
        self.ns / self.batch.max(1) as f64
    }

    /// A marshal-span sample: the observed gather+scatter round trip of
    /// one grouped execution of `batch` requests. The edge/stage/ctx
    /// placeholders are fixed (RU @ 0, `Start`) so marshal samples
    /// never collide with a live catalog cell on any keyed store that
    /// forgets to check the span.
    pub fn marshal(kind: TransformKind, batch: usize, isa: Isa, ns: f64) -> EdgeSample {
        EdgeSample {
            edge: EdgeType::RU,
            stage: 0,
            ctx: Context::Start,
            kind,
            batch,
            isa,
            ns,
            span: SampleSpan::Marshal,
        }
    }

    /// A boundary-pass sample from a traced blocked execution: `edge` is
    /// [`EdgeType::Transpose`] or [`EdgeType::BlockTwiddle`], and the
    /// `rows × cols` shape of the active (p, q) split rides in the span
    /// so the replanner can route it to the shape-keyed boundary stores.
    pub fn boundary(
        edge: EdgeType,
        rows: usize,
        cols: usize,
        kind: TransformKind,
        isa: Isa,
        ns: f64,
    ) -> EdgeSample {
        debug_assert!(edge.is_boundary() && edge != EdgeType::RU);
        EdgeSample {
            edge,
            stage: 0,
            ctx: Context::Start,
            kind,
            batch: 1,
            isa,
            ns,
            span: SampleSpan::Boundary { rows, cols },
        }
    }
}

/// Where sample values come from.
///
/// `Wallclock` reports measured per-edge execution time — the production
/// mode. `Oracle` replaces the measured value with a caller-supplied
/// function of (edge, stage, context); simulator-backed tests and demos
/// use it to inject deterministic weights (including mid-run drift)
/// through the *entire* live pipeline.
#[derive(Clone)]
pub enum SampleMode {
    Wallclock,
    Oracle(Arc<dyn Fn(EdgeType, usize, Context) -> f64 + Send + Sync>),
}

impl fmt::Debug for SampleMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SampleMode::Wallclock => f.write_str("Wallclock"),
            SampleMode::Oracle(_) => f.write_str("Oracle(..)"),
        }
    }
}

/// Sampling decision + bounded hand-off to the re-planner thread.
pub struct TraceSampler {
    period: u64,
    counter: AtomicU64,
    sampled: AtomicU64,
    dropped: AtomicU64,
    tx: SyncSender<Vec<EdgeSample>>,
}

impl TraceSampler {
    /// Create a sampler tracing 1 in `period` requests, with a bounded
    /// queue of `depth` sample batches. Returns the receiver the
    /// re-planner drains.
    pub fn new(period: u64, depth: usize) -> (TraceSampler, Receiver<Vec<EdgeSample>>) {
        let (tx, rx) = sync_channel(depth.max(1));
        let sampler = TraceSampler {
            period: period.max(1),
            counter: AtomicU64::new(0),
            sampled: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            tx,
        };
        (sampler, rx)
    }

    /// Whether the current request should be traced. One relaxed atomic
    /// increment; this is the entire untraced-request overhead.
    pub fn should_sample(&self) -> bool {
        self.counter.fetch_add(1, Ordering::Relaxed) % self.period == 0
    }

    /// Hand a traced request's samples to the re-planner; drops (and
    /// counts the drop) when the queue is full or the re-planner is gone.
    pub fn submit(&self, samples: Vec<EdgeSample>) {
        match self.tx.try_send(samples) {
            Ok(()) => {
                self.sampled.fetch_add(1, Ordering::Relaxed);
            }
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Requests seen by the sampling decision.
    pub fn requests_seen(&self) -> u64 {
        self.counter.load(Ordering::Relaxed)
    }

    /// Sample batches successfully queued.
    pub fn sampled(&self) -> u64 {
        self.sampled.load(Ordering::Relaxed)
    }

    /// Sample batches dropped under backpressure.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// Execute a compiled plan while collecting one [`EdgeSample`] per step
/// (RU boundary steps of real kinds included), with contexts chained
/// exactly as the expanded search graph defines them (first step from
/// `Context::Start`, then `After(prev)`), and the plan's kind recorded
/// on every sample.
pub fn trace_request(
    cp: &CompiledPlan,
    input: &SplitComplex,
    mode: &SampleMode,
    out: &mut Vec<EdgeSample>,
) -> SplitComplex {
    let kind = cp.kind;
    let isa = cp.isa();
    let mut ctx = Context::Start;
    cp.run_on_traced(input, &mut |edge, stage, measured_ns| {
        let ns = match mode {
            SampleMode::Wallclock => measured_ns,
            SampleMode::Oracle(f) => f(edge, stage, ctx),
        };
        out.push(EdgeSample { edge, stage, ctx, kind, batch: 1, isa, ns, span: SampleSpan::Edge });
        ctx = Context::After(edge);
    })
}

/// In-place variant of [`trace_request`] for the zero-copy scalar path:
/// the request's own buffer is transformed where it sits (no clone, no
/// scratch). Arithmetic and samples are identical to [`trace_request`] —
/// only the allocation differs.
pub fn trace_request_inplace(
    cp: &CompiledPlan,
    re: &mut [f32],
    im: &mut [f32],
    mode: &SampleMode,
    out: &mut Vec<EdgeSample>,
) {
    let kind = cp.kind;
    let isa = cp.isa();
    let mut ctx = Context::Start;
    cp.run_traced(re, im, &mut |edge, stage, measured_ns| {
        let ns = match mode {
            SampleMode::Wallclock => measured_ns,
            SampleMode::Oracle(f) => f(edge, stage, ctx),
        };
        out.push(EdgeSample { edge, stage, ctx, kind, batch: 1, isa, ns, span: SampleSpan::Edge });
        ctx = Context::After(edge);
    });
}

/// Trace one in-place execution of a [`crate::fft::CompiledExec`]. Flat
/// entries delegate to [`trace_request_inplace`] (one sample per plan
/// step). Blocked entries run the four-step path and collect its four
/// boundary-pass samples — column gather (TR), panel scatter (TR), block
/// twiddle (BT), final transpose (TR) — shaped by the active (p, q)
/// split. Sub-FFT interiors are not sampled: they are ordinary compiled
/// plans at sub-transform sizes, outside the serving size's attribution
/// grid. Oracle mode substitutes boundary values like edge values
/// (`f(edge, 0, Start)`), keeping simulator-driven tests deterministic.
pub fn trace_exec_inplace(
    ce: &mut crate::fft::CompiledExec,
    re: &mut [f32],
    im: &mut [f32],
    mode: &SampleMode,
    out: &mut Vec<EdgeSample>,
) {
    match ce {
        crate::fft::CompiledExec::Flat(cp) => trace_request_inplace(cp, re, im, mode, out),
        crate::fft::CompiledExec::Blocked(four) => {
            let kind = four.kind();
            let isa = four.isa();
            let (p, q) = four.factors();
            four.run_traced(re, im, &mut |edge, _stage, measured_ns| {
                let ns = match mode {
                    SampleMode::Wallclock => measured_ns,
                    SampleMode::Oracle(f) => f(edge, 0, Context::Start),
                };
                out.push(EdgeSample::boundary(edge, p, q, kind, isa, ns));
            });
        }
    }
}

/// Batched analogue of [`trace_request`]: execute a gathered batch via
/// [`CompiledPlan::run_batch_traced`], collecting one [`EdgeSample`] per
/// step with `batch` set to the group's live size — whole-batch `ns`, so
/// the cost model can learn the per-transform amortization at that batch
/// size. In `Oracle` mode the per-transform oracle value is scaled by
/// the batch size (the oracle has no amortization model; it keeps
/// simulator-driven tests deterministic).
pub fn trace_batch(
    cp: &CompiledPlan,
    buf: &mut crate::fft::BatchBuffer,
    mode: &SampleMode,
    out: &mut Vec<EdgeSample>,
) {
    let b = buf.batch();
    let kind = cp.kind;
    let isa = cp.isa();
    let mut ctx = Context::Start;
    cp.run_batch_traced(buf, &mut |edge, stage, measured_ns| {
        let ns = match mode {
            SampleMode::Wallclock => measured_ns,
            SampleMode::Oracle(f) => f(edge, stage, ctx) * b as f64,
        };
        out.push(EdgeSample { edge, stage, ctx, kind, batch: b, isa, ns, span: SampleSpan::Edge });
        ctx = Context::After(edge);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::Executor;
    use crate::plan::Plan;

    #[test]
    fn period_one_samples_everything() {
        let (s, _rx) = TraceSampler::new(1, 4);
        for _ in 0..10 {
            assert!(s.should_sample());
        }
    }

    #[test]
    fn period_n_samples_one_in_n() {
        let (s, _rx) = TraceSampler::new(4, 4);
        let hits = (0..100).filter(|_| s.should_sample()).count();
        assert_eq!(hits, 25);
        assert_eq!(s.requests_seen(), 100);
    }

    #[test]
    fn submit_is_bounded_and_never_blocks() {
        let (s, rx) = TraceSampler::new(1, 2);
        for _ in 0..5 {
            s.submit(Vec::new());
        }
        assert_eq!(s.sampled(), 2);
        assert_eq!(s.dropped(), 3);
        drop(rx);
        s.submit(Vec::new());
        assert_eq!(s.dropped(), 4);
    }

    #[test]
    fn trace_request_matches_untraced_output_bitwise() {
        let n = 256;
        let mut ex = Executor::new();
        let cp = ex.compile(&Plan::parse("R4,R4,R2,F8").unwrap(), n, true);
        let input = SplitComplex::random(n, 9);
        let mut samples = Vec::new();
        let traced = trace_request(&cp, &input, &SampleMode::Wallclock, &mut samples);
        assert_eq!(traced, cp.run_on(&input));
        assert_eq!(samples.len(), 4);
        // context chain: start, then after each preceding edge
        assert_eq!(samples[0].ctx, Context::Start);
        assert_eq!(samples[1].ctx, Context::After(EdgeType::R4));
        assert_eq!(samples[3].ctx, Context::After(EdgeType::R2));
        assert!(samples.iter().all(|s| s.ns >= 0.0));
        assert!(samples.iter().all(|s| s.batch == 1));
        assert!(samples.iter().all(|s| s.kind == TransformKind::Forward));
        // samples carry the backend the plan actually dispatched to
        assert!(samples.iter().all(|s| s.isa == cp.isa()));
    }

    #[test]
    fn traced_real_transform_samples_the_ru_step_with_its_context() {
        // The RU boundary step is a real CompiledStep: it gets an
        // EdgeSample in the context of the final c2c edge (R2C) or at
        // Start feeding After(RU) into the first c2c edge (C2R) — the
        // context-dependent cost the paper's thesis says no
        // context-free model can price.
        let n = 128;
        let mut ex = Executor::new();
        let half = Plan::parse("R4,R2,F8").unwrap(); // 6 levels for h = 64
        let r2c = ex.compile_kind(&half, n, true, TransformKind::RealForward);
        let mut samples = Vec::new();
        trace_request(&r2c, &SplitComplex::random(n, 1), &SampleMode::Wallclock, &mut samples);
        assert_eq!(samples.len(), 4);
        let ru = samples.last().unwrap();
        assert_eq!(ru.edge, EdgeType::RU);
        assert_eq!(ru.ctx, Context::After(EdgeType::F8));
        assert!(samples.iter().all(|s| s.kind == TransformKind::RealForward));
        let c2r = ex.compile_kind(&half, n, true, TransformKind::RealInverse);
        samples.clear();
        trace_request(&c2r, &SplitComplex::random(n, 2), &SampleMode::Wallclock, &mut samples);
        assert_eq!(samples[0].edge, EdgeType::RU);
        assert_eq!(samples[0].ctx, Context::Start);
        assert_eq!(samples[1].ctx, Context::After(EdgeType::RU));
        assert!(samples.iter().all(|s| s.kind == TransformKind::RealInverse));
    }

    #[test]
    fn trace_batch_matches_run_batch_and_records_batch_size() {
        let n = 256;
        let mut ex = Executor::new();
        let cp = ex.compile(&Plan::parse("R4,R4,R2,F8").unwrap(), n, true);
        let inputs: Vec<SplitComplex> = (0..5).map(|i| SplitComplex::random(n, i)).collect();
        let refs: Vec<&SplitComplex> = inputs.iter().collect();
        let mut traced = crate::fft::BatchBuffer::new(n, 5);
        traced.gather(&refs);
        let mut plain = traced.clone();
        let mut samples = Vec::new();
        trace_batch(&cp, &mut traced, &SampleMode::Wallclock, &mut samples);
        cp.run_batch(&mut plain);
        assert_eq!(traced, plain);
        assert_eq!(samples.len(), 4);
        assert_eq!(samples[0].ctx, Context::Start);
        assert!(samples.iter().all(|s| s.batch == 5));
    }

    #[test]
    fn marshal_samples_carry_the_span_and_fixed_placeholders() {
        let s = EdgeSample::marshal(TransformKind::Forward, 8, Isa::Scalar, 400.0);
        assert_eq!(s.span, SampleSpan::Marshal);
        assert_eq!((s.edge, s.stage, s.ctx), (EdgeType::RU, 0, Context::Start));
        assert_eq!(s.per_transform_ns(), 50.0);
    }

    #[test]
    fn trace_batch_oracle_scales_by_batch_size() {
        let n = 32; // R4,R4,R2 = 5 stages

        let mut ex = Executor::new();
        let cp = ex.compile(&Plan::parse("R4,R4,R2").unwrap(), n, true);
        let mode = SampleMode::Oracle(Arc::new(|_, _, _| 10.0));
        let inputs: Vec<SplitComplex> = (0..3).map(|i| SplitComplex::random(n, i)).collect();
        let refs: Vec<&SplitComplex> = inputs.iter().collect();
        let mut buf = crate::fft::BatchBuffer::new(n, 3);
        buf.gather(&refs);
        let mut samples = Vec::new();
        trace_batch(&cp, &mut buf, &mode, &mut samples);
        assert!(samples.iter().all(|s| s.ns == 30.0 && s.batch == 3));
    }

    #[test]
    fn oracle_mode_reports_oracle_values() {
        let n = 32; // R4,R4,R2 = 5 stages

        let mut ex = Executor::new();
        let cp = ex.compile(&Plan::parse("R4,R4,R2").unwrap(), n, true);
        let mode = SampleMode::Oracle(Arc::new(|e: EdgeType, s: usize, _ctx| {
            (e.index() * 100 + s) as f64 + 1.0
        }));
        let mut samples = Vec::new();
        trace_request(&cp, &SplitComplex::random(n, 1), &mode, &mut samples);
        assert_eq!(samples[0].ns, (EdgeType::R4.index() * 100) as f64 + 1.0);
        assert_eq!(samples[2].ns, (EdgeType::R2.index() * 100 + 4) as f64 + 1.0);
    }
}
