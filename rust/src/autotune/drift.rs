//! Drift detection: is the world still the one the active plan was
//! searched under?
//!
//! The detector keeps a *reference* weight per (cell, batch class) — the
//! value the active plan's search consumed. Each check compares the live
//! per-transform EWMA of every sufficiently-sampled (cell, class)
//! against its reference; a cell whose relative deviation exceeds the
//! threshold is drifted, and enough drifted cells flag the model. After
//! a re-plan the detector is rebased to the weights that search
//! consumed, so detection always measures movement *since the active
//! plan was chosen*, not since process start.
//!
//! The offline prior only knows the unbatched regime, so batched
//! observations initially compare against the class-0 reference: a
//! serving mix that shifts *into* heavy batching reads as drift (the
//! amortized per-transform costs diverge from the unbatched prior),
//! triggers a re-plan at the new regime's batch class, and the rebase
//! then installs per-class references. A shift back *out* of batching
//! leaves per-class weights stable, so it is not drift — the re-planner
//! separately watches the modal batch class and re-searches on a regime
//! shift (see `replanner::run_loop`) — exactly the "optimal plan
//! legitimately differs with B" behavior the batched engine needs.
//!
//! Detection uses the raw live means (fast to react); the re-planner's
//! search uses the prior-damped blend (slow to overreact) — the classic
//! fast-detector/slow-actor split.
//!
//! A second, slower trigger rides on the same comparison: the *residual
//! streak*. A cell whose deviation stays past the (lower) streak
//! threshold for K consecutive checks fires a drift event even though no
//! single check ever crossed the main threshold — the signature of slow
//! co-tenant pressure, where the EWMA tracks a persistent few-percent
//! residual that per-window detection keeps reading as noise. Streaks
//! reset whenever the cell drops back under the streak threshold, and on
//! rebase (the movement was accepted as the new operating point).

use std::collections::HashMap;

use crate::cost::Wisdom;
use crate::edge::{Context, EdgeType};

use super::model::{Cell, OnlineCost};

/// Outcome of one drift check.
#[derive(Debug, Clone, PartialEq)]
pub struct DriftReport {
    pub drifted: bool,
    /// Cells with enough samples to participate.
    pub cells_checked: usize,
    /// Participating cells beyond the threshold.
    pub cells_over: usize,
    /// Largest relative deviation seen.
    pub max_rel_dev: f64,
    /// The cell behind `max_rel_dev`.
    pub worst: Option<(EdgeType, usize, Context)>,
    /// The residual-streak trigger fired: some cell stayed past the
    /// streak threshold for the configured number of consecutive checks
    /// (possibly without ever crossing the main threshold).
    pub streak_fired: bool,
    /// The cell behind `streak_fired` (the longest-running streak).
    pub streak_cell: Option<(EdgeType, usize, Context)>,
}

impl DriftReport {
    /// One-line human summary (`spfft obs` and log lines).
    pub fn summary(&self) -> String {
        let worst = match &self.worst {
            Some((e, s, ctx)) => format!(", worst {e}@{s} in {ctx}"),
            None => String::new(),
        };
        let streak = match &self.streak_cell {
            Some((e, s, ctx)) if self.streak_fired => {
                format!(", residual streak on {e}@{s} in {ctx}")
            }
            _ => String::new(),
        };
        format!(
            "{}: {}/{} cells over, max dev {:.1}%{worst}{streak}",
            if self.drifted { "drifted" } else { "stable" },
            self.cells_over,
            self.cells_checked,
            100.0 * self.max_rel_dev
        )
    }
}

/// Compares live observations against the searched-under reference.
/// Kind-aware implicitly: [`OnlineCost::observed_cells`] returns the
/// *focus kind's* observation slots, so a detector over a model tuned
/// for an inverse (or split-calibrated) workload measures that
/// workload's movement.
#[derive(Debug, Clone)]
pub struct DriftDetector {
    /// (cell, batch class) → per-transform reference ns. Class 0 is
    /// seeded from the prior; other classes appear on rebase.
    reference: HashMap<(Cell, usize), f64>,
    threshold: f64,
    min_samples: u64,
    min_cells: usize,
    /// Residual-streak trigger: deviation a cell must sustain to extend
    /// its streak (normally well under `threshold`).
    streak_threshold: f64,
    /// Consecutive checks past `streak_threshold` that fire the streak
    /// trigger (0 = disabled).
    streak_windows: u32,
    /// Live streak counters per (cell, class); a check under the streak
    /// threshold resets the cell's counter.
    streaks: HashMap<(Cell, usize), u32>,
}

impl DriftDetector {
    pub fn new(
        reference: HashMap<(Cell, usize), f64>,
        threshold: f64,
        min_samples: u64,
        min_cells: usize,
    ) -> DriftDetector {
        assert!(threshold > 0.0, "drift threshold must be positive");
        DriftDetector {
            reference,
            threshold,
            min_samples: min_samples.max(1),
            min_cells: min_cells.max(1),
            streak_threshold: threshold,
            streak_windows: 0,
            streaks: HashMap::new(),
        }
    }

    /// Enable the residual-streak trigger: a cell sustaining a deviation
    /// past `threshold` for `windows` consecutive checks flags drift even
    /// when per-window detection stays quiet. `windows = 0` disables.
    pub fn with_streak(mut self, threshold: f64, windows: u32) -> DriftDetector {
        assert!(windows == 0 || threshold > 0.0, "streak threshold must be positive");
        self.streak_threshold = threshold;
        self.streak_windows = windows;
        self
    }

    /// Reference = the offline prior (the initial plan's search weights),
    /// which only knows the unbatched class.
    pub fn from_wisdom(
        prior: &Wisdom,
        threshold: f64,
        min_samples: u64,
        min_cells: usize,
    ) -> DriftDetector {
        DriftDetector::new(
            prior.cells.iter().map(|&(e, s, ctx, ns)| (((e, s, ctx), 0), ns)).collect(),
            threshold,
            min_samples,
            min_cells,
        )
    }

    /// Compare live per-transform means against the reference. A class
    /// without its own reference falls back to the class-0 (unbatched)
    /// reference, so newly-batched traffic is judged against the prior.
    /// Mutates only the residual-streak counters.
    pub fn check(&mut self, model: &OnlineCost) -> DriftReport {
        let mut report = DriftReport {
            drifted: false,
            cells_checked: 0,
            cells_over: 0,
            max_rel_dev: 0.0,
            worst: None,
            streak_fired: false,
            streak_cell: None,
        };
        let mut streaks = HashMap::new();
        let mut longest = 0u32;
        for ((cell, class), est) in model.observed_cells() {
            if est.count < self.min_samples {
                continue;
            }
            let Some(&reference) = self
                .reference
                .get(&(cell, class))
                .or_else(|| self.reference.get(&(cell, 0)))
            else {
                continue;
            };
            report.cells_checked += 1;
            let rel = (est.mean - reference).abs() / reference.max(1e-9);
            if rel > report.max_rel_dev {
                report.max_rel_dev = rel;
                report.worst = Some(cell);
            }
            if rel > self.threshold {
                report.cells_over += 1;
            }
            // Streak bookkeeping: cells past the streak threshold extend
            // their counter; everything else resets by omission (the new
            // map only keeps cells that sustained the residual).
            if self.streak_windows > 0 && rel > self.streak_threshold {
                let run = self.streaks.get(&(cell, class)).copied().unwrap_or(0) + 1;
                streaks.insert((cell, class), run);
                if run >= self.streak_windows && run > longest {
                    longest = run;
                    report.streak_fired = true;
                    report.streak_cell = Some(cell);
                }
            }
        }
        if self.streak_windows > 0 {
            self.streaks = streaks;
            if report.streak_fired {
                // The trigger hands off to the re-planner; start the next
                // streak from zero instead of re-firing every check while
                // the search and rebase are still in flight.
                self.streaks.clear();
            }
        }
        report.drifted = report.cells_over >= self.min_cells || report.streak_fired;
        report
    }

    /// Rebase every reference cell to the model's current (blended)
    /// estimate — called after a re-plan so the next check measures
    /// movement relative to the weights that search consumed. Observed
    /// (cell, class) pairs without a reference yet gain one here.
    pub fn rebase(&mut self, model: &OnlineCost) {
        let keys: Vec<(Cell, usize)> = self.reference.keys().copied().collect();
        for (cell, class) in keys {
            self.reference.insert((cell, class), model.estimate_at(cell, class));
        }
        for ((cell, class), _) in model.observed_cells() {
            self.reference
                .entry((cell, class))
                .or_insert_with(|| model.estimate_at(cell, class));
        }
        // The rebased weights are the new operating point; sustained
        // residuals against the *old* reference are no longer movement.
        self.streaks.clear();
    }

    /// The reference weight for a (cell, class) (tests / introspection).
    pub fn reference(&self, cell: Cell, class: usize) -> Option<f64> {
        self.reference.get(&(cell, class)).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::sampler::{EdgeSample, SampleSpan};
    use crate::cost::SimCost;

    fn setup(n: usize) -> (OnlineCost, DriftDetector, Wisdom) {
        let w = Wisdom::harvest(&mut SimCost::m1(n), "m1");
        let model = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        let det = DriftDetector::from_wisdom(&w, 0.25, 3, 1);
        (model, det, w)
    }

    fn feed(model: &mut OnlineCost, cell: Cell, ns: f64, times: usize) {
        for _ in 0..times {
            model.observe(&EdgeSample {
                edge: cell.0,
                stage: cell.1,
                ctx: cell.2,
                kind: crate::kind::TransformKind::Forward,
                batch: 1,
                isa: crate::isa::Isa::Scalar,
                span: SampleSpan::Edge,
                ns,
            });
        }
    }

    fn feed_b(model: &mut OnlineCost, cell: Cell, batch: usize, ns: f64, times: usize) {
        for _ in 0..times {
            model.observe(&EdgeSample {
                edge: cell.0,
                stage: cell.1,
                ctx: cell.2,
                kind: crate::kind::TransformKind::Forward,
                batch,
                isa: crate::isa::Isa::Scalar,
                span: SampleSpan::Edge,
                ns,
            });
        }
    }

    #[test]
    fn no_observations_no_drift() {
        let (model, mut det, _) = setup(256);
        let r = det.check(&model);
        assert!(!r.drifted);
        assert_eq!(r.cells_checked, 0);
    }

    #[test]
    fn on_reference_observations_do_not_drift() {
        let (mut model, mut det, w) = setup(256);
        for &(e, s, ctx, ns) in w.cells.iter().take(10) {
            feed(&mut model, (e, s, ctx), ns, 5);
        }
        let r = det.check(&model);
        assert_eq!(r.cells_checked, 10);
        assert!(!r.drifted, "max dev {}", r.max_rel_dev);
    }

    #[test]
    fn inflated_cell_trips_after_min_samples() {
        let (mut model, mut det, w) = setup(256);
        let (e, s, ctx, ns) = w.cells[0];
        feed(&mut model, (e, s, ctx), ns * 3.0, 2);
        assert!(!det.check(&model).drifted, "tripped below min_samples");
        feed(&mut model, (e, s, ctx), ns * 3.0, 2);
        let r = det.check(&model);
        assert!(r.drifted);
        assert_eq!(r.cells_over, 1);
        assert_eq!(r.worst, Some((e, s, ctx)));
        assert!((r.max_rel_dev - 2.0).abs() < 1e-9);
    }

    #[test]
    fn batched_observations_compare_against_class0_prior() {
        // Heavily-batched traffic whose per-transform cost halves (real
        // amortization) must read as drift against the unbatched prior —
        // that is the trigger for re-planning at the new batch regime.
        let (mut model, mut det, w) = setup(256);
        let (e, s, ctx, ns) = w.cells[0];
        feed_b(&mut model, (e, s, ctx), 16, 16.0 * ns * 0.5, 10);
        let r = det.check(&model);
        assert!(r.drifted, "amortized batched cost not flagged: {r:?}");
        assert!((r.max_rel_dev - 0.5).abs() < 1e-6);
    }

    #[test]
    fn rebase_installs_per_class_references() {
        let (mut model, mut det, w) = setup(256);
        let (e, s, ctx, ns) = w.cells[0];
        feed_b(&mut model, (e, s, ctx), 16, 16.0 * ns * 0.5, 20);
        assert!(det.check(&model).drifted);
        assert_eq!(det.reference((e, s, ctx), crate::autotune::model::batch_class(16)), None);
        det.rebase(&model);
        assert!(det.reference((e, s, ctx), crate::autotune::model::batch_class(16)).is_some());
        let r = det.check(&model);
        assert!(!r.drifted, "still drifted after rebase: dev {}", r.max_rel_dev);
    }

    #[test]
    fn rebase_silences_accepted_drift() {
        let (mut model, mut det, w) = setup(256);
        let (e, s, ctx, ns) = w.cells[0];
        feed(&mut model, (e, s, ctx), ns * 3.0, 20);
        assert!(det.check(&model).drifted);
        det.rebase(&model);
        let r = det.check(&model);
        // reference is now the blended estimate; the live mean sits within
        // threshold of it (blend weight 20/24 leaves a small gap)
        assert!(!r.drifted, "still drifted after rebase: dev {}", r.max_rel_dev);
    }

    #[test]
    fn persistent_sub_threshold_residual_fires_via_streak() {
        // 15% deviation: under the 25% main threshold (never a drifted
        // cell), over the 10% streak threshold. Three consecutive checks
        // must fire the streak trigger; the first two stay quiet.
        let (mut model, det, w) = setup(256);
        let mut det = det.with_streak(0.1, 3);
        let (e, s, ctx, ns) = w.cells[0];
        feed(&mut model, (e, s, ctx), ns * 1.15, 5);
        for window in 1..=2 {
            let r = det.check(&model);
            assert!(!r.drifted, "fired after only {window} window(s)");
            assert_eq!(r.cells_over, 0, "15% must stay under the main threshold");
        }
        let r = det.check(&model);
        assert!(r.drifted, "streak of 3 did not fire");
        assert!(r.streak_fired);
        assert_eq!(r.cells_over, 0, "main trigger must stay quiet");
        assert_eq!(r.streak_cell, Some((e, s, ctx)));
        // Firing hands off to the re-planner: the counter restarts, so
        // the very next check is quiet again.
        assert!(!det.check(&model).drifted);
    }

    #[test]
    fn recovering_cell_resets_its_streak() {
        let (mut model, det, w) = setup(256);
        let mut det = det.with_streak(0.1, 3);
        let (e, s, ctx, ns) = w.cells[0];
        // alpha 0.5: two windows over, then the cell recovers to the
        // reference before the streak completes
        feed(&mut model, (e, s, ctx), ns * 1.2, 4);
        assert!(!det.check(&model).drifted);
        assert!(!det.check(&model).drifted);
        feed(&mut model, (e, s, ctx), ns, 40); // EWMA back onto reference
        assert!(!det.check(&model).drifted, "recovered cell still counted");
        // the streak restarted from zero: two more deviating windows
        // must not fire
        feed(&mut model, (e, s, ctx), ns * 1.2, 10);
        assert!(!det.check(&model).drifted);
        assert!(!det.check(&model).drifted);
        let r = det.check(&model);
        assert!(r.streak_fired, "restarted streak never completed");
    }

    #[test]
    fn rebase_clears_streaks() {
        let (mut model, det, w) = setup(256);
        let mut det = det.with_streak(0.1, 3);
        let (e, s, ctx, ns) = w.cells[0];
        feed(&mut model, (e, s, ctx), ns * 1.15, 5);
        assert!(!det.check(&model).drifted);
        assert!(!det.check(&model).drifted);
        det.rebase(&model); // movement accepted as the operating point
        // the old two-window run is gone AND the reference moved: quiet
        let r = det.check(&model);
        assert!(!r.drifted, "streak survived rebase: {}", r.summary());
    }
}
