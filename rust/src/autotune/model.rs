//! The online cost model: live EWMA estimates blended over a wisdom prior.
//!
//! Every sampled edge execution updates an exponentially-weighted running
//! mean for its (edge, stage, context) cell **per batch class**: batched
//! execution amortizes the per-pass twiddle load and memory round trip
//! across the group, so the per-transform cost of an edge is a genuine
//! function of the batch size it ran under, and the optimal plan can
//! legitimately differ with B (a memory-bound R2 chain shrinks relative
//! to fused blocks as the round trip amortizes). Samples are normalized
//! per transform (`ns / batch`) and bucketed by [`batch_class`] (log2).
//!
//! Planning queries return a confidence-weighted blend of the live
//! estimate *at the model's focus batch class* and the offline prior: a
//! cell with `s` samples trusts the live mean with weight
//! `s / (s + blend_samples)`. Cells the active plan never executes at
//! that class keep their prior — which is exactly what makes online
//! re-planning sound: the search compares freshly-observed cells of the
//! running plan against prior-valued alternatives, the same tradeoff
//! FFTW's wisdom makes offline, now maintained continuously and
//! per batch size.

use std::collections::HashMap;

use crate::cost::{CostModel, PlanningSurface, Wisdom};
use crate::edge::{Context, EdgeType};
use crate::isa::Isa;
use crate::kind::TransformKind;

use super::sampler::{EdgeSample, SampleSpan};

/// A cell key: (edge, stage, predecessor context). Observations carry
/// further axes — the transform kind and the codelet ISA — so the full
/// observation key is (kind, cell, batch class, isa); see
/// [`OnlineCost::observe`].
pub type Cell = (EdgeType, usize, Context);

// The batch-class bucketing lives in `crate::cost` now (one axis, one
// bucketing, shared with `PlanningSurface`); re-exported here for the
// historical import paths.
pub use crate::cost::{batch_class, class_batch, BATCH_CLASSES};

/// Live estimate for one (cell, batch class).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellEstimate {
    /// EWMA of observed per-transform nanoseconds.
    pub mean: f64,
    /// Samples folded into the mean.
    pub count: u64,
}

/// [`CostModel`] over prior + live observations.
pub struct OnlineCost {
    n: usize,
    edges: Vec<EdgeType>,
    alpha: f64,
    blend_samples: f64,
    /// Batch class planning queries read (what B the next search plans
    /// for); class 0 = unbatched, the prior's own regime.
    focus: usize,
    /// Transform kind planning queries read (what workload the next
    /// search optimizes). Folded through [`OnlineCost::kind_slot`].
    focus_kind: TransformKind,
    /// Calibration split: when false (default), inverse kinds fold onto
    /// the forward tables ([`TransformKind::measured_alias`] — the c2c
    /// kernels are literally shared); when true, every kind keeps its
    /// own observation cells so an operator can verify the symmetry.
    split_kinds: bool,
    prior: HashMap<Cell, f64>,
    /// Per-batch-class priors (class >= 1): the amortized per-transform
    /// surface harvested offline from a provider with a native batched
    /// path (`SimCost`, `NativeCost`). A class without one falls back to
    /// the unbatched prior — the pre-batched-model behavior. Kind-less:
    /// kinds share the batched c2c surface.
    class_priors: HashMap<(Cell, usize), f64>,
    /// Instruction set the serving executor dispatches (what backend
    /// produced — and will keep producing — the live samples). Planning
    /// queries whose surface leaves the ISA unpinned resolve to this, so
    /// the search tunes the code the host actually runs. Defaults to
    /// scalar; the coordinator stamps the executor's detected ISA.
    exec_isa: Isa,
    /// (cell, batch class, kind slot, isa) → live estimate. Samples from
    /// different codelet backends never fold together: a NEON fused pass
    /// and its scalar fallback are different machine code with different
    /// costs, and blending them would corrupt both surfaces.
    obs: HashMap<(Cell, usize, TransformKind, Isa), CellEstimate>,
    /// Per-batch-class offline prior for the panel transpose (gather or
    /// scatter, one direction), normalized **per transform** — seeded
    /// from the simulator's `marshal_ns` so execution-mode decisions
    /// start from the calibrated surface before any wall samples land.
    marshal_prior: HashMap<usize, f64>,
    /// Per-batch-class live marshal estimates (per-transform EWMA). The
    /// transpose is kind-, plan-, and ISA-agnostic data movement, so a
    /// single class axis suffices.
    marshal_obs: HashMap<usize, CellEstimate>,
    /// Offline prior for one blocked-execution transpose walk over a
    /// rows×cols matrix — seeded from the simulator's `transpose_ns` so
    /// flat-vs-blocked decisions start calibrated. Keyed by shape: the
    /// blocked candidates for one n differ only in (p, q).
    transpose_prior: HashMap<(usize, usize), f64>,
    /// Live EWMA of traced blocked-boundary transpose samples (gather,
    /// scatter, and final walks each count as one transpose of the
    /// active (p, q) — the same three-walk convention the planner
    /// prices). Fed by [`OnlineCost::observe_transpose`]; like the
    /// marshal store this is plan-, kind-, and ISA-agnostic movement.
    transpose_obs: HashMap<(usize, usize), CellEstimate>,
    /// Offline prior for the inter-block twiddle pass over an nn-point
    /// matrix (nn = p·q of the blocked candidate).
    blocktw_prior: HashMap<usize, f64>,
    /// Live EWMA of traced block-twiddle samples, keyed the same way.
    blocktw_obs: HashMap<usize, CellEstimate>,
}

impl OnlineCost {
    /// Build from an offline wisdom database (the prior). The prior is
    /// per-transform and batch-agnostic (wisdom v1 measures B=1).
    pub fn from_wisdom(prior: &Wisdom, alpha: f64, blend_samples: f64) -> OnlineCost {
        assert!(alpha > 0.0 && alpha <= 1.0, "ewma alpha must be in (0, 1]");
        assert!(blend_samples >= 0.0, "blend_samples must be >= 0");
        let mut edges: Vec<EdgeType> = prior.cells.iter().map(|c| c.0).collect();
        edges.sort();
        edges.dedup();
        OnlineCost {
            n: prior.n,
            edges,
            alpha,
            blend_samples,
            focus: 0,
            focus_kind: TransformKind::Forward,
            split_kinds: false,
            exec_isa: Isa::Scalar,
            prior: prior.cells.iter().map(|&(e, s, ctx, ns)| ((e, s, ctx), ns)).collect(),
            class_priors: HashMap::new(),
            obs: HashMap::new(),
            marshal_prior: HashMap::new(),
            marshal_obs: HashMap::new(),
            transpose_prior: HashMap::new(),
            transpose_obs: HashMap::new(),
            blocktw_prior: HashMap::new(),
            blocktw_obs: HashMap::new(),
        }
    }

    /// One EWMA fold into a keyed estimate store.
    fn fold<K: std::hash::Hash + Eq>(
        store: &mut HashMap<K, CellEstimate>,
        key: K,
        alpha: f64,
        value: f64,
    ) {
        match store.get_mut(&key) {
            Some(est) => {
                est.mean = alpha * value + (1.0 - alpha) * est.mean;
                est.count += 1;
            }
            None => {
                store.insert(key, CellEstimate { mean: value, count: 1 });
            }
        }
    }

    /// The observation slot a kind's samples land in: the kind itself
    /// under the calibration split, its [`TransformKind::measured_alias`]
    /// otherwise.
    fn kind_slot(&self, kind: TransformKind) -> TransformKind {
        if self.split_kinds {
            kind
        } else {
            kind.measured_alias()
        }
    }

    /// Enable/disable the calibration split (see `split_kinds` field).
    /// Flip before feeding samples: existing folded observations are not
    /// re-keyed.
    pub fn set_split_kinds(&mut self, split: bool) {
        self.split_kinds = split;
    }

    /// Whether the calibration split is on.
    pub fn split_kinds(&self) -> bool {
        self.split_kinds
    }

    /// ISA unpinned planning surfaces (and the legacy `edge_ns` path)
    /// resolve to.
    pub fn exec_isa(&self) -> Isa {
        self.exec_isa
    }

    /// Point unpinned queries at the executor's dispatched ISA. Set
    /// this from [`crate::fft::exec::Executor::isa`] so the model reads
    /// the observation slot the serving path writes.
    pub fn set_exec_isa(&mut self, isa: Isa) {
        self.exec_isa = isa;
    }

    /// Install a per-class prior: the offline per-transform estimate for
    /// `cell` when executed in groups of the class's batch width. Until
    /// live samples arrive at that class, planning there starts from
    /// this amortized surface instead of the unbatched prior.
    pub fn set_class_prior(&mut self, cell: Cell, class: usize, ns: f64) {
        if ns.is_finite() && ns > 0.0 && class >= 1 && class < BATCH_CLASSES {
            self.class_priors.insert((cell, class), ns);
        }
    }

    /// Install a whole batched prior database (per-transform cells
    /// harvested over batches of `b`, e.g. `Wisdom::harvest_batched`)
    /// at `b`'s batch class.
    pub fn set_batched_prior(&mut self, b: usize, prior: &Wisdom) {
        let class = batch_class(b);
        for &(e, s, ctx, ns) in &prior.cells {
            self.set_class_prior((e, s, ctx), class, ns);
        }
    }

    /// Classes (>= 1) with an installed batched prior for `cell`,
    /// sorted — the persistence view of the class-prior surface.
    pub fn prior_classes(&self, cell: Cell) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .class_priors
            .keys()
            .filter(|(c, _)| *c == cell)
            .map(|(_, class)| *class)
            .collect();
        v.sort_unstable();
        v
    }

    /// The prior consulted at `class`: its own batched prior when
    /// installed, the unbatched prior otherwise. Boundary-context cells
    /// (`After(RU)`) missing from the prior — legacy wisdom files
    /// predate them as stored cells — fall back to the historical
    /// after-R2 proxy cell.
    pub fn prior_at(&self, cell: Cell, class: usize) -> Option<f64> {
        if class > 0 {
            if let Some(&p) = self.class_priors.get(&(cell, class)) {
                return Some(p);
            }
        }
        if let Some(&p) = self.prior.get(&cell) {
            return Some(p);
        }
        if cell.2 == Context::After(EdgeType::RU) {
            return self.prior_at((cell.0, cell.1, Context::After(EdgeType::R2)), class);
        }
        None
    }

    /// Install the offline per-transform marshal prior for a batch
    /// class (one direction of the panel transpose). Until live marshal
    /// samples arrive at that class, [`CostModel::marshal_ns`] answers
    /// from this instead of the cold strided-R2 proxy.
    pub fn set_marshal_prior(&mut self, class: usize, ns_per_tx: f64) {
        if ns_per_tx.is_finite() && ns_per_tx > 0.0 && class < BATCH_CLASSES {
            self.marshal_prior.insert(class, ns_per_tx);
        }
    }

    /// Raw live marshal estimate (per transform) at a batch class;
    /// `None` until a marshal-span sample has landed there.
    pub fn marshal_observation_at(&self, class: usize) -> Option<CellEstimate> {
        self.marshal_obs.get(&class).copied()
    }

    /// Install the offline prior for one blocked transpose walk over a
    /// rows×cols matrix (whole-pass ns, e.g. the simulator's
    /// `transpose_ns`).
    pub fn set_transpose_prior(&mut self, rows: usize, cols: usize, ns: f64) {
        if ns.is_finite() && ns > 0.0 {
            self.transpose_prior.insert((rows, cols), ns);
        }
    }

    /// Install the offline prior for the inter-block twiddle pass over
    /// an nn-point matrix.
    pub fn set_block_twiddle_prior(&mut self, nn: usize, ns: f64) {
        if ns.is_finite() && ns > 0.0 {
            self.blocktw_prior.insert(nn, ns);
        }
    }

    /// Fold one traced blocked-transpose sample (the gather, scatter,
    /// or final walk of a rows×cols blocked run — each is one transpose
    /// under the planner's three-walk pricing). Garbage discarded as in
    /// [`OnlineCost::observe`].
    pub fn observe_transpose(&mut self, rows: usize, cols: usize, ns: f64) {
        if ns.is_finite() && ns > 0.0 {
            Self::fold(&mut self.transpose_obs, (rows, cols), self.alpha, ns);
        }
    }

    /// Fold one traced block-twiddle sample over an nn-point matrix.
    pub fn observe_block_twiddle(&mut self, nn: usize, ns: f64) {
        if ns.is_finite() && ns > 0.0 {
            Self::fold(&mut self.blocktw_obs, nn, self.alpha, ns);
        }
    }

    /// Raw live transpose estimate for a shape; `None` until sampled.
    pub fn transpose_observation(&self, rows: usize, cols: usize) -> Option<CellEstimate> {
        self.transpose_obs.get(&(rows, cols)).copied()
    }

    /// Raw live block-twiddle estimate for a size; `None` until sampled.
    pub fn block_twiddle_observation(&self, nn: usize) -> Option<CellEstimate> {
        self.blocktw_obs.get(&nn).copied()
    }

    /// Confidence blend of an optional prior and optional live estimate;
    /// `None` when neither exists (caller falls back to its proxy).
    fn blend(&self, prior: Option<f64>, obs: Option<CellEstimate>) -> Option<f64> {
        match (prior, obs) {
            (Some(p), Some(o)) => {
                let c = o.count as f64 / (o.count as f64 + self.blend_samples);
                Some(p * (1.0 - c) + o.mean * c)
            }
            (Some(p), None) => Some(p),
            (None, Some(o)) => Some(o.mean),
            (None, None) => None,
        }
    }

    /// Fold one live sample into its (kind, cell, batch class),
    /// normalized per transform (inverse kinds fold onto the forward
    /// slot unless the calibration split is on). Marshal-span samples
    /// route to the per-class transpose store and never touch edge
    /// cells — data movement is not an algorithm edge. Non-finite or
    /// non-positive values (timer glitches) and zero batch sizes are
    /// discarded.
    pub fn observe(&mut self, sample: &EdgeSample) {
        if !sample.ns.is_finite() || sample.ns <= 0.0 || sample.batch == 0 {
            return;
        }
        let per_tx = sample.ns / sample.batch as f64;
        if sample.span == SampleSpan::Marshal {
            let class = batch_class(sample.batch);
            match self.marshal_obs.get_mut(&class) {
                Some(est) => {
                    est.mean = self.alpha * per_tx + (1.0 - self.alpha) * est.mean;
                    est.count += 1;
                }
                None => {
                    self.marshal_obs.insert(class, CellEstimate { mean: per_tx, count: 1 });
                }
            }
            return;
        }
        if sample.edge.is_boundary() && sample.edge != EdgeType::RU {
            // Blocked-boundary samples (TR/BT) carry a matrix shape the
            // generic sample has no field for; the coordinator routes
            // them through `observe_transpose` / `observe_block_twiddle`
            // with the active plan's (p, q). A shapeless one reaching
            // here would fold walks of different sizes into one cell.
            return;
        }
        let key = (
            (sample.edge, sample.stage, sample.ctx),
            batch_class(sample.batch),
            self.kind_slot(sample.kind),
            sample.isa,
        );
        match self.obs.get_mut(&key) {
            Some(est) => {
                est.mean = self.alpha * per_tx + (1.0 - self.alpha) * est.mean;
                est.count += 1;
            }
            None => {
                self.obs.insert(key, CellEstimate { mean: per_tx, count: 1 });
            }
        }
    }

    /// Seed a (kind, cell, class, isa) live estimate directly (wisdom v2
    /// restore). The kind folds through the same slot as live samples;
    /// the ISA is stored verbatim — backends never fold.
    pub fn seed_kind_isa_at(
        &mut self,
        cell: Cell,
        class: usize,
        kind: TransformKind,
        isa: Isa,
        mean: f64,
        count: u64,
    ) {
        if mean.is_finite() && mean > 0.0 && count > 0 && class < BATCH_CLASSES {
            let slot = self.kind_slot(kind);
            self.obs.insert((cell, class, slot, isa), CellEstimate { mean, count });
        }
    }

    /// Seed a (kind, cell, class) live estimate at the exec ISA.
    pub fn seed_kind_at(
        &mut self,
        cell: Cell,
        class: usize,
        kind: TransformKind,
        mean: f64,
        count: u64,
    ) {
        self.seed_kind_isa_at(cell, class, kind, self.exec_isa, mean, count);
    }

    /// Seed a forward (cell, class) live estimate.
    pub fn seed_at(&mut self, cell: Cell, class: usize, mean: f64, count: u64) {
        self.seed_kind_at(cell, class, TransformKind::Forward, mean, count);
    }

    /// Seed the unbatched (class 0) forward estimate.
    pub fn seed(&mut self, cell: Cell, mean: f64, count: u64) {
        self.seed_at(cell, 0, mean, count);
    }

    /// Batch class planning queries are answered for.
    pub fn focus_class(&self) -> usize {
        self.focus
    }

    /// Point planning queries at a batch class (what B the next search
    /// optimizes for).
    pub fn set_focus_class(&mut self, class: usize) {
        self.focus = class.min(BATCH_CLASSES - 1);
    }

    /// Transform kind planning queries are answered for.
    pub fn focus_kind(&self) -> TransformKind {
        self.focus_kind
    }

    /// Point planning queries at a transform kind (what workload the
    /// next search optimizes for).
    pub fn set_focus_kind(&mut self, kind: TransformKind) {
        self.focus_kind = kind;
    }

    /// The blended per-transform estimate for `cell` at a batch class
    /// and kind. Cells without observations at that (class, kind slot)
    /// answer from the prior (the class's own batched prior when one is
    /// installed; the prior itself is kind-less — inverse reuses the
    /// forward tables until live splits say otherwise).
    pub fn estimate_kind_at(&self, cell: Cell, class: usize, kind: TransformKind) -> f64 {
        self.estimate_kind_isa_at(cell, class, kind, self.exec_isa)
    }

    /// The blended per-transform estimate for `cell` at a batch class,
    /// kind, and codelet ISA — the fully-keyed read. The prior is
    /// ISA-less (it describes whatever backend the harvesting provider
    /// dispatched), so unobserved (class, kind, isa) slots all answer
    /// from the same prior surface.
    pub fn estimate_kind_isa_at(
        &self,
        cell: Cell,
        class: usize,
        kind: TransformKind,
        isa: Isa,
    ) -> f64 {
        let prior = self.prior_at(cell, class);
        let obs = self.obs.get(&(cell, class, self.kind_slot(kind), isa)).copied();
        match (prior, obs) {
            (Some(p), Some(o)) => {
                let c = o.count as f64 / (o.count as f64 + self.blend_samples);
                p * (1.0 - c) + o.mean * c
            }
            (Some(p), None) => p,
            (None, Some(o)) => o.mean,
            (None, None) => panic!(
                "online cost: no prior or observation for {}@{} {} (class {class}, {kind})",
                cell.0, cell.1, cell.2
            ),
        }
    }

    /// The blended forward estimate at a batch class.
    pub fn estimate_at(&self, cell: Cell, class: usize) -> f64 {
        self.estimate_kind_at(cell, class, TransformKind::Forward)
    }

    /// The blended forward estimate at the unbatched class (B = 1).
    pub fn estimate(&self, cell: Cell) -> f64 {
        self.estimate_at(cell, 0)
    }

    /// Raw live estimate at a (batch class, kind); `None` until sampled
    /// there.
    pub fn observation_kind_at(
        &self,
        cell: Cell,
        class: usize,
        kind: TransformKind,
    ) -> Option<CellEstimate> {
        self.observation_kind_isa_at(cell, class, kind, self.exec_isa)
    }

    /// Raw live estimate at a (batch class, kind, isa); `None` until
    /// that exact backend has been sampled there.
    pub fn observation_kind_isa_at(
        &self,
        cell: Cell,
        class: usize,
        kind: TransformKind,
        isa: Isa,
    ) -> Option<CellEstimate> {
        self.obs.get(&(cell, class, self.kind_slot(kind), isa)).copied()
    }

    /// Raw forward live estimate at a batch class.
    pub fn observation_at(&self, cell: Cell, class: usize) -> Option<CellEstimate> {
        self.observation_kind_at(cell, class, TransformKind::Forward)
    }

    /// Raw unbatched forward live estimate.
    pub fn observation(&self, cell: Cell) -> Option<CellEstimate> {
        self.observation_at(cell, 0)
    }

    /// All (cell, batch class) pairs with live observations *at the
    /// focus kind's slot and the exec ISA*, sorted — the drift
    /// detector's view: detection measures movement of the workload the
    /// active plan serves, on the backend it actually dispatches.
    pub fn observed_cells(&self) -> Vec<((Cell, usize), CellEstimate)> {
        let slot = self.kind_slot(self.focus_kind);
        let isa = self.exec_isa;
        let mut v: Vec<((Cell, usize), CellEstimate)> = self
            .obs
            .iter()
            .filter(|((_, _, k, i), _)| *k == slot && *i == isa)
            .map(|((cell, class, _, _), v)| ((*cell, *class), *v))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Every prior cell with its prior value and per-(class, kind, isa)
    /// live estimates (sorted by class, kind index, isa index), sorted —
    /// the wisdom v2 export view.
    #[allow(clippy::type_complexity)]
    pub fn export_cells(
        &self,
    ) -> Vec<(Cell, f64, Vec<(usize, TransformKind, Isa, CellEstimate)>)> {
        let mut v: Vec<(Cell, f64, Vec<(usize, TransformKind, Isa, CellEstimate)>)> = self
            .prior
            .iter()
            .map(|(cell, &p)| {
                let mut per: Vec<(usize, TransformKind, Isa, CellEstimate)> = self
                    .obs
                    .iter()
                    .filter(|((c, _, _, _), _)| c == cell)
                    .map(|((_, class, kind, isa), e)| (*class, *kind, *isa, *e))
                    .collect();
                per.sort_by_key(|&(c, k, i, _)| (c, k.index(), i.index()));
                (*cell, p, per)
            })
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Total live samples folded in (all classes).
    pub fn total_samples(&self) -> u64 {
        self.obs.values().map(|e| e.count).sum()
    }
}

impl CostModel for OnlineCost {
    fn n(&self) -> usize {
        self.n
    }

    fn available_edges(&self) -> Vec<EdgeType> {
        self.edges.clone()
    }

    /// Per-transform cost at the focus batch class and focus kind — so
    /// the same search that plans for B=1 forward traffic plans for any
    /// (batch, kind) regime the service serves.
    fn edge_ns(&mut self, edge: EdgeType, stage: usize, ctx: Context) -> f64 {
        self.estimate_kind_at((edge, stage, ctx), self.focus, self.focus_kind)
    }

    fn edge_ns_kind(
        &mut self,
        edge: EdgeType,
        stage: usize,
        ctx: Context,
        kind: TransformKind,
    ) -> f64 {
        if edge == EdgeType::RU {
            return self.unpack_ns(ctx);
        }
        self.estimate_kind_at((edge, stage, ctx), self.focus, kind)
    }

    fn edge_ns_batched(&mut self, edge: EdgeType, stage: usize, ctx: Context, b: usize) -> f64 {
        b as f64 * self.estimate_kind_at((edge, stage, ctx), batch_class(b), self.focus_kind)
    }

    /// Whole-batch panel transpose estimate (one direction): the live
    /// per-transform EWMA at `b`'s batch class blended over the
    /// installed offline prior, scaled back to the whole batch. With
    /// neither, the trait's cold strided-R2 proxy answers.
    fn marshal_ns(&mut self, b: usize) -> f64 {
        let b = b.max(1);
        let class = batch_class(b);
        let prior = self.marshal_prior.get(&class).copied();
        let obs = self.marshal_obs.get(&class).copied();
        let per_tx = match (prior, obs) {
            (Some(p), Some(o)) => {
                let c = o.count as f64 / (o.count as f64 + self.blend_samples);
                p * (1.0 - c) + o.mean * c
            }
            (Some(p), None) => p,
            (None, Some(o)) => o.mean,
            (None, None) => {
                return b as f64 * self.edge_ns(EdgeType::R2, 0, Context::Start);
            }
        };
        b as f64 * per_tx
    }

    /// Whole-pass blocked-transpose estimate for a rows×cols matrix:
    /// live EWMA blended over the installed offline prior; with
    /// neither, the trait's cold strided-R2 proxy.
    fn transpose_ns(&mut self, rows: usize, cols: usize) -> f64 {
        let prior = self.transpose_prior.get(&(rows, cols)).copied();
        let obs = self.transpose_obs.get(&(rows, cols)).copied();
        match self.blend(prior, obs) {
            Some(ns) => ns,
            None => {
                let trips = (rows * cols) as f64 / self.n as f64;
                trips * self.edge_ns(EdgeType::R2, 0, Context::Start)
            }
        }
    }

    /// Whole-pass inter-block twiddle estimate, same blend discipline.
    fn block_twiddle_ns(&mut self, nn: usize) -> f64 {
        let prior = self.blocktw_prior.get(&nn).copied();
        let obs = self.blocktw_obs.get(&nn).copied();
        match self.blend(prior, obs) {
            Some(ns) => ns,
            None => {
                let trips = nn as f64 / self.n as f64;
                trips * self.edge_ns(EdgeType::R2, 0, Context::Start)
            }
        }
    }

    /// Surface queries answer from the per-(kind, cell, batch-class)
    /// store *directly* — no adapter stacking, no focus indirection: the
    /// re-planner names the regime it searches (the modal batch class,
    /// the tuned kind) in the [`PlanningSurface`] it passes down. The
    /// focus fields remain the view of the legacy [`CostModel::edge_ns`]
    /// path and of drift detection. The RU boundary edge answers from
    /// its *own* live observations when the real traced path has fed
    /// any (RU cells have no offline prior), falling back to the
    /// stage-0-R2 proxy — at the surface's own (class, kind), never the
    /// focus, so a boundary search stays class-consistent end to end.
    fn surface_edge_ns(
        &mut self,
        edge: EdgeType,
        stage: usize,
        ctx: Context,
        surface: PlanningSurface,
    ) -> f64 {
        let isa = surface.isa.unwrap_or(self.exec_isa);
        if edge == EdgeType::RU {
            // RU runs scalar permutation code in every backend, but its
            // samples are still keyed by the plan's dispatching ISA —
            // read the same slot the traced path writes.
            let cell = (EdgeType::RU, stage, ctx);
            if self
                .observation_kind_isa_at(cell, surface.batch_class, surface.kind, isa)
                .is_some()
            {
                return self.estimate_kind_isa_at(cell, surface.batch_class, surface.kind, isa);
            }
            return self.estimate_kind_isa_at(
                (EdgeType::R2, 0, ctx),
                surface.batch_class,
                surface.kind,
                isa,
            );
        }
        self.estimate_kind_isa_at((edge, stage, ctx), surface.batch_class, surface.kind, isa)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::SimCost;
    use crate::plan::Plan;
    use crate::planner::{plan as run_plan, Strategy};

    fn m1_model(n: usize) -> OnlineCost {
        let w = Wisdom::harvest(&mut SimCost::m1(n), "m1");
        OnlineCost::from_wisdom(&w, 0.5, 4.0)
    }

    fn sample(edge: EdgeType, stage: usize, ctx: Context, ns: f64) -> EdgeSample {
        EdgeSample { edge, stage, ctx, kind: TransformKind::Forward, batch: 1, isa: Isa::Scalar, span: SampleSpan::Edge, ns }
    }

    fn sample_b(edge: EdgeType, stage: usize, ctx: Context, batch: usize, ns: f64) -> EdgeSample {
        EdgeSample { edge, stage, ctx, kind: TransformKind::Forward, batch, isa: Isa::Scalar, span: SampleSpan::Edge, ns }
    }

    fn sample_k(edge: EdgeType, stage: usize, ctx: Context, kind: TransformKind, ns: f64) -> EdgeSample {
        EdgeSample { edge, stage, ctx, kind, batch: 1, isa: Isa::Scalar, span: SampleSpan::Edge, ns }
    }

    fn sample_i(edge: EdgeType, stage: usize, ctx: Context, isa: Isa, ns: f64) -> EdgeSample {
        EdgeSample { edge, stage, ctx, kind: TransformKind::Forward, batch: 1, isa, span: SampleSpan::Edge, ns }
    }

    #[test]
    fn batch_class_is_log2_and_saturates() {
        assert_eq!(batch_class(1), 0);
        assert_eq!(batch_class(2), 1);
        assert_eq!(batch_class(3), 2);
        assert_eq!(batch_class(4), 2);
        assert_eq!(batch_class(16), 4);
        assert_eq!(batch_class(64), 6);
        assert_eq!(batch_class(1 << 20), BATCH_CLASSES - 1);
        for c in 0..BATCH_CLASSES {
            assert_eq!(batch_class(class_batch(c)), c);
        }
    }

    #[test]
    fn unobserved_model_reproduces_the_prior_plan() {
        let mut model = m1_model(1024);
        let out = run_plan(&mut model, &Strategy::DijkstraContextAware { k: 1 });
        assert_eq!(out.plan, Plan::parse("R4,R2,R4,R4,F8").unwrap());
    }

    #[test]
    fn estimates_converge_to_observations() {
        let mut model = m1_model(1024);
        let cell = (EdgeType::F8, 7, Context::After(EdgeType::R4));
        let prior = model.estimate(cell);
        for _ in 0..200 {
            model.observe(&sample(cell.0, cell.1, cell.2, prior * 10.0));
        }
        let est = model.estimate(cell);
        assert!(est > prior * 9.0, "blended {est} vs prior {prior}");
        assert_eq!(model.observation(cell).unwrap().count, 200);
    }

    #[test]
    fn few_samples_stay_close_to_prior() {
        let mut model = m1_model(1024);
        let cell = (EdgeType::R4, 0, Context::Start);
        let prior = model.estimate(cell);
        model.observe(&sample(cell.0, cell.1, cell.2, prior * 100.0));
        // one sample against blend_samples = 4 → weight 0.2
        let est = model.estimate(cell);
        assert!(est < prior * 25.0, "single outlier dominated: {est}");
        assert!(est > prior, "observation ignored entirely");
    }

    #[test]
    fn garbage_samples_are_discarded() {
        let mut model = m1_model(256);
        let cell = (EdgeType::R2, 0, Context::Start);
        let prior = model.estimate(cell);
        model.observe(&sample(cell.0, cell.1, cell.2, f64::NAN));
        model.observe(&sample(cell.0, cell.1, cell.2, -1.0));
        model.observe(&sample(cell.0, cell.1, cell.2, 0.0));
        model.observe(&sample_b(cell.0, cell.1, cell.2, 0, 5.0));
        assert_eq!(model.observation(cell), None);
        assert_eq!(model.estimate(cell), prior);
    }

    #[test]
    fn batched_samples_land_in_their_class_normalized_per_transform() {
        let mut model = m1_model(256);
        let cell = (EdgeType::R2, 0, Context::Start);
        let prior = model.estimate(cell);
        // a batch of 16 took 16 * prior / 2 ns: per-transform cost halved
        for _ in 0..100 {
            model.observe(&sample_b(cell.0, cell.1, cell.2, 16, 16.0 * prior / 2.0));
        }
        // class 0 untouched; class 4 learned the amortized cost
        assert_eq!(model.observation(cell), None);
        assert_eq!(model.estimate(cell), prior);
        let est16 = model.estimate_at(cell, batch_class(16));
        assert!(
            (est16 - prior / 2.0).abs() / prior < 0.05,
            "batched estimate {est16} vs expected {}",
            prior / 2.0
        );
    }

    #[test]
    fn focus_class_steers_planning_queries() {
        let mut model = m1_model(256);
        let cell = (EdgeType::R2, 0, Context::Start);
        let prior = model.estimate(cell);
        for _ in 0..100 {
            model.observe(&sample_b(cell.0, cell.1, cell.2, 16, 16.0 * prior * 3.0));
        }
        assert_eq!(model.edge_ns(cell.0, cell.1, cell.2), prior);
        model.set_focus_class(batch_class(16));
        let focused = model.edge_ns(cell.0, cell.1, cell.2);
        assert!(focused > prior * 2.0, "focus ignored: {focused} vs prior {prior}");
        // whole-batch query at B=16 = 16 x the focused per-transform cost
        let whole = model.edge_ns_batched(cell.0, cell.1, cell.2, 16);
        assert!((whole - 16.0 * focused).abs() < 1e-9);
    }

    #[test]
    fn class_priors_answer_unobserved_batched_queries() {
        let w = Wisdom::harvest(&mut SimCost::m1(256), "m1");
        let w16 = Wisdom::harvest_batched(&mut SimCost::m1(256), "m1", 16);
        let mut model = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        let cell = (w.cells[0].0, w.cells[0].1, w.cells[0].2);
        let base = model.estimate(cell);
        // without a class prior, class 4 falls back to the unbatched prior
        assert_eq!(model.estimate_at(cell, batch_class(16)), base);
        model.set_batched_prior(16, &w16);
        let amortized = w16.cells[0].3;
        assert_eq!(model.estimate_at(cell, batch_class(16)), amortized);
        // class 0 and other classes are untouched
        assert_eq!(model.estimate(cell), base);
        assert_eq!(model.estimate_at(cell, batch_class(2)), base);
        // live samples still blend over the class prior
        for _ in 0..100 {
            model.observe(&sample_b(cell.0, cell.1, cell.2, 16, 16.0 * amortized * 2.0));
        }
        let est = model.estimate_at(cell, batch_class(16));
        assert!(est > amortized * 1.8, "class prior ignored the samples: {est}");
    }

    #[test]
    fn batched_priors_steer_the_search_at_a_batched_surface() {
        // With the amortized B=16 surface installed as a class prior and
        // the search pointed at that class through its PlanningSurface,
        // the same context-aware search legitimately picks a different
        // arrangement than the unbatched prior — with zero live samples.
        // This is the offline half of "the planner sees the batch axis".
        use crate::cost::PlanningSurface;
        use crate::planner::plan_surface;
        let w = Wisdom::harvest(&mut SimCost::m1(1024), "m1");
        let w16 = Wisdom::harvest_batched(&mut SimCost::m1(1024), "m1", 16);
        let mut model = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        model.set_batched_prior(16, &w16);
        let p0 = run_plan(&mut model, &Strategy::DijkstraContextAware { k: 1 }).plan;
        assert_eq!(p0, Plan::parse("R4,R2,R4,R4,F8").unwrap());
        let ca = Strategy::DijkstraContextAware { k: 1 };
        let p16 =
            plan_surface(&mut model, &ca, PlanningSurface::forward().with_batch(16)).plan;
        assert_ne!(p16, p0, "batched surface reproduced the unbatched plan");
        // the legacy edge_ns path still answers at the focus class
        let cell = w.cells[0];
        model.set_focus_class(batch_class(16));
        assert_eq!(
            model.edge_ns(cell.0, cell.1, cell.2),
            model.estimate_at((cell.0, cell.1, cell.2), batch_class(16))
        );
    }

    #[test]
    fn invalid_class_priors_are_rejected() {
        let mut model = m1_model(256);
        let cell = (EdgeType::R2, 0, Context::Start);
        let base = model.estimate(cell);
        model.set_class_prior(cell, 0, 123.0); // class 0 is the v1 prior's own regime
        model.set_class_prior(cell, 3, f64::NAN);
        model.set_class_prior(cell, 3, -1.0);
        model.set_class_prior(cell, BATCH_CLASSES, 55.0);
        assert_eq!(model.estimate(cell), base);
        assert_eq!(model.estimate_at(cell, 3), base);
    }

    #[test]
    fn export_covers_every_prior_cell() {
        let model = m1_model(1024);
        // 37 positional (edge, stage) pairs x 8 contexts (wisdom tests)
        assert_eq!(model.export_cells().len(), 37 * 8);
        assert_eq!(model.total_samples(), 0);
    }

    #[test]
    fn legacy_priors_answer_boundary_context_via_the_r2_proxy() {
        // A prior harvested before the boundary context became a stored
        // cell (7-context files) must still answer After(RU) queries —
        // via the historical after-R2 proxy cell, not a panic.
        let w = Wisdom::harvest(&mut SimCost::m1(256), "m1");
        let legacy = Wisdom {
            n: w.n,
            source: w.source.clone(),
            cells: w
                .cells
                .iter()
                .filter(|c| c.2 != Context::After(EdgeType::RU))
                .cloned()
                .collect(),
        };
        let model = OnlineCost::from_wisdom(&legacy, 0.5, 4.0);
        let cell = (EdgeType::R4, 0, Context::After(EdgeType::RU));
        let proxy = (EdgeType::R4, 0, Context::After(EdgeType::R2));
        assert_eq!(model.prior_at(cell, 0), model.prior_at(proxy, 0));
        assert!(model.estimate(cell).is_finite());
        // a full (8-context) prior answers the boundary cell natively
        let full = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        let native = SimCost::m1(256).edge_ns(EdgeType::R4, 0, Context::After(EdgeType::RU));
        assert_eq!(full.prior_at(cell, 0), Some(native));
    }

    #[test]
    fn inverse_samples_fold_onto_forward_cells_by_default() {
        // Inverse c2c passes run the identical forward kernels, so
        // without the calibration split their samples sharpen the same
        // cells forward planning reads.
        let mut model = m1_model(256);
        let cell = (EdgeType::R4, 0, Context::Start);
        let prior = model.estimate(cell);
        for _ in 0..100 {
            model.observe(&sample_k(cell.0, cell.1, cell.2, TransformKind::Inverse, prior * 3.0));
        }
        let fwd = model.observation(cell).expect("folded onto forward");
        assert_eq!(fwd.count, 100);
        assert!(model.estimate(cell) > prior * 2.0);
        // the kind-aware read sees the same slot
        assert_eq!(
            model.observation_kind_at(cell, 0, TransformKind::Inverse),
            model.observation(cell)
        );
    }

    #[test]
    fn calibration_split_keeps_kinds_apart() {
        let mut model = m1_model(256);
        model.set_split_kinds(true);
        assert!(model.split_kinds());
        let cell = (EdgeType::R4, 0, Context::Start);
        let prior = model.estimate(cell);
        for _ in 0..100 {
            model.observe(&sample_k(cell.0, cell.1, cell.2, TransformKind::Inverse, prior * 3.0));
        }
        // forward untouched; the inverse slot learned the asymmetry
        assert_eq!(model.observation(cell), None);
        assert_eq!(model.estimate(cell), prior);
        let inv = model.observation_kind_at(cell, 0, TransformKind::Inverse).unwrap();
        assert_eq!(inv.count, 100);
        let est = model.estimate_kind_at(cell, 0, TransformKind::Inverse);
        assert!(est > prior * 2.0, "split estimate ignored samples: {est}");
        // planning at the inverse focus kind consumes the split surface
        model.set_focus_kind(TransformKind::Inverse);
        assert_eq!(model.focus_kind(), TransformKind::Inverse);
        assert!(model.edge_ns(cell.0, cell.1, cell.2) > prior * 2.0);
        // drift's view follows the focus kind
        assert_eq!(model.observed_cells().len(), 1);
        model.set_focus_kind(TransformKind::Forward);
        assert!(model.observed_cells().is_empty());
    }

    #[test]
    fn surface_ru_query_prefers_live_ru_observations_over_the_proxy() {
        use crate::cost::PlanningSurface;
        let mut model = m1_model(256);
        let surface = PlanningSurface::for_kind(TransformKind::RealForward);
        let ctx = Context::After(EdgeType::F8);
        // without RU samples: the stage-0-R2 proxy at the surface's class/kind
        let proxy = model.estimate_kind_at((EdgeType::R2, 0, ctx), 0, surface.kind);
        assert_eq!(model.surface_edge_ns(EdgeType::RU, 8, ctx, surface), proxy);
        // real traced RU samples take over once folded in
        for _ in 0..50 {
            model.observe(&sample_k(EdgeType::RU, 8, ctx, TransformKind::RealForward, 42.0));
        }
        let est = model.surface_edge_ns(EdgeType::RU, 8, ctx, surface);
        assert!((est - 42.0).abs() < 1e-9, "live RU observation ignored: {est}");
        // ...and stays class-consistent: an unobserved batched class
        // falls back to the proxy at that class
        let b16 = surface.with_batch(16);
        assert_eq!(
            model.surface_edge_ns(EdgeType::RU, 8, ctx, b16),
            model.estimate_kind_at((EdgeType::R2, 0, ctx), b16.batch_class, b16.kind)
        );
    }

    #[test]
    fn isa_axis_keeps_backends_apart() {
        let mut model = m1_model(256);
        let cell = (EdgeType::R4, 0, Context::Start);
        let prior = model.estimate(cell);
        for _ in 0..100 {
            model.observe(&sample_i(cell.0, cell.1, cell.2, Isa::Neon, prior * 3.0));
        }
        // the scalar (default exec) slot is untouched...
        assert_eq!(model.observation(cell), None);
        assert_eq!(model.estimate(cell), prior);
        // ...while the NEON slot learned the backend's cost
        let neon = model
            .observation_kind_isa_at(cell, 0, TransformKind::Forward, Isa::Neon)
            .unwrap();
        assert_eq!(neon.count, 100);
        // a surface pinned to NEON reads that slot
        let pinned = PlanningSurface::forward().with_isa(Isa::Neon);
        let est = model.surface_edge_ns(cell.0, cell.1, cell.2, pinned);
        assert!(est > prior * 2.0, "pinned surface ignored NEON samples: {est}");
        // an unpinned surface resolves to the exec ISA (scalar → prior)...
        assert_eq!(
            model.surface_edge_ns(cell.0, cell.1, cell.2, PlanningSurface::forward()),
            prior
        );
        // ...until the coordinator stamps the dispatched backend
        model.set_exec_isa(Isa::Neon);
        assert_eq!(model.exec_isa(), Isa::Neon);
        let resolved = model.surface_edge_ns(cell.0, cell.1, cell.2, PlanningSurface::forward());
        assert!(resolved > prior * 2.0, "unpinned surface ignored exec isa: {resolved}");
        // drift's view follows the exec ISA
        assert_eq!(model.observed_cells().len(), 1);
        model.set_exec_isa(Isa::Scalar);
        assert!(model.observed_cells().is_empty());
        // the export view carries the backend verbatim
        let exported = model.export_cells();
        let (_, _, per) = exported.iter().find(|(c, _, _)| *c == cell).unwrap();
        assert_eq!(per.len(), 1);
        assert_eq!(per[0].2, Isa::Neon);
    }

    #[test]
    fn marshal_samples_feed_the_transpose_store_not_the_cells() {
        let mut model = m1_model(256);
        let proxy = 16.0 * model.edge_ns(EdgeType::R2, 0, Context::Start);
        // no prior, no samples: the trait's cold strided-R2 proxy
        assert!((model.marshal_ns(16) - proxy).abs() < 1e-9);
        // marshal samples land in the transpose store, not any edge cell
        for _ in 0..200 {
            model.observe(&EdgeSample::marshal(TransformKind::Forward, 16, Isa::Scalar, 3200.0));
        }
        assert_eq!(model.total_samples(), 0, "marshal leaked into edge cells");
        let est = model.marshal_observation_at(batch_class(16)).unwrap();
        assert_eq!(est.count, 200);
        // whole-batch read: 16 x the 200 ns/tx the samples converged to
        assert!((model.marshal_ns(16) - 3200.0).abs() < 1.0);
        // other classes still answer from the proxy
        let proxy2 = 2.0 * model.edge_ns(EdgeType::R2, 0, Context::Start);
        assert!((model.marshal_ns(2) - proxy2).abs() < 1e-9);
    }

    #[test]
    fn marshal_priors_seed_unobserved_classes_and_blend_with_samples() {
        let mut model = m1_model(256);
        model.set_marshal_prior(batch_class(16), 50.0); // per transform
        assert!((model.marshal_ns(16) - 16.0 * 50.0).abs() < 1e-9);
        // live samples blend over (and eventually dominate) the prior
        for _ in 0..200 {
            model.observe(&EdgeSample::marshal(TransformKind::Forward, 16, Isa::Scalar, 16.0 * 150.0));
        }
        let est = model.marshal_ns(16);
        assert!(est > 16.0 * 140.0, "prior dominated 200 samples: {est}");
        // garbage marshal samples are discarded like garbage edge samples
        model.observe(&EdgeSample::marshal(TransformKind::Forward, 0, Isa::Scalar, 5.0));
        model.observe(&EdgeSample::marshal(TransformKind::Forward, 16, Isa::Scalar, f64::NAN));
        model.observe(&EdgeSample::marshal(TransformKind::Forward, 16, Isa::Scalar, -4.0));
        assert_eq!(model.marshal_observation_at(batch_class(16)).unwrap().count, 200);
        // invalid priors are rejected
        model.set_marshal_prior(BATCH_CLASSES, 10.0);
        model.set_marshal_prior(2, f64::NAN);
        assert_eq!(model.marshal_observation_at(2), None);
    }

    #[test]
    fn blocked_boundary_stores_blend_and_generic_samples_are_rejected() {
        let mut model = m1_model(1 << 12);
        // no prior, no samples: the cold strided-R2 proxy, scaled by trips
        let one_pass = model.edge_ns(EdgeType::R2, 0, Context::Start);
        assert!((model.transpose_ns(64, 64) - one_pass).abs() < 1e-9);
        assert!((model.block_twiddle_ns(1 << 12) - one_pass).abs() < 1e-9);
        // priors answer unobserved shapes
        model.set_transpose_prior(64, 64, 500.0);
        model.set_block_twiddle_prior(1 << 12, 900.0);
        assert_eq!(model.transpose_ns(64, 64), 500.0);
        assert_eq!(model.block_twiddle_ns(1 << 12), 900.0);
        // other shapes still proxy
        assert!((model.transpose_ns(32, 128) - one_pass).abs() < 1e-9);
        // live samples blend over and eventually dominate the prior
        for _ in 0..200 {
            model.observe_transpose(64, 64, 1500.0);
            model.observe_block_twiddle(1 << 12, 2700.0);
        }
        assert!(model.transpose_ns(64, 64) > 1400.0);
        assert!(model.block_twiddle_ns(1 << 12) > 2500.0);
        assert_eq!(model.transpose_observation(64, 64).unwrap().count, 200);
        assert_eq!(model.block_twiddle_observation(1 << 12).unwrap().count, 200);
        // garbage is discarded
        model.observe_transpose(64, 64, f64::NAN);
        model.observe_block_twiddle(1 << 12, -3.0);
        assert_eq!(model.transpose_observation(64, 64).unwrap().count, 200);
        // a shapeless TR/BT edge-span sample never pollutes edge cells
        model.observe(&sample(EdgeType::Transpose, 0, Context::Start, 100.0));
        model.observe(&sample(EdgeType::BlockTwiddle, 0, Context::Start, 100.0));
        assert_eq!(model.total_samples(), 0);
    }

    #[test]
    fn export_carries_the_kind_axis() {
        let mut model = m1_model(256);
        model.set_split_kinds(true);
        let cell = (EdgeType::R2, 0, Context::Start);
        let prior = model.estimate(cell);
        model.observe(&sample_k(cell.0, cell.1, cell.2, TransformKind::Forward, prior));
        model.observe(&sample_k(cell.0, cell.1, cell.2, TransformKind::Inverse, prior * 2.0));
        let exported = model.export_cells();
        let (_, _, per) = exported.iter().find(|(c, _, _)| *c == cell).unwrap();
        assert_eq!(per.len(), 2);
        assert_eq!((per[0].0, per[0].1), (0, TransformKind::Forward));
        assert_eq!((per[1].0, per[1].1), (0, TransformKind::Inverse));
    }
}
