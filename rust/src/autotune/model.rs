//! The online cost model: live EWMA estimates blended over a wisdom prior.
//!
//! Every sampled edge execution updates an exponentially-weighted running
//! mean for its (edge, stage, context) cell. Planning queries return a
//! confidence-weighted blend of the live estimate and the offline prior:
//! a cell with `s` samples trusts the live mean with weight
//! `s / (s + blend_samples)`. Cells the active plan never executes keep
//! their prior — which is exactly what makes online re-planning sound:
//! the search compares freshly-observed cells of the running plan against
//! prior-valued alternatives, the same tradeoff FFTW's wisdom makes
//! offline, now maintained continuously.

use std::collections::HashMap;

use crate::cost::{CostModel, Wisdom};
use crate::edge::{Context, EdgeType};

use super::sampler::EdgeSample;

/// A cell key: (edge, stage, predecessor context).
pub type Cell = (EdgeType, usize, Context);

/// Live estimate for one cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellEstimate {
    /// EWMA of observed nanoseconds.
    pub mean: f64,
    /// Samples folded into the mean.
    pub count: u64,
}

/// [`CostModel`] over prior + live observations.
pub struct OnlineCost {
    n: usize,
    edges: Vec<EdgeType>,
    alpha: f64,
    blend_samples: f64,
    prior: HashMap<Cell, f64>,
    obs: HashMap<Cell, CellEstimate>,
}

impl OnlineCost {
    /// Build from an offline wisdom database (the prior).
    pub fn from_wisdom(prior: &Wisdom, alpha: f64, blend_samples: f64) -> OnlineCost {
        assert!(alpha > 0.0 && alpha <= 1.0, "ewma alpha must be in (0, 1]");
        assert!(blend_samples >= 0.0, "blend_samples must be >= 0");
        let mut edges: Vec<EdgeType> = prior.cells.iter().map(|c| c.0).collect();
        edges.sort();
        edges.dedup();
        OnlineCost {
            n: prior.n,
            edges,
            alpha,
            blend_samples,
            prior: prior.cells.iter().map(|&(e, s, ctx, ns)| ((e, s, ctx), ns)).collect(),
            obs: HashMap::new(),
        }
    }

    /// Fold one live sample into its cell. Non-finite or non-positive
    /// values (timer glitches) are discarded.
    pub fn observe(&mut self, sample: &EdgeSample) {
        if !sample.ns.is_finite() || sample.ns <= 0.0 {
            return;
        }
        let key = (sample.edge, sample.stage, sample.ctx);
        match self.obs.get_mut(&key) {
            Some(est) => {
                est.mean = self.alpha * sample.ns + (1.0 - self.alpha) * est.mean;
                est.count += 1;
            }
            None => {
                self.obs.insert(key, CellEstimate { mean: sample.ns, count: 1 });
            }
        }
    }

    /// Seed a cell's live estimate directly (wisdom v2 restore).
    pub fn seed(&mut self, cell: Cell, mean: f64, count: u64) {
        if mean.is_finite() && mean > 0.0 && count > 0 {
            self.obs.insert(cell, CellEstimate { mean, count });
        }
    }

    /// The blended estimate a planning query returns for `cell`.
    pub fn estimate(&self, cell: Cell) -> f64 {
        let prior = self.prior.get(&cell).copied();
        let obs = self.obs.get(&cell).copied();
        match (prior, obs) {
            (Some(p), Some(o)) => {
                let c = o.count as f64 / (o.count as f64 + self.blend_samples);
                p * (1.0 - c) + o.mean * c
            }
            (Some(p), None) => p,
            (None, Some(o)) => o.mean,
            (None, None) => panic!(
                "online cost: no prior or observation for {}@{} {}",
                cell.0, cell.1, cell.2
            ),
        }
    }

    /// Raw live estimate (undamped by the prior); `None` until sampled.
    pub fn observation(&self, cell: Cell) -> Option<CellEstimate> {
        self.obs.get(&cell).copied()
    }

    /// All cells with live observations.
    pub fn observed_cells(&self) -> Vec<(Cell, CellEstimate)> {
        let mut v: Vec<(Cell, CellEstimate)> =
            self.obs.iter().map(|(k, v)| (*k, *v)).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Every prior cell with its prior value and live estimate, sorted
    /// (the wisdom v2 export view).
    pub fn export_cells(&self) -> Vec<(Cell, f64, Option<CellEstimate>)> {
        let mut v: Vec<(Cell, f64, Option<CellEstimate>)> = self
            .prior
            .iter()
            .map(|(k, &p)| (*k, p, self.obs.get(k).copied()))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Total live samples folded in.
    pub fn total_samples(&self) -> u64 {
        self.obs.values().map(|e| e.count).sum()
    }
}

impl CostModel for OnlineCost {
    fn n(&self) -> usize {
        self.n
    }

    fn available_edges(&self) -> Vec<EdgeType> {
        self.edges.clone()
    }

    fn edge_ns(&mut self, edge: EdgeType, stage: usize, ctx: Context) -> f64 {
        self.estimate((edge, stage, ctx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::SimCost;
    use crate::plan::Plan;
    use crate::planner::{plan as run_plan, Strategy};

    fn m1_model(n: usize) -> OnlineCost {
        let w = Wisdom::harvest(&mut SimCost::m1(n), "m1");
        OnlineCost::from_wisdom(&w, 0.5, 4.0)
    }

    fn sample(edge: EdgeType, stage: usize, ctx: Context, ns: f64) -> EdgeSample {
        EdgeSample { edge, stage, ctx, ns }
    }

    #[test]
    fn unobserved_model_reproduces_the_prior_plan() {
        let mut model = m1_model(1024);
        let out = run_plan(&mut model, &Strategy::DijkstraContextAware { k: 1 });
        assert_eq!(out.plan, Plan::parse("R4,R2,R4,R4,F8").unwrap());
    }

    #[test]
    fn estimates_converge_to_observations() {
        let mut model = m1_model(1024);
        let cell = (EdgeType::F8, 7, Context::After(EdgeType::R4));
        let prior = model.estimate(cell);
        for _ in 0..200 {
            model.observe(&sample(cell.0, cell.1, cell.2, prior * 10.0));
        }
        let est = model.estimate(cell);
        assert!(est > prior * 9.0, "blended {est} vs prior {prior}");
        assert_eq!(model.observation(cell).unwrap().count, 200);
    }

    #[test]
    fn few_samples_stay_close_to_prior() {
        let mut model = m1_model(1024);
        let cell = (EdgeType::R4, 0, Context::Start);
        let prior = model.estimate(cell);
        model.observe(&sample(cell.0, cell.1, cell.2, prior * 100.0));
        // one sample against blend_samples = 4 → weight 0.2
        let est = model.estimate(cell);
        assert!(est < prior * 25.0, "single outlier dominated: {est}");
        assert!(est > prior, "observation ignored entirely");
    }

    #[test]
    fn garbage_samples_are_discarded() {
        let mut model = m1_model(256);
        let cell = (EdgeType::R2, 0, Context::Start);
        let prior = model.estimate(cell);
        model.observe(&sample(cell.0, cell.1, cell.2, f64::NAN));
        model.observe(&sample(cell.0, cell.1, cell.2, -1.0));
        model.observe(&sample(cell.0, cell.1, cell.2, 0.0));
        assert_eq!(model.observation(cell), None);
        assert_eq!(model.estimate(cell), prior);
    }

    #[test]
    fn export_covers_every_prior_cell() {
        let model = m1_model(1024);
        // 37 positional (edge, stage) pairs x 7 contexts (wisdom tests)
        assert_eq!(model.export_cells().len(), 37 * 7);
        assert_eq!(model.total_samples(), 0);
    }
}
