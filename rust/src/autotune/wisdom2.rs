//! Wisdom v2: persist *learned* contextual weights across restarts.
//!
//! Wisdom v1 (`cost::wisdom`) stores one measured value per cell. The
//! autotuner knows more: the offline prior **and** the live EWMA with its
//! sample count. Wisdom v2 stores all three per cell so a restarted
//! service resumes with its learned confidence instead of re-learning
//! from scratch:
//!
//! ```json
//! {"format": "spfft-wisdom-v2", "n": 1024, "source": "sim:m1",
//!  "cells": [{"edge": "F8", "stage": 7, "ctx": 2, "kind": "forward",
//!             "batch": 1, "prior_ns": 458.0, "obs_ns": 4580.0, "count": 137},
//!            {"edge": "F8", "stage": 7, "ctx": 2, "kind": "inverse",
//!             "batch": 16, "prior_ns": 458.0, "obs_ns": 1100.0, "count": 64}, ...]}
//! ```
//!
//! `ctx` is [`Context::index`] (0 = start, 1.. = edge index + 1); cells
//! with `count == 0` carry no live estimate (`obs_ns` is ignored).
//! `batch` is the representative batch size of the observation's batch
//! class ([`crate::autotune::model::batch_class`]); `obs_ns` is the
//! per-transform EWMA learned at that class, and a batched record's
//! `prior_ns` is the *class's own* offline prior — the amortized
//! per-transform surface. `bin/calibrate --prior-out` writes pure
//! batched priors this way (`count == 0`, via
//! [`WisdomV2::from_batched_priors`]), which seed [`OnlineCost`] class
//! priors on load. Every prior cell appears exactly once with
//! `batch == 1`; batched priors and observations add further records
//! for the same (edge, stage, ctx). `kind` is the transform kind the
//! observation was traced under (non-forward observations exist only
//! when the calibration split is on — folded samples persist as
//! forward). `isa` is the codelet backend the observation was traced
//! under ([`crate::isa::Isa::name`]); observations from different
//! backends never fold, so each keeps its own record. Records without a
//! `batch` field (files written before the batched execution engine)
//! default to 1, records without a `kind` field (files written before
//! the kind axis) load as **forward-only**, records without an `isa`
//! field (files written before the SIMD codelet backends) load as
//! **scalar** — the backend every pre-SIMD build dispatched — and
//! [`WisdomV2::load`] also accepts v1 files, promoting each v1
//! cell to a prior with zero live samples — upgrades are transparent.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::cost::{CostModel, Wisdom};
use crate::edge::{Context, EdgeType};
use crate::isa::Isa;
use crate::kind::TransformKind;
use crate::util::json::{self, Json};

use super::model::OnlineCost;

/// One persisted cell: prior plus live estimate at one batch class and
/// transform kind.
#[derive(Debug, Clone, PartialEq)]
pub struct CellRecord {
    pub edge: EdgeType,
    pub stage: usize,
    pub ctx: Context,
    /// Transform kind the observation was traced under. Files written
    /// before the kind axis carry no `"kind"` field and load as
    /// forward-only (mirroring the `"batch"` migration).
    pub kind: TransformKind,
    /// Representative batch size of the observation's batch class
    /// (1 = unbatched; the prior's own regime).
    pub batch: usize,
    /// Codelet backend the observation was traced under. Files written
    /// before the SIMD backends carry no `"isa"` field and load as
    /// scalar (mirroring the `"kind"` migration).
    pub isa: Isa,
    /// Offline prior (per-transform ns, batch-agnostic).
    pub prior_ns: f64,
    /// Live per-transform EWMA (ns); meaningful only when `count > 0`.
    pub obs_ns: f64,
    /// Live samples folded into `obs_ns`.
    pub count: u64,
}

/// A persisted learned-weight database.
#[derive(Debug, Clone, PartialEq)]
pub struct WisdomV2 {
    pub n: usize,
    pub source: String,
    pub cells: Vec<CellRecord>,
}

impl WisdomV2 {
    /// Snapshot an online model (prior + per-batch-class observations)
    /// for persistence. Every prior cell yields one `batch == 1` record
    /// (carrying the class-0 observation when present); each *installed
    /// batched class prior* adds a pure-prior record (`count == 0`, even
    /// with no traffic at that class — an operator's calibrated surface
    /// must survive the shutdown save); each observed batched class adds
    /// an observation record. A class with both gets both records, so
    /// save → load is lossless.
    pub fn from_model(model: &OnlineCost, source: &str) -> WisdomV2 {
        let mut cells = Vec::new();
        let exec_isa = model.exec_isa();
        for ((edge, stage, ctx), prior_ns, per) in model.export_cells() {
            let cell = (edge, stage, ctx);
            let canonical = per
                .iter()
                .find(|&&(c, k, i, _)| c == 0 && k == TransformKind::Forward && i == exec_isa)
                .map(|&(_, _, _, e)| e);
            cells.push(CellRecord {
                edge,
                stage,
                ctx,
                kind: TransformKind::Forward,
                batch: 1,
                isa: exec_isa,
                prior_ns,
                obs_ns: canonical.map(|o| o.mean).unwrap_or(0.0),
                count: canonical.map(|o| o.count).unwrap_or(0),
            });
            for class in model.prior_classes(cell) {
                cells.push(CellRecord {
                    edge,
                    stage,
                    ctx,
                    kind: TransformKind::Forward,
                    batch: crate::autotune::model::class_batch(class),
                    // pure priors are ISA-less surfaces; stamp the exec
                    // backend so a reload of this exact model is lossless
                    isa: exec_isa,
                    prior_ns: model.prior_at(cell, class).unwrap_or(prior_ns),
                    obs_ns: 0.0,
                    count: 0,
                });
            }
            for (class, kind, isa, est) in per
                .into_iter()
                .filter(|&(c, k, i, _)| !(c == 0 && k == TransformKind::Forward && i == exec_isa))
            {
                cells.push(CellRecord {
                    edge,
                    stage,
                    ctx,
                    kind,
                    batch: crate::autotune::model::class_batch(class),
                    isa,
                    // the class's own (possibly batched) prior, so the
                    // record blends the same way after a reload
                    prior_ns: model.prior_at(cell, class).unwrap_or(prior_ns),
                    obs_ns: est.mean,
                    count: est.count,
                });
            }
        }
        WisdomV2 { n: model.n(), source: source.to_string(), cells }
    }

    /// Build a batched-prior database: the unbatched prior plus, for
    /// each `(b, wisdom)` pair, one zero-count record per cell carrying
    /// the per-transform prior harvested over batches of `b` (the
    /// `bin/calibrate --prior-out` path over `Wisdom::harvest_batched`).
    /// Loading such a file seeds [`OnlineCost`] *class priors*: planning
    /// at a batched regime starts from the amortized surface instead of
    /// the unbatched prior, with no fake live confidence attached.
    /// Batch sizes are canonicalized to their class representative, and
    /// every batched database must be for the same FFT size.
    pub fn from_batched_priors(prior: &Wisdom, batched: &[(usize, Wisdom)]) -> Result<WisdomV2> {
        let mut out = WisdomV2::from_v1(prior);
        let mut seen_classes = std::collections::HashSet::new();
        for (b, w) in batched {
            if w.n != prior.n {
                bail!("batched prior for n={} does not match base prior n={}", w.n, prior.n);
            }
            if *b < 2 {
                bail!("batched prior batch must be >= 2, got {b}");
            }
            let batch = crate::autotune::model::class_batch(crate::autotune::model::batch_class(*b));
            if !seen_classes.insert(batch) {
                // e.g. b=3 and b=4 both canonicalize to class 2: the
                // loader would install whichever came last, silently
                bail!("batched priors for b={b} collide on batch class {batch}");
            }
            out.cells.extend(w.cells.iter().map(|&(edge, stage, ctx, ns)| CellRecord {
                edge,
                stage,
                ctx,
                kind: TransformKind::Forward,
                batch,
                isa: Isa::Scalar,
                prior_ns: ns,
                obs_ns: 0.0,
                count: 0,
            }));
        }
        Ok(out)
    }

    /// Promote a v1 database: priors only, no live samples.
    pub fn from_v1(w: &Wisdom) -> WisdomV2 {
        WisdomV2 {
            n: w.n,
            source: w.source.clone(),
            cells: w
                .cells
                .iter()
                .map(|&(edge, stage, ctx, ns)| CellRecord {
                    edge,
                    stage,
                    ctx,
                    kind: TransformKind::Forward,
                    batch: 1,
                    isa: Isa::Scalar,
                    prior_ns: ns,
                    obs_ns: 0.0,
                    count: 0,
                })
                .collect(),
        }
    }

    /// Restore live estimates into a freshly-built model, each at its
    /// record's batch class, and install *pure-prior* batched records
    /// (`count == 0`, the calibrate / shutdown-save format) as per-class
    /// priors. Observation-carrying batched records deliberately do NOT
    /// install their `prior_ns` as a class prior: files written before
    /// the batched-prior format carry the class-0 prior there, and
    /// letting them overwrite a freshly-harvested amortized surface
    /// (installed from `AutotuneConfig::batched_priors` before seeding)
    /// would regress planning to the unbatched prior. Callers must gate
    /// on compatibility first (same `n` *and* same cost `source` — see
    /// `Autotuner::start`), since estimates only mean anything against
    /// the prior they were learned over.
    pub fn seed_model(&self, model: &mut OnlineCost) {
        for c in &self.cells {
            let class = crate::autotune::model::batch_class(c.batch);
            if c.batch > 1 && c.count == 0 && c.kind == TransformKind::Forward {
                model.set_class_prior((c.edge, c.stage, c.ctx), class, c.prior_ns);
            }
            // Non-forward observation records exist only in files written
            // under the calibration split. Loading one into a *folded*
            // model would route it through `kind_slot` onto the forward
            // slot — and, records being written forward-first, silently
            // clobber the forward estimate with the inverse one. Folded
            // models therefore restore forward records only; the split
            // observations wait for a `--split-kinds` restart.
            if c.count > 0 && (model.split_kinds() || c.kind == TransformKind::Forward) {
                model.seed_kind_isa_at(
                    (c.edge, c.stage, c.ctx),
                    class,
                    c.kind,
                    c.isa,
                    c.obs_ns,
                    c.count,
                );
            }
        }
    }

    /// Collapse to a v1 database of the *blended* unbatched weights
    /// (what a B=1 planning query would consume right now) — for offline
    /// tooling that only speaks v1. Batched records (`batch > 1`) are
    /// skipped; v1 has no batch axis.
    pub fn to_blended_v1(&self, blend_samples: f64) -> Wisdom {
        Wisdom {
            n: self.n,
            source: format!("{}+online", self.source),
            cells: self
                .cells
                .iter()
                .filter(|c| c.batch <= 1)
                .map(|c| {
                    let ns = if c.count == 0 {
                        c.prior_ns
                    } else {
                        let w = c.count as f64 / (c.count as f64 + blend_samples);
                        c.prior_ns * (1.0 - w) + c.obs_ns * w
                    };
                    (c.edge, c.stage, c.ctx, ns)
                })
                .collect(),
        }
    }

    /// Serialize to the wisdom v2 JSON format.
    pub fn to_json(&self) -> String {
        let mut root = BTreeMap::new();
        root.insert("format".to_string(), Json::Str("spfft-wisdom-v2".into()));
        root.insert("n".to_string(), Json::Num(self.n as f64));
        root.insert("source".to_string(), Json::Str(self.source.clone()));
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                let mut o = BTreeMap::new();
                o.insert("edge".into(), Json::Str(c.edge.name().into()));
                o.insert("stage".into(), Json::Num(c.stage as f64));
                o.insert("ctx".into(), Json::Num(c.ctx.index() as f64));
                o.insert("kind".into(), Json::Str(c.kind.name().into()));
                o.insert("batch".into(), Json::Num(c.batch as f64));
                o.insert("isa".into(), Json::Str(c.isa.name().into()));
                o.insert("prior_ns".into(), Json::Num(c.prior_ns));
                o.insert("obs_ns".into(), Json::Num(c.obs_ns));
                o.insert("count".into(), Json::Num(c.count as f64));
                Json::Obj(o)
            })
            .collect();
        root.insert("cells".to_string(), Json::Arr(cells));
        json::to_string(&Json::Obj(root))
    }

    /// Parse the v2 format; v1 input is promoted via [`WisdomV2::from_v1`].
    pub fn from_json(text: &str) -> Result<WisdomV2> {
        let root = json::parse(text).map_err(|e| anyhow!("wisdom2: {e}"))?;
        match root.get("format").as_str() {
            Some("spfft-wisdom-v2") => {}
            Some("spfft-wisdom-v1") => return Ok(WisdomV2::from_v1(&Wisdom::from_json(text)?)),
            other => bail!("not a spfft wisdom file (format {other:?})"),
        }
        let n = root.get("n").as_usize().ok_or_else(|| anyhow!("wisdom2: bad n"))?;
        if n < 2 || !n.is_power_of_two() {
            bail!("wisdom2: n = {n} is not a power of two >= 2");
        }
        let source = root
            .get("source")
            .as_str()
            .ok_or_else(|| anyhow!("wisdom2: missing source"))?
            .to_string();
        let mut cells = Vec::new();
        // Edge records must be unique per (cell, kind, batch class, isa,
        // record role): the loader used to fold duplicates last-wins,
        // which silently dropped whichever estimate serialized first — a
        // hand-edited or badly merged file lost data with no diagnostic.
        // The prior/observation split (`count == 0` vs `> 0`) stays a
        // legitimate pair: `from_model` emits both for a class that has
        // an installed prior *and* live samples.
        let mut seen = std::collections::HashSet::new();
        for c in root.get("cells").as_arr().ok_or_else(|| anyhow!("wisdom2: missing cells"))? {
            let edge = c
                .get("edge")
                .as_str()
                .and_then(EdgeType::parse)
                .ok_or_else(|| anyhow!("wisdom2: bad edge {:?}", c.get("edge")))?;
            let stage = c.get("stage").as_usize().ok_or_else(|| anyhow!("wisdom2: bad stage"))?;
            let ctx = c
                .get("ctx")
                .as_usize()
                .and_then(Context::from_index)
                .ok_or_else(|| anyhow!("wisdom2: bad ctx"))?;
            // Absent in pre-batched-engine files: those records are all
            // unbatched observations.
            let batch = match c.get("batch") {
                Json::Null => 1,
                v => v.as_usize().filter(|&b| b >= 1).ok_or_else(|| anyhow!("wisdom2: bad batch"))?,
            };
            // Absent in pre-kind-axis files: those records are all
            // forward observations (the only kind that existed).
            let kind = match c.get("kind") {
                Json::Null => TransformKind::Forward,
                v => v
                    .as_str()
                    .and_then(TransformKind::parse)
                    .ok_or_else(|| anyhow!("wisdom2: bad kind {:?}", c.get("kind")))?,
            };
            // Absent in pre-SIMD-backend files: every observation in
            // those came from the scalar kernels.
            let isa = match c.get("isa") {
                Json::Null => Isa::Scalar,
                v => v
                    .as_str()
                    .and_then(Isa::parse)
                    .ok_or_else(|| anyhow!("wisdom2: bad isa {:?}", c.get("isa")))?,
            };
            let prior_ns = c.get("prior_ns").as_f64().ok_or_else(|| anyhow!("wisdom2: bad prior_ns"))?;
            if !prior_ns.is_finite() || prior_ns <= 0.0 {
                bail!("wisdom2: non-positive prior for {edge}@{stage}");
            }
            let obs_ns = c.get("obs_ns").as_f64().unwrap_or(0.0);
            let count = c.get("count").as_usize().unwrap_or(0) as u64;
            if count > 0 && (!obs_ns.is_finite() || obs_ns <= 0.0) {
                bail!("wisdom2: non-positive observation for {edge}@{stage}");
            }
            let class = crate::autotune::model::batch_class(batch);
            if !seen.insert((edge, stage, ctx.index(), kind, class, isa, count > 0)) {
                bail!(
                    "wisdom2: duplicate {} record for {edge}@{stage} (ctx {}, kind {}, \
                     batch class {}, isa {}) — records collide after batch-class \
                     canonicalization and last-wins merging would silently drop data",
                    if count > 0 { "observation" } else { "prior" },
                    ctx.index(),
                    kind.name(),
                    crate::autotune::model::class_batch(class),
                    isa.name(),
                );
            }
            cells.push(CellRecord { edge, stage, ctx, kind, batch, isa, prior_ns, obs_ns, count });
        }
        if cells.is_empty() {
            bail!("wisdom2: empty cell set");
        }
        Ok(WisdomV2 { n, source, cells })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json()).map_err(|e| anyhow!("writing {}: {e}", path.display()))
    }

    pub fn load(path: &Path) -> Result<WisdomV2> {
        let text =
            std::fs::read_to_string(path).map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        WisdomV2::from_json(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::sampler::{EdgeSample, SampleSpan};
    use crate::cost::SimCost;

    fn model_with_samples(n: usize) -> (OnlineCost, Wisdom) {
        let w = Wisdom::harvest(&mut SimCost::m1(n), "m1");
        let mut model = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        for &(e, s, ctx, ns) in w.cells.iter().take(5) {
            for _ in 0..7 {
                model.observe(&EdgeSample {
                    edge: e,
                    stage: s,
                    ctx,
                    span: SampleSpan::Edge,
                    kind: TransformKind::Forward,
                    batch: 1,
                    isa: Isa::Scalar,
                    ns: ns * 2.0,
                });
            }
        }
        (model, w)
    }

    #[test]
    fn json_roundtrip() {
        let (model, _) = model_with_samples(256);
        let w2 = WisdomV2::from_model(&model, "m1");
        let back = WisdomV2::from_json(&w2.to_json()).unwrap();
        assert_eq!(back, w2);
        assert_eq!(back.cells.iter().filter(|c| c.count > 0).count(), 5);
        assert!(back.cells.iter().all(|c| c.batch == 1));
        assert!(back.cells.iter().all(|c| c.kind == TransformKind::Forward));
        assert!(back.cells.iter().all(|c| c.isa == Isa::Scalar));
    }

    #[test]
    fn batched_observations_roundtrip_with_their_class() {
        let w = Wisdom::harvest(&mut SimCost::m1(256), "m1");
        let mut model = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        let (e, s, ctx, ns) = w.cells[0];
        for _ in 0..9 {
            // whole-batch sample at B=16: per-transform cost halved
            model.observe(&EdgeSample {
                edge: e,
                stage: s,
                ctx,
                span: SampleSpan::Edge,
                kind: TransformKind::Forward,
                batch: 16,
                isa: Isa::Scalar,
                ns: 16.0 * ns * 0.5,
            });
        }
        let w2 = WisdomV2::from_model(&model, "m1");
        // one batch=1 record per prior cell, plus one batch=16 record
        assert_eq!(w2.cells.len(), w.cells.len() + 1);
        let rec = w2.cells.iter().find(|c| c.batch == 16).expect("batched record");
        assert_eq!((rec.edge, rec.stage, rec.ctx), (e, s, ctx));
        assert_eq!(rec.count, 9);
        let back = WisdomV2::from_json(&w2.to_json()).unwrap();
        assert_eq!(back, w2);
        // seeding a fresh model restores the estimate at the right class
        let mut fresh = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        back.seed_model(&mut fresh);
        let class = crate::autotune::model::batch_class(16);
        assert_eq!(
            fresh.observation_at((e, s, ctx), class),
            model.observation_at((e, s, ctx), class)
        );
        assert_eq!(fresh.observation((e, s, ctx)), None);
        // blended v1 ignores batched records (no batch axis in v1)
        assert_eq!(back.to_blended_v1(4.0).cells.len(), w.cells.len());
    }

    #[test]
    fn batched_priors_roundtrip_and_seed_class_priors() {
        let w = Wisdom::harvest(&mut SimCost::m1(256), "m1");
        let w4 = Wisdom::harvest_batched(&mut SimCost::m1(256), "m1", 4);
        let w16 = Wisdom::harvest_batched(&mut SimCost::m1(256), "m1", 16);
        let w2 =
            WisdomV2::from_batched_priors(&w, &[(4, w4.clone()), (16, w16.clone())]).unwrap();
        assert_eq!(w2.cells.len(), 3 * w.cells.len());
        assert!(w2.cells.iter().all(|c| c.count == 0));
        let back = WisdomV2::from_json(&w2.to_json()).unwrap();
        assert_eq!(back, w2);
        // seeding installs the amortized surfaces as class priors
        let mut model = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        back.seed_model(&mut model);
        assert_eq!(model.total_samples(), 0, "pure priors must carry no live confidence");
        let (e, s, ctx, base) = w.cells[0];
        assert_eq!(model.estimate((e, s, ctx)), base);
        assert_eq!(
            model.estimate_at((e, s, ctx), crate::autotune::model::batch_class(16)),
            w16.cells[0].3
        );
        assert_eq!(
            model.estimate_at((e, s, ctx), crate::autotune::model::batch_class(4)),
            w4.cells[0].3
        );
        // a class without its own prior still falls back to class 0
        assert_eq!(model.estimate_at((e, s, ctx), crate::autotune::model::batch_class(2)), base);
    }

    #[test]
    fn shutdown_save_preserves_unobserved_class_priors() {
        // The serve flow: calibrate-harvested class priors installed at
        // startup, only unbatched traffic observed, model saved on
        // shutdown. The save must carry the amortized surface as
        // pure-prior records, and reloading must restore it — without
        // the observation records' prior_ns clobbering anything.
        let w = Wisdom::harvest(&mut SimCost::m1(256), "m1");
        let w16 = Wisdom::harvest_batched(&mut SimCost::m1(256), "m1", 16);
        let mut model = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        model.set_batched_prior(16, &w16);
        let (e, s, ctx, ns) = w.cells[0];
        for _ in 0..5 {
            model.observe(&EdgeSample {
                edge: e,
                stage: s,
                ctx,
                span: SampleSpan::Edge,
                kind: TransformKind::Forward,
                batch: 1,
                isa: Isa::Scalar,
                ns,
            });
        }
        let saved = WisdomV2::from_model(&model, "m1");
        // one pure-prior batched record per cell, none lost
        assert_eq!(
            saved.cells.iter().filter(|c| c.batch == 16 && c.count == 0).count(),
            w.cells.len()
        );
        let back = WisdomV2::from_json(&saved.to_json()).unwrap();
        assert_eq!(back, saved);
        let mut fresh = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        back.seed_model(&mut fresh);
        let class = crate::autotune::model::batch_class(16);
        assert_eq!(fresh.prior_at((e, s, ctx), class), Some(w16.cells[0].3));
        assert_eq!(fresh.observation((e, s, ctx)).unwrap().count, 5);
    }

    #[test]
    fn legacy_batched_observations_do_not_clobber_installed_class_priors() {
        // A pre-batched-prior wisdom file stores the class-0 prior in
        // its observation records; loading it over freshly-harvested
        // class priors must keep the amortized surface while still
        // seeding the observations.
        let w = Wisdom::harvest(&mut SimCost::m1(256), "m1");
        let w16 = Wisdom::harvest_batched(&mut SimCost::m1(256), "m1", 16);
        let (e, s, ctx, base) = w.cells[0];
        let legacy = WisdomV2 {
            n: 256,
            source: "m1".into(),
            cells: vec![CellRecord {
                edge: e,
                stage: s,
                ctx,
                kind: TransformKind::Forward,
                batch: 16,
                isa: Isa::Scalar,
                prior_ns: base, // legacy files carry the class-0 prior here
                obs_ns: base * 0.5,
                count: 12,
            }],
        };
        let mut model = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        model.set_batched_prior(16, &w16);
        legacy.seed_model(&mut model);
        let class = crate::autotune::model::batch_class(16);
        assert_eq!(model.prior_at((e, s, ctx), class), Some(w16.cells[0].3));
        assert_eq!(model.observation_at((e, s, ctx), class).unwrap().count, 12);
    }

    #[test]
    fn from_batched_priors_rejects_mismatched_or_unbatched_inputs() {
        let w = Wisdom::harvest(&mut SimCost::m1(256), "m1");
        let other = Wisdom::harvest(&mut SimCost::m1(1024), "m1");
        assert!(WisdomV2::from_batched_priors(&w, &[(4, other)]).is_err());
        assert!(WisdomV2::from_batched_priors(&w, &[(1, w.clone())]).is_err());
        // b=3 and b=4 canonicalize to the same batch class: ambiguous
        assert!(WisdomV2::from_batched_priors(&w, &[(3, w.clone()), (4, w.clone())]).is_err());
    }

    #[test]
    fn records_without_batch_field_default_to_unbatched() {
        // Files written before the batched engine have no "batch" key.
        let w2 = WisdomV2::from_json(
            r#"{"format":"spfft-wisdom-v2","n":8,"source":"x",
                "cells":[{"edge":"R2","stage":0,"ctx":0,"prior_ns":5.0,"obs_ns":6.0,"count":3}]}"#,
        )
        .unwrap();
        assert_eq!(w2.cells[0].batch, 1);
        assert!(WisdomV2::from_json(
            r#"{"format":"spfft-wisdom-v2","n":8,"source":"x",
                "cells":[{"edge":"R2","stage":0,"ctx":0,"batch":0,"prior_ns":5.0}]}"#,
        )
        .is_err());
    }

    #[test]
    fn records_without_kind_field_default_to_forward() {
        // Files written before the kind axis have no "kind" key: they
        // load as forward-only (mirroring the "batch" migration).
        let w2 = WisdomV2::from_json(
            r#"{"format":"spfft-wisdom-v2","n":8,"source":"x",
                "cells":[{"edge":"R2","stage":0,"ctx":0,"batch":1,"prior_ns":5.0,"obs_ns":6.0,"count":3}]}"#,
        )
        .unwrap();
        assert_eq!(w2.cells[0].kind, TransformKind::Forward);
        assert!(WisdomV2::from_json(
            r#"{"format":"spfft-wisdom-v2","n":8,"source":"x",
                "cells":[{"edge":"R2","stage":0,"ctx":0,"kind":"sideways","prior_ns":5.0}]}"#,
        )
        .is_err());
    }

    #[test]
    fn records_without_isa_field_default_to_scalar() {
        // Files written before the SIMD codelet backends have no "isa"
        // key: every observation in them came from the scalar kernels.
        let w2 = WisdomV2::from_json(
            r#"{"format":"spfft-wisdom-v2","n":8,"source":"x",
                "cells":[{"edge":"R2","stage":0,"ctx":0,"kind":"forward","batch":1,"prior_ns":5.0,"obs_ns":6.0,"count":3}]}"#,
        )
        .unwrap();
        assert_eq!(w2.cells[0].isa, Isa::Scalar);
        assert!(WisdomV2::from_json(
            r#"{"format":"spfft-wisdom-v2","n":8,"source":"x",
                "cells":[{"edge":"R2","stage":0,"ctx":0,"isa":"sse2","prior_ns":5.0}]}"#,
        )
        .is_err());
    }

    #[test]
    fn non_scalar_observations_roundtrip_and_reseed_at_their_isa() {
        // A model serving through a SIMD backend keys its live estimates
        // by that ISA; the shutdown save must carry the backend and the
        // reload must land the estimate back in the same slot.
        let w = Wisdom::harvest(&mut SimCost::m1(256), "m1");
        let mut model = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        model.set_exec_isa(Isa::Neon);
        let (e, s, ctx, ns) = w.cells[0];
        for _ in 0..8 {
            model.observe(&EdgeSample {
                edge: e,
                stage: s,
                ctx,
                span: SampleSpan::Edge,
                kind: TransformKind::Forward,
                batch: 1,
                isa: Isa::Neon,
                ns: ns * 2.0,
            });
        }
        let w2 = WisdomV2::from_model(&model, "m1");
        let rec = w2.cells.iter().find(|c| c.count > 0).expect("observation record");
        assert_eq!((rec.isa, rec.count), (Isa::Neon, 8));
        let back = WisdomV2::from_json(&w2.to_json()).unwrap();
        assert_eq!(back, w2);
        let mut fresh = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        back.seed_model(&mut fresh);
        assert_eq!(
            fresh.observation_kind_isa_at((e, s, ctx), 0, TransformKind::Forward, Isa::Neon),
            model.observation_kind_isa_at((e, s, ctx), 0, TransformKind::Forward, Isa::Neon)
        );
        // the scalar slot stays clean — backends never fold
        assert_eq!(fresh.observation((e, s, ctx)), None);
    }

    #[test]
    fn split_kind_observations_roundtrip_and_reseed_at_their_kind() {
        // With the calibration split on, inverse observations persist
        // as "kind":"inverse" records and reseed the inverse slot.
        let w = Wisdom::harvest(&mut SimCost::m1(256), "m1");
        let mut model = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        model.set_split_kinds(true);
        let (e, s, ctx, ns) = w.cells[0];
        for _ in 0..6 {
            model.observe(&EdgeSample {
                edge: e,
                stage: s,
                ctx,
                span: SampleSpan::Edge,
                kind: TransformKind::Inverse,
                batch: 1,
                isa: Isa::Scalar,
                ns: ns * 2.0,
            });
        }
        let w2 = WisdomV2::from_model(&model, "m1");
        let rec = w2.cells.iter().find(|c| c.kind == TransformKind::Inverse).expect("inverse record");
        assert_eq!((rec.edge, rec.stage, rec.ctx, rec.count), (e, s, ctx, 6));
        let back = WisdomV2::from_json(&w2.to_json()).unwrap();
        assert_eq!(back, w2);
        let mut fresh = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        fresh.set_split_kinds(true);
        back.seed_model(&mut fresh);
        assert_eq!(
            fresh.observation_kind_at((e, s, ctx), 0, TransformKind::Inverse),
            model.observation_kind_at((e, s, ctx), 0, TransformKind::Inverse)
        );
        // the forward slot stays clean under the split
        assert_eq!(fresh.observation((e, s, ctx)), None);
    }

    #[test]
    fn split_written_files_do_not_clobber_forward_slots_on_folded_reload() {
        // A wisdom file written under --split-kinds carries both forward
        // and inverse class-0 records for a cell. Reloading it into a
        // model WITHOUT the split must keep the forward estimate and
        // drop the inverse record (folding it through kind_slot would
        // overwrite forward with inverse, records being forward-first).
        let w = Wisdom::harvest(&mut SimCost::m1(256), "m1");
        let mut split = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        split.set_split_kinds(true);
        let (e, s, ctx, ns) = w.cells[0];
        for _ in 0..4 {
            split.observe(&EdgeSample {
                edge: e,
                stage: s,
                ctx,
                span: SampleSpan::Edge,
                kind: TransformKind::Forward,
                batch: 1,
                isa: Isa::Scalar,
                ns,
            });
            split.observe(&EdgeSample {
                edge: e,
                stage: s,
                ctx,
                span: SampleSpan::Edge,
                kind: TransformKind::Inverse,
                batch: 1,
                isa: Isa::Scalar,
                ns: ns * 9.0,
            });
        }
        let saved = WisdomV2::from_model(&split, "m1");
        let mut folded = OnlineCost::from_wisdom(&w, 0.5, 4.0); // split off
        saved.seed_model(&mut folded);
        let fwd = folded.observation((e, s, ctx)).expect("forward record restored");
        assert_eq!(fwd.count, 4);
        assert!(
            (fwd.mean - ns).abs() < 1e-9,
            "forward slot clobbered by the inverse record: {}",
            fwd.mean
        );
        // a split reload restores both at their own kinds
        let mut resplit = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        resplit.set_split_kinds(true);
        saved.seed_model(&mut resplit);
        assert!(resplit.observation_kind_at((e, s, ctx), 0, TransformKind::Inverse).is_some());
    }

    #[test]
    fn ru_context_cells_roundtrip_via_index7() {
        // A record whose ctx is After(RU) (index 7: the first c2c pass
        // of a real-inverse transform) must serialize and parse.
        let rec = CellRecord {
            edge: crate::edge::EdgeType::R4,
            stage: 0,
            ctx: crate::edge::Context::After(crate::edge::EdgeType::RU),
            kind: TransformKind::RealInverse,
            batch: 1,
            isa: Isa::Neon,
            prior_ns: 10.0,
            obs_ns: 12.0,
            count: 4,
        };
        let w2 = WisdomV2 { n: 8, source: "x".into(), cells: vec![rec.clone()] };
        let back = WisdomV2::from_json(&w2.to_json()).unwrap();
        assert_eq!(back.cells[0], rec);
    }

    #[test]
    fn seed_model_restores_learned_estimates() {
        let (model, w) = model_with_samples(256);
        let w2 = WisdomV2::from_model(&model, "m1");
        let mut fresh = OnlineCost::from_wisdom(&w, 0.5, 4.0);
        assert_eq!(fresh.total_samples(), 0);
        w2.seed_model(&mut fresh);
        assert_eq!(fresh.total_samples(), model.total_samples());
        let (e, s, ctx, _) = w.cells[0];
        assert_eq!(fresh.observation((e, s, ctx)), model.observation((e, s, ctx)));
    }

    #[test]
    fn v1_files_are_promoted() {
        let w = Wisdom::harvest(&mut SimCost::m1(256), "m1");
        let w2 = WisdomV2::from_json(&w.to_json()).unwrap();
        assert_eq!(w2.n, 256);
        assert_eq!(w2.cells.len(), w.cells.len());
        assert!(w2.cells.iter().all(|c| c.count == 0));
        // blended v1 of an unobserved v2 equals the original weights
        let blended = w2.to_blended_v1(8.0);
        for (a, b) in w.cells.iter().zip(&blended.cells) {
            assert_eq!(a.0, b.0);
            assert!((a.3 - b.3).abs() < 1e-12);
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(WisdomV2::from_json("{}").is_err());
        assert!(WisdomV2::from_json(r#"{"format":"spfft-wisdom-v2","n":8,"source":"x","cells":[]}"#).is_err());
        assert!(WisdomV2::from_json(
            r#"{"format":"spfft-wisdom-v2","n":8,"source":"x",
                "cells":[{"edge":"R2","stage":0,"ctx":0,"prior_ns":5.0,"obs_ns":-1.0,"count":3}]}"#
        )
        .is_err());
    }

    #[test]
    fn duplicate_edge_records_are_a_load_error_not_last_wins() {
        // Two observation records for the same (cell, kind, batch class,
        // isa) — the loader must refuse instead of keeping whichever
        // came last.
        let err = WisdomV2::from_json(
            r#"{"format":"spfft-wisdom-v2","n":8,"source":"x","cells":[
                {"edge":"R2","stage":0,"ctx":0,"kind":"forward","batch":1,"isa":"scalar","prior_ns":5.0,"obs_ns":6.0,"count":3},
                {"edge":"R2","stage":0,"ctx":0,"kind":"forward","batch":1,"isa":"scalar","prior_ns":5.0,"obs_ns":9.0,"count":8}]}"#,
        )
        .expect_err("duplicate observation records must not load");
        let msg = format!("{err}");
        assert!(msg.contains("duplicate observation record"), "unhelpful error: {msg}");
        assert!(msg.contains("R2@0"), "error must name the cell: {msg}");

        // records whose batch sizes canonicalize to the same class
        // collide too (b=3 and b=4 are both class 2)
        assert!(WisdomV2::from_json(
            r#"{"format":"spfft-wisdom-v2","n":8,"source":"x","cells":[
                {"edge":"R2","stage":0,"ctx":0,"kind":"forward","batch":3,"isa":"scalar","prior_ns":5.0,"obs_ns":6.0,"count":3},
                {"edge":"R2","stage":0,"ctx":0,"kind":"forward","batch":4,"isa":"scalar","prior_ns":5.0,"obs_ns":7.0,"count":2}]}"#,
        )
        .is_err());

        // duplicate pure-prior records collide as well
        assert!(WisdomV2::from_json(
            r#"{"format":"spfft-wisdom-v2","n":8,"source":"x","cells":[
                {"edge":"R2","stage":0,"ctx":0,"kind":"forward","batch":16,"isa":"scalar","prior_ns":5.0},
                {"edge":"R2","stage":0,"ctx":0,"kind":"forward","batch":16,"isa":"scalar","prior_ns":7.0}]}"#,
        )
        .is_err());
    }

    #[test]
    fn prior_plus_observation_pair_for_one_cell_still_loads() {
        // The legitimate pair `from_model` emits — a pure class prior
        // (count 0) next to an observation at the same class — must not
        // trip the duplicate check; neither must records differing only
        // in kind, isa, or batch class.
        let w2 = WisdomV2::from_json(
            r#"{"format":"spfft-wisdom-v2","n":8,"source":"x","cells":[
                {"edge":"R2","stage":0,"ctx":0,"kind":"forward","batch":16,"isa":"scalar","prior_ns":5.0},
                {"edge":"R2","stage":0,"ctx":0,"kind":"forward","batch":16,"isa":"scalar","prior_ns":5.0,"obs_ns":6.0,"count":3},
                {"edge":"R2","stage":0,"ctx":0,"kind":"inverse","batch":16,"isa":"scalar","prior_ns":5.0,"obs_ns":6.5,"count":2},
                {"edge":"R2","stage":0,"ctx":0,"kind":"forward","batch":16,"isa":"neon","prior_ns":5.0,"obs_ns":4.0,"count":1},
                {"edge":"R2","stage":0,"ctx":0,"kind":"forward","batch":1,"isa":"scalar","prior_ns":5.0,"obs_ns":5.5,"count":9}]}"#,
        )
        .expect("distinct roles and axes must coexist");
        assert_eq!(w2.cells.len(), 5);
        // ... and every database `from_model` writes stays loadable
        let (model, _) = model_with_samples(256);
        let saved = WisdomV2::from_model(&model, "m1");
        assert_eq!(WisdomV2::from_json(&saved.to_json()).unwrap(), saved);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("spfft-wisdom2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m1.wisdom2.json");
        let (model, _) = model_with_samples(256);
        let w2 = WisdomV2::from_model(&model, "m1");
        w2.save(&path).unwrap();
        assert_eq!(WisdomV2::load(&path).unwrap(), w2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
