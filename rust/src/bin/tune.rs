//! Automated calibration fitter for the simulator parameter sets.
//!
//! Searches the MachineParams space for values that reproduce the paper's
//! *categorical* results (which plans the searches discover, who beats
//! whom) and minimize log-error against the published anchor numbers
//! (Tables 2–4). The winning vector is printed in `params.rs` syntax and
//! baked into `MachineParams::m1()` / `::haswell()`.
//!
//! Usage: cargo run --release --bin tune -- [options]
//!
//!   --machine m1|haswell   target parameter set        [default: m1]
//!   --evals N              optimizer evaluation budget [default: 40000]
//!   --seed S               optimizer RNG seed
//!   --prior-out FILE       after fitting, harvest the fitted machine's
//!                          full contextual cell catalog and write it as a
//!                          wisdom v2 file — the autotuner's offline prior
//!                          (`spfft serve --autotune`, DESIGN.md §autotune)
//!   --prior-n N            FFT size for --prior-out    [default: 1024]
//!
//! Bare positionals (`tune m1 40000`) keep working for older scripts.

use spfft::autotune::WisdomV2;
use spfft::cost::{CostModel, PlanningSurface, SimCost, Wisdom};
use spfft::edge::{Context, EdgeType};
use spfft::plan::Plan;
use spfft::planner::{plan as run_plan, Strategy};
use spfft::sim::{Machine, MachineParams};
use spfft::util::cli::Command;
use spfft::util::rng::Rng;

const N: usize = 1024;

#[derive(Clone, Debug)]
struct Spec {
    names: Vec<&'static str>,
    lo: Vec<f64>,
    hi: Vec<f64>,
}

fn spec() -> Spec {
    let rows: Vec<(&'static str, f64, f64)> = vec![
        ("bf_r2", 2.0, 9.0),
        ("bf_r4", 4.0, 18.0),
        ("bf_r8", 10.0, 90.0),
        ("fused_pps", 0.08, 0.7),
        ("scalar_penalty", 2.0, 8.0),
        ("blk_overhead", 2.0, 24.0),
        ("transpose", 0.5, 12.0),
        ("gather", 1.0, 24.0),
        ("spill", 2.0, 24.0),
        ("twl_stream", 2.0, 40.0),
        ("depth_gamma", 0.0, 0.9),
        ("k_bank", 0.2, 3.5),
        ("pressure_start", 0.05, 0.7),
        ("aff_half", 0.25, 0.95),
        ("aff_same", 0.5, 1.0),
        ("after_fused", 1.0, 1.8),
        ("start_mem", 1.0, 2.2),
        ("l1_bw", 16.0, 96.0),
        ("iso_fused_mem", 0.4, 1.0),
    ];
    Spec {
        names: rows.iter().map(|r| r.0).collect(),
        lo: rows.iter().map(|r| r.1).collect(),
        hi: rows.iter().map(|r| r.2).collect(),
    }
}

fn to_params(base: &MachineParams, x: &[f64]) -> MachineParams {
    let mut p = base.clone();
    p.bf.r2 = x[0];
    p.bf.r4 = x[1];
    p.bf.r8 = x[2];
    p.bf.fused_per_point_stage = x[3];
    p.scalar_penalty = x[4];
    p.blk_overhead_cyc = x[5];
    p.fused_transpose_cyc = x[6];
    p.fused_gather_cyc = x[7];
    p.spill_cyc_per_vreg = x[8];
    p.fused_twiddle_stream_cyc = x[9];
    p.fused_depth_gamma = x[10];
    p.k_bank = x[11];
    p.pressure_start_mult = x[12];
    p.affinity_half_stride = x[13];
    p.affinity_same_stride = x[14];
    p.after_fused_mem = x[15];
    p.start_mem = x[16];
    p.l1_bw_bytes_cyc = x[17];
    p.iso_fused_mem = x[18];
    p
}

fn log_err(got: f64, want: f64) -> f64 {
    let e = (got.max(1.0) / want).ln();
    e * e
}

/// Loss for the M1 target set.
fn loss_m1(params: &MachineParams) -> f64 {
    let machine = Machine::new(params.clone());
    let mut cost = SimCost::new(machine.clone(), N);
    let mut loss = 0.0;

    let p = |s: &str| Plan::parse(s).unwrap();
    let target_cf = p("R4,F8,F32");
    let target_ca = p("R4,R2,R4,R4,F8");

    // --- searches ---
    let cf = run_plan(&mut cost, &Strategy::DijkstraContextFree);
    let ca = run_plan(&mut cost, &Strategy::DijkstraContextAware { k: 1 });
    let ex = run_plan(&mut cost, &Strategy::Exhaustive);
    if cf.plan != target_cf {
        // Qualitative fallback: the paper's CF story needs a fused-heavy,
        // F32-tailed plan distinct from the CA optimum.
        let has_f32 = cf.plan.edges().contains(&EdgeType::F32);
        loss += if has_f32 && cf.plan != target_ca { 8.0 } else { 40.0 };
    }
    if ca.plan != target_ca {
        loss += 60.0;
    }
    if ex.plan != target_ca {
        loss += 60.0;
    }

    // --- Table 3 anchors (steady-state contextual ns) ---
    let anchors = [
        ("R2,R2,R2,R2,R2,R2,R2,R2,R2,R2", 9014.0, 1.0),
        ("R4,R4,R4,R4,R4", 6903.0, 1.0),
        ("R2,R8,R8,R8", 6792.0, 1.0),
        ("R8,R8,R8,R2", 6889.0, 1.0),
        ("R8,R8,R4,R4", 6861.0, 1.0),
        ("R4,R8,R8,R4", 6889.0, 1.0),
        ("R2,R2,R2,R2,R2,F32", 2569.0, 1.0),
        ("R4,R4,R4,F16", 1764.0, 2.0),
        ("R4,F8,F32", 2320.0, 2.0),
        ("R4,R2,R4,R4,F8", 1722.0, 3.0),
    ];
    for (s, want, w) in anchors {
        loss += w * log_err(cost.plan_ns(&p(s)), want);
    }

    // --- Table 2 anchors, read as in-context (warm after-R4) values:
    // the only reading consistent with Table 3's arrangement sums.
    let warm = [
        (EdgeType::F8, 7usize, 458.0, 3.0),   // 33.5 GF over 3 stages
        (EdgeType::F16, 6, 667.0, 3.0),       // 30.7 GF over 4 stages
        (EdgeType::F32, 5, 1249.0, 3.0),      // 20.5 GF over 5 stages
    ];
    for (e, s, want, w) in warm {
        loss += w * log_err(cost.edge_ns(e, s, Context::After(EdgeType::R4)), want);
    }

    // --- Table 4 shape (scale-free ratios; the absolute left side is an
    // isolation artifact our L1-resident model does not chase) ---
    let r2 = |cost: &mut SimCost, s: usize| cost.edge_ns(EdgeType::R2, s, Context::Start);
    let (p1, p4, p7, p10) = (r2(&mut cost, 0), r2(&mut cost, 3), r2(&mut cost, 6), r2(&mut cost, 9));
    loss += 0.5 * log_err(p10 / p7, 4250.0 / 380.0); // right-side collapse
    loss += 0.3 * log_err(p1 / p4, 3580.0 / 750.0);  // left-side stride cost
    if p10 < p1 {
        loss += 2.0; // pass 10 is the slowest in the paper
    }

    // --- CF plan's true (contextual) time anchor: the 26% gap ---
    if cf.plan.edges().contains(&EdgeType::F32) {
        loss += 3.0 * log_err(cf.true_ns, 2320.0);
    }

    // ordering sanity: CA true <= every Table-3 row
    let ca_t = cost.plan_ns(&target_ca);
    for (s, _, _) in anchors {
        if cost.plan_ns(&p(s)) < ca_t - 1e-6 {
            loss += 10.0;
        }
    }
    loss
}

/// Loss for the Haswell target set (categorical only: the 2015 optimum,
/// no fused blocks in the optimum, F32 absent by construction).
fn loss_haswell(params: &MachineParams) -> f64 {
    // Context effects are weak on Haswell (shallower cache hierarchy in
    // the 2015 study): pin the context parameters near 1 so the searches
    // and ground truth agree, and tune only the compute side.
    let mut params = params.clone();
    params.affinity_half_stride = 0.95;
    params.affinity_same_stride = 0.98;
    params.after_fused_mem = 1.05;
    params.iso_fused_mem = 0.95;
    params.start_mem = 1.10;
    let machine = Machine::new(params.clone());
    let mut cost = SimCost::new(machine, N);
    let mut loss = 0.0;
    let target = Plan::parse("R4,R8,R8,R4").unwrap();
    let ex = run_plan(&mut cost, &Strategy::Exhaustive);
    let ca = run_plan(&mut cost, &Strategy::DijkstraContextAware { k: 1 });
    let cf = run_plan(&mut cost, &Strategy::DijkstraContextFree);
    if ex.plan != target {
        loss += 60.0;
    }
    if ca.plan != target {
        loss += 40.0;
    }
    if cf.plan != target {
        loss += 20.0;
    }
    // Keep magnitudes sane: pure-radix plans land in a few microseconds.
    loss += log_err(cost.plan_ns(&target), 4000.0);
    // Fused-tailed plans should lose clearly but not absurdly.
    let f16 = cost.plan_ns(&Plan::parse("R4,R4,R4,F16").unwrap());
    if f16 < cost.plan_ns(&target) {
        loss += 20.0;
    }
    loss += 0.3 * log_err(f16, 6000.0);
    loss
}

fn clampv(spec: &Spec, x: &mut [f64]) {
    for i in 0..x.len() {
        x[i] = x[i].clamp(spec.lo[i], spec.hi[i]);
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("tune", "fit simulator parameters to the paper's shape")
        .opt("machine", "m1", "target parameter set (m1|haswell)")
        .opt("evals", "40000", "optimizer evaluation budget")
        .opt("seed", "", "optimizer RNG seed (default: the baked-in seed)")
        .opt("prior-out", "", "write the fitted machine's contextual cells as wisdom v2")
        .opt("prior-n", "1024", "FFT size for --prior-out")
        .opt("kind", "forward", "transform kind whose planning surface --prior-out harvests");
    if argv.iter().any(|a| a == "--help" || a == "-h") {
        eprintln!("{}", cmd.usage());
        return;
    }
    let args = match cmd.parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    // Back-compat: bare positionals override the flag defaults.
    let positional = args.positional().to_vec();
    let which_owned = positional
        .first()
        .cloned()
        .unwrap_or_else(|| args.get("machine").to_string());
    let which = which_owned.as_str();
    let evals: usize = match positional.get(1) {
        Some(s) => s.parse().unwrap_or_else(|_| {
            eprintln!("error: evals expects an integer, got '{s}'");
            std::process::exit(2);
        }),
        None => args.get_usize("evals").unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(2);
        }),
    };
    let seed: u64 = match args.get("seed") {
        "" => 0xCA11B007,
        s => s.parse().unwrap_or_else(|_| {
            eprintln!("error: --seed expects a u64, got '{s}'");
            std::process::exit(2);
        }),
    };
    // Reject unknown values with the valid-option list (consistent with
    // the --prior-n hardening): a typo'd machine or kind must not fall
    // through to a default fit.
    let base = MachineParams::by_name(which).unwrap_or_else(|| {
        eprintln!("error: --machine must be m1|haswell, got '{which}'");
        std::process::exit(2);
    });
    let kind = spfft::kind::TransformKind::parse(args.get("kind")).unwrap_or_else(|| {
        eprintln!(
            "error: --kind must be {}, got '{}'",
            spfft::kind::TransformKind::valid_names(),
            args.get("kind")
        );
        std::process::exit(2);
    });
    let loss_fn: fn(&MachineParams) -> f64 = match which {
        "m1" => loss_m1,
        _ => loss_haswell,
    };
    let sp = spec();
    // start from the current baked values
    let mut x: Vec<f64> = vec![
        base.bf.r2,
        base.bf.r4,
        base.bf.r8,
        base.bf.fused_per_point_stage,
        base.scalar_penalty,
        base.blk_overhead_cyc,
        base.fused_transpose_cyc,
        base.fused_gather_cyc,
        base.spill_cyc_per_vreg,
        base.fused_twiddle_stream_cyc,
        base.fused_depth_gamma,
        base.k_bank,
        base.pressure_start_mult,
        base.affinity_half_stride,
        base.affinity_same_stride,
        base.after_fused_mem,
        base.start_mem,
        base.l1_bw_bytes_cyc,
        base.iso_fused_mem,
    ];
    clampv(&sp, &mut x);
    let mut best = loss_fn(&to_params(&base, &x));
    let mut rng = Rng::new(seed);
    println!("initial loss: {best:.3}");
    let mut used = 0usize;
    let mut restarts = 0;
    let mut cur = x.clone();
    let mut cur_loss = best;
    let mut best_x = x.clone();
    while used < evals {
        // propose: perturb 1-4 random coordinates multiplicatively
        let k = 1 + (rng.next_below(4) as usize);
        let mut cand = cur.clone();
        for _ in 0..k {
            let i = rng.range(0, cand.len());
            let scale = (rng.next_f64() - 0.5) * 0.6; // +-30%
            cand[i] *= (1.0f64 + scale).max(0.2);
            if rng.next_below(12) == 0 {
                // occasional jump anywhere in range
                cand[i] = sp.lo[i] + rng.next_f64() * (sp.hi[i] - sp.lo[i]);
            }
        }
        clampv(&sp, &mut cand);
        let l = loss_fn(&to_params(&base, &cand));
        used += 1;
        // simulated-annealing-ish acceptance
        if l < cur_loss || rng.next_f64() < 0.02 {
            cur = cand;
            cur_loss = l;
        }
        if l < best {
            best = l;
            best_x = cur.clone();
            println!("eval {used}: loss {best:.3}");
        }
        // restart if stuck
        if used % 6000 == 0 {
            restarts += 1;
            cur = best_x.clone();
            cur_loss = best;
            if restarts % 2 == 0 {
                for i in 0..cur.len() {
                    if rng.next_below(3) == 0 {
                        cur[i] = sp.lo[i] + rng.next_f64() * (sp.hi[i] - sp.lo[i]);
                    }
                }
                clampv(&sp, &mut cur);
                cur_loss = loss_fn(&to_params(&base, &cur));
            }
        }
    }
    println!("\nfinal loss: {best:.3}");
    for (name, v) in sp.names.iter().zip(&best_x) {
        println!("  {name}: {v:.4},");
    }
    // categorical report
    let p = to_params(&base, &best_x);
    let mut cost = SimCost::new(Machine::new(p.clone()), N);
    let cf = run_plan(&mut cost, &Strategy::DijkstraContextFree);
    let ca = run_plan(&mut cost, &Strategy::DijkstraContextAware { k: 1 });
    let ex = run_plan(&mut cost, &Strategy::Exhaustive);
    println!("CF: {}  (true {:.0} ns)", cf.plan, cf.true_ns);
    println!("CA: {}  (true {:.0} ns)", ca.plan, ca.true_ns);
    println!("EX: {}  (true {:.0} ns)", ex.plan, ex.true_ns);

    // Optional: export the fitted machine's full contextual cell catalog
    // as a wisdom v2 prior for the online autotuner.
    let prior_out = args.get("prior-out");
    if !prior_out.is_empty() {
        let prior_n = args.get_usize("prior-n").unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(2);
        });
        let mut source = format!("sim:{which}:tuned");
        if kind != spfft::kind::TransformKind::Forward {
            source.push_str(&format!(":{kind}"));
        }
        let mut prior_cost = SimCost::new(Machine::new(p), prior_n);
        let v1 =
            Wisdom::harvest_surface(&mut prior_cost, &source, PlanningSurface::for_kind(kind));
        let w2 = WisdomV2::from_v1(&v1);
        match w2.save(std::path::Path::new(prior_out)) {
            Ok(()) => println!(
                "wrote autotune prior: {} cells (n={prior_n}) to {prior_out}",
                w2.cells.len()
            ),
            Err(e) => {
                eprintln!("error: {e}");
                std::process::exit(1);
            }
        }
    }
}
