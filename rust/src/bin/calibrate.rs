//! Calibration inspector + batched-prior harvester.
//!
//! Default: prints everything the simulator predicts for the paper's
//! experiments so the M1/Haswell parameter sets can be tuned against the
//! published shape (see DESIGN.md §2 and EXPERIMENTS.md).
//!
//! With `--prior-out FILE`: harvests the full contextual database from
//! the selected machine's `edge_ns_batched` at every `--batches` width
//! and writes unbatched + batched wisdom-v2 priors
//! (`WisdomV2::from_batched_priors`) — the file `spfft serve --autotune
//! --wisdom` and `AutotuneConfig::batched_priors` consume so re-planning
//! at a batched regime starts from the amortized cost surface. (`spfft
//! wisdom --export --batch B` covers the one-width v1 CLI path; this is
//! the multi-class v2 harvest.)
//!
//! Usage: cargo run --bin calibrate [--release] -- [--n N] [--machine M]
//!        [--prior-out FILE [--batches 4,16,64]] [--report]

use spfft::autotune::WisdomV2;
use spfft::cost::{CostModel, PlanningSurface, SimCost, Wisdom};
use spfft::edge::{Context, EdgeType};
use spfft::kind::TransformKind;
use spfft::plan::{table3_arrangements, Plan};
use spfft::planner::{plan, rank_all_plans, Strategy};
use spfft::util::cli::{CliError, Command};
use spfft::util::stats::gflops;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("calibrate", "simulator calibration report / batched-prior harvest")
        .opt("n", "1024", "FFT size for --prior-out harvesting")
        .opt("machine", "m1", "simulated machine (m1|haswell)")
        .opt("prior-out", "", "write unbatched + batched wisdom v2 priors to this file")
        .opt("batches", "4,16,64", "comma-separated batch widths for --prior-out")
        .opt("kind", "forward", "transform kind whose planning surface --prior-out harvests (real kinds: --n is the c2c half size)")
        .flag("report", "also print the calibration report when harvesting");
    if argv.iter().any(|a| a == "--help" || a == "-h") {
        eprintln!("{}", cmd.usage());
        return;
    }
    let args = match cmd.parse(&argv) {
        Ok(args) => args,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let prior_out = args.get("prior-out").to_string();
    if !prior_out.is_empty() {
        if let Err(e) = harvest_priors(&args, &prior_out) {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
    if prior_out.is_empty() || args.flag("report") {
        report();
    }
}

/// Harvest `edge_ns_batched` at every requested width into batched
/// wisdom-v2 priors.
fn harvest_priors(args: &spfft::util::cli::Args, out: &str) -> Result<(), CliError> {
    let n = args.get_usize("n")?;
    if !n.is_power_of_two() || n < 2 {
        return Err(CliError(format!("--n must be a power of two >= 2, got {n}")));
    }
    let machine = spfft::sim::Machine::by_name(args.get("machine"))
        .ok_or_else(|| CliError(format!("--machine must be m1|haswell, got '{}'", args.get("machine"))))?;
    let kind = TransformKind::parse(args.get("kind")).ok_or_else(|| {
        CliError(format!(
            "--kind must be {}, got '{}'",
            TransformKind::valid_names(),
            args.get("kind")
        ))
    })?;
    let mut batches: Vec<usize> = Vec::new();
    for part in args.get("batches").split(',') {
        let b: usize = part
            .trim()
            .parse()
            .map_err(|_| CliError(format!("bad --batches entry '{part}'")))?;
        if b < 2 {
            return Err(CliError(format!("--batches entries must be >= 2, got {b}")));
        }
        batches.push(b);
    }
    let mut source = format!("sim:{}", machine.name());
    if kind != TransformKind::Forward {
        source.push_str(&format!(":{kind}"));
    }
    let mut cost = SimCost::new(machine, n);
    let prior = Wisdom::harvest_surface(&mut cost, &source, PlanningSurface::for_kind(kind));
    let harvested: Vec<(usize, Wisdom)> = batches
        .iter()
        .map(|&b| (b, Wisdom::harvest_batched(&mut cost, &source, b)))
        .collect();
    // visibility: how much the model thinks each width amortizes
    for (b, w) in &harvested {
        let ratio: f64 = w
            .cells
            .iter()
            .zip(&prior.cells)
            .map(|(bc, uc)| bc.3 / uc.3)
            .sum::<f64>()
            / w.cells.len() as f64;
        println!("  B={b}: mean per-transform cost {:.1}% of unbatched", 100.0 * ratio);
    }
    let w2 = WisdomV2::from_batched_priors(&prior, &harvested)
        .map_err(|e| CliError(format!("{e}")))?;
    w2.save(std::path::Path::new(out)).map_err(|e| CliError(format!("{e}")))?;
    println!(
        "wrote {} cells ({} unbatched + {} batched classes, n={n}, source {source}) to {out}",
        w2.cells.len(),
        prior.cells.len(),
        harvested.len(),
    );
    Ok(())
}

fn report() {
    let n = 1024;
    let l = 10;

    println!("=== Table 4: per-pass radix-2 profile (M1 sim) ===");
    let mut cost = SimCost::m1(n);
    for s in 0..l {
        let iso = cost.edge_ns(EdgeType::R2, s, Context::Start);
        let warm = cost.edge_ns(EdgeType::R2, s, Context::After(EdgeType::R2));
        let g = 5.0 * n as f64 / iso;
        println!(
            "  pass {:>2} (stage {s}, stride {:>4}): iso {:>8.0} ns ({:>5.1} GF/pass-stage)  warm {:>8.0} ns",
            s + 1,
            512 >> s,
            iso,
            g,
            warm
        );
    }
    for (e, s) in [(EdgeType::F8, 7usize), (EdgeType::F16, 6), (EdgeType::F32, 5)] {
        let iso = cost.edge_ns(e, s, Context::Start);
        let warm = cost.edge_ns(e, s, Context::After(EdgeType::R4));
        let g = 5.0 * n as f64 * e.stages() as f64 / iso;
        println!(
            "  {:<4} terminal: iso {:>8.0} ns ({:>5.1} GF)  warm-after-R4 {:>8.0} ns ({:>5.1} GF)",
            e.name(),
            iso,
            g,
            warm,
            5.0 * n as f64 * e.stages() as f64 / warm
        );
    }

    println!("\n=== boundary (RU) context cells (M1 sim, c2c half n=512) ===");
    let mut half = SimCost::m1(n / 2);
    for (e, s) in [(EdgeType::R2, 0usize), (EdgeType::R4, 0), (EdgeType::F8, 6)] {
        let after_ru = half.edge_ns(e, s, Context::After(EdgeType::RU));
        let cold = half.edge_ns(e, s, Context::Start);
        println!(
            "  {:<4}@{s}: after-RU {:>8.0} ns  vs isolated {:>8.0} ns",
            e.name(),
            after_ru,
            cold
        );
    }

    println!("\n=== Table 3: arrangements (M1 sim, steady-state contextual) ===");
    let mut rows: Vec<(String, Plan)> = table3_arrangements()
        .into_iter()
        .map(|r| (r.label.to_string(), r.plan))
        .collect();
    // replace the two Dijkstra rows with what the searches actually find
    let cf = plan(&mut cost, &Strategy::DijkstraContextFree);
    let ca = plan(&mut cost, &Strategy::DijkstraContextAware { k: 1 });
    rows[8] = (format!("Dijkstra CF -> {}", cf.plan), cf.plan.clone());
    rows[9] = (format!("Dijkstra CA -> {}", ca.plan), ca.plan.clone());
    let best = rows
        .iter()
        .map(|(_, p)| cost.plan_ns(p))
        .fold(f64::MAX, f64::min);
    for (label, p) in &rows {
        let t = cost.plan_ns(p);
        println!(
            "  {:<44} {:>8.0} ns  {:>5.1} GF  {:>4.0}%",
            label,
            t,
            gflops(n, t),
            100.0 * best / t
        );
    }

    println!("\n=== search agreement ===");
    let ex = plan(&mut cost, &Strategy::Exhaustive);
    println!("  CF  plan: {}  believed {:.0} true {:.0}", cf.plan, cf.believed_ns, cf.true_ns);
    println!("  CA  plan: {}  believed {:.0} true {:.0}", ca.plan, ca.believed_ns, ca.true_ns);
    println!("  EXH plan: {}  true {:.0}", ex.plan, ex.true_ns);
    println!("  targets : CF = R4->F8->F32 | CA = EXH = R4->R2->R4->R4->F8");
    println!("  CA vs CF true improvement: {:.0}%", 100.0 * (1.0 - ca.true_ns / cf.true_ns));

    println!("\n=== top-10 plans by true time (M1 sim) ===");
    for (p, t) in rank_all_plans(&mut cost, l).into_iter().take(10) {
        println!("  {:<36} {:>8.0} ns {:>5.1} GF", p.to_string(), t, gflops(n, t));
    }

    println!("\n=== Haswell ===");
    let mut hw = SimCost::haswell(n);
    let cf_h = plan(&mut hw, &Strategy::DijkstraContextFree);
    let ca_h = plan(&mut hw, &Strategy::DijkstraContextAware { k: 1 });
    let ex_h = plan(&mut hw, &Strategy::Exhaustive);
    println!("  CF  plan: {}", cf_h.plan);
    println!("  CA  plan: {}  (target R4->R8->R8->R4)", ca_h.plan);
    println!("  EXH plan: {}  true {:.0}", ex_h.plan, ex_h.true_ns);
    println!("\n=== top-10 plans (Haswell sim) ===");
    for (p, t) in rank_all_plans(&mut hw, l).into_iter().take(10) {
        println!("  {:<36} {:>8.0} ns {:>5.1} GF", p.to_string(), t, gflops(n, t));
    }
}
