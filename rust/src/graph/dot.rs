//! Graphviz DOT exporters for the paper's figures.
//!
//! * [`context_free_dot`] — Figure 1: nodes 0..L, one edge per
//!   (edge type, stage), colored by type, weighted by isolation cost.
//! * [`context_aware_dot`] — Figure 2: expanded nodes (s, t_prev); the
//!   optimal path is highlighted in red.
//! * [`decomposition_dot`] — Figure 3: a set of plans as stage-interval
//!   chains for side-by-side comparison.

use crate::cost::CostModel;
use crate::edge::{Context, EdgeType};
use crate::plan::Plan;

fn color(e: EdgeType) -> &'static str {
    match e {
        EdgeType::R2 => "blue",
        EdgeType::R4 => "orange",
        EdgeType::R8 => "red",
        EdgeType::F8 | EdgeType::F16 | EdgeType::F32 => "green",
        // never drawn: RU is a boundary pass, not a graph edge
        EdgeType::RU => "purple",
    }
}

/// Figure 1: the context-free computation graph for L stages.
pub fn context_free_dot<C: CostModel>(cost: &mut C, l: usize) -> String {
    let mut s = String::from("digraph contextfree {\n  rankdir=LR;\n  node [shape=circle];\n");
    for stage in 0..=l {
        s.push_str(&format!("  s{stage} [label=\"{stage}\"];\n"));
    }
    for stage in 0..l {
        for e in cost.available_edges() {
            let k = e.stages();
            if !super::edge_allowed(e, stage, l) {
                continue;
            }
            let w = cost.edge_ns(e, stage, Context::Start);
            s.push_str(&format!(
                "  s{stage} -> s{} [label=\"{} {:.0}ns\", color={}];\n",
                stage + k,
                e.name(),
                w,
                color(e)
            ));
        }
    }
    s.push_str("}\n");
    s
}

/// Figure 2: the context-aware expanded graph; `highlight` (if given) is
/// drawn in red with penwidth 3 (the paper highlights the optimal path).
pub fn context_aware_dot<C: CostModel>(cost: &mut C, l: usize, highlight: Option<&Plan>) -> String {
    let mut s =
        String::from("digraph contextaware {\n  rankdir=LR;\n  node [shape=box, fontsize=10];\n");
    let node_id = |stage: usize, ctx: Context| format!("n{}_{}", stage, ctx.index());
    // Highlighted transitions (stage, ctx, edge).
    let mut hot: std::collections::HashSet<(usize, usize, EdgeType)> = Default::default();
    if let Some(plan) = highlight {
        let mut ctx = Context::Start;
        for (e, st) in plan.steps() {
            hot.insert((st, ctx.index(), e));
            ctx = Context::After(e);
        }
    }
    // Reachable expansion from (0, start).
    let mut seen = std::collections::HashSet::new();
    let mut frontier = vec![(0usize, Context::Start)];
    seen.insert((0, Context::Start.index()));
    s.push_str(&format!("  {} [label=\"(0, start)\"];\n", node_id(0, Context::Start)));
    while let Some((stage, ctx)) = frontier.pop() {
        for e in cost.available_edges() {
            let k = e.stages();
            if !super::edge_allowed(e, stage, l) {
                continue;
            }
            let w = cost.edge_ns(e, stage, ctx);
            let next = (stage + k, Context::After(e));
            if seen.insert((next.0, next.1.index())) {
                s.push_str(&format!(
                    "  {} [label=\"({}, {})\"];\n",
                    node_id(next.0, next.1),
                    next.0,
                    e.name()
                ));
                if next.0 < l {
                    frontier.push(next);
                }
            }
            let is_hot = hot.contains(&(stage, ctx.index(), e));
            s.push_str(&format!(
                "  {} -> {} [label=\"{:.0}ns\", color={}, penwidth={}];\n",
                node_id(stage, ctx),
                node_id(next.0, next.1),
                w,
                if is_hot { "red" } else { color(e) },
                if is_hot { 3 } else { 1 },
            ));
        }
    }
    s.push_str("}\n");
    s
}

/// Figure 3: decomposition chains (one subgraph per named plan).
pub fn decomposition_dot(plans: &[(&str, &Plan)]) -> String {
    let mut s = String::from("digraph decompositions {\n  rankdir=LR;\n  node [shape=box];\n");
    for (i, (name, plan)) in plans.iter().enumerate() {
        s.push_str(&format!("  subgraph cluster_{i} {{\n    label=\"{name}\";\n"));
        let mut prev = format!("p{i}_start");
        s.push_str(&format!("    {prev} [label=\"0\", shape=circle];\n"));
        for (j, (e, st)) in plan.steps().into_iter().enumerate() {
            let node = format!("p{i}_{j}");
            s.push_str(&format!(
                "    {node} [label=\"{} @{}\", color={}];\n",
                e.name(),
                st,
                color(e)
            ));
            s.push_str(&format!("    {prev} -> {node};\n"));
            prev = node;
        }
        let end = format!("p{i}_end");
        s.push_str(&format!("    {end} [label=\"done\", shape=circle];\n"));
        s.push_str(&format!("    {prev} -> {end};\n  }}\n"));
    }
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::SimCost;

    #[test]
    fn context_free_dot_has_all_edges() {
        let mut cost = SimCost::m1(1024);
        let dot = context_free_dot(&mut cost, 10);
        assert!(dot.starts_with("digraph"));
        assert_eq!(dot.matches("->").count(), 37); // positional catalog size
        for name in ["R2", "R4", "R8", "F8", "F16", "F32"] {
            assert!(dot.contains(name), "{name}");
        }
    }

    #[test]
    fn context_aware_dot_highlights_plan() {
        let mut cost = SimCost::m1(1024);
        let plan = Plan::parse("R4,R2,R4,R4,F8").unwrap();
        let dot = context_aware_dot(&mut cost, 10, Some(&plan));
        assert!(dot.matches("color=red, penwidth=3").count() == 5, "{}", dot);
    }

    #[test]
    fn decomposition_dot_one_cluster_per_plan() {
        let p1 = Plan::parse("R2,R2,R2,R2,R2,R2,R2,R2,R2,R2").unwrap();
        let p2 = Plan::parse("R4,R2,R4,R4,F8").unwrap();
        let dot = decomposition_dot(&[("pure radix-2", &p1), ("context-aware", &p2)]);
        assert_eq!(dot.matches("subgraph").count(), 2);
        assert!(dot.contains("pure radix-2"));
    }
}
