//! Graphviz DOT exporters for the paper's figures.
//!
//! * [`context_free_dot`] — Figure 1: nodes 0..L, one edge per
//!   (edge type, stage), colored by type, weighted by isolation cost.
//! * [`context_aware_dot`] — Figure 2: expanded nodes (s, t_prev); the
//!   optimal path is highlighted in red. [`expanded_dot`] is the
//!   surface-aware variant: on real-kind surfaces it renders the
//!   boundary-state nodes — the after-RU start node and the terminal
//!   "done" node every (L, t_prev) reaches via a purple RU edge
//!   weighted by that context's unpack cost.
//! * [`decomposition_dot`] — Figure 3: a set of plans as stage-interval
//!   chains for side-by-side comparison.

use crate::cost::{CostModel, PlanningSurface};
use crate::edge::{Context, EdgeType};
use crate::plan::Plan;

fn color(e: EdgeType) -> &'static str {
    match e {
        EdgeType::R2 => "blue",
        EdgeType::R4 => "orange",
        EdgeType::R8 => "red",
        EdgeType::F8 | EdgeType::F16 | EdgeType::F32 => "green",
        // the boundary edge of real-kind expanded graphs
        EdgeType::RU => "purple",
        // blocked-execution boundary edges (never drawn in-graph)
        EdgeType::Transpose | EdgeType::BlockTwiddle => "gray",
    }
}

/// Figure 1: the context-free computation graph for L stages.
pub fn context_free_dot<C: CostModel>(cost: &mut C, l: usize) -> String {
    let mut s = String::from("digraph contextfree {\n  rankdir=LR;\n  node [shape=circle];\n");
    for stage in 0..=l {
        s.push_str(&format!("  s{stage} [label=\"{stage}\"];\n"));
    }
    for stage in 0..l {
        for e in cost.available_edges() {
            let k = e.stages();
            if !super::edge_allowed(e, stage, l) {
                continue;
            }
            let w = cost.edge_ns(e, stage, Context::Start);
            s.push_str(&format!(
                "  s{stage} -> s{} [label=\"{} {:.0}ns\", color={}];\n",
                stage + k,
                e.name(),
                w,
                color(e)
            ));
        }
    }
    s.push_str("}\n");
    s
}

/// Figure 2: the context-aware expanded graph; `highlight` (if given) is
/// drawn in red with penwidth 3 (the paper highlights the optimal path).
pub fn context_aware_dot<C: CostModel>(cost: &mut C, l: usize, highlight: Option<&Plan>) -> String {
    expanded_dot(cost, l, PlanningSurface::forward(), highlight)
}

/// The expanded planning graph on an arbitrary surface. On real-kind
/// (boundary) surfaces the start node is the after-RU boundary state and
/// every terminal (L, t_prev) node reaches the boundary-done node via a
/// purple RU edge weighted by `unpack_ns` in that context — the expanded
/// graph with RU edges exports exactly as the search walks it.
pub fn expanded_dot<C: CostModel>(
    cost: &mut C,
    l: usize,
    surface: PlanningSurface,
    highlight: Option<&Plan>,
) -> String {
    let mut s =
        String::from("digraph contextaware {\n  rankdir=LR;\n  node [shape=box, fontsize=10];\n");
    let node_id = |stage: usize, ctx: Context| format!("n{}_{}", stage, ctx.index());
    let start_ctx = surface.start_context();
    // Highlighted transitions (stage, ctx, edge).
    let mut hot: std::collections::HashSet<(usize, usize, EdgeType)> = Default::default();
    if let Some(plan) = highlight {
        let mut ctx = start_ctx;
        for (e, st) in plan.steps() {
            hot.insert((st, ctx.index(), e));
            ctx = Context::After(e);
        }
    }
    // Reachable expansion from the start node. On boundary surfaces the
    // start node *is* a boundary state (the transform just crossed the
    // RU pass), labeled as such rather than as its catalog proxy.
    let start_label = if surface.has_boundary() { "(0, RU)" } else { "(0, start)" };
    let mut seen = std::collections::HashSet::new();
    let mut terminals: Vec<Context> = Vec::new();
    let mut frontier = vec![(0usize, start_ctx)];
    seen.insert((0, start_ctx.index()));
    s.push_str(&format!("  {} [label=\"{start_label}\"];\n", node_id(0, start_ctx)));
    while let Some((stage, ctx)) = frontier.pop() {
        for e in cost.available_edges() {
            let k = e.stages();
            if !super::edge_allowed(e, stage, l) {
                continue;
            }
            let w = cost.surface_edge_ns(e, stage, ctx, surface);
            let next = (stage + k, Context::After(e));
            if seen.insert((next.0, next.1.index())) {
                s.push_str(&format!(
                    "  {} [label=\"({}, {})\"];\n",
                    node_id(next.0, next.1),
                    next.0,
                    e.name()
                ));
                if next.0 < l {
                    frontier.push(next);
                } else if surface.has_boundary() {
                    terminals.push(next.1);
                }
            }
            let is_hot = hot.contains(&(stage, ctx.index(), e));
            s.push_str(&format!(
                "  {} -> {} [label=\"{:.0}ns\", color={}, penwidth={}];\n",
                node_id(stage, ctx),
                node_id(next.0, next.1),
                w,
                if is_hot { "red" } else { color(e) },
                if is_hot { 3 } else { 1 },
            ));
        }
    }
    if surface.has_boundary() {
        // The boundary-done terminal: every (L, t_prev) node crosses the
        // RU edge, priced in its own context (the terminal-RU expansion
        // the search trades against tail speed).
        s.push_str("  done [label=\"(done, RU)\", shape=doubleoctagon];\n");
        terminals.sort_by_key(|c| c.index());
        for ctx in terminals {
            let w = cost.surface_edge_ns(EdgeType::RU, l, ctx, surface);
            s.push_str(&format!(
                "  {} -> done [label=\"RU {:.0}ns\", color={}];\n",
                node_id(l, ctx),
                w,
                color(EdgeType::RU),
            ));
        }
    }
    s.push_str("}\n");
    s
}

/// Figure 3: decomposition chains (one subgraph per named plan).
pub fn decomposition_dot(plans: &[(&str, &Plan)]) -> String {
    let mut s = String::from("digraph decompositions {\n  rankdir=LR;\n  node [shape=box];\n");
    for (i, (name, plan)) in plans.iter().enumerate() {
        s.push_str(&format!("  subgraph cluster_{i} {{\n    label=\"{name}\";\n"));
        let mut prev = format!("p{i}_start");
        s.push_str(&format!("    {prev} [label=\"0\", shape=circle];\n"));
        for (j, (e, st)) in plan.steps().into_iter().enumerate() {
            let node = format!("p{i}_{j}");
            s.push_str(&format!(
                "    {node} [label=\"{} @{}\", color={}];\n",
                e.name(),
                st,
                color(e)
            ));
            s.push_str(&format!("    {prev} -> {node};\n"));
            prev = node;
        }
        let end = format!("p{i}_end");
        s.push_str(&format!("    {end} [label=\"done\", shape=circle];\n"));
        s.push_str(&format!("    {prev} -> {end};\n  }}\n"));
    }
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::SimCost;

    #[test]
    fn context_free_dot_has_all_edges() {
        let mut cost = SimCost::m1(1024);
        let dot = context_free_dot(&mut cost, 10);
        assert!(dot.starts_with("digraph"));
        assert_eq!(dot.matches("->").count(), 37); // positional catalog size
        for name in ["R2", "R4", "R8", "F8", "F16", "F32"] {
            assert!(dot.contains(name), "{name}");
        }
    }

    #[test]
    fn context_aware_dot_highlights_plan() {
        let mut cost = SimCost::m1(1024);
        let plan = Plan::parse("R4,R2,R4,R4,F8").unwrap();
        let dot = context_aware_dot(&mut cost, 10, Some(&plan));
        assert!(dot.matches("color=red, penwidth=3").count() == 5, "{}", dot);
    }

    #[test]
    fn boundary_surface_dot_renders_ru_edges_and_boundary_nodes() {
        use crate::cost::PlanningSurface;
        use crate::kind::TransformKind;
        let mut cost = SimCost::m1(512); // c2c half of a 1024-point real transform
        let surface = PlanningSurface::for_kind(TransformKind::RealForward);
        let dot = expanded_dot(&mut cost, 9, surface, None);
        // boundary start node + boundary-done terminal
        assert!(dot.contains("(0, RU)"), "{dot}");
        assert!(dot.contains("(done, RU)"), "{dot}");
        // every terminal context crosses a purple RU edge
        assert!(dot.matches("-> done").count() >= 4, "{dot}");
        assert!(dot.contains("color=purple"), "{dot}");
        // forward surfaces render no boundary machinery
        let fwd = expanded_dot(&mut cost, 9, PlanningSurface::forward(), None);
        assert!(!fwd.contains("RU"), "{fwd}");
    }

    #[test]
    fn decomposition_dot_one_cluster_per_plan() {
        let p1 = Plan::parse("R2,R2,R2,R2,R2,R2,R2,R2,R2,R2").unwrap();
        let p2 = Plan::parse("R4,R2,R4,R4,F8").unwrap();
        let dot = decomposition_dot(&[("pure radix-2", &p1), ("context-aware", &p2)]);
        assert_eq!(dot.matches("subgraph").count(), 2);
        assert!(dot.contains("pure radix-2"));
    }
}
