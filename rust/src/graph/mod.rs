//! The decomposition graphs (paper §2).
//!
//! * [`planning`] — [`PlanningGraph`], the first-class context-expanded
//!   graph (nodes = (stage, history ≤ k, boundary state), dense-indexed;
//!   edges include the real transforms' RU boundary pass) that every
//!   strategy in [`crate::planner`] walks, parameterized by a
//!   [`crate::cost::PlanningSurface`] (kind, batch class, context order).
//! * [`enumerate`] — all valid plans (paths 0 → L) for a machine's edge
//!   catalog; the paper's §2.5 decomposition counting (also the
//!   path-enumeration view behind [`PlanningGraph::paths`]).
//! * [`search`] — the historical shortest-path entry points (context-free
//!   Fig. 1, context-aware Fig. 2, higher-order k of §5.1), now thin
//!   wrappers over [`PlanningGraph`] walks on the forward surface.
//! * [`dot`] — Graphviz DOT exporters regenerating Figures 1 and 2
//!   (boundary-state nodes and RU edges included on real-kind surfaces).

pub mod dot;
pub mod enumerate;
pub mod planning;
pub mod search;

pub use enumerate::{count_plans, enumerate_plans};
pub use planning::PlanningGraph;
pub use search::{shortest_path_context_aware, shortest_path_context_free, SearchResult};

use crate::edge::EdgeType;

/// Positional validity of an edge in the graph for an L-stage FFT.
///
/// FFT-16 and FFT-32 blocks rely on the in-register transpose trick
/// (paper Table 1: "NEON 4x4 transpose"), which needs the B points
/// *contiguous* — i.e. the block must cover the final log2(B) stages.
/// Mid-path placements would need j-twiddle vector sets that blow the
/// register budget the blocks exist to exploit. FFT-8 groups gather like
/// a radix-8 butterfly and work at any stage (the paper's context-free
/// plan R4 -> F8 -> F32 uses a mid-path F8). This catalog also matches
/// the paper's §2.5 measurement budget (~30 context-free cells).
pub fn edge_allowed(edge: EdgeType, stage: usize, l: usize) -> bool {
    // Boundary passes (RU, TR, BT) are not decomposition steps: they
    // advance zero stages (an RU/TR/BT "edge" re-walks the data between
    // FFT passes), so admitting one here would let enumeration loop
    // forever at a fixed stage. The planning graph inserts RU
    // structurally on real-kind surfaces, and the four-step boundary
    // edges are priced by `plan_exec` outside the per-stage graph —
    // none of them is ever a positional choice.
    if edge.is_boundary() {
        return false;
    }
    if stage + edge.stages() > l {
        return false;
    }
    match edge {
        EdgeType::F16 | EdgeType::F32 => stage + edge.stages() == l,
        _ => true,
    }
}
