//! Enumeration of valid mixed-radix decompositions (paper §2.5) — the
//! path view of the planning graph ([`super::PlanningGraph::paths`] and
//! the exhaustive walk enumerate through here).
//!
//! A decomposition for L stages is an ordered edge sequence whose stage
//! advances sum to L, with F16/F32 restricted to the terminal position
//! (see [`super::edge_allowed`]). R2/R4/R8/F8 plans follow the recurrence
//! `T(l) = T(l-1) + T(l-2) + 2 T(l-3)` (585 at L = 10); terminal F16/F32
//! tails add T(6) + T(5) = 55, for 640 total. The paper (citing the 2015
//! thesis) reports 247 valid decompositions for L = 10 under the thesis'
//! smaller catalog; both counts are enumerated exactly by this module and
//! the discrepancy is documented in EXPERIMENTS.md.

use crate::edge::EdgeType;
use crate::plan::Plan;

/// All valid plans for `l` stages over the given edge catalog, in
/// lexicographic catalog order, honoring the positional rule of
/// [`super::edge_allowed`] (F16/F32 terminal-only). For L = 10 with the
/// full six-edge catalog this is 640 plans — small enough for exhaustive
/// ground-truth evaluation.
pub fn enumerate_plans(l: usize, edges: &[EdgeType]) -> Vec<Plan> {
    let mut out = Vec::new();
    let mut cur = Vec::new();
    fn rec(l: usize, stage: usize, edges: &[EdgeType], cur: &mut Vec<EdgeType>, out: &mut Vec<Plan>) {
        if stage == l {
            out.push(Plan::new(cur.clone()));
            return;
        }
        for &e in edges {
            if super::edge_allowed(e, stage, l) {
                cur.push(e);
                rec(l, stage + e.stages(), edges, cur, out);
                cur.pop();
            }
        }
    }
    rec(l, 0, edges, &mut cur, &mut out);
    out
}

/// Count of valid plans without materializing them (DP over stages,
/// honoring the positional rule).
pub fn count_plans(l: usize, edges: &[EdgeType]) -> u64 {
    // f[s] = number of plan prefixes reaching stage s
    let mut f = vec![0u64; l + 1];
    f[0] = 1;
    for s in 0..l {
        if f[s] == 0 {
            continue;
        }
        for &e in edges {
            if super::edge_allowed(e, s, l) {
                f[s + e.stages()] += f[s];
            }
        }
    }
    f[l]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::edge::ALL_EDGES;

    #[test]
    fn count_matches_enumeration() {
        for l in 0..=10 {
            let plans = enumerate_plans(l, &ALL_EDGES);
            assert_eq!(plans.len() as u64, count_plans(l, &ALL_EDGES), "l={l}");
        }
    }

    #[test]
    fn full_catalog_l10_is_640() {
        // R2/R4/R8/F8 at any stage + terminal-only F16/F32:
        // T(l) = T(l-1) + T(l-2) + 2 T(l-3) gives 585 radix+F8 plans,
        // plus T(6) + T(5) = 37 + 18 fused-16/32 tails.
        assert_eq!(count_plans(10, &ALL_EDGES), 640);
    }

    #[test]
    fn f16_f32_only_terminal() {
        for p in enumerate_plans(10, &ALL_EDGES) {
            for (e, s) in p.steps() {
                if matches!(e, EdgeType::F16 | EdgeType::F32) {
                    assert_eq!(s + e.stages(), 10, "{p}");
                }
            }
        }
    }

    #[test]
    fn radix_only_l10_is_tribonacci_274() {
        // Compositions of 10 into parts {1,2,3} = tribonacci(10) = 274 —
        // the classic mixed-radix count the 2015 thesis' 247 approximates
        // under its extra constraints.
        let radix = [EdgeType::R2, EdgeType::R4, EdgeType::R8];
        assert_eq!(count_plans(10, &radix), 274);
    }

    #[test]
    fn all_enumerated_plans_are_valid_and_unique() {
        let plans = enumerate_plans(8, &ALL_EDGES);
        let mut seen = std::collections::HashSet::new();
        for p in &plans {
            assert!(p.is_valid_for(8), "{p}");
            assert!(seen.insert(p.to_string()), "duplicate {p}");
        }
    }

    #[test]
    fn restricted_catalog_respected() {
        // Haswell: no F32.
        let edges: Vec<EdgeType> = ALL_EDGES.iter().copied().filter(|e| *e != EdgeType::F32).collect();
        let plans = enumerate_plans(10, &edges);
        assert!(plans.iter().all(|p| !p.edges().contains(&EdgeType::F32)));
        assert!(count_plans(10, &edges) < 846);
    }

    #[test]
    fn l0_has_exactly_the_empty_plan() {
        let plans = enumerate_plans(0, &ALL_EDGES);
        assert_eq!(plans.len(), 1);
        assert!(plans[0].is_empty());
    }
}
