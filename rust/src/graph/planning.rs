//! The first-class context-expanded planning graph (paper §2.3, Eq. 1–2)
//! — one graph object every strategy walks.
//!
//! The repo used to build this graph implicitly five times: the
//! context-free and context-aware searches, the FFTW-style DP, the beam
//! baseline, and the exhaustive evaluator each re-derived node expansion
//! and edge legality inline, and the real transforms' RU (split/unpack)
//! boundary pass was invisible to all of them — a real plan trading a
//! faster tail for a cheaper unpack could never be found, the same
//! optimal-substructure blind spot FFTW concedes in *Implementing FFTs
//! in Practice*. [`PlanningGraph`] makes the object explicit:
//!
//! * **Nodes** are `(stage, context-history ≤ k, boundary state)`.
//!   Histories are encoded densely as base-(|T|+1) integers (most
//!   recent edge in the low digit), so the whole node space is two flat
//!   arrays instead of the former `HashMap<(usize, Vec<EdgeType>)>`
//!   with its per-stage full-map scans and history clones — the node
//!   count is exactly the paper's `(L+1)·|T|^k` (77 at k=1, 539 at k=2
//!   for L=10, counting the start context).
//! * **Edges** carry [`EdgeType`] *including* the boundary passes:
//!   on a real-kind surface the graph has a terminal
//!   [`EdgeType::RU`] edge from every `(L, history)` node to the
//!   boundary-done state, weighted by `unpack_ns` *in that history's
//!   context* — nearly free after a fused register block, a memory
//!   round trip after a strided radix pass (`Machine::unpack_ns`).
//!   Walks on a boundary surface also *start* in the after-RU context
//!   ([`PlanningSurface::start_context`]): the steady-state loop of a
//!   real transform is `[RU, c2c…]` / `[c2c…, RU]`, so the first c2c
//!   edge always runs after the boundary pass. Together these make the
//!   k = 1 context-aware walk **exactly optimal** under the true
//!   steady-state [`PlanningSurface::plan_ns`] — not an approximation
//!   whose RU cost is bolted on after the argmin.
//! * **Weights** come from a [`CostModel`] queried through a
//!   [`PlanningSurface`] — kind, batch class, and context order are
//!   graph-level parameters, not adapter wrappers.
//!
//! Every strategy in [`crate::planner`] is a walk over this one graph:
//! [`PlanningGraph::shortest_path`] (CA-k, the paper's contribution),
//! [`PlanningGraph::isolation_shortest_path`] (CF),
//! [`PlanningGraph::backward_dp`] (FFTW-style DP),
//! [`PlanningGraph::beam`] (SPIRAL-style), and
//! [`PlanningGraph::exhaustive`] (ground truth over
//! [`PlanningGraph::paths`]).

use std::collections::HashSet;

use crate::cost::{CostModel, PlanningSurface};
use crate::edge::{Context, EdgeType};
use crate::plan::Plan;

use super::search::SearchResult;

/// The context-expanded planning graph for one (L, surface) pair.
#[derive(Debug, Clone)]
pub struct PlanningGraph {
    l: usize,
    surface: PlanningSurface,
    /// Decomposition-edge catalog, sorted canonically (never contains
    /// RU — the boundary edge is structural, not a catalog entry).
    edges: Vec<EdgeType>,
    /// History digit base: |catalog| + 1 (digit 0 = "no edge yet").
    base: usize,
    /// Number of history codes: base^k.
    codes: usize,
    /// base^(k-1) — the modulus that drops the oldest digit on push.
    keep: usize,
}

impl PlanningGraph {
    /// Build the graph for `l` decomposition stages over `catalog`.
    /// The catalog is sorted and deduplicated so walk order (and thus
    /// tie-breaking) is canonical regardless of provider order. An
    /// ISA-pinned surface first masks edges that ISA's register file
    /// cannot hold ([`crate::isa::Isa::supports`]: no F32 on AVX2's 16
    /// registers — paper Table 1's "impossible on AVX2" as graph
    /// structure, so no walk can ever schedule the edge).
    pub fn new(l: usize, surface: PlanningSurface, catalog: Vec<EdgeType>) -> PlanningGraph {
        assert!(surface.k >= 1, "context order must be >= 1");
        let mut edges = catalog;
        if let Some(isa) = surface.isa {
            edges.retain(|&e| isa.supports(e));
        }
        edges.sort();
        edges.dedup();
        assert!(
            !edges.contains(&EdgeType::RU),
            "RU is the boundary edge, not a catalog entry"
        );
        let base = edges.len() + 1;
        let codes = base.checked_pow(surface.k as u32).expect("history space overflow");
        assert!(
            (l + 1).saturating_mul(codes) <= 1 << 26,
            "expanded node space too large (l={l}, k={})",
            surface.k
        );
        let keep = base.pow(surface.k as u32 - 1);
        PlanningGraph { l, surface, edges, base, codes, keep }
    }

    /// Graph for a cost model's size and catalog. For real-kind surfaces
    /// the model is the *half-size* c2c surface (the caller passes it
    /// that way, exactly as the service plans), so `l` is the c2c level
    /// count — the RU boundary edge sits one past it.
    pub fn for_cost<C: CostModel + ?Sized>(cost: &mut C, surface: PlanningSurface) -> PlanningGraph {
        PlanningGraph::new(crate::fft::log2i(cost.n()), surface, cost.available_edges())
    }

    pub fn l(&self) -> usize {
        self.l
    }

    pub fn surface(&self) -> PlanningSurface {
        self.surface
    }

    /// The decomposition-edge catalog (sorted, RU excluded).
    pub fn catalog(&self) -> &[EdgeType] {
        &self.edges
    }

    /// Expanded node count: `(l+1) · (|catalog|+1)^k` stage/history
    /// nodes, plus the boundary-done terminal on real-kind surfaces.
    pub fn node_count(&self) -> usize {
        (self.l + 1) * self.codes + usize::from(self.surface.has_boundary())
    }

    /// Slide `edge` (by catalog position) into a history code: the
    /// oldest digit falls off, the new edge enters the low digit.
    fn push_code(&self, code: usize, edge_pos: usize) -> usize {
        (code % self.keep) * self.base + edge_pos + 1
    }

    /// Context a node's history implies: the most recent edge, or the
    /// surface's start context for the empty history (node (0, ·)).
    fn context_of(&self, code: usize) -> Context {
        match code % self.base {
            0 => self.surface.start_context(),
            d => Context::After(self.edges[d - 1]),
        }
    }

    /// Decode a history code to edges, oldest first (tie-break order —
    /// matches the former `Vec<EdgeType>` key comparison).
    fn decode_hist(&self, code: usize) -> Vec<EdgeType> {
        let mut digits = Vec::with_capacity(self.surface.k);
        let mut c = code;
        for _ in 0..self.surface.k {
            digits.push(c % self.base);
            c /= self.base;
        }
        digits
            .into_iter()
            .rev()
            .filter(|&d| d != 0)
            .map(|d| self.edges[d - 1])
            .collect()
    }

    /// All valid plans (paths 0 → L honoring positional legality) — the
    /// path-enumeration view ([`super::enumerate`]).
    pub fn paths(&self) -> Vec<Plan> {
        super::enumerate::enumerate_plans(self.l, &self.edges)
    }

    /// True steady-state per-transform time of `plan` on this graph's
    /// surface (delegates to [`PlanningSurface::plan_ns`]; boundary
    /// surfaces include the RU edge in the last edge's context).
    pub fn plan_true_ns<C: CostModel + ?Sized>(&self, cost: &mut C, plan: &Plan) -> f64 {
        self.surface.plan_ns(cost, plan)
    }

    /// Believed cost of `plan` under the context-aware walk's objective
    /// (delegates to [`PlanningSurface::plan_objective_ns`]).
    pub fn plan_objective_ns<C: CostModel + ?Sized>(&self, cost: &mut C, plan: &Plan) -> f64 {
        self.surface.plan_objective_ns(cost, plan)
    }

    /// The context-aware shortest path (paper Eq. 1–2; §5.1 for k > 1):
    /// forward relaxation over the dense node arrays in stage order (the
    /// graph is a DAG — "Dijkstra" names the idea, no priority queue
    /// needed). On a boundary surface the walk starts in the after-RU
    /// context and the terminal choice includes each candidate tail's RU
    /// edge, so the result is the exact optimum of
    /// [`PlanningSurface::plan_ns`] at k = 1 — the search itself trades
    /// a faster tail against a cheaper unpack.
    pub fn shortest_path<C: CostModel + ?Sized>(&self, cost: &mut C) -> SearchResult {
        let codes = self.codes;
        let mut dist = vec![f64::INFINITY; (self.l + 1) * codes];
        let mut pred: Vec<Option<(usize, EdgeType)>> = vec![None; (self.l + 1) * codes];
        let mut cell_set: HashSet<(EdgeType, usize, Context)> = HashSet::new();
        dist[0] = 0.0;
        for s in 0..self.l {
            for code in 0..codes {
                let d = dist[s * codes + code];
                if !d.is_finite() {
                    continue;
                }
                let ctx = self.context_of(code);
                for (pos, &e) in self.edges.iter().enumerate() {
                    if !super::edge_allowed(e, s, self.l) {
                        continue;
                    }
                    let w = self.surface.edge_ns(cost, e, s, ctx);
                    cell_set.insert((e, s, ctx));
                    let ni = (s + e.stages()) * codes + self.push_code(code, pos);
                    if d + w < dist[ni] {
                        dist[ni] = d + w;
                        pred[ni] = Some((s * codes + code, e));
                    }
                }
            }
        }
        // Terminal choice: min (cost, history) — histories compared
        // oldest-first so ties resolve canonically. Boundary surfaces
        // add each candidate's RU edge in its own tail context here,
        // *inside* the argmin.
        let mut best: Option<(f64, usize, Vec<EdgeType>)> = None;
        for code in 0..codes {
            let d = dist[self.l * codes + code];
            if !d.is_finite() {
                continue;
            }
            let total = if self.surface.has_boundary() {
                let ctx = self.context_of(code);
                cell_set.insert((EdgeType::RU, self.l, ctx));
                d + self.surface.edge_ns(cost, EdgeType::RU, self.l, ctx)
            } else {
                d
            };
            let hist = self.decode_hist(code);
            let better = match &best {
                None => true,
                Some((bt, _, bh)) => {
                    total < *bt || (total == *bt && hist < *bh)
                }
            };
            if better {
                best = Some((total, code, hist));
            }
        }
        let (cost_ns, best_code, _) = best.expect("no path to L");
        let mut rev = Vec::new();
        let mut node = self.l * codes + best_code;
        while let Some((prev, e)) = pred[node] {
            rev.push(e);
            node = prev;
        }
        rev.reverse();
        SearchResult { plan: Plan::new(rev), cost_ns, cells: cell_set.len() }
    }

    /// The context-free shortest path (paper §2.1): stage nodes only,
    /// isolation weights ([`Context::Start`]). On a boundary surface the
    /// RU edge is priced in isolation too — a *constant* added to every
    /// path, so the argmin is exactly as RU-blind as the historical
    /// search (which is the point of keeping this baseline).
    pub fn isolation_shortest_path<C: CostModel + ?Sized>(&self, cost: &mut C) -> SearchResult {
        let mut dist = vec![f64::INFINITY; self.l + 1];
        let mut pred: Vec<Option<(usize, EdgeType)>> = vec![None; self.l + 1];
        let mut cells = 0;
        dist[0] = 0.0;
        for s in 0..self.l {
            if dist[s].is_infinite() {
                continue;
            }
            for &e in &self.edges {
                if !super::edge_allowed(e, s, self.l) {
                    continue;
                }
                let w = self.surface.edge_ns(cost, e, s, Context::Start);
                cells += 1;
                let k = e.stages();
                if dist[s] + w < dist[s + k] {
                    dist[s + k] = dist[s] + w;
                    pred[s + k] = Some((s, e));
                }
            }
        }
        let mut cost_ns = dist[self.l];
        if self.surface.has_boundary() {
            cost_ns += self.surface.edge_ns(cost, EdgeType::RU, self.l, Context::Start);
            cells += 1;
        }
        let mut rev = Vec::new();
        let mut s = self.l;
        while s > 0 {
            let (ps, e) = pred[s].expect("unreachable node");
            rev.push(e);
            s = ps;
        }
        rev.reverse();
        SearchResult { plan: Plan::new(rev), cost_ns, cells }
    }

    /// FFTW-style dynamic programming (paper §1/§5.1): best sub-plan per
    /// stage suffix under isolation weights — the optimal-substructure
    /// assumption. On a DAG this reproduces the context-free argmin (the
    /// *assumption*, not the algorithm, is what context-awareness
    /// fixes); the boundary RU edge is the isolation-priced constant
    /// base case, keeping the DP equally RU-blind.
    pub fn backward_dp<C: CostModel + ?Sized>(&self, cost: &mut C) -> SearchResult {
        let mut best = vec![f64::INFINITY; self.l + 1];
        let mut choice: Vec<Option<EdgeType>> = vec![None; self.l + 1];
        let mut cells = 0;
        best[self.l] = 0.0;
        if self.surface.has_boundary() {
            best[self.l] = self.surface.edge_ns(cost, EdgeType::RU, self.l, Context::Start);
            cells += 1;
        }
        for s in (0..self.l).rev() {
            for &e in &self.edges {
                if !super::edge_allowed(e, s, self.l) {
                    continue;
                }
                let w = self.surface.edge_ns(cost, e, s, Context::Start);
                cells += 1;
                if w + best[s + e.stages()] < best[s] {
                    best[s] = w + best[s + e.stages()];
                    choice[s] = Some(e);
                }
            }
        }
        let mut plan = Vec::new();
        let mut s = 0;
        while s < self.l {
            let e = choice[s].expect("unreachable");
            plan.push(e);
            s += e.stages();
        }
        SearchResult { plan: Plan::new(plan), cost_ns: best[0], cells }
    }

    /// SPIRAL-style beam search (paper §5.1): extend prefixes under true
    /// contextual weights, keep the `width` cheapest per stage. Boundary
    /// surfaces start in the after-RU context and add each terminal
    /// candidate's RU edge before the final choice — beam is RU-aware,
    /// but a narrow beam can still prune the global optimum (the
    /// position-dependence problem the paper describes).
    pub fn beam<C: CostModel + ?Sized>(&self, cost: &mut C, width: usize) -> SearchResult {
        assert!(width >= 1);
        let mut cell_set: HashSet<(EdgeType, usize, Context)> = HashSet::new();
        let mut frontiers: Vec<Vec<(f64, Vec<EdgeType>, Context)>> = vec![Vec::new(); self.l + 1];
        frontiers[0].push((0.0, Vec::new(), self.surface.start_context()));
        for s in 0..self.l {
            frontiers[s].sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            frontiers[s].truncate(width);
            let snapshot = frontiers[s].clone();
            for (c, prefix, ctx) in snapshot {
                for &e in &self.edges {
                    if !super::edge_allowed(e, s, self.l) {
                        continue;
                    }
                    cell_set.insert((e, s, ctx));
                    let w = self.surface.edge_ns(cost, e, s, ctx);
                    let mut np = prefix.clone();
                    np.push(e);
                    frontiers[s + e.stages()].push((c + w, np, Context::After(e)));
                }
            }
        }
        let mut best: Option<(f64, Vec<EdgeType>)> = None;
        for (c, plan, ctx) in &frontiers[self.l] {
            let total = if self.surface.has_boundary() {
                cell_set.insert((EdgeType::RU, self.l, *ctx));
                c + self.surface.edge_ns(cost, EdgeType::RU, self.l, *ctx)
            } else {
                *c
            };
            if best.as_ref().is_none_or(|(bt, _)| total < *bt) {
                best = Some((total, plan.clone()));
            }
        }
        let (cost_ns, plan) = best.expect("no complete plan");
        SearchResult { plan: Plan::new(plan), cost_ns, cells: cell_set.len() }
    }

    /// Exhaustive ground truth: evaluate the true steady-state time of
    /// every path ([`PlanningSurface::plan_ns`] — c2c kinds loop
    /// back-to-back, boundary surfaces cycle through the RU edge).
    pub fn exhaustive<C: CostModel + ?Sized>(&self, cost: &mut C) -> SearchResult {
        let mut cell_set: HashSet<(EdgeType, usize, Context)> = HashSet::new();
        let mut best: Option<(Plan, f64)> = None;
        for p in self.paths() {
            if p.is_empty() {
                continue;
            }
            let mut ctx = if self.surface.has_boundary() {
                self.surface.start_context()
            } else {
                Context::After(*p.edges().last().unwrap())
            };
            let mut t = 0.0;
            for (e, s) in p.steps() {
                cell_set.insert((e, s, ctx));
                t += self.surface.edge_ns(cost, e, s, ctx);
                ctx = Context::After(e);
            }
            if self.surface.has_boundary() {
                cell_set.insert((EdgeType::RU, self.l, ctx));
                t += self.surface.edge_ns(cost, EdgeType::RU, self.l, ctx);
            }
            if best.as_ref().is_none_or(|(_, bt)| t < *bt) {
                best = Some((p, t));
            }
        }
        let (plan, cost_ns) = best.expect("no plans");
        SearchResult { plan, cost_ns, cells: cell_set.len() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::SimCost;
    use crate::kind::TransformKind;

    fn m1_graph(n: usize, surface: PlanningSurface) -> PlanningGraph {
        PlanningGraph::for_cost(&mut SimCost::m1(n), surface)
    }

    #[test]
    fn node_counts_match_the_paper() {
        // (L+1)·|T|^k with |T| = 7 contexts (start + 6 catalog edges).
        let g1 = m1_graph(1024, PlanningSurface::forward());
        assert_eq!(g1.node_count(), 77);
        let g2 = m1_graph(1024, PlanningSurface::forward().with_k(2));
        assert_eq!(g2.node_count(), 539);
        // boundary surfaces add the done-terminal
        let gr = m1_graph(512, PlanningSurface::for_kind(TransformKind::RealForward));
        assert_eq!(gr.node_count(), 10 * 7 + 1);
    }

    #[test]
    fn history_codes_roundtrip() {
        let g = m1_graph(1024, PlanningSurface::forward().with_k(2));
        // push R4 (pos 1) then F8 (pos 3) onto the empty history
        let c1 = g.push_code(0, 1);
        let c2 = g.push_code(c1, 3);
        assert_eq!(g.decode_hist(c2), vec![EdgeType::R4, EdgeType::F8]);
        assert_eq!(g.context_of(c2), Context::After(EdgeType::F8));
        // a third push slides the oldest out
        let c3 = g.push_code(c2, 0);
        assert_eq!(g.decode_hist(c3), vec![EdgeType::F8, EdgeType::R2]);
        assert_eq!(g.context_of(0), Context::Start);
    }

    #[test]
    fn shortest_path_discovers_the_paper_plan() {
        let mut cost = SimCost::m1(1024);
        let g = PlanningGraph::for_cost(&mut cost, PlanningSurface::forward());
        let res = g.shortest_path(&mut cost);
        assert_eq!(res.plan, Plan::parse("R4,R2,R4,R4,F8").unwrap());
        assert!(res.cells > 100 && res.cells < 300);
    }

    #[test]
    fn k2_matches_k1_for_first_order_models() {
        let mut cost = SimCost::m1(256);
        let g1 = PlanningGraph::for_cost(&mut cost, PlanningSurface::forward());
        let g2 = PlanningGraph::for_cost(&mut cost, PlanningSurface::forward().with_k(2));
        let r1 = g1.shortest_path(&mut cost);
        let r2 = g2.shortest_path(&mut cost);
        assert_eq!(r1.plan, r2.plan);
        assert!((r1.cost_ns - r2.cost_ns).abs() < 1e-6);
    }

    #[test]
    fn boundary_shortest_path_is_exactly_the_plan_ns_optimum() {
        // On a boundary surface the k=1 walk optimizes the true
        // steady-state loop — it must match exhaustive exactly.
        for lh in [5usize, 8, 9] {
            let h = 1 << lh;
            let mut cost = SimCost::m1(h);
            let surface = PlanningSurface::for_kind(TransformKind::RealForward);
            let g = PlanningGraph::for_cost(&mut cost, surface);
            let sp = g.shortest_path(&mut cost);
            let ex = g.exhaustive(&mut cost);
            assert!((sp.cost_ns - ex.cost_ns).abs() < 1e-6, "h={h}");
            assert!((g.plan_true_ns(&mut cost, &sp.plan) - sp.cost_ns).abs() < 1e-6);
        }
    }

    #[test]
    fn boundary_searches_count_ru_cells() {
        let mut cost = SimCost::m1(256);
        let fwd = PlanningGraph::for_cost(&mut cost, PlanningSurface::forward());
        let real = PlanningGraph::for_cost(
            &mut cost,
            PlanningSurface::for_kind(TransformKind::RealForward),
        );
        let f = fwd.isolation_shortest_path(&mut cost);
        let r = real.isolation_shortest_path(&mut cost);
        // same relaxations + the one isolation-priced RU query
        assert_eq!(r.cells, f.cells + 1);
        assert!(r.cost_ns > f.cost_ns);
        assert_eq!(r.plan, f.plan, "isolation RU is a constant: argmin unchanged");
    }

    #[test]
    fn dp_reproduces_the_isolation_argmin() {
        for surface in [
            PlanningSurface::forward(),
            PlanningSurface::for_kind(TransformKind::RealForward),
        ] {
            let n = if surface.has_boundary() { 512 } else { 1024 };
            let mut cost = SimCost::m1(n);
            let g = PlanningGraph::for_cost(&mut cost, surface);
            let dp = g.backward_dp(&mut cost);
            let cf = g.isolation_shortest_path(&mut cost);
            assert!((dp.cost_ns - cf.cost_ns).abs() < 1e-6);
        }
    }

    #[test]
    fn wide_beam_recovers_the_boundary_optimum() {
        let mut cost = SimCost::m1(256);
        let g = PlanningGraph::for_cost(
            &mut cost,
            PlanningSurface::for_kind(TransformKind::RealForward),
        );
        let beam = g.beam(&mut cost, 4096);
        let ex = g.exhaustive(&mut cost);
        assert!((beam.cost_ns - ex.cost_ns).abs() < 1e-6);
    }

    #[test]
    fn batched_surface_walks_use_the_amortized_weights() {
        let mut cost = SimCost::m1(1024);
        let g0 = PlanningGraph::for_cost(&mut cost, PlanningSurface::forward());
        let g16 = PlanningGraph::for_cost(&mut cost, PlanningSurface::forward().with_batch(16));
        let p0 = g0.shortest_path(&mut cost);
        let p16 = g16.shortest_path(&mut cost);
        // amortized per-transform weights are cheaper across the board
        assert!(p16.cost_ns < p0.cost_ns);
    }

    #[test]
    fn catalog_is_canonicalized() {
        let g = PlanningGraph::new(
            8,
            PlanningSurface::forward(),
            vec![EdgeType::F8, EdgeType::R2, EdgeType::R2, EdgeType::R4],
        );
        assert_eq!(g.catalog(), &[EdgeType::R2, EdgeType::R4, EdgeType::F8]);
    }

    #[test]
    #[should_panic(expected = "boundary edge")]
    fn ru_is_rejected_from_the_catalog() {
        PlanningGraph::new(4, PlanningSurface::forward(), vec![EdgeType::R2, EdgeType::RU]);
    }

    #[test]
    fn avx2_surface_masks_f32_from_the_catalog() {
        use crate::isa::Isa;
        let full: Vec<EdgeType> = crate::edge::ALL_EDGES
            .iter()
            .copied()
            .filter(|e| *e != EdgeType::RU)
            .collect();
        // AVX2's 16-register file cannot hold FFT-32: the edge is graph
        // structure, absent before any walk runs.
        let avx2 = PlanningGraph::new(
            10,
            PlanningSurface::forward().with_isa(Isa::Avx2),
            full.clone(),
        );
        assert!(!avx2.catalog().contains(&EdgeType::F32));
        assert_eq!(avx2.catalog().len(), full.len() - 1);
        // every other backend — and the unpinned surface — keeps it
        for isa in [Isa::Scalar, Isa::Portable, Isa::Neon] {
            let g = PlanningGraph::new(10, PlanningSurface::forward().with_isa(isa), full.clone());
            assert!(g.catalog().contains(&EdgeType::F32), "{isa}");
        }
        let unpinned = PlanningGraph::new(10, PlanningSurface::forward(), full.clone());
        assert!(unpinned.catalog().contains(&EdgeType::F32));
        // node space shrinks with the catalog: base 6, not 7
        assert_eq!(avx2.node_count(), 11 * 6);
    }
}
