//! Shortest-path searches over the decomposition graphs — thin wrappers
//! around [`PlanningGraph`](super::PlanningGraph) walks on the default
//! (unbatched forward) surface.
//!
//! Both graphs are DAGs (edges only advance the stage counter), so
//! Dijkstra reduces to a forward relaxation in topological (stage) order —
//! we keep the paper's "Dijkstra" name for the algorithmic idea while
//! exploiting the DAG structure (identical result, no priority queue).
//!
//! * [`shortest_path_context_free`] — nodes {0..L} (paper §2.1, Fig. 1);
//!   weights are *isolation* measurements (`Context::Start`).
//! * [`shortest_path_context_aware`] — nodes {(s, t_prev)} (paper §2.3,
//!   Fig. 2, Eq. 1-2); weights conditional on the predecessor type.
//! * [`shortest_path_context_aware_k`] — §5.1's higher-order extension:
//!   context = last k edge types; node space (L+1) x |T|^k.
//!
//! Kind- or batch-specific walks (including the real transforms' RU
//! boundary edge) construct a [`PlanningGraph`](super::PlanningGraph)
//! with the wanted [`PlanningSurface`](crate::cost::PlanningSurface)
//! directly — the wrappers here exist for the historical call sites and
//! the paper-reproduction tests.

use crate::cost::{CostModel, PlanningSurface};
use crate::plan::Plan;

use super::planning::PlanningGraph;

/// Result of a search: the plan, its predicted cost under the search's own
/// weights, and how many weight cells were queried.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub plan: Plan,
    /// Path cost under the weights the search used (ns). For the
    /// context-free search this is a *prediction* that the true
    /// (contextual) execution time will generally exceed — that gap is
    /// the paper's point.
    pub cost_ns: f64,
    /// Distinct weight cells queried (paper §2.5 measurement budget).
    pub cells: usize,
}

/// Context-free shortest path: weights w(edge, stage) measured in
/// isolation, independent of predecessor (paper §2.1).
pub fn shortest_path_context_free<C: CostModel>(cost: &mut C, l: usize) -> SearchResult {
    PlanningGraph::new(l, PlanningSurface::forward(), cost.available_edges())
        .isolation_shortest_path(cost)
}

/// Context-aware shortest path over the expanded node space
/// {(stage, t_prev)} (paper Eq. 1); start node (0, start).
pub fn shortest_path_context_aware<C: CostModel>(cost: &mut C, l: usize) -> SearchResult {
    shortest_path_context_aware_k(cost, l, 1)
}

/// Higher-order context-aware search: context = last `k` edge types
/// (paper §5.1). With the first-order cost models in this crate, k > 1
/// explores a larger node space but reproduces the k = 1 optimum; the
/// interface exists for higher-order cost models (and measures the node
/// growth the paper quotes: 77 nodes at k=1, 539 at k=2 for L=10).
pub fn shortest_path_context_aware_k<C: CostModel>(cost: &mut C, l: usize, k: usize) -> SearchResult {
    PlanningGraph::new(l, PlanningSurface::forward().with_k(k), cost.available_edges())
        .shortest_path(cost)
}

/// Number of nodes in the k-order expanded graph for L stages and |T|
/// contexts (paper §2.3 / §5.1: 77 for k=1, 539 for k=2 at L=10).
pub fn expanded_node_count(l: usize, num_contexts: usize, k: usize) -> usize {
    (l + 1) * num_contexts.pow(k as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::{CostModel, SimCost};
    use crate::edge::{Context, EdgeType};
    use crate::graph::enumerate::enumerate_plans;

    #[test]
    fn context_free_beats_or_equals_every_plan_under_its_weights() {
        let mut cost = SimCost::m1(256);
        let res = shortest_path_context_free(&mut cost, 8);
        assert!(res.plan.is_valid_for(8));
        // isolation-weight sum of every enumerated plan >= search result
        for p in enumerate_plans(8, &cost.available_edges()) {
            let sum: f64 = p
                .steps()
                .into_iter()
                .map(|(e, s)| cost.edge_ns(e, s, Context::Start))
                .sum();
            assert!(sum + 1e-6 >= res.cost_ns, "{p}: {sum} < {}", res.cost_ns);
        }
    }

    #[test]
    fn context_aware_beats_or_equals_every_plan_under_true_weights() {
        let mut cost = SimCost::m1(256);
        let res = shortest_path_context_aware(&mut cost, 8);
        assert!(res.plan.is_valid_for(8));
        for p in enumerate_plans(8, &cost.available_edges()) {
            // from-start contextual sum (the search's objective)
            let mut ctx = Context::Start;
            let mut sum = 0.0;
            for (e, s) in p.steps() {
                sum += cost.edge_ns(e, s, ctx);
                ctx = Context::After(e);
            }
            assert!(sum + 1e-6 >= res.cost_ns, "{p}");
        }
    }

    #[test]
    fn context_aware_never_worse_than_context_free_on_true_weights() {
        let mut cost = SimCost::m1(1024);
        let cf = shortest_path_context_free(&mut cost, 10);
        let ca = shortest_path_context_aware(&mut cost, 10);
        // Evaluate both on true contextual timing.
        let t_cf = cost.plan_ns(&cf.plan);
        let t_ca = cost.plan_ns(&ca.plan);
        assert!(t_ca <= t_cf + 1e-6, "ca {t_ca} vs cf {t_cf}");
    }

    #[test]
    fn k2_matches_k1_for_first_order_models() {
        let mut cost = SimCost::m1(256);
        let k1 = shortest_path_context_aware_k(&mut cost, 8, 1);
        let k2 = shortest_path_context_aware_k(&mut cost, 8, 2);
        assert_eq!(k1.plan, k2.plan);
        assert!((k1.cost_ns - k2.cost_ns).abs() < 1e-6);
    }

    #[test]
    fn node_counts_match_paper() {
        assert_eq!(expanded_node_count(10, 7, 1), 77);
        assert_eq!(expanded_node_count(10, 7, 2), 539);
    }

    #[test]
    fn measurement_budget_matches_paper_scale() {
        // §2.5: ~30 context-free vs ~180 context-aware measurements.
        let mut cost = SimCost::m1(1024);
        let cf = shortest_path_context_free(&mut cost, 10);
        assert_eq!(cf.cells, 37); // R2:10 R4:9 R8:8 F8:8 F16@6 F32@5 (~30 in the paper)
        let ca = shortest_path_context_aware(&mut cost, 10);
        assert!(ca.cells > 100 && ca.cells < 300, "cells = {}", ca.cells);
    }

    #[test]
    fn haswell_search_never_uses_f32() {
        let mut cost = SimCost::haswell(1024);
        let cf = shortest_path_context_free(&mut cost, 10);
        let ca = shortest_path_context_aware(&mut cost, 10);
        for p in [&cf.plan, &ca.plan] {
            assert!(!p.edges().contains(&EdgeType::F32), "{p}");
        }
    }
}
