//! The edge catalog of the decomposition graph (paper Table 1).
//!
//! | Edge | Stages | NEON regs | Instruction advantage                    |
//! |------|--------|-----------|------------------------------------------|
//! | R2   | 1      | 0         | simplest; best for large strides         |
//! | R4   | 2      | 0         | W_4^1 = -j: swap+negate (free)           |
//! | R8   | 3      | 0         | W_8^{1,3}: multiply by 1/sqrt(2) only    |
//! | F8   | 3      | 4         | in-register; zero memory traffic         |
//! | F16  | 4      | 8         | in-register; NEON 4x4 transpose          |
//! | F32  | 5      | 16        | in-register; novel (needs 32 registers)  |

use std::fmt;

/// One edge type of the decomposition graph: a radix pass or a fused
/// register block (paper Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum EdgeType {
    /// Radix-2 pass: 1 stage, memory round trip per pass.
    R2,
    /// Radix-4 pass: 2 stages; exploits W_4^1 = -j (swap+negate).
    R4,
    /// Radix-8 pass: 3 stages; exploits W_8^{1,3} (scale by 1/sqrt(2)).
    R8,
    /// Fused FFT-8 block: 3 stages in 4 vector registers.
    F8,
    /// Fused FFT-16 block: 4 stages in 8 vector registers.
    F16,
    /// Fused FFT-32 block: 5 stages in 16 vector registers (novel on NEON;
    /// impossible on AVX2's 16-register file).
    F32,
    /// Real-transform split/unpack pass (R2C unpack / C2R spectrum
    /// pack): one symmetric walk over the full buffer with a twiddle
    /// multiply per conjugate pair. NOT part of the decomposition-graph
    /// catalog ([`ALL_EDGES`]) — it advances no DIF stages and never
    /// appears inside a [`crate::plan::Plan`]. It *is* a real edge of
    /// the expanded planning graph on real-kind surfaces: the boundary
    /// edge from every terminal (L, t_prev) node, weighted by
    /// `unpack_ns` in that context (nearly free after a fused register
    /// block, a full memory round trip after a strided radix pass) —
    /// see [`crate::graph::PlanningGraph`]. At execution time it is a
    /// first-class `CompiledStep` that shows up in traces and gets an
    /// `EdgeSample`.
    RU,
    /// Tiled matrix transpose of the four-step (blocked) decomposition:
    /// the strided walk that moves a p x q block matrix between
    /// column-major and row-major order (the column-tile gather/scatter
    /// and the final reorder to natural output order). Like [`EdgeType::RU`]
    /// it advances no DIF stages and never appears inside a
    /// [`crate::plan::Plan`]; it is the *memory-tier* boundary edge of
    /// blocked execution, priced by `CostModel::transpose_ns` (the way
    /// `marshal_ns` prices the serving-path panel transpose) and emitted
    /// as a first-class `EdgeSample` by traced blocked runs.
    Transpose,
    /// The inter-block twiddle multiply of the four-step decomposition:
    /// one streaming pass over the whole buffer applying W_n^{j2·k1}
    /// between the column and row sub-FFTs. A zero-stage boundary edge
    /// like [`EdgeType::RU`] / [`EdgeType::Transpose`]; priced by
    /// `CostModel::block_twiddle_ns` and sampled in traced blocked runs.
    BlockTwiddle,
}

/// All *decomposition-graph* edge types in catalog order (matches `T` in
/// paper Eq. 1, minus the synthetic `start` context). [`EdgeType::RU`]
/// is deliberately excluded: it is the boundary edge of real-kind
/// expanded graphs, not a stage-advancing catalog entry.
pub const ALL_EDGES: [EdgeType; 6] = [
    EdgeType::R2,
    EdgeType::R4,
    EdgeType::R8,
    EdgeType::F8,
    EdgeType::F16,
    EdgeType::F32,
];

impl EdgeType {
    /// DIF stage advance of this edge (k in "edge (s, s+k)"). The real
    /// split/unpack pass advances none — it is a boundary pass outside
    /// the decomposition.
    pub fn stages(self) -> usize {
        match self {
            EdgeType::R2 => 1,
            EdgeType::R4 => 2,
            EdgeType::R8 | EdgeType::F8 => 3,
            EdgeType::F16 => 4,
            EdgeType::F32 => 5,
            EdgeType::RU | EdgeType::Transpose | EdgeType::BlockTwiddle => 0,
        }
    }

    /// Whether this edge is a fused register block.
    pub fn is_fused(self) -> bool {
        matches!(self, EdgeType::F8 | EdgeType::F16 | EdgeType::F32)
    }

    /// Whether this edge is a boundary pass (zero stage advance, outside
    /// the decomposition-graph catalog, never inside a plan): the real
    /// split/unpack walk or one of the blocked-execution data-movement
    /// edges.
    pub fn is_boundary(self) -> bool {
        matches!(self, EdgeType::RU | EdgeType::Transpose | EdgeType::BlockTwiddle)
    }

    /// Block size B of a fused edge (number of points kept in registers).
    pub fn block_size(self) -> Option<usize> {
        self.is_fused().then(|| 1usize << self.stages())
    }

    /// 128-bit NEON vector registers holding live data across the edge's
    /// internal stages (paper Table 1; radix passes hold none across
    /// butterflies). Split-complex: B points = 2*B/4 vectors.
    pub fn neon_data_regs(self) -> usize {
        match self {
            EdgeType::F8 => 4,
            EdgeType::F16 => 8,
            EdgeType::F32 => 16,
            _ => 0,
        }
    }

    /// Short instruction-advantage description (paper Table 1 column 4).
    pub fn advantage(self) -> &'static str {
        match self {
            EdgeType::R2 => "Simplest; best for large strides",
            EdgeType::R4 => "W_4^1 = -j: swap+negate (free)",
            EdgeType::R8 => "W_8^{1,3}: mul by 1/sqrt(2) only",
            EdgeType::F8 => "In-register; zero memory traffic",
            EdgeType::F16 => "In-register; NEON 4x4 transpose",
            EdgeType::F32 => "In-register; novel (needs 32 regs)",
            EdgeType::RU => "Real split/unpack; predecessor decides cost",
            EdgeType::Transpose => "Blocked tiled transpose; strided walk",
            EdgeType::BlockTwiddle => "Four-step twiddle; streaming pass",
        }
    }

    /// Canonical name used across the stack (matches the Python side and
    /// the artifact manifest): "R2", "R4", "R8", "F8", "F16", "F32".
    pub fn name(self) -> &'static str {
        match self {
            EdgeType::R2 => "R2",
            EdgeType::R4 => "R4",
            EdgeType::R8 => "R8",
            EdgeType::F8 => "F8",
            EdgeType::F16 => "F16",
            EdgeType::F32 => "F32",
            EdgeType::RU => "RU",
            EdgeType::Transpose => "TR",
            EdgeType::BlockTwiddle => "BT",
        }
    }

    /// Parse a canonical name.
    pub fn parse(s: &str) -> Option<EdgeType> {
        match s {
            "RU" => return Some(EdgeType::RU),
            "TR" => return Some(EdgeType::Transpose),
            "BT" => return Some(EdgeType::BlockTwiddle),
            _ => {}
        }
        ALL_EDGES.iter().copied().find(|e| e.name() == s)
    }

    /// Compact index in [0, 9) — used to index context tables. The
    /// graph-catalog edges occupy [0, 6); the boundary edges sit past
    /// them: RU at 6, then the blocked-execution edges at 7 and 8.
    pub fn index(self) -> usize {
        match self {
            EdgeType::R2 => 0,
            EdgeType::R4 => 1,
            EdgeType::R8 => 2,
            EdgeType::F8 => 3,
            EdgeType::F16 => 4,
            EdgeType::F32 => 5,
            EdgeType::RU => 6,
            EdgeType::Transpose => 7,
            EdgeType::BlockTwiddle => 8,
        }
    }

    /// Inverse of [`EdgeType::index`].
    pub fn from_index(i: usize) -> Option<EdgeType> {
        match i {
            6 => return Some(EdgeType::RU),
            7 => return Some(EdgeType::Transpose),
            8 => return Some(EdgeType::BlockTwiddle),
            _ => {}
        }
        ALL_EDGES.get(i).copied()
    }
}

impl fmt::Display for EdgeType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Predecessor context of an edge measurement: either the start of the
/// transform (cold caches / fresh input) or the edge type that ran
/// immediately before (paper Eq. 1: t_prev in T = {start} ∪ edges).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Context {
    /// No preceding operation (node (s=0, start) in the expanded graph).
    Start,
    /// Immediately preceded by an edge of this type.
    After(EdgeType),
}

/// Number of distinct *graph-catalog* contexts: start + the 6 graph
/// edge types (|T| = 7, paper §2.3). [`Context::After`]`(`[`EdgeType::RU`]`)`
/// additionally exists at index 7 — the boundary context real-kind
/// plans start their c2c walk in (the first c2c pass of a real
/// transform's steady-state loop runs after the split/unpack pass) —
/// and is measured/persisted as its own cell via
/// [`Context::all_with_boundary`]; [`Context::all`] iterates the graph
/// catalog only.
pub const NUM_CONTEXTS: usize = 7;

/// Catalog contexts plus the after-RU boundary context (|T| + 1 = 8):
/// the full measured cell space since the boundary context became a
/// calibrated cell. The blocked-execution boundary contexts
/// (`After(Transpose)` at index 8, `After(BlockTwiddle)` at index 9)
/// exist past this — they appear in traces and attribution cells but
/// are *not* measured wisdom cells (blocked boundary edges are priced
/// analytically via `transpose_ns`/`block_twiddle_ns`, never harvested),
/// so the persisted cell space is unchanged.
pub const NUM_CONTEXTS_WITH_BOUNDARY: usize = 8;

impl Context {
    /// Compact index: 0 = start, 1.. = edge index + 1 (7 = after-RU,
    /// 8/9 = after the blocked-execution boundary edges).
    pub fn index(self) -> usize {
        match self {
            Context::Start => 0,
            Context::After(e) => e.index() + 1,
        }
    }

    /// Inverse of [`Context::index`].
    pub fn from_index(i: usize) -> Option<Context> {
        match i {
            0 => Some(Context::Start),
            _ => EdgeType::from_index(i - 1).map(Context::After),
        }
    }

    /// All *graph-catalog* contexts, start first (after-RU excluded:
    /// the expanded graph's history digits encode catalog edges only).
    pub fn all() -> impl Iterator<Item = Context> {
        (0..NUM_CONTEXTS).map(|i| Context::from_index(i).unwrap())
    }

    /// Every measured context: the graph catalog plus the after-RU
    /// boundary context (the context real-kind c2c walks start in).
    /// Harvest/calibration loops iterate this so the boundary cell is a
    /// measured quantity, not an after-R2 proxy.
    pub fn all_with_boundary() -> impl Iterator<Item = Context> {
        (0..NUM_CONTEXTS_WITH_BOUNDARY).map(|i| Context::from_index(i).unwrap())
    }
}

impl fmt::Display for Context {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Context::Start => f.write_str("start"),
            Context::After(e) => write!(f, "after-{e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_advances_match_table1() {
        let expect = [("R2", 1), ("R4", 2), ("R8", 3), ("F8", 3), ("F16", 4), ("F32", 5)];
        for (name, k) in expect {
            assert_eq!(EdgeType::parse(name).unwrap().stages(), k);
        }
    }

    #[test]
    fn block_sizes() {
        assert_eq!(EdgeType::F8.block_size(), Some(8));
        assert_eq!(EdgeType::F16.block_size(), Some(16));
        assert_eq!(EdgeType::F32.block_size(), Some(32));
        assert_eq!(EdgeType::R8.block_size(), None);
    }

    #[test]
    fn neon_regs_match_table1() {
        assert_eq!(EdgeType::F8.neon_data_regs(), 4);
        assert_eq!(EdgeType::F16.neon_data_regs(), 8);
        assert_eq!(EdgeType::F32.neon_data_regs(), 16);
        assert_eq!(EdgeType::R4.neon_data_regs(), 0);
    }

    #[test]
    fn name_parse_roundtrip() {
        for e in ALL_EDGES {
            assert_eq!(EdgeType::parse(e.name()), Some(e));
        }
        assert_eq!(EdgeType::parse("RU"), Some(EdgeType::RU));
        assert_eq!(EdgeType::parse("TR"), Some(EdgeType::Transpose));
        assert_eq!(EdgeType::parse("BT"), Some(EdgeType::BlockTwiddle));
        assert_eq!(EdgeType::parse("R16"), None);
        assert_eq!(EdgeType::parse(""), None);
    }

    #[test]
    fn index_roundtrip() {
        for (i, e) in ALL_EDGES.iter().enumerate() {
            assert_eq!(e.index(), i);
            assert_eq!(EdgeType::from_index(i), Some(*e));
        }
        assert_eq!(EdgeType::from_index(6), Some(EdgeType::RU));
        assert_eq!(EdgeType::RU.index(), 6);
        assert_eq!(EdgeType::from_index(7), Some(EdgeType::Transpose));
        assert_eq!(EdgeType::Transpose.index(), 7);
        assert_eq!(EdgeType::from_index(8), Some(EdgeType::BlockTwiddle));
        assert_eq!(EdgeType::BlockTwiddle.index(), 8);
        assert_eq!(EdgeType::from_index(9), None);
    }

    #[test]
    fn boundary_edges_are_not_graph_edges() {
        for e in [EdgeType::RU, EdgeType::Transpose, EdgeType::BlockTwiddle] {
            assert!(!ALL_EDGES.contains(&e));
            assert!(e.is_boundary());
            assert_eq!(e.stages(), 0);
            assert!(!e.is_fused());
            assert_eq!(e.block_size(), None);
            assert_eq!(e.neon_data_regs(), 0);
        }
        for e in ALL_EDGES {
            assert!(!e.is_boundary());
        }
    }

    #[test]
    fn context_index_roundtrip() {
        let all: Vec<Context> = Context::all().collect();
        assert_eq!(all.len(), NUM_CONTEXTS);
        for (i, c) in all.iter().enumerate() {
            assert_eq!(c.index(), i);
            assert_eq!(Context::from_index(i), Some(*c));
        }
        // after-RU sits past the graph catalog at index 7 — a measured
        // boundary cell, excluded from the graph-history contexts.
        assert_eq!(Context::from_index(7), Some(Context::After(EdgeType::RU)));
        assert_eq!(Context::After(EdgeType::RU).index(), 7);
        assert!(!Context::all().any(|c| c == Context::After(EdgeType::RU)));
        let full: Vec<Context> = Context::all_with_boundary().collect();
        assert_eq!(full.len(), NUM_CONTEXTS_WITH_BOUNDARY);
        assert_eq!(full[..NUM_CONTEXTS], Context::all().collect::<Vec<_>>()[..]);
        assert_eq!(*full.last().unwrap(), Context::After(EdgeType::RU));
        // the blocked-execution boundary contexts exist past the measured
        // cell space (traces/attribution only, never wisdom cells)
        assert_eq!(Context::from_index(8), Some(Context::After(EdgeType::Transpose)));
        assert_eq!(Context::from_index(9), Some(Context::After(EdgeType::BlockTwiddle)));
        assert_eq!(Context::After(EdgeType::Transpose).index(), 8);
        assert_eq!(Context::After(EdgeType::BlockTwiddle).index(), 9);
        assert!(!Context::all_with_boundary().any(|c| c == Context::After(EdgeType::Transpose)));
        assert_eq!(Context::from_index(10), None);
    }

    #[test]
    fn display_names() {
        assert_eq!(EdgeType::F16.to_string(), "F16");
        assert_eq!(EdgeType::Transpose.to_string(), "TR");
        assert_eq!(EdgeType::BlockTwiddle.to_string(), "BT");
        assert_eq!(Context::Start.to_string(), "start");
        assert_eq!(Context::After(EdgeType::R4).to_string(), "after-R4");
        assert_eq!(Context::After(EdgeType::Transpose).to_string(), "after-TR");
    }
}
