//! The live per-edge attribution table: observed nanoseconds per
//! `(kind, isa, batch class, stage, edge, context)` cell, next to the
//! cost model's believed value for the same cell.
//!
//! This is the observability face of the paper's central object — the
//! contextual cost table. The autotuner already *learns* from traced
//! samples; this table *accounts* for them: every sampled edge execution
//! lands in exactly one cell, the cell keeps the raw sum of whole-batch
//! nanoseconds (plain `+=` in feed order, so a test replaying the same
//! trace reproduces the sums bit-exactly), and the exporters render the
//! residual between what the service observed and what the planning
//! surface believed ([`crate::cost::CostModel::surface_edge_ns`]).

use std::collections::HashMap;
use std::sync::Mutex;

use crate::autotune::{EdgeSample, SampleSpan};
use crate::cost::batch_class;
use crate::edge::{Context, EdgeType};
use crate::isa::Isa;
use crate::kind::TransformKind;

/// Attribution cell key: (kind, isa, batch class, stage, edge, context).
/// The ISA is the codelet backend that executed the sampled pass
/// ([`EdgeSample::isa`]) — a scalar-forced replay and a native run
/// account into different cells, so residuals never mix backends.
pub type AttrKey = (TransformKind, Isa, usize, usize, EdgeType, Context);

/// One attribution cell.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AttrCell {
    /// Raw sum of observed whole-batch nanoseconds, in feed order.
    pub observed_ns: f64,
    /// Transforms covered (sum of batch widths across samples).
    pub transforms: u64,
    /// Edge samples folded in.
    pub samples: u64,
    /// The cost model's believed per-transform nanoseconds for this
    /// cell's planning surface (filled by [`Attribution::fill_believed`]).
    pub believed_ns: f64,
    pub has_believed: bool,
}

impl AttrCell {
    /// Observed per-transform nanoseconds (0 when nothing observed).
    pub fn observed_per_transform(&self) -> f64 {
        if self.transforms == 0 {
            0.0
        } else {
            self.observed_ns / self.transforms as f64
        }
    }

    /// Observed-minus-believed per-transform residual, when a believed
    /// value has been filled in.
    pub fn residual_ns(&self) -> Option<f64> {
        self.has_believed.then(|| self.observed_per_transform() - self.believed_ns)
    }
}

/// Thread-safe attribution table (one coarse lock; writes happen only on
/// the sampled 1-in-P path, never per request).
#[derive(Debug, Default)]
pub struct Attribution {
    cells: Mutex<HashMap<AttrKey, AttrCell>>,
}

impl Attribution {
    pub fn new() -> Attribution {
        Attribution::default()
    }

    /// The cell key a sample lands in.
    pub fn key_of(sample: &EdgeSample) -> AttrKey {
        (
            sample.kind,
            sample.isa,
            batch_class(sample.batch.max(1)),
            sample.stage,
            sample.edge,
            sample.ctx,
        )
    }

    /// Fold one sample into its cell. Marshal-span samples are data
    /// movement, not catalog cells — their edge/stage/ctx fields are
    /// placeholders, so folding them would invent a bogus RU@0 row.
    /// The metrics layer accounts marshal time separately. Boundary-span
    /// samples (the TR/BT passes of a traced blocked execution) *are*
    /// cells: their edge field is real, and attribution is exactly where
    /// an operator looks to see a blocked size's transpose bill.
    pub fn observe(&self, sample: &EdgeSample) {
        if sample.span == SampleSpan::Marshal {
            return;
        }
        let mut cells = self.cells.lock().unwrap();
        let cell = cells.entry(Self::key_of(sample)).or_default();
        cell.observed_ns += sample.ns;
        cell.transforms += sample.batch.max(1) as u64;
        cell.samples += 1;
    }

    /// Fold a traced execution's samples in, preserving their order.
    pub fn observe_all(&self, samples: &[EdgeSample]) {
        for s in samples {
            self.observe(s);
        }
    }

    /// Ask `believed` for every observed cell's model value. The
    /// callback sees the cell key and returns per-transform ns (`None`
    /// leaves the cell's believed value unset).
    pub fn fill_believed(&self, mut believed: impl FnMut(AttrKey) -> Option<f64>) {
        let mut cells = self.cells.lock().unwrap();
        for (key, cell) in cells.iter_mut() {
            if let Some(ns) = believed(*key) {
                cell.believed_ns = ns;
                cell.has_believed = true;
            }
        }
    }

    /// Snapshot of every cell, sorted by (kind, isa, class, stage, edge,
    /// ctx) index order — stable across runs for golden tests and
    /// exporters.
    pub fn cells(&self) -> Vec<(AttrKey, AttrCell)> {
        let mut out: Vec<(AttrKey, AttrCell)> =
            self.cells.lock().unwrap().iter().map(|(k, v)| (*k, *v)).collect();
        out.sort_by_key(|((kind, isa, class, stage, edge, ctx), _)| {
            (kind.index(), isa.index(), *class, *stage, edge.index(), ctx.index())
        });
        out
    }

    /// Observed cells count.
    pub fn len(&self) -> usize {
        self.cells.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.lock().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(edge: EdgeType, stage: usize, ctx: Context, batch: usize, ns: f64) -> EdgeSample {
        EdgeSample {
            edge,
            stage,
            ctx,
            kind: TransformKind::Forward,
            batch,
            isa: Isa::Scalar,
            span: SampleSpan::Edge,
            ns,
        }
    }

    #[test]
    fn marshal_spans_never_become_cells() {
        let a = Attribution::new();
        a.observe(&EdgeSample::marshal(TransformKind::Forward, 16, Isa::Scalar, 800.0));
        assert!(a.is_empty());
    }

    #[test]
    fn samples_accumulate_bit_exactly_in_feed_order() {
        let a = Attribution::new();
        let values = [10.25f64, 3.5, 0.125, 7.75];
        for &ns in &values {
            a.observe(&sample(EdgeType::R4, 0, Context::Start, 1, ns));
        }
        let cells = a.cells();
        assert_eq!(cells.len(), 1);
        let (key, cell) = cells[0];
        assert_eq!(key, (TransformKind::Forward, Isa::Scalar, 0, 0, EdgeType::R4, Context::Start));
        // bit-exact: the cell is the plain left-to-right sum
        let want = values.iter().fold(0.0f64, |acc, &v| acc + v);
        assert_eq!(cell.observed_ns.to_bits(), want.to_bits());
        assert_eq!(cell.samples, 4);
        assert_eq!(cell.transforms, 4);
    }

    #[test]
    fn batch_width_maps_to_batch_class_and_per_transform_normalizes() {
        let a = Attribution::new();
        // 16-wide batch: class 4, whole-batch 1600 ns → 100 ns/transform
        a.observe(&sample(EdgeType::F8, 5, Context::After(EdgeType::R4), 16, 1600.0));
        let (key, cell) = a.cells()[0];
        assert_eq!(key.2, 4);
        assert_eq!(cell.transforms, 16);
        assert_eq!(cell.observed_per_transform(), 100.0);
    }

    #[test]
    fn distinct_contexts_kinds_and_isas_are_distinct_cells() {
        let a = Attribution::new();
        a.observe(&sample(EdgeType::R2, 0, Context::Start, 1, 5.0));
        a.observe(&sample(EdgeType::R2, 0, Context::After(EdgeType::R2), 1, 3.0));
        let mut inv = sample(EdgeType::R2, 0, Context::Start, 1, 4.0);
        inv.kind = TransformKind::Inverse;
        a.observe(&inv);
        // same cell coordinates as the first sample, different backend
        let mut neon = sample(EdgeType::R2, 0, Context::Start, 1, 2.0);
        neon.isa = Isa::Neon;
        a.observe(&neon);
        assert_eq!(a.len(), 4);
        // sorted: forward cells first (kind index), scalar before neon
        // (isa index), then by ctx index
        let cells = a.cells();
        assert_eq!(cells[0].0 .5, Context::Start);
        assert_eq!(cells[1].0 .5, Context::After(EdgeType::R2));
        assert_eq!(cells[2].0 .1, Isa::Neon);
        assert_eq!(cells[3].0 .0, TransformKind::Inverse);
    }

    #[test]
    fn believed_fill_and_residual() {
        let a = Attribution::new();
        a.observe(&sample(EdgeType::R4, 2, Context::Start, 1, 120.0));
        assert_eq!(a.cells()[0].1.residual_ns(), None);
        a.fill_believed(|(_, _, _, _, edge, _)| (edge == EdgeType::R4).then_some(100.0));
        let cell = a.cells()[0].1;
        assert!(cell.has_believed);
        assert_eq!(cell.residual_ns(), Some(20.0));
    }

    #[test]
    fn blocked_boundary_samples_become_cells_with_their_edges() {
        // A traced blocked run emits three TR walks + one BT multiply;
        // they must land on their own edges (not vanish like marshal
        // spans) so the attribution table shows the transpose bill.
        let a = Attribution::new();
        for ns in [100.0, 110.0, 105.0] {
            a.observe(&EdgeSample::boundary(
                EdgeType::Transpose,
                256,
                256,
                TransformKind::Forward,
                Isa::Scalar,
                ns,
            ));
        }
        a.observe(&EdgeSample::boundary(
            EdgeType::BlockTwiddle,
            256,
            256,
            TransformKind::Forward,
            Isa::Scalar,
            400.0,
        ));
        assert_eq!(a.len(), 2);
        let cells = a.cells();
        let tr = cells.iter().find(|(k, _)| k.4 == EdgeType::Transpose).unwrap();
        let bt = cells.iter().find(|(k, _)| k.4 == EdgeType::BlockTwiddle).unwrap();
        assert_eq!(tr.1.samples, 3);
        assert_eq!(tr.1.observed_ns, 315.0);
        assert_eq!(bt.1.samples, 1);
        assert_eq!(bt.1.observed_ns, 400.0);
    }

    #[test]
    fn ru_boundary_samples_get_their_own_cell() {
        let a = Attribution::new();
        let mut s = sample(EdgeType::RU, 0, Context::After(EdgeType::F8), 1, 50.0);
        s.kind = TransformKind::RealForward;
        a.observe(&s);
        let (key, _) = a.cells()[0];
        assert_eq!(key.4, EdgeType::RU);
        assert_eq!(key.0, TransformKind::RealForward);
    }
}
