//! The flight recorder: a bounded ring of typed service events.
//!
//! Every layer of the serving stack appends [`Event`]s here — request
//! submission, coalesce hold/flush decisions, group formation,
//! per-request latency spans, and the autotuner's drift → replan → swap
//! audit trail. The ring is fixed-capacity and never blocks a writer:
//! recording claims a slot with one atomic increment and takes only
//! that slot's lock, so concurrent writers on different slots never
//! contend and a full ring overwrites the oldest events (flight-recorder
//! semantics: the recent past is always available, the distant past is
//! not).
//!
//! Timestamps are nanoseconds from the owning
//! [`Observer`](super::Observer)'s origin instant, so a deterministic
//! harness driving a virtual clock produces bit-stable `t_ns` values.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::edge::{Context, EdgeType};
use crate::kind::TransformKind;
use crate::plan::Plan;

/// Per-stage execution time attributed to one request: (edge, stage,
/// per-request nanoseconds). Batched groups divide each whole-batch
/// sample evenly across their lanes.
pub type StageTime = (EdgeType, usize, f64);

/// What happened. Field units: `*_ns` are nanoseconds; `t_ns` on the
/// enclosing [`Event`] is the recorder-origin-relative wall offset.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// A request entered the service queue.
    Submit { req: u64, kind: TransformKind, n: usize },
    /// A submission was rejected (admission control) or an admitted
    /// request was shed at pull time. `reason` is the stable
    /// `Rejected::reason()` tag: `queue_full`, `shed`, `shutting_down`,
    /// or `invalid`.
    Rejected { kind: TransformKind, n: usize, reason: String },
    /// The coalescer decided to hold an under-filled group open for
    /// (at least) one more pull window.
    CoalesceHold { kind: TransformKind, n: usize, size: usize, held_windows: u32 },
    /// A same-(kind, n) group was handed to execution.
    GroupFormed {
        kind: TransformKind,
        n: usize,
        size: usize,
        held_windows: u32,
        paired_singletons: bool,
    },
    /// A group that had been held across pull windows flushed.
    CoalesceFlush {
        kind: TransformKind,
        n: usize,
        size: usize,
        held_windows: u32,
        held_age_ns: u64,
        /// Members gained while held (the hold's payoff).
        gained: usize,
        paired_singletons: bool,
        /// `FlushReason` as text ("Filled", "Deadline", ...).
        reason: String,
    },
    /// A request completed: its end-to-end latency span, decomposed.
    /// `queue_ns + held_ns + exec_ns == total_ns` exactly (the
    /// decomposition is computed by subtraction, never re-measured).
    RequestDone {
        req: u64,
        kind: TransformKind,
        n: usize,
        group_size: usize,
        /// Waiting in the submit queue before its group was touched.
        queue_ns: u64,
        /// Held open by the coalescer (capped at `total_ns - exec_ns`).
        held_ns: u64,
        /// Gather + kernel + scatter for the group it rode in.
        exec_ns: u64,
        total_ns: u64,
        /// Per-stage edge timings when the group was traced (empty for
        /// untraced groups).
        stages: Vec<StageTime>,
    },
    /// A drift check flagged the model (autotuner audit trail, step 1).
    Drift {
        checks: u64,
        cells_checked: usize,
        cells_over: usize,
        max_rel_dev: f64,
        worst: Option<(EdgeType, usize, Context)>,
    },
    /// The re-planner searched and found this plan (audit step 2).
    Replan { kind: TransformKind, class: usize, plan: Plan, cost_ns: f64 },
    /// The search result was published (audit step 3): before/after
    /// plans with the costs the decision believed.
    Swap {
        version: u64,
        old_plan: Plan,
        /// Believed cost of the outgoing plan under the *current* model.
        old_cost_ns: f64,
        new_plan: Plan,
        new_cost_ns: f64,
    },
    /// The search result did not clear the hysteresis gate (audit
    /// step 3, declined branch).
    SwapDeclined { plan: Plan, cost_ns: f64, current_cost_ns: f64 },
}

impl EventKind {
    /// Stable type tag used by the JSON export and the pretty-printer.
    pub fn tag(&self) -> &'static str {
        match self {
            EventKind::Submit { .. } => "submit",
            EventKind::Rejected { .. } => "rejected",
            EventKind::CoalesceHold { .. } => "coalesce_hold",
            EventKind::GroupFormed { .. } => "group_formed",
            EventKind::CoalesceFlush { .. } => "coalesce_flush",
            EventKind::RequestDone { .. } => "request_done",
            EventKind::Drift { .. } => "drift",
            EventKind::Replan { .. } => "replan",
            EventKind::Swap { .. } => "swap",
            EventKind::SwapDeclined { .. } => "swap_declined",
        }
    }
}

/// One recorded event: a global sequence number (total order across all
/// writers), a timestamp relative to the observer's origin, and the
/// typed payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub seq: u64,
    pub t_ns: u64,
    pub kind: EventKind,
}

/// Counter snapshot of a [`FlightRecorder`], as the exporters render it
/// (`recorder` object in `spfft.metrics.v1`, `spfft_recorder_*`
/// Prometheus families).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecorderStats {
    /// Ring capacity (events the recorder can hold).
    pub capacity: usize,
    /// Events ever recorded, including overwritten ones.
    pub recorded: u64,
    /// Events lost to ring overwrite (`recorded - capacity`, floored).
    pub dropped: u64,
}

/// Fixed-capacity multi-writer event ring.
#[derive(Debug)]
pub struct FlightRecorder {
    slots: Vec<Mutex<Option<Event>>>,
    cursor: AtomicU64,
}

impl FlightRecorder {
    pub fn new(capacity: usize) -> FlightRecorder {
        let cap = capacity.max(1);
        FlightRecorder {
            slots: (0..cap).map(|_| Mutex::new(None)).collect(),
            cursor: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total events ever recorded (including ones the ring has since
    /// overwritten).
    pub fn recorded(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }

    /// Events the bounded ring has overwritten (flight-recorder drops).
    /// The ring always holds the newest `capacity()` events, so this is
    /// exactly `recorded - capacity`, floored at zero.
    pub fn dropped(&self) -> u64 {
        self.recorded().saturating_sub(self.capacity() as u64)
    }

    /// One consistent counter snapshot for the exporters.
    pub fn stats(&self) -> RecorderStats {
        let recorded = self.recorded();
        RecorderStats {
            capacity: self.capacity(),
            recorded,
            dropped: recorded.saturating_sub(self.capacity() as u64),
        }
    }

    /// Append an event; returns its sequence number. Lock scope is one
    /// slot; the claim itself is a single atomic increment.
    pub fn record(&self, t_ns: u64, kind: EventKind) -> u64 {
        let seq = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(seq % self.slots.len() as u64) as usize];
        let mut guard = slot.lock().unwrap();
        // A writer lapped by a faster one must not clobber the newer
        // event: the slot only moves forward in sequence order.
        if guard.as_ref().map_or(true, |e| e.seq < seq) {
            *guard = Some(Event { seq, t_ns, kind });
        }
        seq
    }

    /// The surviving events in sequence order.
    pub fn snapshot(&self) -> Vec<Event> {
        let mut out: Vec<Event> =
            self.slots.iter().filter_map(|s| s.lock().unwrap().clone()).collect();
        out.sort_by_key(|e| e.seq);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn submit(req: u64) -> EventKind {
        EventKind::Submit { req, kind: TransformKind::Forward, n: 256 }
    }

    #[test]
    fn records_in_sequence_order() {
        let r = FlightRecorder::new(8);
        for i in 0..5 {
            assert_eq!(r.record(i * 10, submit(i)), i);
        }
        let events = r.snapshot();
        assert_eq!(events.len(), 5);
        for (i, e) in events.iter().enumerate() {
            assert_eq!(e.seq, i as u64);
            assert_eq!(e.t_ns, i as u64 * 10);
            assert_eq!(e.kind, submit(i as u64));
        }
        assert_eq!(r.recorded(), 5);
    }

    #[test]
    fn ring_overwrites_oldest() {
        let r = FlightRecorder::new(4);
        for i in 0..10 {
            r.record(i, submit(i));
        }
        let events = r.snapshot();
        assert_eq!(events.len(), 4);
        let seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
        assert_eq!(r.recorded(), 10);
    }

    #[test]
    fn dropped_counts_ring_overwrites() {
        let r = FlightRecorder::new(4);
        assert_eq!(r.dropped(), 0);
        for i in 0..4 {
            r.record(i, submit(i));
        }
        // exactly full: nothing lost yet
        assert_eq!(r.dropped(), 0);
        for i in 4..10 {
            r.record(i, submit(i));
        }
        assert_eq!(r.dropped(), 6);
        let stats = r.stats();
        assert_eq!(stats, RecorderStats { capacity: 4, recorded: 10, dropped: 6 });
    }

    #[test]
    fn zero_capacity_is_clamped() {
        let r = FlightRecorder::new(0);
        assert_eq!(r.capacity(), 1);
        r.record(1, submit(0));
        r.record(2, submit(1));
        assert_eq!(r.snapshot().len(), 1);
        assert_eq!(r.snapshot()[0].seq, 1);
    }

    #[test]
    fn concurrent_writers_keep_sequence_integrity() {
        let r = Arc::new(FlightRecorder::new(64));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..200 {
                    r.record(t * 1000 + i, submit(t));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.recorded(), 800);
        let events = r.snapshot();
        assert_eq!(events.len(), 64);
        // the ring holds the newest 64 sequence numbers, strictly ordered
        for w in events.windows(2) {
            assert!(w[0].seq < w[1].seq);
        }
        assert!(events.iter().all(|e| e.seq >= 800 - 64));
    }
}
