//! Structured observability for the serving stack.
//!
//! Three pieces, all reachable through one shared [`Observer`]:
//!
//! * the **flight recorder** ([`FlightRecorder`]) — a bounded,
//!   never-blocking ring of typed [`Event`]s covering the whole request
//!   path (submit → coalesce hold → group formation → flush → per-request
//!   latency span) and the autotuner's decision trail (drift → replan →
//!   swap, with before/after plans and the costs the decision believed);
//! * the **attribution table** ([`Attribution`]) — observed nanoseconds
//!   per `(kind, isa, batch class, stage, edge, context)` cell, accumulated
//!   from the same traced samples the autotuner learns from, exposing
//!   the residual against the cost model's believed `surface_edge_ns`;
//! * the **exporters** ([`export`]) — versioned JSON snapshots
//!   (`spfft serve --metrics-out`), a Prometheus text renderer, and the
//!   event-stream dump `spfft obs` replays.
//!
//! The observer is deliberately passive: layers call `record_at` /
//! `observe_samples` with data they already have; nothing here touches
//! the hot path unless an observer was configured
//! (`ServiceConfig::observer` / `AutotuneConfig::observer`). Timestamps
//! are nanoseconds from the observer's origin [`Instant`], which the
//! deterministic harness pins to its virtual clock's origin so event
//! times (and therefore golden event-stream tests) are bit-stable.

pub mod attribution;
pub mod export;
pub mod recorder;

pub use attribution::{AttrCell, AttrKey, Attribution};
pub use export::{
    audit_trail, ctx_from_label, ctx_label, events_from_json, events_json, prometheus_text,
    prometheus_text_sharded, render_events, schema_check_prometheus, schema_check_snapshot,
    snapshot_json, snapshot_json_sharded,
};
pub use recorder::{Event, EventKind, FlightRecorder, RecorderStats, StageTime};

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::autotune::EdgeSample;

/// Default flight-recorder capacity when none is configured.
pub const DEFAULT_RECORDER_CAPACITY: usize = 4096;

/// The shared observability handle: one per service (and cloned into the
/// autotuner), owning the flight recorder, the attribution table, and
/// the request-id counter that ties Submit events to RequestDone spans.
#[derive(Debug)]
pub struct Observer {
    origin: Instant,
    recorder: FlightRecorder,
    attribution: Attribution,
    next_request: AtomicU64,
}

impl Observer {
    pub fn new(capacity: usize) -> Observer {
        Observer::with_origin(Instant::now(), capacity)
    }

    /// An observer whose `t_ns` timestamps are measured from `origin`.
    /// The deterministic harness passes its virtual clock's origin here
    /// so recorded times equal virtual-clock offsets exactly.
    pub fn with_origin(origin: Instant, capacity: usize) -> Observer {
        Observer {
            origin,
            recorder: FlightRecorder::new(capacity),
            attribution: Attribution::new(),
            next_request: AtomicU64::new(0),
        }
    }

    pub fn origin(&self) -> Instant {
        self.origin
    }

    /// Nanoseconds from the origin to `at` (0 for instants before it).
    pub fn t_ns(&self, at: Instant) -> u64 {
        at.saturating_duration_since(self.origin).as_nanos() as u64
    }

    /// Allocate the next request id (Submit/RequestDone correlation key).
    pub fn next_request_id(&self) -> u64 {
        self.next_request.fetch_add(1, Ordering::Relaxed)
    }

    /// Record an event stamped at `at`; returns its sequence number.
    pub fn record_at(&self, at: Instant, kind: EventKind) -> u64 {
        self.recorder.record(self.t_ns(at), kind)
    }

    /// Record an event stamped now.
    pub fn record_now(&self, kind: EventKind) -> u64 {
        self.record_at(Instant::now(), kind)
    }

    /// The surviving events, in sequence order.
    pub fn events(&self) -> Vec<Event> {
        self.recorder.snapshot()
    }

    pub fn recorder(&self) -> &FlightRecorder {
        &self.recorder
    }

    pub fn attribution(&self) -> &Attribution {
        &self.attribution
    }

    /// Fold a traced execution's edge samples into the attribution
    /// table, preserving feed order (bit-exact accumulation).
    pub fn observe_samples(&self, samples: &[EdgeSample]) {
        self.attribution.observe_all(samples);
    }
}

impl Default for Observer {
    fn default() -> Observer {
        Observer::new(DEFAULT_RECORDER_CAPACITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::edge::{Context, EdgeType};
    use crate::kind::TransformKind;
    use std::time::Duration;

    #[test]
    fn request_ids_are_sequential() {
        let obs = Observer::new(16);
        assert_eq!(obs.next_request_id(), 0);
        assert_eq!(obs.next_request_id(), 1);
        assert_eq!(obs.next_request_id(), 2);
    }

    #[test]
    fn timestamps_are_origin_relative() {
        let origin = Instant::now();
        let obs = Observer::with_origin(origin, 16);
        assert_eq!(obs.t_ns(origin), 0);
        let later = origin + Duration::from_micros(5);
        assert_eq!(obs.t_ns(later), 5_000);
        // instants before the origin clamp to zero rather than panic
        assert_eq!(obs.t_ns(origin - Duration::from_micros(1)), 0);
        obs.record_at(later, EventKind::Submit { req: 0, kind: TransformKind::Forward, n: 64 });
        let events = obs.events();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].t_ns, 5_000);
    }

    #[test]
    fn observe_samples_feeds_the_attribution_table() {
        let obs = Observer::new(16);
        obs.observe_samples(&[
            EdgeSample {
                edge: EdgeType::R4,
                stage: 0,
                ctx: Context::Start,
                kind: TransformKind::Forward,
                batch: 4,
                isa: crate::isa::Isa::Scalar,
                span: crate::autotune::SampleSpan::Edge,
                ns: 400.0,
            },
            EdgeSample {
                edge: EdgeType::F8,
                stage: 2,
                ctx: Context::After(EdgeType::R4),
                kind: TransformKind::Forward,
                batch: 4,
                isa: crate::isa::Isa::Scalar,
                span: crate::autotune::SampleSpan::Edge,
                ns: 900.0,
            },
            // marshal spans are data movement, not catalog cells — the
            // attribution table must not grow a bogus RU@0 row
            EdgeSample::marshal(TransformKind::Forward, 4, crate::isa::Isa::Scalar, 555.0),
        ]);
        assert_eq!(obs.attribution().len(), 2);
        let cells = obs.attribution().cells();
        assert_eq!(cells[0].1.observed_per_transform(), 100.0);
    }
}
