//! Exporters: JSON metrics snapshots, Prometheus text format, and the
//! flight-recorder event-stream format (`spfft obs` replays it).
//!
//! Formats are versioned by a `schema` tag (`spfft.metrics.v1`,
//! `spfft.events.v1`); [`schema_check_snapshot`] /
//! [`schema_check_prometheus`] are the validation CI runs against a live
//! `spfft serve --metrics-out` capture — a renamed or dropped field
//! fails the check, not a downstream dashboard.

use std::collections::BTreeMap;

use crate::autotune::AutotuneStatus;
use crate::coordinator::MetricsSnapshot;
use crate::edge::{Context, EdgeType};
use crate::isa::Isa;
use crate::kind::{TransformKind, ALL_KINDS};
use crate::plan::Plan;
use crate::util::json::{self, Json};

use super::attribution::{AttrCell, AttrKey};
use super::recorder::{Event, EventKind, RecorderStats};

/// Prometheus-safe context label: `start`, `after_R2`, ... `after_RU`.
pub fn ctx_label(ctx: Context) -> String {
    match ctx {
        Context::Start => "start".to_string(),
        Context::After(e) => format!("after_{}", e.name()),
    }
}

/// Inverse of [`ctx_label`] (also accepts the `after-R2` display form).
pub fn ctx_from_label(label: &str) -> Option<Context> {
    if label == "start" {
        return Some(Context::Start);
    }
    let rest = label.strip_prefix("after_").or_else(|| label.strip_prefix("after-"))?;
    EdgeType::parse(rest).map(Context::After)
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn s(text: impl Into<String>) -> Json {
    Json::Str(text.into())
}

// ---------------------------------------------------------------------
// metrics snapshot (spfft.metrics.v1)
// ---------------------------------------------------------------------

fn attribution_json(cells: &[(AttrKey, AttrCell)]) -> Json {
    Json::Arr(
        cells
            .iter()
            .map(|((kind, isa, class, stage, edge, ctx), cell)| {
                obj(vec![
                    ("kind", s(kind.name())),
                    ("isa", s(isa.name())),
                    ("class", num(*class as f64)),
                    ("stage", num(*stage as f64)),
                    ("edge", s(edge.name())),
                    ("ctx", s(ctx_label(*ctx))),
                    ("observed_ns", num(cell.observed_ns)),
                    ("transforms", num(cell.transforms as f64)),
                    ("samples", num(cell.samples as f64)),
                    ("observed_per_transform_ns", num(cell.observed_per_transform())),
                    (
                        "believed_ns",
                        if cell.has_believed { num(cell.believed_ns) } else { Json::Null },
                    ),
                    ("residual_ns", cell.residual_ns().map(num).unwrap_or(Json::Null)),
                ])
            })
            .collect(),
    )
}

fn autotune_json(status: &AutotuneStatus) -> Json {
    obj(vec![
        ("kind", s(status.kind.name())),
        ("active_plan", s(status.active_plan.to_string())),
        ("plan_version", num(status.plan_version as f64)),
        ("predicted_ns", num(status.predicted_ns)),
        ("plan_batch", num(status.plan_batch as f64)),
        ("batches_ingested", num(status.batches_ingested as f64)),
        ("samples_ingested", num(status.samples_ingested as f64)),
        ("batches_dropped", num(status.batches_dropped as f64)),
        ("drift_checks", num(status.drift_checks as f64)),
        ("drift_events", num(status.drift_events as f64)),
        ("replans", num(status.replans as f64)),
        ("swaps", num(status.swaps as f64)),
        ("last_swap_latency_ns", num(status.last_swap_latency_ns as f64)),
    ])
}

/// Render one metrics snapshot (plus the attribution table, the
/// flight-recorder counters, and, when autotuning, the tuner status) as
/// the versioned JSON document `spfft serve --metrics-out` writes.
pub fn snapshot_json(
    snap: &MetricsSnapshot,
    attribution: &[(AttrKey, AttrCell)],
    recorder: &RecorderStats,
    autotune: Option<&AutotuneStatus>,
) -> Json {
    let by_kind = Json::Obj(
        ALL_KINDS
            .iter()
            .map(|k| (k.name().to_string(), num(snap.completed_by_kind[k.index()] as f64)))
            .collect::<BTreeMap<_, _>>(),
    );
    obj(vec![
        ("schema", s("spfft.metrics.v1")),
        (
            "counters",
            obj(vec![
                ("submitted", num(snap.submitted as f64)),
                ("completed", num(snap.completed as f64)),
                ("completed_by_kind", by_kind),
                ("failed", num(snap.failed as f64)),
                ("rejected_full", num(snap.rejected_full as f64)),
                ("rejected_stopped", num(snap.rejected_stopped as f64)),
                ("rejected_invalid", num(snap.rejected_invalid as f64)),
                ("rejected_shed", num(snap.rejected_shed as f64)),
                ("batches", num(snap.batches as f64)),
                ("mean_batch_size", num(snap.mean_batch_size)),
                ("groups", num(snap.groups as f64)),
                ("mean_group_size", num(snap.mean_group_size)),
                ("coalesced_flushes", num(snap.coalesced_flushes as f64)),
                ("coalesce_hits", num(snap.coalesce_hits as f64)),
                ("coalesce_hit_rate", num(snap.coalesce_hit_rate)),
                ("singleton_pairings", num(snap.singleton_pairings as f64)),
                ("exec_panel_groups", num(snap.exec_panel_groups as f64)),
                ("exec_scalar_groups", num(snap.exec_scalar_groups as f64)),
                ("exec_panel_requests", num(snap.exec_panel_requests as f64)),
                ("exec_scalar_requests", num(snap.exec_scalar_requests as f64)),
                ("twiddle_hits", num(snap.twiddle_hits as f64)),
                ("twiddle_misses", num(snap.twiddle_misses as f64)),
                ("twiddle_hit_rate", num(snap.twiddle_hit_rate)),
            ]),
        ),
        (
            "group_size_hist",
            Json::Arr(snap.group_size_hist.iter().map(|&c| num(c as f64)).collect()),
        ),
        (
            "latency_ns",
            obj(vec![
                ("p50", num(snap.latency_p50.as_nanos() as f64)),
                ("p95", num(snap.latency_p95.as_nanos() as f64)),
                ("p99", num(snap.latency_p99.as_nanos() as f64)),
                ("max", num(snap.latency_max.as_nanos() as f64)),
            ]),
        ),
        (
            "held_age_ns",
            obj(vec![
                ("mean", num(snap.mean_held_age.as_nanos() as f64)),
                ("max", num(snap.max_held_age.as_nanos() as f64)),
            ]),
        ),
        ("busy_ns", num(snap.busy.as_nanos() as f64)),
        ("marshal_ns_total", num(snap.marshal_time.as_nanos() as f64)),
        (
            "recorder",
            obj(vec![
                ("capacity", num(recorder.capacity as f64)),
                ("recorded", num(recorder.recorded as f64)),
                ("dropped", num(recorder.dropped as f64)),
            ]),
        ),
        ("attribution", attribution_json(attribution)),
        ("autotune", autotune.map(autotune_json).unwrap_or(Json::Null)),
    ])
}

/// One shard's counter block for the `shards` array of a sharded
/// `spfft.metrics.v1` document.
fn shard_json(shard: usize, snap: &MetricsSnapshot) -> Json {
    obj(vec![
        ("shard", num(shard as f64)),
        ("submitted", num(snap.submitted as f64)),
        ("completed", num(snap.completed as f64)),
        ("failed", num(snap.failed as f64)),
        ("rejected_full", num(snap.rejected_full as f64)),
        ("rejected_stopped", num(snap.rejected_stopped as f64)),
        ("rejected_invalid", num(snap.rejected_invalid as f64)),
        ("rejected_shed", num(snap.rejected_shed as f64)),
        ("batches", num(snap.batches as f64)),
        ("groups", num(snap.groups as f64)),
        ("coalesced_flushes", num(snap.coalesced_flushes as f64)),
        ("coalesce_hits", num(snap.coalesce_hits as f64)),
        ("coalesce_hit_rate", num(snap.coalesce_hit_rate)),
        ("singleton_pairings", num(snap.singleton_pairings as f64)),
        ("exec_panel_groups", num(snap.exec_panel_groups as f64)),
        ("exec_scalar_groups", num(snap.exec_scalar_groups as f64)),
        ("marshal_ns_total", num(snap.marshal_time.as_nanos() as f64)),
        (
            "latency_ns",
            obj(vec![
                ("p50", num(snap.latency_p50.as_nanos() as f64)),
                ("p95", num(snap.latency_p95.as_nanos() as f64)),
                ("p99", num(snap.latency_p99.as_nanos() as f64)),
                ("max", num(snap.latency_max.as_nanos() as f64)),
            ]),
        ),
    ])
}

/// Sharded variant of [`snapshot_json`]: the top-level counters are the
/// fleet aggregate ([`MetricsSnapshot::aggregate`] — counters sum,
/// order statistics are conservative elementwise maxima) and a `shards`
/// array carries each shard's own counter block, indexed by shard id.
/// Still `spfft.metrics.v1`: single-shard consumers read the aggregate
/// exactly as before, the `shards` key is additive.
pub fn snapshot_json_sharded(
    shards: &[MetricsSnapshot],
    attribution: &[(AttrKey, AttrCell)],
    recorder: &RecorderStats,
    autotune: Option<&AutotuneStatus>,
) -> Json {
    let total = MetricsSnapshot::aggregate(shards);
    let mut doc = snapshot_json(&total, attribution, recorder, autotune);
    if let Json::Obj(map) = &mut doc {
        map.insert(
            "shards".to_string(),
            Json::Arr(shards.iter().enumerate().map(|(i, s)| shard_json(i, s)).collect()),
        );
    }
    doc
}

/// Validate a `spfft.metrics.v1` document: schema tag, every counter and
/// latency field present, every attribution cell fully keyed. Renaming
/// or dropping a field is a hard error.
pub fn schema_check_snapshot(doc: &Json) -> Result<(), String> {
    if doc.get("schema").as_str() != Some("spfft.metrics.v1") {
        return Err(format!(
            "schema tag mismatch: want \"spfft.metrics.v1\", got {}",
            json::to_string(doc.get("schema"))
        ));
    }
    let counters = doc.get("counters");
    for field in [
        "submitted",
        "completed",
        "failed",
        "rejected_full",
        "rejected_stopped",
        "rejected_invalid",
        "rejected_shed",
        "batches",
        "mean_batch_size",
        "groups",
        "mean_group_size",
        "coalesced_flushes",
        "coalesce_hits",
        "coalesce_hit_rate",
        "singleton_pairings",
        "exec_panel_groups",
        "exec_scalar_groups",
        "exec_panel_requests",
        "exec_scalar_requests",
        "twiddle_hits",
        "twiddle_misses",
        "twiddle_hit_rate",
    ] {
        if counters.get(field).as_f64().is_none() {
            return Err(format!("counters.{field} missing or not a number"));
        }
    }
    if doc.get("marshal_ns_total").as_f64().is_none() {
        return Err("marshal_ns_total missing or not a number".to_string());
    }
    let by_kind = counters.get("completed_by_kind");
    for kind in ALL_KINDS {
        if by_kind.get(kind.name()).as_f64().is_none() {
            return Err(format!("counters.completed_by_kind.{} missing", kind.name()));
        }
    }
    for field in ["p50", "p95", "p99", "max"] {
        if doc.get("latency_ns").get(field).as_f64().is_none() {
            return Err(format!("latency_ns.{field} missing or not a number"));
        }
    }
    for field in ["mean", "max"] {
        if doc.get("held_age_ns").get(field).as_f64().is_none() {
            return Err(format!("held_age_ns.{field} missing or not a number"));
        }
    }
    if doc.get("group_size_hist").as_arr().is_none() {
        return Err("group_size_hist missing or not an array".to_string());
    }
    for field in ["capacity", "recorded", "dropped"] {
        if doc.get("recorder").get(field).as_f64().is_none() {
            return Err(format!("recorder.{field} missing or not a number"));
        }
    }
    let cells = doc
        .get("attribution")
        .as_arr()
        .ok_or_else(|| "attribution missing or not an array".to_string())?;
    for (i, cell) in cells.iter().enumerate() {
        let kind = cell
            .get("kind")
            .as_str()
            .ok_or_else(|| format!("attribution[{i}].kind missing"))?;
        if TransformKind::parse(kind).is_none() {
            return Err(format!("attribution[{i}].kind \"{kind}\" unknown"));
        }
        let isa =
            cell.get("isa").as_str().ok_or_else(|| format!("attribution[{i}].isa missing"))?;
        if Isa::parse(isa).is_none() {
            return Err(format!("attribution[{i}].isa \"{isa}\" unknown"));
        }
        let edge =
            cell.get("edge").as_str().ok_or_else(|| format!("attribution[{i}].edge missing"))?;
        if EdgeType::parse(edge).is_none() {
            return Err(format!("attribution[{i}].edge \"{edge}\" unknown"));
        }
        let ctx =
            cell.get("ctx").as_str().ok_or_else(|| format!("attribution[{i}].ctx missing"))?;
        if ctx_from_label(ctx).is_none() {
            return Err(format!("attribution[{i}].ctx \"{ctx}\" unknown"));
        }
        for field in ["class", "stage", "observed_ns", "transforms", "samples"] {
            if cell.get(field).as_f64().is_none() {
                return Err(format!("attribution[{i}].{field} missing or not a number"));
            }
        }
    }
    // `shards` is optional (single-shard docs omit it) but, when
    // present, every entry must carry its id and the full rejection
    // decomposition — the per-shard labels CI's export gate asserts.
    match doc.get("shards") {
        Json::Null => {}
        shards => {
            let arr = shards.as_arr().ok_or("shards present but not an array")?;
            for (i, shard) in arr.iter().enumerate() {
                for field in [
                    "shard",
                    "submitted",
                    "completed",
                    "failed",
                    "rejected_full",
                    "rejected_stopped",
                    "rejected_invalid",
                    "rejected_shed",
                    "coalesce_hits",
                    "coalesce_hit_rate",
                    "exec_panel_groups",
                    "exec_scalar_groups",
                    "marshal_ns_total",
                ] {
                    if shard.get(field).as_f64().is_none() {
                        return Err(format!("shards[{i}].{field} missing or not a number"));
                    }
                }
            }
        }
    }
    // autotune is nullable but, when present, must carry its core fields
    let at = doc.get("autotune");
    if !matches!(at, Json::Null) {
        for field in ["plan_version", "replans", "swaps", "drift_events"] {
            if at.get(field).as_f64().is_none() {
                return Err(format!("autotune.{field} missing or not a number"));
            }
        }
        if at.get("active_plan").as_str().and_then(Plan::parse).is_none() {
            return Err("autotune.active_plan missing or unparseable".to_string());
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Prometheus text format
// ---------------------------------------------------------------------

fn prom_escape(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"")
}

fn prom_line(out: &mut String, name: &str, labels: &[(&str, String)], value: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("{k}=\"{}\"", prom_escape(v)));
        }
        out.push('}');
    }
    out.push_str(&format!(" {value}\n"));
}

fn prom_head(out: &mut String, name: &str, kind: &str, help: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

/// Render a [`MetricsSnapshot`], the attribution table, and the
/// flight-recorder counters in the Prometheus text exposition format.
pub fn prometheus_text(
    snap: &MetricsSnapshot,
    attribution: &[(AttrKey, AttrCell)],
    recorder: &RecorderStats,
) -> String {
    let mut out = String::new();
    prom_head(&mut out, "spfft_submitted_total", "counter", "Requests accepted into the queue");
    prom_line(&mut out, "spfft_submitted_total", &[], snap.submitted as f64);
    prom_head(&mut out, "spfft_completed_total", "counter", "Requests completed, by transform kind");
    for kind in ALL_KINDS {
        prom_line(
            &mut out,
            "spfft_completed_total",
            &[("kind", kind.name().to_string())],
            snap.completed_by_kind[kind.index()] as f64,
        );
    }
    prom_head(&mut out, "spfft_failed_total", "counter", "Requests failed or rejected");
    prom_line(&mut out, "spfft_failed_total", &[], snap.failed as f64);
    prom_head(
        &mut out,
        "spfft_rejected_total",
        "counter",
        "Rejections by reason (queue_full, shutting_down, invalid, shed)",
    );
    for (reason, count) in [
        ("queue_full", snap.rejected_full),
        ("shutting_down", snap.rejected_stopped),
        ("invalid", snap.rejected_invalid),
        ("shed", snap.rejected_shed),
    ] {
        prom_line(&mut out, "spfft_rejected_total", &[("reason", reason.to_string())], count as f64);
    }
    prom_head(&mut out, "spfft_batches_total", "counter", "Batches pulled by workers");
    prom_line(&mut out, "spfft_batches_total", &[], snap.batches as f64);
    prom_head(&mut out, "spfft_groups_total", "counter", "Same-(kind, n) groups executed");
    prom_line(&mut out, "spfft_groups_total", &[], snap.groups as f64);
    prom_head(&mut out, "spfft_group_size_hist", "gauge", "Groups per batch class (ceil-log2 size)");
    for (class, &count) in snap.group_size_hist.iter().enumerate() {
        prom_line(&mut out, "spfft_group_size_hist", &[("class", class.to_string())], count as f64);
    }
    prom_head(&mut out, "spfft_coalesced_flushes_total", "counter", "Held groups flushed");
    prom_line(&mut out, "spfft_coalesced_flushes_total", &[], snap.coalesced_flushes as f64);
    prom_head(&mut out, "spfft_coalesce_hits_total", "counter", "Held groups that gained members");
    prom_line(&mut out, "spfft_coalesce_hits_total", &[], snap.coalesce_hits as f64);
    prom_head(&mut out, "spfft_singleton_pairings_total", "counter", "Singletons paired across pulls");
    prom_line(&mut out, "spfft_singleton_pairings_total", &[], snap.singleton_pairings as f64);
    prom_head(
        &mut out,
        "spfft_exec_groups_total",
        "counter",
        "Native groups executed, by execution mode (panel = lane-blocked batch, scalar = sequential in place)",
    );
    for (mode, count) in [("panel", snap.exec_panel_groups), ("scalar", snap.exec_scalar_groups)] {
        prom_line(&mut out, "spfft_exec_groups_total", &[("mode", mode.to_string())], count as f64);
    }
    prom_head(
        &mut out,
        "spfft_exec_requests_total",
        "counter",
        "Requests executed through native groups, by execution mode",
    );
    for (mode, count) in
        [("panel", snap.exec_panel_requests), ("scalar", snap.exec_scalar_requests)]
    {
        prom_line(&mut out, "spfft_exec_requests_total", &[("mode", mode.to_string())], count as f64);
    }
    prom_head(
        &mut out,
        "spfft_marshal_ns_total",
        "counter",
        "Time spent marshalling panels (gather + scatter round trip, ns)",
    );
    prom_line(&mut out, "spfft_marshal_ns_total", &[], snap.marshal_time.as_nanos() as f64);
    prom_head(
        &mut out,
        "spfft_twiddle_intern_total",
        "counter",
        "Twiddle-table intern lookups since service start, by outcome (hit = table reused, miss = first-time construction)",
    );
    for (outcome, count) in [("hit", snap.twiddle_hits), ("miss", snap.twiddle_misses)] {
        prom_line(
            &mut out,
            "spfft_twiddle_intern_total",
            &[("outcome", outcome.to_string())],
            count as f64,
        );
    }
    prom_head(&mut out, "spfft_latency_ns", "gauge", "Request latency percentiles (ns)");
    for (q, d) in [
        ("p50", snap.latency_p50),
        ("p95", snap.latency_p95),
        ("p99", snap.latency_p99),
        ("max", snap.latency_max),
    ] {
        prom_line(&mut out, "spfft_latency_ns", &[("quantile", q.to_string())], d.as_nanos() as f64);
    }
    prom_head(&mut out, "spfft_held_age_ns", "gauge", "Coalesce hold age at flush (ns)");
    prom_line(&mut out, "spfft_held_age_ns", &[("stat", "mean".into())], snap.mean_held_age.as_nanos() as f64);
    prom_line(&mut out, "spfft_held_age_ns", &[("stat", "max".into())], snap.max_held_age.as_nanos() as f64);
    prom_head(&mut out, "spfft_busy_ns_total", "counter", "Total worker busy time (ns)");
    prom_line(&mut out, "spfft_busy_ns_total", &[], snap.busy.as_nanos() as f64);
    prom_head(
        &mut out,
        "spfft_recorder_events_total",
        "counter",
        "Flight-recorder events ever recorded (including overwritten)",
    );
    prom_line(&mut out, "spfft_recorder_events_total", &[], recorder.recorded as f64);
    prom_head(
        &mut out,
        "spfft_recorder_dropped_total",
        "counter",
        "Flight-recorder events lost to ring overwrite",
    );
    prom_line(&mut out, "spfft_recorder_dropped_total", &[], recorder.dropped as f64);
    prom_head(&mut out, "spfft_recorder_capacity", "gauge", "Flight-recorder ring capacity");
    prom_line(&mut out, "spfft_recorder_capacity", &[], recorder.capacity as f64);

    prom_head(
        &mut out,
        "spfft_edge_observed_ns_total",
        "counter",
        "Observed whole-batch ns per (kind, isa, class, stage, edge, ctx) attribution cell",
    );
    let cell_labels = |(kind, isa, class, stage, edge, ctx): &AttrKey| {
        vec![
            ("kind", kind.name().to_string()),
            ("isa", isa.name().to_string()),
            ("class", class.to_string()),
            ("stage", stage.to_string()),
            ("edge", edge.name().to_string()),
            ("ctx", ctx_label(*ctx)),
        ]
    };
    for (key, cell) in attribution {
        prom_line(&mut out, "spfft_edge_observed_ns_total", &cell_labels(key), cell.observed_ns);
    }
    prom_head(
        &mut out,
        "spfft_edge_transforms_total",
        "counter",
        "Transforms covered per attribution cell",
    );
    for (key, cell) in attribution {
        prom_line(&mut out, "spfft_edge_transforms_total", &cell_labels(key), cell.transforms as f64);
    }
    prom_head(
        &mut out,
        "spfft_edge_believed_ns",
        "gauge",
        "Cost model's believed per-transform ns for the cell's surface",
    );
    prom_head(
        &mut out,
        "spfft_edge_residual_ns",
        "gauge",
        "Observed-minus-believed per-transform ns",
    );
    for (key, cell) in attribution {
        if cell.has_believed {
            prom_line(&mut out, "spfft_edge_believed_ns", &cell_labels(key), cell.believed_ns);
            prom_line(
                &mut out,
                "spfft_edge_residual_ns",
                &cell_labels(key),
                cell.residual_ns().unwrap_or(0.0),
            );
        }
    }
    out
}

/// Sharded variant of [`prometheus_text`]: fleet-aggregate families
/// exactly as the single-shard exposition renders them, plus per-shard
/// `spfft_shard_*` families labeled `shard="i"` so overload and
/// coalescing are attributable to the shard that saw them.
pub fn prometheus_text_sharded(
    shards: &[MetricsSnapshot],
    attribution: &[(AttrKey, AttrCell)],
    recorder: &RecorderStats,
) -> String {
    let total = MetricsSnapshot::aggregate(shards);
    let mut out = prometheus_text(&total, attribution, recorder);
    prom_head(&mut out, "spfft_shard_submitted_total", "counter", "Requests accepted, per shard");
    for (i, s) in shards.iter().enumerate() {
        prom_line(
            &mut out,
            "spfft_shard_submitted_total",
            &[("shard", i.to_string())],
            s.submitted as f64,
        );
    }
    prom_head(&mut out, "spfft_shard_completed_total", "counter", "Requests completed, per shard");
    for (i, s) in shards.iter().enumerate() {
        prom_line(
            &mut out,
            "spfft_shard_completed_total",
            &[("shard", i.to_string())],
            s.completed as f64,
        );
    }
    prom_head(
        &mut out,
        "spfft_shard_rejected_total",
        "counter",
        "Rejections by reason, per shard",
    );
    for (i, s) in shards.iter().enumerate() {
        for (reason, count) in [
            ("queue_full", s.rejected_full),
            ("shutting_down", s.rejected_stopped),
            ("invalid", s.rejected_invalid),
            ("shed", s.rejected_shed),
        ] {
            prom_line(
                &mut out,
                "spfft_shard_rejected_total",
                &[("shard", i.to_string()), ("reason", reason.to_string())],
                count as f64,
            );
        }
    }
    prom_head(
        &mut out,
        "spfft_shard_coalesce_hits_total",
        "counter",
        "Held groups that gained members, per shard",
    );
    for (i, s) in shards.iter().enumerate() {
        prom_line(
            &mut out,
            "spfft_shard_coalesce_hits_total",
            &[("shard", i.to_string())],
            s.coalesce_hits as f64,
        );
    }
    prom_head(
        &mut out,
        "spfft_shard_exec_groups_total",
        "counter",
        "Native groups executed by execution mode, per shard",
    );
    for (i, s) in shards.iter().enumerate() {
        for (mode, count) in [("panel", s.exec_panel_groups), ("scalar", s.exec_scalar_groups)] {
            prom_line(
                &mut out,
                "spfft_shard_exec_groups_total",
                &[("shard", i.to_string()), ("mode", mode.to_string())],
                count as f64,
            );
        }
    }
    prom_head(
        &mut out,
        "spfft_shard_marshal_ns_total",
        "counter",
        "Panel marshal time per shard (ns)",
    );
    for (i, s) in shards.iter().enumerate() {
        prom_line(
            &mut out,
            "spfft_shard_marshal_ns_total",
            &[("shard", i.to_string())],
            s.marshal_time.as_nanos() as f64,
        );
    }
    prom_head(
        &mut out,
        "spfft_shard_latency_ns",
        "gauge",
        "Request latency percentiles per shard (ns)",
    );
    for (i, s) in shards.iter().enumerate() {
        for (q, d) in [
            ("p50", s.latency_p50),
            ("p95", s.latency_p95),
            ("p99", s.latency_p99),
            ("max", s.latency_max),
        ] {
            prom_line(
                &mut out,
                "spfft_shard_latency_ns",
                &[("shard", i.to_string()), ("quantile", q.to_string())],
                d.as_nanos() as f64,
            );
        }
    }
    out
}

/// Validate Prometheus text output: the core metric families (including
/// the flight-recorder counters and the rejection decomposition) must be
/// present, every sample line must parse as `name[{labels}] value`,
/// every attribution sample must carry the full six-label cell key, and
/// every `spfft_shard_*` sample must carry its `shard` label.
pub fn schema_check_prometheus(text: &str) -> Result<(), String> {
    let required = [
        "spfft_submitted_total",
        "spfft_completed_total",
        "spfft_failed_total",
        "spfft_rejected_total",
        "spfft_batches_total",
        "spfft_groups_total",
        "spfft_exec_groups_total",
        "spfft_exec_requests_total",
        "spfft_marshal_ns_total",
        "spfft_twiddle_intern_total",
        "spfft_latency_ns",
        "spfft_recorder_events_total",
        "spfft_recorder_dropped_total",
    ];
    for name in required {
        if !text.lines().any(|l| !l.starts_with('#') && l.starts_with(name)) {
            return Err(format!("required metric family {name} has no samples"));
        }
    }
    for (lineno, line) in text.lines().enumerate() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let err = |what: &str| Err(format!("line {}: {what}: {line}", lineno + 1));
        let (name_labels, value) = match line.rsplit_once(' ') {
            Some(parts) => parts,
            None => return err("no value"),
        };
        if value.parse::<f64>().is_err() {
            return err("value is not a number");
        }
        let name = name_labels.split('{').next().unwrap_or("");
        if name.is_empty() || !name.starts_with("spfft_") {
            return err("metric name must start with spfft_");
        }
        if name_labels.contains('{') && !name_labels.ends_with('}') {
            return err("unterminated label set");
        }
        if name == "spfft_edge_observed_ns_total" {
            for label in ["kind=", "isa=", "class=", "stage=", "edge=", "ctx="] {
                if !name_labels.contains(label) {
                    return err(&format!("attribution sample missing {label} label"));
                }
            }
        }
        if name.starts_with("spfft_shard_") && !name_labels.contains("shard=") {
            return err("per-shard sample missing shard= label");
        }
        if name == "spfft_rejected_total" && !name_labels.contains("reason=") {
            return err("rejection sample missing reason= label");
        }
        if (name == "spfft_exec_groups_total" || name == "spfft_exec_requests_total")
            && !name_labels.contains("mode=")
        {
            return err("execution-mode sample missing mode= label");
        }
        if name == "spfft_twiddle_intern_total" && !name_labels.contains("outcome=") {
            return err("twiddle intern sample missing outcome= label");
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// event stream (spfft.events.v1)
// ---------------------------------------------------------------------

fn plan_json(p: &Plan) -> Json {
    s(p.to_string())
}

fn worst_json(worst: &Option<(EdgeType, usize, Context)>) -> Json {
    match worst {
        None => Json::Null,
        Some((e, stage, ctx)) => obj(vec![
            ("edge", s(e.name())),
            ("stage", num(*stage as f64)),
            ("ctx", s(ctx_label(*ctx))),
        ]),
    }
}

fn event_json(e: &Event) -> Json {
    let mut pairs = vec![
        ("seq", num(e.seq as f64)),
        ("t_ns", num(e.t_ns as f64)),
        ("type", s(e.kind.tag())),
    ];
    match &e.kind {
        EventKind::Submit { req, kind, n } => {
            pairs.push(("req", num(*req as f64)));
            pairs.push(("kind", s(kind.name())));
            pairs.push(("n", num(*n as f64)));
        }
        EventKind::Rejected { kind, n, reason } => {
            pairs.push(("kind", s(kind.name())));
            pairs.push(("n", num(*n as f64)));
            pairs.push(("reason", s(reason.clone())));
        }
        EventKind::CoalesceHold { kind, n, size, held_windows } => {
            pairs.push(("kind", s(kind.name())));
            pairs.push(("n", num(*n as f64)));
            pairs.push(("size", num(*size as f64)));
            pairs.push(("held_windows", num(*held_windows as f64)));
        }
        EventKind::GroupFormed { kind, n, size, held_windows, paired_singletons } => {
            pairs.push(("kind", s(kind.name())));
            pairs.push(("n", num(*n as f64)));
            pairs.push(("size", num(*size as f64)));
            pairs.push(("held_windows", num(*held_windows as f64)));
            pairs.push(("paired_singletons", Json::Bool(*paired_singletons)));
        }
        EventKind::CoalesceFlush {
            kind,
            n,
            size,
            held_windows,
            held_age_ns,
            gained,
            paired_singletons,
            reason,
        } => {
            pairs.push(("kind", s(kind.name())));
            pairs.push(("n", num(*n as f64)));
            pairs.push(("size", num(*size as f64)));
            pairs.push(("held_windows", num(*held_windows as f64)));
            pairs.push(("held_age_ns", num(*held_age_ns as f64)));
            pairs.push(("gained", num(*gained as f64)));
            pairs.push(("paired_singletons", Json::Bool(*paired_singletons)));
            pairs.push(("reason", s(reason.clone())));
        }
        EventKind::RequestDone {
            req,
            kind,
            n,
            group_size,
            queue_ns,
            held_ns,
            exec_ns,
            total_ns,
            stages,
        } => {
            pairs.push(("req", num(*req as f64)));
            pairs.push(("kind", s(kind.name())));
            pairs.push(("n", num(*n as f64)));
            pairs.push(("group_size", num(*group_size as f64)));
            pairs.push(("queue_ns", num(*queue_ns as f64)));
            pairs.push(("held_ns", num(*held_ns as f64)));
            pairs.push(("exec_ns", num(*exec_ns as f64)));
            pairs.push(("total_ns", num(*total_ns as f64)));
            pairs.push((
                "stages",
                Json::Arr(
                    stages
                        .iter()
                        .map(|(e, stage, ns)| {
                            obj(vec![
                                ("edge", s(e.name())),
                                ("stage", num(*stage as f64)),
                                ("ns", num(*ns)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        EventKind::Drift { checks, cells_checked, cells_over, max_rel_dev, worst } => {
            pairs.push(("checks", num(*checks as f64)));
            pairs.push(("cells_checked", num(*cells_checked as f64)));
            pairs.push(("cells_over", num(*cells_over as f64)));
            pairs.push(("max_rel_dev", num(*max_rel_dev)));
            pairs.push(("worst", worst_json(worst)));
        }
        EventKind::Replan { kind, class, plan, cost_ns } => {
            pairs.push(("kind", s(kind.name())));
            pairs.push(("class", num(*class as f64)));
            pairs.push(("plan", plan_json(plan)));
            pairs.push(("cost_ns", num(*cost_ns)));
        }
        EventKind::Swap { version, old_plan, old_cost_ns, new_plan, new_cost_ns } => {
            pairs.push(("version", num(*version as f64)));
            pairs.push(("old_plan", plan_json(old_plan)));
            pairs.push(("old_cost_ns", num(*old_cost_ns)));
            pairs.push(("new_plan", plan_json(new_plan)));
            pairs.push(("new_cost_ns", num(*new_cost_ns)));
        }
        EventKind::SwapDeclined { plan, cost_ns, current_cost_ns } => {
            pairs.push(("plan", plan_json(plan)));
            pairs.push(("cost_ns", num(*cost_ns)));
            pairs.push(("current_cost_ns", num(*current_cost_ns)));
        }
    }
    obj(pairs)
}

/// Serialize a flight-recorder dump as the versioned event-stream
/// document (`spfft serve --obs-out` writes it, `spfft obs --dump`
/// replays it).
pub fn events_json(events: &[Event]) -> Json {
    obj(vec![
        ("schema", s("spfft.events.v1")),
        ("events", Json::Arr(events.iter().map(event_json).collect())),
    ])
}

fn get_u64(v: &Json, field: &str, at: &str) -> Result<u64, String> {
    v.get(field)
        .as_f64()
        .map(|x| x as u64)
        .ok_or_else(|| format!("{at}: {field} missing or not a number"))
}

fn get_usize(v: &Json, field: &str, at: &str) -> Result<usize, String> {
    v.get(field).as_usize().ok_or_else(|| format!("{at}: {field} missing or not a number"))
}

fn get_f64(v: &Json, field: &str, at: &str) -> Result<f64, String> {
    v.get(field).as_f64().ok_or_else(|| format!("{at}: {field} missing or not a number"))
}

fn get_kind(v: &Json, at: &str) -> Result<TransformKind, String> {
    v.get("kind")
        .as_str()
        .and_then(TransformKind::parse)
        .ok_or_else(|| format!("{at}: kind missing or unknown"))
}

fn get_plan(v: &Json, field: &str, at: &str) -> Result<Plan, String> {
    v.get(field)
        .as_str()
        .and_then(Plan::parse)
        .ok_or_else(|| format!("{at}: {field} missing or unparseable"))
}

/// Parse a `spfft.events.v1` document back into events.
pub fn events_from_json(doc: &Json) -> Result<Vec<Event>, String> {
    if doc.get("schema").as_str() != Some("spfft.events.v1") {
        return Err(format!(
            "schema tag mismatch: want \"spfft.events.v1\", got {}",
            json::to_string(doc.get("schema"))
        ));
    }
    let arr = doc.get("events").as_arr().ok_or("events missing or not an array")?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, v) in arr.iter().enumerate() {
        let at = format!("events[{i}]");
        let tag = v.get("type").as_str().ok_or_else(|| format!("{at}: type missing"))?;
        let kind = match tag {
            "submit" => EventKind::Submit {
                req: get_u64(v, "req", &at)?,
                kind: get_kind(v, &at)?,
                n: get_usize(v, "n", &at)?,
            },
            "rejected" => EventKind::Rejected {
                kind: get_kind(v, &at)?,
                n: get_usize(v, "n", &at)?,
                reason: v
                    .get("reason")
                    .as_str()
                    .ok_or_else(|| format!("{at}: reason missing"))?
                    .to_string(),
            },
            "coalesce_hold" => EventKind::CoalesceHold {
                kind: get_kind(v, &at)?,
                n: get_usize(v, "n", &at)?,
                size: get_usize(v, "size", &at)?,
                held_windows: get_u64(v, "held_windows", &at)? as u32,
            },
            "group_formed" => EventKind::GroupFormed {
                kind: get_kind(v, &at)?,
                n: get_usize(v, "n", &at)?,
                size: get_usize(v, "size", &at)?,
                held_windows: get_u64(v, "held_windows", &at)? as u32,
                paired_singletons: v.get("paired_singletons").as_bool().unwrap_or(false),
            },
            "coalesce_flush" => EventKind::CoalesceFlush {
                kind: get_kind(v, &at)?,
                n: get_usize(v, "n", &at)?,
                size: get_usize(v, "size", &at)?,
                held_windows: get_u64(v, "held_windows", &at)? as u32,
                held_age_ns: get_u64(v, "held_age_ns", &at)?,
                gained: get_usize(v, "gained", &at)?,
                paired_singletons: v.get("paired_singletons").as_bool().unwrap_or(false),
                reason: v
                    .get("reason")
                    .as_str()
                    .ok_or_else(|| format!("{at}: reason missing"))?
                    .to_string(),
            },
            "request_done" => {
                let mut stages = Vec::new();
                for (j, sv) in v.get("stages").as_arr().unwrap_or(&[]).iter().enumerate() {
                    let sat = format!("{at}.stages[{j}]");
                    let edge = sv
                        .get("edge")
                        .as_str()
                        .and_then(EdgeType::parse)
                        .ok_or_else(|| format!("{sat}: edge missing or unknown"))?;
                    stages.push((edge, get_usize(sv, "stage", &sat)?, get_f64(sv, "ns", &sat)?));
                }
                EventKind::RequestDone {
                    req: get_u64(v, "req", &at)?,
                    kind: get_kind(v, &at)?,
                    n: get_usize(v, "n", &at)?,
                    group_size: get_usize(v, "group_size", &at)?,
                    queue_ns: get_u64(v, "queue_ns", &at)?,
                    held_ns: get_u64(v, "held_ns", &at)?,
                    exec_ns: get_u64(v, "exec_ns", &at)?,
                    total_ns: get_u64(v, "total_ns", &at)?,
                    stages,
                }
            }
            "drift" => {
                let worst = match v.get("worst") {
                    Json::Null => None,
                    w => Some((
                        w.get("edge")
                            .as_str()
                            .and_then(EdgeType::parse)
                            .ok_or_else(|| format!("{at}: worst.edge missing or unknown"))?,
                        get_usize(w, "stage", &at)?,
                        w.get("ctx")
                            .as_str()
                            .and_then(ctx_from_label)
                            .ok_or_else(|| format!("{at}: worst.ctx missing or unknown"))?,
                    )),
                };
                EventKind::Drift {
                    checks: get_u64(v, "checks", &at)?,
                    cells_checked: get_usize(v, "cells_checked", &at)?,
                    cells_over: get_usize(v, "cells_over", &at)?,
                    max_rel_dev: get_f64(v, "max_rel_dev", &at)?,
                    worst,
                }
            }
            "replan" => EventKind::Replan {
                kind: get_kind(v, &at)?,
                class: get_usize(v, "class", &at)?,
                plan: get_plan(v, "plan", &at)?,
                cost_ns: get_f64(v, "cost_ns", &at)?,
            },
            "swap" => EventKind::Swap {
                version: get_u64(v, "version", &at)?,
                old_plan: get_plan(v, "old_plan", &at)?,
                old_cost_ns: get_f64(v, "old_cost_ns", &at)?,
                new_plan: get_plan(v, "new_plan", &at)?,
                new_cost_ns: get_f64(v, "new_cost_ns", &at)?,
            },
            "swap_declined" => EventKind::SwapDeclined {
                plan: get_plan(v, "plan", &at)?,
                cost_ns: get_f64(v, "cost_ns", &at)?,
                current_cost_ns: get_f64(v, "current_cost_ns", &at)?,
            },
            other => return Err(format!("{at}: unknown event type \"{other}\"")),
        };
        out.push(Event { seq: get_u64(v, "seq", &at)?, t_ns: get_u64(v, "t_ns", &at)?, kind });
    }
    Ok(out)
}

/// Pretty-print an event stream as a timeline, one event per line.
pub fn render_events(events: &[Event]) -> String {
    let mut out = String::new();
    for e in events {
        let t_us = e.t_ns as f64 / 1000.0;
        let detail = match &e.kind {
            EventKind::Submit { req, kind, n } => format!("req #{req} {kind} n={n}"),
            EventKind::Rejected { kind, n, reason } => format!("{kind} n={n} rejected: {reason}"),
            EventKind::CoalesceHold { kind, n, size, held_windows } => {
                format!("{kind} n={n} size={size} held for window {held_windows}")
            }
            EventKind::GroupFormed { kind, n, size, held_windows, paired_singletons } => format!(
                "{kind} n={n} size={size} held_windows={held_windows}{}",
                if *paired_singletons { " paired-singleton" } else { "" }
            ),
            EventKind::CoalesceFlush { kind, n, size, held_windows, held_age_ns, gained, reason, .. } => {
                format!(
                    "{kind} n={n} size={size} after {held_windows} windows \
                     ({:.1} us held, +{gained} gained): {reason}",
                    *held_age_ns as f64 / 1000.0
                )
            }
            EventKind::RequestDone { req, kind, n, group_size, queue_ns, held_ns, exec_ns, total_ns, stages } => {
                let stage_txt = if stages.is_empty() {
                    String::new()
                } else {
                    let parts: Vec<String> = stages
                        .iter()
                        .map(|(e, stg, ns)| format!("{e}@{stg}={ns:.0}ns"))
                        .collect();
                    format!(" [{}]", parts.join(" "))
                };
                format!(
                    "req #{req} {kind} n={n} group={group_size}: \
                     {total_ns}ns = queue {queue_ns} + held {held_ns} + exec {exec_ns}{stage_txt}"
                )
            }
            EventKind::Drift { checks, cells_checked, cells_over, max_rel_dev, worst } => {
                let worst_txt = match worst {
                    Some((e, stg, ctx)) => format!(" worst {e}@{stg} in {ctx}"),
                    None => String::new(),
                };
                format!(
                    "check #{checks}: {cells_over}/{cells_checked} cells over, \
                     max dev {:.1}%{worst_txt}",
                    100.0 * max_rel_dev
                )
            }
            EventKind::Replan { kind, class, plan, cost_ns } => {
                format!("{kind} class {class}: found {plan} ({cost_ns:.0} ns)")
            }
            EventKind::Swap { version, old_plan, old_cost_ns, new_plan, new_cost_ns } => format!(
                "v{version}: {old_plan} ({old_cost_ns:.0} ns) -> {new_plan} ({new_cost_ns:.0} ns)"
            ),
            EventKind::SwapDeclined { plan, cost_ns, current_cost_ns } => format!(
                "{plan} ({cost_ns:.0} ns) vs current ({current_cost_ns:.0} ns): under hysteresis"
            ),
        };
        out.push_str(&format!("[{t_us:>12.3} us] #{:<6} {:<14} {detail}\n", e.seq, e.kind.tag()));
    }
    out
}

/// Extract the autotune decision audit: every drift → replan →
/// swap/declined chain, in event order. Each returned line is one
/// decision step; a chain renders as consecutive lines.
pub fn audit_trail(events: &[Event]) -> Vec<String> {
    let mut out = Vec::new();
    for e in events {
        match &e.kind {
            EventKind::Drift { cells_over, cells_checked, max_rel_dev, .. } => out.push(format!(
                "drift detected at t={} ns: {cells_over}/{cells_checked} cells over (max {:.1}%)",
                e.t_ns,
                100.0 * max_rel_dev
            )),
            EventKind::Replan { kind, class, plan, cost_ns } => out.push(format!(
                "replanned {kind} at batch class {class}: {plan} believed {cost_ns:.0} ns"
            )),
            EventKind::Swap { version, old_plan, old_cost_ns, new_plan, new_cost_ns } => {
                out.push(format!(
                    "swapped to v{version}: {old_plan} (believed {old_cost_ns:.0} ns) -> \
                     {new_plan} (believed {new_cost_ns:.0} ns)"
                ))
            }
            EventKind::SwapDeclined { plan, cost_ns, current_cost_ns } => out.push(format!(
                "declined swap: {plan} ({cost_ns:.0} ns) vs current {current_cost_ns:.0} ns"
            )),
            _ => {}
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn sample_snapshot() -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: 10,
            completed: 9,
            completed_by_kind: [4, 2, 2, 1],
            failed: 1,
            rejected_full: 1,
            rejected_stopped: 0,
            rejected_invalid: 0,
            rejected_shed: 0,
            batches: 3,
            mean_batch_size: 3.0,
            groups: 4,
            mean_group_size: 2.25,
            group_size_hist: [2, 1, 1, 0, 0, 0, 0, 0],
            coalesced_flushes: 2,
            coalesce_hits: 1,
            coalesce_hit_rate: 0.5,
            singleton_pairings: 1,
            mean_held_age: Duration::from_micros(300),
            max_held_age: Duration::from_micros(500),
            exec_panel_groups: 3,
            exec_scalar_groups: 1,
            exec_panel_requests: 7,
            exec_scalar_requests: 2,
            marshal_time: Duration::from_micros(120),
            twiddle_hits: 6,
            twiddle_misses: 2,
            twiddle_hit_rate: 0.75,
            busy: Duration::from_micros(900),
            latency_p50: Duration::from_micros(10),
            latency_p95: Duration::from_micros(40),
            latency_p99: Duration::from_micros(80),
            latency_max: Duration::from_micros(100),
        }
    }

    fn sample_cells() -> Vec<(AttrKey, AttrCell)> {
        vec![
            (
                (TransformKind::Forward, Isa::Scalar, 0, 0, EdgeType::R4, Context::Start),
                AttrCell {
                    observed_ns: 120.0,
                    transforms: 2,
                    samples: 2,
                    believed_ns: 55.0,
                    has_believed: true,
                },
            ),
            (
                (
                    TransformKind::RealForward,
                    Isa::Neon,
                    2,
                    0,
                    EdgeType::RU,
                    Context::After(EdgeType::F8),
                ),
                AttrCell { observed_ns: 30.0, transforms: 4, samples: 1, ..Default::default() },
            ),
        ]
    }

    fn sample_recorder() -> RecorderStats {
        RecorderStats { capacity: 64, recorded: 100, dropped: 36 }
    }

    #[test]
    fn snapshot_json_round_trips_through_parse_and_validates() {
        let doc = snapshot_json(&sample_snapshot(), &sample_cells(), &sample_recorder(), None);
        let text = json::to_string(&doc);
        let parsed = json::parse(&text).unwrap();
        schema_check_snapshot(&parsed).unwrap();
        assert_eq!(parsed.get("counters").get("submitted").as_usize(), Some(10));
        assert_eq!(parsed.get("counters").get("twiddle_hits").as_usize(), Some(6));
        assert_eq!(parsed.get("counters").get("twiddle_misses").as_usize(), Some(2));
        assert_eq!(parsed.get("counters").get("twiddle_hit_rate").as_f64(), Some(0.75));
        assert_eq!(
            parsed.get("counters").get("completed_by_kind").get("inverse").as_usize(),
            Some(2)
        );
        assert_eq!(parsed.get("recorder").get("capacity").as_usize(), Some(64));
        assert_eq!(parsed.get("recorder").get("recorded").as_usize(), Some(100));
        assert_eq!(parsed.get("recorder").get("dropped").as_usize(), Some(36));
        let cells = parsed.get("attribution").as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].get("edge").as_str(), Some("R4"));
        assert_eq!(cells[0].get("isa").as_str(), Some("scalar"));
        assert_eq!(cells[0].get("believed_ns").as_f64(), Some(55.0));
        assert_eq!(cells[0].get("residual_ns").as_f64(), Some(5.0));
        assert_eq!(cells[1].get("ctx").as_str(), Some("after_F8"));
        assert_eq!(cells[1].get("isa").as_str(), Some("neon"));
        assert!(matches!(cells[1].get("believed_ns"), Json::Null));
    }

    #[test]
    fn schema_check_rejects_missing_fields() {
        let doc = snapshot_json(&sample_snapshot(), &[], &sample_recorder(), None);
        let mut text = json::to_string(&doc);
        schema_check_snapshot(&json::parse(&text).unwrap()).unwrap();
        // rename a counter: must fail
        text = text.replace("\"submitted\"", "\"submitted_renamed\"");
        let err = schema_check_snapshot(&json::parse(&text).unwrap()).unwrap_err();
        assert!(err.contains("submitted"), "unhelpful error: {err}");
        // wrong schema tag: must fail
        let bad = json::parse(
            &json::to_string(&snapshot_json(&sample_snapshot(), &[], &sample_recorder(), None))
                .replace("spfft.metrics.v1", "spfft.metrics.v0"),
        )
        .unwrap();
        assert!(schema_check_snapshot(&bad).is_err());
    }

    #[test]
    fn recorder_counters_are_gated_by_the_schema_checks() {
        // JSON: renaming the drop counter is a hard error
        let doc = snapshot_json(&sample_snapshot(), &sample_cells(), &sample_recorder(), None);
        let text = json::to_string(&doc);
        let renamed = text.replace("\"dropped\"", "\"lost\"");
        let err = schema_check_snapshot(&json::parse(&renamed).unwrap()).unwrap_err();
        assert!(err.contains("recorder.dropped"), "unhelpful error: {err}");
        // Prometheus: stripping the drop-counter family is a hard error
        let prom = prometheus_text(&sample_snapshot(), &sample_cells(), &sample_recorder());
        assert!(prom.contains("spfft_recorder_events_total 100"));
        assert!(prom.contains("spfft_recorder_dropped_total 36"));
        assert!(prom.contains("spfft_recorder_capacity 64"));
        let stripped: String = prom
            .lines()
            .filter(|l| !l.contains("spfft_recorder_dropped_total"))
            .map(|l| format!("{l}\n"))
            .collect();
        let err = schema_check_prometheus(&stripped).unwrap_err();
        assert!(err.contains("spfft_recorder_dropped_total"), "unhelpful error: {err}");
    }

    #[test]
    fn prometheus_text_validates_and_carries_cell_labels() {
        let text = prometheus_text(&sample_snapshot(), &sample_cells(), &sample_recorder());
        schema_check_prometheus(&text).unwrap();
        assert!(text.contains("spfft_submitted_total 10"));
        assert!(text.contains("spfft_completed_total{kind=\"forward\"} 4"));
        assert!(text.contains(
            "spfft_edge_observed_ns_total{kind=\"forward\",isa=\"scalar\",class=\"0\",\
             stage=\"0\",edge=\"R4\",ctx=\"start\"} 120"
        ));
        assert!(text.contains("spfft_edge_residual_ns"));
        // the believed-less RU cell exports observed but not believed,
        // and carries its own backend label
        assert!(text.contains("isa=\"neon\""));
        assert!(text.contains("edge=\"RU\",ctx=\"after_F8\"} 30"));
        assert!(!text.contains("spfft_edge_believed_ns{kind=\"real\""));
    }

    #[test]
    fn prometheus_check_catches_malformed_lines() {
        assert!(schema_check_prometheus("garbage").is_err());
        let mut text = prometheus_text(&sample_snapshot(), &sample_cells(), &sample_recorder());
        schema_check_prometheus(&text).unwrap();
        text.push_str("spfft_bad_line_no_value\n");
        assert!(schema_check_prometheus(&text).is_err());
        let stripped = prometheus_text(&sample_snapshot(), &sample_cells(), &sample_recorder())
            .replace("kind=\"forward\",isa=\"scalar\",", "");
        assert!(schema_check_prometheus(&stripped).is_err(), "missing cell labels not caught");
    }

    #[test]
    fn rejected_counters_export_and_are_gated() {
        // JSON: the rejection decomposition is present and schema-gated
        let doc = snapshot_json(&sample_snapshot(), &[], &sample_recorder(), None);
        let text = json::to_string(&doc);
        let parsed = json::parse(&text).unwrap();
        schema_check_snapshot(&parsed).unwrap();
        assert_eq!(parsed.get("counters").get("rejected_full").as_usize(), Some(1));
        assert_eq!(parsed.get("counters").get("rejected_shed").as_usize(), Some(0));
        let renamed = text.replace("\"rejected_shed\"", "\"rejected_other\"");
        let err = schema_check_snapshot(&json::parse(&renamed).unwrap()).unwrap_err();
        assert!(err.contains("rejected_shed"), "unhelpful error: {err}");
        // Prometheus: every reason gets a labeled sample, and both the
        // family and its reason label are schema-gated
        let prom = prometheus_text(&sample_snapshot(), &[], &sample_recorder());
        schema_check_prometheus(&prom).unwrap();
        assert!(prom.contains("spfft_rejected_total{reason=\"queue_full\"} 1"));
        assert!(prom.contains("spfft_rejected_total{reason=\"shed\"} 0"));
        let stripped: String = prom
            .lines()
            .filter(|l| !l.contains("spfft_rejected_total"))
            .map(|l| format!("{l}\n"))
            .collect();
        assert!(schema_check_prometheus(&stripped).is_err());
        let unlabeled = prom.replace(
            "spfft_rejected_total{reason=\"queue_full\"}",
            "spfft_rejected_total",
        );
        let err = schema_check_prometheus(&unlabeled).unwrap_err();
        assert!(err.contains("reason="), "unhelpful error: {err}");
    }

    #[test]
    fn exec_mode_and_marshal_export_and_are_gated() {
        // JSON: the exec-mode split and the marshal counter are present
        // and schema-gated
        let doc = snapshot_json(&sample_snapshot(), &[], &sample_recorder(), None);
        let text = json::to_string(&doc);
        let parsed = json::parse(&text).unwrap();
        schema_check_snapshot(&parsed).unwrap();
        assert_eq!(parsed.get("counters").get("exec_panel_groups").as_usize(), Some(3));
        assert_eq!(parsed.get("counters").get("exec_scalar_groups").as_usize(), Some(1));
        assert_eq!(parsed.get("counters").get("exec_panel_requests").as_usize(), Some(7));
        assert_eq!(parsed.get("counters").get("exec_scalar_requests").as_usize(), Some(2));
        assert_eq!(parsed.get("marshal_ns_total").as_usize(), Some(120_000));
        let renamed = text.replace("\"exec_panel_groups\"", "\"panel_groups\"");
        let err = schema_check_snapshot(&json::parse(&renamed).unwrap()).unwrap_err();
        assert!(err.contains("exec_panel_groups"), "unhelpful error: {err}");
        let renamed = text.replace("\"marshal_ns_total\"", "\"marshal_ns\"");
        let err = schema_check_snapshot(&json::parse(&renamed).unwrap()).unwrap_err();
        assert!(err.contains("marshal_ns_total"), "unhelpful error: {err}");
        // Prometheus: mode-labeled families plus the marshal counter,
        // all schema-gated
        let prom = prometheus_text(&sample_snapshot(), &[], &sample_recorder());
        schema_check_prometheus(&prom).unwrap();
        assert!(prom.contains("spfft_exec_groups_total{mode=\"panel\"} 3"));
        assert!(prom.contains("spfft_exec_groups_total{mode=\"scalar\"} 1"));
        assert!(prom.contains("spfft_exec_requests_total{mode=\"panel\"} 7"));
        assert!(prom.contains("spfft_exec_requests_total{mode=\"scalar\"} 2"));
        assert!(prom.contains("spfft_marshal_ns_total 120000"));
        assert!(prom.contains("spfft_twiddle_intern_total{outcome=\"hit\"} 6"));
        assert!(prom.contains("spfft_twiddle_intern_total{outcome=\"miss\"} 2"));
        let stripped: String = prom
            .lines()
            .filter(|l| !l.contains("spfft_marshal_ns_total"))
            .map(|l| format!("{l}\n"))
            .collect();
        assert!(schema_check_prometheus(&stripped).is_err());
        let unlabeled =
            prom.replace("spfft_exec_groups_total{mode=\"panel\"}", "spfft_exec_groups_total");
        let err = schema_check_prometheus(&unlabeled).unwrap_err();
        assert!(err.contains("mode="), "unhelpful error: {err}");
    }

    #[test]
    fn sharded_exports_carry_per_shard_labels_and_validate() {
        let mut shard1 = sample_snapshot();
        shard1.submitted = 7;
        shard1.completed = 5;
        shard1.rejected_shed = 2;
        shard1.coalesce_hits = 3;
        let shards = vec![sample_snapshot(), shard1];
        // JSON: aggregate counters on top, per-shard blocks in `shards`
        let doc = snapshot_json_sharded(&shards, &sample_cells(), &sample_recorder(), None);
        let text = json::to_string(&doc);
        let parsed = json::parse(&text).unwrap();
        schema_check_snapshot(&parsed).unwrap();
        assert_eq!(parsed.get("counters").get("submitted").as_usize(), Some(17));
        assert_eq!(parsed.get("counters").get("rejected_shed").as_usize(), Some(2));
        let arr = parsed.get("shards").as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("shard").as_usize(), Some(0));
        assert_eq!(arr[1].get("shard").as_usize(), Some(1));
        assert_eq!(arr[1].get("rejected_shed").as_usize(), Some(2));
        assert_eq!(arr[1].get("coalesce_hits").as_usize(), Some(3));
        assert_eq!(arr[1].get("exec_panel_groups").as_usize(), Some(3));
        assert_eq!(arr[1].get("marshal_ns_total").as_usize(), Some(120_000));
        // dropping a per-shard rejection counter is a hard error
        let broken = text.replace("\"rejected_stopped\"", "\"rejected_gone\"");
        assert!(schema_check_snapshot(&json::parse(&broken).unwrap()).is_err());
        // Prometheus: aggregate families plus shard-labeled families
        let prom = prometheus_text_sharded(&shards, &sample_cells(), &sample_recorder());
        schema_check_prometheus(&prom).unwrap();
        assert!(prom.contains("spfft_submitted_total 17"));
        assert!(prom.contains("spfft_shard_submitted_total{shard=\"0\"} 10"));
        assert!(prom.contains("spfft_shard_submitted_total{shard=\"1\"} 7"));
        assert!(prom.contains("spfft_shard_rejected_total{shard=\"1\",reason=\"shed\"} 2"));
        assert!(prom.contains("spfft_shard_coalesce_hits_total{shard=\"1\"} 3"));
        assert!(prom.contains("spfft_shard_exec_groups_total{shard=\"0\",mode=\"panel\"} 3"));
        assert!(prom.contains("spfft_shard_marshal_ns_total{shard=\"1\"} 120000"));
        // a shard sample without its shard label is a hard error
        let unlabeled = prom.replace("spfft_shard_submitted_total{shard=\"0\"}", "spfft_shard_submitted_total");
        let err = schema_check_prometheus(&unlabeled).unwrap_err();
        assert!(err.contains("shard="), "unhelpful error: {err}");
    }

    #[test]
    fn event_stream_round_trips_every_variant() {
        let plan = Plan::parse("R4,R4,R2,F8").unwrap();
        let plan2 = Plan::parse("R8,F8,R2,R2").unwrap();
        let events = vec![
            Event {
                seq: 0,
                t_ns: 100,
                kind: EventKind::Submit { req: 7, kind: TransformKind::RealInverse, n: 512 },
            },
            Event {
                seq: 1,
                t_ns: 200,
                kind: EventKind::CoalesceHold {
                    kind: TransformKind::Forward,
                    n: 256,
                    size: 2,
                    held_windows: 1,
                },
            },
            Event {
                seq: 9,
                t_ns: 850,
                kind: EventKind::Rejected {
                    kind: TransformKind::Forward,
                    n: 256,
                    reason: "queue_full".to_string(),
                },
            },
            Event {
                seq: 2,
                t_ns: 300,
                kind: EventKind::GroupFormed {
                    kind: TransformKind::Forward,
                    n: 256,
                    size: 4,
                    held_windows: 1,
                    paired_singletons: true,
                },
            },
            Event {
                seq: 3,
                t_ns: 300,
                kind: EventKind::CoalesceFlush {
                    kind: TransformKind::Forward,
                    n: 256,
                    size: 4,
                    held_windows: 1,
                    held_age_ns: 1500,
                    gained: 2,
                    paired_singletons: false,
                    reason: "Filled".to_string(),
                },
            },
            Event {
                seq: 4,
                t_ns: 400,
                kind: EventKind::RequestDone {
                    req: 7,
                    kind: TransformKind::Forward,
                    n: 256,
                    group_size: 4,
                    queue_ns: 100,
                    held_ns: 150,
                    exec_ns: 50,
                    total_ns: 300,
                    stages: vec![(EdgeType::R4, 0, 12.5), (EdgeType::F8, 5, 7.25)],
                },
            },
            Event {
                seq: 5,
                t_ns: 500,
                kind: EventKind::Drift {
                    checks: 3,
                    cells_checked: 20,
                    cells_over: 4,
                    max_rel_dev: 1.75,
                    worst: Some((EdgeType::R2, 1, Context::After(EdgeType::RU))),
                },
            },
            Event {
                seq: 6,
                t_ns: 600,
                kind: EventKind::Replan {
                    kind: TransformKind::Forward,
                    class: 4,
                    plan: plan2.clone(),
                    cost_ns: 900.0,
                },
            },
            Event {
                seq: 7,
                t_ns: 700,
                kind: EventKind::Swap {
                    version: 2,
                    old_plan: plan.clone(),
                    old_cost_ns: 1200.0,
                    new_plan: plan2.clone(),
                    new_cost_ns: 900.0,
                },
            },
            Event {
                seq: 8,
                t_ns: 800,
                kind: EventKind::SwapDeclined {
                    plan: plan.clone(),
                    cost_ns: 1000.0,
                    current_cost_ns: 1010.0,
                },
            },
        ];
        let text = json::to_string(&events_json(&events));
        let parsed = events_from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, events);
    }

    #[test]
    fn events_from_json_rejects_unknown_schema_and_types() {
        let doc = json::parse(r#"{"schema":"spfft.events.v2","events":[]}"#).unwrap();
        assert!(events_from_json(&doc).is_err());
        let doc = json::parse(
            r#"{"schema":"spfft.events.v1","events":[{"seq":0,"t_ns":0,"type":"mystery"}]}"#,
        )
        .unwrap();
        assert!(events_from_json(&doc).unwrap_err().contains("mystery"));
    }

    #[test]
    fn render_and_audit_trail_order_matches_events() {
        let plan = Plan::parse("R4,R4,R2,F8").unwrap();
        let plan2 = Plan::parse("R8,F8,R2,R2").unwrap();
        let events = vec![
            Event {
                seq: 0,
                t_ns: 100,
                kind: EventKind::Drift {
                    checks: 1,
                    cells_checked: 10,
                    cells_over: 2,
                    max_rel_dev: 0.8,
                    worst: None,
                },
            },
            Event {
                seq: 1,
                t_ns: 200,
                kind: EventKind::Replan {
                    kind: TransformKind::Forward,
                    class: 0,
                    plan: plan2.clone(),
                    cost_ns: 500.0,
                },
            },
            Event {
                seq: 2,
                t_ns: 300,
                kind: EventKind::Swap {
                    version: 2,
                    old_plan: plan,
                    old_cost_ns: 700.0,
                    new_plan: plan2,
                    new_cost_ns: 500.0,
                },
            },
        ];
        let audit = audit_trail(&events);
        assert_eq!(audit.len(), 3);
        assert!(audit[0].starts_with("drift detected"));
        assert!(audit[1].starts_with("replanned"));
        assert!(audit[2].starts_with("swapped to v2"));
        let rendered = render_events(&events);
        assert_eq!(rendered.lines().count(), 3);
        assert!(rendered.contains("drift"));
        assert!(rendered.contains("R8->F8->R2->R2"));
    }

    #[test]
    fn ctx_labels_round_trip() {
        for ctx in Context::all_with_boundary() {
            assert_eq!(ctx_from_label(&ctx_label(ctx)), Some(ctx));
        }
        assert_eq!(ctx_from_label("after-R4"), Some(Context::After(EdgeType::R4)));
        assert_eq!(ctx_from_label("after_R16"), None);
        assert_eq!(ctx_from_label(""), None);
    }
}
