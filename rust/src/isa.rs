//! Instruction-set architectures as a first-class planning axis.
//!
//! The paper's thesis is *SIMD instruction scheduling*: every edge weight
//! in Table 1 is a NEON instruction mix, and the availability of an edge
//! is an ISA property — `F32`'s 16-vector working set fits AArch64's
//! 32-register file but is "impossible on AVX2's 16-register file"
//! ([`crate::edge`], Table 1 comment). This module makes that axis
//! explicit:
//!
//! * [`crate::fft::simd`] — a codelet vtable per ISA; the executor picks
//!   one at plan-compile time ([`Isa::detect`]), so `NativeCost` measures
//!   the instruction mix the host actually runs;
//! * [`crate::cost::PlanningSurface`] — an optional `isa` axis: `None`
//!   plans for the cost model's native ISA (the historical behavior, all
//!   pinned plans unchanged), `Some(isa)` prices edges for a specific
//!   instruction set via [`crate::cost::CostModel::isa_edge_mult`];
//! * [`crate::graph::PlanningGraph`] — edge availability: register-file
//!   constraints become graph structure ([`Isa::supports`]), so an AVX2
//!   surface simply has no F32 edges to relax;
//! * [`crate::autotune`] — [`crate::autotune::EdgeSample`] and wisdom-v2
//!   records carry the ISA that produced each measurement, so the online
//!   model tunes the surface the host executes rather than a simulated
//!   one.
//!
//! The `SPFFT_FORCE_SCALAR` environment variable (set to anything but
//! `0`) forces [`Isa::detect`] to `Scalar` — the CI parity leg runs the
//! whole suite under it to pin the scalar fallback.

use std::fmt;

use crate::edge::EdgeType;

/// An instruction-set backend a kernel vtable can be compiled for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Isa {
    /// Plain scalar Rust — always available, the parity baseline.
    Scalar,
    /// `std::simd` portable vectors (nightly; behind the `portable-simd`
    /// cargo feature). 8-lane f32.
    Portable,
    /// AArch64 NEON: 32 × 128-bit vector registers, 4-lane f32. The
    /// paper's native target.
    Neon,
    /// x86-64 AVX2: 16 × 256-bit vector registers, 8-lane f32. Wider
    /// lanes, half the register count — F32 does not fit (Table 1).
    Avx2,
}

/// Number of ISAs (sizes per-ISA knob arrays, e.g. in
/// [`crate::sim::MachineParams`]).
pub const NUM_ISAS: usize = 4;

/// All ISAs, in [`Isa::index`] order.
pub const ALL_ISAS: [Isa; NUM_ISAS] = [Isa::Scalar, Isa::Portable, Isa::Neon, Isa::Avx2];

impl Isa {
    /// Canonical CLI / persistence name.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Portable => "portable",
            Isa::Neon => "neon",
            Isa::Avx2 => "avx2",
        }
    }

    /// Parse a canonical name.
    pub fn parse(s: &str) -> Option<Isa> {
        match s {
            "scalar" => Some(Isa::Scalar),
            "portable" => Some(Isa::Portable),
            "neon" => Some(Isa::Neon),
            "avx2" => Some(Isa::Avx2),
            _ => None,
        }
    }

    /// The valid-option list CLI parse errors print.
    pub fn valid_names() -> &'static str {
        "scalar|portable|neon|avx2"
    }

    /// Compact index in [0, [`NUM_ISAS`]).
    pub fn index(self) -> usize {
        match self {
            Isa::Scalar => 0,
            Isa::Portable => 1,
            Isa::Neon => 2,
            Isa::Avx2 => 3,
        }
    }

    /// Inverse of [`Isa::index`].
    pub fn from_index(i: usize) -> Option<Isa> {
        ALL_ISAS.get(i).copied()
    }

    /// Number of f32 lanes one vector register of this ISA holds (1 for
    /// the scalar baseline).
    pub fn lanes(self) -> usize {
        match self {
            Isa::Scalar => 1,
            Isa::Portable => 8,
            Isa::Neon => 4,
            Isa::Avx2 => 8,
        }
    }

    /// Size of the vector register file this ISA schedules against.
    pub fn vregs(self) -> usize {
        match self {
            // The scalar/portable paths leave register allocation to the
            // compiler over the host's full file; credit them the larger
            // (AArch64) file so availability is not artificially masked.
            Isa::Scalar | Isa::Portable | Isa::Neon => 32,
            Isa::Avx2 => 16,
        }
    }

    /// Edge availability under this ISA's register file (paper Table 1):
    /// `F32` holds a 16-vector data working set plus twiddles and
    /// temporaries — feasible on a 32-register file (NEON — the paper's
    /// novel codelet — and the scalar/portable paths, where the compiler
    /// spills invisibly), impossible on AVX2's 16 registers. Everything
    /// else is realizable everywhere.
    pub fn supports(self, edge: EdgeType) -> bool {
        !(self == Isa::Avx2 && edge == EdgeType::F32)
    }

    /// Whether `SPFFT_FORCE_SCALAR` requests the scalar fallback (set
    /// and not `"0"`).
    pub fn force_scalar_requested() -> bool {
        match std::env::var("SPFFT_FORCE_SCALAR") {
            Ok(v) => !v.is_empty() && v != "0",
            Err(_) => false,
        }
    }

    /// The best ISA this host can execute: the scalar fallback when
    /// forced ([`Isa::force_scalar_requested`]), otherwise the native
    /// SIMD tier (NEON on aarch64, AVX2 on x86-64 when detected), then
    /// the portable backend when compiled in, then scalar.
    pub fn detect() -> Isa {
        if Isa::force_scalar_requested() {
            Isa::Scalar
        } else {
            native_isa()
        }
    }
}

#[cfg(target_arch = "aarch64")]
fn native_isa() -> Isa {
    Isa::Neon
}

#[cfg(target_arch = "x86_64")]
fn native_isa() -> Isa {
    if std::arch::is_x86_feature_detected!("avx2") {
        Isa::Avx2
    } else {
        portable_or_scalar()
    }
}

#[cfg(not(any(target_arch = "aarch64", target_arch = "x86_64")))]
fn native_isa() -> Isa {
    portable_or_scalar()
}

#[allow(dead_code)] // unreferenced on aarch64, where NEON is baseline
fn portable_or_scalar() -> Isa {
    if cfg!(feature = "portable-simd") {
        Isa::Portable
    } else {
        Isa::Scalar
    }
}

impl fmt::Display for Isa {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::edge::ALL_EDGES;

    #[test]
    fn name_parse_roundtrip() {
        for isa in ALL_ISAS {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse("sse2"), None);
        assert_eq!(Isa::parse(""), None);
    }

    #[test]
    fn index_roundtrip() {
        for (i, isa) in ALL_ISAS.iter().enumerate() {
            assert_eq!(isa.index(), i);
            assert_eq!(Isa::from_index(i), Some(*isa));
        }
        assert_eq!(Isa::from_index(NUM_ISAS), None);
    }

    #[test]
    fn only_avx2_masks_f32() {
        for isa in ALL_ISAS {
            for e in ALL_EDGES {
                let expect = !(isa == Isa::Avx2 && e == EdgeType::F32);
                assert_eq!(isa.supports(e), expect, "{isa} {e:?}");
            }
            // The boundary edge is ISA-invariant (pure shuffles).
            assert!(isa.supports(EdgeType::RU));
        }
    }

    #[test]
    fn detect_returns_an_executable_isa() {
        // Whatever the host, detect() must name an ISA whose kernel
        // table resolves (possibly to the scalar fallback) — pinned
        // end-to-end in fft::simd tests; here just check stability.
        assert_eq!(Isa::detect(), Isa::detect());
    }

    #[test]
    fn display_matches_name() {
        assert_eq!(Isa::Avx2.to_string(), "avx2");
        assert_eq!(Isa::Scalar.to_string(), "scalar");
    }
}
